"""End-to-end training driver: a ~25M-param TinyLlama-family model for a
few hundred steps on the synthetic pipeline, with checkpointing.

Run:  PYTHONPATH=src python examples/train_tinyllama.py [--steps 300]
"""
import argparse
import sys

from repro.launch.train_launch import main as train_main

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    args, _ = ap.parse_known_args()
    sys.argv = [
        "train", "--arch", "tinyllama-1.1b", "--steps", str(args.steps),
        "--batch", "8", "--seq", "128", "--lr", "1e-3",
        "--microbatches", "2", "--ckpt", "/tmp/repro_tinyllama.npz",
        "--log-every", "20",
    ]
    train_main()
