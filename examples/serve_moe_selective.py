"""Serve a MoE model (kimi-k2 family, reduced) with mixed det/nondet
traffic — the family where router flips make DVR matter most.

Run:  PYTHONPATH=src python examples/serve_moe_selective.py
"""
import sys

from repro.launch.serve import main as serve_main

if __name__ == "__main__":
    sys.argv = [
        "serve", "--arch", "kimi-k2-1t-a32b", "--requests", "8",
        "--det-ratio", "0.25", "--max-new", "24", "--mode", "llm42",
        "--window", "6", "--group", "2",
    ]
    serve_main()
