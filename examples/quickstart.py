"""Quickstart: selective determinism in 30 lines.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.configs import get_smoke_config
from repro.core.determinism import Mode
from repro.models import init_params
from repro.serving.engine import Engine
from repro.serving.request import Request, SamplingParams

cfg = get_smoke_config("llama3-8b")  # reduced Llama-3.1-8B (CPU-runnable)
params = init_params(cfg, jax.random.key(0))

engine = Engine(cfg, params, mode=Mode.LLM42, window=8, group=2,
                max_batch=8, capacity=256)

# one request NEEDS determinism (audit/eval); the rest are free-running
for i in range(4):
    engine.submit(Request(
        rid=i,
        prompt=[7 * i + j for j in range(8)],
        sampling=SamplingParams(
            max_new_tokens=24,
            is_deterministic=(i == 0),  # the paper's per-request API flag
            seed=42,
        ),
    ))

for r in sorted(engine.run(), key=lambda r: r.rid):
    tag = "DET  " if r.sampling.is_deterministic else "fast "
    print(f"[{tag}] req {r.rid}: {r.committed}")
    if r.sampling.is_deterministic:
        print(f"         rollbacks={r.num_rollbacks} "
              f"recomputed={r.num_recomputed_tokens} "
              f"(identical on every rerun, any co-traffic)")
