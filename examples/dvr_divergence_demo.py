"""Demonstrates the paper's O1 phenomenon end-to-end:

1. fast-path decoding DIVERGES across batch compositions (floating-point
   reduction-order drift amplified autoregressively), and
2. DVR repairs it: the deterministic request's committed output is
   bitwise identical across all traffic mixes.

Run:  PYTHONPATH=src python examples/dvr_divergence_demo.py
"""
import jax

from repro.configs import get_smoke_config
from repro.core.determinism import Mode, ReductionPolicy
from repro.core.spans import consistent_spans
from repro.models import init_params
from repro.serving.engine import Engine
from repro.serving.request import Request, SamplingParams

cfg = get_smoke_config("llama3-8b")
params = init_params(cfg, jax.random.key(0))
policy = ReductionPolicy(thresholds=((2, 16), (4, 8), (8, 4)),
                         combine_dtype="bfloat16")
PROMPT = list(range(1, 11))


def run(n_neighbours, deterministic):
    eng = Engine(cfg, params, mode=Mode.LLM42 if deterministic else Mode.NONDET,
                 policy=policy, window=6, group=2, max_batch=8, capacity=256)
    eng.submit(Request(rid=0, prompt=PROMPT, sampling=SamplingParams(
        max_new_tokens=48, is_deterministic=deterministic, seed=7)))
    for i in range(n_neighbours):
        eng.submit(Request(rid=1 + i, prompt=[3 * i + k for k in range(6)],
                           sampling=SamplingParams(max_new_tokens=48)))
    out = {r.rid: r for r in eng.run()}
    return out[0]


print("=== fast path only (NONDET): same request, different co-traffic ===")
alone = run(0, False).committed
for n in (3, 6):
    other = run(n, False).committed
    s = consistent_spans(alone, other)
    print(f"  vs {n} neighbours: first consistent span {s.first_span}/{s.total}, "
          f"second span {s.second_span}  (diverged: {alone != other})")

print("=== with DVR (LLM42): determinism enforced by verification ===")
a = run(0, True)
for n in (3, 6):
    b = run(n, True)
    print(f"  vs {n} neighbours: identical={a.committed == b.committed} "
          f"rollbacks={b.num_rollbacks} recomputed={b.num_recomputed_tokens}")
