"""Split-K GEMM Pallas kernel (TPU target; validated interpret=True on CPU).

TPU adaptation of CUDA split-K (DESIGN.md §2): there are no atomics and the
grid is walked sequentially per core, so "split-K" here means the K axis is
the *minor grid dimension* and each K-chunk's f32 partial is folded into a
VMEM accumulator **rounded through combine_dtype between chunks** — the same
reduction tree as a CUDA split-K partial-sum epilogue, and bit-identical to
``ref.gemm_splitk``.

Blocking: (bm x bn) output tile resident in VMEM f32 scratch; each grid step
streams a (bm x bk) x (bk x bn) pair through the MXU.  bk = K / splits, so
the *number of partials* — the shape of the reduction tree — is the
schedule's split count.  MXU alignment: bm, bn multiples of 128 when the
problem allows (ops.py pads).
"""

# det: fastpath
# This file implements the licensed speculative fast path: its split
# schedules are batch-adaptive BY DESIGN and the taint pass proves them
# unreachable from the commit side.
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

F32 = jnp.float32


def _kernel(x_ref, w_ref, o_ref, acc_ref, *, splits: int, combine_dtype: str):
    s = pl.program_id(2)  # K-split index (minor grid dim)
    cd = jnp.dtype(combine_dtype)

    partial = jnp.dot(
        x_ref[...].astype(F32), w_ref[...].astype(F32),
        preferred_element_type=F32,
    )
    if splits > 1:
        # round each partial through the combine dtype (split-K epilogue
        # semantics); an unsplit GEMM is a single pure-f32 reduction
        partial = partial.astype(cd).astype(F32)

    @pl.when(s == 0)
    def _init():
        acc_ref[...] = partial

    @pl.when(s > 0)
    def _fold():
        folded = (acc_ref[...] + partial).astype(cd).astype(F32)
        acc_ref[...] = folded

    @pl.when(s == splits - 1)
    def _emit():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("splits", "combine_dtype", "bm", "bn", "interpret")
)
def gemm_splitk(
    x: jax.Array,  # (M, K)
    w: jax.Array,  # (K, N)
    *,
    splits: int = 4,
    combine_dtype: str = "float32",
    bm: int = 128,
    bn: int = 128,
    interpret: bool = True,
) -> jax.Array:
    M, K = x.shape
    K2, N = w.shape
    assert K == K2 and K % splits == 0, (x.shape, w.shape, splits)
    bm = min(bm, M)
    bn = min(bn, N)
    assert M % bm == 0 and N % bn == 0, "ops.py pads to block multiples"
    bk = K // splits

    grid = (M // bm, N // bn, splits)
    return pl.pallas_call(
        functools.partial(_kernel, splits=splits, combine_dtype=combine_dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, s: (i, s)),
            pl.BlockSpec((bk, bn), lambda i, j, s: (s, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), F32)],
        interpret=interpret,
    )(x, w)
