"""Batch-invariant GEMM Pallas kernel — the He-et-al. baseline (paper §2.3).

One *universal* reduction schedule for every input shape: fixed K-block size
walked in a fixed order, all accumulation in f32, no split-K, no
shape-adaptive tiling.  Each output row's reduction tree is therefore
independent of the batch dimension M — batch-invariant — at the cost of the
shape-adaptive parallelism a tuned kernel would exploit (the performance gap
quantified in paper Fig. 4a and our fig4 benchmark).

The fixed f32 K-walk accumulates without intermediate rounding, so for any
M this matches ``ref.gemm_batch_invariant`` (a single-pass f32 reduction)
bitwise up to f32 dot associativity of the backend — in interpret mode the
jnp.dot inside each block is the same single-pass reduction as the oracle's.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

F32 = jnp.float32

#: The universal schedule's fixed blocks.  NEVER shape-dependent — a
#: shape-adaptive block size would change the within-block reduction
#: geometry with batch size, which is exactly the non-invariance being
#: eliminated.  Inputs are padded up to block multiples instead.
UNIVERSAL_BK = 512
UNIVERSAL_BM = 128
UNIVERSAL_BN = 128


def _kernel(x_ref, w_ref, o_ref, acc_ref, *, k_steps: int):
    s = pl.program_id(2)

    partial = jnp.dot(
        x_ref[...].astype(F32), w_ref[...].astype(F32),
        preferred_element_type=F32,
    )

    @pl.when(s == 0)
    def _init():
        acc_ref[...] = partial

    @pl.when(s > 0)
    def _fold():
        acc_ref[...] = acc_ref[...] + partial  # pure f32, no rounding

    @pl.when(s == k_steps - 1)
    def _emit():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def gemm_batch_invariant(
    x: jax.Array,  # (M, K)
    w: jax.Array,  # (K, N)
    *,
    interpret: bool = True,
) -> jax.Array:
    M, K = x.shape
    K2, N = w.shape
    assert K == K2
    bm, bn = UNIVERSAL_BM, UNIVERSAL_BN
    bk = UNIVERSAL_BK
    # pad everything to the universal block grid (zero K-padding does not
    # perturb the f32 accumulation: the extra products are exact zeros)
    Mp, Np, Kp = (-M) % bm + M, (-N) % bn + N, (-K) % bk + K
    xp = jnp.pad(x, ((0, Mp - M), (0, Kp - K)))
    wp = jnp.pad(w, ((0, Kp - K), (0, Np - N)))
    k_steps = Kp // bk

    out = pl.pallas_call(
        functools.partial(_kernel, k_steps=k_steps),
        grid=(Mp // bm, Np // bn, k_steps),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, s: (i, s)),
            pl.BlockSpec((bk, bn), lambda i, j, s: (s, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), F32)],
        interpret=interpret,
    )(xp, wp)
    return out[:M, :N]
