"""Pure-jnp oracles for every Pallas kernel in this package.

These define the *numerical contract* each kernel must satisfy bitwise
(or to tight tolerance) in interpret mode.  The split-K / split-KV refs are
the same reduction-tree semantics as ``repro.core.determinism`` — the model
zoo's jnp fallback path — so kernel == ref == model numerics.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.determinism import Schedule, matmul as _sched_matmul

F32 = jnp.float32


def gemm_splitk(x: jax.Array, w: jax.Array, splits: int,
                combine_dtype: str = "float32") -> jax.Array:
    """Split-K GEMM oracle: per-chunk f32 reduction, sequential combine in
    combine_dtype.  x: (M, K), w: (K, N)."""
    return _sched_matmul(x, w, Schedule(splits=splits, combine_dtype=combine_dtype))


def gemm_batch_invariant(x: jax.Array, w: jax.Array) -> jax.Array:
    """Universal-schedule GEMM oracle: one f32 reduction pass, no splits."""
    return _sched_matmul(x, w, Schedule(splits=1))


def decode_attention(
    q: jax.Array,        # (B, H, D)
    k: jax.Array,        # (B, S, KV, D)
    v: jax.Array,        # (B, S, KV, D)
    lengths: jax.Array,  # (B,) number of valid cache positions
    kv_splits: int,
    combine_dtype: str = "float32",
) -> jax.Array:
    """Flash-decode oracle: chunked softmax with LSE combine in combine_dtype."""
    B, H, D = q.shape
    S, KV = k.shape[1], k.shape[2]
    G = H // KV
    qg = (q.reshape(B, KV, G, D) * (D**-0.5)).astype(F32)
    kf, vf = k.astype(F32), v.astype(F32)
    pos = jnp.arange(S)[None, :]  # (1, S)
    valid = pos < lengths[:, None]  # (B, S)

    cd = jnp.dtype(combine_dtype)
    base, rem = divmod(S, kv_splits)
    sizes = [base + (1 if i < rem else 0) for i in range(kv_splits)]
    m_acc = d_acc = o_acc = None
    start = 0
    for size in sizes:
        kc = jax.lax.slice_in_dim(kf, start, start + size, axis=1)
        vc = jax.lax.slice_in_dim(vf, start, start + size, axis=1)
        mc = jax.lax.slice_in_dim(valid, start, start + size, axis=1)
        s = jnp.einsum("bkgd,bskd->bkgs", qg, kc,
                       precision=jax.lax.Precision.HIGHEST)
        s = jnp.where(mc[:, None, None, :], s, -jnp.inf)
        m_c = jnp.maximum(jnp.max(s, axis=-1), -1e30)
        e = jnp.exp(s - m_c[..., None])
        d_c = jnp.sum(e, axis=-1)
        o_c = jnp.einsum("bkgs,bskd->bkgd", e, vc,
                         precision=jax.lax.Precision.HIGHEST)
        if m_acc is None:
            m_acc, d_acc, o_acc = m_c, d_c.astype(cd), o_c.astype(cd)
        else:
            m_new = jnp.maximum(m_acc, m_c)
            a1, a2 = jnp.exp(m_acc - m_new), jnp.exp(m_c - m_new)
            d_acc = (a1 * d_acc.astype(F32) + a2 * d_c).astype(cd)
            o_acc = (a1[..., None] * o_acc.astype(F32) + a2[..., None] * o_c).astype(cd)
            m_acc = m_new
        start += size
    out = o_acc.astype(F32) / jnp.maximum(d_acc.astype(F32), 1e-30)[..., None]
    return out.reshape(B, H, D).astype(q.dtype)


def paged_attention(
    q: jax.Array,         # (B, H, D)
    k_pool: jax.Array,    # (NB, bs, KV, D) global block pool
    v_pool: jax.Array,    # (NB, bs, KV, D)
    pos_pool: jax.Array,  # (NB, bs) int32 absolute positions, -1 = empty
    tables: jax.Array,    # (B, nblk) int32 block ids, -1 = unallocated
    q_pos: jax.Array,     # (B,) int32 absolute query position
    *,
    null_bid: int | None = None,
    kv_splits: int = 1,
    combine_dtype: str = "float32",
) -> jax.Array:
    """Paged-attention oracle: gather the per-row view through the block
    table (``-1`` entries read the null block, masked via ``pos == -1``),
    then run the same softmax semantics as ``decode_attention`` over it.
    ``kv_splits=1`` is the commit-path universal schedule: a single-pass
    f32 softmax whose reduction extent is the fixed table reach."""
    B, H, D = q.shape
    NB, bs = k_pool.shape[0], k_pool.shape[1]
    KV = k_pool.shape[2]
    nblk = tables.shape[1]
    nb = (NB - 2) if null_bid is None else null_bid
    tab = jnp.where(tables < 0, nb, tables)
    kf = k_pool[tab].reshape(B, nblk * bs, KV, D).astype(F32)
    vf = v_pool[tab].reshape(B, nblk * bs, KV, D).astype(F32)
    pos = pos_pool[tab].reshape(B, nblk * bs)
    valid = (pos >= 0) & (pos <= q_pos[:, None])  # (B, S)

    G = H // KV
    qg = (q.reshape(B, KV, G, D) * (D**-0.5)).astype(F32)
    cd = jnp.dtype(combine_dtype)
    S = nblk * bs
    base, rem = divmod(S, kv_splits)
    sizes = [base + (1 if i < rem else 0) for i in range(kv_splits)]
    m_acc = d_acc = o_acc = None
    start = 0
    for size in sizes:
        kc = jax.lax.slice_in_dim(kf, start, start + size, axis=1)
        vc = jax.lax.slice_in_dim(vf, start, start + size, axis=1)
        mc = jax.lax.slice_in_dim(valid, start, start + size, axis=1)
        s = jnp.einsum("bkgd,bskd->bkgs", qg, kc,
                       precision=jax.lax.Precision.HIGHEST)
        s = jnp.where(mc[:, None, None, :], s, -jnp.inf)
        m_c = jnp.maximum(jnp.max(s, axis=-1), -1e30)
        e = jnp.exp(s - m_c[..., None])
        d_c = jnp.sum(e, axis=-1)
        o_c = jnp.einsum("bkgs,bskd->bkgd", e, vc,
                         precision=jax.lax.Precision.HIGHEST)
        if m_acc is None:
            m_acc, d_acc, o_acc = m_c, d_c.astype(cd), o_c.astype(cd)
        else:
            m_new = jnp.maximum(m_acc, m_c)
            a1, a2 = jnp.exp(m_acc - m_new), jnp.exp(m_c - m_new)
            d_acc = (a1 * d_acc.astype(F32) + a2 * d_c).astype(cd)
            o_acc = (a1[..., None] * o_acc.astype(F32) + a2[..., None] * o_c).astype(cd)
            m_acc = m_new
        start += size
    out = o_acc.astype(F32) / jnp.maximum(d_acc.astype(F32), 1e-30)[..., None]
    return out.reshape(B, H, D).astype(F32)


def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-5,
            residual: jax.Array | None = None) -> jax.Array:
    """Fused (residual-add +) RMSNorm oracle; f32 single-pass reduction."""
    if residual is not None:
        x = (x.astype(F32) + residual.astype(F32)).astype(x.dtype)
    xf = x.astype(F32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * scale.astype(F32)
    return out.astype(x.dtype)
