"""Paged decode/verify attention: K/V read through the block table.

vLLM-paged-attention-shaped: K/V live in a global pool of fixed-size blocks
``(num_blocks + 2, block_size, KV, D)`` with no batch axis; each row owns a
table of block indices (``-1`` = unallocated, mapped to the pool's *null
block* whose positions are ``-1`` and therefore always masked).  The kernel
assembles the row's view inside the launch — the host-side gather copy the
legacy path paid per iteration never materializes.

Two variants, per the determinism contract:

* ``paged_attention`` — the commit-path kernel.  Grid ``(B, KV)`` carries no
  reduction axes at all (both axes index the output tile); the block-table
  walk is a ``fori_loop`` whose chunk size is the literal ``block_size`` and
  whose trip count is the table reach, so the reduction tree over keys is a
  single fixed-shape f32 softmax — exactly the universal schedule
  ``kernels/ref.py`` defines.  It must stay clean under
  ``repro.analysis.kernel_lint``.
* ``paged_attention_fast`` — the licensed fast path: kv-split flash-decode
  over the table (grid ``(B, KV, kv_splits)``), merging per-split partials
  through f32 VMEM scratch.  Split count follows the workload, so its
  schedule is nondeterministic by design and the function is exempted with
  ``# det: fastpath`` (the taint pass proves it unreachable from the commit
  side).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

F32 = jnp.float32


def _gather_view(kp_ref, vp_ref, pp_ref, tab_ref, *, lo, n_blocks, block_size, d):
    """Assemble ``n_blocks`` table blocks starting at ``lo`` into one view.

    Returns f32 ``(n_blocks * block_size, d)`` K and V plus the int32
    position vector.  The walk order and chunk size are static, so the
    assembled view — and every reduction over it — has a fixed shape.
    """
    size = n_blocks * block_size

    def body(j, carry):
        kv, vv, pv = carry
        bid = tab_ref[0, lo + j]
        kb = pl.load(
            kp_ref, (pl.dslice(bid, 1), slice(None), slice(None), slice(None))
        )
        vb = pl.load(
            vp_ref, (pl.dslice(bid, 1), slice(None), slice(None), slice(None))
        )
        pb = pl.load(pp_ref, (pl.dslice(bid, 1), slice(None)))
        off = j * block_size
        kv = jax.lax.dynamic_update_slice(
            kv, kb.reshape(block_size, d).astype(F32), (off, 0)
        )
        vv = jax.lax.dynamic_update_slice(
            vv, vb.reshape(block_size, d).astype(F32), (off, 0)
        )
        pv = jax.lax.dynamic_update_slice(pv, pb.reshape(block_size), (off,))
        return kv, vv, pv

    init = (
        jnp.zeros((size, d), F32),
        jnp.zeros((size, d), F32),
        jnp.full((size,), -1, jnp.int32),
    )
    return jax.lax.fori_loop(0, n_blocks, body, init)


def _paged_kernel(
    q_ref, kp_ref, vp_ref, pp_ref, tab_ref, qpos_ref, o_ref, *, blocks_per_row,
    block_size, scale
):
    # q_ref (1, 1, G, D); pools (NB, bs, 1, D) / (NB, bs); tab_ref (1, nblk)
    q = q_ref[0, 0].astype(F32) * scale  # (G, D)
    d = q.shape[-1]
    kv, vv, pv = _gather_view(
        kp_ref, vp_ref, pp_ref, tab_ref,
        lo=0, n_blocks=blocks_per_row, block_size=block_size, d=d,
    )
    qp = qpos_ref[0, 0]
    s = jnp.dot(q, kv.T, preferred_element_type=F32)  # (G, S)
    valid = (pv >= 0) & (pv <= qp)
    s = jnp.where(valid[None, :], s, -jnp.inf)
    m = jnp.maximum(jnp.max(s, axis=-1, keepdims=True), -1e30)
    e = jnp.exp(s - m)
    denom = jnp.sum(e, axis=-1, keepdims=True)
    o = jnp.dot(e, vv, preferred_element_type=F32) / jnp.maximum(denom, 1e-30)
    o_ref[0, 0] = o.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("null_bid", "interpret"))
def paged_attention(
    q: jax.Array,  # (B, H, D)
    k_pool: jax.Array,  # (NB, bs, KV, D)
    v_pool: jax.Array,  # (NB, bs, KV, D)
    pos_pool: jax.Array,  # (NB, bs) int32, -1 = empty
    tables: jax.Array,  # (B, nblk) int32 block ids, -1 = unallocated
    q_pos: jax.Array,  # (B,) int32 absolute query position
    *,
    null_bid: int | None = None,
    interpret: bool = True,
) -> jax.Array:
    """Commit-path paged attention: one fixed-shape f32 softmax per row."""
    B, H, D = q.shape
    NB, bs, KVH, _ = k_pool.shape
    nblk = tables.shape[1]
    qg = q.reshape(B, KVH, H // KVH, D)
    B, KV, G, D = qg.shape
    sentinel = (NB - 2) if null_bid is None else null_bid
    tab = jnp.where(tables < 0, sentinel, tables).astype(jnp.int32)
    qp = q_pos.reshape(B, 1).astype(jnp.int32)
    out = pl.pallas_call(
        functools.partial(
            _paged_kernel,
            blocks_per_row=nblk,
            block_size=bs,
            scale=D ** -0.5,
        ),
        grid=(B, KV),
        in_specs=[
            pl.BlockSpec((1, 1, G, D), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((NB, bs, 1, D), lambda b, h: (0, 0, h, 0)),
            pl.BlockSpec((NB, bs, 1, D), lambda b, h: (0, 0, h, 0)),
            pl.BlockSpec((NB, bs), lambda b, h: (0, 0)),
            pl.BlockSpec((1, nblk), lambda b, h: (b, 0)),
            pl.BlockSpec((1, 1), lambda b, h: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, h: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KV, G, D), F32),
        interpret=interpret,
    )(qg, k_pool, v_pool, pos_pool, tab, qp)
    return out.reshape(B, H, D)


# det: fastpath
def _paged_fast_kernel(
    q_ref, kp_ref, vp_ref, pp_ref, tab_ref, qpos_ref, o_ref, m_ref, d_ref,
    acc_ref, *, kv_splits, blocks_per_split, block_size, scale, combine_dtype
):
    s_idx = pl.program_id(2)
    q = q_ref[0, 0].astype(F32) * scale  # (G, D)
    d = q.shape[-1]
    kv, vv, pv = _gather_view(
        kp_ref, vp_ref, pp_ref, tab_ref,
        lo=s_idx * blocks_per_split, n_blocks=blocks_per_split,
        block_size=block_size, d=d,
    )
    qp = qpos_ref[0, 0]
    s = jnp.dot(q, kv.T, preferred_element_type=F32)
    valid = (pv >= 0) & (pv <= qp)
    s = jnp.where(valid[None, :], s, -jnp.inf)
    m_c = jnp.maximum(jnp.max(s, axis=-1), -1e30)  # (G,)
    e = jnp.exp(s - m_c[:, None]).astype(combine_dtype)
    d_c = jnp.sum(e, axis=-1)  # (G,)
    o_c = jnp.dot(e.astype(F32), vv, preferred_element_type=F32)  # (G, D)

    @pl.when(s_idx == 0)
    def _init():
        m_ref[...] = m_c
        d_ref[...] = d_c.astype(F32)
        acc_ref[...] = o_c

    @pl.when(s_idx > 0)
    def _merge():
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, m_c)
        a_prev = jnp.exp(m_prev - m_new)
        a_c = jnp.exp(m_c - m_new)
        m_ref[...] = m_new
        d_ref[...] = d_ref[...] * a_prev + d_c.astype(F32) * a_c
        acc_ref[...] = acc_ref[...] * a_prev[:, None] + o_c * a_c[:, None]

    @pl.when(s_idx == kv_splits - 1)
    def _emit():
        denom = jnp.maximum(d_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / denom[:, None]).astype(o_ref.dtype)


# det: fastpath
@functools.partial(
    jax.jit, static_argnames=("kv_splits", "combine_dtype", "null_bid", "interpret")
)
def paged_attention_fast(
    q: jax.Array,
    k_pool: jax.Array,
    v_pool: jax.Array,
    pos_pool: jax.Array,
    tables: jax.Array,
    q_pos: jax.Array,
    *,
    kv_splits: int = 1,
    combine_dtype: str = "float32",
    null_bid: int | None = None,
    interpret: bool = True,
) -> jax.Array:
    """Fast-path paged attention: kv-split flash-decode over the table."""
    B, H, D = q.shape
    NB, bs, KVH, _ = k_pool.shape
    nblk = tables.shape[1]
    if nblk % kv_splits != 0:
        raise ValueError(f"kv_splits={kv_splits} must divide table reach {nblk}")
    qg = q.reshape(B, KVH, H // KVH, D)
    B, KV, G, D = qg.shape
    sentinel = (NB - 2) if null_bid is None else null_bid
    tab = jnp.where(tables < 0, sentinel, tables).astype(jnp.int32)
    qp = q_pos.reshape(B, 1).astype(jnp.int32)
    out = pl.pallas_call(
        functools.partial(
            _paged_fast_kernel,
            kv_splits=kv_splits,
            blocks_per_split=nblk // kv_splits,
            block_size=bs,
            scale=D ** -0.5,
            combine_dtype=jnp.dtype(combine_dtype),
        ),
        grid=(B, KV, kv_splits),
        in_specs=[
            pl.BlockSpec((1, 1, G, D), lambda b, h, s: (b, h, 0, 0)),
            pl.BlockSpec((NB, bs, 1, D), lambda b, h, s: (0, 0, h, 0)),
            pl.BlockSpec((NB, bs, 1, D), lambda b, h, s: (0, 0, h, 0)),
            pl.BlockSpec((NB, bs), lambda b, h, s: (0, 0)),
            pl.BlockSpec((1, nblk), lambda b, h, s: (b, 0)),
            pl.BlockSpec((1, 1), lambda b, h, s: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, h, s: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KV, G, D), F32),
        scratch_shapes=[
            pltpu.VMEM((G,), F32),
            pltpu.VMEM((G,), F32),
            pltpu.VMEM((G, D), F32),
        ],
        interpret=interpret,
    )(qg, k_pool, v_pool, pos_pool, tab, qp)
    return out.reshape(B, H, D)
