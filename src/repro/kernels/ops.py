"""Jit'd dispatch wrappers for the Pallas kernels.

Handles shape padding (block-multiple M/N, split-multiple K/S) and backend
selection: ``impl="pallas"`` runs the Pallas kernel (interpret=True on CPU,
compiled on TPU), ``impl="jnp"`` runs the pure-jnp reference semantics from
``repro.core.determinism`` (bit-identical contract, fast on CPU).  The
serving engine uses the jnp path on CPU; the Pallas path is the TPU-target
implementation validated against the same oracle.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.core.determinism import Schedule
from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention as _pallas_decode_attn
from repro.kernels.gemm_batch_invariant import gemm_batch_invariant as _pallas_bi
from repro.kernels.gemm_splitk import gemm_splitk as _pallas_splitk
from repro.kernels.paged_attention import (
    paged_attention as _pallas_paged_attn,
    paged_attention_fast as _pallas_paged_attn_fast,
)
from repro.kernels.rmsnorm import rmsnorm as _pallas_rmsnorm


def _pad_to(x: jax.Array, axis: int, multiple: int) -> jax.Array:
    n = x.shape[axis]
    pad = (-n) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def matmul(
    x: jax.Array,
    w: jax.Array,
    schedule: Schedule,
    *,
    impl: str = "auto",
) -> jax.Array:
    """Schedule-aware GEMM.  x: (..., K), w: (K, N).

    ``schedule.tp_shards > 1`` decomposes K into the mesh chunks of the
    canonical TP reduction *above* the local split schedule: each chunk runs
    the local kernel on f32 inputs (one device's shard arithmetic), then the
    partials combine by the pinned balanced tree (commit path) or
    sequentially in combine_dtype (un-pinned fast path) — same semantics as
    the jnp reference in ``repro.core.determinism``.
    """
    if impl == "auto":
        impl = "pallas" if on_tpu() else "jnp"
    if impl == "jnp":
        from repro.core.determinism import matmul as jnp_matmul

        return jnp_matmul(x, w, schedule)

    K = x.shape[-1]
    if schedule.tp_shards > 1 and schedule.tp_shards <= K:
        from repro.core.determinism import _split_sizes, tree_combine

        local = schedule._replace(tp_shards=1, tp_pinned=False)
        parts = []
        start = 0
        for size in _split_sizes(K, schedule.tp_shards):
            xc = jax.lax.slice_in_dim(x, start, start + size, axis=x.ndim - 1)
            wc = jax.lax.slice_in_dim(w, start, start + size, axis=0)
            parts.append(
                matmul(
                    xc.astype(jnp.float32), wc.astype(jnp.float32),
                    local, impl=impl,
                )
            )
            start += size
        if schedule.tp_pinned:
            acc = tree_combine(parts)
        else:
            cd = jnp.dtype(schedule.combine_dtype)
            acc = None
            for p in parts:
                pc = p.astype(cd)
                acc = pc if acc is None else (acc + pc)
        return acc.astype(x.dtype)

    lead = x.shape[:-1]
    x2 = x.reshape(-1, K)
    M = x2.shape[0]
    bm = 128 if M >= 128 else max(8, M)
    xp = _pad_to(x2, 0, bm)
    wp = _pad_to(w, 1, 128) if w.shape[1] % 128 else w
    splits = schedule.splits if K % max(schedule.splits, 1) == 0 else 1
    out = _pallas_splitk(
        xp, wp, splits=max(splits, 1), combine_dtype=schedule.combine_dtype,
        bm=bm, bn=min(128, wp.shape[1]), interpret=not on_tpu(),
    )
    return out[: M, : w.shape[1]].reshape(*lead, w.shape[1])


def matmul_batch_invariant(x: jax.Array, w: jax.Array, *, impl: str = "auto") -> jax.Array:
    if impl == "auto":
        impl = "pallas" if on_tpu() else "jnp"
    if impl == "jnp":
        return ref.gemm_batch_invariant(x, w)
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    out = _pallas_bi(x2, w, interpret=not on_tpu())  # pads internally
    return out.reshape(*lead, w.shape[1])


def decode_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    lengths: jax.Array,
    schedule: Schedule,
    *,
    impl: str = "auto",
) -> jax.Array:
    if impl == "auto":
        impl = "pallas" if on_tpu() else "jnp"
    S = k.shape[1]
    splits = schedule.kv_splits if S % max(schedule.kv_splits, 1) == 0 else 1
    if impl == "jnp":
        return ref.decode_attention(
            q, k, v, lengths, max(splits, 1), schedule.combine_dtype
        )
    return _pallas_decode_attn(
        q, k, v, lengths, kv_splits=max(splits, 1),
        combine_dtype=schedule.combine_dtype, interpret=not on_tpu(),
    )


def paged_attention(
    q: jax.Array,         # (B, H, D)
    k_pool: jax.Array,    # (NB, bs, KV, D)
    v_pool: jax.Array,    # (NB, bs, KV, D)
    pos_pool: jax.Array,  # (NB, bs)
    tables: jax.Array,    # (B, nblk)
    q_pos: jax.Array,     # (B,)
    schedule: Schedule,
    *,
    null_bid: int | None = None,
    impl: str = "auto",
) -> jax.Array:
    """Paged decode/verify attention reading K/V through the block table.

    ``schedule.kv_splits == 1`` selects the commit-path kernel (fixed-shape
    single-pass softmax, lint-clean); any other split count selects the
    ``# det: fastpath`` flash-decode variant.  Splits that do not divide the
    table reach fall back to 1, mirroring ``decode_attention``.
    """
    if impl == "auto":
        impl = "pallas" if on_tpu() else "jnp"
    nblk = tables.shape[1]
    splits = schedule.kv_splits if nblk % max(schedule.kv_splits, 1) == 0 else 1
    splits = max(splits, 1)
    if impl == "jnp":
        return ref.paged_attention(
            q, k_pool, v_pool, pos_pool, tables, q_pos,
            null_bid=null_bid, kv_splits=splits,
            combine_dtype=schedule.combine_dtype,
        )
    if splits == 1:
        return _pallas_paged_attn(
            q, k_pool, v_pool, pos_pool, tables, q_pos,
            null_bid=null_bid, interpret=not on_tpu(),
        )
    return _pallas_paged_attn_fast(
        q, k_pool, v_pool, pos_pool, tables, q_pos,
        kv_splits=splits, combine_dtype=schedule.combine_dtype,
        null_bid=null_bid, interpret=not on_tpu(),
    )


def rmsnorm(
    x: jax.Array,
    scale: jax.Array,
    residual: jax.Array | None = None,
    *,
    eps: float = 1e-5,
    impl: str = "auto",
) -> jax.Array:
    if impl == "auto":
        impl = "pallas" if on_tpu() else "jnp"
    if impl == "jnp":
        return ref.rmsnorm(x, scale, eps, residual)
    lead = x.shape[:-1]
    D = x.shape[-1]
    x2 = x.reshape(-1, D)
    M = x2.shape[0]
    bm = 128 if M >= 128 else max(1, M)
    xp = _pad_to(x2, 0, bm)
    rp = _pad_to(residual.reshape(-1, D), 0, bm) if residual is not None else None
    out = _pallas_rmsnorm(xp, scale, rp, eps=eps, bm=bm, interpret=not on_tpu())
    return out[:M].reshape(*lead, D)
