"""Flash-decode GQA attention Pallas kernel with a ``kv_splits`` schedule.

One query token per sequence attends over a KV cache.  The key axis is
partitioned into ``kv_splits`` chunks (FlashDecoding-style sequence
parallelism — paper §4.4 "Attention"); each chunk produces a local
(max, exp-sum, weighted-value) triple in f32, and the triples are merged
*sequentially in combine_dtype* as the split axis is the minor grid dim.

``kv_splits`` is the schedule knob: the fast path picks it from batch size
(more splits at small batch to fill the machine), the verifier pins it to 1.
Semantics are bit-identical to ``ref.decode_attention``.

Grid: (B, KV_heads, kv_splits); the G = H/KV query heads sharing a KV head
are processed together as an (G x D) MXU tile.  VMEM scratch holds the
running (m, d, o) triple for the current (b, kv) tile.
"""

# det: fastpath
# This file implements the licensed speculative fast path: its split
# schedules are batch-adaptive BY DESIGN and the taint pass proves them
# unreachable from the commit side.
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

F32 = jnp.float32


def _kernel(q_ref, k_ref, v_ref, valid_ref, o_ref, m_ref, d_ref, acc_ref,
            *, kv_splits: int, combine_dtype: str, scale: float):
    s = pl.program_id(2)
    cd = jnp.dtype(combine_dtype)

    q = q_ref[0, 0].astype(F32) * scale         # (G, D)
    k = k_ref[0, :, 0, :].astype(F32)           # (chunk, D)
    v = v_ref[0, :, 0, :].astype(F32)
    valid = valid_ref[0]                        # (chunk,)

    scores = jnp.dot(q, k.T, preferred_element_type=F32)  # (G, chunk)
    scores = jnp.where(valid[None, :], scores, -jnp.inf)
    m_c = jnp.maximum(jnp.max(scores, axis=-1), -1e30)    # (G,)
    e = jnp.exp(scores - m_c[:, None])
    d_c = jnp.sum(e, axis=-1)                             # (G,)
    o_c = jnp.dot(e, v, preferred_element_type=F32)       # (G, D)

    @pl.when(s == 0)
    def _init():
        m_ref[...] = m_c
        d_ref[...] = d_c.astype(cd).astype(F32)
        acc_ref[...] = o_c.astype(cd).astype(F32)

    @pl.when(s > 0)
    def _merge():
        m_prev, d_prev, o_prev = m_ref[...], d_ref[...], acc_ref[...]
        m_new = jnp.maximum(m_prev, m_c)
        a1 = jnp.exp(m_prev - m_new)
        a2 = jnp.exp(m_c - m_new)
        m_ref[...] = m_new
        d_ref[...] = (a1 * d_prev + a2 * d_c).astype(cd).astype(F32)
        acc_ref[...] = (a1[:, None] * o_prev + a2[:, None] * o_c).astype(cd).astype(F32)

    @pl.when(s == kv_splits - 1)
    def _emit():
        out = acc_ref[...] / jnp.maximum(d_ref[...], 1e-30)[:, None]
        o_ref[0, 0] = out.astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("kv_splits", "combine_dtype", "interpret")
)
def decode_attention(
    q: jax.Array,        # (B, H, D)
    k: jax.Array,        # (B, S, KV, D)
    v: jax.Array,        # (B, S, KV, D)
    lengths: jax.Array,  # (B,) valid cache positions
    *,
    kv_splits: int = 1,
    combine_dtype: str = "float32",
    interpret: bool = True,
) -> jax.Array:
    B, H, D = q.shape
    S, KV = k.shape[1], k.shape[2]
    G = H // KV
    assert S % kv_splits == 0, "ops.py pads the cache to a split multiple"
    chunk = S // kv_splits

    qg = q.reshape(B, KV, G, D)
    valid = (jnp.arange(S)[None, :] < lengths[:, None])  # (B, S)

    out = pl.pallas_call(
        functools.partial(
            _kernel, kv_splits=kv_splits, combine_dtype=combine_dtype,
            scale=D**-0.5,
        ),
        grid=(B, KV, kv_splits),
        in_specs=[
            pl.BlockSpec((1, 1, G, D), lambda b, h, s: (b, h, 0, 0)),
            pl.BlockSpec((1, chunk, 1, D), lambda b, h, s: (b, s, h, 0)),
            pl.BlockSpec((1, chunk, 1, D), lambda b, h, s: (b, s, h, 0)),
            pl.BlockSpec((1, chunk), lambda b, h, s: (b, s)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, h, s: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KV, G, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G,), F32),
            pltpu.VMEM((G,), F32),
            pltpu.VMEM((G, D), F32),
        ],
        interpret=interpret,
    )(qg, k, v, valid)
    return out.reshape(B, H, D)
