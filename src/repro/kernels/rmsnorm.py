"""Fused (residual +) RMSNorm Pallas kernel.

Position-invariant by construction (paper Table 2): the feature reduction is
a single f32 pass whose tree depends only on D, never on the number of rows,
so the same token produces the same bits at any batch size.  This is the
fused-CUDA-kernel analogue the paper benchmarks in Fig. 4b; the
batch-invariant *and* fast implementations coincide for RMSNorm on TPU,
which is itself a point the paper makes (only schedules must be pinned, not
kernels rewritten).

Grid: rows/bm; each step holds a (bm x D) tile in VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

F32 = jnp.float32


def _kernel(x_ref, scale_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(F32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps) * scale_ref[...].astype(F32)).astype(
        o_ref.dtype
    )


def _kernel_residual(x_ref, res_ref, scale_ref, o_ref, *, eps: float, out_dtype):
    x = (x_ref[...].astype(F32) + res_ref[...].astype(F32)).astype(out_dtype)
    xf = x.astype(F32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    o_ref[...] = (xf * jax.lax.rsqrt(var + eps) * scale_ref[...].astype(F32)).astype(
        o_ref.dtype
    )


@functools.partial(jax.jit, static_argnames=("eps", "bm", "interpret"))
def rmsnorm(
    x: jax.Array,  # (M, D)
    scale: jax.Array,  # (D,)
    residual: jax.Array | None = None,
    *,
    eps: float = 1e-5,
    bm: int = 128,
    interpret: bool = True,
) -> jax.Array:
    M, D = x.shape
    bm = min(bm, M)
    assert M % bm == 0, "ops.py pads rows"
    grid = (M // bm,)
    row_spec = pl.BlockSpec((bm, D), lambda i: (i, 0))
    scale_spec = pl.BlockSpec((D,), lambda i: (0,))
    if residual is None:
        return pl.pallas_call(
            functools.partial(_kernel, eps=eps),
            grid=grid,
            in_specs=[row_spec, scale_spec],
            out_specs=row_spec,
            out_shape=jax.ShapeDtypeStruct((M, D), x.dtype),
            interpret=interpret,
        )(x, scale)
    return pl.pallas_call(
        functools.partial(_kernel_residual, eps=eps, out_dtype=x.dtype),
        grid=grid,
        in_specs=[row_spec, row_spec, scale_spec],
        out_specs=row_spec,
        out_shape=jax.ShapeDtypeStruct((M, D), x.dtype),
        interpret=interpret,
    )(x, residual, scale)
