"""Fixed-size KV block pool: allocator + block-indexed device cache layout.

The dense cache manager bound one ``max_seq_len``-long KV ring to every
slot, so concurrency was capped by the *worst-case* footprint of a request
(ROADMAP: "heavy traffic from millions of users" wants memory-bounded
admission, not slot-bounded).  This module replaces that layout for
full-attention KV leaves with a **paged** one:

* the position axis of every full-attention leaf (``k``/``v``/``pos``,
  capacity-long) is re-cut into fixed-size **blocks**: a leaf shaped
  ``(..., B_slots, capacity, ...)`` becomes ``(..., num_blocks + 2,
  block_size, ...)`` — one global pool of blocks shared by all requests;
* a request owns an ordered **block table** (``Request.blocks``): block
  ``j`` holds its KV for absolute positions ``[j*bs, (j+1)*bs)``;
* :func:`gather` assembles, per batch row, a contiguous
  ``(B, view_capacity, ...)`` view by indexing blocks — the forward pass
  (and its ``pos``-mask) is completely unchanged; :func:`scatter` writes
  the view back through the table.

Blocks are **ref-counted** so the prefix cache (``serving.prefixcache``)
can map one committed-prefix block into many requests' tables read-only;
refcounts dropping to zero return a block to the free list (or leave it
resident-but-evictable when the prefix cache registered it).

Two sentinel block ids make fixed-shape views safe without per-row length
plumbing:

* ``null`` — a frozen all-empty block (``pos == -1`` everywhere, never
  written): table entries past a request's allocated extent *gather* from
  it, so the view tail is guaranteed masked out;
* ``scratch`` — a trash block that *absorbs* every write the scatter
  would otherwise direct at an unallocated table entry (the view tail
  pass-through, and verify-pass pad writes past the ensured extent).
  Scratch content is never gathered, so the junk is quarantined.

Recurrent O(1) state (mamba/rwkv), sliding-window rings (bounded at
``window + RING_SLACK``) and encdec cross caches keep the dense per-slot
layout — paging buys nothing for constant-size state; :func:`build_layout`
classifies every cache leaf once, by shape, into ``slot`` vs ``paged``.

Freed blocks are wiped (``pos`` leaves back to -1) before they can be
reallocated: a stale absolute position *smaller* than a new owner's query
position would otherwise mask garbage keys into attention.  (Stale
positions *ahead* of the query are harmless — the same shadowing argument
the verifier's pointer-free rollback already relies on.)
"""

from __future__ import annotations

import dataclasses
from typing import Any, List, Optional, Set

import jax
import jax.numpy as jnp

from repro.models.base import ModelConfig
from repro.models.transformer import cache_spec

#: shape sentinels for leaf classification (never collide with real dims)
_SENT_B = 1_000_003
_SENT_C = 1_000_033

#: default KV block size (tokens per block)
DEFAULT_BLOCK_SIZE = 16


# ---------------------------------------------------------------------------
# layout: classify cache leaves, size the paged storage
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LeafDesc:
    """Per-leaf addressing descriptor.  Deliberately NOT a pytree
    container, so an axes tree of these zips leaf-for-leaf with the cache
    tree under ``tree_map``."""

    kind: str  # "slot" (dense per-slot) | "paged" (block-cut)
    axis: int  # batch axis (paged: capacity axis is axis + 1)


@dataclasses.dataclass(frozen=True)
class Layout:
    """Static description of the paged cache layout (closed over by jits).

    ``axes`` mirrors the cache pytree with a :class:`LeafDesc` per leaf:
    ``slot`` for dense per-slot leaves, ``paged`` for block-cut leaves.
    """

    axes: Any
    block_size: int
    num_blocks: int  # real allocatable blocks (excludes null + scratch)
    blocks_per_table: int  # table width: ceil(capacity / block_size)
    has_paged: bool

    @property
    def null_bid(self) -> int:
        return self.num_blocks

    @property
    def scratch_bid(self) -> int:
        return self.num_blocks + 1

    @property
    def view_capacity(self) -> int:
        return self.blocks_per_table * self.block_size


def build_layout(
    cfg: ModelConfig, capacity: int, block_size: int, num_blocks: int
) -> Layout:
    """Classify every cache leaf by shape (sentinel batch/capacity dims)."""
    assert block_size >= 1
    spec = cache_spec(cfg, _SENT_B, _SENT_C)

    def classify(s: jax.ShapeDtypeStruct) -> LeafDesc:
        b = [i for i, d in enumerate(s.shape) if d == _SENT_B]
        assert len(b) == 1, f"ambiguous batch axis in {s.shape}"
        c = [i for i, d in enumerate(s.shape) if d == _SENT_C]
        if not c:
            return LeafDesc("slot", b[0])
        assert c == [b[0] + 1], f"capacity axis must follow batch in {s.shape}"
        return LeafDesc("paged", b[0])

    axes = jax.tree_util.tree_map(classify, spec)
    has_paged = any(
        d.kind == "paged" for d in jax.tree_util.tree_leaves(axes)
    )
    bpt = -(-capacity // block_size)
    return Layout(
        axes=axes, block_size=block_size, num_blocks=num_blocks,
        blocks_per_table=bpt, has_paged=has_paged,
    )


def init_cache(cfg: ModelConfig, lay: Layout, num_slots: int) -> Any:
    """Device storage: slot leaves carry ``num_slots + 1`` rows (+ scratch
    slot, as before); paged leaves carry ``num_blocks + 2`` blocks of
    ``block_size`` (+ null + scratch blocks)."""
    spec = cache_spec(cfg, _SENT_B, _SENT_C)

    def make(s: jax.ShapeDtypeStruct, desc: LeafDesc) -> jax.Array:
        if desc.kind == "slot":
            shape = tuple(
                num_slots + 1 if d == _SENT_B else d for d in s.shape
            )
        else:
            ax = desc.axis
            shape = (
                s.shape[:ax]
                + (lay.num_blocks + 2, lay.block_size)
                + s.shape[ax + 2:]
            )
        if s.dtype == jnp.int32:
            return jnp.full(shape, -1, s.dtype)  # pos slots start empty
        return jnp.zeros(shape, s.dtype)

    return jax.tree_util.tree_map(make, spec, lay.axes)


# ---------------------------------------------------------------------------
# device gather / scatter through block tables
# ---------------------------------------------------------------------------


def gather(pool: Any, lay: Layout, slots: jax.Array, tables: jax.Array) -> Any:
    """Per-row cache views: slot leaves index by ``slots`` (B,), paged
    leaves assemble ``(B, view_capacity, ...)`` from ``tables``
    (B, blocks_per_table) int32; ``-1`` table entries read the null block
    (always masked)."""
    B, nblk = tables.shape
    flat = jnp.where(tables < 0, lay.null_bid, tables).reshape(-1)

    def g(leaf, desc):
        ax = desc.axis
        if desc.kind == "slot":
            return jnp.take(leaf, slots, axis=ax)
        out = jnp.take(leaf, flat, axis=ax)  # (..., B*nblk, bs, ...)
        shape = leaf.shape[:ax] + (B, nblk * lay.block_size) + leaf.shape[ax + 2:]
        return out.reshape(shape)

    return jax.tree_util.tree_map(g, pool, lay.axes)


def scatter(
    pool: Any, lay: Layout, slots: jax.Array, tables: jax.Array, update: Any
) -> Any:
    """Write per-row views back: ``-1`` table entries dump into the scratch
    block (absorbing view-tail pass-through and pad writes); duplicate real
    entries (prefix-shared blocks in one batch) carry bitwise-identical
    content, so write order is immaterial."""
    B, nblk = tables.shape
    flat = jnp.where(tables < 0, lay.scratch_bid, tables).reshape(-1)

    def s(leaf, desc, u):
        ax = desc.axis
        if desc.kind == "slot":
            idx = (slice(None),) * ax + (slots,)
            return leaf.at[idx].set(u.astype(leaf.dtype))
        u2 = u.reshape(
            leaf.shape[:ax] + (B * nblk, lay.block_size) + leaf.shape[ax + 2:]
        )
        idx = (slice(None),) * ax + (flat,)
        return leaf.at[idx].set(u2.astype(leaf.dtype))

    return jax.tree_util.tree_map(s, pool, lay.axes, update)


def gather_mixed(pool: Any, lay: Layout, slots: jax.Array) -> Any:
    """Row-pack *slot* leaves only; *paged* leaves pass through whole.

    The paged-attention forward reads K/V in place through the block table,
    so — unlike :func:`gather` — no per-row contiguous view is ever copied
    out for full-attention leaves.  Dense leaves (recurrent state, sliding
    rings, cross caches) still need row packing by ``slots``.
    """

    def g(leaf, desc):
        if desc.kind == "paged":
            return leaf
        return jnp.take(leaf, slots, axis=desc.axis)

    return jax.tree_util.tree_map(g, pool, lay.axes)


def scatter_mixed(pool: Any, lay: Layout, slots: jax.Array, update: Any) -> Any:
    """Inverse of :func:`gather_mixed`: slot leaves write back per-row by
    ``slots``; paged leaves were updated in place by the forward (the update
    *is* the new pool) and replace the old leaf wholesale."""

    def sm(leaf, desc, u):
        if desc.kind == "paged":
            return u.astype(leaf.dtype)
        idx = (slice(None),) * desc.axis + (slots,)
        return leaf.at[idx].set(u.astype(leaf.dtype))

    return jax.tree_util.tree_map(sm, pool, lay.axes, update)


def wipe_blocks(pool: Any, lay: Layout, bids: List[int]) -> Any:
    """Reset freed blocks' position bookkeeping (``pos`` -> -1) so stale
    absolute positions never mask into a future owner's attention."""
    if not bids:
        return pool
    idx = jnp.array(bids, jnp.int32)

    def wipe(leaf, desc):
        if desc.kind != "paged" or leaf.dtype != jnp.int32:
            return leaf
        at = (slice(None),) * desc.axis + (idx,)
        return leaf.at[at].set(-1)

    return jax.tree_util.tree_map(wipe, pool, lay.axes)


def copy_blocks(
    src_pool: Any, dst_pool: Any, lay: Layout,
    src_bids: List[int], dst_bids: List[int],
) -> Any:
    """Copy paged-leaf block rows ``src_bids`` (of ``src_pool``) into
    ``dst_bids`` (of ``dst_pool``); returns the updated destination tree.

    The cluster front end's cross-replica prefix transfer
    (``cluster.replica.transfer_prefix``): both pools must share one
    :class:`Layout`.  Slot leaves (recurrent state, rings) never move —
    prefix sharing is defined only for paged full-attention KV.
    """
    assert len(src_bids) == len(dst_bids)
    if not src_bids:
        return dst_pool
    si = jnp.array(src_bids, jnp.int32)
    di = jnp.array(dst_bids, jnp.int32)

    def cp(dst_leaf, src_leaf, desc):
        if desc.kind != "paged":
            return dst_leaf
        rows = jnp.take(src_leaf, si, axis=desc.axis)
        at = (slice(None),) * desc.axis + (di,)
        return dst_leaf.at[at].set(rows.astype(dst_leaf.dtype))

    return jax.tree_util.tree_map(cp, dst_pool, src_pool, lay.axes)


def wipe_slot(pool: Any, lay: Layout, slot: int) -> Any:
    """Reset a released slot's dense leaves (sliding rings, recurrent
    state): int32 leaves to -1, the rest to zero — the old dense-pool
    ``free`` semantics, now scoped to slot-kind leaves only."""

    def wipe(leaf, desc):
        if desc.kind != "slot":
            return leaf
        idx = (slice(None),) * desc.axis + (slot,)
        if leaf.dtype == jnp.int32:
            return leaf.at[idx].set(-1)
        return leaf.at[idx].set(jnp.zeros_like(leaf[idx]))

    return jax.tree_util.tree_map(wipe, pool, lay.axes)


# ---------------------------------------------------------------------------
# host-side allocator
# ---------------------------------------------------------------------------


class BlockAllocator:
    """Ref-counted free-list allocator over ``num_blocks`` block ids.

    ``cached`` marks blocks registered with the prefix cache: their
    refcount reaching zero leaves them *resident* (evictable by the cache's
    LRU policy) instead of free.  The allocator never touches the device —
    the cache pool wipes freed blocks before reuse.
    """

    def __init__(self, num_blocks: int):
        assert num_blocks >= 1
        self.num_blocks = num_blocks
        self._free: List[int] = list(range(num_blocks - 1, -1, -1))
        self.refs: List[int] = [0] * num_blocks
        self.cached: Set[int] = set()
        self.peak_in_use = 0
        # allocation-churn telemetry (obs.metrics gauges)
        self.num_allocs = 0
        self.num_frees = 0

    def num_free(self) -> int:
        return len(self._free)

    def in_use(self) -> int:
        return self.num_blocks - len(self._free)

    def num_evictable(self) -> int:
        """Cached blocks no live request references — reclaimable."""
        return sum(1 for b in self.cached if self.refs[b] == 0)

    def available(self) -> int:
        """Free now plus reclaimable-by-eviction."""
        return self.num_free() + self.num_evictable()

    def alloc(self) -> Optional[int]:
        if not self._free:
            return None
        bid = self._free.pop()
        assert self.refs[bid] == 0 and bid not in self.cached
        self.refs[bid] = 1
        self.num_allocs += 1
        self.peak_in_use = max(self.peak_in_use, self.in_use())
        return bid

    def incref(self, bid: int) -> None:
        self.refs[bid] += 1

    def decref(self, bid: int) -> int:
        assert self.refs[bid] > 0, f"double free of block {bid}"
        self.refs[bid] -= 1
        return self.refs[bid]

    def release(self, bid: int) -> None:
        """Return a zero-ref, uncached block to the free list."""
        assert self.refs[bid] == 0 and bid not in self.cached
        self.num_frees += 1
        self._free.append(bid)
