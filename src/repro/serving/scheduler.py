"""Scheduler subsystem: per-iteration verify/decode co-scheduling policies.

The paper's prototype pauses *all* decoding whenever a verification pass
runs (§5.2 limitation (1)) — a handful of deterministic requests stalls the
whole non-deterministic fast path.  This module makes that choice pluggable:

* ``PauseDecodePolicy`` — the prototype's behaviour, verbatim: an iteration
  is either one verify pass or one decode batch, never both.  Kept as the
  reference policy (and for A/B ablation in ``benchmarks/fig_overlap.py``).
* ``OverlapPolicy``      — the default for ``Mode.LLM42``: a verify group is
  launched *alongside* the same iteration's decode batch.  Non-deterministic
  requests never idle behind verification, and (on attention-only archs) a
  deterministic request keeps speculating past a window that is already in
  flight — ``core.dvr.begin_inflight`` / ``apply_inflight_result`` own the
  splice/rollback bookkeeping.

Prefill is the third lane (§5.2 limitation (2)): when the engine runs with
``prefill_chunk > 0``, admitted requests enter ``State.PREFILLING`` and
advance one fixed-shape chunk per iteration instead of one exclusive
whole-prompt pass at admission.  ``OverlapPolicy`` co-schedules one chunk
(shortest-remaining-first) with the iteration's decode batch and verify
launch; ``PauseDecodePolicy`` runs chunks exclusively, preserving the
prototype's prefill-stalls-everything semantics chunk by chunk.  A
per-request fixed chunk schedule is shape-consistent by construction, so
committed streams are bitwise identical across chunk sizes too.

A policy is a pure function from a :class:`SchedulerView` (what is
decodable, what is ready to verify) to a :class:`Plan` (what this iteration
runs).  It decides *scheduling*, never token semantics — the committed
stream of a deterministic request is the verifier's reference sequence by
construction, so it is bitwise identical across policies, arrival orders
and co-batched traffic.  ``tests/test_scheduler.py`` asserts exactly that.

Recurrent/hybrid archs (``ssm``/``hybrid`` families) cap speculation at one
window: their fast path advances state irreversibly, so speculating past a
submitted window would decode from a state the verifier is about to
replace.  Overlap still applies to *other* requests' decoding — the pause
the tentpole removes.
"""

from __future__ import annotations

import abc
import dataclasses
from typing import List, Optional

from repro.core import dvr
from repro.core.determinism import Mode
from repro.serving.request import Request, State


@dataclasses.dataclass(frozen=True)
class SchedulerView:
    """Immutable snapshot the engine hands a policy each iteration."""

    running: tuple  # all RUNNING requests, admission order
    mode: Mode
    window: int
    group: int
    #: False for ssm/hybrid archs: no speculation past an in-flight window
    speculate_past_inflight: bool
    now: int  # logical iteration counter
    #: iterations until a launched verdict lands (Engine.verify_latency);
    #: at 1, verdicts land before the same iteration's decode batch runs
    verify_latency: int = 1
    #: requests mid chunked-prefill (State.PREFILLING), admission order;
    #: empty when the engine runs legacy exclusive prefill (chunk size 0)
    prefilling: tuple = ()


@dataclasses.dataclass(frozen=True)
class Plan:
    """What one engine iteration executes.  ``verify`` non-empty launches a
    grouped verification pass; ``decode`` non-empty runs a decode batch;
    ``prefill`` non-None advances that request's prefill by one fixed-shape
    chunk.  Multiple lanes non-empty == an overlapped iteration (costed as
    concurrent by the cost model)."""

    decode: List[Request] = dataclasses.field(default_factory=list)
    verify: List[Request] = dataclasses.field(default_factory=list)
    prefill: Optional[Request] = None

    @property
    def overlapped(self) -> bool:
        lanes = bool(self.decode) + bool(self.verify) + (self.prefill is not None)
        return lanes >= 2

    @property
    def empty(self) -> bool:
        return not self.decode and not self.verify and self.prefill is None


def decodable(view: SchedulerView) -> List[Request]:
    """Requests that can take a fast-path decode token this iteration."""
    out = []
    max_cand = dvr.candidates_per_window(view.window)
    for r in view.running:
        if r.state is State.PREFILLING:
            continue  # no committed token yet: nothing to decode from
        if r.done_decoding():
            continue
        if view.mode == Mode.LLM42 and r.sampling.is_deterministic:
            if len(r.candidates) >= max_cand:
                continue  # current window full; awaiting (or in) verification
            if r.inflight is not None and not view.speculate_past_inflight:
                continue  # recurrent state: no speculation past the window
        out.append(r)
    return out


def verify_ready(view: SchedulerView) -> List[Request]:
    if view.mode != Mode.LLM42:
        return []
    return [r for r in view.running if dvr.ready_for_verify(r, view.window)]


class SchedulePolicy(abc.ABC):
    """Maps a scheduler view to this iteration's plan."""

    name: str = "abstract"
    #: True => verify verdicts go through per-request in-flight state and
    #: land ``Engine.verify_latency`` iterations after launch; False => the
    #: verdict is applied synchronously inside the verify pass (seed flow).
    defers_verify: bool = False

    @abc.abstractmethod
    def plan(self, view: SchedulerView) -> Plan:
        ...


class PauseDecodePolicy(SchedulePolicy):
    """Paper-prototype scheduling: verification pauses decoding.

    Verify when a full group is ready or when nothing can decode; otherwise
    decode.  One device pass per iteration — the §5.2 limitation (1)
    behaviour the seed engine shipped with."""

    name = "pause_decode"

    def plan(self, view: SchedulerView) -> Plan:
        if view.prefilling:
            # exclusive prefill chunk, head of line: the prototype's
            # synchronous-prefill semantics, merely sliced into fixed-shape
            # pieces — nothing else runs while a prompt is prefilling
            return Plan(prefill=view.prefilling[0])
        ready = verify_ready(view)
        dec = decodable(view)
        if ready and (len(ready) >= view.group or not dec):
            return Plan(verify=ready)
        if dec:
            return Plan(decode=dec)
        if ready:  # drain stragglers
            return Plan(verify=ready)
        return Plan()


class OverlapPolicy(SchedulePolicy):
    """Co-schedule a verify group alongside the iteration's decode batch.

    The decode batch always contains every decodable request — verification
    never idles the fast path.  Verify groups are launched group-aware: a
    fixed-shape (G, W) pass costs the same however few rows are real, so a
    partial group waits while other deterministic windows are still filling
    (they will pool into a fuller pass) and launches once no more can join —
    or once nothing can decode, where holding would stall the iteration.
    Holding is not free for the HELD rows: their window is full, so they
    neither decode nor verify until the group launches (the same wait
    pause-decode's full-group gate imposes); what the policy never trades
    away is the progress of everything else in the batch."""

    name = "overlap"
    defers_verify = True

    def plan(self, view: SchedulerView) -> Plan:
        ready = verify_ready(view)
        dec = decodable(view)
        if ready and len(ready) < view.group and dec:
            ready_set = set(id(r) for r in ready)
            may_join = any(
                r.sampling.is_deterministic
                and id(r) not in ready_set
                # a PREFILLING request's join horizon (finish prefill, then
                # fill a window) is too far out to hold a ready group for
                and r.state is not State.PREFILLING
                and (r.inflight is not None or not r.done_decoding())
                for r in view.running
            )
            if may_join:
                ready = []
        if ready and view.speculate_past_inflight:
            # the rows being submitted (the engine takes the first `group`)
            # decode in this very iteration too — their first token past
            # the window rides the launch quantum instead of costing an
            # iteration of their own.  The engine decodes BEFORE launching
            # the verify, so the window's KV repair still wins (engine.step
            # docstring); excluded on recurrent archs like any other
            # past-window speculation
            for r in ready[: view.group]:
                if not r.done_decoding():
                    dec.append(r)
        prefill = None
        if view.prefilling:
            # one prefill chunk rides alongside the decode batch and verify
            # launch, picked shortest-remaining-first — a short prompt's
            # single chunk never queues behind a long prefill (head-of-line
            # blocking; ties break by admission order, stable min).  Every
            # fourth iteration serves the admission-order head instead, so
            # a sustained stream of short arrivals can never starve a long
            # prefill (it advances at least every 4 iterations) while
            # shorts rarely wait more than one extra slot.  Lane order
            # never affects token semantics — per-request prefill numerics
            # are independent of when the chunks run.
            if view.now % 4 == 0:
                prefill = view.prefilling[0]
            else:
                prefill = min(
                    view.prefilling, key=lambda r: r.prefill_remaining
                )
        return Plan(decode=dec, verify=ready, prefill=prefill)


def default_policy(mode: Mode) -> SchedulePolicy:
    """LLM42 overlaps by default; other modes never verify, so the pause
    policy's decode-only branch is all they use."""
    return OverlapPolicy() if mode == Mode.LLM42 else PauseDecodePolicy()
