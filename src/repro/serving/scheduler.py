"""Scheduler subsystem: per-iteration verify/decode co-scheduling policies.

The paper's prototype pauses *all* decoding whenever a verification pass
runs (§5.2 limitation (1)) — a handful of deterministic requests stalls the
whole non-deterministic fast path.  This module makes that choice pluggable:

* ``PauseDecodePolicy`` — the prototype's behaviour, verbatim: an iteration
  is either one verify pass or one decode batch, never both.  Kept as the
  reference policy (and for A/B ablation in ``benchmarks/fig_overlap.py``).
* ``OverlapPolicy``      — the default for ``Mode.LLM42``: a verify group is
  launched *alongside* the same iteration's decode batch.  Non-deterministic
  requests never idle behind verification, and a deterministic request
  keeps speculating past windows already in flight — and keeps *launching*
  further windows, up to the engine's ``spec_depth`` pipelining bound
  (``SchedulerView.spec_depth``) — ``core.pipeline`` owns the in-order
  splice / cascade-rollback bookkeeping, ``serving.statepool`` the device
  state checkpoints that make the depth safe on recurrent archs.

Prefill is the third lane (§5.2 limitation (2)): when the engine runs with
``prefill_chunk > 0``, admitted requests enter ``State.PREFILLING`` and
advance one fixed-shape chunk per iteration instead of one exclusive
whole-prompt pass at admission.  ``OverlapPolicy`` co-schedules one chunk
(shortest-remaining-first) with the iteration's decode batch and verify
launch; ``PauseDecodePolicy`` runs chunks exclusively, preserving the
prototype's prefill-stalls-everything semantics chunk by chunk.  A
per-request fixed chunk schedule is shape-consistent by construction, so
committed streams are bitwise identical across chunk sizes too.

* ``AdaptivePolicy``     — acceptance-adaptive: runs ``OverlapPolicy``
  verbatim while speculation is paying off, but watches each request's
  acceptance EMA (``Request.accept_ema``, updated by ``core.dvr`` on every
  verdict) and *demotes* requests whose candidates keep flipping to
  pause-style verification: synchronous verdicts (no in-flight window, no
  speculation past it — nothing wasted on latency) and *eager* partial
  windows whose depth scales with the acceptance rate, so a request in a
  near-constant-rollback regime stops burning W-1 doomed decode
  iterations per committed token.  Hysteresis (demote below / promote
  above) keeps it from flapping; a recovered request is promoted back to
  full overlapped speculation.

A policy maps a :class:`SchedulerView` (what is decodable, what is ready
to verify, stream occupancy, acceptance telemetry) to a :class:`Plan`
(what this iteration runs).  It decides *scheduling*, never token
semantics — the committed stream of a deterministic request is the
verifier's reference sequence by construction, so it is bitwise identical
across policies, arrival orders and co-batched traffic.
``tests/test_scheduler.py`` asserts exactly that.

Recurrent/hybrid archs used to cap speculation at one window (their fast
path advances state irreversibly); with the double-buffered state pool the
verifier never writes live state at launch, so the engine now reports
``speculate_past_inflight=True`` for every family.  The flag remains for
policy logic (and for hypothetical deployments without the pool): when
False, requests with in-flight windows are excluded from the decode batch.
"""

from __future__ import annotations

import abc
import dataclasses
from typing import List, Mapping, Optional, Set

from repro.core import dvr
from repro.core.determinism import Mode
from repro.serving.request import Request, State


@dataclasses.dataclass(frozen=True)
class SchedulerView:
    """Immutable snapshot the engine hands a policy each iteration."""

    running: tuple  # all RUNNING requests, admission order
    mode: Mode
    window: int
    group: int
    #: False for ssm/hybrid archs: no speculation past an in-flight window
    speculate_past_inflight: bool
    now: int  # logical iteration counter
    #: requests mid chunked-prefill (State.PREFILLING), admission order;
    #: empty when the engine runs legacy exclusive prefill (chunk size 0)
    prefilling: tuple = ()
    #: continuous main-stream clock (seconds under a costed clock,
    #: iteration ticks under the logical shim)
    now_time: float = 0.0
    #: stream occupancy: number of verify windows currently in flight
    #: (submitted, verdict not yet landed) across all requests
    verify_inflight: int = 0
    #: seconds of verify-stream work scheduled past ``now_time`` — how far
    #: behind the verify stream is running (0 when caught up / logical)
    verify_backlog: float = 0.0
    #: per-request acceptance telemetry: rid -> EMA of the accepted
    #: fraction per verdict (Request.accept_ema); 1.0 before any verdict
    acceptance: Mapping[int, float] = dataclasses.field(default_factory=dict)
    #: engine pipelining bound: verify windows a single request may have in
    #: flight (``Engine(spec_depth=...)`` / ``serve.py --spec-depth``); the
    #: paper's protocol is depth 1.  Policies may plan shallower (the
    #: adaptive policy scales depth with acceptance) but never deeper —
    #: the state pool holds exactly this many checkpoint buffers per slot
    spec_depth: int = 1
    #: paged-KV memory telemetry (serving.blockpool): free blocks in the
    #: pool right now, and requests currently preempted (blocks evicted,
    #: waiting on the restore lane).  Policies may read these to shape
    #: speculation depth under memory pressure; admission/preemption
    #: themselves are the engine's BlockMemoryPolicy's job.
    free_blocks: int = 0
    num_preempted: int = 0


@dataclasses.dataclass(frozen=True)
class Plan:
    """What one engine iteration executes.  ``verify`` non-empty launches a
    grouped verification pass; ``decode`` non-empty runs a decode batch;
    ``prefill`` non-None advances that request's prefill by one fixed-shape
    chunk.  Multiple lanes non-empty == an overlapped iteration (costed as
    concurrent by the cost model)."""

    decode: List[Request] = dataclasses.field(default_factory=list)
    verify: List[Request] = dataclasses.field(default_factory=list)
    prefill: Optional[Request] = None
    #: True forces this iteration's verify pass to apply its verdict
    #: synchronously (pause-style) even under a deferring policy —
    #: AdaptivePolicy uses it for demoted high-flip requests
    sync_verify: bool = False

    @property
    def overlapped(self) -> bool:
        lanes = bool(self.decode) + bool(self.verify) + (self.prefill is not None)
        return lanes >= 2

    @property
    def empty(self) -> bool:
        return not self.decode and not self.verify and self.prefill is None


def decodable(view: SchedulerView) -> List[Request]:
    """Requests that can take a fast-path decode token this iteration."""
    out = []
    max_cand = dvr.candidates_per_window(view.window)
    for r in view.running:
        if r.state is State.PREFILLING:
            continue  # no committed token yet: nothing to decode from
        if r.done_decoding():
            continue
        if view.mode == Mode.LLM42 and r.sampling.is_deterministic:
            if len(r.candidates) >= max_cand:
                continue  # current window full; awaiting (or in) verification
            if r.pipeline and not view.speculate_past_inflight:
                continue  # no state pool: no speculation past a window
        out.append(r)
    return out


def verify_ready(
    view: SchedulerView, depth: Optional[int] = None
) -> List[Request]:
    """Requests with a submittable window.  ``depth`` bounds windows in
    flight per request (default: the engine's ``spec_depth``); a request
    already at depth waits for a verdict before launching again."""
    if view.mode != Mode.LLM42:
        return []
    d = view.spec_depth if depth is None else depth
    return [
        r for r in view.running
        if dvr.ready_for_verify(r, view.window, depth=d)
    ]


def expand_chained(
    view: SchedulerView, ready: List[Request], depth_of=None
) -> List[Request]:
    """Lift the one-window-per-iteration cap: a request whose speculation
    buffer holds SEVERAL due windows (spec_depth > 1 — e.g. its verdicts
    all landed this iteration and re-opened the pipeline) contributes one
    plan entry PER submittable window, as a contiguous run.  The engine's
    fused step packs the k-th occurrences into the k-th chained grouped
    pass, so every due window lands the iteration it became due instead of
    dribbling out one per iteration.  ``depth_of`` overrides the
    per-request pipelining bound (AdaptivePolicy's acceptance-scaled
    depth); entries stay bounded by FIFO room either way.  Engines that
    launch one window per request per iteration (the legacy lanes) simply
    collapse the run to its first entry — pacing, never semantics."""
    k = dvr.candidates_per_window(view.window)
    out: List[Request] = []
    for r in ready:
        d = view.spec_depth if depth_of is None else depth_of(r)
        room = d - len(r.pipeline)
        full = len(r.candidates) // k
        windows = full + (
            1 if (len(r.candidates) % k and r.done_decoding()) else 0
        )
        out.extend([r] * max(1, min(windows, room)))
    return out


def pick_prefill(view: SchedulerView) -> Optional[Request]:
    """The prefill chunk that rides a co-scheduled iteration, picked
    shortest-remaining-first — a short prompt's single chunk never queues
    behind a long prefill (head-of-line blocking; ties break by admission
    order, stable min).  Every fourth iteration serves the admission-order
    head instead, so a sustained stream of short arrivals can never starve
    a long prefill (it advances at least every 4 iterations) while shorts
    rarely wait more than one extra slot.  Lane order never affects token
    semantics — per-request prefill numerics are independent of when the
    chunks run."""
    if not view.prefilling:
        return None
    if view.now % 4 == 0:
        return view.prefilling[0]
    return min(view.prefilling, key=lambda r: r.prefill_remaining)


class SchedulePolicy(abc.ABC):
    """Maps a scheduler view to this iteration's plan."""

    name: str = "abstract"
    #: True => verify verdicts go through per-request in-flight state and
    #: land at their verify-stream deadline (serving.streams); False =>
    #: the verdict is applied synchronously inside the verify pass (seed
    #: flow).
    defers_verify: bool = False

    @abc.abstractmethod
    def plan(self, view: SchedulerView) -> Plan:
        ...


class PauseDecodePolicy(SchedulePolicy):
    """Paper-prototype scheduling: verification pauses decoding.

    Verify when a full group is ready or when nothing can decode; otherwise
    decode.  One device pass per iteration — the §5.2 limitation (1)
    behaviour the seed engine shipped with."""

    name = "pause_decode"

    def plan(self, view: SchedulerView) -> Plan:
        if view.prefilling:
            # exclusive prefill chunk, head of line: the prototype's
            # synchronous-prefill semantics, merely sliced into fixed-shape
            # pieces — nothing else runs while a prompt is prefilling
            return Plan(prefill=view.prefilling[0])
        # sync verdicts apply in the launch iteration: nothing is ever in
        # flight, so the pipelining depth is irrelevantly 1 here
        ready = verify_ready(view, depth=1)
        dec = decodable(view)
        if ready and (len(ready) >= view.group or not dec):
            return Plan(verify=ready)
        if dec:
            return Plan(decode=dec)
        if ready:  # drain stragglers
            return Plan(verify=ready)
        return Plan()


class OverlapPolicy(SchedulePolicy):
    """Co-schedule a verify group alongside the iteration's decode batch.

    The decode batch always contains every decodable request — verification
    never idles the fast path.  Verify groups are launched group-aware: a
    fixed-shape (G, W) pass costs the same however few rows are real, so a
    partial group waits while other deterministic windows are still filling
    (they will pool into a fuller pass) and launches once no more can join —
    or once nothing can decode, where holding would stall the iteration.
    Holding is not free for the HELD rows: their window is full, so they
    neither decode nor verify until the group launches (the same wait
    pause-decode's full-group gate imposes); what the policy never trades
    away is the progress of everything else in the batch."""

    name = "overlap"
    defers_verify = True

    def __init__(self, max_inflight: int = 0):
        #: GLOBAL cap on concurrently in-flight verify windows across all
        #: requests (0 = unbounded) — the verify-stream backlog knob.  The
        #: per-request pipelining depth is the engine's ``spec_depth``
        #: (``SchedulerView.spec_depth``): the policy keeps launching a
        #: request's next window while its FIFO has room, so with a slow
        #: verify stream (--verify-latency-ms) a single request can hide
        #: ``spec_depth`` verdict round-trips — the depth axis
        #: benchmarks/fig_pipeline.py sweeps.
        self.max_inflight = max_inflight

    def plan(self, view: SchedulerView) -> Plan:
        return self._compose(
            view, expand_chained(view, verify_ready(view)), decodable(view),
            view.running,
        )

    def _compose(
        self,
        view: SchedulerView,
        ready: List[Request],
        dec: List[Request],
        det_pool,
    ) -> Plan:
        """Overlap composition over an explicit candidate set.

        ``ready``/``dec`` are the verify-ready and decodable requests this
        policy may schedule; ``det_pool`` is the set whose deterministic
        members might still *join* a partial verify group (AdaptivePolicy
        passes a filtered pool so demoted requests — which will never
        launch deferred — cannot hold a group open forever)."""
        if ready and len(ready) < view.group and dec:
            ready_set = set(id(r) for r in ready)
            may_join = any(
                r.sampling.is_deterministic
                and id(r) not in ready_set
                # a PREFILLING request's join horizon (finish prefill, then
                # fill a window) is too far out to hold a ready group for
                and r.state is not State.PREFILLING
                and (bool(r.pipeline) or not r.done_decoding())
                for r in det_pool
            )
            if may_join:
                ready = []
        if self.max_inflight and ready:
            # depth cap: a launch may only fill the REMAINING room, so the
            # in-flight window count never exceeds max_inflight (a
            # pre-launch gate alone would overshoot by up to group-1 —
            # the launch itself adds up to `group` windows).  Runs after
            # the group-holding logic: a trimmed partial launch is the
            # cap's doing, not a group worth waiting to fill.
            room = self.max_inflight - view.verify_inflight
            ready = ready[: max(room, 0)]
        if ready and view.speculate_past_inflight:
            # the rows being submitted (the engine takes the first `group`
            # DISTINCT requests — chained-window entries repeat) decode in
            # this very iteration too — their first token past the window
            # rides the launch quantum instead of costing an iteration of
            # their own.  The engine decodes BEFORE landing the verify
            # submits' state rule, so the window's KV repair still wins
            # (engine.step docstring); excluded on recurrent archs like
            # any other past-window speculation
            seen: Set[int] = set()
            for r in ready:
                if len(seen) >= view.group:
                    break
                if id(r) in seen:
                    continue
                seen.add(id(r))
                if not r.done_decoding():
                    dec.append(r)
        return Plan(decode=dec, verify=ready, prefill=pick_prefill(view))


class AdaptivePolicy(SchedulePolicy):
    """Acceptance-adaptive scheduling: overlap while speculation pays,
    pause-style verification for requests it keeps failing.

    Near-constant rollback is where overlapping loses (fig_overlap
    ``50pct_stress``): a high-flip request burns W-1 decode iterations
    filling a window the verifier is about to reject, its in-flight
    verdict lands a latency late, and everything it speculated past the
    window is recomputed — the contention term with nothing hidden behind
    it.  This policy watches the per-request acceptance EMA the view
    carries and **demotes** a request once its EMA drops below
    ``demote_below``:

    * its verification turns synchronous and exclusive (the pause
      prototype's semantics — no in-flight window, no speculation past
      it, verdict applied in the launch iteration);
    * its windows shrink to an *eager* depth that scales with the EMA
      (``max(1, round(ema * (W-1)))``): at near-zero acceptance it
      submits after a single candidate, so each committed token costs one
      decode plus its share of a grouped verify pass instead of W-1
      doomed speculations.  Window pacing is scheduling, not semantics —
      the committed stream is the same reference sequence at every depth.

    A demoted request whose EMA recovers above ``promote_above`` is
    promoted back to full overlapped speculation (hysteresis prevents
    flapping).  Non-demoted requests pipeline with **acceptance-scaled
    depth**: a request may hold ``max(1, round(ema * spec_depth))``
    windows in flight, so a request whose candidates have started flipping
    stops pushing a deep pipeline it will mostly cascade away, *before*
    the demotion threshold trips.  At full acceptance (and always at
    ``spec_depth=1``) the policy IS ``OverlapPolicy`` — identical plans,
    identical events — so low-rollback traffic keeps the whole overlap
    win.

    Note the policy carries per-request hysteresis state (the demoted
    set), unlike the stateless pause/overlap policies — use one instance
    per engine."""

    name = "adaptive"
    defers_verify = True

    def __init__(
        self,
        demote_below: float = 0.6,
        promote_above: float = 0.8,
        max_inflight: int = 0,
    ):
        assert 0.0 < demote_below <= promote_above <= 1.0
        self.demote_below = demote_below
        self.promote_above = promote_above
        self._overlap = OverlapPolicy(max_inflight=max_inflight)
        self._demoted: Set[int] = set()
        # hysteresis-transition telemetry (obs.metrics gauges)
        self.num_demotions = 0
        self.num_promotions = 0

    def _update_demotions(self, view: SchedulerView) -> None:
        alive = set()
        for r in view.running:
            if not r.sampling.is_deterministic:
                continue
            alive.add(r.rid)
            ema = view.acceptance.get(r.rid, 1.0)
            if r.rid in self._demoted:
                if ema >= self.promote_above:
                    self._demoted.discard(r.rid)
                    self.num_promotions += 1
            elif ema < self.demote_below:
                self._demoted.add(r.rid)
                self.num_demotions += 1
        self._demoted &= alive  # drop retired requests

    def _eager_depth(self, view: SchedulerView, r: Request) -> int:
        ema = view.acceptance.get(r.rid, 1.0)
        return max(1, int(round(ema * dvr.candidates_per_window(view.window))))

    def _pipeline_depth(self, view: SchedulerView, r: Request) -> int:
        """Acceptance-scaled in-flight depth for a promoted request: full
        ``spec_depth`` at EMA 1.0, shrinking toward 1 as candidates start
        flipping — a deep pipeline behind a likely rollback is pure
        cascade fodder.  Never 0: demotion (not depth) turns overlap off."""
        ema = view.acceptance.get(r.rid, 1.0)
        return max(1, int(round(ema * view.spec_depth)))

    def _promoted_ready(self, view: SchedulerView) -> List[Request]:
        return [
            r for r in view.running
            if r.rid not in self._demoted
            and dvr.ready_for_verify(
                r, view.window, depth=self._pipeline_depth(view, r)
            )
        ]

    def _expanded_ready(self, view: SchedulerView) -> List[Request]:
        """Promoted ready set with chained-window entries, bounded by each
        request's ACCEPTANCE-SCALED depth (not the engine's full
        spec_depth): a request trending toward rollback keeps a shallow
        pipeline even when several of its windows are due at once."""
        return expand_chained(
            view, self._promoted_ready(view),
            depth_of=lambda r: self._pipeline_depth(view, r),
        )

    def plan(self, view: SchedulerView) -> Plan:
        self._update_demotions(view)
        if not self._demoted:
            return self._overlap._compose(
                view, self._expanded_ready(view), decodable(view),
                view.running,
            )
        demoted = [r for r in view.running if r.rid in self._demoted]
        dem_ready = [
            r for r in demoted
            # sync verification replays from committed[-1]: a freshly
            # demoted request first drains its in-flight FIFO
            if not r.pipeline and dvr.ready_for_verify(
                r, view.window, min_candidates=self._eager_depth(view, r)
            )
        ]
        dec = decodable(view)
        dem_decodable = [r for r in dec if r.rid in self._demoted]
        if dem_ready and (
            len(dem_ready) >= min(view.group, len(demoted))
            or not dem_decodable
        ):
            # pause-style exclusive verification for the demoted group:
            # sync verdict, no decode co-scheduled — exactly the
            # prototype's iteration, so a fully demoted workload
            # degenerates to PauseDecodePolicy with shallower (cheaper)
            # windows.  A prefill chunk still rides along: it touches only
            # its own slot (order-independent) and starving it every sync
            # iteration would halve a co-resident prompt's prefill rate
            # at eager depth 1 (sync passes can fire every other
            # iteration)
            return Plan(
                verify=dem_ready[: view.group], sync_verify=True,
                prefill=pick_prefill(view),
            )
        # otherwise: overlap composition for everything else.  Demoted
        # requests may decode (filling their eager window) but never
        # launch deferred, and — because they can never join a deferred
        # group — they are excluded from the group-holding pool.
        ready = self._expanded_ready(view)
        det_pool = [r for r in view.running if r.rid not in self._demoted]
        return self._overlap._compose(view, ready, dec, det_pool)


class BlockMemoryPolicy:
    """Admission + preemption policy for the paged KV block pool.

    The scheduler's verify/decode policies above decide what RUNS each
    iteration; this policy decides who gets MEMORY when the block pool
    runs dry:

    * **victim choice** — least-recently-scheduled (LRU) among the running
      requests, deterministic ``(last_sched, rid)`` tie-break.  Requests
      mid-prefill are never preempted (they have committed nothing — their
      replay anchor does not exist yet), and the engine excludes the
      requester itself.
    * **anti-thrash hysteresis** — (a) a freshly *restored* request is
      passed over as a victim for ``restore_cooldown`` iterations unless
      every candidate is equally fresh (preempting what you just replayed
      is pure thrash — but forward progress beats fairness, so the shield
      is advisory, never absolute); (b) a preempted request re-admits only
      once ``restore_cooldown`` iterations have passed since ITS
      preemption AND the pool can cover its full worst-case need plus
      ``watermark_blocks`` of headroom — a restore that would immediately
      preempt someone else (or be re-preempted itself) never starts.

    Preemption is *safe* by the commit rule: the victim keeps its slot
    (recurrent state rows are O(1) — the memory being reclaimed is KV
    blocks), its committed stream, and its statepool replay anchor; the
    restore replays only committed tokens through the chunked-prefill
    lane, which is bitwise-identical by construction.
    """

    name = "block-lru"

    def __init__(self, watermark_blocks: int = 0, restore_cooldown: int = 8):
        assert watermark_blocks >= 0 and restore_cooldown >= 0
        self.watermark_blocks = watermark_blocks
        self.restore_cooldown = restore_cooldown

    def pick_victim(
        self, candidates: List[Request], now: int
    ) -> Optional[Request]:
        """LRU victim among ``candidates`` (running, not the requester,
        not mid-prefill — the engine pre-filters)."""
        if not candidates:
            return None
        shielded = lambda r: now - r.restore_iter < self.restore_cooldown  # noqa: E731
        pool = [r for r in candidates if not shielded(r)] or candidates
        return min(pool, key=lambda r: (r.last_sched, r.rid))

    def may_restore(
        self, req: Request, free_blocks: int, need_blocks: int, now: int
    ) -> bool:
        """Gate the restore lane: cooldown since the request's own
        preemption + full worst-case need + watermark of headroom."""
        if now - req.preempt_iter < self.restore_cooldown:
            return False
        return free_blocks - need_blocks >= self.watermark_blocks

    def may_admit(self, free_blocks: int, need_blocks: int) -> bool:
        """Gate fresh admission on the prompt's block need + watermark.
        Fresh traffic never preempts running work — it waits; only the
        *growth* of already-admitted requests may preempt."""
        return free_blocks - need_blocks >= self.watermark_blocks


def default_policy(mode: Mode) -> SchedulePolicy:
    """LLM42 overlaps by default; other modes never verify, so the pause
    policy's decode-only branch is all they use."""
    return OverlapPolicy() if mode == Mode.LLM42 else PauseDecodePolicy()
