"""Event-driven execution streams: the engine's dual-clock runtime.

The paper's verify-rollback loop is asynchronous in spirit — verification
runs *beside* decoding, not inside it — but the engine originally modeled
that with a single lock-step integer iteration counter and a fixed
``verify_latency`` iteration count.  That is too coarse to study deeper
pipelining (ROADMAP): a verify pass that takes 1.7 decode-iterations of
device time either rounds to 1 or to 2, verify passes can never queue
behind each other, and the cost model had to approximate concurrency with
a composite per-iteration "overlap" formula.

This module replaces the time model with *execution streams*, the same
abstraction accelerators expose (CUDA/TPU streams): an :class:`ExecStream`
is an in-order work queue with its own continuous clock; concurrency
between streams is real (each stream has its own frontier), while work
within a stream serializes.  The engine composes two of them in a
:class:`DualClockRuntime`:

* the **main** stream runs everything the scheduler plans on the fast
  path — decode batches and prefill chunks (serial within an iteration:
  they are separate kernel launches on one stream);
* the **verify** stream runs deferred verification passes.  A launch
  starts no earlier than its launch iteration and no earlier than the
  previous verify pass's completion (passes queue — genuine stream
  occupancy), and its verdict becomes visible ``extra latency`` seconds
  after the pass completes.

Cross-stream interference is modeled with a single contention coefficient:
the portion of a verify pass that overlaps the launching iteration's
main-stream work slows the main stream by ``contention * overlap`` (both
streams share HBM).  ``contention = 0`` is an ideal dual-issue machine;
``contention = 1`` degenerates to serial execution.

Determinism note: stream timing decides only *when* verdicts land, never
what they say — the committed stream of a deterministic request is the
verifier's reference sequence by construction, so it is bitwise identical
across clock modes, verify latencies, and verdict landing orders
(``tests/test_scheduler.py::TestVerdictOrdering`` asserts the out-of-order
case explicitly via ``latency_schedule``).

Two clock modes:

* **logical** (``cost_fn is None``) — the engine's default clock: every
  iteration advances the main clock by exactly 1.0 and a verify launch
  is ready ``latency`` ticks later (the engine passes 1 — a verdict
  lands the iteration after its launch).
* **costed** (``cost_fn`` given) — clocks advance by modeled device
  seconds (``serving.costmodel.step_time``); verify passes have real
  durations, queue on their stream, and land ``latency`` *seconds* after
  completion (``--verify-latency-ms``).
"""

from __future__ import annotations

import dataclasses
import heapq
from typing import Any, Callable, Dict, List, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class StreamEvent:
    """A deadline in stream time.  Ordered by (time, seq): two events due
    at the same instant resolve in push order — deterministic tie-break."""

    time: float
    seq: int
    kind: str
    payload: Any = None


class EventQueue:
    """Min-heap of :class:`StreamEvent` deadlines."""

    def __init__(self) -> None:
        self._heap: List[Tuple[float, int, StreamEvent]] = []
        self._seq = 0

    def push(self, time: float, kind: str, payload: Any = None) -> StreamEvent:
        ev = StreamEvent(time=time, seq=self._seq, kind=kind, payload=payload)
        heapq.heappush(self._heap, (time, self._seq, ev))
        self._seq += 1
        return ev

    def pop_due(self, now: float) -> List[StreamEvent]:
        """All events with ``time <= now``, in (time, push-order) order."""
        out: List[StreamEvent] = []
        while self._heap and self._heap[0][0] <= now:
            out.append(heapq.heappop(self._heap)[2])
        return out

    def peek_time(self) -> Optional[float]:
        return self._heap[0][0] if self._heap else None

    def __len__(self) -> int:
        return len(self._heap)


class ExecStream:
    """An in-order execution stream with a continuous clock.

    ``now`` is the stream's frontier: the time through which work has been
    scheduled.  ``launch`` appends work (start = max(frontier, not_before)),
    ``wait`` stalls the frontier without accruing busy time.  ``busy``
    accumulates only launched work, so ``occupancy(horizon)`` is the
    utilization telemetry the scheduler reads.
    """

    def __init__(self, name: str, start: float = 0.0) -> None:
        self.name = name
        self.now = float(start)
        self.busy = 0.0

    def launch(self, duration: float, *, not_before: float = 0.0) -> Tuple[float, float]:
        """Queue ``duration`` seconds of work; returns (start, finish)."""
        assert duration >= 0.0, "a pass cannot take negative time"
        start = max(self.now, not_before)
        finish = start + duration
        self.now = finish
        self.busy += duration
        return start, finish

    def wait(self, t: float) -> None:
        """Stall (idle) until ``t``; no-op if the frontier is already past."""
        self.now = max(self.now, t)

    def advance(self, dt: float) -> None:
        """Push the frontier forward by ``dt`` without accruing busy time
        (contention slip, logical ticks)."""
        assert dt >= 0.0
        self.now += dt

    def occupancy(self, horizon: float) -> float:
        """Fraction of ``horizon`` this stream spent executing work."""
        return self.busy / horizon if horizon > 0 else 0.0


class DualClockRuntime:
    """Main + verify execution streams + the verdict deadline queue.

    One engine iteration brackets as::

        now = rt.begin_iteration()      # land verdicts with ready <= now
        rt.charge(decode_event)         # main-stream passes, serial
        rt.charge(prefill_event)
        ready = rt.launch_verify(ev)    # verify-stream pass -> verdict time
        rt.end_iteration()              # event-driven skip when main idled

    ``cost_fn`` maps an engine event dict to modeled device seconds; when
    ``None`` the runtime runs the logical (iteration-count) shim.
    ``latency`` is the extra delay between a verify pass completing and its
    verdict becoming visible — iterations in logical mode, seconds in
    costed mode.  ``latency_schedule`` (when set) overrides ``latency``
    per launch, in launch order — a test hook for out-of-order verdict
    landings; entries past the schedule fall back to ``latency``.
    """

    def __init__(
        self,
        cost_fn: Optional[Callable[[Dict[str, Any]], float]] = None,
        *,
        latency: float = 1.0,
        contention: float = 0.0,
    ) -> None:
        assert latency >= 0.0, "a verdict cannot land before its launch"
        assert 0.0 <= contention <= 1.0
        self.cost_fn = cost_fn
        self.latency = float(latency)
        self.contention = float(contention)
        self.main = ExecStream("main")
        self.verify = ExecStream("verify")
        self.verdicts = EventQueue()
        self.latency_schedule: Optional[List[float]] = None
        #: earliest external event (e.g. the online runner's next request
        #: arrival): the event-driven skip never jumps past it, so an
        #: arrival during a verdict-gated idle window is admitted at its
        #: arrival time, not at the verdict deadline
        self.skip_horizon: Optional[float] = None
        #: deepest verdict queue seen (verdicts launched, not yet due):
        #: with multi-window pipelining (Engine spec_depth > 1) several
        #: verdicts per request can be airborne — this is the occupancy
        #: telemetry benchmarks report alongside verify-stream busy time
        self.peak_outstanding = 0
        #: (start, finish) of the most recent costed launch on each stream
        #: — the tracer reads these right after ``charge`` /
        #: ``launch_verify`` to place the pass's slice on the timeline.
        #: None under the logical clock (passes there have no extent; the
        #: tracer synthesizes a layout inside the iteration window instead)
        self.last_main_span: Optional[Tuple[float, float]] = None
        self.last_verify_span: Optional[Tuple[float, float]] = None
        self._n_launches = 0
        self._t0 = 0.0
        self._did_main_work = False

    # ------------------------------------------------------------------

    @property
    def logical(self) -> bool:
        return self.cost_fn is None

    @property
    def now(self) -> float:
        """The main-stream clock — 'the present' from the scheduler's view."""
        return self.main.now

    @property
    def makespan(self) -> float:
        """Time at which ALL scheduled work (both streams) has completed."""
        return max(self.main.now, self.verify.now)

    @property
    def verify_backlog(self) -> float:
        """Seconds of verify-stream work scheduled past the present — how
        far behind the verify stream is running (0 when caught up)."""
        return max(0.0, self.verify.now - self.main.now)

    @property
    def outstanding_verdicts(self) -> int:
        """Verdicts launched but not yet due (the in-flight window count
        as the streams see it)."""
        return len(self.verdicts)

    def _latency_for_launch(self) -> float:
        i = self._n_launches
        self._n_launches += 1
        if self.latency_schedule is not None and i < len(self.latency_schedule):
            return float(self.latency_schedule[i])
        return self.latency

    # ------------------------------------------------------------------
    # iteration protocol
    # ------------------------------------------------------------------

    def begin_iteration(self) -> float:
        """Start an iteration; returns the clock against which verdict
        deadlines are checked (``ready_at <= now`` lands)."""
        if self.logical:
            self.main.advance(1.0)
        self._t0 = self.main.now
        self._did_main_work = False
        # drain deadlines that have come due; application itself is the
        # engine's job (per-request ``InflightVerify.ready_at`` check)
        self.verdicts.pop_due(self.main.now)
        return self.main.now

    def charge(self, ev: Dict[str, Any]) -> float:
        """Charge one main-stream pass (decode / prefill); returns its
        modeled duration.  Passes within an iteration serialize — they are
        separate kernel launches on one stream."""
        self._did_main_work = True
        if self.logical:
            self.last_main_span = None
            return 0.0
        dur = self.cost_fn(ev)
        self.last_main_span = self.main.launch(dur)
        return dur

    def launch_verify(self, ev: Dict[str, Any], *, sync: bool = False) -> float:
        """Launch a verification pass; returns its verdict-ready time.

        Deferred (``sync=False``): the pass queues on the verify stream
        (start = max(iteration start, previous pass's completion)) and the
        verdict is visible ``latency`` after completion.  The overlap with
        this iteration's main-stream work costs ``contention * overlap`` of
        main-stream slip.  Sync (``sync=True``, pause-style): the pass
        blocks the main stream for its full duration — the verdict applies
        inside the iteration, so the returned time is just 'now'.
        """
        lat = self._latency_for_launch()
        if self.logical:
            self.last_verify_span = None
            if sync:
                self._did_main_work = True
                return self.main.now
            ready = self.main.now + lat
            self.verdicts.push(ready, "verdict", ev)
            self.peak_outstanding = max(self.peak_outstanding, len(self.verdicts))
            return ready
        dur = self.cost_fn(ev)
        if sync:
            # exclusive: everything waits on the pass (and on any verify
            # work still draining); busy time accrues to the verify stream
            # so occupancy telemetry sees sync and deferred passes alike
            start, finish = self.verify.launch(dur, not_before=self.main.now)
            self.last_verify_span = (start, finish)
            self.main.wait(finish)
            self._did_main_work = True
            return self.main.now
        start, finish = self.verify.launch(dur, not_before=self._t0)
        self.last_verify_span = (start, finish)
        overlap = max(0.0, min(self.main.now, finish) - max(self._t0, start))
        self.main.advance(self.contention * overlap)
        ready = finish + lat
        self.verdicts.push(ready, "verdict", ev)
        self.peak_outstanding = max(self.peak_outstanding, len(self.verdicts))
        return ready

    def end_iteration(self) -> None:
        """Close the iteration.  Event-driven skip: an iteration that did
        no main-stream work (everything gated on in-flight verdicts) waits
        for the earliest pending deadline instead of spinning — this is
        what makes the continuous clock terminate where the old integer
        counter relied on +1 per iteration."""
        if self.logical or self._did_main_work:
            return
        t = self.verdicts.peek_time()
        if t is None or t <= self.main.now:
            return
        if self.skip_horizon is not None and self.skip_horizon > self.main.now:
            t = min(t, self.skip_horizon)
        self.main.wait(t)

    def idle_until(self, t: float) -> None:
        """Idle the main stream until ``t`` (online runner: no work until
        the next arrival)."""
        self.main.wait(t)
