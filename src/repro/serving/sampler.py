"""Batch-invariant sampling (paper §4.4 "Sampling").

Greedy (temperature == 0): argmax with first-max tiebreak — ``jnp.argmax``
returns the first maximal index, matching SGLang's deterministic argmax.

Stochastic: ``multinomial_with_seed`` semantics — Gumbel noise generated
from a counter-based hash of (seed, output_position), so the sample is a
pure function of (logits, seed, position) and *independent of batch size or
position in the batch*.  This replaces torch.multinomial, which consumes a
global RNG stream and is therefore batch-order dependent.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

F32 = jnp.float32


def _gumbel_for(seed: jax.Array, position: jax.Array, vocab: int) -> jax.Array:
    """Counter-based Gumbel noise: pure function of (seed, position)."""
    key = jax.random.fold_in(jax.random.PRNGKey(0), seed)
    key = jax.random.fold_in(key, position)
    return jax.random.gumbel(key, (vocab,), F32)


def sample_token(
    logits: jax.Array,  # (V,) f32
    seed: jax.Array,  # scalar int32
    position: jax.Array,  # scalar int32 — output index of this token
    temperature: jax.Array,  # scalar f32; 0 => greedy
    top_k: jax.Array | int = 0,  # 0 => no truncation
) -> jax.Array:
    """Sample one token deterministically.  Returns scalar int32.

    top_k truncation is applied by thresholding at the k-th largest logit
    (ties keep all equal-valued candidates — a pure function of the logits,
    hence batch-invariant), then Gumbel-argmax over the survivors.  The
    result is a pure function of (logits, seed, position, temperature,
    top_k): fixed hyper-parameters => reproducible samples (paper
    footnote 2's intended semantics).
    """
    greedy = jnp.argmax(logits).astype(jnp.int32)

    top_k = jnp.asarray(top_k, jnp.int32)
    V = logits.shape[-1]
    # threshold at the top_k-th value (top_k<=0 disables truncation)
    sorted_desc = jnp.sort(logits)[::-1]
    kth = sorted_desc[jnp.clip(top_k - 1, 0, V - 1)]
    keep = (top_k <= 0) | (logits >= kth)
    masked = jnp.where(keep, logits, -jnp.inf)

    g = _gumbel_for(seed, position, V)
    t = jnp.maximum(temperature, 1e-6)
    stochastic = jnp.argmax(masked / t + g).astype(jnp.int32)

    return jnp.where(temperature <= 0.0, greedy, stochastic)


def top2_margin(logits: jax.Array) -> jax.Array:
    """Top-1 minus top-2 logit margin along the last axis; ties give 0.

    The second max is taken with the argmax *index* masked out (not the
    max *value*), so two equal maximal logits — the only case where an
    infinitesimal reduction reorder can flip the argmax — report margin
    exactly 0.  Reductions span the vocab axis only (batch-invariant like
    the argmax in ``sample_token``).  This is the audit log's provenance
    margin and the calibration signal for margin-gated sparse
    verification (ROADMAP): a token with margin ``m`` is stable under any
    schedule whose accumulated error is below ``m/2``.
    """
    x = logits.astype(F32)
    am = jnp.argmax(x, axis=-1)
    top1 = jnp.max(x, axis=-1)
    is_top1 = jnp.arange(x.shape[-1]) == am[..., None]
    top2 = jnp.max(jnp.where(is_top1, -jnp.inf, x), axis=-1)
    return top1 - top2


def sample_batch(
    logits: jax.Array,  # (B, V)
    seeds: jax.Array,  # (B,)
    positions: jax.Array,  # (B,)
    temperatures: jax.Array,  # (B,)
    top_ks: jax.Array | None = None,  # (B,) int32; None => no truncation
) -> jax.Array:
    if top_ks is None:
        top_ks = jnp.zeros(logits.shape[0], jnp.int32)
    return jax.vmap(sample_token)(logits, seeds, positions, temperatures,
                                  top_ks)


def sample_window(
    logits: jax.Array,  # (B, W, V)
    seeds: jax.Array,  # (B,)
    base_positions: jax.Array,  # (B,) output index of the first window token
    temperatures: jax.Array,  # (B,)
    top_ks: jax.Array | None = None,  # (B,)
) -> jax.Array:
    """Sample each window position with its own (seed, position) counter."""
    B, W, V = logits.shape
    if top_ks is None:
        top_ks = jnp.zeros(B, jnp.int32)
    pos = base_positions[:, None] + jnp.arange(W)[None, :]  # (B, W)
    flat = jax.vmap(sample_token)(
        logits.reshape(B * W, V),
        jnp.repeat(seeds, W),
        pos.reshape(-1),
        jnp.repeat(temperatures, W),
        jnp.repeat(top_ks, W),
    )
    return flat.reshape(B, W)
