"""The LLM-42 serving engine: continuous batching + selective determinism.

Three modes (paper §5 baselines):

  * ``Mode.NONDET``           — SGLang-Non-Deterministic: fast path only;
                                schedules vary with dynamic batch size.
  * ``Mode.BATCH_INVARIANT``  — SGLang-Deterministic: one universal schedule
                                for every op, all traffic pays for it.
  * ``Mode.LLM42``            — the paper: fast path for everyone +
                                decode-verify-rollback for requests with
                                ``is_deterministic=True``.

Per-iteration verify/decode arbitration is delegated to the scheduler
subsystem (``serving.scheduler``): ``PauseDecodePolicy`` reproduces the
paper prototype's behaviour (verification pauses decoding, §5.2 limitation
(1)); ``OverlapPolicy`` — the default for ``Mode.LLM42`` — co-schedules the
verify group alongside the same iteration's decode batch, with a
per-request in-flight verify FIFO (``core.pipeline``) so a request keeps
speculating past submitted windows and pipelines up to ``spec_depth``
windows deep; verdicts splice strictly in submission order, rollbacks
cascade through later windows, and the double-buffered state pool
(``serving.statepool``) checkpoints recurrent state at each window
submission so ssm/hybrid archs pipeline just as deep (they used to be
hard-capped at one window).  Prefill stays per-request (deterministic by
construction, never co-batched) but is chunk-resumable: with
``prefill_chunk > 0`` a prompt advances ``C`` tokens per iteration as the
scheduler's third lane instead of one exclusive pass at admission, so a
long prompt no longer stalls the decode batch (§5.2 limitation (2));
decode batches are formed from all decodable requests each iteration
(continuous batching).

Every device step goes through a jitted function cached per *shape class*
(batch size, prompt bucket, window) — recompilation per shape is exactly
the shape→schedule coupling (O2) the paper builds on.

Time is kept by the dual-clock execution-stream runtime
(``serving.streams``): decode/prefill passes charge the main stream,
deferred verification launches on the verify stream, and verdict deadlines
are continuous (``verify_latency_ms``; the integer ``verify_latency`` is
the deprecated 1-tick-per-iteration shim).  An event log still records
(kind, shape metadata, wall time) per step; the benchmark harness replays
it through the TPU cost model (``serving.costmodel``) to derive
paper-comparable throughput numbers.
"""

from __future__ import annotations

import time
import warnings
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.core import dvr, pipeline
from repro.core.determinism import (
    FAST_PATH_POLICY,
    INVARIANT_SCHEDULE,
    Mode,
    ReductionPolicy,
    Schedule,
    VERIFY_SCHEDULE,
)
from repro.core.verifier import make_verify_fn
from repro.models.base import ModelConfig
from repro.models.transformer import build_cross_cache, forward
from repro.serving import costmodel, kv_cache, statepool, streams
from repro.serving import scheduler as sched
from repro.serving.request import Request, State
from repro.serving.sampler import sample_batch, sample_token


def _bucket(n: int) -> int:
    """Next power-of-two bucket (>= 8) for prompt padding."""
    b = 8
    while b < n:
        b *= 2
    return b


class Engine:
    def __init__(
        self,
        cfg: ModelConfig,
        params: Dict,
        *,
        mode: Mode = Mode.LLM42,
        policy: ReductionPolicy = FAST_PATH_POLICY,
        window: int = 8,  # verification window W (verifies W-1 candidates)
        group: int = 4,  # requests verified together (grouped verification)
        max_batch: int = 8,
        capacity: Optional[int] = None,
        scheduler: Optional[sched.SchedulePolicy] = None,
        spec_depth: int = 1,  # verify windows in flight per request
        verify_latency: Optional[int] = None,  # DEPRECATED logical-shim ticks
        verify_latency_ms: Optional[float] = None,  # continuous verdict latency
        cost_cfg: Optional[ModelConfig] = None,  # config the stream clocks cost at
        hw: costmodel.Hardware = costmodel.V5E,
        prefill_chunk: int = 0,  # tokens per prefill chunk; 0 = exclusive
    ):
        self.cfg = cfg
        self.params = params
        self.mode = mode
        self.policy = policy
        self.window = window
        self.group = group
        self.max_batch = max_batch
        self.capacity = capacity or cfg.max_seq_len
        self.pool = kv_cache.CachePool(cfg, max_batch, self.capacity)
        self.axes = self.pool.axes
        # recurrent/hybrid archs advance SSM/RWKV state irreversibly on the
        # fast path; the double-buffered state pool (serving.statepool)
        # carries the verify replay anchor + per-window rollback checkpoints
        # so speculation can run `spec_depth` windows deep anyway.  For
        # attention archs the pool is host-side depth/extent telemetry only.
        self.has_recurrent_state = statepool.has_recurrent_state(cfg)
        assert spec_depth >= 1, "at least one verify window must be allowed"
        self.spec_depth = int(spec_depth)
        self.statepool = statepool.StatePool(cfg, max_batch, self.spec_depth)

        self.scheduler = scheduler if scheduler is not None else sched.default_policy(mode)
        if verify_latency is not None:
            warnings.warn(
                "Engine(verify_latency=...) is deprecated: the integer "
                "logical shim counts iterations, not time.  Use "
                "verify_latency_ms (the costed dual-stream clock) instead.",
                DeprecationWarning,
                stacklevel=2,
            )
        else:
            verify_latency = 1
        assert verify_latency >= 1, "a verdict cannot land before its launch"
        self.verify_latency = verify_latency  # deprecated: logical-shim ticks
        assert verify_latency_ms is None or verify_latency_ms >= 0.0
        self.verify_latency_ms = verify_latency_ms
        self.hw = hw
        # dual-clock execution-stream runtime (serving.streams).  Default is
        # the logical shim (1 tick per iteration, verdicts verify_latency
        # ticks after launch — the pre-stream behaviour, bit for bit).
        # Passing verify_latency_ms — or calling bind_cost_model(), which
        # run_online() does — switches to the costed clock: continuous
        # main/verify stream times from the cost model, verify passes
        # queueing on their own stream, verdicts landing latency_ms after
        # the pass completes.
        self.runtime = streams.DualClockRuntime(latency=float(verify_latency))
        if verify_latency_ms is not None:
            self.bind_cost_model(cost_cfg or cfg, hw)
        assert prefill_chunk >= 0, "prefill_chunk must be >= 0 (0 = exclusive)"
        self.prefill_chunk = int(prefill_chunk)
        # chunked prefill covers every family: attention archs share the
        # embeds-based chunk pass; recurrent/hybrid archs run a
        # state-collecting variant that checkpoints the state at each
        # chunk's last REAL position, so final-chunk padding never leaks
        # into the recurrent state and the chunk schedule is
        # size-invariant (the per-chunk prefill checkpoint from ROADMAP)
        self.chunked_prefill = self.prefill_chunk > 0

        self.queue: List[Request] = []
        self.running: List[Request] = []
        self.finished: List[Request] = []
        self.events: List[Dict[str, Any]] = []
        self._fns: Dict[Any, Callable] = {}
        self._verify_fn = make_verify_fn(cfg, group, window)
        self._now = 0  # logical iteration counter

    # ------------------------------------------------------------------
    # stream clocks
    # ------------------------------------------------------------------

    def bind_cost_model(
        self,
        cost_cfg: ModelConfig,
        hw: Optional[costmodel.Hardware] = None,
        *,
        invariant: bool = False,
    ) -> None:
        """Switch the runtime to a costed clock: stream times come from the
        TPU cost model evaluated at ``cost_cfg``'s scale (benchmarks cost
        the full model while scheduling the reduced one).  Must happen
        before the first step — rebinding mid-run would tear the clock.

        Verdict latency under a costed clock is ``verify_latency_ms``
        (default 0: a verdict is visible as soon as the verify-stream pass
        completes).  The deprecated integer ``verify_latency`` has no
        meaning in seconds and is ignored here beyond its >= 1 contract.
        """
        assert getattr(self, "_now", 0) == 0, "bind the clock before stepping"
        hw = hw or self.hw
        self.hw = hw

        def cost_fn(ev: Dict[str, Any]) -> float:
            if invariant:
                ev = dict(ev, invariant=True)
            return costmodel.step_time(cost_cfg, ev, hw)

        self.runtime = streams.DualClockRuntime(
            cost_fn,
            latency=(self.verify_latency_ms or 0.0) / 1e3,
            contention=hw.stream_contention,
        )

    # ------------------------------------------------------------------
    # jitted step builders (cached per shape class)
    # ------------------------------------------------------------------

    def _decode_fn(self, B: int, schedule: Schedule) -> Callable:
        key = ("decode", B, schedule)
        if key not in self._fns:
            cfg, axes = self.cfg, self.axes

            @jax.jit
            def step(params, pool, slots, tokens, pos, seeds, temps, out_pos,
                     top_ks):
                cache = kv_cache.gather(pool, axes, slots)
                logits, new_cache, _ = forward(
                    params, cfg, tokens[:, None],
                    cache=cache, start_pos=pos, schedule=schedule,
                )
                nxt = sample_batch(logits[:, 0], seeds, out_pos, temps, top_ks)
                pool2 = kv_cache.scatter(pool, axes, slots, new_cache)
                return pool2, nxt

            self._fns[key] = step
        return self._fns[key]

    def _prefill_fn(self, P: int) -> Callable:
        key = ("prefill", P)
        if key not in self._fns:
            cfg, axes = self.cfg, self.axes
            n_prefix = cfg.num_prefix_embeds
            rec = self.has_recurrent_state
            schedule = (
                INVARIANT_SCHEDULE if self.mode == Mode.BATCH_INVARIANT
                else VERIFY_SCHEDULE
            )

            @jax.jit
            def step(params, pool, slot, tokens, plen, seed, temp, top_k,
                     prefix_embeds):
                slots = slot[None]
                cache = kv_cache.gather(pool, axes, slots)
                if n_prefix:
                    tok_embeds = jnp.take(params["embed"], tokens, axis=0)
                    embeds = jnp.concatenate([prefix_embeds, tok_embeds], axis=1)
                    logits, new_cache, per_pos = forward(
                        params, cfg, inputs_embeds=embeds,
                        cache=cache, start_pos=jnp.zeros(1, jnp.int32),
                        schedule=schedule, collect_states=rec,
                    )
                    last = plen + n_prefix - 1
                else:
                    logits, new_cache, per_pos = forward(
                        params, cfg, tokens,
                        cache=cache, start_pos=jnp.zeros(1, jnp.int32),
                        schedule=schedule, collect_states=rec,
                    )
                    last = plen - 1
                tok = sample_token(logits[0, last], seed, jnp.int32(0), temp,
                                   top_k)
                if rec:  # bucket-pad positions must not advance O(1) state
                    new_cache = statepool.merge_rows(
                        new_cache, statepool.select_index(per_pos, last[None]),
                    )
                pool2 = kv_cache.scatter(pool, axes, slots, new_cache)
                return pool2, tok

            self._fns[key] = step
        return self._fns[key]

    def _prefill_chunk_fn(self, C: int) -> Callable:
        """Fixed-shape C-token prefill chunk, usable by every arch
        (generalizes the old sliding-window-only chunk path).  Takes input
        embeddings so token prompts, prefix embeds (multimodal) and encdec
        decoder prompts all share one shape class per chunk size.

        Recurrent/hybrid archs take a state-collecting variant: the chunk's
        recurrent state is checkpointed at ``last`` (the chunk's final REAL
        position), so final-chunk pad embeds never advance the O(1) state —
        which is what makes a recurrent chunk schedule size-invariant and
        lets ssm/hybrid prompts join the co-scheduled prefill lane."""
        rec = self.has_recurrent_state
        key = ("prefill_chunk_rec" if rec else "prefill_chunk", C)
        if key not in self._fns:
            cfg, axes = self.cfg, self.axes
            schedule = (
                INVARIANT_SCHEDULE if self.mode == Mode.BATCH_INVARIANT
                else VERIFY_SCHEDULE
            )

            @jax.jit
            def step(params, pool, slot, embeds, start, last):
                slots = slot[None]
                cache = kv_cache.gather(pool, axes, slots)
                logits, new_cache, per_pos = forward(
                    params, cfg, inputs_embeds=embeds, cache=cache,
                    start_pos=start[None], schedule=schedule,
                    collect_states=rec,
                )
                if rec:  # state after the last real position, pads dropped
                    new_cache = statepool.merge_rows(
                        new_cache,
                        statepool.select_index(per_pos, last[None]),
                    )
                return kv_cache.scatter(pool, axes, slots, new_cache), logits

            self._fns[key] = step
        return self._fns[key]

    def _cross_fn(self, Se: int) -> Callable:
        key = ("cross", Se)
        if key not in self._fns:
            cfg = self.cfg

            @jax.jit
            def build(params, enc_embeds):
                return build_cross_cache(params, cfg, enc_embeds)

            self._fns[key] = build
        return self._fns[key]

    # ------------------------------------------------------------------
    # scheduling
    # ------------------------------------------------------------------

    def submit(self, req: Request) -> None:
        self._check_capacity(req)
        req.state = State.QUEUED
        self.queue.append(req)

    def _check_capacity(self, req: Request) -> None:
        """Admission capacity guard: reject a request whose KV footprint
        (padded prefill extent + output budget + speculation overshoot)
        cannot fit a slot, instead of silently overflowing the pool.

        A deterministic request reserves ``spec_depth x (W-1) + 1`` verify
        rows past its output budget: up to ``spec_depth`` windows of W-1
        candidates can be in flight at once, and the deepest window's
        replay writes one verifier token past its last candidate."""
        cfg = self.cfg
        has_full_attn = cfg.attn_kind != "sliding" and any(
            cfg.layer_kind(i) == "attn" for i in range(cfg.num_layers)
        )
        if not has_full_attn:
            return  # sliding ring buffers wrap; recurrent state is O(1)
        prefix = cfg.num_prefix_embeds or 0
        L = prefix + req.prompt_len
        if self._use_chunked(req):
            C = self._chunk_size()
            extent = -(-L // C) * C  # the last chunk pads to the chunk shape
        else:
            extent = prefix + _bucket(req.prompt_len)
        spec = (
            self.spec_depth * (self.window - 1) + 1
            if self.mode == Mode.LLM42 and req.sampling.is_deterministic
            else 0
        )
        # peak slot usage is the MAX of the two phases, not their sum:
        # decode/verify writes start at L and overwrite the prefill pad tail
        need = max(extent, L + req.sampling.max_new_tokens + spec)
        if need > self.capacity:
            raise ValueError(
                f"request {req.rid} cannot fit the KV pool: "
                f"max(prefill extent {extent}, prompt {L} + max_new_tokens "
                f"{req.sampling.max_new_tokens} + verify rows "
                f"{spec} [= depth {self.spec_depth} x (W-1) + 1]) = "
                f"{need} > capacity {self.capacity}"
            )

    def _chunk_size(self) -> int:
        """Effective prefill chunk (ring-buffer contract caps it at the
        sliding window so a pass never overwrites in-window keys)."""
        C = self.prefill_chunk
        if self.cfg.attn_kind == "sliding":
            C = min(C, self.cfg.window)
        return max(1, C)

    def _use_chunked(self, req: Request) -> bool:
        """Chunked lane only when the prompt actually spans > 1 chunk: a
        prompt that fits one chunk runs the legacy exclusive pass — same
        single-iteration stall, but padded to its (smaller) power-of-two
        bucket instead of the full chunk width."""
        if not self.chunked_prefill:
            return False
        prefix = self.cfg.num_prefix_embeds or 0
        return prefix + req.prompt_len > self._chunk_size()

    def _admit(self) -> None:
        while self.queue and self.pool.num_free() > 0 and (
            len(self.running) < self.max_batch
        ):
            req = self.queue.pop(0)
            req.slot = self.pool.alloc()
            if self._use_chunked(req):
                # third lane: prefill advances chunk-by-chunk via scheduler
                # plans instead of one exclusive pass at admission
                self._prepare_prefill(req)
                req.state = State.PREFILLING
            else:
                self._prefill(req)
                req.state = State.RUNNING
            self.running.append(req)

    def _build_cross(self, req: Request) -> None:
        assert req.enc_embeds is not None, "encdec request needs enc_embeds"
        cross = self._cross_fn(req.enc_embeds.shape[1])(self.params, req.enc_embeds)
        slot = jnp.array([req.slot])
        cross_axes = {"k": 1, "v": 1, "mask": 0}
        self.pool.data["cross"] = kv_cache.scatter(
            self.pool.data["cross"], cross_axes, slot, cross
        )

    def _prepare_prefill(self, req: Request) -> None:
        """Host-side setup for chunk-resumable prefill: side inputs (cross
        cache, prefix embeds) and the chunk cursor.  Chunks embed their own
        token slice on demand (``_chunk_embeds``), so residency stays
        O(chunk), not O(prompt)."""
        cfg = self.cfg
        req._prefix_len = cfg.num_prefix_embeds
        if cfg.family == "encdec":
            self._build_cross(req)
        if cfg.num_prefix_embeds:
            prefix = req.prefix_embeds
            if prefix is None:
                prefix = jnp.zeros(
                    (1, cfg.num_prefix_embeds, cfg.d_model), jnp.dtype(cfg.dtype)
                )
            req._prefix_src = prefix
        req.prefill_total = (cfg.num_prefix_embeds or 0) + req.prompt_len
        req.prefill_pos = 0

    def _chunk_embeds(self, req: Request, s: int, C: int) -> jax.Array:
        """Input embeddings for prefill positions [s, s+C): prefix embeds
        where the chunk overlaps the prefix region, token embeddings for
        the prompt slice.  At most C real positions materialize."""
        prefix = getattr(req, "_prefix_len", 0) or 0
        parts = []
        if s < prefix:
            parts.append(req._prefix_src[:, s : min(prefix, s + C)])
        lo = max(s - prefix, 0)
        hi = min(s + C - prefix, req.prompt_len)
        if hi > lo:
            toks = jnp.array([req.prompt[lo:hi]], jnp.int32)
            parts.append(jnp.take(self.params["embed"], toks, axis=0))
        return parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=1)

    def _pad_embed(self) -> jax.Array:
        """(1, 1, D) embedding of token 0 — the legacy pad content."""
        if not hasattr(self, "_pad_row"):
            self._pad_row = jnp.take(
                self.params["embed"], jnp.array([[0]], jnp.int32), axis=0
            )
        return self._pad_row

    def _prefill_advance(self, req: Request, C: int) -> Dict[str, Any]:
        """Advance one fixed-shape C-token prefill chunk; the final chunk
        samples T0 and flips the request to RUNNING.  Pad positions embed
        token 0 (exactly the legacy padded passes); their KV lands past the
        prompt and is overwritten by decode before it can ever mask in."""
        s = req.prefill_pos
        total = req.prefill_total
        emb = self._chunk_embeds(req, s, C)
        real = emb.shape[1]
        if real < C:
            pad = jnp.broadcast_to(self._pad_embed(), (1, C - real, emb.shape[2]))
            emb = jnp.concatenate([emb, pad], axis=1)
        t0 = time.perf_counter()
        self.pool.data, logits = self._prefill_chunk_fn(C)(
            self.params, self.pool.data, jnp.int32(req.slot), emb,
            jnp.int32(s), jnp.int32(max(real - 1, 0)),
        )
        wall = time.perf_counter() - t0
        req.prefill_pos = s + real
        done = req.prefill_pos >= total
        if done:
            tok = sample_token(
                logits[0, total - 1 - s], jnp.int32(req.sampling.seed),
                jnp.int32(0), jnp.float32(req.sampling.temperature),
                jnp.int32(req.sampling.top_k),
            )
            # commit point == post-prompt state: first verify replay anchor
            self.statepool.set_commit_point(self.pool.data, req.slot)
            req.committed.append(int(tok))  # T0: deterministic by construction
            req.prefill_time = self._now
            req.state = State.RUNNING
            req._prefix_src = None
        return {
            "kind": "prefill_chunk", "tokens": real, "padded": C, "start": s,
            "wall": wall, "iter": self._now, "rid": req.rid, "done": done,
        }

    def _prefill(self, req: Request) -> None:
        cfg = self.cfg
        P = _bucket(req.prompt_len)
        if cfg.attn_kind == "sliding" and P > cfg.window:
            # ring-buffer contract: feed the prompt in window-sized chunks
            self._prepare_prefill(req)
            self._prefill_sliding(req)
            return
        req._prefix_len = cfg.num_prefix_embeds
        if cfg.family == "encdec":
            self._build_cross(req)
        tokens = jnp.array(
            [req.prompt + [0] * (P - req.prompt_len)], jnp.int32
        )
        prefix = req.prefix_embeds
        if cfg.num_prefix_embeds and prefix is None:
            prefix = jnp.zeros(
                (1, cfg.num_prefix_embeds, cfg.d_model), jnp.dtype(cfg.dtype)
            )
        t0 = time.perf_counter()
        self.pool.data, tok = self._prefill_fn(P)(
            self.params, self.pool.data, jnp.int32(req.slot), tokens,
            jnp.int32(req.prompt_len), jnp.int32(req.sampling.seed),
            jnp.float32(req.sampling.temperature),
            jnp.int32(req.sampling.top_k), prefix,
        )
        wall = time.perf_counter() - t0
        # commit point == post-prompt state: first verify replay anchor
        self.statepool.set_commit_point(self.pool.data, req.slot)
        req.committed.append(int(tok))  # T0: deterministic by construction
        req.prefill_time = self._now
        ev = {
            "kind": "prefill", "tokens": req.prompt_len + (cfg.num_prefix_embeds or 0),
            "padded": P + (cfg.num_prefix_embeds or 0), "wall": wall, "iter": self._now,
        }
        self.runtime.charge(ev)
        self.events.append(ev)

    def _prefill_sliding(self, req: Request) -> None:
        """Exclusive chunked prefill for sliding-window archs (<= window per
        pass — the ring-buffer contract).  Runs the same chunk machinery as
        the co-scheduled lane, synchronously, and emits one legacy
        ``prefill`` event.  Per-request fixed chunking => still
        deterministic by construction."""
        W = self.cfg.window
        wall = 0.0
        while req.prefill_pos < req.prefill_total:
            wall += self._prefill_advance(req, W)["wall"]
        ev = {
            "kind": "prefill", "tokens": req.prompt_len,
            "padded": ((req.prompt_len + W - 1) // W) * W, "wall": wall,
            "iter": self._now,
        }
        self.runtime.charge(ev)
        self.events.append(ev)

    def _view(self) -> sched.SchedulerView:
        """Snapshot handed to the schedule policy each iteration."""
        return sched.SchedulerView(
            running=tuple(self.running),
            mode=self.mode,
            window=self.window,
            group=self.group,
            # the double-buffered state pool makes speculation past
            # submitted windows safe on EVERY arch: verification never
            # writes the live recurrent state at launch, and rollbacks
            # restore from the window's ring checkpoint
            speculate_past_inflight=True,
            now=self._now,
            verify_latency=self.verify_latency,
            prefilling=tuple(
                r for r in self.running if r.state is State.PREFILLING
            ),
            now_time=self.runtime.now,
            verify_inflight=sum(len(r.pipeline) for r in self.running),
            verify_backlog=self.runtime.verify_backlog,
            acceptance={r.rid: r.accept_ema for r in self.running},
            spec_depth=self.spec_depth,
        )

    # ------------------------------------------------------------------
    # steps
    # ------------------------------------------------------------------

    def _decode_step(self, batch: List[Request]) -> Dict[str, Any]:
        B = len(batch)
        if self.mode == Mode.BATCH_INVARIANT:
            schedule = INVARIANT_SCHEDULE
        else:
            schedule = self.policy.schedule_for(B)
        slots = jnp.array([r.slot for r in batch], jnp.int32)
        last_tok, pos, out_pos, seeds, temps, top_ks = [], [], [], [], [], []
        for r in batch:
            # speculation order: committed, in-flight window, fresh candidates
            seq = r.committed + r.speculation
            last_tok.append(seq[-1])
            prefix = getattr(r, "_prefix_len", 0)
            pos.append(r.prompt_len + prefix + len(seq) - 1)
            out_pos.append(len(seq))
            seeds.append(r.sampling.seed)
            temps.append(r.sampling.temperature)
            top_ks.append(r.sampling.top_k)
        t0 = time.perf_counter()
        self.pool.data, nxt = self._decode_fn(B, schedule)(
            self.params, self.pool.data, slots,
            jnp.array(last_tok, jnp.int32), jnp.array(pos, jnp.int32),
            jnp.array(seeds, jnp.int32), jnp.array(temps, jnp.float32),
            jnp.array(out_pos, jnp.int32), jnp.array(top_ks, jnp.int32),
        )
        wall = time.perf_counter() - t0
        nxt = [int(t) for t in nxt]
        for r, t in zip(batch, nxt):
            if self.mode == Mode.LLM42 and r.sampling.is_deterministic:
                r.candidates.append(t)
                dvr.mark_window_state(r, self.window)
            else:
                r.committed.append(t)
        return {
            "kind": "decode", "batch": B, "schedule": tuple(schedule),
            "ctx_sum": sum(pos) + B, "wall": wall, "iter": self._now,
            "rids": [r.rid for r in batch],
        }

    def _verify_step(
        self, group: List[Request], *, defer: bool = False,
        n_decodable: int = 0,
    ) -> Dict[str, Any]:
        """Run one grouped verification pass.

        ``defer=False`` (pause policy / an AdaptivePolicy sync plan): the
        verdict is applied synchronously, exactly the seed behaviour; the
        pass blocks the main stream.  ``defer=True`` (overlap policy): the
        submitted candidates move into each request's in-flight FIFO
        (``core.pipeline``, up to ``spec_depth`` windows deep) and the
        pass is launched on the verify *stream* — its verdict becomes
        visible when the stream completes the pass plus the modeled extra
        latency (``verify_latency_ms``; ``verify_latency`` ticks under the
        logical shim), and splices strictly in submission order.  The
        device pass still executes eagerly (host-sequential simulation of
        an async verify stream), so its KV repair is in place before any
        later cache read — in particular before the next chained window of
        the same request replays — but the *protocol* result arrives at
        the stream-clock deadline.  On recurrent archs the pass routes its
        state selections through the double-buffered state pool instead of
        touching the live state (``core.verifier`` docstring).
        """
        G, W = self.group, self.window
        rows = group[:G]
        assert len({id(r) for r in rows}) == len(rows), (
            "a request may contribute one window per grouped pass — chained "
            "windows replay sequentially, never inside one batch"
        )
        n_pad = G - len(rows)
        inputs, cands, cand_lens, starts, bases, slots, seeds, temps, tks = (
            [], [], [], [], [], [], [], [], []
        )
        ring_idxs = []
        for r in rows:
            i, c, cl, sp, ob = dvr.build_verify_row(r, W)
            inputs.append(i)
            cands.append(c)
            cand_lens.append(cl)
            starts.append(sp)
            bases.append(ob)
            slots.append(r.slot)
            seeds.append(r.sampling.seed)
            temps.append(r.sampling.temperature)
            tks.append(r.sampling.top_k)
            if defer:
                assert len(r.pipeline) < self.spec_depth, (
                    "scheduler plan exceeds the configured spec_depth"
                )
                ring_idxs.append(r.window_seq % self.spec_depth)
            else:
                ring_idxs.append(0)  # sync: FIFO empty, ring 0 is free
        for _ in range(n_pad):
            inputs.append([0] * W)
            cands.append([-1] * (W - 1))
            cand_lens.append(0)
            starts.append(0)
            bases.append(0)
            slots.append(self.pool.scratch_slot)
            seeds.append(0)
            temps.append(0.0)
            tks.append(0)
            ring_idxs.append(0)
        t0 = time.perf_counter()
        args = (
            jnp.array(slots, jnp.int32), jnp.array(starts, jnp.int32),
            jnp.array(inputs, jnp.int32), jnp.array(cands, jnp.int32),
            jnp.array(cand_lens, jnp.int32), jnp.array(seeds, jnp.int32),
            jnp.array(temps, jnp.float32), jnp.array(bases, jnp.int32),
            jnp.array(tks, jnp.int32),
        )
        if self.has_recurrent_state:
            (self.pool.data, self.statepool.anchor, commit_rows, n_match,
             commit_tok, _v) = self._verify_fn(
                self.params, self.pool.data, self.statepool.anchor, *args
            )
            self.statepool.checkpoint(ring_idxs, slots, commit_rows)
        else:
            self.pool.data, n_match, commit_tok, _v = self._verify_fn(
                self.params, self.pool.data, *args
            )
        wall = time.perf_counter() - t0
        n_match = [int(n) for n in n_match]
        commit_tok = [int(t) for t in commit_tok]
        ev = {
            "kind": "verify", "group": len(rows), "window": W, "pad_rows": n_pad,
            "ctx_sum": sum(starts) + W * G, "wall": wall, "iter": self._now,
            # requests that could decode this iteration — under the pause
            # policy these are the requests the verify pass stalls; under
            # overlap they ride in the composite event's decode batch
            "rids": [r.rid for r in rows], "n_decodable": n_decodable,
            # stream assignment for per-stream time accounting: a deferred
            # pass rides the verify stream; a sync pass blocks the main one
            "deferred": defer,
        }
        ready_at = self.runtime.launch_verify(ev, sync=not defer)
        if defer:
            submitted_at = self.runtime.now
            for i, r in enumerate(rows):
                fl = pipeline.submit_window(
                    r, W, submitted_at, ready_at, ring_idx=ring_idxs[i]
                )
                fl.n_match, fl.commit_tok = n_match[i], commit_tok[i]
                self.statepool.note_submit(r.slot, starts[i] + W)
        else:
            for r, n, t in zip(rows, n_match, commit_tok):
                dvr.apply_verify_result(r, n, t, window=W)
                if self.statepool.active:
                    # live state + replay anchor <- the commit-index state
                    # the pass just checkpointed (ring 0)
                    self.pool.data = self.statepool.restore(
                        self.pool.data, r.slot, 0
                    )
        return ev

    def _retire(self) -> None:
        done = [r for r in self.running if r.finished() or (
            not r.sampling.is_deterministic and r.done_decoding()
        ) or (self.mode != Mode.LLM42 and r.done_decoding())]
        for r in done:
            # a det request must have no outstanding speculation at retirement
            if self.mode == Mode.LLM42 and r.sampling.is_deterministic and (
                r.candidates or r.pipeline
            ):
                continue
            r.state = State.FINISHED
            r.finish_time = self._now
            self.running.remove(r)
            self.pool.free(r.slot)
            self.statepool.note_release(r.slot)
            r.slot = -1
            self.finished.append(r)

    # ------------------------------------------------------------------
    # main loop
    # ------------------------------------------------------------------

    def step(self) -> bool:
        """One scheduler iteration.  Returns False when fully drained.

        Order within an iteration: advance the stream clock, land due
        verdicts, retire, admit, plan, PREFILL chunk, DECODE, then VERIFY
        launch.  Verdicts land *before* retirement so a request whose final
        in-flight verdict is due this iteration retires this iteration —
        not one late (``finish_time`` off-by-one, drain one step longer).
        Decode-before-verify is a correctness requirement, not taste: the
        decode of a row being submitted this iteration re-feeds its last
        candidate, writing fast-path KV at the window's final position — a
        position the verify replay is about to repair and that no later
        replay will ever cover again.  Launching the verify afterwards lets
        its repair win; every later speculative write lands at positions >=
        the next window start, which the next replay rewrites.  The prefill
        chunk touches only its own (PREFILLING) slot, so it is
        order-independent.

        Time accounting rides the dual-stream runtime: prefill and decode
        passes charge the main stream (serial — two launches on one
        stream), a deferred verify launches on the verify stream
        (``streams.DualClockRuntime``), and a sync verify (pause policy, or
        an ``AdaptivePolicy`` demotion) blocks the main stream.  An
        iteration that ran >= 2 passes still emits a single composite
        ``overlap`` event for log replay (``costmodel``)."""
        self._now += 1
        self.runtime.begin_iteration()
        applied = self._apply_due_verdicts()
        self._retire()
        self._admit()
        if not self.running and not self.queue:
            return False

        view = self._view()
        plan = self.scheduler.plan(view)
        pev = dev = vev = None
        if plan.prefill is not None:
            pev = self._prefill_advance(plan.prefill, self._chunk_size())
            self.runtime.charge(pev)
        if plan.decode:
            batch = [r for r in plan.decode if not r.done_decoding()]
            if batch:
                dev = self._decode_step(batch)
                self.runtime.charge(dev)
        if plan.verify:
            vev = self._verify_step(
                plan.verify,
                defer=self.scheduler.defers_verify and not plan.sync_verify,
                n_decodable=len(sched.decodable(view)),
            )
        self.runtime.end_iteration()

        subs = [("decode", dev), ("verify", vev), ("prefill", pev)]
        present = [(k, ev) for k, ev in subs if ev is not None]
        if len(present) >= 2:
            self.events.append({
                "kind": "overlap", **dict(present),
                "wall": sum(ev["wall"] for _, ev in present),
                "iter": self._now,
            })
        elif present:
            self.events.append(present[0][1])
        if present or applied:
            return True
        return bool(self.running or self.queue)

    def _apply_due_verdicts(self) -> bool:
        """Land in-flight verify results whose stream-clock deadline has
        been reached (``ready_at <= main-stream now``).  Groups launched at
        different times may land in the same iteration — and, with a
        per-launch latency schedule, in inverted launch order; splicing is
        per-request and strictly in submission order (``core.pipeline``
        applies only the FIFO front, however early later verdicts arrived),
        so landing order never moves a committed token.  A rollback splice
        — or one that leaves no surviving speculation — restores the slot's
        live recurrent state (and replay anchor) from the window's
        state-pool checkpoint."""
        applied = False
        now = self.runtime.now
        for r in self.running:
            for outcome in pipeline.apply_ready(r, self.window, now):
                applied = True
                self.statepool.note_splice(r.slot, len(outcome.cascaded))
                if not self.statepool.active or (
                    r.finished() and not (r.pipeline or r.candidates)
                ):
                    # skip device work only when the request is about to
                    # retire with nothing left to verify — an EOS-finished
                    # request with a surviving tail still verifies it, and
                    # that replay needs the anchor advanced
                    continue
                if outcome.restore_state:
                    self.pool.data = self.statepool.restore(
                        self.pool.data, r.slot, outcome.record.ring_idx
                    )
                elif outcome.reanchor:
                    # FIFO drained but live state + speculation tail
                    # survive: only the replay anchor moves (the next
                    # window launches anchored, one token past the chained
                    # start state the last launch recorded)
                    self.statepool.reanchor(r.slot, outcome.record.ring_idx)
        return applied

    def run(self, max_iters: int = 100000) -> List[Request]:
        for _ in range(max_iters):
            if not self.step():
                break
        assert not self.running and not self.queue, "engine did not drain"
        return self.finished
