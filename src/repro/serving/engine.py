"""The LLM-42 serving engine: continuous batching + selective determinism.

Three modes (paper §5 baselines):

  * ``Mode.NONDET``           — SGLang-Non-Deterministic: fast path only;
                                schedules vary with dynamic batch size.
  * ``Mode.BATCH_INVARIANT``  — SGLang-Deterministic: one universal schedule
                                for every op, all traffic pays for it.
  * ``Mode.LLM42``            — the paper: fast path for everyone +
                                decode-verify-rollback for requests with
                                ``is_deterministic=True``.

Per-iteration verify/decode arbitration is delegated to the scheduler
subsystem (``serving.scheduler``): ``PauseDecodePolicy`` reproduces the
paper prototype's behaviour (verification pauses decoding, §5.2 limitation
(1)); ``OverlapPolicy`` — the default for ``Mode.LLM42`` — co-schedules the
verify group alongside the same iteration's decode batch, with a
per-request in-flight verify FIFO (``core.pipeline``) so a request keeps
speculating past submitted windows and pipelines up to ``spec_depth``
windows deep; verdicts splice strictly in submission order, rollbacks
cascade through later windows, and the double-buffered state pool
(``serving.statepool``) checkpoints recurrent state at each window
submission so ssm/hybrid archs pipeline just as deep (they used to be
hard-capped at one window).  Prefill stays per-request (deterministic by
construction, never co-batched) but is chunk-resumable: with
``prefill_chunk > 0`` a prompt advances ``C`` tokens per iteration as the
scheduler's third lane instead of one exclusive pass at admission, so a
long prompt no longer stalls the decode batch (§5.2 limitation (2));
decode batches are formed from all decodable requests each iteration
(continuous batching).

Memory is paged (``serving.blockpool``): full-attention KV lives in a
global pool of fixed-size ref-counted blocks addressed through per-request
block tables, a radix prefix cache (``serving.prefixcache``) maps the
longest *committed*-prefix match of an arriving prompt onto shared
read-only blocks (only the tail is prefilled), and on pool exhaustion the
memory policy (``scheduler.BlockMemoryPolicy``) preempts an LRU victim —
its blocks are evicted while its committed stream, slot and statepool
replay anchor survive, and the later restore replays only committed
tokens through the chunked-prefill lane, which is bitwise-identical by
construction.  Admission is free-block accounting, not dense per-slot
reservation.

Every device step goes through a jitted function cached per *shape class*
(batch size, prompt bucket, window) — recompilation per shape is exactly
the shape→schedule coupling (O2) the paper builds on.

Time is kept by the dual-clock execution-stream runtime
(``serving.streams``): decode/prefill passes charge the main stream,
deferred verification launches on the verify stream, and verdict deadlines
are continuous (``verify_latency_ms``; the default clock is the logical
1-tick-per-iteration mode).  An event log still records (kind, shape
metadata, wall time) per step; the benchmark harness replays it through
the TPU cost model (``serving.costmodel``) to derive paper-comparable
throughput numbers.
"""

from __future__ import annotations

import time
from typing import Any, Callable, Dict, List, Optional, Set

import jax
import jax.numpy as jnp

from repro.core import dvr, pipeline
from repro.core.determinism import (
    FAST_PATH_POLICY,
    INVARIANT_SCHEDULE,
    Mode,
    ReductionPolicy,
    Schedule,
    VERIFY_SCHEDULE,
)
from repro.core.verifier import make_verify_body, make_verify_fn
from repro.models.base import ModelConfig
from repro.models.layers import PagedView
from repro.models.transformer import build_cross_cache, forward
from repro.obs import Observability, TokenProvenance
from repro.serving import costmodel, kv_cache, prefixcache, statepool, streams
from repro.serving import blockpool
from repro.serving import scheduler as sched
from repro.serving.request import Request, State
from repro.serving.sampler import sample_batch, sample_token, top2_margin


def _bucket(n: int) -> int:
    """Next power-of-two bucket (>= 8) for prompt padding."""
    b = 8
    while b < n:
        b *= 2
    return b


class Engine:
    def __init__(
        self,
        cfg: ModelConfig,
        params: Dict,
        *,
        mode: Mode = Mode.LLM42,
        policy: ReductionPolicy = FAST_PATH_POLICY,
        window: int = 8,  # verification window W (verifies W-1 candidates)
        group: int = 4,  # requests verified together (grouped verification)
        max_batch: int = 8,
        capacity: Optional[int] = None,
        scheduler: Optional[sched.SchedulePolicy] = None,
        spec_depth: int = 1,  # verify windows in flight per request
        verify_latency_ms: Optional[float] = None,  # continuous verdict latency
        cost_cfg: Optional[ModelConfig] = None,  # config the stream clocks cost at
        hw: costmodel.Hardware = costmodel.V5E,
        prefill_chunk: int = 0,  # tokens per prefill chunk; 0 = exclusive
        block_size: int = blockpool.DEFAULT_BLOCK_SIZE,  # KV tokens per block
        num_blocks: Optional[int] = None,  # pool size; None = dense parity
        prefix_cache: bool = True,  # share committed-prefix KV blocks
        mem_policy: Optional[sched.BlockMemoryPolicy] = None,
        paged_attention: bool = True,  # in-place paged forward + fused step
        trace: bool = False,  # dual-stream request tracing (repro.obs.trace)
        audit: bool = False,  # per-token determinism audit (repro.obs.audit)
        tp: int = 1,  # fast-path tensor-parallel width (logical model axis)
    ):
        self.cfg = cfg
        self.params = params
        self.mode = mode
        self.policy = policy
        # Logical TP width of the FAST PATH: decode/prefill schedules carry
        # tp_shards=tp un-pinned (the mesh-order combine a width-tp ring
        # all-reduce would produce).  The commit path never reads this —
        # make_verify_fn closes over CANONICAL_MESH_SCHEDULE, whose pinned
        # balanced tree every power-of-two width dividing
        # CANONICAL_TP_SHARDS realizes bitwise — which is exactly the
        # TP-invariance theorem the analysis prover checks.
        from repro.core.determinism import CANONICAL_TP_SHARDS
        assert tp >= 1 and CANONICAL_TP_SHARDS % tp == 0, (
            f"tp={tp} must divide the canonical shard count "
            f"{CANONICAL_TP_SHARDS} for the commit tree to be realizable"
        )
        self.tp = int(tp)
        self.window = window
        self.group = group
        self.max_batch = max_batch
        self.capacity = capacity or cfg.max_seq_len
        self.pool = kv_cache.CachePool(
            cfg, max_batch, self.capacity,
            block_size=block_size, num_blocks=num_blocks,
        )
        self.axes = self.pool.axes
        # commit-aware prefix sharing needs (a) paged full-attention KV to
        # share, (b) a token-addressable position 0 (prefix embeds and
        # encdec cross caches are per-request side inputs the radix key
        # cannot see), and (c) no recurrent state (an O(1) state at the
        # match point cannot be reconstructed from shared KV alone)
        shareable = (
            self.pool.paged
            and not statepool.has_recurrent_state(cfg)
            and not cfg.num_prefix_embeds
            and cfg.family != "encdec"
        )
        self.prefix_cache: Optional[prefixcache.PrefixCache] = (
            prefixcache.PrefixCache(self.pool.block_size)
            if (prefix_cache and shareable) else None
        )
        self.mem_policy = mem_policy or sched.BlockMemoryPolicy()
        # recurrent/hybrid archs advance SSM/RWKV state irreversibly on the
        # fast path; the double-buffered state pool (serving.statepool)
        # carries the verify replay anchor + per-window rollback checkpoints
        # so speculation can run `spec_depth` windows deep anyway.  For
        # attention archs the pool is host-side depth/extent telemetry only.
        self.has_recurrent_state = statepool.has_recurrent_state(cfg)
        assert spec_depth >= 1, "at least one verify window must be allowed"
        self.spec_depth = int(spec_depth)
        self.statepool = statepool.StatePool(cfg, max_batch, self.spec_depth)

        self.scheduler = scheduler if scheduler is not None else sched.default_policy(mode)
        assert verify_latency_ms is None or verify_latency_ms >= 0.0
        self.verify_latency_ms = verify_latency_ms
        self.hw = hw
        # dual-clock execution-stream runtime (serving.streams).  Default is
        # the logical clock (1 tick per iteration, verdicts 1 tick after
        # launch).  Passing verify_latency_ms — or calling
        # bind_cost_model(), which run_online() does — switches to the
        # costed clock: continuous main/verify stream times from the cost
        # model, verify passes queueing on their own stream, verdicts
        # landing latency_ms after the pass completes.
        self.runtime = streams.DualClockRuntime(latency=1.0)
        if verify_latency_ms is not None:
            self.bind_cost_model(cost_cfg or cfg, hw)
        assert prefill_chunk >= 0, "prefill_chunk must be >= 0 (0 = exclusive)"
        self.prefill_chunk = int(prefill_chunk)
        # chunked prefill covers every family: attention archs share the
        # embeds-based chunk pass; recurrent/hybrid archs run a
        # state-collecting variant that checkpoints the state at each
        # chunk's last REAL position, so final-chunk padding never leaks
        # into the recurrent state and the chunk schedule is
        # size-invariant (the per-chunk prefill checkpoint from ROADMAP)
        self.chunked_prefill = self.prefill_chunk > 0

        self.queue: List[Request] = []
        self.running: List[Request] = []
        self.preempted: List[Request] = []  # blocks evicted, restore pending
        self.finished: List[Request] = []
        self.events: List[Dict[str, Any]] = []
        self._fns: Dict[Any, Callable] = {}
        # paged in-place forward: decode/verify (and the chunked prefill)
        # read and repair full-attention KV *through* the block tables
        # instead of round-tripping a per-row gathered view, which is what
        # lets one fused launch cover the whole mixed batch.  Requires a
        # paged pool (full-attention leaves); archs without one (rwkv,
        # sliding-only) keep the legacy lanes.  Committed streams are
        # bitwise identical either way.
        self.paged_attention = bool(paged_attention)
        self._paged_fwd = self.pool.paged and self.paged_attention
        self._verify_fn = make_verify_fn(
            cfg, group, window, self.pool.layout, paged=self._paged_fwd
        )
        self._now = 0  # logical iteration counter
        # memory-subsystem telemetry
        self.num_preemptions = 0
        self.num_restores = 0
        self.restored_tokens = 0
        self.peak_running = 0
        # unified observability (repro.obs): metrics registry (always on),
        # tracer and audit log (Null twins unless asked for).  Host-side
        # bookkeeping over values the engine computes anyway — committed
        # streams are bitwise identical with recording on or off.
        self.obs = Observability(trace=trace, audit=audit)
        self._register_metrics()

    # ------------------------------------------------------------------
    # stream clocks
    # ------------------------------------------------------------------

    def bind_cost_model(
        self,
        cost_cfg: ModelConfig,
        hw: Optional[costmodel.Hardware] = None,
        *,
        invariant: bool = False,
    ) -> None:
        """Switch the runtime to a costed clock: stream times come from the
        TPU cost model evaluated at ``cost_cfg``'s scale (benchmarks cost
        the full model while scheduling the reduced one).  Must happen
        before the first step — rebinding mid-run would tear the clock.

        Verdict latency under a costed clock is ``verify_latency_ms``
        (default 0: a verdict is visible as soon as the verify-stream pass
        completes).
        """
        assert getattr(self, "_now", 0) == 0, "bind the clock before stepping"
        hw = hw or self.hw
        self.hw = hw

        def cost_fn(ev: Dict[str, Any]) -> float:
            if invariant:
                ev = dict(ev, invariant=True)
            if self.tp > 1 and "tp" not in ev:
                ev = dict(ev, tp=self.tp)
            return costmodel.step_time(cost_cfg, ev, hw)

        self.runtime = streams.DualClockRuntime(
            cost_fn,
            latency=(self.verify_latency_ms or 0.0) / 1e3,
            contention=hw.stream_contention,
        )

    # ------------------------------------------------------------------
    # observability (repro.obs)
    # ------------------------------------------------------------------

    def _register_metrics(self) -> None:
        """Register every engine series with the metrics registry.  Pull
        gauges close over ``self`` attribute lookups — never over the
        objects themselves (``bind_cost_model`` replaces ``self.runtime``
        wholesale)."""
        m = self.obs.metrics
        self._c_iters = m.counter(
            "engine.iterations", unit="iterations",
            help="scheduler iterations stepped")
        self._c_submitted = m.counter(
            "engine.requests_submitted", unit="requests",
            help="requests submitted to the engine")
        self._c_finished = m.counter(
            "engine.requests_finished", unit="requests",
            help="requests retired")
        self._c_fused = m.counter(
            "engine.fused_steps", unit="launches",
            help="iterations whose whole device side ran as one fused "
                 "mixed-batch launch")
        m.gauge_fn("engine.running", lambda: len(self.running),
                   unit="requests", help="requests in the running set")
        m.gauge_fn("engine.queued", lambda: len(self.queue),
                   unit="requests", help="requests awaiting admission")
        m.gauge_fn("engine.preempted", lambda: len(self.preempted),
                   unit="requests", help="requests evicted, restore pending")
        m.gauge_fn("engine.peak_running", lambda: self.peak_running,
                   unit="requests", help="peak concurrent running requests")
        m.gauge_fn("engine.tp", lambda: self.tp,
                   unit="shards", help="fast-path tensor-parallel width")
        self._c_committed = m.counter(
            "tokens.committed", unit="tokens",
            help="tokens committed across all requests (prefill T0 + "
                 "direct decode commits + verify splices)")
        self._c_recomputed = m.counter(
            "tokens.recomputed", unit="tokens",
            help="speculated tokens rejected by verification (rollback "
                 "recompute cost)")
        self._c_windows = m.counter(
            "verify.windows_submitted", unit="windows",
            help="verify windows moved into in-flight FIFOs (deferred path)")
        self._c_passes = m.counter(
            "verify.passes", unit="verdicts",
            help="verify verdicts applied (sync passes + pipelined splices)")
        self._c_rollbacks = m.counter(
            "verify.rollbacks", unit="rollbacks",
            help="verdicts that rejected at least one speculated token")
        self._c_cascaded = m.counter(
            "verify.cascaded_windows", unit="windows",
            help="in-flight windows discarded by cascade invalidation")
        self._h_rollback_depth = m.histogram(
            "verify.rollback_depth", unit="tokens",
            help="tokens rejected per rolling-back verdict (in-window + "
                 "cascaded + fresh tail)")
        self._h_acceptance = m.histogram(
            "verify.acceptance_ema", unit="fraction",
            help="per-request acceptance EMA at retirement (det requests)")
        m.gauge_fn("verify.inflight",
                   lambda: sum(len(r.pipeline) for r in self.running),
                   unit="windows", help="verify windows currently in flight")
        # dual-clock stream telemetry (serving.streams)
        m.gauge_fn("streams.main_busy", lambda: self.runtime.main.busy,
                   unit="s", help="main-stream busy time (costed clock)")
        m.gauge_fn("streams.verify_busy", lambda: self.runtime.verify.busy,
                   unit="s", help="verify-stream busy time (costed clock)")
        m.gauge_fn("streams.makespan", lambda: self.runtime.makespan,
                   unit="s", help="completion time of all scheduled work")
        m.gauge_fn("streams.verify_backlog",
                   lambda: self.runtime.verify_backlog,
                   unit="s", help="verify-stream work scheduled past now")
        m.gauge_fn("streams.outstanding_verdicts",
                   lambda: self.runtime.outstanding_verdicts,
                   unit="verdicts", help="verdicts launched but not yet due")
        m.gauge_fn("streams.peak_outstanding",
                   lambda: self.runtime.peak_outstanding,
                   unit="verdicts", help="deepest verdict queue seen")
        # memory subsystem: block pool, preemption lane, prefix cache
        m.gauge_fn("mem.preemptions", lambda: self.num_preemptions,
                   unit="preemptions", help="requests evicted under pressure")
        m.gauge_fn("mem.restores", lambda: self.num_restores,
                   unit="restores", help="preempted requests re-admitted")
        m.gauge_fn("mem.restored_tokens", lambda: self.restored_tokens,
                   unit="tokens", help="positions replayed by restores")
        m.gauge_fn("blockpool.block_size", lambda: self.pool.block_size,
                   unit="tokens", help="KV positions per block")
        m.gauge_fn("blockpool.num_blocks",
                   lambda: self.pool.alloc_blocks.num_blocks,
                   unit="blocks", help="total pool blocks")
        m.gauge_fn("blockpool.blocks_in_use",
                   lambda: self.pool.alloc_blocks.in_use(),
                   unit="blocks", help="blocks currently referenced")
        m.gauge_fn("blockpool.peak_blocks_in_use",
                   lambda: self.pool.alloc_blocks.peak_in_use,
                   unit="blocks", help="peak referenced blocks")
        m.gauge_fn("blockpool.free_blocks",
                   lambda: self.pool.alloc_blocks.num_free(),
                   unit="blocks", help="immediately free blocks")
        m.gauge_fn("blockpool.allocs",
                   lambda: getattr(self.pool.alloc_blocks, "num_allocs", 0),
                   unit="blocks", help="block allocations served")
        m.gauge_fn("blockpool.frees",
                   lambda: getattr(self.pool.alloc_blocks, "num_frees", 0),
                   unit="blocks", help="blocks returned to the free list")
        m.gauge_fn("blockpool.paged", lambda: int(self.pool.paged),
                   help="1 when full-attention KV is paged")
        if self.prefix_cache is not None:
            def _pc(key: str):
                return lambda: self.prefix_cache.stats()[key]
            m.gauge_fn("prefixcache.hits", _pc("prefix_hits"),
                       unit="lookups", help="admissions matching >= 1 block")
            m.gauge_fn("prefixcache.misses", _pc("prefix_misses"),
                       unit="lookups", help="admissions matching 0 blocks")
            m.gauge_fn("prefixcache.hit_tokens", _pc("prefix_hit_tokens"),
                       unit="tokens", help="prompt tokens served from cache")
            m.gauge_fn("prefixcache.insertions", _pc("prefix_insertions"),
                       unit="blocks", help="blocks registered with the radix")
            m.gauge_fn("prefixcache.evictions", _pc("prefix_evictions"),
                       unit="blocks", help="cached blocks reclaimed LRU")
            m.gauge_fn("prefixcache.size_blocks", _pc("prefix_size_blocks"),
                       unit="blocks", help="blocks resident in the cache")
            m.gauge_fn(
                "prefixcache.hit_rate",
                lambda: (lambda s: s["prefix_hits"]
                         / max(s["prefix_hits"] + s["prefix_misses"], 1))(
                    self.prefix_cache.stats()),
                unit="fraction", help="lookup hit rate")
        if hasattr(self.scheduler, "num_demotions"):
            m.gauge_fn("scheduler.demotions",
                       lambda: self.scheduler.num_demotions,
                       unit="transitions",
                       help="adaptive demotions to sync verification")
            m.gauge_fn("scheduler.promotions",
                       lambda: self.scheduler.num_promotions,
                       unit="transitions",
                       help="adaptive promotions back to overlap")
        self._h_ttft = m.histogram(
            "latency.ttft", unit="s",
            help="submit to first committed token (stream clock)")
        self._h_tpot = m.histogram(
            "latency.tpot", unit="s",
            help="mean inter-token time past T0 (stream clock)")
        self._h_e2e = m.histogram(
            "latency.e2e", unit="s",
            help="submit to retirement (stream clock)")

    def _charge_main(self, ev: Dict[str, Any]) -> None:
        """Charge one main-stream pass AND record its trace slice (the
        runtime stashes the launch's (start, finish) in
        ``last_main_span``; None under the logical clock — the tracer
        lays those out across the iteration window)."""
        self.runtime.charge(ev)
        tr = self.obs.tracer
        if tr.enabled:
            tr.pass_span("main", ev["kind"], self.runtime.last_main_span,
                         self._trace_args(ev))

    @staticmethod
    def _trace_args(ev: Dict[str, Any]) -> Dict[str, Any]:
        """Scalar-only view of an engine event for trace-slice args."""
        return {
            k: (list(v) if k == "rids" else v)
            for k, v in ev.items()
            if k not in ("wall",)
            and isinstance(v, (int, float, str, bool, list, tuple))
        }

    def _note_t0(self, req: Request, margin: Optional[float] = None) -> None:
        """Metrics + audit for the T0 token a prefill pass just committed
        (sampled under the fixed verify-grade schedule in every mode —
        deterministic by construction, window -1)."""
        self._c_committed.inc()
        if req.first_token_clock < 0:
            req.first_token_clock = self.runtime.now
        au = self.obs.audit
        if au.enabled:
            schedule = (
                INVARIANT_SCHEDULE if self.mode == Mode.BATCH_INVARIANT
                else VERIFY_SCHEDULE
            )
            au.record(TokenProvenance(
                rid=req.rid, index=len(req.committed) - 1,
                token=req.committed[-1], origin="prefill",
                schedule=schedule, margin=margin,
            ))

    def _note_splice(self, req: Request, outcome: pipeline.SpliceOutcome,
                     ) -> None:
        """Metrics + trace + audit for one pipelined front splice."""
        fl = outcome.record
        self._c_passes.inc()
        self._c_committed.inc(outcome.committed_count)
        if outcome.rejected:
            self._c_rollbacks.inc()
            self._c_recomputed.inc(outcome.rejected)
            self._h_rollback_depth.observe(outcome.rejected)
        if outcome.cascaded:
            self._c_cascaded.inc(len(outcome.cascaded))
        tr = self.obs.tracer
        if tr.enabled:
            tr.instant(
                "rollback" if outcome.rolled_back else "commit",
                t=self.runtime.now, rid=req.rid, window=fl.seq,
                n_match=fl.n_match, committed=outcome.committed_count,
                rejected=outcome.rejected, cascaded=len(outcome.cascaded),
            )
        if req.first_token_clock < 0 and req.committed:
            req.first_token_clock = self.runtime.now
        au = self.obs.audit
        if not au.enabled:
            return
        n = min(fl.n_match, len(fl.cands))
        for j in range(outcome.committed_count):
            idx = outcome.committed_base + j
            au.record(TokenProvenance(
                rid=req.rid, index=idx, token=req.committed[idx],
                origin="verify", schedule=VERIFY_SCHEDULE,
                window=fl.seq, occurrence=fl.ring_idx,
                n_match=fl.n_match, accepted=j < n,
                rollback=outcome.rolled_back,
                cascaded=len(outcome.cascaded), shifted=fl.shifted,
                margin=(fl.margins[j]
                        if fl.margins and j < len(fl.margins) else None),
            ))

    # ------------------------------------------------------------------
    # jitted step builders (cached per shape class)
    # ------------------------------------------------------------------

    def _pview(self) -> Optional[PagedView]:
        """Static paged-addressing descriptor threaded into ``forward``
        when the in-place paged path is on (None = legacy gathered
        views)."""
        if not self._paged_fwd:
            return None
        lay = self.pool.layout
        return PagedView(lay.block_size, lay.null_bid, lay.scratch_bid)

    def _decode_body(self, B: int, schedule: Schedule) -> Callable:
        """UNJITTED one-token decode body for a fixed batch size.

        Under the paged path the pool's full-attention leaves are passed
        whole and the forward reads/writes them through the block tables
        (``models.layers.attention_paged``) — the per-iteration
        gather/scatter copy of every row's KV never materializes.  The
        legacy path keeps the gathered per-row views.  Separate from
        ``_decode_fn`` so the fused mixed-batch step can compose it with
        the prefill-chunk and verify bodies under one jit."""
        cfg, lay = self.cfg, self.pool.layout
        paged, pview = self._paged_fwd, self._pview()

        def step(params, pool, slots, tables, tokens, pos, seeds, temps,
                 out_pos, top_ks):
            if paged:
                cache = kv_cache.gather_mixed(pool, lay, slots)
            else:
                cache = kv_cache.gather(pool, lay, slots, tables)
            logits, new_cache, _ = forward(
                params, cfg, tokens[:, None],
                cache=cache, start_pos=pos, schedule=schedule,
                tables=tables if paged else None, paged=pview,
            )
            nxt = sample_batch(logits[:, 0], seeds, out_pos, temps, top_ks)
            # top-1/top-2 margin per row: audit provenance for directly
            # committed fast-path tokens.  Computed unconditionally so the
            # device program is identical with auditing on or off; host
            # float conversion is gated instead.
            margins = top2_margin(logits[:, 0])
            if paged:
                pool2 = kv_cache.scatter_mixed(pool, lay, slots, new_cache)
            else:
                pool2 = kv_cache.scatter(pool, lay, slots, tables, new_cache)
            return pool2, nxt, margins

        return step

    def _decode_fn(self, B: int, schedule: Schedule) -> Callable:
        key = ("decode", B, schedule)
        if key not in self._fns:
            self._fns[key] = jax.jit(self._decode_body(B, schedule))
        return self._fns[key]

    # det: commit-path
    def _prefill_fn(self, P: int) -> Callable:
        key = ("prefill", P)
        if key not in self._fns:
            cfg, lay = self.cfg, self.pool.layout
            n_prefix = cfg.num_prefix_embeds
            rec = self.has_recurrent_state
            schedule = (
                INVARIANT_SCHEDULE if self.mode == Mode.BATCH_INVARIANT
                else VERIFY_SCHEDULE
            )

            @jax.jit
            def step(params, pool, slot, table, tokens, plen, seed, temp,
                     top_k, prefix_embeds):
                slots = slot[None]
                cache = kv_cache.gather(pool, lay, slots, table[None])
                if n_prefix:
                    tok_embeds = jnp.take(params["embed"], tokens, axis=0)
                    embeds = jnp.concatenate([prefix_embeds, tok_embeds], axis=1)
                    logits, new_cache, per_pos = forward(
                        params, cfg, inputs_embeds=embeds,
                        cache=cache, start_pos=jnp.zeros(1, jnp.int32),
                        schedule=schedule, collect_states=rec,
                    )
                    last = plen + n_prefix - 1
                else:
                    logits, new_cache, per_pos = forward(
                        params, cfg, tokens,
                        cache=cache, start_pos=jnp.zeros(1, jnp.int32),
                        schedule=schedule, collect_states=rec,
                    )
                    last = plen - 1
                tok = sample_token(logits[0, last], seed, jnp.int32(0), temp,
                                   top_k)
                marg = top2_margin(logits[0, last])  # T0 audit provenance
                if rec:  # bucket-pad positions must not advance O(1) state
                    new_cache = statepool.merge_rows(
                        new_cache, statepool.select_index(per_pos, last[None]),
                    )
                pool2 = kv_cache.scatter(pool, lay, slots, table[None], new_cache)
                return pool2, tok, marg

            self._fns[key] = step
        return self._fns[key]

    def _prefill_chunk_body(self, C: int) -> Callable:
        """UNJITTED fixed-shape C-token prefill-chunk body.

        Recurrent/hybrid archs run a state-collecting variant: the chunk's
        recurrent state is checkpointed at ``last`` (the chunk's final REAL
        position), so final-chunk pad embeds never advance the O(1) state —
        which is what makes a recurrent chunk schedule size-invariant and
        lets ssm/hybrid prompts join the co-scheduled prefill lane.  The
        chunk runs the fixed verify-grade schedule in every mode, and the
        paged in-place variant reads/writes KV through the block table, so
        both variants are deterministic by construction and bitwise
        equal."""
        cfg, lay = self.cfg, self.pool.layout
        rec = self.has_recurrent_state
        paged, pview = self._paged_fwd, self._pview()
        schedule = (
            INVARIANT_SCHEDULE if self.mode == Mode.BATCH_INVARIANT
            else VERIFY_SCHEDULE
        )

        def chunk(params, pool, slot, table, embeds, start, last):
            slots = slot[None]
            tables = table[None]
            if paged:
                cache = kv_cache.gather_mixed(pool, lay, slots)
            else:
                cache = kv_cache.gather(pool, lay, slots, tables)
            logits, new_cache, per_pos = forward(
                params, cfg, inputs_embeds=embeds, cache=cache,
                start_pos=start[None], schedule=schedule,
                collect_states=rec,
                tables=tables if paged else None, paged=pview,
            )
            if rec:  # state after the last real position, pads dropped
                new_cache = statepool.merge_rows(
                    new_cache,
                    statepool.select_index(per_pos, last[None]),
                )
            if paged:
                pool2 = kv_cache.scatter_mixed(pool, lay, slots, new_cache)
            else:
                pool2 = kv_cache.scatter(pool, lay, slots, tables, new_cache)
            return pool2, logits

        return chunk

    # det: commit-path
    def _prefill_chunk_fn(self, C: int) -> Callable:
        """Fixed-shape C-token prefill chunk, usable by every arch
        (generalizes the old sliding-window-only chunk path).  Takes input
        embeddings so token prompts, prefix embeds (multimodal) and encdec
        decoder prompts all share one shape class per chunk size.  The
        semantics live in ``_prefill_chunk_body``; this is just the cached
        standalone jit of it."""
        rec = self.has_recurrent_state
        key = ("prefill_chunk_rec" if rec else "prefill_chunk", C)
        if key not in self._fns:
            self._fns[key] = jax.jit(self._prefill_chunk_body(C))
        return self._fns[key]

    def _cross_fn(self, Se: int) -> Callable:
        key = ("cross", Se)
        if key not in self._fns:
            cfg = self.cfg

            @jax.jit
            def build(params, enc_embeds):
                return build_cross_cache(params, cfg, enc_embeds)

            self._fns[key] = build
        return self._fns[key]

    def _fused_fn(
        self, C: Optional[int], B: int, schedule: Schedule, n_groups: int
    ) -> Callable:
        """ONE jitted launch for the iteration's whole mixed batch: the
        current prefill chunk (``C`` tokens, or None), the decode batch
        (``B`` rows, or 0) and ``n_groups`` due verify groups run as
        sequential sub-passes threading a single pool (+ state-pool anchor
        on recurrent archs).  The weights stream once per iteration instead
        of once per sub-pass, and the per-launch fixed overhead is paid
        once; the sub-passes keep their exact standalone bodies (and their
        exact per-shape schedules), so fusing moves no committed token.
        Cached per shape class — (chunk, batch, schedule, group count) —
        like every other jitted step."""
        key = ("fused", C, B, schedule, n_groups)
        if key not in self._fns:
            pbody = self._prefill_chunk_body(C) if C is not None else None
            dbody = self._decode_body(B, schedule) if B else None
            vbody = (
                make_verify_body(
                    self.cfg, self.group, self.window, self.pool.layout,
                    paged=self._paged_fwd,
                )
                if n_groups else None
            )
            rec = self.has_recurrent_state

            def fused(params, pool, anchor, pargs, dargs, vargs_list):
                logits_p = nxt = dmarg = None
                if pbody is not None:
                    pool, logits_p = pbody(params, pool, *pargs)
                if dbody is not None:
                    pool, nxt, dmarg = dbody(params, pool, *dargs)
                vouts = []
                for vargs in vargs_list:
                    if rec:
                        (pool, anchor, commit_rows, n_match, commit_tok,
                         _v, marg) = vbody(params, pool, anchor, *vargs)
                        vouts.append((commit_rows, n_match, commit_tok, marg))
                    else:
                        pool, n_match, commit_tok, _v, marg = vbody(
                            params, pool, *vargs
                        )
                        vouts.append((None, n_match, commit_tok, marg))
                return pool, anchor, logits_p, nxt, dmarg, vouts

            self._fns[key] = jax.jit(fused)
        return self._fns[key]

    # ------------------------------------------------------------------
    # scheduling
    # ------------------------------------------------------------------

    def submit(self, req: Request) -> None:
        self._check_capacity(req)
        req.state = State.QUEUED
        req.submit_clock = self.runtime.now
        self.queue.append(req)
        self._c_submitted.inc()
        tr = self.obs.tracer
        if tr.enabled:
            tr.request_begin(req.rid, req.submit_clock)
            tr.instant("submit", t=req.submit_clock, rid=req.rid,
                       prompt_len=req.prompt_len,
                       deterministic=req.sampling.is_deterministic)

    def _worst_need(self, req: Request) -> int:
        """Worst-case KV positions this request can ever occupy.

        A deterministic request reserves ``spec_depth x (W-1) + 1`` verify
        rows past its output budget: up to ``spec_depth`` windows of W-1
        candidates can be in flight at once, and the deepest window's
        replay writes one verifier token past its last candidate.  Peak
        usage is the MAX of the prefill and decode phases, not their sum —
        decode/verify writes start at L and overwrite the prefill pad
        tail."""
        cfg = self.cfg
        prefix = cfg.num_prefix_embeds or 0
        L = prefix + req.prompt_len
        if self._use_chunked(req):
            C = self._chunk_size()
            extent = -(-L // C) * C  # the last chunk pads to the chunk shape
        else:
            extent = prefix + _bucket(req.prompt_len)
        spec = (
            self.spec_depth * (self.window - 1) + 1
            if self.mode == Mode.LLM42 and req.sampling.is_deterministic
            else 0
        )
        return max(extent, L + req.sampling.max_new_tokens + spec)

    def _check_capacity(self, req: Request) -> None:
        """Admission capacity guard, derived from block-pool accounting:
        reject a request whose worst-case footprint (prefill extent vs
        prompt + output budget + the ``spec_depth x (W-1) + 1`` verify-row
        reservation) exceeds the per-request block-table reach
        (``capacity``) or the whole pool's block supply — instead of
        silently overflowing.  Transient pressure is NOT rejected here:
        a request that could *ever* fit queues, and the preemption lane
        arbitrates the pool at run time."""
        cfg = self.cfg
        has_full_attn = cfg.attn_kind != "sliding" and any(
            cfg.layer_kind(i) == "attn" for i in range(cfg.num_layers)
        )
        if not has_full_attn:
            return  # sliding ring buffers wrap; recurrent state is O(1)
        need = self._worst_need(req)
        total_blocks = self.pool.alloc_blocks.num_blocks
        need_blocks = self._blocks_for(need)
        if need > self.capacity or need_blocks > total_blocks:
            prefix = cfg.num_prefix_embeds or 0
            raise ValueError(
                f"request {req.rid} cannot fit the KV pool: "
                f"max(prefill extent, prompt {prefix + req.prompt_len} + "
                f"max_new_tokens {req.sampling.max_new_tokens} + verify "
                f"rows [depth {self.spec_depth} x (W-1) + 1]) = {need} "
                f"positions = {need_blocks} blocks > per-request capacity "
                f"{self.capacity} or pool {total_blocks} blocks of "
                f"{self.pool.block_size}"
            )

    def _chunk_size(self) -> int:
        """Effective prefill chunk (ring-buffer contract caps it at the
        sliding window so a pass never overwrites in-window keys)."""
        C = self.prefill_chunk
        if self.cfg.attn_kind == "sliding":
            C = min(C, self.cfg.window)
        return max(1, C)

    def _use_chunked(self, req: Request) -> bool:
        """Chunked lane only when the prompt actually spans > 1 chunk: a
        prompt that fits one chunk runs the legacy exclusive pass — same
        single-iteration stall, but padded to its (smaller) power-of-two
        bucket instead of the full chunk width."""
        if not self.chunked_prefill:
            return False
        prefix = self.cfg.num_prefix_embeds or 0
        return prefix + req.prompt_len > self._chunk_size()

    # ------------------------------------------------------------------
    # block-pool accounting, admission, preemption
    # ------------------------------------------------------------------

    def _blocks_for(self, positions: int) -> int:
        """Blocks covering ``positions`` KV slots."""
        return -(-max(positions, 0) // self.pool.block_size)

    def _alloc_block(self) -> Optional[int]:
        """One free block, reclaiming LRU zero-ref prefix-cache blocks on
        demand.  None = pool genuinely exhausted (preemption's cue)."""
        alloc = self.pool.alloc_blocks
        bid = alloc.alloc()
        while bid is None and self.prefix_cache is not None:
            evicted = self.prefix_cache.evict_lru(alloc)
            if evicted is None:
                break
            self.pool.free_blocks([evicted])
            bid = alloc.alloc()
        return bid

    def _grow_blocks(self, req: Request, target_blocks: int) -> bool:
        """Append private blocks until the table reaches ``target_blocks``;
        False (with partial growth kept) when the pool is dry."""
        while len(req.blocks) < target_blocks:
            bid = self._alloc_block()
            if bid is None:
                return False
            req.blocks.append(bid)
        return True

    def _ensure_blocks(self, req: Request, end_pos: int) -> bool:
        """Guarantee the table covers KV positions [0, end_pos), preempting
        LRU victims (scheduler.BlockMemoryPolicy) when the pool is dry.
        False = unsatisfiable right now (no victim left): the request
        stalls this iteration and retries next."""
        if not self.pool.paged:
            return True
        target = self._blocks_for(min(end_pos, self.capacity))
        while not self._grow_blocks(req, target):
            cands = [
                r for r in self.running
                if r is not req and r.state is not State.PREFILLING
            ]
            victim = self.mem_policy.pick_victim(cands, self._now)
            if victim is None:
                return False
            self.preempt(victim)
        return True

    def _release_blocks(self, req: Request, *, insert: bool) -> None:
        """Drop the request's block references.  ``insert=True`` first
        registers the committed-stream prefix with the radix cache (the
        blocks then stay resident-but-evictable instead of freeing)."""
        alloc = self.pool.alloc_blocks
        if insert and self.prefix_cache is not None:
            stream = self._cacheable_stream(req)
            n = len(stream) // self.pool.block_size
            if n:
                self.prefix_cache.insert(
                    stream, req.blocks[:n], self._now, alloc
                )
        freed = []
        for bid in req.blocks:
            if alloc.decref(bid) == 0 and bid not in alloc.cached:
                freed.append(bid)
        self.pool.free_blocks(freed)
        req.blocks = []
        req.blocks_shared = 0

    def _cacheable_stream(self, req: Request) -> List[int]:
        """The committed token stream whose KV is deterministic AND
        resident: the prompt always (prefill runs the fixed schedule in
        every mode), plus committed output for deterministic traffic
        (verify-grade by the DVR protocol) and BATCH_INVARIANT mode —
        minus the last committed token, whose KV is written by the *next*
        decode and may not exist yet.  Never fast-path non-deterministic
        output."""
        det_out = (
            self.mode == Mode.BATCH_INVARIANT
            or (self.mode == Mode.LLM42 and req.sampling.is_deterministic)
        )
        if det_out and req.committed:
            return list(req.prompt) + list(req.committed[:-1])
        return list(req.prompt)

    def _insert_prompt_blocks(self, req: Request) -> None:
        """Register the freshly prefilled prompt's whole blocks with the
        prefix cache, so concurrent arrivals with the same system prompt
        share them immediately."""
        if self.prefix_cache is None:
            return
        n = req.prompt_len // self.pool.block_size
        if n:
            self.prefix_cache.insert(
                req.prompt, req.blocks[:n], self._now,
                self.pool.alloc_blocks,
            )

    def _admit(self) -> None:
        # restore lane first: preempted requests re-enter with priority
        # (their committed work is sunk cost), gated by the memory
        # policy's anti-thrash hysteresis
        while self.preempted and len(self.running) < self.max_batch:
            req = self.preempted[0]
            avail = (
                self.pool.alloc_blocks.available()
                if self.pool.paged else 10 ** 9
            )
            need = self._blocks_for(self._worst_need(req))
            if not self.mem_policy.may_restore(req, avail, need, self._now):
                break
            self.preempted.pop(0)
            self._restore(req)
        while self.queue and self.pool.num_free() > 0 and (
            len(self.running) < self.max_batch
        ):
            if not self._try_admit(self.queue[0]):
                break  # FIFO admission: the head waits for memory
            self.queue.pop(0)

    def _try_admit(self, req: Request) -> bool:
        """Admit one queued request if the block pool can cover its prompt
        (free-block accounting, not dense per-slot reservation): map the
        longest committed-prefix match to shared cache blocks, allocate
        private blocks for the tail, and start prefill on the tail only."""
        cfg = self.cfg
        prefix = cfg.num_prefix_embeds or 0
        L = prefix + req.prompt_len
        alloc = self.pool.alloc_blocks
        matched: List[int] = []
        if self.prefix_cache is not None:
            matched = self.prefix_cache.match(req.prompt, self._now)
            # the boundary block is never shared: at least the prompt's
            # last position must run (T0's logits), and it writes KV —
            # copy-on-write by recompute
            matched = matched[: (req.prompt_len - 1) // self.pool.block_size]
        if self.pool.paged:
            need = self._blocks_for(L) - len(matched)
            if not self.mem_policy.may_admit(alloc.available(), need):
                return False
        for bid in matched:
            alloc.incref(bid)
        req.blocks = list(matched)
        req.blocks_shared = len(matched)
        req.cached_prefix_tokens = len(matched) * self.pool.block_size
        if self.pool.paged and not self._grow_blocks(req, self._blocks_for(L)):
            # raced the watermark (fragmentation vs evictable estimate):
            # roll back and keep the request queued
            self._release_blocks(req, insert=False)
            return False
        if self.prefix_cache is not None:
            self.prefix_cache.note_lookup(len(matched))
        req.slot = self.pool.alloc()
        cached = req.cached_prefix_tokens
        if cached:
            self.events.append({
                "kind": "cache_hit", "rid": req.rid, "tokens": cached,
                "iter": self._now,
            })
        tr = self.obs.tracer
        if tr.enabled:
            tr.instant("admit", t=self.runtime.now, rid=req.rid,
                       cached_tokens=cached, slot=req.slot)
        if self._use_chunked(req) or cached > 0:
            # third lane: prefill advances chunk-by-chunk via scheduler
            # plans instead of one exclusive pass at admission; a cache
            # hit enters the same lane with the cursor past the match
            self._prepare_prefill(req)
            req.prefill_pos = cached
            req.state = State.PREFILLING
            if not self._use_chunked(req):
                # cache-hit tail under the exclusive-prefill engine: run
                # the tail synchronously (legacy admission semantics)
                self._prefill_tail_sync(req)
        else:
            self._prefill(req)
            req.state = State.RUNNING
        self.running.append(req)
        return True

    def _flush_pipeline(self, req: Request) -> None:
        """Force-apply every in-flight verdict in submission order.  The
        discrete-event engine computes verdicts eagerly at launch — only
        their *visibility* is deferred — so an early flush commits exactly
        the tokens that would have committed anyway (in-order splices,
        cascades included).  Device state work is skipped: the caller is
        about to evict the slot's KV and the restore replay rebuilds
        recurrent state from the committed stream."""
        for outcome in pipeline.apply_ready(req, self.window, float("inf")):
            self._note_splice(req, outcome)
            self.statepool.note_splice(req.slot, len(outcome.cascaded))
        self.statepool.note_preempt(req.slot)

    def preempt(self, req: Request) -> bool:
        """Evict a running request's KV blocks (the memory policy's lane,
        and a test hook for adversarial eviction schedules).  The request
        keeps its slot, its committed stream and its statepool replay
        anchor; fresh speculation is dropped (uncommitted by definition)
        and in-flight verdicts are flushed first — so the committed stream
        is untouched, which is what makes the later restore-by-recompute
        bitwise-identical."""
        if req not in self.running or req.state is State.PREFILLING:
            return False
        self._flush_pipeline(req)
        if req.finished():
            # the flushed verdicts completed the request: retire instead
            self._finish(req)
            return True
        dropped = len(req.candidates)
        req.candidates = []
        req.num_preempted_tokens += dropped
        req.num_preemptions += 1
        req.preempt_iter = self._now
        # committed-prefix blocks go to the radix cache (evictable, so the
        # pool reclaims them LRU — and an early restore may re-match them);
        # the speculative tail frees outright
        self._release_blocks(req, insert=True)
        req.state = State.PREEMPTED
        self.running.remove(req)
        self.preempted.append(req)
        self.num_preemptions += 1
        self.events.append({
            "kind": "preempt", "rid": req.rid, "iter": self._now,
            "dropped_tokens": dropped, "committed": len(req.committed),
        })
        tr = self.obs.tracer
        if tr.enabled:
            tr.instant("preempt", t=self.runtime.now, rid=req.rid,
                       dropped_tokens=dropped, committed=len(req.committed))
        return True

    def _restore(self, req: Request) -> None:
        """Re-admit a preempted request by deterministic recompute: replay
        its committed stream (prompt + committed[:-1] — the last committed
        token's KV is written by the resuming decode, exactly as in the
        un-preempted flow) through the chunked-prefill lane.  The replay
        runs the fixed verify-grade schedule, and every committed
        position's KV was verify-grade before eviction, so the rebuilt
        cache — and, on recurrent archs, the rebuilt state and replay
        anchor — is bitwise-identical by construction.  Blocks still
        resident in the prefix cache are re-matched instead of
        recomputed."""
        stream = list(req.prompt) + list(req.committed[:-1])
        # the replay starts from position 0 on a pristine state row: the
        # slot survived preemption, but its live recurrent state (and any
        # sliding ring content) is post-speculation — NOT the state a
        # fresh prefill would start from
        self.pool.reset_slot(req.slot)
        alloc = self.pool.alloc_blocks
        matched: List[int] = []
        if self.prefix_cache is not None:
            # a replay samples nothing, so a full-stream match needs no
            # recompute at all — the boundary rule only bounds matches to
            # whole blocks, which the radix walk does by construction
            matched = self.prefix_cache.match(stream, self._now)
        for bid in matched:
            alloc.incref(bid)
        req.blocks = list(matched)
        req.blocks_shared = len(matched)
        self._prepare_prefill(req, stream=stream)
        req.prefill_pos = len(matched) * self.pool.block_size
        req.replaying = True
        ok = self._grow_blocks(
            req, self._blocks_for((self.cfg.num_prefix_embeds or 0)
                                  + len(stream))
        )
        assert ok, "restore gate admitted a replay the pool cannot hold"
        self.num_restores += 1
        self.restored_tokens += max(req.prefill_total - req.prefill_pos, 0)
        self.events.append({
            "kind": "restore", "rid": req.rid, "iter": self._now,
            "replay_tokens": max(req.prefill_total - req.prefill_pos, 0),
            "rematched_blocks": len(matched),
        })
        tr = self.obs.tracer
        if tr.enabled:
            tr.instant(
                "restore", t=self.runtime.now, rid=req.rid,
                replay_tokens=max(req.prefill_total - req.prefill_pos, 0),
                rematched_blocks=len(matched),
            )
        self.running.append(req)
        if req.prefill_pos >= req.prefill_total:
            # everything survived in the cache: nothing to recompute
            self._finish_prefill(req, sample=False)
        else:
            # a restore always re-enters via PREFILLING: the replay rides
            # the third lane when chunking is on, else runs synchronously
            req.state = State.PREFILLING
            if not self.chunked_prefill:
                self._prefill_tail_sync(req)

    def _prefill_tail_sync(self, req: Request) -> None:
        """Exclusive (synchronous) prefill of the remaining
        ``[prefill_pos, prefill_total)`` span — the cache-hit tail or a
        restore replay under a non-chunked engine.  One fixed-shape pass
        sized to the tail's power-of-two bucket (capped at the sliding
        window's ring contract), looped to completion; emits one legacy
        ``prefill`` event."""
        start = req.prefill_pos
        replay = req.replaying
        C = _bucket(max(req.prefill_total - start, 1))
        if self.cfg.attn_kind == "sliding":
            C = min(C, self.cfg.window)
        wall = 0.0
        while req.prefill_pos < req.prefill_total:
            wall += self._prefill_advance(req, C)["wall"]
        ev = {
            "kind": "prefill", "tokens": req.prefill_total - start,
            "padded": -(-(req.prefill_total - start) // C) * C,
            "wall": wall, "iter": self._now, "cached": start,
            "replay": replay,
        }
        self._charge_main(ev)
        self.events.append(ev)

    def mem_stats(self) -> Dict[str, Any]:
        """Legacy memory-telemetry view — now a thin compat shim over the
        metrics registry's ``snapshot()`` (the single source of truth).
        New consumers should read ``engine.obs.metrics.snapshot()``
        directly; the namespaced keys carry the same values."""
        snap = self.obs.metrics.snapshot()
        out: Dict[str, Any] = {
            "block_size": snap["blockpool.block_size"],
            "num_blocks": snap["blockpool.num_blocks"],
            "blocks_in_use": snap["blockpool.blocks_in_use"],
            "peak_blocks_in_use": snap["blockpool.peak_blocks_in_use"],
            "free_blocks": snap["blockpool.free_blocks"],
            "num_preemptions": snap["mem.preemptions"],
            "num_restores": snap["mem.restores"],
            "restored_tokens": snap["mem.restored_tokens"],
            "peak_running": snap["engine.peak_running"],
            "paged": bool(snap["blockpool.paged"]),
        }
        if self.prefix_cache is not None:
            out.update({
                "prefix_hits": snap["prefixcache.hits"],
                "prefix_misses": snap["prefixcache.misses"],
                "prefix_hit_tokens": snap["prefixcache.hit_tokens"],
                "prefix_insertions": snap["prefixcache.insertions"],
                "prefix_evictions": snap["prefixcache.evictions"],
                "prefix_size_blocks": snap["prefixcache.size_blocks"],
            })
        return out

    def _build_cross(self, req: Request) -> None:
        assert req.enc_embeds is not None, "encdec request needs enc_embeds"
        cross = self._cross_fn(req.enc_embeds.shape[1])(self.params, req.enc_embeds)
        slot = jnp.array([req.slot])
        cross_axes = {"k": 1, "v": 1, "mask": 0}
        self.pool.data["cross"] = kv_cache.scatter_slots(
            self.pool.data["cross"], cross_axes, slot, cross
        )

    def _prepare_prefill(
        self, req: Request, stream: Optional[List[int]] = None
    ) -> None:
        """Host-side setup for chunk-resumable prefill: side inputs (cross
        cache, prefix embeds) and the chunk cursor.  Chunks embed their own
        token slice on demand (``_chunk_embeds``), so residency stays
        O(chunk), not O(prompt).  ``stream`` overrides the fed tokens — a
        restore replay feeds prompt + committed[:-1] instead of the
        prompt."""
        cfg = self.cfg
        req._prefix_len = cfg.num_prefix_embeds
        if cfg.family == "encdec":
            self._build_cross(req)
        if cfg.num_prefix_embeds:
            prefix = req.prefix_embeds
            if prefix is None:
                prefix = jnp.zeros(
                    (1, cfg.num_prefix_embeds, cfg.d_model), jnp.dtype(cfg.dtype)
                )
            req._prefix_src = prefix
        req.prefill_stream = (
            list(stream) if stream is not None else list(req.prompt)
        )
        req.prefill_total = (cfg.num_prefix_embeds or 0) + len(req.prefill_stream)
        req.prefill_pos = 0

    def _chunk_embeds(self, req: Request, s: int, C: int) -> jax.Array:
        """Input embeddings for prefill positions [s, s+C): prefix embeds
        where the chunk overlaps the prefix region, token embeddings for
        the fed-stream slice.  At most C real positions materialize."""
        prefix = getattr(req, "_prefix_len", 0) or 0
        stream = req.prefill_stream
        parts = []
        if s < prefix:
            parts.append(req._prefix_src[:, s : min(prefix, s + C)])
        lo = max(s - prefix, 0)
        hi = min(s + C - prefix, len(stream))
        if hi > lo:
            toks = jnp.array([stream[lo:hi]], jnp.int32)
            parts.append(jnp.take(self.params["embed"], toks, axis=0))
        return parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=1)

    def _pad_embed(self) -> jax.Array:
        """(1, 1, D) embedding of token 0 — the legacy pad content."""
        if not hasattr(self, "_pad_row"):
            self._pad_row = jnp.take(
                self.params["embed"], jnp.array([[0]], jnp.int32), axis=0
            )
        return self._pad_row

    def _finish_prefill(
        self, req: Request, *, sample: bool, logits=None, last_rel: int = 0
    ) -> None:
        """Prefill-completion bookkeeping, shared by the chunk lane and the
        zero-recompute restore path.  ``sample=True`` commits T0 from the
        final chunk's logits; a restore replay skips it (T0 — and
        everything after — is already committed)."""
        if sample:
            tok = sample_token(
                logits[0, last_rel], jnp.int32(req.sampling.seed),
                jnp.int32(0), jnp.float32(req.sampling.temperature),
                jnp.int32(req.sampling.top_k),
            )
            req.committed.append(int(tok))  # T0: deterministic by construction
            self._note_t0(req, (
                float(top2_margin(logits[0, last_rel]))
                if self.obs.audit.enabled else None
            ))
        # commit point == post-stream state: the verify replay anchor (on a
        # replay, the state after committed[:-1] — exactly what the next
        # anchored window starts from)
        self.statepool.set_commit_point(self.pool.data, req.slot)
        if req.prefill_time < 0:
            req.prefill_time = self._now
        req.state = State.RUNNING
        req._prefix_src = None
        if req.replaying:
            req.replaying = False
            req.restore_iter = self._now
        else:
            self._insert_prompt_blocks(req)

    def _prefill_chunk_prep(self, req: Request, C: int):
        """Device arguments for the request's next C-token prefill chunk.
        Pad positions embed token 0 (exactly the legacy padded passes);
        their writes land past the allocated block table and are absorbed
        by the pool's scratch block.  Returns ``(args, s, real)`` — the
        chunk cursor and real-token count feed ``_prefill_chunk_post``."""
        s = req.prefill_pos
        emb = self._chunk_embeds(req, s, C)
        real = emb.shape[1]
        if real < C:
            pad = jnp.broadcast_to(self._pad_embed(), (1, C - real, emb.shape[2]))
            emb = jnp.concatenate([emb, pad], axis=1)
        table = self.pool.table_array([req.blocks])[0]
        args = (
            jnp.int32(req.slot), table, emb, jnp.int32(s),
            jnp.int32(max(real - 1, 0)),
        )
        return args, s, real

    def _prefill_chunk_post(
        self, req: Request, C: int, s: int, real: int, logits, wall: float
    ) -> Dict[str, Any]:
        """Host bookkeeping after a chunk pass: advance the cursor; the
        final chunk samples T0 (unless this is a restore replay) and flips
        the request to RUNNING."""
        req.last_sched = self._now
        req.prefill_pos = s + real
        total = req.prefill_total
        done = req.prefill_pos >= total
        replay = req.replaying
        if done:
            self._finish_prefill(
                req, sample=not replay, logits=logits,
                last_rel=total - 1 - s,
            )
        return {
            "kind": "prefill_chunk", "tokens": real, "padded": C, "start": s,
            "wall": wall, "iter": self._now, "rid": req.rid, "done": done,
            "replay": replay,
        }

    def _prefill_advance(self, req: Request, C: int) -> Dict[str, Any]:
        """Advance one fixed-shape C-token prefill chunk as a standalone
        launch (the fused step composes the same prep/body/post instead)."""
        args, s, real = self._prefill_chunk_prep(req, C)
        t0 = time.perf_counter()
        self.pool.data, logits = self._prefill_chunk_fn(C)(
            self.params, self.pool.data, *args
        )
        wall = time.perf_counter() - t0
        return self._prefill_chunk_post(req, C, s, real, logits, wall)

    # det: commit-path
    def _prefill(self, req: Request) -> None:
        cfg = self.cfg
        P = _bucket(req.prompt_len)
        if cfg.attn_kind == "sliding" and P > cfg.window:
            # ring-buffer contract: feed the prompt in window-sized chunks
            self._prepare_prefill(req)
            self._prefill_sliding(req)
            return
        req._prefix_len = cfg.num_prefix_embeds
        if cfg.family == "encdec":
            self._build_cross(req)
        tokens = jnp.array(
            [req.prompt + [0] * (P - req.prompt_len)], jnp.int32
        )
        prefix = req.prefix_embeds
        if cfg.num_prefix_embeds and prefix is None:
            prefix = jnp.zeros(
                (1, cfg.num_prefix_embeds, cfg.d_model), jnp.dtype(cfg.dtype)
            )
        table = self.pool.table_array([req.blocks])[0]
        t0 = time.perf_counter()
        self.pool.data, tok, marg = self._prefill_fn(P)(
            self.params, self.pool.data, jnp.int32(req.slot), table, tokens,
            jnp.int32(req.prompt_len), jnp.int32(req.sampling.seed),
            jnp.float32(req.sampling.temperature),
            jnp.int32(req.sampling.top_k), prefix,
        )
        wall = time.perf_counter() - t0
        req.last_sched = self._now
        # commit point == post-prompt state: first verify replay anchor
        self.statepool.set_commit_point(self.pool.data, req.slot)
        req.committed.append(int(tok))  # T0: deterministic by construction
        self._note_t0(req, float(marg) if self.obs.audit.enabled else None)
        req.prefill_time = self._now
        self._insert_prompt_blocks(req)
        ev = {
            "kind": "prefill", "tokens": req.prompt_len + (cfg.num_prefix_embeds or 0),
            "padded": P + (cfg.num_prefix_embeds or 0), "wall": wall, "iter": self._now,
        }
        self._charge_main(ev)
        self.events.append(ev)

    def _prefill_sliding(self, req: Request) -> None:
        """Exclusive chunked prefill for sliding-window archs (<= window per
        pass — the ring-buffer contract).  Runs the same chunk machinery as
        the co-scheduled lane, synchronously, and emits one legacy
        ``prefill`` event.  Per-request fixed chunking => still
        deterministic by construction."""
        W = self.cfg.window
        wall = 0.0
        while req.prefill_pos < req.prefill_total:
            wall += self._prefill_advance(req, W)["wall"]
        ev = {
            "kind": "prefill", "tokens": req.prompt_len,
            "padded": ((req.prompt_len + W - 1) // W) * W, "wall": wall,
            "iter": self._now,
        }
        self._charge_main(ev)
        self.events.append(ev)

    def _view(self, stalled: Optional[Set[int]] = None) -> sched.SchedulerView:
        """Snapshot handed to the schedule policy each iteration.
        ``stalled`` rids (block-pool pressure with no victim left) are
        hidden from the policy — they retry next iteration."""
        stalled = stalled or set()
        visible = tuple(r for r in self.running if r.rid not in stalled)
        return sched.SchedulerView(
            running=visible,
            mode=self.mode,
            window=self.window,
            group=self.group,
            # the double-buffered state pool makes speculation past
            # submitted windows safe on EVERY arch: verification never
            # writes the live recurrent state at launch, and rollbacks
            # restore from the window's ring checkpoint
            speculate_past_inflight=True,
            now=self._now,
            prefilling=tuple(
                r for r in visible if r.state is State.PREFILLING
            ),
            now_time=self.runtime.now,
            verify_inflight=sum(len(r.pipeline) for r in self.running),
            verify_backlog=self.runtime.verify_backlog,
            acceptance={r.rid: r.accept_ema for r in self.running},
            spec_depth=self.spec_depth,
            free_blocks=self.pool.num_free_blocks(),
            num_preempted=len(self.preempted),
        )

    # ------------------------------------------------------------------
    # steps
    # ------------------------------------------------------------------

    def _decode_schedule(self, B: int) -> Schedule:
        if self.mode == Mode.BATCH_INVARIANT:
            return INVARIANT_SCHEDULE
        sched_ = self.policy.schedule_for(B)
        if self.tp > 1:
            # fast path on a width-tp mesh: the TP partial-sum tree follows
            # the mesh (un-pinned) — mesh geometry perturbs decode exactly
            # like batch geometry does, and DVR catches both the same way
            sched_ = sched_._replace(tp_shards=self.tp, tp_pinned=False)
        return sched_

    def _decode_prep(self, batch: List[Request]):
        """Device arguments for one decode pass over ``batch``.  Safe to
        run before OR after this iteration's verify pre-launch: submitting
        a window only moves the candidates' head into the in-flight FIFO's
        tail, so ``committed + speculation`` — everything read here — is
        unchanged by it."""
        slots = jnp.array([r.slot for r in batch], jnp.int32)
        tables = self.pool.table_array([r.blocks for r in batch])
        last_tok, pos, out_pos, seeds, temps, top_ks = [], [], [], [], [], []
        for r in batch:
            # speculation order: committed, in-flight window, fresh candidates
            seq = r.committed + r.speculation
            last_tok.append(seq[-1])
            prefix = getattr(r, "_prefix_len", 0)
            pos.append(r.prompt_len + prefix + len(seq) - 1)
            out_pos.append(len(seq))
            seeds.append(r.sampling.seed)
            temps.append(r.sampling.temperature)
            top_ks.append(r.sampling.top_k)
            r.last_sched = self._now
        args = (
            slots, tables,
            jnp.array(last_tok, jnp.int32), jnp.array(pos, jnp.int32),
            jnp.array(seeds, jnp.int32), jnp.array(temps, jnp.float32),
            jnp.array(out_pos, jnp.int32), jnp.array(top_ks, jnp.int32),
        )
        return args, pos

    def _decode_post(
        self, batch: List[Request], schedule: Schedule, pos: List[int],
        nxt, wall: float, margins=None,
    ) -> Dict[str, Any]:
        """Land one decode pass's tokens: fresh candidates for det
        requests (plus window-state marking), committed tokens otherwise.
        Directly committed tokens get a decode-origin audit record carrying
        the fast-path schedule that produced them (``margins`` is the
        pass's per-row top-1/top-2 margin output; host conversion is gated
        on auditing)."""
        B = len(batch)
        nxt = [int(t) for t in nxt]
        au = self.obs.audit
        for i, (r, t) in enumerate(zip(batch, nxt)):
            if self.mode == Mode.LLM42 and r.sampling.is_deterministic:
                r.candidates.append(t)
                dvr.mark_window_state(r, self.window)
            else:
                r.committed.append(t)
                self._c_committed.inc()
                if r.first_token_clock < 0:
                    r.first_token_clock = self.runtime.now
                if au.enabled:
                    au.record(TokenProvenance(
                        rid=r.rid, index=len(r.committed) - 1, token=t,
                        origin="decode", schedule=schedule,
                        margin=(float(margins[i])
                                if margins is not None else None),
                    ))
        return {
            "kind": "decode", "batch": B, "schedule": tuple(schedule),
            "ctx_sum": sum(pos) + B, "wall": wall, "iter": self._now,
            "rids": [r.rid for r in batch],
        }

    def _decode_step(self, batch: List[Request]) -> Dict[str, Any]:
        B = len(batch)
        schedule = self._decode_schedule(B)
        args, pos = self._decode_prep(batch)
        t0 = time.perf_counter()
        self.pool.data, nxt, margins = self._decode_fn(B, schedule)(
            self.params, self.pool.data, *args
        )
        wall = time.perf_counter() - t0
        return self._decode_post(batch, schedule, pos, nxt, wall, margins)

    def _pad_verify_row(self, inputs, cands, cand_lens, starts, bases, slots,
                        seeds, temps, tks, ring_idxs, table_rows) -> None:
        """One padding row for a short verify group: writes go to the
        pool's scratch slot; the empty block table sends paged reads to
        the frozen null block and paged writes to the scratch block."""
        W = self.window
        inputs.append([0] * W)
        cands.append([-1] * (W - 1))
        cand_lens.append(0)
        starts.append(0)
        bases.append(0)
        slots.append(self.pool.scratch_slot)
        seeds.append(0)
        temps.append(0.0)
        tks.append(0)
        ring_idxs.append(0)
        table_rows.append([])

    def _verify_prelaunch(self, rows: List[Request]):
        """Host protocol work for one deferred verify group, BEFORE the
        device pass: build each row's replay inputs, then move its window
        into the request's in-flight FIFO as a placeholder record
        (``n_match = -1`` keeps ``apply_ready`` from splicing it before the
        verdict payload lands in ``_verify_postlaunch``).  Submitting at
        prep time is what lets several chained groups of one iteration
        stack: group k+1's rows condition on the windows group k just
        pushed.  It is also safe ahead of the same iteration's decode
        bookkeeping — the submit only moves the candidates' head into the
        FIFO tail, leaving ``committed + speculation`` unchanged, and the
        fresh decode token lands behind the window just built."""
        G, W = self.group, self.window
        assert len({id(r) for r in rows}) == len(rows), (
            "a request may contribute one window per grouped pass — chained "
            "windows replay sequentially, never inside one batch"
        )
        n_pad = G - len(rows)
        inputs, cands, cand_lens, starts, bases, slots, seeds, temps, tks = (
            [], [], [], [], [], [], [], [], []
        )
        ring_idxs: List[int] = []
        fls: List[pipeline.InflightVerify] = []
        table_rows: List[List[int]] = []
        for r in rows:
            assert len(r.pipeline) < self.spec_depth, (
                "scheduler plan exceeds the configured spec_depth"
            )
            ring_idx = r.window_seq % self.spec_depth
            i, c, cl, sp, ob = dvr.build_verify_row(r, W)
            inputs.append(i)
            cands.append(c)
            cand_lens.append(cl)
            starts.append(sp)
            bases.append(ob)
            slots.append(r.slot)
            seeds.append(r.sampling.seed)
            temps.append(r.sampling.temperature)
            tks.append(r.sampling.top_k)
            table_rows.append(r.blocks)
            r.last_sched = self._now
            ring_idxs.append(ring_idx)
            fls.append(pipeline.submit_window(
                r, W, 0.0, float("inf"), ring_idx=ring_idx
            ))
        for _ in range(n_pad):
            self._pad_verify_row(inputs, cands, cand_lens, starts, bases,
                                 slots, seeds, temps, tks, ring_idxs,
                                 table_rows)
        args = (
            jnp.array(slots, jnp.int32),
            self.pool.table_array(table_rows),
            jnp.array(starts, jnp.int32),
            jnp.array(inputs, jnp.int32), jnp.array(cands, jnp.int32),
            jnp.array(cand_lens, jnp.int32), jnp.array(seeds, jnp.int32),
            jnp.array(temps, jnp.float32), jnp.array(bases, jnp.int32),
            jnp.array(tks, jnp.int32),
        )
        return args, fls, ring_idxs, slots, starts, n_pad

    def _verify_event(
        self, rows: List[Request], starts: List[int], n_pad: int,
        wall: float, n_decodable: int, deferred: bool,
    ) -> Dict[str, Any]:
        G, W = self.group, self.window
        return {
            "kind": "verify", "group": len(rows), "window": W,
            "pad_rows": n_pad,
            "ctx_sum": sum(starts) + W * G, "wall": wall, "iter": self._now,
            # requests that could decode this iteration — under the pause
            # policy these are the requests the verify pass stalls; under
            # overlap they ride in the composite event's decode batch
            "rids": [r.rid for r in rows], "n_decodable": n_decodable,
            # stream assignment for per-stream time accounting: a deferred
            # pass rides the verify stream; a sync pass blocks the main one
            "deferred": deferred,
        }

    def _verify_postlaunch(
        self, rows: List[Request], fls, ev: Dict[str, Any], ring_idxs,
        slots, starts, n_match, commit_tok, commit_rows, margins=None,
    ) -> None:
        """Land the host side of one deferred verify pass: stream-clock
        launch, state-pool checkpoints, verdict payloads into the
        placeholder FIFO records — and the post-submit state rule,
        re-applied here because it must be evaluated AFTER this iteration's
        decode bookkeeping (the fused step submits windows before the
        decode's candidate lands; the rule's ``done_decoding`` answer is
        only final once it has)."""
        W = self.window
        ready_at = self.runtime.launch_verify(ev, sync=False)
        submitted_at = self.runtime.now
        tr = self.obs.tracer
        if tr.enabled:
            tr.pass_span("verify", "verify", self.runtime.last_verify_span,
                         self._trace_args(ev))
        self._c_windows.inc(len(rows))
        audit = self.obs.audit.enabled
        if commit_rows is not None:
            self.statepool.checkpoint(ring_idxs, slots, commit_rows)
        n_match = [int(n) for n in n_match]
        commit_tok = [int(t) for t in commit_tok]
        for i, r in enumerate(rows):
            fl = fls[i]
            fl.submitted_at, fl.ready_at = submitted_at, ready_at
            fl.n_match, fl.commit_tok = n_match[i], commit_tok[i]
            if audit and margins is not None:
                # window-position margins, parallel to cands + commit token
                # (front normalization pops both in lockstep)
                fl.margins = [float(x) for x in margins[i]]
            if tr.enabled:
                tr.instant("verify_submit", t=submitted_at, rid=r.rid,
                           window=fl.seq, cands=len(fl.cands),
                           ready_at=ready_at)
            self.statepool.note_submit(r.slot, starts[i] + W)
            if r.state is not State.FINISHED:
                r.state = (
                    State.AWAITING_VERIFY if r.done_decoding()
                    else State.RUNNING
                )

    def _pack_verify_groups(
        self, entries: List[Request]
    ) -> List[List[Request]]:
        """Split the plan's verify entries into grouped passes.  The k-th
        occurrence of a request is its k-th chained window this iteration
        and must replay after its predecessors, so occurrences layer:
        layer k's groups follow every layer < k, and each group holds up
        to ``group`` DISTINCT requests."""
        layers: List[List[Request]] = []
        seen: Dict[int, int] = {}
        for r in entries:
            k = seen.get(id(r), 0)
            seen[id(r)] = k + 1
            if k == len(layers):
                layers.append([])
            layers[k].append(r)
        groups: List[List[Request]] = []
        for layer in layers:
            for i in range(0, len(layer), self.group):
                groups.append(layer[i:i + self.group])
        return groups

    def _verify_step(
        self, group: List[Request], *, defer: bool = False,
        n_decodable: int = 0,
    ) -> Dict[str, Any]:
        """Run one grouped verification pass as a standalone launch.

        ``defer=False`` (pause policy / an AdaptivePolicy sync plan): the
        verdict is applied synchronously, exactly the seed behaviour; the
        pass blocks the main stream.  ``defer=True`` (overlap policy): the
        submitted candidates move into each request's in-flight FIFO
        (``core.pipeline``, up to ``spec_depth`` windows deep) and the
        pass is launched on the verify *stream* — its verdict becomes
        visible when the stream completes the pass plus the modeled extra
        latency (``verify_latency_ms``; one tick under the logical
        clock), and splices strictly in submission order.  The
        device pass still executes eagerly (host-sequential simulation of
        an async verify stream), so its KV repair is in place before any
        later cache read — in particular before the next chained window of
        the same request replays — but the *protocol* result arrives at
        the stream-clock deadline.  On recurrent archs the pass routes its
        state selections through the double-buffered state pool instead of
        touching the live state (``core.verifier`` docstring).
        """
        G, W = self.group, self.window
        rows = group[:G]
        if defer:
            args, fls, ring_idxs, slots, starts, n_pad = (
                self._verify_prelaunch(rows)
            )
            t0 = time.perf_counter()
            if self.has_recurrent_state:
                (self.pool.data, self.statepool.anchor, commit_rows, n_match,
                 commit_tok, _v, margins) = self._verify_fn(
                    self.params, self.pool.data, self.statepool.anchor, *args
                )
            else:
                commit_rows = None
                self.pool.data, n_match, commit_tok, _v, margins = (
                    self._verify_fn(self.params, self.pool.data, *args)
                )
            wall = time.perf_counter() - t0
            ev = self._verify_event(rows, starts, n_pad, wall, n_decodable,
                                    True)
            self._verify_postlaunch(rows, fls, ev, ring_idxs, slots, starts,
                                    n_match, commit_tok, commit_rows, margins)
            return ev
        # ---- sync path: FIFOs are empty, the verdict applies on the spot
        assert len({id(r) for r in rows}) == len(rows), (
            "a request may contribute one window per grouped pass — chained "
            "windows replay sequentially, never inside one batch"
        )
        n_pad = G - len(rows)
        inputs, cands, cand_lens, starts, bases, slots, seeds, temps, tks = (
            [], [], [], [], [], [], [], [], []
        )
        ring_idxs: List[int] = []
        table_rows: List[List[int]] = []
        for r in rows:
            i, c, cl, sp, ob = dvr.build_verify_row(r, W)
            inputs.append(i)
            cands.append(c)
            cand_lens.append(cl)
            starts.append(sp)
            bases.append(ob)
            slots.append(r.slot)
            seeds.append(r.sampling.seed)
            temps.append(r.sampling.temperature)
            tks.append(r.sampling.top_k)
            table_rows.append(r.blocks)
            r.last_sched = self._now
            ring_idxs.append(0)  # sync: FIFO empty, ring 0 is free
        for _ in range(n_pad):
            self._pad_verify_row(inputs, cands, cand_lens, starts, bases,
                                 slots, seeds, temps, tks, ring_idxs,
                                 table_rows)
        t0 = time.perf_counter()
        args = (
            jnp.array(slots, jnp.int32),
            self.pool.table_array(table_rows),
            jnp.array(starts, jnp.int32),
            jnp.array(inputs, jnp.int32), jnp.array(cands, jnp.int32),
            jnp.array(cand_lens, jnp.int32), jnp.array(seeds, jnp.int32),
            jnp.array(temps, jnp.float32), jnp.array(bases, jnp.int32),
            jnp.array(tks, jnp.int32),
        )
        if self.has_recurrent_state:
            (self.pool.data, self.statepool.anchor, commit_rows, n_match,
             commit_tok, _v, margins) = self._verify_fn(
                self.params, self.pool.data, self.statepool.anchor, *args
            )
            self.statepool.checkpoint(ring_idxs, slots, commit_rows)
        else:
            self.pool.data, n_match, commit_tok, _v, margins = (
                self._verify_fn(self.params, self.pool.data, *args)
            )
        wall = time.perf_counter() - t0
        n_match = [int(n) for n in n_match]
        commit_tok = [int(t) for t in commit_tok]
        ev = self._verify_event(rows, starts, n_pad, wall, n_decodable,
                                False)
        self.runtime.launch_verify(ev, sync=True)
        tr, au = self.obs.tracer, self.obs.audit
        if tr.enabled:
            tr.pass_span("verify", "verify", self.runtime.last_verify_span,
                         self._trace_args(ev))
        for i, (r, n, t) in enumerate(zip(rows, n_match, commit_tok)):
            cand_len = len(r.candidates)
            base = len(r.committed)
            nc, nrej = dvr.apply_verify_result(r, n, t, window=W)
            self._c_passes.inc()
            self._c_committed.inc(nc)
            if nrej:
                self._c_rollbacks.inc()
                self._c_recomputed.inc(nrej)
                self._h_rollback_depth.observe(nrej)
            if tr.enabled:
                tr.instant("rollback" if nrej else "commit",
                           t=self.runtime.now, rid=r.rid,
                           window=r.num_verify_passes - 1, n_match=n,
                           committed=nc, rejected=nrej, cascaded=0)
            if r.first_token_clock < 0 and r.committed:
                r.first_token_clock = self.runtime.now
            if au.enabled:
                # sync windows never enter the in-flight FIFO, so the
                # audit window id is the request's verify-pass ordinal
                # (``window_seq`` stays untouched on this path)
                for j in range(nc):
                    idx = base + j
                    au.record(TokenProvenance(
                        rid=r.rid, index=idx, token=r.committed[idx],
                        origin="verify", schedule=VERIFY_SCHEDULE,
                        window=r.num_verify_passes - 1, occurrence=0,
                        n_match=n, accepted=j < min(n, cand_len),
                        rollback=nrej > 0,
                        margin=float(margins[i][j]),
                    ))
            if self.statepool.active:
                # live state + replay anchor <- the commit-index state
                # the pass just checkpointed (ring 0)
                self.pool.data = self.statepool.restore(
                    self.pool.data, r.slot, 0
                )
        return ev

    def _fused_step(self, plan: sched.Plan, view: sched.SchedulerView):
        """Run the iteration's entire device side — the current prefill
        chunk, the decode batch and EVERY due verify group — as ONE fused
        mixed-batch launch (``_fused_fn``) threading a single pool.  The
        paged in-place forward is what makes this possible: no sub-pass
        needs a privately gathered copy of the pool, so they chain on the
        shared leaves with no host round-trip between them.

        Host order: all preps first (prefill args, decode args, verify
        pre-launches in layer order), one device call, then prefill post,
        decode post (fresh candidates + window marking) and verify
        post-launches in layer order — each post-launch re-applies the
        post-submit state rule, so the request ends the iteration exactly
        where the legacy decode-then-submit order would put it.  Wall time
        splits equally across sub-passes; the lead sub-event (prefill,
        else decode, else the first verify group) carries the iteration's
        single weight stream + launch overhead in the cost model and every
        follower is marked ``fused``.  Returns ``(pev, dev, vev, vextra)``
        — the composite-event parts (``vextra`` = verify groups past the
        first)."""
        C = self._chunk_size()
        preq = plan.prefill
        pargs = ps = preal = None
        if preq is not None:
            pargs, ps, preal = self._prefill_chunk_prep(preq, C)
        batch = [r for r in plan.decode if not r.done_decoding()]
        B = len(batch)
        schedule = self._decode_schedule(B)
        dargs = dpos = None
        if batch:
            dargs, dpos = self._decode_prep(batch)
        groups = (
            self._pack_verify_groups(list(plan.verify)) if plan.verify else []
        )
        vargs_list, vstates = [], []
        for rows in groups:
            args, fls, ring_idxs, slots, starts, n_pad = (
                self._verify_prelaunch(rows)
            )
            vargs_list.append(args)
            vstates.append((rows, fls, ring_idxs, slots, starts, n_pad))
        n_subs = (preq is not None) + (1 if batch else 0) + len(groups)
        if n_subs == 0:
            return None, None, None, []
        n_decodable = len(sched.decodable(view))
        rec = self.has_recurrent_state
        if n_subs == 1:
            # single lane: dispatch to the standalone per-lane jit (same
            # bodies, no extra compile variants for degenerate shapes)
            if preq is not None:
                t0 = time.perf_counter()
                self.pool.data, logits = self._prefill_chunk_fn(C)(
                    self.params, self.pool.data, *pargs
                )
                pev = self._prefill_chunk_post(
                    preq, C, ps, preal, logits, time.perf_counter() - t0
                )
                self._charge_main(pev)
                return pev, None, None, []
            if batch:
                t0 = time.perf_counter()
                self.pool.data, nxt, dmarg = self._decode_fn(B, schedule)(
                    self.params, self.pool.data, *dargs
                )
                dev = self._decode_post(
                    batch, schedule, dpos, nxt, time.perf_counter() - t0,
                    dmarg,
                )
                self._charge_main(dev)
                return None, dev, None, []
            rows, fls, ring_idxs, slots, starts, n_pad = vstates[0]
            t0 = time.perf_counter()
            if rec:
                (self.pool.data, self.statepool.anchor, commit_rows, n_match,
                 commit_tok, _v, vmarg) = self._verify_fn(
                    self.params, self.pool.data, self.statepool.anchor,
                    *vargs_list[0]
                )
            else:
                commit_rows = None
                self.pool.data, n_match, commit_tok, _v, vmarg = (
                    self._verify_fn(self.params, self.pool.data,
                                    *vargs_list[0])
                )
            vev = self._verify_event(
                rows, starts, n_pad, time.perf_counter() - t0, n_decodable,
                True,
            )
            self._verify_postlaunch(rows, fls, vev, ring_idxs, slots, starts,
                                    n_match, commit_tok, commit_rows, vmarg)
            return None, None, vev, []

        t0 = time.perf_counter()
        anchor = self.statepool.anchor if rec else None
        pool, anchor, logits_p, nxt, dmarg, vouts = self._fused_fn(
            C if preq is not None else None, B, schedule, len(groups)
        )(
            self.params, self.pool.data, anchor,
            pargs if pargs is not None else (),
            dargs if dargs is not None else (),
            tuple(vargs_list),
        )
        self.pool.data = pool
        if rec:
            self.statepool.anchor = anchor
        wall = time.perf_counter() - t0
        share = wall / n_subs
        self._c_fused.inc()
        tr = self.obs.tracer
        if tr.enabled:
            # one launch with nested sub-pass slices: the sub-passes
            # recorded below nest under a fused_step parent span
            tr.begin_group("fused_step", iter=self._now, subs=n_subs)

        pev = dev = vev = None
        vextra: List[Dict[str, Any]] = []
        lead = True
        if preq is not None:
            pev = self._prefill_chunk_post(preq, C, ps, preal, logits_p,
                                           share)
            lead = False
            self._charge_main(pev)
        if batch:
            dev = self._decode_post(batch, schedule, dpos, nxt, share, dmarg)
            if not lead:
                dev["fused"] = True
            lead = False
            self._charge_main(dev)
        for gi, (rows, fls, ring_idxs, slots, starts, n_pad) in enumerate(
            vstates
        ):
            commit_rows, n_match, commit_tok, vmarg = vouts[gi]
            ev = self._verify_event(rows, starts, n_pad, share, n_decodable,
                                    True)
            if not lead:
                ev["fused"] = True
            lead = False
            self._verify_postlaunch(rows, fls, ev, ring_idxs, slots, starts,
                                    n_match, commit_tok, commit_rows, vmarg)
            if vev is None:
                vev = ev
            else:
                vextra.append(ev)
        if tr.enabled:
            tr.end_group()
        return pev, dev, vev, vextra

    def _finish(self, req: Request) -> None:
        """Retire one request: committed-stream blocks go to the prefix
        cache (commit-aware insertion — ``_cacheable_stream``), the rest
        free, and the slot's dense rows are wiped for the next owner."""
        req.state = State.FINISHED
        req.finish_time = self._now
        self.running.remove(req)
        self._release_blocks(req, insert=True)
        self.pool.free(req.slot)
        self.statepool.note_release(req.slot)
        req.slot = -1
        self.finished.append(req)
        self._c_finished.inc()
        now = self.runtime.now
        if req.submit_clock >= 0:
            self._h_e2e.observe(now - req.submit_clock)
            if req.first_token_clock >= 0:
                self._h_ttft.observe(req.first_token_clock - req.submit_clock)
        if req.first_token_clock >= 0 and req.num_output > 1:
            self._h_tpot.observe(
                (now - req.first_token_clock) / (req.num_output - 1)
            )
        if self.mode == Mode.LLM42 and req.sampling.is_deterministic:
            self._h_acceptance.observe(req.accept_ema)
        tr = self.obs.tracer
        if tr.enabled:
            tr.instant("retire", t=now, rid=req.rid,
                       committed=req.num_output,
                       rollbacks=req.num_rollbacks,
                       verify_passes=req.num_verify_passes)
            tr.request_end(req.rid, now)

    def _retire(self) -> None:
        done = [r for r in self.running if r.finished() or (
            not r.sampling.is_deterministic and r.done_decoding()
        ) or (self.mode != Mode.LLM42 and r.done_decoding())]
        for r in done:
            # a det request must have no outstanding speculation at retirement
            if self.mode == Mode.LLM42 and r.sampling.is_deterministic and (
                r.candidates or r.pipeline
            ):
                continue
            self._finish(r)

    # ------------------------------------------------------------------
    # main loop
    # ------------------------------------------------------------------

    def step(self) -> bool:
        """One scheduler iteration.  Returns False when fully drained.

        Order within an iteration: advance the stream clock, land due
        verdicts, retire, admit, plan, PREFILL chunk, DECODE, then VERIFY
        launch.  Verdicts land *before* retirement so a request whose final
        in-flight verdict is due this iteration retires this iteration —
        not one late (``finish_time`` off-by-one, drain one step longer).
        Decode-before-verify is a correctness requirement, not taste: the
        decode of a row being submitted this iteration re-feeds its last
        candidate, writing fast-path KV at the window's final position — a
        position the verify replay is about to repair and that no later
        replay will ever cover again.  Launching the verify afterwards lets
        its repair win; every later speculative write lands at positions >=
        the next window start, which the next replay rewrites.  The prefill
        chunk touches only its own (PREFILLING) slot, so it is
        order-independent.

        Under the paged in-place forward (``paged_attention=True`` on a
        paged pool) a deferring iteration runs its whole device side as
        ONE fused launch (``_fused_step``): same sub-pass bodies, same
        host bookkeeping order for every observable effect, one weight
        stream.  Archs without paged KV — and sync-verify plans — keep
        the legacy one-launch-per-role lanes below.

        Time accounting rides the dual-stream runtime: prefill and decode
        passes charge the main stream (serial — two launches on one
        stream), a deferred verify launches on the verify stream
        (``streams.DualClockRuntime``), and a sync verify (pause policy, or
        an ``AdaptivePolicy`` demotion) blocks the main stream.  An
        iteration that ran >= 2 passes still emits a single composite
        ``overlap`` event for log replay (``costmodel``)."""
        self._now += 1
        self.runtime.begin_iteration()
        self._c_iters.inc()
        tr = self.obs.tracer
        if tr.enabled:
            # iteration window start: under the logical clock
            # begin_iteration just advanced main by 1.0, so the window the
            # tick represents is [now - 1, now]; costed passes extend the
            # frontier from now onward
            tr.begin_iteration(
                self._now,
                self.runtime.now - (1.0 if self.runtime.logical else 0.0),
            )
        applied = self._apply_due_verdicts()
        self._retire()
        self._admit()
        if not self.running and not self.queue and not self.preempted:
            if tr.enabled:
                tr.end_iteration(self.runtime.now)
            return False
        self.peak_running = max(self.peak_running, len(self.running))

        stalled = self._ensure_memory()
        view = self._view(stalled)
        plan = self.scheduler.plan(view)
        defer = self.scheduler.defers_verify and not plan.sync_verify
        pev = dev = vev = None
        vextra: List[Dict[str, Any]] = []
        if self._paged_fwd and defer:
            # fused mixed-batch step: prefill chunk + decode + every due
            # verify group under ONE launch (the tentpole path)
            pev, dev, vev, vextra = self._fused_step(plan, view)
        else:
            # legacy lanes: one launch per role, gathered KV views.  A
            # plan with chained-window repeats (multi-group expansion)
            # collapses to first occurrences — the legacy verify pass
            # launches one window per request per iteration.
            if plan.prefill is not None:
                pev = self._prefill_advance(plan.prefill, self._chunk_size())
                self._charge_main(pev)
            if plan.decode:
                batch = [r for r in plan.decode if not r.done_decoding()]
                if batch:
                    dev = self._decode_step(batch)
                    self._charge_main(dev)
            if plan.verify:
                rows, seen = [], set()
                for r in plan.verify:
                    if id(r) not in seen:
                        seen.add(id(r))
                        rows.append(r)
                vev = self._verify_step(
                    rows, defer=defer,
                    n_decodable=len(sched.decodable(view)),
                )
        self.runtime.end_iteration()
        if tr.enabled:
            tr.end_iteration(self.runtime.now)

        subs = [("decode", dev), ("verify", vev), ("prefill", pev)]
        present = [(k, ev) for k, ev in subs if ev is not None]
        if present and (len(present) + len(vextra)) >= 2:
            comp = {
                "kind": "overlap", **dict(present),
                "wall": sum(ev["wall"] for _, ev in present)
                + sum(ev["wall"] for ev in vextra),
                "iter": self._now,
            }
            if vextra:
                # verify groups past the first (chained windows landing
                # the iteration they became due) ride along explicitly
                comp["verifies"] = vextra
            self.events.append(comp)
        elif present:
            self.events.append(present[0][1])
        if present or applied:
            return True
        return bool(self.running or self.queue or self.preempted)

    def _ensure_memory(self) -> Set[int]:
        """Pre-plan memory phase: grow every running request's block table
        to cover this iteration's worst-case writes (one decode token past
        the live sequence; a prefill chunk for the third lane), preempting
        LRU victims on exhaustion.  Returns the rids that could not be
        covered — they are hidden from the scheduler this iteration.
        Verify writes never exceed the decode bound for *real* content:
        window pad positions land past the table and are absorbed by the
        scratch block."""
        if not self.pool.paged:
            return set()
        stalled: Set[int] = set()
        for r in list(self.running):
            if r not in self.running:
                continue  # preempted by an earlier request's growth
            prefix = getattr(r, "_prefix_len", 0) or 0
            if r.state is State.PREFILLING:
                chunk = (
                    self._chunk_size() if self.chunked_prefill
                    else r.prefill_total - r.prefill_pos
                )
                end = min(r.prefill_pos + max(chunk, 1), r.prefill_total)
            else:
                seq = len(r.committed) + len(r.speculation)
                end = r.prompt_len + prefix + seq
            if not self._ensure_blocks(r, end):
                stalled.add(r.rid)
        return stalled

    def _apply_due_verdicts(self) -> bool:
        """Land in-flight verify results whose stream-clock deadline has
        been reached (``ready_at <= main-stream now``).  Groups launched at
        different times may land in the same iteration — and, with a
        per-launch latency schedule, in inverted launch order; splicing is
        per-request and strictly in submission order (``core.pipeline``
        applies only the FIFO front, however early later verdicts arrived),
        so landing order never moves a committed token.  A rollback splice
        — or one that leaves no surviving speculation — restores the slot's
        live recurrent state (and replay anchor) from the window's
        state-pool checkpoint."""
        applied = False
        now = self.runtime.now
        for r in self.running:
            for outcome in pipeline.apply_ready(r, self.window, now):
                applied = True
                self._note_splice(r, outcome)
                self.statepool.note_splice(r.slot, len(outcome.cascaded))
                if not self.statepool.active or (
                    r.finished() and not (r.pipeline or r.candidates)
                ):
                    # skip device work only when the request is about to
                    # retire with nothing left to verify — an EOS-finished
                    # request with a surviving tail still verifies it, and
                    # that replay needs the anchor advanced
                    continue
                if outcome.restore_state:
                    self.pool.data = self.statepool.restore(
                        self.pool.data, r.slot, outcome.record.ring_idx
                    )
                elif outcome.reanchor:
                    # FIFO drained but live state + speculation tail
                    # survive: only the replay anchor moves (the next
                    # window launches anchored, one token past the chained
                    # start state the last launch recorded)
                    self.statepool.reanchor(r.slot, outcome.record.ring_idx)
        return applied

    def run(self, max_iters: int = 100000) -> List[Request]:
        for _ in range(max_iters):
            if not self.step():
                break
        assert not (self.running or self.queue or self.preempted), (
            "engine did not drain"
        )
        return self.finished
