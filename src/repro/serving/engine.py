"""The LLM-42 serving engine: continuous batching + selective determinism.

Three modes (paper §5 baselines):

  * ``Mode.NONDET``           — SGLang-Non-Deterministic: fast path only;
                                schedules vary with dynamic batch size.
  * ``Mode.BATCH_INVARIANT``  — SGLang-Deterministic: one universal schedule
                                for every op, all traffic pays for it.
  * ``Mode.LLM42``            — the paper: fast path for everyone +
                                decode-verify-rollback for requests with
                                ``is_deterministic=True``.

Per-iteration verify/decode arbitration is delegated to the scheduler
subsystem (``serving.scheduler``): ``PauseDecodePolicy`` reproduces the
paper prototype's behaviour (verification pauses decoding, §5.2 limitation
(1)); ``OverlapPolicy`` — the default for ``Mode.LLM42`` — co-schedules the
verify group alongside the same iteration's decode batch, with per-request
in-flight-verify state (``core.dvr``) so a request keeps speculating past a
window already submitted.  Prefill stays per-request (deterministic by
construction, never co-batched); decode batches are formed from all
decodable requests each iteration (continuous batching).

Every device step goes through a jitted function cached per *shape class*
(batch size, prompt bucket, window) — recompilation per shape is exactly
the shape→schedule coupling (O2) the paper builds on.

An event log records (kind, shape metadata, wall time) per step; the
benchmark harness replays it through the TPU cost model
(``serving.costmodel``) to derive paper-comparable throughput numbers.
"""

from __future__ import annotations

import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.core import dvr
from repro.core.determinism import (
    FAST_PATH_POLICY,
    INVARIANT_SCHEDULE,
    Mode,
    ReductionPolicy,
    Schedule,
    VERIFY_SCHEDULE,
)
from repro.core.verifier import make_verify_fn
from repro.models.base import ModelConfig
from repro.models.transformer import build_cross_cache, forward
from repro.serving import kv_cache
from repro.serving import scheduler as sched
from repro.serving.request import Request, State
from repro.serving.sampler import sample_batch, sample_token


def _bucket(n: int) -> int:
    """Next power-of-two bucket (>= 8) for prompt padding."""
    b = 8
    while b < n:
        b *= 2
    return b


class Engine:
    def __init__(
        self,
        cfg: ModelConfig,
        params: Dict,
        *,
        mode: Mode = Mode.LLM42,
        policy: ReductionPolicy = FAST_PATH_POLICY,
        window: int = 8,  # verification window W (verifies W-1 candidates)
        group: int = 4,  # requests verified together (grouped verification)
        max_batch: int = 8,
        capacity: Optional[int] = None,
        scheduler: Optional[sched.SchedulePolicy] = None,
        verify_latency: int = 1,  # iterations until an overlapped verdict lands
    ):
        self.cfg = cfg
        self.params = params
        self.mode = mode
        self.policy = policy
        self.window = window
        self.group = group
        self.max_batch = max_batch
        self.capacity = capacity or cfg.max_seq_len
        self.pool = kv_cache.CachePool(cfg, max_batch, self.capacity)
        self.axes = self.pool.axes
        # recurrent/hybrid archs need a commit-point state checkpoint: the
        # fast path advances SSM states irreversibly, so the verifier replays
        # from this shadow pool (core/verifier.py docstring; DESIGN.md §4)
        self.needs_ckpt = cfg.family in ("ssm", "hybrid")
        self.ckpt = (
            jax.tree_util.tree_map(jnp.copy, self.pool.data)
            if self.needs_ckpt else None
        )

        self.scheduler = scheduler if scheduler is not None else sched.default_policy(mode)
        assert verify_latency >= 1, "a verdict cannot land before its launch"
        self.verify_latency = verify_latency

        self.queue: List[Request] = []
        self.running: List[Request] = []
        self.finished: List[Request] = []
        self.events: List[Dict[str, Any]] = []
        self._fns: Dict[Any, Callable] = {}
        self._verify_fn = make_verify_fn(cfg, group, window)
        self._now = 0  # logical iteration counter

    # ------------------------------------------------------------------
    # jitted step builders (cached per shape class)
    # ------------------------------------------------------------------

    def _decode_fn(self, B: int, schedule: Schedule) -> Callable:
        key = ("decode", B, schedule)
        if key not in self._fns:
            cfg, axes = self.cfg, self.axes

            @jax.jit
            def step(params, pool, slots, tokens, pos, seeds, temps, out_pos,
                     top_ks):
                cache = kv_cache.gather(pool, axes, slots)
                logits, new_cache, _ = forward(
                    params, cfg, tokens[:, None],
                    cache=cache, start_pos=pos, schedule=schedule,
                )
                nxt = sample_batch(logits[:, 0], seeds, out_pos, temps, top_ks)
                pool2 = kv_cache.scatter(pool, axes, slots, new_cache)
                return pool2, nxt

            self._fns[key] = step
        return self._fns[key]

    def _prefill_fn(self, P: int) -> Callable:
        key = ("prefill", P)
        if key not in self._fns:
            cfg, axes = self.cfg, self.axes
            n_prefix = cfg.num_prefix_embeds
            schedule = (
                INVARIANT_SCHEDULE if self.mode == Mode.BATCH_INVARIANT
                else VERIFY_SCHEDULE
            )

            @jax.jit
            def step(params, pool, slot, tokens, plen, seed, temp, top_k,
                     prefix_embeds):
                slots = slot[None]
                cache = kv_cache.gather(pool, axes, slots)
                if n_prefix:
                    tok_embeds = jnp.take(params["embed"], tokens, axis=0)
                    embeds = jnp.concatenate([prefix_embeds, tok_embeds], axis=1)
                    logits, new_cache, _ = forward(
                        params, cfg, inputs_embeds=embeds,
                        cache=cache, start_pos=jnp.zeros(1, jnp.int32),
                        schedule=schedule,
                    )
                    last = plen + n_prefix - 1
                else:
                    logits, new_cache, _ = forward(
                        params, cfg, tokens,
                        cache=cache, start_pos=jnp.zeros(1, jnp.int32),
                        schedule=schedule,
                    )
                    last = plen - 1
                tok = sample_token(logits[0, last], seed, jnp.int32(0), temp,
                                   top_k)
                pool2 = kv_cache.scatter(pool, axes, slots, new_cache)
                return pool2, tok

            self._fns[key] = step
        return self._fns[key]

    def _cross_fn(self, Se: int) -> Callable:
        key = ("cross", Se)
        if key not in self._fns:
            cfg = self.cfg

            @jax.jit
            def build(params, enc_embeds):
                return build_cross_cache(params, cfg, enc_embeds)

            self._fns[key] = build
        return self._fns[key]

    # ------------------------------------------------------------------
    # scheduling
    # ------------------------------------------------------------------

    def submit(self, req: Request) -> None:
        req.state = State.QUEUED
        self.queue.append(req)

    def _admit(self) -> None:
        while self.queue and self.pool.num_free() > 0 and (
            len(self.running) < self.max_batch
        ):
            req = self.queue.pop(0)
            req.slot = self.pool.alloc()
            self._prefill(req)
            req.state = State.RUNNING
            self.running.append(req)

    def _prefill(self, req: Request) -> None:
        cfg = self.cfg
        req._prefix_len = cfg.num_prefix_embeds
        if cfg.family == "encdec":
            assert req.enc_embeds is not None, "encdec request needs enc_embeds"
            cross = self._cross_fn(req.enc_embeds.shape[1])(self.params, req.enc_embeds)
            slot = jnp.array([req.slot])
            cross_axes = {"k": 1, "v": 1, "mask": 0}
            self.pool.data["cross"] = kv_cache.scatter(
                self.pool.data["cross"], cross_axes, slot, cross
            )
        P = _bucket(req.prompt_len)
        if cfg.attn_kind == "sliding" and P > cfg.window:
            # ring-buffer contract: feed the prompt in window-sized chunks
            self._prefill_sliding(req)
            return
        tokens = jnp.array(
            [req.prompt + [0] * (P - req.prompt_len)], jnp.int32
        )
        prefix = req.prefix_embeds
        if cfg.num_prefix_embeds and prefix is None:
            prefix = jnp.zeros(
                (1, cfg.num_prefix_embeds, cfg.d_model), jnp.dtype(cfg.dtype)
            )
        t0 = time.perf_counter()
        self.pool.data, tok = self._prefill_fn(P)(
            self.params, self.pool.data, jnp.int32(req.slot), tokens,
            jnp.int32(req.prompt_len), jnp.int32(req.sampling.seed),
            jnp.float32(req.sampling.temperature),
            jnp.int32(req.sampling.top_k), prefix,
        )
        wall = time.perf_counter() - t0
        if self.needs_ckpt:  # commit point == post-prefill state
            slot = jnp.array([req.slot], jnp.int32)
            grabbed = kv_cache.gather(self.pool.data, self.axes, slot)
            self.ckpt = kv_cache.scatter(self.ckpt, self.axes, slot, grabbed)
        req.committed.append(int(tok))  # T0: deterministic by construction
        req.prefill_time = self._now
        self.events.append({
            "kind": "prefill", "tokens": req.prompt_len + (cfg.num_prefix_embeds or 0),
            "padded": P + (cfg.num_prefix_embeds or 0), "wall": wall, "iter": self._now,
        })

    def _prefill_sliding(self, req: Request) -> None:
        """Chunked prefill for sliding-window archs (<= window per pass).
        Per-request fixed chunking => still deterministic by construction."""
        cfg = self.cfg
        W = cfg.window
        key = ("prefill_chunk", W)
        if key not in self._fns:
            axes = self.axes

            @jax.jit
            def chunk_fn(params, pool, slot, tokens, start):
                slots = slot[None]
                cache = kv_cache.gather(pool, axes, slots)
                logits, new_cache, _ = forward(
                    params, cfg, tokens, cache=cache,
                    start_pos=start[None], schedule=VERIFY_SCHEDULE,
                )
                return kv_cache.scatter(pool, axes, slots, new_cache), logits

            self._fns[key] = chunk_fn
        t0 = time.perf_counter()
        prompt = req.prompt
        logits = None
        for s in range(0, len(prompt), W):
            chunk = prompt[s : s + W]
            chunk = chunk + [0] * (W - len(chunk))  # fixed shape per chunk
            self.pool.data, logits = self._fns[key](
                self.params, self.pool.data, jnp.int32(req.slot),
                jnp.array([chunk], jnp.int32), jnp.int32(s),
            )
        last = (len(prompt) - 1) % W
        tok = sample_token(
            logits[0, last], jnp.int32(req.sampling.seed), jnp.int32(0),
            jnp.float32(req.sampling.temperature),
            jnp.int32(req.sampling.top_k),
        )
        wall = time.perf_counter() - t0
        if self.needs_ckpt:
            slot = jnp.array([req.slot], jnp.int32)
            grabbed = kv_cache.gather(self.pool.data, self.axes, slot)
            self.ckpt = kv_cache.scatter(self.ckpt, self.axes, slot, grabbed)
        req.committed.append(int(tok))
        req.prefill_time = self._now
        self.events.append({
            "kind": "prefill", "tokens": req.prompt_len,
            "padded": ((req.prompt_len + W - 1) // W) * W, "wall": wall,
            "iter": self._now,
        })

    def _view(self) -> sched.SchedulerView:
        """Snapshot handed to the schedule policy each iteration."""
        return sched.SchedulerView(
            running=tuple(self.running),
            mode=self.mode,
            window=self.window,
            group=self.group,
            # recurrent state advances irreversibly: no speculating past a
            # submitted window on ssm/hybrid archs (scheduler.py docstring)
            speculate_past_inflight=not self.needs_ckpt,
            now=self._now,
            verify_latency=self.verify_latency,
        )

    # ------------------------------------------------------------------
    # steps
    # ------------------------------------------------------------------

    def _decode_step(self, batch: List[Request]) -> Dict[str, Any]:
        B = len(batch)
        if self.mode == Mode.BATCH_INVARIANT:
            schedule = INVARIANT_SCHEDULE
        else:
            schedule = self.policy.schedule_for(B)
        slots = jnp.array([r.slot for r in batch], jnp.int32)
        last_tok, pos, out_pos, seeds, temps, top_ks = [], [], [], [], [], []
        for r in batch:
            # speculation order: committed, in-flight window, fresh candidates
            seq = r.committed + r.speculation
            last_tok.append(seq[-1])
            prefix = getattr(r, "_prefix_len", 0)
            pos.append(r.prompt_len + prefix + len(seq) - 1)
            out_pos.append(len(seq))
            seeds.append(r.sampling.seed)
            temps.append(r.sampling.temperature)
            top_ks.append(r.sampling.top_k)
        t0 = time.perf_counter()
        self.pool.data, nxt = self._decode_fn(B, schedule)(
            self.params, self.pool.data, slots,
            jnp.array(last_tok, jnp.int32), jnp.array(pos, jnp.int32),
            jnp.array(seeds, jnp.int32), jnp.array(temps, jnp.float32),
            jnp.array(out_pos, jnp.int32), jnp.array(top_ks, jnp.int32),
        )
        wall = time.perf_counter() - t0
        nxt = [int(t) for t in nxt]
        for r, t in zip(batch, nxt):
            if self.mode == Mode.LLM42 and r.sampling.is_deterministic:
                r.candidates.append(t)
            else:
                r.committed.append(t)
        return {
            "kind": "decode", "batch": B, "schedule": tuple(schedule),
            "ctx_sum": sum(pos) + B, "wall": wall, "iter": self._now,
            "rids": [r.rid for r in batch],
        }

    def _verify_step(
        self, group: List[Request], *, defer: bool = False,
        n_decodable: int = 0,
    ) -> Dict[str, Any]:
        """Run one grouped verification pass.

        ``defer=False`` (pause policy): the verdict is applied synchronously,
        exactly the seed behaviour.  ``defer=True`` (overlap policy): the
        submitted candidates move to per-request in-flight state and the
        verdict lands at the start of an iteration ``verify_latency`` steps
        later — the device pass still executes eagerly (host-sequential
        simulation of an async verify stream), so its KV/state repair is in
        place before any later cache read, but the *protocol* result
        arrives with the modeled latency.
        """
        G, W = self.group, self.window
        rows = group[:G]
        n_pad = G - len(rows)
        inputs, cands, cand_lens, starts, bases, slots, seeds, temps, tks = (
            [], [], [], [], [], [], [], [], []
        )
        for r in rows:
            i, c, cl, sp, ob = dvr.build_verify_row(r, W)
            inputs.append(i)
            cands.append(c)
            cand_lens.append(cl)
            starts.append(sp)
            bases.append(ob)
            slots.append(r.slot)
            seeds.append(r.sampling.seed)
            temps.append(r.sampling.temperature)
            tks.append(r.sampling.top_k)
        for _ in range(n_pad):
            inputs.append([0] * W)
            cands.append([-1] * (W - 1))
            cand_lens.append(0)
            starts.append(0)
            bases.append(0)
            slots.append(self.pool.scratch_slot)
            seeds.append(0)
            temps.append(0.0)
            tks.append(0)
        t0 = time.perf_counter()
        ckpt_in = self.ckpt if self.needs_ckpt else self.pool.data
        self.pool.data, ckpt_out, n_match, commit_tok, _v = self._verify_fn(
            self.params, self.pool.data, ckpt_in,
            jnp.array(slots, jnp.int32), jnp.array(starts, jnp.int32),
            jnp.array(inputs, jnp.int32), jnp.array(cands, jnp.int32),
            jnp.array(cand_lens, jnp.int32), jnp.array(seeds, jnp.int32),
            jnp.array(temps, jnp.float32), jnp.array(bases, jnp.int32),
            jnp.array(tks, jnp.int32),
        )
        if self.needs_ckpt:
            self.ckpt = ckpt_out
        wall = time.perf_counter() - t0
        n_match = [int(n) for n in n_match]
        commit_tok = [int(t) for t in commit_tok]
        if defer:
            # verdict usable at the START of iteration now + latency
            ready_iter = self._now + self.verify_latency
            for r, n, t in zip(rows, n_match, commit_tok):
                fl = dvr.begin_inflight(r, W, self._now, ready_iter)
                fl.n_match, fl.commit_tok = n, t
        else:
            for r, n, t in zip(rows, n_match, commit_tok):
                dvr.apply_verify_result(r, n, t)
        return {
            "kind": "verify", "group": len(rows), "window": W, "pad_rows": n_pad,
            "ctx_sum": sum(starts) + W * G, "wall": wall, "iter": self._now,
            # requests that could decode this iteration — under the pause
            # policy these are the requests the verify pass stalls; under
            # overlap they ride in the composite event's decode batch
            "rids": [r.rid for r in rows], "n_decodable": n_decodable,
        }

    def _retire(self) -> None:
        done = [r for r in self.running if r.finished() or (
            not r.sampling.is_deterministic and r.done_decoding()
        ) or (self.mode != Mode.LLM42 and r.done_decoding())]
        for r in done:
            # a det request must have no outstanding speculation at retirement
            if self.mode == Mode.LLM42 and r.sampling.is_deterministic and (
                r.candidates or r.inflight is not None
            ):
                continue
            r.state = State.FINISHED
            r.finish_time = self._now
            self.running.remove(r)
            self.pool.free(r.slot)
            r.slot = -1
            self.finished.append(r)

    # ------------------------------------------------------------------
    # main loop
    # ------------------------------------------------------------------

    def step(self) -> bool:
        """One scheduler iteration.  Returns False when fully drained.

        Order within an iteration: land due verdicts, plan, DECODE, then
        VERIFY launch.  Decode-before-verify is a correctness requirement,
        not taste: the decode of a row being submitted this iteration
        re-feeds its last candidate, writing fast-path KV at the window's
        final position — a position the verify replay is about to repair
        and that no later replay will ever cover again.  Launching the
        verify afterwards lets its repair win; every later speculative
        write lands at positions >= the next window start, which the next
        replay rewrites.  An iteration that ran both passes emits a single
        composite ``overlap`` event so the cost model can charge them as
        concurrent (``costmodel.step_time``)."""
        self._now += 1
        self._retire()
        self._admit()
        if not self.running and not self.queue:
            return False

        applied = self._apply_due_verdicts()
        view = self._view()
        plan = self.scheduler.plan(view)
        vev = dev = None
        if plan.decode:
            batch = [r for r in plan.decode if not r.done_decoding()]
            if batch:
                dev = self._decode_step(batch)
        if plan.verify:
            vev = self._verify_step(
                plan.verify, defer=self.scheduler.defers_verify,
                n_decodable=len(sched.decodable(view)),
            )

        if vev is not None and dev is not None:
            self.events.append({
                "kind": "overlap", "decode": dev, "verify": vev,
                "wall": dev["wall"] + vev["wall"], "iter": self._now,
            })
        elif vev is not None:
            self.events.append(vev)
        elif dev is not None:
            self.events.append(dev)
        if vev is not None or dev is not None or applied:
            return True
        return bool(self.running or self.queue)

    def _apply_due_verdicts(self) -> bool:
        """Land in-flight verify results whose modeled latency has elapsed."""
        applied = False
        for r in self.running:
            fl = r.inflight
            if fl is not None and fl.n_match >= 0 and fl.ready_iter <= self._now:
                dvr.apply_inflight_result(r)
                applied = True
        return applied

    def run(self, max_iters: int = 100000) -> List[Request]:
        for _ in range(max_iters):
            if not self.step():
                break
        assert not self.running and not self.queue, "engine did not drain"
        return self.finished
