"""Online-inference runner: Poisson arrivals against a simulated TPU clock.

Drives the real engine (real scheduling, real rollbacks) while advancing a
simulated clock by the cost model's per-step time — the standard
discrete-event approach for evaluating serving schedulers without the
target hardware.  Produces per-request end-to-end latency and TTFT
(paper Fig. 11 / Table 5).

Overlapped iterations (``OverlapPolicy``) arrive as composite ``overlap``
events; ``costmodel.step_time`` charges them as concurrent (max + a
contention term), so the clock advances by less than the pause policy's
decode-then-verify sum — the latency benefit shows up here directly.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

from repro.models.base import ModelConfig
from repro.serving import costmodel
from repro.serving.engine import Engine
from repro.serving.request import Request


@dataclasses.dataclass
class OnlineResult:
    latencies: Dict[int, float]  # rid -> end-to-end seconds (sim)
    ttfts: Dict[int, float]  # rid -> time-to-first-token seconds (sim)
    total_time: float
    out_tokens: int


def run_online(
    engine: Engine,
    cost_cfg: ModelConfig,
    requests: List[Tuple[Request, float]],  # (request, arrival_time_s)
    *,
    hw: costmodel.Hardware = costmodel.V5E,
    invariant_mode: bool = False,
    max_iters: int = 200000,
) -> OnlineResult:
    pending = sorted(requests, key=lambda p: p[1])
    clock = 0.0
    arrival: Dict[int, float] = {}
    ttft: Dict[int, float] = {}
    latency: Dict[int, float] = {}
    n_events = 0

    def admit():
        nonlocal pending
        while pending and pending[0][1] <= clock:
            req, t = pending.pop(0)
            arrival[req.rid] = t
            engine.submit(req)

    for _ in range(max_iters):
        admit()
        if not pending and not engine.running and not engine.queue:
            break
        progressed = engine.step()
        new_events = engine.events[n_events:]
        n_events = len(engine.events)
        for ev in new_events:
            ev = dict(ev)
            if invariant_mode:
                ev["invariant"] = True
            clock += costmodel.step_time(cost_cfg, ev, hw)
        # first token timestamps (prefill commits T0 synchronously)
        for r in engine.running:
            if r.rid not in ttft and r.committed:
                ttft[r.rid] = clock - arrival[r.rid]
        for r in engine.finished:
            if r.rid not in latency:
                latency[r.rid] = clock - arrival[r.rid]
                ttft.setdefault(r.rid, clock - arrival[r.rid])
        if not progressed and pending:
            clock = max(clock, pending[0][1])  # idle until next arrival
    # drain bookkeeping for anything that finished on the last step
    for r in engine.finished:
        latency.setdefault(r.rid, clock - arrival[r.rid])
        ttft.setdefault(r.rid, clock - arrival[r.rid])

    out_tokens = sum(r.num_output for r in engine.finished)
    return OnlineResult(latency, ttft, clock, out_tokens)


def percentile(values: List[float], p: float) -> float:
    if not values:
        return float("nan")
    vs = sorted(values)
    idx = min(int(p / 100.0 * len(vs)), len(vs) - 1)
    return vs[idx]
