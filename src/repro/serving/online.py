"""Online-inference runner: Poisson arrivals against the engine's stream clocks.

Drives the real engine (real scheduling, real rollbacks) in costed-clock
mode: ``Engine.bind_cost_model`` switches the dual-stream runtime
(``serving.streams``) to continuous device time, so the discrete-event
clock IS the engine's main-stream clock — decode/prefill passes advance
it serially, deferred verification queues on the verify stream and only
slows the main stream by the modeled cross-stream contention, and verify
tails longer than their launch iteration spill into the verify stream's
backlog instead of blocking anything.  Produces per-request end-to-end
latency and TTFT (paper Fig. 11 / Table 5).

Exhausting ``max_iters`` before the workload drains raises (it used to
fall out of the loop and silently return truncated latency/TTFT dicts —
quietly partial benchmark numbers); pass ``on_exhaust="warn"`` to instead
keep the partial result and get a warning with the unfinished counts.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Dict, List, Tuple

from repro.models.base import ModelConfig
from repro.serving import costmodel
from repro.serving.engine import Engine
from repro.serving.request import Request


@dataclasses.dataclass
class OnlineResult:
    latencies: Dict[int, float]  # rid -> end-to-end seconds (sim)
    ttfts: Dict[int, float]  # rid -> time-to-first-token seconds (sim)
    total_time: float
    out_tokens: int
    #: final ``engine.obs.metrics.snapshot()`` — the registry view of the
    #: run (stream occupancy, rollback depth distribution, TTFT/TPOT
    #: percentiles on the sim clock, block-pool/prefix-cache state)
    metrics: Dict[str, Any] = dataclasses.field(default_factory=dict)


def run_online(
    engine: Engine,
    cost_cfg: ModelConfig,
    requests: List[Tuple[Request, float]],  # (request, arrival_time_s)
    *,
    hw: costmodel.Hardware = costmodel.V5E,
    invariant_mode: bool = False,
    max_iters: int = 200000,
    on_exhaust: str = "raise",  # "raise" | "warn"
) -> OnlineResult:
    assert on_exhaust in ("raise", "warn")
    engine.bind_cost_model(cost_cfg, hw, invariant=invariant_mode)
    pending = sorted(requests, key=lambda p: p[1])
    arrival: Dict[int, float] = {}
    ttft: Dict[int, float] = {}
    latency: Dict[int, float] = {}

    def admit():
        nonlocal pending
        while pending and pending[0][1] <= engine.runtime.now:
            req, t = pending.pop(0)
            arrival[req.rid] = t
            engine.submit(req)

    for _ in range(max_iters):
        admit()
        if not pending and not engine.running and not engine.queue and (
            not engine.preempted
        ):
            break
        # the runtime's event-driven skip (verdict-gated idle iterations)
        # must never jump past the next arrival — the main stream is free
        # to admit and prefill it the moment it lands
        engine.runtime.skip_horizon = pending[0][1] if pending else None
        progressed = engine.step()
        clock = engine.runtime.now
        # first token timestamps (prefill commits T0 synchronously)
        for r in engine.running:
            if r.rid not in ttft and r.committed:
                ttft[r.rid] = clock - arrival[r.rid]
        for r in engine.finished:
            if r.rid not in latency:
                latency[r.rid] = clock - arrival[r.rid]
                ttft.setdefault(r.rid, clock - arrival[r.rid])
        if not progressed and pending:
            engine.runtime.idle_until(pending[0][1])  # idle until next arrival
    # re-check after the loop: a workload that drains on exactly the last
    # permitted step is complete, not truncated
    if pending or engine.running or engine.queue or engine.preempted:
        msg = (
            f"run_online exhausted max_iters={max_iters} before draining: "
            f"{len(engine.running)} running, {len(engine.queue)} queued, "
            f"{len(engine.preempted)} preempted awaiting restore, "
            f"{len(pending)} not yet arrived; latency/TTFT dicts would be "
            f"partial ({len(latency)}/{len(requests)} finished)"
        )
        if on_exhaust == "raise":
            raise RuntimeError(msg)
        warnings.warn(msg, RuntimeWarning, stacklevel=2)
    clock = engine.runtime.now
    # drain bookkeeping for anything that finished on the last step
    for r in engine.finished:
        latency.setdefault(r.rid, clock - arrival[r.rid])
        ttft.setdefault(r.rid, clock - arrival[r.rid])

    out_tokens = sum(r.num_output for r in engine.finished)
    return OnlineResult(
        latency, ttft, clock, out_tokens, engine.obs.metrics.snapshot()
    )


def percentile(values: List[float], p: float) -> float:
    if not values:
        return float("nan")
    vs = sorted(values)
    idx = min(int(p / 100.0 * len(vs)), len(vs) - 1)
    return vs[idx]
