"""TPU-v5e cost model: replays engine event logs into simulated time.

The container is CPU-only, so wall-clock is meaningless for TPU throughput
claims.  Instead each engine step is costed with a two-term roofline
(compute, HBM) from its shape metadata; the paper's throughput comparisons
(Figs. 5, 10, 11, 12) are reproduced by replaying the *same scheduling
decisions* (the engine's real event log, including real rollbacks and
recomputation measured on the reduced model) through this cost model at the
full model's scale.

Batch-invariance penalty: the paper measures He-et-al. Triton GEMMs at 194
vs. 527 cuBLAS TFLOPS (Fig. 4a, -63%) and batch-invariant RMSNorm at up to
50% slower than the fused kernel (Fig. 4b).  We model BATCH_INVARIANT mode
with ``bi_compute_frac = 194/527`` of peak and ``bi_mem_frac = 0.7`` of
achieved bandwidth, citing those measurements.

Fast-path split-K benefit: at small batch a GEMM cannot fill the machine;
effective compute utilisation ~ min(1, rows * splits / SAT_ROWS).  split-K
raises utilisation exactly as on GPU (it exists to fill SMs/MXU at low
occupancy); the batch-invariant kernel is pinned to splits=1 and eats the
low-utilisation penalty — this is the mechanism behind paper Fig. 5.

Per-stream time accounting (the dual-clock runtime, ``serving.streams``):
the engine executes on two streams — decode and prefill passes serialize
on the **main** stream (separate kernel launches, one queue), deferred
verification rides the **verify** stream.  ``simulate``/``simulate_streams``
replay an event log through exactly that model: a composite ``overlap``
event's decode + prefill sub-passes are charged serially on the main
clock, its verify sub-pass starts at max(iteration start, previous verify
completion) on the verify clock, and the portion of the verify pass that
overlaps the iteration's main-stream work slows the main stream by
``stream_contention * overlap`` (shared HBM).  A verify pass *longer* than
its launch iteration no longer blocks anything — its tail spills into the
verify stream's backlog and only delays when the verdict lands.  Total
simulated time is the two-stream makespan.  Sync (pause-style) verify
events — standalone ``verify`` events without ``deferred: True`` — block
the main stream for their full duration, exactly the prototype's cost.

``step_time`` on a single composite event keeps a memoryless
approximation of the same rule (no cross-event backlog):
t = max(t_main, t_verify) + ``stream_contention`` * min(t_main, t_verify),
where t_main is the decode + prefill serial sum.  This is always <= the
serial sum — the pause policy's cost — and >= the max, i.e. overlap is
never modeled as free.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Iterable, List, Tuple

from repro.models.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class Hardware:
    name: str = "tpu-v5e"
    peak_flops: float = 197e12  # bf16 / chip
    hbm_bw: float = 819e9  # B/chip/s
    ici_bw: float = 50e9  # B/link/s (TP all-reduce term, events with "tp")
    # batch-invariance penalties, calibrated from paper Fig. 4
    bi_compute_frac: float = 194.0 / 527.0
    bi_mem_frac: float = 0.7
    # rows needed to saturate the MXU pipeline (128x128 systolic tiles,
    # a few in flight)
    sat_rows: int = 256
    dtype_bytes: int = 2  # bf16 weights/KV at serving time
    # fraction of a concurrent verify-stream pass NOT hidden behind the
    # main stream's work (contention on HBM + inter-pass scheduling gaps);
    # 0 = ideal dual-issue, 1 = serial execution
    overlap_serial_frac: float = 0.35
    # fixed per-kernel-launch overhead (dispatch + XLA prologue); passes
    # fused into an already-running launch (``fused: True`` sub-events of
    # the engine's single mixed-batch step) pay neither this nor a second
    # weight stream
    launch_overhead_s: float = 5e-6

    @property
    def stream_contention(self) -> float:
        """Cross-stream interference coefficient (alias of the historical
        ``overlap_serial_frac`` field — same physics, stream vocabulary)."""
        return self.overlap_serial_frac


V5E = Hardware()


def kv_bytes_per_token(cfg: ModelConfig, dtype_bytes: int = 2) -> int:
    """KV-cache bytes appended per token (attention layers only)."""
    total = 0
    for i in range(cfg.num_layers):
        if cfg.layer_kind(i) == "attn":
            total += 2 * cfg.num_kv_heads * cfg.hd * dtype_bytes
    return total


def kv_block_bytes(
    cfg: ModelConfig, block_size: int, dtype_bytes: int = 2
) -> int:
    """HBM bytes of one paged KV block (``serving.blockpool``): K + V +
    the int32 position row, across all full-attention layers."""
    total = 0
    for i in range(cfg.num_layers):
        if cfg.layer_kind(i) == "attn":
            total += 2 * block_size * cfg.num_kv_heads * cfg.hd * dtype_bytes
            total += 4 * block_size  # pos (int32)
    return total


def pool_hbm_bytes(
    cfg: ModelConfig, num_blocks: int, num_slots: int, block_size: int,
    dtype_bytes: int = 2,
) -> int:
    """HBM budget of a paged cache pool: ``num_blocks`` KV blocks plus the
    per-slot recurrent state rows.  The dense manager's footprint is the
    special case ``num_blocks = num_slots * ceil(capacity / block_size)`` —
    which is exactly the default pool size, so ``fig_cache`` compares
    paged vs dense at a genuinely equal budget."""
    return (
        num_blocks * kv_block_bytes(cfg, block_size, dtype_bytes)
        + num_slots * state_bytes(cfg, dtype_bytes)
    )


def dense_hbm_bytes(
    cfg: ModelConfig, num_slots: int, capacity: int, dtype_bytes: int = 2
) -> int:
    """HBM budget of the legacy dense manager: one ``capacity``-long KV
    ring per slot plus the recurrent state rows."""
    per_slot = capacity * kv_bytes_per_token(cfg, dtype_bytes)
    per_slot += 4 * capacity * sum(
        1 for i in range(cfg.num_layers) if cfg.layer_kind(i) == "attn"
    )  # pos rows
    return num_slots * (per_slot + state_bytes(cfg, dtype_bytes))


def memory_report(events: Iterable[Dict[str, Any]]) -> Dict[str, int]:
    """Memory-pressure + cache-hit accounting over an engine event log:
    prompt tokens served from the prefix cache (``cached`` on prefill
    events), preemption/restore counts, tokens dropped at preemption and
    tokens deterministically recomputed by restore replays."""
    out = {
        "cached_tokens": 0, "preemptions": 0, "restores": 0,
        "preempted_tokens": 0, "replayed_tokens": 0,
    }
    for ev in flatten_events(events):
        kind = ev.get("kind")
        if kind == "cache_hit":
            out["cached_tokens"] += ev.get("tokens", 0)
        elif kind == "prefill_chunk" and ev.get("replay"):
            out["replayed_tokens"] += ev.get("tokens", 0)
        elif kind == "preempt":
            out["preemptions"] += 1
            out["preempted_tokens"] += ev.get("dropped_tokens", 0)
        elif kind == "restore":
            out["restores"] += 1
        elif kind == "prefill" and ev.get("replay"):
            out["replayed_tokens"] += ev.get("tokens", 0)
    return out


def state_bytes(cfg: ModelConfig, dtype_bytes: int = 2) -> int:
    """Recurrent state bytes per request (mamba/rwkv layers)."""
    total = 0
    for i in range(cfg.num_layers):
        kind = cfg.layer_kind(i)
        if kind == "mamba":
            total += (cfg.d_conv - 1) * cfg.d_inner * dtype_bytes
            total += cfg.d_inner * cfg.d_state * 4
        elif kind == "rwkv":
            h = cfg.d_model // cfg.rwkv_head_dim
            total += 2 * cfg.d_model * dtype_bytes
            total += h * cfg.rwkv_head_dim**2 * 4
    return total


def flops_per_token(cfg: ModelConfig) -> float:
    """~2 * active params per token (matmul MACs x2)."""
    return 2.0 * cfg.active_param_count()


def attn_flops(cfg: ModelConfig, tokens: int, ctx: float) -> float:
    """Attention score+value FLOPs for `tokens` queries at avg context ctx."""
    n_attn = sum(1 for i in range(cfg.num_layers) if cfg.layer_kind(i) == "attn")
    if cfg.attn_kind == "sliding":
        ctx = min(ctx, cfg.window)
    return 4.0 * n_attn * tokens * ctx * cfg.num_heads * cfg.hd


def flatten_events(
    events: Iterable[Dict[str, Any]],
) -> List[Dict[str, Any]]:
    """Expand composite ``overlap`` events into their leaf sub-events.

    For consumers that inspect per-pass metadata (tests, span analyses);
    time accounting must instead go through ``step_time``/``simulate``,
    which charge an overlapped pair as concurrent rather than serial.
    """
    out: List[Dict[str, Any]] = []
    for ev in events:
        if ev.get("kind") == "overlap":
            for k in ("decode", "verify", "prefill"):
                if k in ev:
                    out.append(ev[k])
            out.extend(ev.get("verifies", ()))
        else:
            out.append(ev)
    return out


def step_time(cfg: ModelConfig, ev: Dict[str, Any], hw: Hardware = V5E) -> float:
    """Simulated seconds for one engine event on one chip."""
    kind = ev["kind"]
    if kind == "overlap":
        # composite iteration, per-stream rule: decode + prefill serialize
        # on the main stream (two launches, one queue); the verify pass
        # rides the second stream concurrently, derated by the cross-
        # stream contention coefficient.  Memoryless single-event view —
        # ``simulate_streams`` carries verify tails across iterations.
        t_main, t_verify = _lane_times(cfg, ev, hw)
        return max(t_main, t_verify) + hw.stream_contention * min(
            t_main, t_verify
        )

    pbytes = cfg.active_param_count() * hw.dtype_bytes
    kvb = kv_bytes_per_token(cfg, hw.dtype_bytes)
    if kind in ("prefill", "prefill_chunk"):
        tokens = ev["padded"]
        start = ev.get("start", 0)  # chunk offset into the prompt
        ctx = start + tokens / 2
        rows, splits = tokens, 1
        invariant = False
    elif kind == "decode":
        tokens = ev["batch"]
        ctx = ev.get("ctx_sum", tokens) / max(tokens, 1)
        rows = tokens
        sched = ev.get("schedule", (1, 1, "float32", False))
        splits = sched[0]
        invariant = ev.get("invariant", False)
    elif kind == "verify":
        tokens = ev["group"] * ev["window"]
        ctx = ev.get("ctx_sum", tokens) / max(ev["group"], 1)
        rows, splits = tokens, 1
        invariant = False
    else:
        return 0.0

    flops = flops_per_token(cfg) * tokens + attn_flops(cfg, tokens, ctx)
    # memory: weights stream once per pass; KV read ~ ctx per sequence row
    if kind in ("decode", "verify"):
        kv_read = kvb * ev.get("ctx_sum", 0)
    else:
        # prefill: causal-local reads — flash-style q-chunks (Q_CHUNK=512)
        # each stream the cache written so far once, so the pass reads
        # ~avg-context bytes per q-chunk (ctx already = start + tokens/2);
        # sliding-window archs never read past the window
        read_ctx = min(ctx, cfg.window) if cfg.attn_kind == "sliding" else ctx
        n_qchunks = -(-tokens // 512)
        kv_read = kvb * read_ctx * max(n_qchunks, 1)
    # a fused follower shares the lead pass's launch: the weights are
    # already streaming and there is no second dispatch
    fused = ev.get("fused", False)
    bytes_moved = (0 if fused else pbytes) + kv_read + kvb * tokens

    # width-tp model-axis mesh: weights, KV and matmul FLOPs shard 1/tp per
    # chip; each layer's row-parallel matmuls all-reduce the
    # (tokens, d_model) activation over ICI — a ring moves 2(tp-1)/tp of
    # the data per chip, twice per layer.  The un-overlapped ICI term is
    # what makes the fig_cluster TP sweep sub-linear.
    tp = int(ev.get("tp", 1))
    t_ici = 0.0
    if tp > 1:
        flops /= tp
        bytes_moved /= tp
        t_ici = (
            2.0 * cfg.num_layers * tokens * cfg.d_model * hw.dtype_bytes
            * 2.0 * (tp - 1) / tp / hw.ici_bw
        )

    peak = hw.peak_flops
    bw = hw.hbm_bw
    util = min(1.0, (rows * max(splits, 1)) / hw.sat_rows)
    if invariant:
        peak *= hw.bi_compute_frac
        bw *= hw.bi_mem_frac
        util = min(1.0, rows / hw.sat_rows)  # no split-K allowed

    t_compute = flops / (peak * max(util, 1e-3))
    t_memory = bytes_moved / bw
    t = max(t_compute, t_memory) + t_ici
    if not fused:
        t += hw.launch_overhead_s
    return t


def _lane_times(
    cfg: ModelConfig, ev: Dict[str, Any], hw: Hardware
) -> Tuple[float, float]:
    """(main-stream seconds, verify-stream seconds) for one composite
    ``overlap`` event: decode + prefill serialize on the main stream, the
    verify sub-pass is the verify stream's work."""
    sub = {k: dict(ev[k]) for k in ("decode", "verify", "prefill") if k in ev}
    extra = [dict(v) for v in ev.get("verifies", ())]
    if ev.get("invariant"):
        for s in sub.values():
            s["invariant"] = True
        for s in extra:
            s["invariant"] = True
    if ev.get("tp", 1) > 1:
        for s in sub.values():
            s.setdefault("tp", ev["tp"])
        for s in extra:
            s.setdefault("tp", ev["tp"])
    t_main = sum(
        step_time(cfg, s, hw) for k, s in sub.items() if k != "verify"
    )
    t_verify = step_time(cfg, sub["verify"], hw) if "verify" in sub else 0.0
    # extra verify groups (multi-window iterations) serialize behind the
    # first on the verify stream
    t_verify += sum(step_time(cfg, s, hw) for s in extra)
    return t_main, t_verify


@dataclasses.dataclass
class StreamSim:
    """Two-stream replay result: ``total_s`` is the makespan, the busy
    fields are per-stream work, ``verify_occupancy`` is the verify
    stream's utilization over the makespan, ``peak_inflight`` is the
    deepest verdict queue the replay saw (> 1 only with multi-window
    pipelining, where it is the telemetry that shows whether a depth
    setting was actually exercised), and ``breakdown`` holds leaf per-kind
    device seconds (informational — their sum exceeds the makespan exactly
    when streams overlapped)."""

    total_s: float
    main_busy_s: float
    verify_busy_s: float
    verify_occupancy: float
    breakdown: Dict[str, float]
    peak_inflight: int = 0


def simulate_streams(
    cfg: ModelConfig, events: Iterable[Dict[str, Any]], hw: Hardware = V5E,
    *, invariant_mode: bool = False,
) -> StreamSim:
    """Replay an event log through genuine two-stream time accounting.

    The replay drives the SAME :class:`streams.DualClockRuntime` the
    engine's costed clock runs on — one implementation of the physics:
    main-stream passes (decode, prefill chunks — and sync verify, which
    blocks everything) serialize on the main clock; a deferred verify pass
    (``deferred: True``, or any verify sub-pass of a composite ``overlap``
    event) queues on the verify clock, its tail spilling across
    iterations, and the portion overlapping the launch iteration's
    main-stream work slows the main clock by ``stream_contention *
    overlap``.  A verify-only iteration waits out its verdict, exactly as
    the engine's event-driven skip does.  ``total_s`` is the two-stream
    makespan.  (Iterations the engine spent fully verdict-gated emit no
    events and are invisible to any log replay — when the engine itself
    ran a costed clock, ``engine.runtime.makespan`` is authoritative.)
    """
    from repro.serving import streams  # local import: streams is a leaf

    breakdown: Dict[str, float] = {}

    def cost_fn(ev: Dict[str, Any]) -> float:
        e = dict(ev, invariant=True) if invariant_mode else ev
        t = step_time(cfg, e, hw)
        breakdown[ev["kind"]] = breakdown.get(ev["kind"], 0.0) + t
        return t

    rt = streams.DualClockRuntime(
        cost_fn, latency=0.0, contention=hw.stream_contention
    )
    for ev in events:
        kind = ev.get("kind")
        rt.begin_iteration()
        if kind == "overlap":
            for k in ("decode", "prefill"):
                if k in ev:
                    rt.charge(ev[k])
            if "verify" in ev:
                rt.launch_verify(ev["verify"])
            for v in ev.get("verifies", ()):
                rt.launch_verify(v)
        elif kind == "verify":
            rt.launch_verify(ev, sync=not ev.get("deferred"))
        else:
            rt.charge(ev)
        rt.end_iteration()
    total = rt.makespan
    return StreamSim(
        total_s=total,
        main_busy_s=rt.main.busy,
        verify_busy_s=rt.verify.busy,
        verify_occupancy=rt.verify.busy / total if total > 0 else 0.0,
        breakdown=breakdown,
        peak_inflight=rt.peak_outstanding,
    )


def simulate(
    cfg: ModelConfig, events: Iterable[Dict[str, Any]], hw: Hardware = V5E,
    *, invariant_mode: bool = False,
) -> Dict[str, float]:
    """Stream-accounted total time + leaf per-kind breakdown for an event
    log.  ``total_s`` is the two-stream makespan (``simulate_streams``);
    the per-kind entries are device seconds per pass kind, so their sum
    can exceed ``total_s`` when streams overlapped."""
    sim = simulate_streams(cfg, events, hw, invariant_mode=invariant_mode)
    return {
        "total_s": sim.total_s,
        "main_busy_s": sim.main_busy_s,
        "verify_busy_s": sim.verify_busy_s,
        "verify_occupancy": sim.verify_occupancy,
        "peak_inflight": sim.peak_inflight,
        **{f"{k}_s": v for k, v in sim.breakdown.items()},
    }


def throughput_tokens_per_s(
    cfg: ModelConfig, events: List[Dict[str, Any]], output_tokens: int,
    hw: Hardware = V5E, *, invariant_mode: bool = False,
) -> float:
    sim = simulate(cfg, events, hw, invariant_mode=invariant_mode)
    return output_tokens / max(sim["total_s"], 1e-12)
