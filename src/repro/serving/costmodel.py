"""TPU-v5e cost model: replays engine event logs into simulated time.

The container is CPU-only, so wall-clock is meaningless for TPU throughput
claims.  Instead each engine step is costed with a two-term roofline
(compute, HBM) from its shape metadata; the paper's throughput comparisons
(Figs. 5, 10, 11, 12) are reproduced by replaying the *same scheduling
decisions* (the engine's real event log, including real rollbacks and
recomputation measured on the reduced model) through this cost model at the
full model's scale.

Batch-invariance penalty: the paper measures He-et-al. Triton GEMMs at 194
vs. 527 cuBLAS TFLOPS (Fig. 4a, -63%) and batch-invariant RMSNorm at up to
50% slower than the fused kernel (Fig. 4b).  We model BATCH_INVARIANT mode
with ``bi_compute_frac = 194/527`` of peak and ``bi_mem_frac = 0.7`` of
achieved bandwidth, citing those measurements.

Fast-path split-K benefit: at small batch a GEMM cannot fill the machine;
effective compute utilisation ~ min(1, rows * splits / SAT_ROWS).  split-K
raises utilisation exactly as on GPU (it exists to fill SMs/MXU at low
occupancy); the batch-invariant kernel is pinned to splits=1 and eats the
low-utilisation penalty — this is the mechanism behind paper Fig. 5.

Overlapped iterations (scheduler ``OverlapPolicy``): a composite ``overlap``
event carries its decode and verify sub-events — and, under chunked
prefill, a ``prefill_chunk`` sub-event for the co-scheduled prefill lane.
No single pass fills the chip (decode is HBM-bound at small batch, the
verify window and a prefill chunk are short fixed-shape passes), so running
them concurrently hides most of the shorter passes:
t = max(ts) + ``overlap_serial_frac`` * sum(rest), the serial fraction
modeling shared-resource contention (HBM bandwidth, scheduler gaps).  This
is always <= the serial sum — the pause policy's cost — and >= the max,
i.e. overlap is never modeled as free.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Iterable, List

from repro.models.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class Hardware:
    name: str = "tpu-v5e"
    peak_flops: float = 197e12  # bf16 / chip
    hbm_bw: float = 819e9  # B/chip/s
    ici_bw: float = 50e9  # B/link/s (unused in single-chip serving model)
    # batch-invariance penalties, calibrated from paper Fig. 4
    bi_compute_frac: float = 194.0 / 527.0
    bi_mem_frac: float = 0.7
    # rows needed to saturate the MXU pipeline (128x128 systolic tiles,
    # a few in flight)
    sat_rows: int = 256
    dtype_bytes: int = 2  # bf16 weights/KV at serving time
    # fraction of the shorter pass NOT hidden when verify overlaps decode
    # (contention on HBM + inter-pass scheduling gaps)
    overlap_serial_frac: float = 0.35


V5E = Hardware()


def kv_bytes_per_token(cfg: ModelConfig, dtype_bytes: int = 2) -> int:
    """KV-cache bytes appended per token (attention layers only)."""
    total = 0
    for i in range(cfg.num_layers):
        if cfg.layer_kind(i) == "attn":
            total += 2 * cfg.num_kv_heads * cfg.hd * dtype_bytes
    return total


def state_bytes(cfg: ModelConfig, dtype_bytes: int = 2) -> int:
    """Recurrent state bytes per request (mamba/rwkv layers)."""
    total = 0
    for i in range(cfg.num_layers):
        kind = cfg.layer_kind(i)
        if kind == "mamba":
            total += (cfg.d_conv - 1) * cfg.d_inner * dtype_bytes
            total += cfg.d_inner * cfg.d_state * 4
        elif kind == "rwkv":
            h = cfg.d_model // cfg.rwkv_head_dim
            total += 2 * cfg.d_model * dtype_bytes
            total += h * cfg.rwkv_head_dim**2 * 4
    return total


def flops_per_token(cfg: ModelConfig) -> float:
    """~2 * active params per token (matmul MACs x2)."""
    return 2.0 * cfg.active_param_count()


def attn_flops(cfg: ModelConfig, tokens: int, ctx: float) -> float:
    """Attention score+value FLOPs for `tokens` queries at avg context ctx."""
    n_attn = sum(1 for i in range(cfg.num_layers) if cfg.layer_kind(i) == "attn")
    if cfg.attn_kind == "sliding":
        ctx = min(ctx, cfg.window)
    return 4.0 * n_attn * tokens * ctx * cfg.num_heads * cfg.hd


def flatten_events(
    events: Iterable[Dict[str, Any]],
) -> List[Dict[str, Any]]:
    """Expand composite ``overlap`` events into their leaf sub-events.

    For consumers that inspect per-pass metadata (tests, span analyses);
    time accounting must instead go through ``step_time``/``simulate``,
    which charge an overlapped pair as concurrent rather than serial.
    """
    out: List[Dict[str, Any]] = []
    for ev in events:
        if ev.get("kind") == "overlap":
            for k in ("decode", "verify", "prefill"):
                if k in ev:
                    out.append(ev[k])
        else:
            out.append(ev)
    return out


def step_time(cfg: ModelConfig, ev: Dict[str, Any], hw: Hardware = V5E) -> float:
    """Simulated seconds for one engine event on one chip."""
    kind = ev["kind"]
    if kind == "overlap":
        # composite iteration: up to three concurrent passes (decode,
        # verify launch, prefill chunk).  3-way generalization of the
        # 2-way rule: the longest pass hides the rest up to a shared-
        # resource serial fraction — never free, never worse than serial.
        sub = [dict(ev[k]) for k in ("decode", "verify", "prefill") if k in ev]
        if ev.get("invariant"):
            for s in sub:
                s["invariant"] = True
        ts = sorted((step_time(cfg, s, hw) for s in sub), reverse=True)
        return ts[0] + hw.overlap_serial_frac * sum(ts[1:])

    pbytes = cfg.active_param_count() * hw.dtype_bytes
    kvb = kv_bytes_per_token(cfg, hw.dtype_bytes)
    if kind in ("prefill", "prefill_chunk"):
        tokens = ev["padded"]
        start = ev.get("start", 0)  # chunk offset into the prompt
        ctx = start + tokens / 2
        rows, splits = tokens, 1
        invariant = False
    elif kind == "decode":
        tokens = ev["batch"]
        ctx = ev.get("ctx_sum", tokens) / max(tokens, 1)
        rows = tokens
        sched = ev.get("schedule", (1, 1, "float32", False))
        splits = sched[0]
        invariant = ev.get("invariant", False)
    elif kind == "verify":
        tokens = ev["group"] * ev["window"]
        ctx = ev.get("ctx_sum", tokens) / max(ev["group"], 1)
        rows, splits = tokens, 1
        invariant = False
    else:
        return 0.0

    flops = flops_per_token(cfg) * tokens + attn_flops(cfg, tokens, ctx)
    # memory: weights stream once per pass; KV read ~ ctx per sequence row
    if kind in ("decode", "verify"):
        kv_read = kvb * ev.get("ctx_sum", 0)
    else:
        # prefill: causal-local reads — flash-style q-chunks (Q_CHUNK=512)
        # each stream the cache written so far once, so the pass reads
        # ~avg-context bytes per q-chunk (ctx already = start + tokens/2);
        # sliding-window archs never read past the window
        read_ctx = min(ctx, cfg.window) if cfg.attn_kind == "sliding" else ctx
        n_qchunks = -(-tokens // 512)
        kv_read = kvb * read_ctx * max(n_qchunks, 1)
    bytes_moved = pbytes + kv_read + kvb * tokens

    peak = hw.peak_flops
    bw = hw.hbm_bw
    util = min(1.0, (rows * max(splits, 1)) / hw.sat_rows)
    if invariant:
        peak *= hw.bi_compute_frac
        bw *= hw.bi_mem_frac
        util = min(1.0, rows / hw.sat_rows)  # no split-K allowed

    t_compute = flops / (peak * max(util, 1e-3))
    t_memory = bytes_moved / bw
    return max(t_compute, t_memory)


def simulate(
    cfg: ModelConfig, events: Iterable[Dict[str, Any]], hw: Hardware = V5E,
    *, invariant_mode: bool = False,
) -> Dict[str, float]:
    """Total simulated time + per-kind breakdown for an event log."""
    total = 0.0
    breakdown: Dict[str, float] = {}
    for ev in events:
        ev = dict(ev)
        if invariant_mode:
            ev["invariant"] = True
        t = step_time(cfg, ev, hw)
        total += t
        breakdown[ev["kind"]] = breakdown.get(ev["kind"], 0.0) + t
    return {"total_s": total, **{f"{k}_s": v for k, v in breakdown.items()}}


def throughput_tokens_per_s(
    cfg: ModelConfig, events: List[Dict[str, Any]], output_tokens: int,
    hw: Hardware = V5E, *, invariant_mode: bool = False,
) -> float:
    sim = simulate(cfg, events, hw, invariant_mode=invariant_mode)
    return output_tokens / max(sim["total_s"], 1e-12)
