"""Commit-aware radix prefix cache over the KV block pool.

Identical system prompts are recomputed for every request under the dense
cache manager.  The paper's commit point makes a *safe* sharing rule
possible: only **committed** tokens are guaranteed bitwise-stable across
runs — their KV is verify-grade (prefill runs under the fixed verify
schedule; every committed token's cache entry was written by a fixed-shape
verify replay before the token could commit) — so KV for committed
prefixes can be shared read-only, and evicted-then-recomputed, without
ever breaking the determinism contract.  Speculative/verify tails stay
private by construction: sharing is whole-block and never extends past the
committed stream, so every speculative write lands in the owner's private
copy-on-write tail blocks.

The cache is a radix tree with **block-granular edges**: each node is one
KV block, keyed by the exact ``block_size``-token chunk it holds, rooted
at position 0.  Admission walks the tree with the request's prompt and
maps the longest whole-block committed-prefix match into the request's
block table (refcount +1 per block); prefill then chunk-prefills just the
tail.  A partially-matched boundary block is never shared — the tail is
recomputed into a private block instead (copy-on-write by recompute),
which keeps shared blocks strictly read-only.

Insertion points (the "commit-aware" rule):

* prefill completion — prompt blocks: a prompt is committed by the user,
  and prefill runs the fixed deterministic schedule in every engine mode;
* retirement / preemption — the committed *output* extension, but only
  for traffic whose generated KV is deterministic (LLM42 deterministic
  requests: verify-grade by the DVR protocol; BATCH_INVARIANT mode:
  invariant schedule everywhere).  Non-deterministic fast-path output is
  never cached.  The last committed token is always excluded — its KV is
  written by the *next* decode, so it may not exist yet.

Eviction is leaf-first LRU over zero-ref nodes (an interior node's KV is
the prefix context of its children, so the tree frees from the outside
in), with deterministic (last_use, insertion-seq) tie-breaks.  Evicting
never breaks a live request — blocks with a nonzero refcount are skipped —
and an evicted prefix is simply a cache miss later: restore-by-recompute
is bitwise-identical because the stream it replays is committed.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

from repro.serving.blockpool import BlockAllocator


@dataclasses.dataclass
class _Node:
    key: Tuple[int, ...]  # the block's token chunk (edge label from parent)
    bid: int
    parent: Optional["_Node"]
    children: Dict[Tuple[int, ...], "_Node"] = dataclasses.field(
        default_factory=dict
    )
    last_use: int = 0
    seq: int = 0  # insertion order: deterministic LRU tie-break


class PrefixCache:
    """Radix tree of committed-token KV blocks (block-granular edges)."""

    def __init__(self, block_size: int):
        assert block_size >= 1
        self.block_size = block_size
        self.root = _Node(key=(), bid=-1, parent=None)
        self._seq = 0
        # stats (serve-loop / benchmark telemetry)
        self.hits = 0  # admissions that matched >= 1 block
        self.misses = 0  # admissions that matched nothing
        self.hit_tokens = 0  # prompt tokens served from cache
        self.insertions = 0  # blocks registered
        self.evictions = 0  # blocks reclaimed by LRU eviction
        self.size = 0  # blocks currently registered

    # -- lookup ----------------------------------------------------------

    def _chunks(self, tokens: Sequence[int]) -> List[Tuple[int, ...]]:
        bs = self.block_size
        n_full = len(tokens) // bs
        return [
            tuple(int(t) for t in tokens[i * bs:(i + 1) * bs])
            for i in range(n_full)
        ]

    def match(self, tokens: Sequence[int], now: int) -> List[int]:
        """Block ids for the longest whole-block prefix of ``tokens``
        present in the cache; bumps LRU clocks along the path.  The caller
        increfs the returned blocks (same host step — no eviction can
        intervene) and calls :meth:`note_lookup` once the admission
        actually goes through, so retried admissions don't inflate the
        hit-rate stats."""
        bids: List[int] = []
        node = self.root
        for chunk in self._chunks(tokens):
            child = node.children.get(chunk)
            if child is None:
                break
            child.last_use = now
            bids.append(child.bid)
            node = child
        return bids

    def peek(self, tokens: Sequence[int]) -> int:
        """Blocks of the longest cached whole-block prefix of ``tokens``,
        WITHOUT bumping LRU clocks or hit-rate stats — the cluster
        router's affinity probe, which inspects every replica's radix and
        must not perturb the LRU state of replicas it does not pick."""
        n = 0
        node = self.root
        for chunk in self._chunks(tokens):
            child = node.children.get(chunk)
            if child is None:
                break
            n += 1
            node = child
        return n

    def note_lookup(self, n_matched_blocks: int) -> None:
        """Record one completed admission lookup in the hit-rate stats."""
        if n_matched_blocks > 0:
            self.hits += 1
            self.hit_tokens += n_matched_blocks * self.block_size
        else:
            self.misses += 1

    # -- insertion -------------------------------------------------------

    def insert(
        self,
        tokens: Sequence[int],
        bids: Sequence[int],
        now: int,
        allocator: BlockAllocator,
    ) -> int:
        """Register the whole-block prefix of ``tokens`` (held in ``bids``,
        table order) with the tree.  Blocks already cached along the path
        are left as-is (the duplicate stays owned by its request and frees
        normally); newly adopted blocks are marked ``cached`` in the
        allocator, so they stay resident-but-evictable when their refcount
        drains.  Returns the number of blocks adopted."""
        node = self.root
        adopted = 0
        for i, chunk in enumerate(self._chunks(tokens)):
            if i >= len(bids):
                break
            child = node.children.get(chunk)
            if child is None:
                bid = int(bids[i])
                if bid in allocator.cached:
                    break  # already registered under a different path: stop
                self._seq += 1
                child = _Node(
                    key=chunk, bid=bid, parent=node, last_use=now,
                    seq=self._seq,
                )
                node.children[chunk] = child
                allocator.cached.add(bid)
                adopted += 1
                self.size += 1
                self.insertions += 1
            child.last_use = now
            node = child
        return adopted

    # -- eviction --------------------------------------------------------

    def evict_lru(self, allocator: BlockAllocator) -> Optional[int]:
        """Reclaim the least-recently-used zero-ref *leaf* block: detach it
        from the tree and drop its ``cached`` mark.  The caller returns the
        block id to the pool (wipe + free list).  Returns None when nothing
        is evictable."""
        best: Optional[_Node] = None
        stack = [self.root]
        while stack:
            node = stack.pop()
            stack.extend(node.children.values())
            if node is self.root or node.children:
                continue  # interior nodes carry their children's context
            if allocator.refs[node.bid] != 0:
                continue
            if best is None or (node.last_use, node.seq) < (
                best.last_use, best.seq
            ):
                best = node
        if best is None:
            return None
        assert best.parent is not None
        del best.parent.children[best.key]
        allocator.cached.discard(best.bid)
        self.size -= 1
        self.evictions += 1
        return best.bid

    # -- telemetry -------------------------------------------------------

    def stats(self) -> Dict[str, int]:
        return {
            "prefix_hits": self.hits,
            "prefix_misses": self.misses,
            "prefix_hit_tokens": self.hit_tokens,
            "prefix_insertions": self.insertions,
            "prefix_evictions": self.evictions,
            "prefix_size_blocks": self.size,
        }
