"""Paged cache manager: slot pool for O(1) state + block pool for KV.

Historically this module bound one dense ``capacity``-long KV ring to every
slot; since the paged-KV subsystem (``serving.blockpool``) the layout is
split by leaf kind:

* **slot leaves** — recurrent O(1) state (mamba conv/ssm, rwkv shift/wkv),
  sliding-window rings (bounded at ``window + RING_SLACK``) and encdec
  cross caches keep the dense per-slot layout, ``num_slots`` rows plus one
  *scratch slot* used as the write target for padding rows in grouped
  verification;
* **paged leaves** — full-attention ``k``/``v``/``pos`` leaves are cut
  into a global pool of ``block_size``-token blocks, allocated on demand
  as sequences grow, ref-counted so the prefix cache can share committed
  prefixes read-only, and reclaimed (wiped) on free.

``gather`` / ``scatter`` convert between the pool layout and per-step
batched caches: slot leaves index by ``slots`` (B,), paged leaves
assemble / disassemble per-row ``(B, view_capacity, ...)`` views through
block ``tables`` (B, blocks_per_table) int32 with ``-1`` marking
unallocated entries (reads hit the frozen null block, writes are absorbed
by the scratch block).  The forward pass is unchanged — its ``pos`` mask
already handles every hole the paged view can present.
"""

from __future__ import annotations

from typing import Any, List, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.models.base import ModelConfig
from repro.models.transformer import cache_spec
from repro.serving import blockpool
from repro.serving.blockpool import BlockAllocator, Layout  # noqa: F401

_SENTINEL = 1717


def batch_axes(cfg: ModelConfig) -> Any:
    """Pytree (cache structure) of the batch-dim index per leaf — the
    legacy dense axes map, still used for slot-shaped side caches (the
    encdec cross cache) and by the state pool's axis convention."""
    spec = cache_spec(cfg, _SENTINEL, _SENTINEL + 1)

    def axis_of(s: jax.ShapeDtypeStruct) -> int:
        idx = [i for i, d in enumerate(s.shape) if d == _SENTINEL]
        assert len(idx) == 1, f"ambiguous batch axis in {s.shape}"
        return idx[0]

    return jax.tree_util.tree_map(axis_of, spec)


def gather_slots(pool: Any, axes: Any, slots: jax.Array) -> Any:
    """Dense slot gather over an explicit axes map (legacy helper)."""
    return jax.tree_util.tree_map(
        lambda a, ax: jnp.take(a, slots, axis=ax), pool, axes
    )


def scatter_slots(pool: Any, axes: Any, slots: jax.Array, update: Any) -> Any:
    """Dense slot scatter over an explicit axes map (legacy helper)."""

    def put(a, ax, u):
        idx = (slice(None),) * ax + (slots,)
        return a.at[idx].set(u.astype(a.dtype))

    return jax.tree_util.tree_map(put, pool, axes, update)


#: paged-aware entry points (slot + block-table addressing)
gather = blockpool.gather
scatter = blockpool.scatter

#: in-place paged forward entry points: slot leaves row-packed, paged
#: leaves passed whole (the forward writes them through the block table)
gather_mixed = blockpool.gather_mixed
scatter_mixed = blockpool.scatter_mixed


class CachePool:
    """Mutable host-side wrapper around the pooled cache pytree.

    ``num_slots`` slots of O(1)/ring state (+1 scratch) plus ``num_blocks``
    KV blocks of ``block_size`` tokens (+ null + scratch blocks).  The
    default pool size matches the dense manager's footprint exactly —
    ``num_slots * ceil(capacity / block_size)`` blocks — so existing
    configurations keep their admission behaviour; production deployments
    size ``num_blocks`` to the HBM budget instead.
    """

    def __init__(
        self,
        cfg: ModelConfig,
        num_slots: int,
        capacity: int,
        *,
        block_size: int = blockpool.DEFAULT_BLOCK_SIZE,
        num_blocks: Optional[int] = None,
    ):
        self.cfg = cfg
        self.num_slots = num_slots
        self.capacity = capacity
        bpt = -(-capacity // block_size)
        if num_blocks is None:
            num_blocks = num_slots * bpt  # dense-parity HBM footprint
        self.layout = blockpool.build_layout(
            cfg, capacity, block_size, num_blocks
        )
        self.axes = batch_axes(cfg)  # legacy map (cross-cache scatter)
        self.data = blockpool.init_cache(cfg, self.layout, num_slots)
        self.alloc_blocks = BlockAllocator(num_blocks)
        self._free: List[int] = list(range(num_slots))

    # -- slots (O(1) state rows) ----------------------------------------

    @property
    def scratch_slot(self) -> int:
        return self.num_slots

    def alloc(self) -> int:
        return self._free.pop(0)

    def free(self, slot: int) -> None:
        # reset the slot's dense leaves so stale entries never mask in
        self.reset_slot(slot)
        self._free.append(slot)

    def reset_slot(self, slot: int) -> None:
        """Wipe a slot's dense leaves to pristine (recurrent state to
        zeros, ring positions to -1) without releasing it — a restore
        replay must start from exactly the state a fresh slot would have,
        not from the victim's stale post-speculation state."""
        self.data = blockpool.wipe_slot(self.data, self.layout, slot)

    def num_free(self) -> int:
        return len(self._free)

    # -- blocks ----------------------------------------------------------

    @property
    def paged(self) -> bool:
        return self.layout.has_paged

    @property
    def block_size(self) -> int:
        return self.layout.block_size

    @property
    def blocks_per_table(self) -> int:
        return self.layout.blocks_per_table

    def num_free_blocks(self) -> int:
        return self.alloc_blocks.num_free()

    def free_blocks(self, bids: List[int]) -> None:
        """Wipe + return zero-ref, uncached blocks to the free list."""
        if not bids:
            return
        self.data = blockpool.wipe_blocks(self.data, self.layout, bids)
        for bid in bids:
            self.alloc_blocks.release(bid)

    def table_array(self, blocks_list: Sequence[Sequence[int]]) -> jax.Array:
        """(B, blocks_per_table) int32 tables, ``-1``-padded."""
        nblk = self.layout.blocks_per_table
        rows = []
        for blocks in blocks_list:
            assert len(blocks) <= nblk, "block table exceeds view capacity"
            rows.append(list(blocks) + [-1] * (nblk - len(blocks)))
        return jnp.array(rows, jnp.int32)
