"""Slot-pool cache manager.

The pool holds per-request recurrent state (attention KV ring buffers /
ssm states / rwkv states — whatever ``models.transformer.cache_spec``
says the architecture needs) for ``num_slots`` concurrent requests plus one
*scratch slot* used as the write target for padding rows in grouped
verification (so fixed-shape verify passes never corrupt a live request).

``gather(slots)`` / ``scatter(slots, cache)`` convert between the pool
layout and per-step batched caches; batch axes differ per leaf (layer-
stacked leaves carry the batch at axis 1), so the axis map is derived once
from a sentinel-sized spec.
"""

from __future__ import annotations

from typing import Any, List

import jax
import jax.numpy as jnp

from repro.models.base import ModelConfig
from repro.models.transformer import cache_spec, init_cache


_SENTINEL = 1717


def batch_axes(cfg: ModelConfig) -> Any:
    """Pytree (cache structure) of the batch-dim index per leaf."""
    spec = cache_spec(cfg, _SENTINEL, _SENTINEL + 1)

    def axis_of(s: jax.ShapeDtypeStruct) -> int:
        idx = [i for i, d in enumerate(s.shape) if d == _SENTINEL]
        assert len(idx) == 1, f"ambiguous batch axis in {s.shape}"
        return idx[0]

    return jax.tree_util.tree_map(axis_of, spec)


def gather(pool: Any, axes: Any, slots: jax.Array) -> Any:
    return jax.tree_util.tree_map(
        lambda a, ax: jnp.take(a, slots, axis=ax), pool, axes
    )


def scatter(pool: Any, axes: Any, slots: jax.Array, update: Any) -> Any:
    def put(a, ax, u):
        idx = (slice(None),) * ax + (slots,)
        return a.at[idx].set(u.astype(a.dtype))

    return jax.tree_util.tree_map(put, pool, axes, update)


class CachePool:
    """Mutable host-side wrapper around the pooled cache pytree."""

    def __init__(self, cfg: ModelConfig, num_slots: int, capacity: int):
        self.cfg = cfg
        self.num_slots = num_slots
        self.capacity = capacity
        self.axes = batch_axes(cfg)
        # +1 scratch slot for grouped-verification padding rows
        self.data = init_cache(cfg, num_slots + 1, capacity)
        self._free: List[int] = list(range(num_slots))

    @property
    def scratch_slot(self) -> int:
        return self.num_slots

    def alloc(self) -> int:
        return self._free.pop(0)

    def free(self, slot: int) -> None:
        # reset the slot's position book-keeping so stale entries never mask in
        def wipe(a, ax):
            idx = (slice(None),) * ax + (slot,)
            if a.dtype == jnp.int32:
                return a.at[idx].set(-1)
            return a.at[idx].set(jnp.zeros_like(a[idx]))

        self.data = jax.tree_util.tree_map(wipe, self.data, self.axes)
        self._free.append(slot)

    def num_free(self) -> int:
        return len(self._free)
