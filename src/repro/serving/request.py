"""Request lifecycle for the serving engine.

The paper's per-request determinism control (O4) is the
``SamplingParams.is_deterministic`` flag: deterministic requests go through
the decode-verify-rollback protocol; everything else streams straight from
the fast path with zero overhead.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import List, Optional


class State(enum.Enum):
    QUEUED = "queued"
    PREFILLING = "prefilling"  # admitted; prompt advancing chunk by chunk
    RUNNING = "running"  # decoding (candidates may be outstanding)
    AWAITING_VERIFY = "awaiting_verify"  # candidate window full, needs verify
    PREEMPTED = "preempted"  # KV blocks evicted; committed stream retained
    FINISHED = "finished"


@dataclasses.dataclass
class InflightVerify:
    """A verification window submitted to the device but not yet applied.

    Requests hold a FIFO of these (``Request.pipeline``): the scheduler may
    keep a request speculating — and keep *submitting further windows* —
    while earlier windows are outstanding, up to the engine's
    ``spec_depth``.  ``core.pipeline`` owns the in-order splice / cascade
    semantics.  ``n_match``/``commit_tok`` are filled in when the device
    pass completes (< 0 means still pending from the protocol's view — the
    discrete-event engine computes them eagerly but *applies* them at
    ``ready_at`` to model verification latency).

    ``submitted_at``/``ready_at`` are continuous stream-clock times
    (``serving.streams``): seconds under a costed clock, iteration ticks
    under the deprecated logical shim.  The verdict lands at the first
    iteration whose main-stream clock reaches ``ready_at`` — and only once
    every earlier window of the same request has spliced."""

    cands: List[int]
    submitted_at: float
    ready_at: float
    n_match: int = -1
    commit_tok: int = -1
    #: token the window's replay re-consumed first: the previous in-flight
    #: window's last candidate (chained) or ``committed[-1]`` (anchored)
    cond_tok: int = -1
    #: state-pool ring buffer holding this window's rollback checkpoint
    ring_idx: int = 0
    #: candidates popped off the front by predecessor splices (front
    #: normalization): they were ACCEPTED — committed as the predecessor's
    #: commit token — so acceptance telemetry must count them even though
    #: ``cands``/``n_match`` no longer do
    shifted: int = 0
    #: per-request window submission sequence number (``Request.window_seq``
    #: at submit) — the audit log's window id
    seq: int = -1
    #: verifier top-1/top-2 logit margins per window position, parallel to
    #: ``cands`` + the commit token (audit provenance; filled only when an
    #: audit log is attached, and popped alongside ``cands`` by front
    #: normalization so the alignment survives shifts)
    margins: Optional[List[float]] = None


@dataclasses.dataclass
class SamplingParams:
    temperature: float = 0.0  # 0 => greedy (argmax, first-max tiebreak)
    top_k: int = 0  # 0 => no truncation; deterministic for fixed k
    seed: int = 42
    max_new_tokens: int = 64
    is_deterministic: bool = False  # the paper's new API flag; default False
    eos_id: Optional[int] = None


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    sampling: SamplingParams = dataclasses.field(default_factory=SamplingParams)
    arrival_time: float = 0.0

    # --- runtime state (engine-managed) ---
    state: State = State.QUEUED
    slot: int = -1
    # paged-KV block table (serving.blockpool): block j holds this
    # request's full-attention KV for absolute positions
    # [j * block_size, (j+1) * block_size).  The first ``blocks_shared``
    # entries are read-only prefix-cache blocks (refcounted, never written
    # — all writes land past the committed-prefix match by construction).
    blocks: List[int] = dataclasses.field(default_factory=list)
    blocks_shared: int = 0
    # chunk-resumable prefill progress (chunked-prefill lane): positions
    # [0, prefill_pos) of the input sequence (prefix embeds + prompt) are
    # already written into the cache; prefill_total is the full length.
    # ``prefill_stream`` is the token stream the lane feeds: the prompt
    # on admission, prompt + committed[:-1] on a post-preemption restore
    # replay (``replaying`` skips the T0 sample — T0 is already committed).
    prefill_pos: int = 0
    prefill_total: int = 0
    prefill_stream: Optional[List[int]] = None
    replaying: bool = False
    committed: List[int] = dataclasses.field(default_factory=list)
    candidates: List[int] = dataclasses.field(default_factory=list)
    # FIFO of windows submitted for verification while decoding continues
    # (core.pipeline owns in-order splicing and cascade invalidation)
    pipeline: List[InflightVerify] = dataclasses.field(default_factory=list)
    # monotone per-request window counter (state-pool ring indexing)
    window_seq: int = 0
    # acceptance telemetry: EMA of per-verdict acceptance fraction
    # (n_match / candidates submitted), updated by core.dvr on every
    # verdict.  Starts optimistic; AdaptivePolicy reads it to demote
    # high-flip requests to pause-style verification (and promote back).
    accept_ema: float = 1.0
    # preemption / memory-pressure bookkeeping (serving.blockpool lane):
    # last_sched drives the LRU victim choice; preempt_iter / restore_iter
    # feed the anti-thrash hysteresis in scheduler.BlockMemoryPolicy
    last_sched: int = 0
    preempt_iter: int = -(10 ** 9)
    restore_iter: int = -(10 ** 9)
    num_preemptions: int = 0
    num_preempted_tokens: int = 0  # speculation dropped at preemption
    cached_prefix_tokens: int = 0  # prompt tokens served by the prefix cache
    # stats
    num_rollbacks: int = 0
    num_recomputed_tokens: int = 0
    num_verify_passes: int = 0
    num_cascaded_windows: int = 0  # windows discarded by cascade rollbacks
    prefill_time: float = -1.0
    finish_time: float = -1.0
    # stream-clock latency marks (obs.metrics TTFT/TPOT/e2e histograms):
    # set at submit / first committed token, read at retirement
    submit_clock: float = -1.0
    first_token_clock: float = -1.0
    # encdec / multimodal payloads (stub-frontend outputs)
    enc_embeds: Optional[object] = None
    prefix_embeds: Optional[object] = None

    @property
    def prompt_len(self) -> int:
        return len(self.prompt)

    @property
    def prefill_remaining(self) -> int:
        """Input positions still to be written (0 once prefill completes)."""
        return max(0, self.prefill_total - self.prefill_pos)

    @property
    def num_output(self) -> int:
        """Committed output length (what the user has received)."""
        return len(self.committed)

    @property
    def inflight_cands(self) -> List[int]:
        """All in-flight window candidates, submission (= sequence) order."""
        return [t for fl in self.pipeline for t in fl.cands]

    @property
    def speculation(self) -> List[int]:
        """All uncommitted tokens in sequence order (in-flight FIFO first)."""
        return self.inflight_cands + self.candidates

    @property
    def total_generated(self) -> int:
        return len(self.committed) + len(self.inflight_cands) + len(self.candidates)

    def done_decoding(self) -> bool:
        """All tokens generated (committed + speculation reach the budget)."""
        if self.total_generated >= self.sampling.max_new_tokens:
            return True
        eos = self.sampling.eos_id
        if eos is not None and (
            eos in self.committed
            or eos in self.candidates
            or eos in self.inflight_cands
        ):
            return True
        return False

    def finished(self) -> bool:
        if self.num_output >= self.sampling.max_new_tokens:
            return True
        eos = self.sampling.eos_id
        if eos is not None and eos in self.committed:
            return True
        return False
