"""Slot-indexed, double-buffered device state pool (speculation tentpole).

Attention KV rollback is *pointer-free*: the verifier's replay overwrites
the window's cache entries positionally and anything past the commit point
is shadowed by the position mask until the resumed decode rewrites it.
Recurrent state (mamba conv/ssm, rwkv shift/wkv) has no positions to hide
behind — the fast path advances one O(1) state per slot irreversibly, which
is what used to cap ssm/hybrid archs at a single in-flight verify window:
the verify pass had to scatter its commit-point state straight into the
live pool, so decoding past a submitted window would have read state the
verifier was about to replace.

This module lifts that cap with per-slot *double buffering*:

* the **live** state stays in the engine's main cache pool and is advanced
  only by the fast path (decode / prefill) — verification never writes it
  at launch time;
* the **anchor** buffer holds, per slot, the state the *next* submitted
  verify window's replay starts from (state after all speculation that
  precedes the window, minus the window's conditioning token — the replay
  re-consumes that token, exactly the commit-checkpoint convention).  With
  no windows in flight the anchor IS the commit-point state, so sync
  (pause-style) verification reads the same buffer;
* a **ring** of ``depth`` checkpoint buffers holds one snapshot per
  in-flight window: the per-position replay state selected at the window's
  commit index (``per_pos[n_match]``).  When the window's verdict splices
  with a rollback — or leaves the request with no surviving speculation —
  the engine restores the live pool (and the anchor) from the window's
  ring entry, so depth is bounded by the ring, not by the protocol.

For attention-only archs there is no device state to buffer; the pool
degrades to host-side KV-length / pipeline-depth accounting (telemetry the
benchmarks and ``serve.py`` report).

State trees
-----------

All device buffers here are *state trees*: pytrees mirroring the cache
structure (``models.transformer.cache_spec``) with recurrent leaves
materialized and attention/cross leaves replaced by ``None`` (an empty
pytree node, so jit boundaries stay clean).  ``blocks`` leaves carry the
slot axis at 1 (layer-stacked), ``head_layers`` leaves at 0 — the same
convention as ``kv_cache.batch_axes``.
"""

from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.models.base import ModelConfig
from repro.models.transformer import cache_spec

#: leaf names of recurrent (O(1), position-free) cache state
RECURRENT_KEYS = frozenset({"conv", "ssm", "tm_shift", "cm_shift", "wkv"})


def has_recurrent_state(cfg: ModelConfig) -> bool:
    return cfg.family in ("ssm", "hybrid")


# ---------------------------------------------------------------------------
# state-tree structure helpers
# ---------------------------------------------------------------------------


def _kind(sub: Any) -> str:
    """Classify a cache/per-pos subtree: ``"state"`` (a recurrent leaf
    dict), ``"skip"`` (an attention/cross leaf dict, or a placeholder —
    scalar ``0.0``, a scan-stacked array of them, or ``None``), or
    ``"recurse"`` (structural nesting).  The single source of truth every
    tree walker here dispatches on."""
    if not isinstance(sub, dict):
        return "skip"
    if set(sub) & RECURRENT_KEYS:
        return "state"
    if "k" in sub or "mask" in sub:  # attention / cross leaves
        return "skip"
    return "recurse"


def _filter_spec(sub: Any) -> Any:
    """Keep recurrent leaf dicts, replace attention-layer dicts by None."""
    kind = _kind(sub)
    if kind == "skip":
        return None
    if kind == "state":
        return dict(sub)
    return {k: _filter_spec(v) for k, v in sub.items()}


def state_spec(cfg: ModelConfig, batch: int) -> Dict[str, Any]:
    """ShapeDtypeStruct state tree for ``batch`` slots (no capacity axis —
    recurrent state is O(1) per slot; the attention capacity argument below
    only shapes leaves we immediately drop)."""
    spec = cache_spec(cfg, batch, capacity=8)
    return {
        top: _filter_spec(spec[top])
        for top in ("blocks", "head_layers")
        if top in spec
    }


def init_state(cfg: ModelConfig, batch: int) -> Dict[str, Any]:
    return jax.tree_util.tree_map(
        lambda s: jnp.zeros(s.shape, s.dtype), state_spec(cfg, batch)
    )


def _rec(fn, subs: Sequence[Any], ax: int) -> Any:
    s0 = subs[0]
    kind = _kind(s0)
    if kind == "skip":
        return s0  # placeholder passes through
    if kind == "state":
        return {k: fn([s[k] for s in subs], ax) for k in s0}
    return {k: _rec(fn, [s[k] for s in subs], ax) for k in s0}


def _map_state(fn, *trees: Any) -> Dict[str, Any]:
    """Apply ``fn(leaves, b_axis)`` at every recurrent leaf of congruent
    state trees (``blocks`` slot axis 1, ``head_layers`` axis 0)."""
    first = trees[0]
    out: Dict[str, Any] = {}
    for top, ax in (("blocks", 1), ("head_layers", 0)):
        if top in first:
            out[top] = _rec(fn, [t[top] for t in trees], ax)
    return out


def gather_rows(state: Dict[str, Any], slots: jax.Array) -> Dict[str, Any]:
    """Batched rows (slot axis -> len(slots)) from a slot-indexed tree."""
    return _map_state(
        lambda ls, ax: jnp.take(ls[0], slots, axis=ax), state
    )


def scatter_rows(
    state: Dict[str, Any], slots: jax.Array, rows: Dict[str, Any]
) -> Dict[str, Any]:
    """Write batched rows back into a slot-indexed tree."""

    def put(ls, ax):
        a, u = ls
        idx = (slice(None),) * ax + (slots,)
        return a.at[idx].set(u.astype(a.dtype))

    return _map_state(put, state, rows)


def rows_from_cache(cache: Dict[str, Any], slots: Optional[jax.Array] = None
                    ) -> Dict[str, Any]:
    """Extract the recurrent leaves of a (pool- or batch-shaped) cache tree
    as a state tree; gathers at ``slots`` when given."""

    def take(sub: Any) -> Any:
        kind = _kind(sub)
        if kind == "skip":
            return None
        if kind == "state":
            return {k: sub[k] for k in sub if k in RECURRENT_KEYS}
        return {k: take(v) for k, v in sub.items()}

    tree = {
        top: take(cache[top])
        for top in ("blocks", "head_layers")
        if top in cache
    }
    if slots is None:
        return tree
    return gather_rows(tree, slots)


def merge_rows(cache: Dict[str, Any], rows: Dict[str, Any]) -> Dict[str, Any]:
    """Return ``cache`` with its recurrent leaves replaced by ``rows``
    (batch-shaped); attention/cross leaves pass through untouched."""

    def merge(c: Any, r: Any) -> Any:
        if r is None:
            return c
        if isinstance(r, dict):
            return {k: (merge(c[k], r[k]) if k in r else c[k]) for k in c}
        return r.astype(c.dtype)

    out = dict(cache)
    for top in ("blocks", "head_layers"):
        if top in rows and top in cache:
            out[top] = merge(cache[top], rows[top])
    return out


def select_index(per_pos: Any, idx: jax.Array) -> Dict[str, Any]:
    """Pick, per row, the per-position replay state at ``idx`` (shape (G,)).

    ``per_pos`` is ``forward(collect_states=True)``'s output: recurrent
    leaves carry an extra window axis right after the batch axis
    (``blocks``: (L, B, W, *rest); ``head_layers``: (B, W, *rest));
    attention layers hold a scalar placeholder, emitted here as ``None``.
    ``per_pos[j]`` is the state *after consuming* window input ``j``.
    """

    def pick(pp, ax):
        if ax == 0:
            return jax.vmap(lambda row, n: row[n], (0, 0), 0)(pp, idx)
        return jax.vmap(lambda row, n: row[:, n], (1, 0), 1)(pp, idx)

    def walk(sub: Any, ax: int) -> Any:
        kind = _kind(sub)
        if kind == "skip":
            # attention-layer placeholder: a scalar 0.0, or a scan-stacked
            # array of them inside the block stack — either way, no state
            return None
        if kind == "state":
            return {k: pick(v, ax) for k, v in sub.items()}
        return {k: walk(v, ax) for k, v in sub.items()}

    return {
        top: walk(per_pos[top], ax)
        for top, ax in (("blocks", 1), ("head_layers", 0))
        if top in per_pos
    }


def scatter_into_cache(
    cache: Dict[str, Any], slots: jax.Array, rows: Dict[str, Any]
) -> Dict[str, Any]:
    """Write state-tree rows into the *full* cache pool at ``slots`` —
    the live-state restore used on rollback splices."""

    def put(c: Any, r: Any, ax: int) -> Any:
        if r is None:
            return c
        if isinstance(r, dict):
            return {k: (put(c[k], r[k], ax) if k in r else c[k]) for k in c}
        idx = (slice(None),) * ax + (slots,)
        return c.at[idx].set(r.astype(c.dtype))

    out = dict(cache)
    for top, ax in (("blocks", 1), ("head_layers", 0)):
        if top in rows and top in cache:
            out[top] = put(cache[top], rows[top], ax)
    return out


# ---------------------------------------------------------------------------
# the pool
# ---------------------------------------------------------------------------


class StatePool:
    """Double-buffered per-slot state checkpoints + depth/extent accounting.

    ``active`` (recurrent/hybrid archs) means device buffers exist; for
    attention-only archs every device method is a no-op and only the host
    accounting (in-flight depth, speculative KV extent) is live.
    """

    def __init__(self, cfg: ModelConfig, num_slots: int, depth: int = 1):
        assert depth >= 1, "the ring needs at least one checkpoint buffer"
        self.cfg = cfg
        self.depth = depth
        self.num_slots = num_slots
        self.active = has_recurrent_state(cfg)
        # +1 scratch row so grouped-verification padding rows have a target
        if self.active:
            self.anchor = init_state(cfg, num_slots + 1)
            self.ring: List[Dict[str, Any]] = [
                init_state(cfg, num_slots + 1) for _ in range(depth)
            ]
        else:
            self.anchor = None
            self.ring = []
        # host accounting (all archs): per-slot in-flight windows + peaks
        self._inflight: Dict[int, int] = {}
        self.peak_depth = 0
        self.peak_extent = 0
        self.num_preempts = 0  # slots whose buffers went stale to eviction

    # -- host accounting ------------------------------------------------

    def note_submit(self, slot: int, extent: int) -> int:
        """Record one submitted window; returns the slot's new depth."""
        d = self._inflight.get(slot, 0) + 1
        self._inflight[slot] = d
        self.peak_depth = max(self.peak_depth, d)
        self.peak_extent = max(self.peak_extent, extent)
        return d

    def note_splice(self, slot: int, flushed: int = 0) -> None:
        """One verdict spliced (plus ``flushed`` cascade-discarded ones)."""
        d = self._inflight.get(slot, 0) - 1 - flushed
        if d > 0:
            self._inflight[slot] = d
        else:
            self._inflight.pop(slot, None)

    def note_release(self, slot: int) -> None:
        self._inflight.pop(slot, None)

    def note_preempt(self, slot: int) -> None:
        """Preemption (paged-KV lane): the slot's in-flight accounting
        drops — its verdicts were flushed at eviction.  The slot itself
        SURVIVES preemption (recurrent rows are O(1); the memory being
        reclaimed is KV blocks), but its device buffers here (anchor +
        ring) go stale the moment the engine wipes the slot's live state
        for the restore replay: nothing is copied out, because the replay
        rebuilds the anchor exactly — ``set_commit_point`` at replay end
        is the state after ``committed[:-1]``, which is bitwise the
        anchor an un-preempted run would hold (the replay feeds only
        committed tokens through the same fixed schedule)."""
        self._inflight.pop(slot, None)
        self.num_preempts += 1

    def depth_of(self, slot: int) -> int:
        return self._inflight.get(slot, 0)

    # -- device buffers --------------------------------------------------

    def set_commit_point(self, pool_data: Dict[str, Any], slot: int) -> None:
        """Anchor <- the slot's live state (prefill end: the state after the
        full prompt is the first replay anchor / commit checkpoint)."""
        if not self.active:
            return
        slots = jnp.array([slot], jnp.int32)
        rows = rows_from_cache(pool_data, slots)
        self.anchor = scatter_rows(self.anchor, slots, rows)

    def checkpoint(
        self, ring_idxs: Sequence[int], slots: Sequence[int], rows: Any
    ) -> None:
        """Store each row's commit-index state in its window's ring buffer
        (rows batched as returned by the verify pass; grouped per ring
        index so co-launched windows of different requests coexist)."""
        if not self.active or rows is None:
            return
        for d in sorted(set(ring_idxs)):
            sel = [i for i, x in enumerate(ring_idxs) if x == d]
            idx = jnp.array(sel, jnp.int32)
            sub = _map_state(lambda ls, ax: jnp.take(ls[0], idx, axis=ax), rows)
            self.ring[d] = scatter_rows(
                self.ring[d], jnp.array([slots[i] for i in sel], jnp.int32), sub
            )

    def reanchor(self, slot: int, ring_idx: int) -> None:
        """Replay anchor <- the window's checkpointed commit state.  Needed
        whenever the in-flight FIFO drains: the next window launches
        anchored on ``committed[-1]`` (whose replay starts one token LATER
        than the chained start state the last launch left in the anchor)."""
        if not self.active:
            return
        slots = jnp.array([slot], jnp.int32)
        rows = gather_rows(self.ring[ring_idx], slots)
        self.anchor = scatter_rows(self.anchor, slots, rows)

    def restore(
        self, pool_data: Dict[str, Any], slot: int, ring_idx: int
    ) -> Dict[str, Any]:
        """Rollback (or drained-speculation) restore: live pool state and
        the anchor both return to the window's checkpointed commit state.
        Returns the updated pool tree."""
        if not self.active:
            return pool_data
        slots = jnp.array([slot], jnp.int32)
        rows = gather_rows(self.ring[ring_idx], slots)
        self.anchor = scatter_rows(self.anchor, slots, rows)
        return scatter_into_cache(pool_data, slots, rows)
