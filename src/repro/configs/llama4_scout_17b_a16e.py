"""llama4-scout-17b-a16e [moe] — MoE, early fusion
[hf:meta-llama/Llama-4-Scout-17B-16E].

16 experts, top-1 routing, every layer MoE.  The long-context variant uses
chunked-local (iRoPE-style) attention modeled as a sliding window of 8192.
Early-fusion multimodality: text-only backbone here; image tokens would
arrive as prefix embeddings (same stub path as llava).
"""
import dataclasses

from repro.models.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llama4-scout-17b-a16e",
        family="moe",
        num_layers=48,
        d_model=5120,
        num_heads=40,
        num_kv_heads=8,
        d_ff=8192,
        moe_d_ff=8192,
        vocab_size=202048,
        head_dim=128,
        num_experts=16,
        top_k=1,
        rope_theta=500000.0,
        tie_embeddings=False,
        max_seq_len=32768 + 128,
        dtype="bfloat16",
        source="hf:meta-llama/Llama-4-Scout-17B-16E",
    )


def long_config() -> ModelConfig:
    return dataclasses.replace(
        config(), name="llama4-scout-chunked8k", attn_kind="sliding",
        window=8192, max_seq_len=524288 + 128,
    )


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), name="llama4-scout-smoke", num_layers=2, d_model=256,
        num_heads=8, num_kv_heads=2, head_dim=32, d_ff=256, moe_d_ff=256,
        vocab_size=512, num_experts=4, top_k=1, max_seq_len=512,
        dtype="float32",
    )
