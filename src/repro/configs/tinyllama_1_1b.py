"""tinyllama-1.1b [dense] — llama2-arch small [arXiv:2401.02385]."""
import dataclasses

from repro.models.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="tinyllama-1.1b",
        family="dense",
        num_layers=22,
        d_model=2048,
        num_heads=32,
        num_kv_heads=4,
        d_ff=5632,
        vocab_size=32000,
        head_dim=64,
        rope_theta=10000.0,
        tie_embeddings=False,
        max_seq_len=32768 + 128,
        dtype="bfloat16",
        source="arXiv:2401.02385 (TinyLlama), llama2 architecture",
    )


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), name="tinyllama-smoke", num_layers=2, d_model=256,
        num_heads=8, num_kv_heads=2, head_dim=32, d_ff=512, vocab_size=512,
        max_seq_len=512, dtype="float32",
    )
