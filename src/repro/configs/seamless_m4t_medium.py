"""seamless-m4t-medium [audio] — enc-dec, multimodal [arXiv:2308.11596].

Speech encoder (w2v-BERT conv frontend) is stubbed: the encoder consumes
precomputed frame embeddings (models.multimodal.audio_frames).  The
12L encoder + 12L decoder transformer is fully implemented.
GQA kv=16 == num_heads: standard MHA.
"""
import dataclasses

from repro.models.base import ModelConfig

ENCODER_FRAMES = 1024  # ~20s speech after conv subsampling


def config() -> ModelConfig:
    return ModelConfig(
        name="seamless-m4t-medium",
        family="encdec",
        num_layers=12,
        num_encoder_layers=12,
        encoder_seq_len=ENCODER_FRAMES,
        d_model=1024,
        num_heads=16,
        num_kv_heads=16,
        d_ff=4096,
        vocab_size=256206,
        head_dim=64,
        rope_theta=10000.0,
        tie_embeddings=False,
        max_seq_len=32768 + 128,
        dtype="bfloat16",
        source="arXiv:2308.11596 (SeamlessM4T medium)",
    )


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), name="seamless-smoke", num_layers=2, num_encoder_layers=2,
        encoder_seq_len=32, d_model=256, num_heads=8, num_kv_heads=8,
        head_dim=32, d_ff=512, vocab_size=512, max_seq_len=512, dtype="float32",
    )
