"""rwkv6-3b [ssm] — Finch, data-dependent decay [arXiv:2404.05892].

Attention-free: O(1) recurrent state, so long_500k runs natively.
num_heads/num_kv_heads are nominal (d_model / rwkv_head_dim) — there is no
attention; they size the rwkv head reshape.
"""
import dataclasses

from repro.models.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-3b",
        family="ssm",
        num_layers=32,
        d_model=2560,
        num_heads=40,
        num_kv_heads=40,
        d_ff=8960,
        vocab_size=65536,
        rwkv_head_dim=64,
        tie_embeddings=False,
        max_seq_len=524288 + 128,
        dtype="bfloat16",
        source="arXiv:2404.05892 (RWKV-6 Finch)",
    )


def long_config() -> ModelConfig:
    return config()  # natively sub-quadratic


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), name="rwkv6-smoke", num_layers=2, d_model=256, num_heads=4,
        num_kv_heads=4, d_ff=512, vocab_size=512, rwkv_head_dim=64,
        max_seq_len=512, dtype="float32",
    )
