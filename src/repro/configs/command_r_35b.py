"""command-r-35b [dense] — GQA, no-bias [hf:CohereForAI/c4ai-command-r-v01].

Command-R ties input/output embeddings (model card)."""
import dataclasses

from repro.models.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="command-r-35b",
        family="dense",
        num_layers=40,
        d_model=8192,
        num_heads=64,
        num_kv_heads=8,
        d_ff=22528,
        vocab_size=256000,
        head_dim=128,
        rope_theta=8000000.0,
        use_bias=False,
        tie_embeddings=True,
        max_seq_len=32768 + 128,
        dtype="bfloat16",
        source="hf:CohereForAI/c4ai-command-r-v01",
    )


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), name="command-r-smoke", num_layers=2, d_model=512,
        num_heads=8, num_kv_heads=2, head_dim=64, d_ff=1024, vocab_size=512,
        max_seq_len=512, dtype="float32",
    )
