"""Assigned architecture configs (+ the paper's own Llama-3.1-8B).

Each module exposes ``config()`` (the exact assigned architecture) and
``smoke_config()`` (a reduced same-family variant: <=2-4 layers,
d_model<=512, <=4 experts) for CPU smoke tests.
"""

from __future__ import annotations

import importlib
from typing import List

from repro.models.base import ModelConfig

ARCH_IDS: List[str] = [
    "llava_next_mistral_7b",
    "kimi_k2_1t_a32b",
    "tinyllama_1_1b",
    "seamless_m4t_medium",
    "internlm2_20b",
    "command_r_35b",
    "llama4_scout_17b_a16e",
    "jamba_1_5_large_398b",
    "rwkv6_3b",
    "phi3_mini_3_8b",
]

#: canonical dashed ids (as assigned) -> module names
DASHED = {i.replace("_", "-"): i for i in ARCH_IDS}
DASHED["llava-next-mistral-7b"] = "llava_next_mistral_7b"
DASHED["kimi-k2-1t-a32b"] = "kimi_k2_1t_a32b"
DASHED["tinyllama-1.1b"] = "tinyllama_1_1b"
DASHED["seamless-m4t-medium"] = "seamless_m4t_medium"
DASHED["internlm2-20b"] = "internlm2_20b"
DASHED["command-r-35b"] = "command_r_35b"
DASHED["llama4-scout-17b-a16e"] = "llama4_scout_17b_a16e"
DASHED["jamba-1.5-large-398b"] = "jamba_1_5_large_398b"
DASHED["rwkv6-3b"] = "rwkv6_3b"
DASHED["phi3-mini-3.8b"] = "phi3_mini_3_8b"


def _module(name: str):
    key = DASHED.get(name, name).replace("-", "_").replace(".", "_")
    return importlib.import_module(f"repro.configs.{key}")


def get_config(name: str) -> ModelConfig:
    return _module(name).config()


def get_smoke_config(name: str) -> ModelConfig:
    return _module(name).smoke_config()


def get_long_config(name: str) -> ModelConfig:
    """Sub-quadratic variant for long_500k, or raise if unsupported."""
    mod = _module(name)
    if not hasattr(mod, "long_config"):
        raise ValueError(f"{name} has no sub-quadratic long-context variant")
    return mod.long_config()


def supports_long(name: str) -> bool:
    return hasattr(_module(name), "long_config")


def list_archs() -> List[str]:
    return list(ARCH_IDS)
