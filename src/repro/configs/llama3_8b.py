"""llama3-8b — the paper's evaluation model (Llama-3.1-8B-Instruct).

Not part of the assigned-architecture pool; included for paper-parity
experiments (Figs. 4-6, 9-12 reproduce against this architecture family).
"""
import dataclasses

from repro.models.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llama3-8b",
        family="dense",
        num_layers=32,
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        d_ff=14336,
        vocab_size=128256,
        head_dim=128,
        rope_theta=500000.0,
        tie_embeddings=False,
        max_seq_len=32768 + 128,
        dtype="bfloat16",
        source="meta-llama/Llama-3.1-8B-Instruct (paper's model)",
    )


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), name="llama3-smoke", num_layers=2, d_model=256, num_heads=8,
        num_kv_heads=2, head_dim=32, d_ff=512, vocab_size=512, max_seq_len=512, dtype="float32",
    )
