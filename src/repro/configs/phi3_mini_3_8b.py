"""phi3-mini-3.8b [dense] — RoPE SwiGLU GQA [arXiv:2404.14219].

kv=32 == num_heads: phi-3-mini is effectively MHA.  long_500k uses the
sliding-window variant (phi-3 natively uses a 2047-token sliding window in
the 4k variant; LongRoPE variants extend context — we model long context
with SW attention, window 4096, per DESIGN.md).
"""
import dataclasses

from repro.models.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="phi3-mini-3.8b",
        family="dense",
        num_layers=32,
        d_model=3072,
        num_heads=32,
        num_kv_heads=32,
        d_ff=8192,
        vocab_size=32064,
        head_dim=96,
        rope_theta=10000.0,
        tie_embeddings=False,
        max_seq_len=32768 + 128,
        dtype="bfloat16",
        source="arXiv:2404.14219 (Phi-3)",
    )


def long_config() -> ModelConfig:
    return dataclasses.replace(
        config(), name="phi3-mini-3.8b-sw4k", attn_kind="sliding", window=4096,
        max_seq_len=524288 + 128,
    )


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), name="phi3-smoke", num_layers=2, d_model=256, num_heads=8,
        num_kv_heads=8, head_dim=32, d_ff=512, vocab_size=512, max_seq_len=512, dtype="float32",
    )
