"""kimi-k2-1t-a32b [moe] — trillion-param MoE [arXiv:2501.kimi2].

61L, d_model=7168, 64H (GQA kv=8), per-expert d_ff=2048, 384 experts top-8,
first layer dense (DeepSeek-V3-style first_k_dense=1).
"""
import dataclasses

from repro.models.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="kimi-k2-1t-a32b",
        family="moe",
        num_layers=61,
        d_model=7168,
        num_heads=64,
        num_kv_heads=8,
        d_ff=2048,
        moe_d_ff=2048,
        vocab_size=163840,
        head_dim=112,
        num_experts=384,
        top_k=8,
        first_k_dense=1,
        rope_theta=50000.0,
        tie_embeddings=False,
        max_seq_len=32768 + 128,
        dtype="bfloat16",
        source="arXiv:2501.kimi2 (Kimi K2, paper-table config)",
    )


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), name="kimi-k2-smoke", num_layers=2, d_model=256, num_heads=8,
        num_kv_heads=2, head_dim=32, d_ff=256, moe_d_ff=256, vocab_size=512,
        num_experts=4, top_k=2, first_k_dense=1, max_seq_len=512,
        dtype="float32",
    )
