"""llava-next-mistral-7b [vlm] — anyres tiling
[hf:llava-hf/llava-v1.6-mistral-7b-hf].

The Mistral-7B language backbone is fully implemented; the vision tower
(CLIP ViT-L/336) + projector is stubbed per assignment:
``models.multimodal.vision_embeds`` provides 576*(1+4)=2880 patch-token
embeddings (anyres: base image + 4 tiles) prepended to the prompt.
"""
import dataclasses

from repro.models.base import ModelConfig
from repro.models.multimodal import num_vision_tokens


def config() -> ModelConfig:
    return ModelConfig(
        name="llava-next-mistral-7b",
        family="dense",
        num_layers=32,
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        d_ff=14336,
        vocab_size=32000,
        head_dim=128,
        rope_theta=1000000.0,
        tie_embeddings=False,
        num_prefix_embeds=num_vision_tokens(),  # 2880 anyres patch tokens
        max_seq_len=32768 + 128,
        dtype="bfloat16",
        source="hf:llava-hf/llava-v1.6-mistral-7b-hf (Mistral-7B backbone)",
    )


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), name="llava-next-smoke", num_layers=2, d_model=256,
        num_heads=8, num_kv_heads=2, head_dim=32, d_ff=512, vocab_size=512,
        num_prefix_embeds=16, max_seq_len=512, dtype="float32",
    )
