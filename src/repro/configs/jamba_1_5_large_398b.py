"""jamba-1.5-large-398b [hybrid] — Mamba+attn 1:7 interleave, MoE
[arXiv:2403.19887].

72 layers; attention every 8th layer (1 attn : 7 mamba); MoE FFN on every
other layer (16 experts, top-2).  long_500k runs with the attention layers
bounded by a 4096 sliding window (mamba layers are O(1)-state already).
"""
import dataclasses

from repro.models.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="jamba-1.5-large-398b",
        family="hybrid",
        num_layers=72,
        d_model=8192,
        num_heads=64,
        num_kv_heads=8,
        d_ff=24576,
        moe_d_ff=24576,
        vocab_size=65536,
        head_dim=128,
        num_experts=16,
        top_k=2,
        moe_every=2,
        moe_offset=1,
        attn_every=8,
        d_state=16,
        d_conv=4,
        expand=2,
        rope_theta=10000.0,
        tie_embeddings=False,
        max_seq_len=32768 + 128,
        dtype="bfloat16",
        source="arXiv:2403.19887 (Jamba-1.5)",
    )


def long_config() -> ModelConfig:
    return dataclasses.replace(
        config(), name="jamba-1.5-large-sw4k", attn_kind="sliding", window=4096,
        max_seq_len=524288 + 128,
    )


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), name="jamba-smoke", num_layers=4, d_model=256, num_heads=8,
        num_kv_heads=2, head_dim=32, d_ff=512, moe_d_ff=512, vocab_size=512,
        num_experts=4, top_k=2, moe_every=2, moe_offset=1, attn_every=2,
        d_state=8, d_conv=4, expand=2, max_seq_len=512, dtype="float32",
    )
