"""internlm2-20b [dense] — GQA [arXiv:2403.17297]."""
import dataclasses

from repro.models.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="internlm2-20b",
        family="dense",
        num_layers=48,
        d_model=6144,
        num_heads=48,
        num_kv_heads=8,
        d_ff=16384,
        vocab_size=92544,
        head_dim=128,
        rope_theta=1000000.0,
        tie_embeddings=False,
        max_seq_len=32768 + 128,
        dtype="bfloat16",
        source="arXiv:2403.17297 (InternLM2)",
    )


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), name="internlm2-smoke", num_layers=2, d_model=384,
        num_heads=6, num_kv_heads=2, head_dim=64, d_ff=768, vocab_size=512,
        max_seq_len=512, dtype="float32",
    )
