"""Shared layers: RMSNorm, RoPE, GQA attention (cached + train), SwiGLU, MoE.

Every matrix multiply routes through ``repro.core.determinism.matmul`` with an
explicit ``Schedule``, so the reduction tree of the entire forward pass is a
function of the schedule — which the fast path derives from the dynamic batch
size (the paper's non-determinism mechanism) and the verifier pins.

Cached attention uses a uniform cache layout:
    {"k": (B, C, KV, HD), "v": (B, C, KV, HD), "pos": (B, C) int32}
where C is the cache capacity (max_seq_len for full attention, the window
size for sliding-window attention — a ring buffer).  ``pos`` records the
absolute position held in each slot (-1 = empty); masking is computed from
``pos`` so ring-buffer wraparound needs no special cases.
"""

from __future__ import annotations

from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core.determinism import Schedule, matmul, segment_reduce_sum
from repro.kernels import ops

F32 = jnp.float32


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def rms_norm(x: jax.Array, scale: jax.Array, eps: float, schedule: Schedule) -> jax.Array:
    """RMSNorm with a schedule-dependent feature reduction (paper Fig. 4b)."""
    ss = segment_reduce_sum(x * x, axis=-1, schedule=schedule)
    var = ss / x.shape[-1]
    inv = jax.lax.rsqrt(var + eps)
    return (x.astype(F32) * inv[..., None]).astype(x.dtype) * scale


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Apply rotary embedding.  x: (..., T, H, D); positions: (..., T)."""
    d = x.shape[-1]
    half = d // 2
    freqs = jnp.exp(-jnp.arange(0, half, dtype=F32) * (jnp.log(theta) / half))
    ang = positions[..., None].astype(F32) * freqs  # (..., T, half)
    cos = jnp.cos(ang)[..., None, :]  # (..., T, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half].astype(F32), x[..., half : 2 * half].astype(F32)
    out1 = x1 * cos - x2 * sin
    out2 = x2 * cos + x1 * sin
    out = jnp.concatenate([out1, out2, x[..., 2 * half :].astype(F32)], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------


def _qkv(p: Dict, cfg, x: jax.Array, schedule: Schedule):
    """Project to q,k,v heads.  x: (B, T, D)."""
    B, T, _ = x.shape
    q = matmul(x, p["wq"], schedule)
    k = matmul(x, p["wk"], schedule)
    v = matmul(x, p["wv"], schedule)
    if cfg.use_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, T, cfg.num_heads, cfg.hd)
    k = k.reshape(B, T, cfg.num_kv_heads, cfg.hd)
    v = v.reshape(B, T, cfg.num_kv_heads, cfg.hd)
    return q, k, v


def _softmax_attend(
    q: jax.Array,  # (B, T, H, D) f32, pre-scaled
    k: jax.Array,  # (B, S, KV, D)
    v: jax.Array,  # (B, S, KV, D)
    mask: jax.Array,  # (B, T, S) bool or broadcastable
    schedule: Schedule,
    logit_softcap: float = 0.0,
) -> jax.Array:
    """GQA attention with schedule-dependent KV-split softmax combine.

    kv_splits == 1: single-pass softmax over the full key axis in f32 (the
    verifier's / batch-invariant schedule).  kv_splits == S: the key axis is
    chunked (FlashDecoding-style sequence parallelism); each chunk computes a
    local (max, exp-sum, weighted value) triple in f32, and chunk triples are
    combined *sequentially in combine_dtype* — a different reduction tree,
    hence potentially different low-order bits (paper §4.4 "Attention").
    """
    B, T, H, D = q.shape
    S = k.shape[1]
    KV = k.shape[2]
    G = H // KV  # query heads per kv head
    qg = q.reshape(B, T, KV, G, D).astype(F32)
    kf = k.astype(F32)
    vf = v.astype(F32)

    def scores_for(kc):  # kc: (B, Sc, KV, D) -> (B, T, KV, G, Sc)
        s = jnp.einsum("btkgd,bskd->btkgs", qg, kc, precision=jax.lax.Precision.HIGHEST)
        if logit_softcap > 0.0:
            s = jnp.tanh(s / logit_softcap) * logit_softcap
        return s

    splits = schedule.kv_splits
    if splits <= 1 or splits > S:
        s = scores_for(kf)
        s = jnp.where(mask[:, :, None, None, :], s, -jnp.inf)
        m = jnp.max(s, axis=-1, keepdims=True)
        m = jnp.maximum(m, -1e30)  # rows with no valid key
        e = jnp.exp(s - m)
        denom = jnp.sum(e, axis=-1)
        out = jnp.einsum("btkgs,bskd->btkgd", e, vf, precision=jax.lax.Precision.HIGHEST)
        out = out / jnp.maximum(denom, 1e-30)[..., None]
        return out.reshape(B, T, H, D)

    # chunked (split-KV) path
    cd = jnp.dtype(schedule.combine_dtype)
    base, rem = divmod(S, splits)
    sizes = [base + (1 if i < rem else 0) for i in range(splits)]
    m_acc = None  # (B,T,KV,G)
    d_acc = None
    o_acc = None  # (B,T,KV,G,D)
    start = 0
    for size in sizes:
        kc = jax.lax.slice_in_dim(kf, start, start + size, axis=1)
        vc = jax.lax.slice_in_dim(vf, start, start + size, axis=1)
        mc = jax.lax.slice_in_dim(mask, start, start + size, axis=2)
        s = scores_for(kc)
        s = jnp.where(mc[:, :, None, None, :], s, -jnp.inf)
        m_c = jnp.maximum(jnp.max(s, axis=-1), -1e30)
        e = jnp.exp(s - m_c[..., None])
        d_c = jnp.sum(e, axis=-1)
        o_c = jnp.einsum("btkgs,bskd->btkgd", e, vc, precision=jax.lax.Precision.HIGHEST)
        if m_acc is None:
            m_acc, d_acc, o_acc = m_c, d_c.astype(cd), o_c.astype(cd)
        else:
            m_new = jnp.maximum(m_acc, m_c)
            a1 = jnp.exp(m_acc - m_new)
            a2 = jnp.exp(m_c - m_new)
            d_acc = (a1 * d_acc.astype(F32) + a2 * d_c).astype(cd)
            o_acc = (
                a1[..., None] * o_acc.astype(F32) + a2[..., None] * o_c
            ).astype(cd)
            m_acc = m_new
        start += size
    out = o_acc.astype(F32) / jnp.maximum(d_acc.astype(F32), 1e-30)[..., None]
    return out.reshape(B, T, H, D)


#: above this many query rows, attention runs q-chunked (flash-style) so the
#: (B, T, S) score tensor is never materialized — essential for the 32k/4k
#: dry-run memory analysis and faithful to production TPU attention.
CHUNK_THRESHOLD = 2048
Q_CHUNK = 512


def _chunked_attend(
    q: jax.Array,  # (B, T, H, D) f32, pre-scaled + roped
    k: jax.Array,  # (B, S, KV, D)
    v: jax.Array,
    q_pos: jax.Array,  # (B, T) absolute positions
    k_pos: jax.Array,  # (B, S) absolute positions (-1 = invalid)
    schedule: Schedule,
    logit_softcap: float,
    window: int,
) -> jax.Array:
    """Query-chunked attention: lax.map over q chunks; per-chunk scores are
    (B, Q_CHUNK, S) — bounded VMEM/HBM footprint at any context length."""
    B, T, H, D = q.shape
    pad = (-T) % Q_CHUNK
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, ((0, 0), (0, pad)), constant_values=-(10**9))
    n_chunks = q.shape[1] // Q_CHUNK
    qc = q.reshape(B, n_chunks, Q_CHUNK, H, D).transpose(1, 0, 2, 3, 4)
    pc = q_pos.reshape(B, n_chunks, Q_CHUNK).transpose(1, 0, 2)

    def one(args):
        q_i, p_i = args  # (B, Qc, H, D), (B, Qc)
        mask = (k_pos[:, None, :] >= 0) & (k_pos[:, None, :] <= p_i[:, :, None])
        if window > 0:
            mask = mask & (k_pos[:, None, :] > p_i[:, :, None] - window)
        return _softmax_attend(q_i, k, v, mask, schedule, logit_softcap)

    out = jax.lax.map(one, (qc, pc))  # (n_chunks, B, Qc, H, D)
    out = out.transpose(1, 0, 2, 3, 4).reshape(B, -1, H, D)
    return out[:, :T]


def attention_train(
    p: Dict, cfg, x: jax.Array, schedule: Schedule, window: int = 0
) -> jax.Array:
    """Full-sequence causal attention (training / no cache).  x: (B, S, D)."""
    B, S, _ = x.shape
    q, k, v = _qkv(p, cfg, x, schedule)
    pos = jnp.arange(S)[None, :]
    q = rope(q, jnp.broadcast_to(pos, (B, S)), cfg.rope_theta)
    k = rope(k, jnp.broadcast_to(pos, (B, S)), cfg.rope_theta)
    q = q * (cfg.hd**-0.5)
    if S > CHUNK_THRESHOLD:
        pos_b = jnp.broadcast_to(pos, (B, S))
        out = _chunked_attend(
            q.astype(F32), k, v, pos_b, pos_b, schedule,
            cfg.logit_softcap, window,
        )
    else:
        qp = jnp.arange(S)[:, None]
        kp = jnp.arange(S)[None, :]
        mask = kp <= qp
        if window > 0:
            mask = mask & (kp > qp - window)
        mask = jnp.broadcast_to(mask[None], (B, S, S))
        out = _softmax_attend(q, k, v, mask, schedule, cfg.logit_softcap)
    return matmul(out.reshape(B, S, -1).astype(x.dtype), p["wo"], schedule)


def attention_cached(
    p: Dict,
    cfg,
    x: jax.Array,  # (B, W, D)
    cache: Dict,  # {"k","v": (B,C,KV,HD), "pos": (B,C)}
    start_pos: jax.Array,  # (B,) absolute position of x[:, 0]
    schedule: Schedule,
    window: int = 0,
) -> Tuple[jax.Array, Dict]:
    """Incremental attention: write W new tokens into the cache, attend.

    Works uniformly for prefill (W = prompt len), decode (W = 1) and
    verification (W = window).  The cache may be a ring buffer (C < max
    position): slots are addressed by ``abs_pos % C`` and masking uses the
    stored absolute ``pos`` so wraparound is handled naturally.
    """
    B, W, _ = x.shape
    C = cache["k"].shape[1]
    # Ring-buffer contract: a pass writing W positions must not overwrite
    # keys still inside any query's attention window:
    # capacity >= W + window - 1.  Callers chunk longer prefills
    # (Engine._prefill_sliding); full-attention caches have C >= max pos.
    need = W + (window - 1 if window > 0 else 0)
    assert need <= C, (
        f"pass of {W} tokens (+window {window}) exceeds cache capacity {C}; "
        f"chunk it")
    q, k_new, v_new = _qkv(p, cfg, x, schedule)
    abs_pos = start_pos[:, None] + jnp.arange(W)[None, :]  # (B, W)
    q = rope(q, abs_pos, cfg.rope_theta) * (cfg.hd**-0.5)
    k_new = rope(k_new, abs_pos, cfg.rope_theta)

    slots = abs_pos % C  # (B, W)
    b_idx = jnp.arange(B)[:, None]
    k_cache = cache["k"].at[b_idx, slots].set(k_new.astype(cache["k"].dtype))
    v_cache = cache["v"].at[b_idx, slots].set(v_new.astype(cache["v"].dtype))
    pos_cache = cache["pos"].at[b_idx, slots].set(abs_pos)

    if W > CHUNK_THRESHOLD:
        out = _chunked_attend(
            q.astype(F32), k_cache, v_cache, abs_pos, pos_cache, schedule,
            cfg.logit_softcap, window,
        )
    else:
        kp = pos_cache[:, None, :]  # (B, 1, C)
        qp = abs_pos[:, :, None]  # (B, W, 1)
        mask = (kp >= 0) & (kp <= qp)
        if window > 0:
            mask = mask & (kp > qp - window)
        out = _softmax_attend(q, k_cache, v_cache, mask, schedule, cfg.logit_softcap)
    out = matmul(out.reshape(B, W, -1).astype(x.dtype), p["wo"], schedule)
    return out, {"k": k_cache, "v": v_cache, "pos": pos_cache}


class PagedView(NamedTuple):
    """Static geometry of a paged KV pool, threaded into the forward pass."""

    block_size: int
    null_bid: int  # reads through -1 table entries land here (pos == -1)
    scratch_bid: int  # writes past the table land here (never read)


def attention_paged(
    p: Dict,
    cfg,
    x: jax.Array,  # (B, W, D)
    cache: Dict,  # {"k","v": (NB+2, bs, KV, HD), "pos": (NB+2, bs)} pool-shaped
    tables: jax.Array,  # (B, nblk) int32 block ids, -1 = unallocated
    start_pos: jax.Array,  # (B,) absolute position of x[:, 0]
    schedule: Schedule,
    paged: PagedView,
) -> Tuple[jax.Array, Dict]:
    """Incremental attention reading/writing K/V *through the block table*.

    The pool leaves carry no batch axis; each row's view is the
    concatenation of its table's blocks (``-1`` entries read the null block,
    whose positions are ``-1`` and therefore always masked).  Writes for the
    W new tokens go to ``tables[b, abs_pos // block_size]``; positions past
    the table (padded rows / padded window tails) are absorbed by the
    scratch block, which is never read.  Semantically — and bitwise — this
    equals gathering the view and running :func:`attention_cached` on it;
    the host-side gather copy is what disappears.
    """
    B, W, _ = x.shape
    bs = paged.block_size
    nblk = tables.shape[1]
    q, k_new, v_new = _qkv(p, cfg, x, schedule)
    abs_pos = start_pos[:, None] + jnp.arange(W)[None, :]  # (B, W)
    q = rope(q, abs_pos, cfg.rope_theta)
    k_new = rope(k_new, abs_pos, cfg.rope_theta)

    blk = abs_pos // bs  # (B, W)
    off = abs_pos % bs
    bid = jnp.take_along_axis(tables, jnp.clip(blk, 0, nblk - 1), axis=1)
    bid = jnp.where((bid < 0) | (blk >= nblk), paged.scratch_bid, bid)
    k_cache = cache["k"].at[bid, off].set(k_new.astype(cache["k"].dtype))
    v_cache = cache["v"].at[bid, off].set(v_new.astype(cache["v"].dtype))
    pos_cache = cache["pos"].at[bid, off].set(abs_pos)

    if W == 1 and ops.on_tpu() and cfg.logit_softcap == 0:
        # single-token decode on TPU: the table-walking Pallas kernels
        # (commit single-pass vs `# det: fastpath` split variant, selected
        # by the schedule) read K/V in place — the (B, nblk*bs, ...) view
        # gather below never materializes.  The dispatcher scales q by
        # hd^-0.5 itself, so it gets the unscaled roped q.
        out = ops.paged_attention(
            q[:, 0], k_cache, v_cache, pos_cache, tables, abs_pos[:, 0],
            schedule, null_bid=paged.null_bid,
        )
        out = matmul(out.reshape(B, W, -1).astype(x.dtype), p["wo"], schedule)
        return out, {"k": k_cache, "v": v_cache, "pos": pos_cache}

    q = q * (cfg.hd**-0.5)
    flat = jnp.where(tables < 0, paged.null_bid, tables)  # (B, nblk)
    k_view = k_cache[flat].reshape(B, nblk * bs, -1, cfg.hd)
    v_view = v_cache[flat].reshape(B, nblk * bs, -1, cfg.hd)
    kp = pos_cache[flat].reshape(B, 1, nblk * bs)  # (B, 1, S)
    qp = abs_pos[:, :, None]  # (B, W, 1)
    mask = (kp >= 0) & (kp <= qp)
    out = _softmax_attend(q, k_view, v_view, mask, schedule, cfg.logit_softcap)
    out = matmul(out.reshape(B, W, -1).astype(x.dtype), p["wo"], schedule)
    return out, {"k": k_cache, "v": v_cache, "pos": pos_cache}


def cross_attention(
    p: Dict,
    cfg,
    x: jax.Array,  # (B, W, D) decoder states
    enc_k: jax.Array,  # (B, Se, KV, HD) precomputed encoder keys
    enc_v: jax.Array,
    enc_mask: jax.Array,  # (B, Se) bool
    schedule: Schedule,
) -> jax.Array:
    B, W, _ = x.shape
    q = matmul(x, p["wq"], schedule).reshape(B, W, cfg.num_heads, cfg.hd)
    q = q * (cfg.hd**-0.5)
    mask = jnp.broadcast_to(enc_mask[:, None, :], (B, W, enc_k.shape[1]))
    out = _softmax_attend(q.astype(F32), enc_k, enc_v, mask, schedule)
    return matmul(out.reshape(B, W, -1).astype(x.dtype), p["wo"], schedule)


def encode_cross_kv(p: Dict, cfg, enc_out: jax.Array, schedule: Schedule):
    """Precompute cross-attention K/V from encoder output (per request)."""
    B, Se, _ = enc_out.shape
    k = matmul(enc_out, p["wk"], schedule).reshape(B, Se, cfg.num_kv_heads, cfg.hd)
    v = matmul(enc_out, p["wv"], schedule).reshape(B, Se, cfg.num_kv_heads, cfg.hd)
    return k, v


# ---------------------------------------------------------------------------
# feed-forward
# ---------------------------------------------------------------------------


def swiglu_ffn(p: Dict, x: jax.Array, schedule: Schedule) -> jax.Array:
    gate = matmul(x, p["wi_gate"], schedule)
    up = matmul(x, p["wi_up"], schedule)
    h = jax.nn.silu(gate.astype(F32)).astype(x.dtype) * up
    return matmul(h, p["wo"], schedule)


def moe_ffn(
    p: Dict, cfg, x: jax.Array, schedule: Schedule, capacity_factor: float = 1.25
) -> Tuple[jax.Array, Dict]:
    """Top-k MoE with sort-based dispatch and static expert capacity.

    Routing itself goes through a schedule-dependent matmul: the router's
    argmax can flip under different reduction trees, which is why MoE models
    are where the paper's O1 token flips are most likely (DESIGN.md §4).

    Returns (output, aux) where aux carries router load statistics.
    """
    orig_shape = x.shape
    d = orig_shape[-1]
    xt = x.reshape(-1, d)  # (T, d)
    T = xt.shape[0]
    E, K = cfg.num_experts, cfg.top_k

    logits = matmul(xt, p["router"], schedule).astype(F32)  # (T, E)
    gates, idx = jax.lax.top_k(logits, K)  # (T, K)
    gates = jax.nn.softmax(gates, axis=-1)

    if schedule.moe_no_drop:
        C = T  # worst case: every token routed to one expert — never drop
    else:
        C = max(int(T * K * capacity_factor / E + 0.999), 1)
        # pad capacity to a lane-friendly multiple when large
        if C > 8:
            C = (C + 7) // 8 * 8

    flat_e = idx.reshape(-1)  # (T*K,)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    # position of each routed token within its expert bucket.  The bucket
    # starts are exact integer counts (#{assignments < e}, i.e. the 'left'
    # insertion index) computed by a fixed-structure reduction rather than
    # jnp.searchsorted, which lowers to a binary-search scan whose
    # ceil(log2(T*K)) trip count varies with the token count — a
    # batch-variant structure on the commit path
    starts = jnp.sum(
        (sorted_e[None, :] < jnp.arange(E)[:, None]).astype(jnp.int32), axis=1
    )
    pos_in_e = jnp.arange(T * K) - starts[sorted_e]
    keep = pos_in_e < C
    dest = jnp.where(keep, sorted_e * C + pos_in_e, E * C)  # overflow bucket

    token_idx = order // K  # which token each routed slot came from
    xin = xt[token_idx]  # (T*K, d)
    buckets = jnp.zeros((E * C + 1, d), xt.dtype).at[dest].set(
        jnp.where(keep[:, None], xin, 0)
    )
    buckets = buckets[: E * C].reshape(E, C, d)

    # expert computation — active FLOPs only: E * C * d * f per matmul
    gate_h = jnp.einsum(
        "ecd,edf->ecf", buckets.astype(F32), p["wi_gate"].astype(F32),
        precision=jax.lax.Precision.HIGHEST,
    )
    up_h = jnp.einsum(
        "ecd,edf->ecf", buckets.astype(F32), p["wi_up"].astype(F32),
        precision=jax.lax.Precision.HIGHEST,
    )
    h = jax.nn.silu(gate_h) * up_h
    yb = jnp.einsum(
        "ecf,efd->ecd", h, p["wo"].astype(F32),
        precision=jax.lax.Precision.HIGHEST,
    ).astype(xt.dtype)

    # gather back: routed slot -> (token, k)
    yb_flat = jnp.concatenate([yb.reshape(E * C, d), jnp.zeros((1, d), xt.dtype)], 0)
    y_routed = yb_flat[dest]  # (T*K, d); dropped slots read the zero row
    inv = jnp.argsort(order, stable=True)
    y_per_k = y_routed[inv].reshape(T, K, d)
    y = jnp.sum(y_per_k.astype(F32) * gates[..., None], axis=1).astype(xt.dtype)

    load = jnp.bincount(flat_e, length=E) / (T * K)
    importance = jnp.mean(jax.nn.softmax(logits, -1), axis=0)
    aux = {
        "router_load": load,
        "aux_loss": E * jnp.sum(load * importance),
        "dropped_frac": 1.0 - jnp.mean(keep.astype(F32)),
    }
    return y.reshape(orig_shape), aux
