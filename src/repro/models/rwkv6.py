"""RWKV-6 "Finch" layer: token-shift time mixing with data-dependent decay,
plus squared-ReLU channel mixing (arXiv:2404.05892).

Simplifications vs. the reference implementation (documented per DESIGN.md):
  * static token-shift interpolation weights (mu) for r/k/v/g instead of the
    full data-dependent ddlerp — the data-*dependent decay* w (the paper's
    headline feature) is kept, via its LoRA parameterization;
  * per-head RMS normalization of the wkv output instead of GroupNorm.

State layout (per layer, per request):
    tm_shift: (B, D)            last input to time mixing
    cm_shift: (B, D)            last input to channel mixing
    wkv:      (B, H, hd, hd)    recurrent outer-product state (f32)
"""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.core.determinism import Schedule, matmul

F32 = jnp.float32


def init_state(cfg, batch: int, dtype) -> Dict[str, jax.Array]:
    h = cfg.d_model // cfg.rwkv_head_dim
    return {
        "tm_shift": jnp.zeros((batch, cfg.d_model), dtype),
        "cm_shift": jnp.zeros((batch, cfg.d_model), dtype),
        "wkv": jnp.zeros((batch, h, cfg.rwkv_head_dim, cfg.rwkv_head_dim), F32),
    }


def _token_shift(x: jax.Array, prev: jax.Array) -> jax.Array:
    """x: (B, W, D); prev: (B, D) -> shifted (B, W, D) (x at t-1)."""
    return jnp.concatenate([prev[:, None], x[:, :-1]], axis=1)


def time_mix(
    p: Dict,
    cfg,
    x: jax.Array,  # (B, W, D), already layer-norm'd
    prev_shift: jax.Array,  # (B, D)
    wkv0: jax.Array,  # (B, H, hd, hd)
    schedule: Schedule,
    collect_states: bool = False,
):
    """Returns (out, new_shift, new_wkv, per_pos_wkv or None)."""
    B, W, D = x.shape
    hd = cfg.rwkv_head_dim
    H = D // hd

    xs = _token_shift(x, prev_shift)
    mix = lambda mu: x + (xs - x) * mu  # noqa: E731
    r = matmul(mix(p["mu_r"]), p["wr"], schedule).reshape(B, W, H, hd)
    k = matmul(mix(p["mu_k"]), p["wk"], schedule).reshape(B, W, H, hd)
    v = matmul(mix(p["mu_v"]), p["wv"], schedule).reshape(B, W, H, hd)
    g = matmul(mix(p["mu_g"]), p["wg"], schedule)

    # data-dependent decay (the Finch contribution): w = exp(-exp(dd))
    dd = p["w_decay"].astype(F32) + matmul(
        jnp.tanh(matmul(mix(p["mu_w"]), p["w_lora_a"], schedule)),
        p["w_lora_b"], schedule,
    ).astype(F32)
    w = jnp.exp(-jnp.exp(dd)).reshape(B, W, H, hd)  # in (0, 1), per channel

    u = p["u_bonus"].astype(F32)  # (H, hd)

    def step(s, t):  # s: (B, H, hd, hd) indexed [k_dim, v_dim]
        r_t, k_t, v_t, w_t = t
        kv = k_t[..., :, None] * v_t[..., None, :]  # (B, H, hd, hd)
        out = jnp.einsum("bhk,bhkv->bhv", r_t, s + u[None, :, :, None] * kv,
                         precision=jax.lax.Precision.HIGHEST)
        s = w_t[..., :, None] * s + kv
        return s, (out, s if collect_states else 0.0)

    xs_scan = tuple(jnp.moveaxis(a.astype(F32), 1, 0) for a in (r, k, v, w))
    sT, (outs, states_pp) = jax.lax.scan(step, wkv0, xs_scan)
    tm = jnp.moveaxis(outs, 0, 1)  # (B, W, H, hd)
    rms = jax.lax.rsqrt(jnp.mean(tm**2, axis=-1, keepdims=True) + 1e-6)
    tm = (tm * rms).reshape(B, W, D) * p["ln_x_scale"]
    tm = tm * jax.nn.silu(g.astype(F32))
    out = matmul(tm.astype(x.dtype), p["wo"], schedule)

    per_pos = jnp.moveaxis(states_pp, 0, 1) if collect_states else None
    return out, x[:, -1], sT, per_pos


def channel_mix(
    p: Dict,
    cfg,
    x: jax.Array,  # (B, W, D), already layer-norm'd
    prev_shift: jax.Array,  # (B, D)
    schedule: Schedule,
):
    """Returns (out, new_shift)."""
    xs = _token_shift(x, prev_shift)
    mix = lambda mu: x + (xs - x) * mu  # noqa: E731
    k = matmul(mix(p["cm_mu_k"]), p["cm_wk"], schedule)
    k = jnp.square(jax.nn.relu(k.astype(F32))).astype(x.dtype)
    out = jax.nn.sigmoid(
        matmul(mix(p["cm_mu_r"]), p["cm_wr"], schedule).astype(F32)
    ).astype(x.dtype) * matmul(k, p["cm_wv"], schedule)
    return out, x[:, -1]
