from repro.models.base import (  # noqa: F401
    ModelConfig,
    ParamSpec,
    abstract_params,
    init_params,
    logical_axes,
    param_specs,
)
from repro.models.transformer import (  # noqa: F401
    build_cross_cache,
    cache_spec,
    encode,
    forward,
    forward_train,
    init_cache,
)
