"""Model zoo foundation: configs, parameter specs, logical sharding axes.

Parameters are plain pytrees (nested dicts of jnp arrays) built from
``ParamSpec`` trees.  Each spec records the tensor shape *and* its logical
axis names, so ``specs`` is the single source of truth for both
initialization and distributed sharding (``repro.distributed.sharding`` maps
logical axes -> mesh ``PartitionSpec`` per execution mode).

Layer stacks are stored with a leading ``layers`` dimension so forward
passes can ``jax.lax.scan`` over layers — this keeps compiled HLO compact
(essential for the 512-device dry-run on large configs like kimi-k2).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads
    # attention
    attn_kind: str = "full"  # full | sliding
    window: int = 4096  # sliding-window size (used when attn_kind == sliding
    #                     or in long-context decode for archs that support it)
    rope_theta: float = 10000.0
    use_bias: bool = False
    logit_softcap: float = 0.0
    # MoE
    num_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    first_k_dense: int = 0  # leading layers use dense FFN (kimi-k2 style)
    moe_every: int = 1  # MoE FFN on layers where (i % moe_every == moe_offset)
    moe_offset: int = 0
    # hybrid (jamba): attention layer every `attn_every` layers, else mamba
    attn_every: int = 0
    # mamba
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    # rwkv6
    rwkv_head_dim: int = 64
    # encoder-decoder
    num_encoder_layers: int = 0
    encoder_seq_len: int = 0  # frames from the (stubbed) audio frontend
    # multimodal frontend stub: number of prepended embedding tokens
    num_prefix_embeds: int = 0
    # misc
    norm_eps: float = 1e-5
    tie_embeddings: bool = True
    max_seq_len: int = 8192
    dtype: str = "float32"
    source: str = ""  # citation for the config

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.num_heads)

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    def layer_kind(self, i: int) -> str:
        """'attn' | 'mamba' | 'rwkv' for decoder layer i."""
        if self.family == "ssm":
            return "rwkv"
        if self.family == "hybrid" and self.attn_every > 0:
            return "attn" if (i % self.attn_every == 0) else "mamba"
        return "attn"

    def ffn_kind(self, i: int) -> str:
        """'dense' | 'moe' for decoder layer i."""
        if self.num_experts <= 0 or i < self.first_k_dense:
            return "dense"
        if i % self.moe_every != self.moe_offset:
            return "dense"
        return "moe"

    def block_period(self) -> int:
        """Smallest repeating period of (layer_kind, ffn_kind) patterns."""
        period = 1
        if self.family == "hybrid" and self.attn_every:
            period = self.attn_every
        if self.num_experts > 0 and self.moe_every > 1:
            import math

            period = period * self.moe_every // math.gcd(period, self.moe_every)
        return period

    def param_count(self) -> int:
        """Total parameter count (for roofline MODEL_FLOPS)."""
        total = 0
        for _, spec in jax.tree_util.tree_leaves_with_path(param_specs(self)):
            n = 1
            for s in spec.shape:
                n *= s
            total += n
        return total

    def active_param_count(self) -> int:
        """Params active per token (MoE: only top_k experts count)."""
        total = 0
        for path, spec in jax.tree_util.tree_leaves_with_path(param_specs(self)):
            n = 1
            for s in spec.shape:
                n *= s
            if "experts" in spec.axes and self.num_experts > 0:
                n = n * self.top_k // self.num_experts
            total += n
        return total


class ParamSpec:
    """Shape + logical axes + initializer for one parameter tensor."""

    __slots__ = ("shape", "axes", "init", "scale")

    def __init__(self, shape, axes, init="normal", scale=None):
        assert len(shape) == len(axes), (shape, axes)
        self.shape = tuple(int(s) for s in shape)
        self.axes = tuple(axes)
        self.init = init
        self.scale = scale

    def instantiate(self, key, dtype) -> jax.Array:
        if self.init == "zeros":
            return jnp.zeros(self.shape, dtype)
        if self.init == "ones":
            return jnp.ones(self.shape, dtype)
        fan_in = self.shape[0] if len(self.shape) >= 2 else max(self.shape[-1], 1)
        scale = self.scale if self.scale is not None else fan_in**-0.5
        return (jax.random.normal(key, self.shape, jnp.float32) * scale).astype(dtype)

    def __repr__(self):
        return f"ParamSpec({self.shape}, {self.axes})"


# ---------------------------------------------------------------------------
# per-layer-kind parameter specs.  Logical axis vocabulary:
#   embed   d_model dims of weight matrices (FSDP axis in training)
#   heads   fused head*head_dim output dims (tensor-parallel)
#   kv      fused kv_head*head_dim dims (tensor-parallel, small)
#   ffn     feed-forward hidden (tensor-parallel)
#   vocab   vocabulary (tensor-parallel)
#   experts MoE expert dim (expert-parallel)
#   inner   mamba/rwkv inner dims (tensor-parallel)
#   state   mamba state / conv dims (replicated)
#   null    replicated small tensors
# ---------------------------------------------------------------------------


def attn_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.hd
    specs = {
        "wq": ParamSpec((d, h * hd), ("embed", "heads")),
        "wk": ParamSpec((d, kv * hd), ("embed", "kv")),
        "wv": ParamSpec((d, kv * hd), ("embed", "kv")),
        "wo": ParamSpec((h * hd, d), ("heads", "embed")),
    }
    if cfg.use_bias:
        specs["bq"] = ParamSpec((h * hd,), ("heads",), init="zeros")
        specs["bk"] = ParamSpec((kv * hd,), ("kv",), init="zeros")
        specs["bv"] = ParamSpec((kv * hd,), ("kv",), init="zeros")
    return specs


def cross_attn_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    return attn_specs(cfg)


def dense_ffn_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d, f = cfg.d_model, cfg.d_ff
    return {
        "wi_gate": ParamSpec((d, f), ("embed", "ffn")),
        "wi_up": ParamSpec((d, f), ("embed", "ffn")),
        "wo": ParamSpec((f, d), ("ffn", "embed")),
    }


def moe_ffn_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d, f, e = cfg.d_model, cfg.moe_d_ff or cfg.d_ff, cfg.num_experts
    return {
        "router": ParamSpec((d, e), ("embed", "experts"), scale=0.02),
        "wi_gate": ParamSpec((e, d, f), ("experts", "embed", "ffn")),
        "wi_up": ParamSpec((e, d, f), ("experts", "embed", "ffn")),
        "wo": ParamSpec((e, f, d), ("experts", "ffn", "embed")),
    }


def mamba_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d, di, ds, dc = cfg.d_model, cfg.d_inner, cfg.d_state, cfg.d_conv
    dt_rank = max(d // 16, 1)
    return {
        "in_proj": ParamSpec((d, 2 * di), ("embed", "inner")),
        "conv_w": ParamSpec((dc, di), ("state", "inner"), scale=0.5),
        "conv_b": ParamSpec((di,), ("inner",), init="zeros"),
        "x_proj": ParamSpec((di, dt_rank + 2 * ds), ("inner", "state")),
        "dt_proj_w": ParamSpec((dt_rank, di), ("state", "inner")),
        "dt_proj_b": ParamSpec((di,), ("inner",), init="zeros"),
        "A_log": ParamSpec((di, ds), ("inner", "state"), init="ones"),
        "D": ParamSpec((di,), ("inner",), init="ones"),
        "out_proj": ParamSpec((di, d), ("inner", "embed")),
    }


def rwkv_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d = cfg.d_model
    n_heads = d // cfg.rwkv_head_dim
    hd = cfg.rwkv_head_dim
    return {
        # time mixing (attention analogue)
        "mu_r": ParamSpec((d,), ("embed",), scale=0.1),
        "mu_k": ParamSpec((d,), ("embed",), scale=0.1),
        "mu_v": ParamSpec((d,), ("embed",), scale=0.1),
        "mu_w": ParamSpec((d,), ("embed",), scale=0.1),
        "mu_g": ParamSpec((d,), ("embed",), scale=0.1),
        "wr": ParamSpec((d, d), ("embed", "heads")),
        "wk": ParamSpec((d, d), ("embed", "heads")),
        "wv": ParamSpec((d, d), ("embed", "heads")),
        "wg": ParamSpec((d, d), ("embed", "heads")),
        "w_decay": ParamSpec((d,), ("embed",), scale=0.1),  # data-dep decay base
        "w_lora_a": ParamSpec((d, 64), ("embed", "state"), scale=0.02),
        "w_lora_b": ParamSpec((64, d), ("state", "embed"), scale=0.02),
        "u_bonus": ParamSpec((n_heads, hd), ("heads", "state"), scale=0.1),
        "wo": ParamSpec((d, d), ("heads", "embed")),
        "ln_x_scale": ParamSpec((d,), ("embed",), init="ones"),
        # channel mixing (FFN analogue)
        "cm_mu_k": ParamSpec((d,), ("embed",), scale=0.1),
        "cm_mu_r": ParamSpec((d,), ("embed",), scale=0.1),
        "cm_wk": ParamSpec((d, cfg.d_ff), ("embed", "ffn")),
        "cm_wv": ParamSpec((cfg.d_ff, d), ("ffn", "embed")),
        "cm_wr": ParamSpec((d, d), ("embed", "heads")),
    }


def norm_specs(cfg: ModelConfig, n: int = 2) -> Dict[str, ParamSpec]:
    return {
        f"norm{i}": ParamSpec((cfg.d_model,), ("embed",), init="ones")
        for i in range(n)
    }


def layer_specs(cfg: ModelConfig, i: int, *, decoder: bool = True) -> Dict[str, Any]:
    """Specs for decoder layer ``i`` (or an encoder layer if decoder=False)."""
    kind = cfg.layer_kind(i) if decoder else "attn"
    specs: Dict[str, Any] = {}
    if kind == "attn":
        specs["attn"] = attn_specs(cfg)
    elif kind == "mamba":
        specs["mamba"] = mamba_specs(cfg)
    elif kind == "rwkv":
        specs["rwkv"] = rwkv_specs(cfg)
    if decoder and cfg.family == "encdec":
        specs["cross_attn"] = cross_attn_specs(cfg)
        specs.update(norm_specs(cfg, 3))
    else:
        specs.update(norm_specs(cfg, 2))
    fk = cfg.ffn_kind(i) if decoder else "dense"
    if kind == "rwkv":
        pass  # rwkv_specs already includes channel-mix FFN
    elif fk == "moe":
        specs["moe"] = moe_ffn_specs(cfg)
    else:
        specs["ffn"] = dense_ffn_specs(cfg)
    return specs


def _stack_specs(per_layer: list) -> Dict[str, Any]:
    """Stack a list of identical spec trees into leading-layer-dim specs."""
    n = len(per_layer)
    return jax.tree_util.tree_map(
        lambda s: ParamSpec((n,) + s.shape, ("layers",) + s.axes, s.init, s.scale),
        per_layer[0],
    )


def param_specs(cfg: ModelConfig) -> Dict[str, Any]:
    """The full parameter spec tree for a model config.

    Decoder layers are grouped into repeating *blocks* of length
    ``cfg.block_period()``; each block position gets its own stacked spec
    tree (so heterogeneous hybrids like jamba still scan cleanly).
    """
    specs: Dict[str, Any] = {
        "embed": ParamSpec((cfg.vocab_size, cfg.d_model), ("vocab", "embed"), scale=0.02),
        "final_norm": ParamSpec((cfg.d_model,), ("embed",), init="ones"),
    }
    if not cfg.tie_embeddings:
        specs["unembed"] = ParamSpec((cfg.d_model, cfg.vocab_size), ("embed", "vocab"))

    period = cfg.block_period()
    assert cfg.num_layers % period == 0 or period == 1, (cfg.name, period)
    n_blocks = cfg.num_layers // period if cfg.num_layers % period == 0 else cfg.num_layers
    if cfg.num_layers % period != 0:
        period = 1
    # first_k_dense breaks homogeneity: give those layers their own (unstacked)
    # entries.
    fkd = cfg.first_k_dense
    if fkd:
        specs["head_layers"] = {
            str(i): layer_specs(cfg, i) for i in range(fkd)
        }
        rest = cfg.num_layers - fkd
        assert rest % period == 0
        n_blocks = rest // period
        specs["blocks"] = {
            str(p): _stack_specs(
                [layer_specs(cfg, fkd + b * period + p) for b in range(n_blocks)]
            )
            for p in range(period)
        }
    else:
        n_blocks = cfg.num_layers // period
        specs["blocks"] = {
            str(p): _stack_specs(
                [layer_specs(cfg, b * period + p) for b in range(n_blocks)]
            )
            for p in range(period)
        }

    if cfg.family == "encdec":
        specs["enc_blocks"] = {
            "0": _stack_specs(
                [layer_specs(cfg, i, decoder=False) for i in range(cfg.num_encoder_layers)]
            )
        }
        specs["enc_final_norm"] = ParamSpec((cfg.d_model,), ("embed",), init="ones")
    return specs


def init_params(cfg: ModelConfig, key: jax.Array) -> Dict[str, Any]:
    specs = param_specs(cfg)
    leaves, treedef = jax.tree_util.tree_flatten(specs)
    keys = jax.random.split(key, len(leaves))
    dtype = jnp.dtype(cfg.dtype)
    arrs = [spec.instantiate(k, dtype) for spec, k in zip(leaves, keys)]
    return jax.tree_util.tree_unflatten(treedef, arrs)


def abstract_params(cfg: ModelConfig) -> Dict[str, Any]:
    """ShapeDtypeStruct param tree (for dry-run lowering, no allocation)."""
    dtype = jnp.dtype(cfg.dtype)
    return jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct(s.shape, dtype), param_specs(cfg)
    )


def logical_axes(cfg: ModelConfig) -> Dict[str, Any]:
    """Pytree (same structure as params) of logical-axis tuples."""
    return jax.tree_util.tree_map(lambda s: s.axes, param_specs(cfg))
