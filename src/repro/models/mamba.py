"""Mamba-1 selective state-space layer (for the Jamba hybrid).

State layout (per layer, per request):
    conv_state: (B, d_conv - 1, d_inner)  — trailing inputs for the causal conv
    ssm_state:  (B, d_inner, d_state)     — the recurrent SSM state

Unlike attention, a recurrent state cannot be "truncated" for DVR rollback;
``repro.core.dvr`` instead checkpoints the state at commit points.  To let
the verifier pick the state at an arbitrary commit index inside the window,
``mamba_layer(..., collect_states=True)`` emits the state after *every*
position.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.determinism import Schedule, matmul

F32 = jnp.float32


def init_state(cfg, batch: int, dtype) -> Dict[str, jax.Array]:
    return {
        "conv": jnp.zeros((batch, cfg.d_conv - 1, cfg.d_inner), dtype),
        "ssm": jnp.zeros((batch, cfg.d_inner, cfg.d_state), F32),
    }


def mamba_layer(
    p: Dict,
    cfg,
    x: jax.Array,  # (B, W, D)
    state: Optional[Dict],
    schedule: Schedule,
    collect_states: bool = False,
) -> Tuple[jax.Array, Optional[Dict], Optional[Dict]]:
    """Returns (y, new_state, per_pos_states or None)."""
    B, W, D = x.shape
    di, ds, dc = cfg.d_inner, cfg.d_state, cfg.d_conv
    dt_rank = max(D // 16, 1)

    xz = matmul(x, p["in_proj"], schedule)  # (B, W, 2*di)
    xi, z = jnp.split(xz, 2, axis=-1)

    # causal depthwise conv over time (width dc)
    if state is not None:
        ctx = jnp.concatenate([state["conv"].astype(xi.dtype), xi], axis=1)
    else:
        ctx = jnp.concatenate([jnp.zeros((B, dc - 1, di), xi.dtype), xi], axis=1)
    windows = jnp.stack(
        [jax.lax.slice_in_dim(ctx, i, i + W, axis=1) for i in range(dc)], axis=-1
    )  # (B, W, di, dc)
    xc = jnp.einsum(
        "bwic,ci->bwi", windows.astype(F32), p["conv_w"].astype(F32),
        precision=jax.lax.Precision.HIGHEST,
    )
    xc = jax.nn.silu(xc + p["conv_b"].astype(F32)).astype(x.dtype)
    new_conv = jax.lax.slice_in_dim(ctx, ctx.shape[1] - (dc - 1), ctx.shape[1], axis=1)

    proj = matmul(xc, p["x_proj"], schedule)  # (B, W, dt_rank + 2*ds)
    dt_in = proj[..., :dt_rank]
    Bm = proj[..., dt_rank : dt_rank + ds].astype(F32)  # (B, W, ds)
    Cm = proj[..., dt_rank + ds :].astype(F32)
    dt = jax.nn.softplus(
        matmul(dt_in, p["dt_proj_w"], schedule).astype(F32) + p["dt_proj_b"].astype(F32)
    )  # (B, W, di)

    A = -jnp.exp(p["A_log"].astype(F32))  # (di, ds)
    decay = jnp.exp(dt[..., None] * A[None, None])  # (B, W, di, ds)
    drive = (dt * xc.astype(F32))[..., None] * Bm[:, :, None, :]  # (B, W, di, ds)

    h0 = state["ssm"] if state is not None else jnp.zeros((B, di, ds), F32)

    def step(h, t):
        d_t, u_t, c_t = t
        h = d_t * h + u_t  # (B, di, ds)
        y = jnp.einsum("bis,bs->bi", h, c_t,
                       precision=jax.lax.Precision.HIGHEST)
        return h, (y, h if collect_states else 0.0)

    xs = (
        jnp.moveaxis(decay, 1, 0),
        jnp.moveaxis(drive, 1, 0),
        jnp.moveaxis(Cm, 1, 0),
    )
    hT, (ys, hs) = jax.lax.scan(step, h0, xs)
    y = jnp.moveaxis(ys, 0, 1)  # (B, W, di)
    y = y + xc.astype(F32) * p["D"].astype(F32)
    y = y * jax.nn.silu(z.astype(F32))
    out = matmul(y.astype(x.dtype), p["out_proj"], schedule)

    new_state = {"conv": new_conv.astype(xi.dtype), "ssm": hT}
    per_pos = None
    if collect_states:
        # conv state after position w = inputs [w-dc+2 .. w]; gathered from
        # ctx in one vectorized lookup — a per-w Python slice loop would
        # make the traced structure (eqn count) vary with the chunk width,
        # breaking commit-path batch invariance
        idx = jnp.arange(W)[:, None] + 1 + jnp.arange(dc - 1)[None, :]
        conv_per_pos = ctx[:, idx]  # (B, W, dc-1, di)
        per_pos = {"conv": conv_per_pos.astype(xi.dtype), "ssm": jnp.moveaxis(hs, 0, 1)}
    return out, new_state, per_pos
