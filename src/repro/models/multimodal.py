"""Modality frontend STUBS (the one permitted carve-out per assignment).

[vlm]   llava-next: the ViT/SigLIP vision tower + projector is stubbed;
        ``vision_embeds`` returns patch embeddings of the right shape.
        LLaVA-NeXT "anyres" tiling: a 336px base image + up to 4 tiles,
        each 24x24=576 patches -> 576 * (1 + num_tiles) patch tokens.
[audio] seamless-m4t: the mel-spectrogram + conv feature extractor
        (w2v-BERT frontend) is stubbed; ``audio_frames`` returns frame
        embeddings consumed by the speech encoder.

The *language/decoder transformer* that consumes these embeddings is fully
implemented (models/transformer.py); only the perception stack is stubbed.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


LLAVA_BASE_PATCHES = 576  # 24x24 @ patch 14, 336px
LLAVA_NUM_TILES = 4  # anyres high-res tiles


def num_vision_tokens(num_tiles: int = LLAVA_NUM_TILES) -> int:
    return LLAVA_BASE_PATCHES * (1 + num_tiles)


def vision_embeds(key: jax.Array, batch: int, d_model: int,
                  num_tiles: int = LLAVA_NUM_TILES, dtype=jnp.float32) -> jax.Array:
    """Stub for ViT tower + 2-layer MLP projector output."""
    n = num_vision_tokens(num_tiles)
    return jax.random.normal(key, (batch, n, d_model), dtype) * 0.02


def vision_embeds_spec(batch: int, d_model: int,
                       num_tiles: int = LLAVA_NUM_TILES, dtype=jnp.float32):
    return jax.ShapeDtypeStruct((batch, num_vision_tokens(num_tiles), d_model),
                                jnp.dtype(dtype))


def audio_frames(key: jax.Array, batch: int, num_frames: int, d_model: int,
                 dtype=jnp.float32) -> jax.Array:
    """Stub for mel-spectrogram + conv subsampler output (w2v-BERT frontend)."""
    return jax.random.normal(key, (batch, num_frames, d_model), dtype) * 0.02


def audio_frames_spec(batch: int, num_frames: int, d_model: int, dtype=jnp.float32):
    return jax.ShapeDtypeStruct((batch, num_frames, d_model), jnp.dtype(dtype))
