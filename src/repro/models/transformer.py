"""Unified forward pass for the whole model zoo.

One code path serves all six families (dense / moe / ssm / hybrid / encdec /
multimodal-backbone).  Decoder layers are grouped into repeating *blocks* of
``cfg.block_period()`` sub-layers and executed with ``jax.lax.scan`` over the
block stack, keeping compiled HLO compact enough for the 512-device dry-run
at kimi-k2 scale.

Three entry points:
  * ``forward_train``  — full causal, no cache, returns (logits, aux).
  * ``forward``        — incremental with cache: prefill (W = prompt len),
                         decode (W = 1) and verification (W = window) all use
                         this; ``collect_states=True`` additionally returns
                         per-position recurrent states (for DVR commit-point
                         state selection on SSM/hybrid archs).
  * ``encode``         — encoder stack for enc-dec models (seamless-m4t).

Every entry point takes an explicit reduction ``Schedule``; the schedule —
not the code — decides whether execution is fast-path (batch-dependent) or
verifier-grade (fixed) numerics.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.determinism import Schedule, VERIFY_SCHEDULE, matmul
from repro.models import mamba as mamba_mod
from repro.models import rwkv6 as rwkv_mod
from repro.models.base import ModelConfig
from repro.models.layers import (
    PagedView,
    attention_cached,
    attention_paged,
    attention_train,
    cross_attention,
    encode_cross_kv,
    moe_ffn,
    rms_norm,
    swiglu_ffn,
)

F32 = jnp.float32


# ---------------------------------------------------------------------------
# cache construction
# ---------------------------------------------------------------------------


#: extra ring-buffer slots beyond the window so a multi-token pass (prefill
#: chunk / verify window, <= RING_SLACK tokens) never overwrites keys still
#: inside a query's window: capacity >= window + pass - 1 is required.
RING_SLACK = 128


def _layer_cache_shape(cfg: ModelConfig, kind: str, batch: int, capacity: int):
    """(shape, dtype) tree for one layer's cache."""
    dtype = jnp.dtype(cfg.dtype)
    if kind == "attn":
        cap = (min(capacity, cfg.window + RING_SLACK)
               if cfg.attn_kind == "sliding" else capacity)
        kv = (batch, cap, cfg.num_kv_heads, cfg.hd)
        return {
            "k": jax.ShapeDtypeStruct(kv, dtype),
            "v": jax.ShapeDtypeStruct(kv, dtype),
            "pos": jax.ShapeDtypeStruct((batch, cap), jnp.int32),
        }
    if kind == "mamba":
        return {
            "conv": jax.ShapeDtypeStruct((batch, cfg.d_conv - 1, cfg.d_inner), dtype),
            "ssm": jax.ShapeDtypeStruct((batch, cfg.d_inner, cfg.d_state), F32),
        }
    if kind == "rwkv":
        h = cfg.d_model // cfg.rwkv_head_dim
        return {
            "tm_shift": jax.ShapeDtypeStruct((batch, cfg.d_model), dtype),
            "cm_shift": jax.ShapeDtypeStruct((batch, cfg.d_model), dtype),
            "wkv": jax.ShapeDtypeStruct(
                (batch, h, cfg.rwkv_head_dim, cfg.rwkv_head_dim), F32
            ),
        }
    raise ValueError(kind)


def cache_spec(cfg: ModelConfig, batch: int, capacity: int) -> Dict[str, Any]:
    """ShapeDtypeStruct tree for the full cache (dry-run friendly)."""
    period = _period(cfg)
    fkd = cfg.first_k_dense
    spec: Dict[str, Any] = {}
    if fkd:
        spec["head_layers"] = {
            str(i): _layer_cache_shape(cfg, cfg.layer_kind(i), batch, capacity)
            for i in range(fkd)
        }
    n_blocks = (cfg.num_layers - fkd) // period
    spec["blocks"] = {}
    for p in range(period):
        per_layer = _layer_cache_shape(cfg, cfg.layer_kind(fkd + p), batch, capacity)
        spec["blocks"][str(p)] = jax.tree_util.tree_map(
            lambda s: jax.ShapeDtypeStruct((n_blocks,) + s.shape, s.dtype), per_layer
        )
    if cfg.family == "encdec":
        se = cfg.encoder_seq_len
        kv = (n_blocks, batch, se, cfg.num_kv_heads, cfg.hd)
        dtype = jnp.dtype(cfg.dtype)
        spec["cross"] = {
            "k": jax.ShapeDtypeStruct(kv, dtype),
            "v": jax.ShapeDtypeStruct(kv, dtype),
            "mask": jax.ShapeDtypeStruct((batch, se), jnp.bool_),
        }
    return spec


def init_cache(cfg: ModelConfig, batch: int, capacity: int) -> Dict[str, Any]:
    def make(s: jax.ShapeDtypeStruct) -> jax.Array:
        if s.dtype == jnp.int32:
            return jnp.full(s.shape, -1, s.dtype)  # pos slots start empty
        if s.dtype == jnp.bool_:
            return jnp.zeros(s.shape, s.dtype)
        return jnp.zeros(s.shape, s.dtype)

    return jax.tree_util.tree_map(make, cache_spec(cfg, batch, capacity))


def _period(cfg: ModelConfig) -> int:
    period = cfg.block_period()
    if (cfg.num_layers - cfg.first_k_dense) % period != 0:
        return 1
    return period


# ---------------------------------------------------------------------------
# single layer application
# ---------------------------------------------------------------------------


def _apply_layer(
    cfg: ModelConfig,
    layer_idx: int,
    lp: Dict,
    x: jax.Array,
    lc: Optional[Dict],
    start_pos: Optional[jax.Array],
    schedule: Schedule,
    collect_states: bool,
    cross_kv: Optional[Dict] = None,
    tables: Optional[jax.Array] = None,
    paged: Optional[PagedView] = None,
) -> Tuple[jax.Array, Optional[Dict], Any, Dict]:
    """Apply decoder layer `layer_idx`.  Returns (x, new_cache, per_pos, aux)."""
    kind = cfg.layer_kind(layer_idx)
    fk = cfg.ffn_kind(layer_idx)
    window = cfg.window if cfg.attn_kind == "sliding" else 0
    aux: Dict[str, Any] = {"aux_loss": jnp.float32(0.0), "dropped_frac": jnp.float32(0.0)}
    per_pos: Any = 0.0

    if kind == "rwkv":
        st = lc if lc is not None else rwkv_mod.init_state(cfg, x.shape[0], x.dtype)
        h_tm = rms_norm(x, lp["norm0"], cfg.norm_eps, schedule)
        tm_out, tm_shift, wkv, pp_wkv = rwkv_mod.time_mix(
            lp["rwkv"], cfg, h_tm, st["tm_shift"], st["wkv"], schedule, collect_states
        )
        x = x + tm_out
        h_cm = rms_norm(x, lp["norm1"], cfg.norm_eps, schedule)
        cm_out, cm_shift = rwkv_mod.channel_mix(
            lp["rwkv"], cfg, h_cm, st["cm_shift"], schedule
        )
        x = x + cm_out
        new_state = {"tm_shift": tm_shift, "cm_shift": cm_shift, "wkv": wkv}
        if collect_states:
            per_pos = {"tm_shift": h_tm, "cm_shift": h_cm, "wkv": pp_wkv}
        return x, new_state, per_pos, aux

    # attention or mamba sub-layer
    h = rms_norm(x, lp["norm0"], cfg.norm_eps, schedule)
    new_cache = lc
    if kind == "attn":
        if lc is None:
            out = attention_train(lp["attn"], cfg, h, schedule, window)
        elif paged is not None and window == 0:
            # paged pool leaves carry no batch axis; sliding (window > 0)
            # archs keep dense rings, so their leaves are never paged
            out, new_cache = attention_paged(
                lp["attn"], cfg, h, lc, tables, start_pos, schedule, paged
            )
        else:
            out, new_cache = attention_cached(
                lp["attn"], cfg, h, lc, start_pos, schedule, window
            )
    else:  # mamba
        out, new_cache, per_pos = mamba_mod.mamba_layer(
            lp["mamba"], cfg, h, lc, schedule, collect_states
        )
        if per_pos is None:
            per_pos = 0.0
    x = x + out

    norm_idx = 1
    if cfg.family == "encdec" and cross_kv is not None:
        h = rms_norm(x, lp["norm1"], cfg.norm_eps, schedule)
        x = x + cross_attention(
            lp["cross_attn"], cfg, h, cross_kv["k"], cross_kv["v"],
            cross_kv["mask"], schedule,
        )
        norm_idx = 2

    h = rms_norm(x, lp[f"norm{norm_idx}"], cfg.norm_eps, schedule)
    if fk == "moe":
        out, moe_aux = moe_ffn(lp["moe"], cfg, h, schedule)
        aux = {k: moe_aux[k] for k in ("aux_loss", "dropped_frac")}
    else:
        out = swiglu_ffn(lp["ffn"], h, schedule)
    x = x + out
    return x, new_cache, per_pos, aux


# ---------------------------------------------------------------------------
# full forward passes
# ---------------------------------------------------------------------------


def _embed(params, cfg, tokens, inputs_embeds):
    if inputs_embeds is not None:
        return inputs_embeds
    return jnp.take(params["embed"], tokens, axis=0)


def _unembed(params, cfg, x, schedule):
    if cfg.tie_embeddings:
        return matmul(x, params["embed"].T, schedule)
    return matmul(x, params["unembed"], schedule)


def forward(
    params: Dict,
    cfg: ModelConfig,
    tokens: Optional[jax.Array] = None,  # (B, W) int32
    *,
    inputs_embeds: Optional[jax.Array] = None,  # (B, W, D) overrides tokens
    cache: Dict,
    start_pos: jax.Array,  # (B,) absolute position of tokens[:, 0]
    schedule: Schedule = VERIFY_SCHEDULE,
    collect_states: bool = False,
    unroll: bool = False,
    tables: Optional[jax.Array] = None,  # (B, nblk) block tables (paged mode)
    paged: Optional[PagedView] = None,
) -> Tuple[jax.Array, Dict, Any]:
    """Incremental forward: prefill / decode / verify.

    Returns (logits (B, W, V) f32, new_cache, per_pos_states).
    ``per_pos_states`` mirrors the recurrent-layer caches with an extra
    per-position axis (only when collect_states=True; else None).

    When ``paged`` is given, full-attention cache leaves are pool-shaped
    (no batch axis) and attention reads/writes through ``tables``; the
    tables are closed over by the block scan (constant across blocks),
    while the pool leaves ride the scanned cache tree as usual.
    """
    x = _embed(params, cfg, tokens, inputs_embeds)
    period = _period(cfg)
    fkd = cfg.first_k_dense

    new_cache: Dict[str, Any] = {}
    per_pos_head: Dict[str, Any] = {}
    if fkd:
        new_cache["head_layers"] = {}
        for i in range(fkd):
            x, nc, pp, _ = _apply_layer(
                cfg, i, params["head_layers"][str(i)], x,
                cache["head_layers"][str(i)], start_pos, schedule, collect_states,
                tables=tables, paged=paged,
            )
            new_cache["head_layers"][str(i)] = nc
            per_pos_head[str(i)] = pp

    cross = cache.get("cross") if cfg.family == "encdec" else None

    def block_body(carry, xs):
        h = carry
        if cfg.family == "encdec":
            block_params, block_cache, cross_kv = xs
            cross_kv = {**cross_kv, "mask": cross["mask"]}
        else:
            block_params, block_cache = xs
            cross_kv = None
        new_caches, pps = {}, {}
        for p in range(period):
            h, nc, pp, _aux = _apply_layer(
                cfg, fkd + p, block_params[str(p)], h, block_cache[str(p)],
                start_pos, schedule, collect_states, cross_kv,
                tables=tables, paged=paged,
            )
            new_caches[str(p)] = nc
            pps[str(p)] = pp
        return h, (new_caches, pps)

    if cfg.family == "encdec":
        xs = (
            params["blocks"],
            cache["blocks"],
            {"k": cross["k"], "v": cross["v"]},
        )
    else:
        xs = (params["blocks"], cache["blocks"])
    x, (block_caches, block_pps) = jax.lax.scan(block_body, x, xs, unroll=unroll)
    new_cache["blocks"] = block_caches
    if cfg.family == "encdec":
        new_cache["cross"] = cross

    x = rms_norm(x, params["final_norm"], cfg.norm_eps, schedule)
    logits = _unembed(params, cfg, x, schedule).astype(F32)

    per_pos = None
    if collect_states:
        per_pos = {"blocks": block_pps}
        if fkd:
            per_pos["head_layers"] = per_pos_head
    return logits, new_cache, per_pos


def forward_train(
    params: Dict,
    cfg: ModelConfig,
    tokens: jax.Array,  # (B, S)
    *,
    inputs_embeds: Optional[jax.Array] = None,
    schedule: Schedule = VERIFY_SCHEDULE,
    enc_embeds: Optional[jax.Array] = None,  # (B, Se, D) for encdec
    remat: bool = False,
    unroll: bool = False,
) -> Tuple[jax.Array, Dict]:
    """Full causal forward for training.  Returns (logits, aux)."""
    x = _embed(params, cfg, tokens, inputs_embeds)
    period = _period(cfg)
    fkd = cfg.first_k_dense

    cross_mask = None
    enc_out = None
    if cfg.family == "encdec":
        assert enc_embeds is not None
        enc_out = encode(params, cfg, enc_embeds, schedule, unroll=unroll)
        cross_mask = jnp.ones(enc_out.shape[:2], jnp.bool_)

    aux_acc = {"aux_loss": jnp.float32(0.0), "dropped_frac": jnp.float32(0.0)}
    if fkd:
        for i in range(fkd):
            x, _, _, aux = _apply_layer(
                cfg, i, params["head_layers"][str(i)], x, None, None, schedule, False
            )
            aux_acc = {k: aux_acc[k] + aux[k] for k in aux_acc}

    def block_body(h, block_params):
        cross_kv = None
        if cfg.family == "encdec":
            block_params, cross_raw = block_params
            cross_kv = {**cross_raw, "mask": cross_mask}
        aux_sum = {"aux_loss": jnp.float32(0.0), "dropped_frac": jnp.float32(0.0)}
        for p in range(period):
            h, _, _, aux = _apply_layer(
                cfg, fkd + p, block_params[str(p)], h, None, None, schedule,
                False, cross_kv,
            )
            aux_sum = {k: aux_sum[k] + aux[k] for k in aux_sum}
        return h, aux_sum

    body = jax.checkpoint(block_body) if remat else block_body
    if cfg.family == "encdec":
        assert period == 1, "encdec assumes homogeneous decoder blocks"

        def per_layer(lp):
            return encode_cross_kv(lp["cross_attn"], cfg, enc_out, schedule)

        k, v = jax.vmap(per_layer)(params["blocks"]["0"])
        x, auxs = jax.lax.scan(body, x, (params["blocks"], {"k": k, "v": v}), unroll=unroll)
    else:
        x, auxs = jax.lax.scan(body, x, params["blocks"], unroll=unroll)
    aux_acc = {k: aux_acc[k] + jnp.sum(auxs[k]) for k in aux_acc}

    x = rms_norm(x, params["final_norm"], cfg.norm_eps, schedule)
    logits = _unembed(params, cfg, x, schedule).astype(F32)
    return logits, aux_acc


# ---------------------------------------------------------------------------
# encoder (enc-dec models)
# ---------------------------------------------------------------------------


def encode(
    params: Dict,
    cfg: ModelConfig,
    enc_embeds: jax.Array,  # (B, Se, D) — stubbed frontend output
    schedule: Schedule = VERIFY_SCHEDULE,
    unroll: bool = False,
) -> jax.Array:
    """Bidirectional encoder stack.  Returns (B, Se, D)."""
    x = enc_embeds

    from repro.models.layers import _qkv, _softmax_attend, rope

    def body(h, lp):
        a = rms_norm(h, lp["norm0"], cfg.norm_eps, schedule)
        B, S, _ = a.shape
        q, k, v = _qkv(lp["attn"], cfg, a, schedule)
        pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        q = rope(q, pos, cfg.rope_theta) * (cfg.hd**-0.5)
        k = rope(k, pos, cfg.rope_theta)
        mask = jnp.ones((B, S, S), jnp.bool_)  # bidirectional
        out = _softmax_attend(q.astype(F32), k, v, mask, schedule)
        out = matmul(out.reshape(B, S, -1).astype(h.dtype), lp["attn"]["wo"], schedule)
        h = h + out
        a = rms_norm(h, lp["norm1"], cfg.norm_eps, schedule)
        h = h + swiglu_ffn(lp["ffn"], a, schedule)
        return h, 0.0

    x, _ = jax.lax.scan(body, x, params["enc_blocks"]["0"], unroll=unroll)
    return rms_norm(x, params["enc_final_norm"], cfg.norm_eps, schedule)


# det: commit-path
def build_cross_cache(
    params: Dict, cfg: ModelConfig, enc_embeds: jax.Array,
    enc_mask: Optional[jax.Array] = None,
    schedule: Schedule = VERIFY_SCHEDULE,
) -> Dict:
    """Encoder pass + per-decoder-layer cross K/V (serving admission path)."""
    enc_out = encode(params, cfg, enc_embeds, schedule)
    period = _period(cfg)
    assert period == 1, "encdec assumes homogeneous decoder blocks"

    def per_layer(lp):
        return encode_cross_kv(lp["cross_attn"], cfg, enc_out, schedule)

    k, v = jax.vmap(per_layer)(params["blocks"]["0"])  # (n_blocks, B, Se, KV, HD)
    if enc_mask is None:
        enc_mask = jnp.ones(enc_embeds.shape[:2], jnp.bool_)
    return {"k": k, "v": v, "mask": enc_mask}
