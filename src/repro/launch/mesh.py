"""Production mesh construction.

A function, not a module-level constant: importing this module must never
touch jax device state (the dry-run sets XLA_FLAGS before first jax init).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod (TPU v5e); 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Single-process mesh for CPU tests (data=devices/model, model axis)."""
    n = len(jax.devices())
    if model < 1 or n % model != 0:
        raise ValueError(
            f"model-axis width {model} does not divide the {n} available "
            f"device(s); pick a divisor of {n}, or relaunch with "
            f"XLA_FLAGS=--xla_force_host_platform_device_count=<N> set "
            f"before jax initializes to fake more host devices"
        )
    return jax.make_mesh((n // model, model), ("data", "model"))
