import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable (e)).

For every (architecture × input shape) on the production meshes —
16x16 = 256 chips single-pod and (2,16,16) = 512 chips multi-pod —
``jax.jit(fn, in_shardings, out_shardings).lower(*specs).compile()`` must
succeed.  The compiled artifact's ``memory_analysis()`` / ``cost_analysis()``
plus collective bytes parsed from the optimized HLO feed §Roofline.

The XLA_FLAGS line above MUST precede any other import that initializes
jax: device count locks on first backend init.  (It is set here only — the
rest of the repo sees the real single CPU device.)

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch tinyllama-1.1b \
      --shape decode_32k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all --out experiments/dryrun
"""

import argparse
import json
import re
import time
import traceback
from typing import Any, Dict

import jax

from repro import configs as config_registry
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import INPUT_SHAPES, build_case


COLLECTIVE_OPS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _type_bytes(type_str: str) -> int:
    """Total bytes of an HLO type string (handles tuples)."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum operand bytes of every collective op in optimized HLO.

    Builds a name -> output-bytes table from definitions, then for each
    collective op sums the bytes of its operands (falling back to the op's
    own output size when an operand is not resolvable).
    """
    def_bytes: Dict[str, int] = {}
    def_re = re.compile(r"^\s*(%?[\w.\-]+)\s*=\s*([^\s]+(?:\([^)]*\))?)\s+(\S+)\(")
    for line in hlo_text.splitlines():
        m = def_re.match(line)
        if m:
            def_bytes[m.group(1).lstrip("%")] = _type_bytes(m.group(2))

    totals = {op: 0 for op in COLLECTIVE_OPS}
    op_re = re.compile(
        r"^\s*(%?[\w.\-]+)\s*=\s*(\S+?)\s+([\w\-]+)(?:\.\d+)?\("
    )
    for line in hlo_text.splitlines():
        m = op_re.match(line)
        if not m:
            continue
        opname = m.group(3)
        base = None
        for c in COLLECTIVE_OPS:
            if opname.startswith(c):
                base = c
                break
        if base is None:
            continue
        # operands: %names inside the parens
        paren = line[line.index("(") + 1:]
        operands = re.findall(r"%?([\w.\-]+)", paren.split(")")[0])
        ob = sum(def_bytes.get(o, 0) for o in operands)
        if ob == 0:
            ob = _type_bytes(m.group(2))
        totals[base] += ob
    totals["total"] = sum(totals.values())
    return totals


def run_case(arch: str, shape: str, multi_pod: bool, out_dir: str | None,
             save_hlo: bool = False) -> Dict[str, Any]:
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "multipod_2x16x16" if multi_pod else "pod_16x16"
    rec: Dict[str, Any] = {
        "arch": arch, "shape": shape, "mesh": mesh_name,
        "chips": int(mesh.devices.size),
    }
    case = build_case(arch, shape, mesh)
    if case.skipped:
        rec["status"] = "skipped"
        rec["reason"] = case.skipped
        if out_dir:
            os.makedirs(out_dir, exist_ok=True)
            fname = f"{arch}_{shape}_{mesh_name}.json"
            with open(os.path.join(out_dir, fname), "w") as f:
                json.dump(rec, f, indent=1)
        return rec
    t0 = time.time()
    try:
        with mesh:
            jitted = jax.jit(
                case.fn, in_shardings=case.in_shardings,
                out_shardings=case.out_shardings,
            )
            lowered = jitted.lower(*case.args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()
        coll = collective_bytes(hlo)
        rec.update({
            "status": "ok",
            "lower_s": round(t_lower, 1),
            "compile_s": round(t_compile, 1),
            "memory": {
                "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
                "output_bytes": getattr(mem, "output_size_in_bytes", None),
                "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
                "generated_code_bytes": getattr(
                    mem, "generated_code_size_in_bytes", None
                ),
            },
            "cost": {
                "flops": cost.get("flops"),
                "bytes_accessed": cost.get("bytes accessed"),
                "transcendentals": cost.get("transcendentals"),
            },
            "collective_bytes": coll,
            "hlo_bytes": len(hlo),
        })
        if save_hlo and out_dir:
            with open(os.path.join(
                out_dir, f"{arch}_{shape}_{mesh_name}.hlo"), "w") as f:
                f.write(hlo)
    except Exception as e:  # a failure here is a sharding bug — report it
        rec["status"] = "fail"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        fname = f"{arch}_{shape}_{mesh_name}.json"
        with open(os.path.join(out_dir, fname), "w") as f:
            json.dump(rec, f, indent=1, default=str)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="arch id or 'all'")
    ap.add_argument("--shape", default=None, choices=list(INPUT_SHAPES) + ["all"])
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--save-hlo", action="store_true")
    args = ap.parse_args()

    archs = (
        config_registry.list_archs()
        if (args.all or args.arch in (None, "all"))
        else [args.arch]
    )
    shapes = (
        [k for k, v in INPUT_SHAPES.items() if not v.get("extra")]
        if (args.all or args.shape in (None, "all"))
        else [args.shape]
    )
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    n_ok = n_skip = n_fail = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                rec = run_case(arch, shape, mp, args.out, args.save_hlo)
                status = rec["status"]
                n_ok += status == "ok"
                n_skip += status == "skipped"
                n_fail += status == "fail"
                extra = ""
                if status == "ok":
                    extra = (
                        f"compile={rec['compile_s']}s "
                        f"flops={rec['cost']['flops']:.3g} "
                        f"coll={rec['collective_bytes']['total']:.3g}B"
                    )
                elif status == "fail":
                    extra = rec["error"][:160]
                print(f"[{status:7s}] {arch:26s} {shape:12s} "
                      f"{rec['mesh']:16s} {extra}", flush=True)
    print(f"\nok={n_ok} skipped={n_skip} fail={n_fail}")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
