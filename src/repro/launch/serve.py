"""End-to-end serving driver.

Runs the LLM-42 engine on a synthetic or ShareGPT-like workload with a mix
of deterministic and non-deterministic requests, reporting throughput
(simulated TPU-v5e time via the cost model + CPU wall time), rollback and
recomputation statistics.

  PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
      --requests 16 --det-ratio 0.25 --mode llm42
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro import configs as config_registry
from repro.core.determinism import FAST_PATH_POLICY, Mode
from repro.models import init_params
from repro.models.multimodal import audio_frames, vision_embeds
from repro.serving import costmodel
from repro.serving.engine import Engine
from repro.serving.request import Request, SamplingParams
from repro.serving.scheduler import (
    AdaptivePolicy,
    OverlapPolicy,
    PauseDecodePolicy,
)
from repro.training.data import SHAREGPT, sample_workload


def build_requests(cfg, n, det_ratio, max_out, seed=0, workload="synthetic",
                   in_len=32):
    rng = np.random.default_rng(seed)
    if workload == "sharegpt":
        lens = sample_workload(SHAREGPT, n, seed, max_in=256, max_out=max_out)
    else:
        lens = [(in_len, max_out)] * n
    reqs = []
    for i, (il, ol) in enumerate(lens):
        prompt = rng.integers(0, cfg.vocab_size, il).tolist()
        det = rng.random() < det_ratio
        r = Request(
            rid=i, prompt=prompt,
            sampling=SamplingParams(
                max_new_tokens=min(ol, max_out), is_deterministic=det,
                seed=1000 + i,
            ),
        )
        if cfg.family == "encdec":
            r.enc_embeds = audio_frames(
                jax.random.PRNGKey(i), 1, cfg.encoder_seq_len, cfg.d_model
            )
        if cfg.num_prefix_embeds:
            r.prefix_embeds = vision_embeds(
                jax.random.PRNGKey(i), 1, cfg.d_model,
                num_tiles=0 if cfg.num_prefix_embeds < 576 else 4,
            )[:, : cfg.num_prefix_embeds]
        reqs.append(r)
    return reqs


def run_cluster(args, full_cfg, make_engine, reqs) -> None:
    """Multi-replica path: N engines behind the deterministic router,
    driven on per-replica costed dual-clock runtimes (repro.cluster)."""
    from repro.cluster import Cluster, run_online
    from repro.obs import validate_chrome_trace

    cluster = Cluster(make_engine, args.replicas)
    arrivals = [
        (i / args.qps) if args.qps > 0 else 0.0 for i in range(len(reqs))
    ]
    t0 = time.time()
    res = run_online(
        cluster, full_cfg, list(zip(reqs, arrivals)),
        invariant_mode=(args.mode == "batch_invariant"),
    )
    wall = time.time() - t0
    done = cluster.finished
    print(f"cluster: {args.replicas} replicas, tp={args.tp}, "
          f"finished {len(done)} requests, {res.out_tokens} tokens "
          f"in {wall:.1f}s wall")
    print(f"simulated v5e fleet time: {res.total_time * 1e3:.1f} ms "
          f"-> {res.throughput:.0f} tok/s aggregate "
          f"(goodput @ TTFT<=1s: {res.goodput(1.0):.0f} tok/s)")
    rt = cluster.router
    print(f"router: {rt.assignments} assignments, "
          f"affinity hit rate {100 * rt.affinity_hit_rate:.0f}%, "
          f"{rt.diverted} diverted by load guard, "
          f"{rt.transfers} block transfers "
          f"({rt.transferred_tokens} KV tokens moved)")
    occ = ", ".join(
        f"r{r.idx}={res.metrics[f'cluster.replica.{r.idx}.occupancy']:.2f}"
        for r in cluster.replicas
    )
    print(f"final occupancy: {occ}")
    if args.trace_out:
        trace = cluster.chrome_trace()
        errors = validate_chrome_trace(trace)
        assert not errors, f"trace failed schema validation: {errors[:5]}"
        with open(args.trace_out, "w") as f:
            json.dump(trace, f)
        print(f"trace: {len(trace['traceEvents'])} events across "
              f"{args.replicas} pids -> {args.trace_out}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--smoke", action="store_true", default=True,
                    help="use the reduced config (CPU-runnable)")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--det-ratio", type=float, default=0.25)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--mode", default="llm42",
                    choices=["llm42", "nondet", "batch_invariant"])
    ap.add_argument("--window", type=int, default=8)
    ap.add_argument("--group", type=int, default=4)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--workload", default="synthetic",
                    choices=["synthetic", "sharegpt"])
    ap.add_argument("--scheduler", default="default",
                    choices=["default", "overlap", "pause", "adaptive"],
                    help="verify/decode policy (default: overlap for llm42;"
                         " adaptive demotes high-flip requests to pause-style"
                         " verification and promotes them back)")
    ap.add_argument("--verify-latency-ms", type=float, default=None,
                    help="continuous verdict latency: run the engine on the"
                         " costed dual-stream clock (serving.streams), with"
                         " verdicts landing this many ms after the verify"
                         " stream completes the pass (default: the legacy"
                         " 1-iteration logical shim)")
    ap.add_argument("--spec-depth", type=int, default=1,
                    help="verify windows a deterministic request may have in"
                         " flight at once (multi-window speculation pipeline;"
                         " 1 = the paper's protocol).  Deeper pipelines hide"
                         " verdict latency; rollbacks cascade through later"
                         " windows, and on ssm/hybrid archs the double-"
                         " buffered state pool checkpoints recurrent state"
                         " per window")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="tokens per prefill chunk, co-scheduled with decode"
                         " under the overlap policy (0 = legacy exclusive"
                         " whole-prompt prefill at admission)")
    ap.add_argument("--block-size", type=int, default=16,
                    help="paged-KV block size in tokens (serving.blockpool):"
                         " full-attention KV is allocated block-by-block as"
                         " sequences grow instead of one dense max_seq_len"
                         " ring per slot")
    ap.add_argument("--num-blocks", type=int, default=None,
                    help="KV block-pool size (HBM budget knob); default ="
                         " dense parity (max_batch x capacity/block_size)."
                         " Undersized pools trigger the preemption lane:"
                         " LRU victims are evicted and later restored by"
                         " deterministic recompute of their committed"
                         " stream")
    ap.add_argument("--prefix-cache", default="on", choices=["on", "off"],
                    help="commit-aware radix prefix cache: admissions map"
                         " their longest committed-prefix match to shared"
                         " read-only KV blocks and prefill only the tail")
    ap.add_argument("--tp", type=int, default=1,
                    help="logical tensor-parallel width for the FAST path"
                         " (reduction schedule modeling a TP=N mesh; must"
                         " divide the canonical pinned width).  The commit"
                         " path always replays under the canonical mesh"
                         " schedule, so committed streams are identical at"
                         " any --tp — that invariance is what the analysis"
                         " gate proves")
    ap.add_argument("--replicas", type=int, default=1,
                    help="engine replicas behind the deterministic cluster"
                         " router (repro.cluster): radix-prefix-affinity"
                         " routing with index tie-breaks, cross-replica KV"
                         " block transfer on diverted prefix hits, aggregate"
                         " goodput off the shared cost model")
    ap.add_argument("--qps", type=float, default=0.0,
                    help="replica-mode arrival rate (requests/s of simulated"
                         " time, evenly spaced; 0 = all arrive at t=0)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="export a Chrome/Perfetto trace-event JSON of the"
                         " run (per-request lifecycle spans + main/verify"
                         " stream pass slices; load in ui.perfetto.dev or"
                         " chrome://tracing)")
    ap.add_argument("--metrics-interval", type=int, default=0, metavar="N",
                    help="print a metrics-snapshot line every N engine"
                         " iterations (0 = only the final summary)")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="dump the final metrics-registry snapshot as JSON")
    ap.add_argument("--audit-out", default=None, metavar="PATH",
                    help="write the per-committed-token determinism audit"
                         " log as JSONL (one provenance record per token:"
                         " committing schedule, verify window, n_match,"
                         " top-1/top-2 logit margin)")
    args = ap.parse_args()

    cfg = config_registry.get_smoke_config(args.arch)
    full_cfg = config_registry.get_config(args.arch)
    print(f"arch={cfg.name} mode={args.mode} n={args.requests} "
          f"det_ratio={args.det_ratio}")
    params = init_params(cfg, jax.random.key(0))

    def make_engine(idx: int = 0) -> Engine:
        return Engine(
            cfg, params, mode=Mode(args.mode), policy=FAST_PATH_POLICY,
            window=args.window, group=args.group, max_batch=args.max_batch,
            capacity=min(cfg.max_seq_len, 512),
            scheduler={
                "default": None,
                "overlap": OverlapPolicy(),
                "pause": PauseDecodePolicy(),
                "adaptive": AdaptivePolicy(),
            }[args.scheduler],
            spec_depth=args.spec_depth,
            verify_latency_ms=args.verify_latency_ms,
            cost_cfg=full_cfg,  # deadlines priced at the full model's scale
            prefill_chunk=args.prefill_chunk,
            block_size=args.block_size,
            num_blocks=args.num_blocks,
            prefix_cache=(args.prefix_cache == "on"),
            trace=args.trace_out is not None,
            audit=args.audit_out is not None,
            tp=args.tp,
        )

    reqs = build_requests(cfg, args.requests, args.det_ratio, args.max_new,
                          args.seed, args.workload)

    if args.replicas > 1:
        run_cluster(args, full_cfg, make_engine, reqs)
        return

    engine = make_engine()
    for r in reqs:
        engine.submit(r)
    t0 = time.time()
    if args.metrics_interval > 0:
        done = None
        for it in range(1, 100001):
            if not engine.step():
                done = engine.finished
                break
            if it % args.metrics_interval == 0:
                snap = engine.obs.metrics.snapshot()
                print(f"[iter {it}] committed={snap['tokens.committed']} "
                      f"running={snap['engine.running']} "
                      f"queued={snap['engine.queued']} "
                      f"rollbacks={snap['verify.rollbacks']} "
                      f"verify_inflight={snap['verify.inflight']}")
        assert done is not None, "engine did not drain"
    else:
        done = engine.run()
    wall = time.time() - t0

    out_tokens = sum(r.num_output for r in done)
    rollbacks = sum(r.num_rollbacks for r in done)
    recomputed = sum(r.num_recomputed_tokens for r in done)
    cascaded = sum(r.num_cascaded_windows for r in done)
    sim = costmodel.simulate(
        full_cfg, engine.events,
        invariant_mode=(args.mode == "batch_invariant"),
    )
    print(f"finished {len(done)} requests, {out_tokens} tokens "
          f"in {wall:.1f}s wall")
    print(f"rollbacks={rollbacks} recomputed_tokens={recomputed} "
          f"({100.0 * recomputed / max(out_tokens, 1):.2f}%)")
    print(f"speculation pipeline: depth limit {args.spec_depth}, "
          f"peak in-flight {engine.statepool.peak_depth}, "
          f"cascade-invalidated windows {cascaded}")
    ms = engine.mem_stats()
    if ms["paged"]:
        print(f"paged KV: {ms['num_blocks']} blocks x {ms['block_size']} tok, "
              f"peak in use {ms['peak_blocks_in_use']}, "
              f"peak concurrency {ms['peak_running']}")
        if engine.prefix_cache is not None:
            hits, misses = ms["prefix_hits"], ms["prefix_misses"]
            rate = hits / max(hits + misses, 1)
            print(f"prefix cache: hit rate {100 * rate:.0f}% "
                  f"({ms['prefix_hit_tokens']} tokens served from cache), "
                  f"{ms['prefix_size_blocks']} blocks resident, "
                  f"{ms['prefix_evictions']} evicted")
        print(f"preemption lane: {ms['num_preemptions']} preemptions, "
              f"{ms['num_restores']} restores "
              f"({ms['restored_tokens']} tokens recomputed bitwise)")
    prefill_ms = (sim.get("prefill_s", 0) + sim.get("prefill_chunk_s", 0)) * 1e3
    # a costed engine clock is authoritative (it saw verdict-gated waits
    # that emit no events); the log replay is the fallback for the
    # logical shim
    total_s = (
        engine.runtime.makespan
        if args.verify_latency_ms is not None else sim["total_s"]
    )
    print(f"simulated v5e time: {total_s * 1e3:.1f} ms "
          f"-> {out_tokens / total_s:.0f} tok/s "
          f"(decode {sim.get('decode_s', 0) * 1e3:.1f} ms, "
          f"verify {sim.get('verify_s', 0) * 1e3:.1f} ms, "
          f"prefill {prefill_ms:.1f} ms; "
          f"verify-stream occupancy "
          f"{100.0 * sim.get('verify_occupancy', 0):.0f}%)")
    if args.verify_latency_ms is not None:
        rt = engine.runtime
        print(f"stream clocks: main {rt.main.now * 1e3:.1f} ms, "
              f"verify backlog {rt.verify_backlog * 1e3:.2f} ms, "
              f"makespan {rt.makespan * 1e3:.1f} ms")

    if args.trace_out:
        from repro.obs import validate_chrome_trace

        trace = engine.obs.tracer.to_chrome_trace()
        errors = validate_chrome_trace(trace)
        assert not errors, f"trace failed schema validation: {errors[:5]}"
        with open(args.trace_out, "w") as f:
            json.dump(trace, f)
        print(f"trace: {len(trace['traceEvents'])} events -> {args.trace_out}"
              f" (load in ui.perfetto.dev)")
    if args.metrics_out:
        engine.obs.metrics.dump(args.metrics_out)
        print(f"metrics: {len(engine.obs.metrics.snapshot())} series "
              f"-> {args.metrics_out}")
    if args.audit_out:
        audit = engine.obs.audit
        errors = audit.coverage_errors(done)
        assert not errors, f"audit coverage check failed: {errors[:5]}"
        audit.to_jsonl(args.audit_out)
        print(f"audit: {len(audit.records)} provenance records "
              f"({len(done)} requests, every committed token covered) "
              f"-> {args.audit_out}")


if __name__ == "__main__":
    main()
