"""End-to-end training driver (CPU-runnable on smoke configs; the full-scale
multi-pod path is exercised by launch/dryrun.py).

  PYTHONPATH=src python -m repro.launch.train_launch --arch tinyllama-1.1b \
      --steps 200 --batch 8 --seq 64
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import configs as config_registry
from repro.models import init_params
from repro.models.multimodal import audio_frames
from repro.training.checkpoint import restore, save
from repro.training.data import SyntheticTextStream
from repro.training.optimizer import AdamWConfig, init_opt_state
from repro.training.train import make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--resume", default=None)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = config_registry.get_smoke_config(args.arch)
    print(f"training {cfg.name}: {cfg.param_count() / 1e6:.1f}M params")
    params = init_params(cfg, jax.random.key(0))
    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                          total_steps=args.steps)
    opt_state = init_opt_state(params)
    start_step = 0
    if args.resume:
        params, opt_state, start_step = restore(args.resume, params, opt_state)
        print(f"resumed from {args.resume} at step {start_step}")

    step_fn = jax.jit(make_train_step(cfg, opt_cfg,
                                      num_microbatches=args.microbatches))
    stream = iter(SyntheticTextStream(cfg.vocab_size, args.seq, args.batch))

    t0 = time.time()
    for i in range(start_step, args.steps):
        b = next(stream)
        batch = {
            "tokens": jnp.asarray(b.tokens),
            "targets": jnp.asarray(b.targets),
            "loss_mask": jnp.asarray(b.loss_mask),
        }
        if cfg.family == "encdec":
            batch["enc_embeds"] = audio_frames(
                jax.random.PRNGKey(i), args.batch, cfg.encoder_seq_len,
                cfg.d_model,
            )
        params, opt_state, m = step_fn(params, opt_state, batch)
        if (i + 1) % args.log_every == 0 or i == start_step:
            tps = (i + 1 - start_step) * args.batch * args.seq / (time.time() - t0)
            print(f"step {i + 1:5d}  loss {float(m['loss']):.4f}  "
                  f"gnorm {float(m['grad_norm']):.2f}  lr {float(m['lr']):.2e}  "
                  f"{tps:.0f} tok/s")
    if args.ckpt:
        save(args.ckpt, params, opt_state, step=args.steps)
        print(f"saved checkpoint to {args.ckpt}")


if __name__ == "__main__":
    main()
