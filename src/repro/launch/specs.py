"""Dry-run case construction: (arch × input-shape × mesh) → jittable fn +
ShapeDtypeStruct inputs + shardings.

The four assigned input shapes:

  train_4k     seq 4,096   global_batch 256   -> train_step
  prefill_32k  seq 32,768  global_batch 32    -> prefill (cache fill)
  decode_32k   seq 32,768  global_batch 128   -> serve_step (ONE new token
                                                 against a full KV cache)
  long_500k    seq 524,288 global_batch 1     -> serve_step; only for archs
               with a sub-quadratic long-context variant (DESIGN.md §4)

All inputs are ShapeDtypeStructs — nothing is allocated; the dry-run proves
the distribution config lowers and compiles, and its cost/memory analyses
feed §Roofline.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import configs as config_registry
from repro.core.determinism import VERIFY_SCHEDULE
from repro.distributed import sharding
from repro.models.base import ModelConfig, abstract_params
from repro.models.transformer import cache_spec, forward
from repro.training.optimizer import AdamWConfig
from repro.training.train import make_train_step

F32 = jnp.float32

INPUT_SHAPES: Dict[str, Dict[str, Any]] = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="decode", seq=32768, batch=128),
    "long_500k": dict(kind="decode", seq=524288, batch=1, long=True),
    # EXTRA (beyond the assigned 4): the paper's own mechanism lowered at
    # production scale — one grouped-verification pass, fixed shape
    # (G=8 requests x W=64 window) against 32k caches.  Not part of the
    # 40-pair sweep; used for the DVR-representative §Perf analysis.
    "verify_32k": dict(kind="verify", seq=32768, batch=8, window=64,
                       extra=True),
}

#: decode capacity padding beyond the context length
CAP_PAD = 128


@dataclasses.dataclass
class Case:
    arch: str
    shape: str
    cfg: ModelConfig
    fn: Callable
    args: Tuple[Any, ...]
    in_shardings: Any
    out_shardings: Any
    skipped: Optional[str] = None  # reason if (arch, shape) is inapplicable


def _maybe_batch_spec(batch: int, mesh: Mesh) -> P:
    import numpy as np

    d = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    sizes = dict(mesh.shape)
    axes = list(d)
    while axes and batch % int(np.prod([sizes[a] for a in axes])) != 0:
        axes.pop(0)  # drop pod first, keep data
    if not axes:
        return P(None)
    return P(tuple(axes) if len(axes) > 1 else axes[0])


def _replicated(mesh: Mesh):
    return NamedSharding(mesh, P())


def _ns(mesh: Mesh, tree: Any) -> Any:
    return jax.tree_util.tree_map(lambda p: NamedSharding(mesh, p), tree)


def resolve_config(arch: str, shape: str) -> Tuple[Optional[ModelConfig], Optional[str]]:
    meta = INPUT_SHAPES[shape]
    if meta.get("long"):
        if not config_registry.supports_long(arch):
            return None, (
                f"{arch} is full-attention-only; long_500k requires a "
                "sub-quadratic variant (DESIGN.md long_500k skips)"
            )
        return config_registry.get_long_config(arch), None
    return config_registry.get_config(arch), None


def decode_capacity(cfg: ModelConfig, seq: int) -> int:
    if cfg.attn_kind == "sliding":
        return cfg.window + CAP_PAD  # ring slack (models/transformer.py)
    return seq + CAP_PAD


def build_case(arch: str, shape: str, mesh: Mesh) -> Case:
    cfg, skip = resolve_config(arch, shape)
    if skip:
        return Case(arch, shape, None, None, (), None, None, skipped=skip)
    meta = INPUT_SHAPES[shape]
    kind = meta["kind"]
    B, S = meta["batch"], meta["seq"]
    dtype = jnp.dtype(cfg.dtype)
    bspec = _maybe_batch_spec(B, mesh)

    if kind == "train":
        return _train_case(arch, shape, cfg, mesh, B, S, bspec)

    # serving cases
    rules = sharding.rules_serve(mesh)
    p_shard = sharding.param_shardings(cfg, mesh, rules)
    params = abstract_params(cfg)
    cap = decode_capacity(cfg, S)
    cache = cache_spec(cfg, B, cap)
    cache_shard = _ns(mesh, sharding.cache_pspec_tree(cfg, mesh, B, cap))
    bshard = NamedSharding(mesh, bspec)

    if kind == "prefill":
        n_prefix = cfg.num_prefix_embeds
        S_tok = S - n_prefix  # total context (incl. image tokens) == S

        def prefill_step(params, cache, tokens, prefix_embeds, start_pos):
            if n_prefix:
                tok_embeds = jnp.take(params["embed"], tokens, axis=0)
                embeds = jnp.concatenate([prefix_embeds, tok_embeds], axis=1)
                logits, new_cache, _ = forward(
                    params, cfg, inputs_embeds=embeds, cache=cache,
                    start_pos=start_pos, schedule=VERIFY_SCHEDULE,
                )
            else:
                logits, new_cache, _ = forward(
                    params, cfg, tokens, cache=cache,
                    start_pos=start_pos, schedule=VERIFY_SCHEDULE,
                )
            tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
            return tok, new_cache

        tokens = jax.ShapeDtypeStruct((B, S_tok), jnp.int32)
        prefix = jax.ShapeDtypeStruct((B, n_prefix, cfg.d_model), dtype)
        start = jax.ShapeDtypeStruct((B,), jnp.int32)
        in_sh = (p_shard, cache_shard, bshard, bshard, bshard)
        out_sh = (bshard, cache_shard)
        return Case(arch, shape, cfg, prefill_step,
                    (params, cache, tokens, prefix, start), in_sh, out_sh)

    if kind == "verify":
        G, W = B, meta["window"]
        from repro.serving.sampler import sample_window

        def verify_step(params, cache, inputs, cand, cand_len, start_pos,
                        seeds, temps, out_base):
            logits, new_cache, _ = forward(
                params, cfg, inputs, cache=cache, start_pos=start_pos,
                schedule=VERIFY_SCHEDULE,
            )
            v = sample_window(logits, seeds, out_base, temps)
            cmp = (v[:, : W - 1] == cand).astype(jnp.int32)
            valid = (jnp.arange(W - 1)[None] < cand_len[:, None]).astype(jnp.int32)
            n_match = jnp.sum(jnp.cumprod(cmp * valid, axis=1), axis=1)
            commit = jnp.take_along_axis(v, n_match[:, None], axis=1)[:, 0]
            return n_match, commit, new_cache

        i32 = jnp.int32
        args = (params, cache,
                jax.ShapeDtypeStruct((G, W), i32),
                jax.ShapeDtypeStruct((G, W - 1), i32),
                jax.ShapeDtypeStruct((G,), i32),
                jax.ShapeDtypeStruct((G,), i32),
                jax.ShapeDtypeStruct((G,), i32),
                jax.ShapeDtypeStruct((G,), jnp.float32),
                jax.ShapeDtypeStruct((G,), i32))
        in_sh = (p_shard, cache_shard) + (bshard,) * 7
        out_sh = (bshard, bshard, cache_shard)
        return Case(arch, shape, cfg, verify_step, args, in_sh, out_sh)

    # decode: ONE new token against a cache of S tokens
    def serve_step(params, cache, tokens, start_pos):
        logits, new_cache, _ = forward(
            params, cfg, tokens, cache=cache, start_pos=start_pos,
            schedule=VERIFY_SCHEDULE,
        )
        tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return tok, new_cache

    tokens = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    start = jax.ShapeDtypeStruct((B,), jnp.int32)
    in_sh = (p_shard, cache_shard, bshard, bshard)
    out_sh = (bshard, cache_shard)
    return Case(arch, shape, cfg, serve_step,
                (params, cache, tokens, start), in_sh, out_sh)


def _train_case(arch, shape, cfg, mesh, B, S, bspec) -> Case:
    rules = sharding.rules_train(mesh)
    p_pspecs = sharding.param_pspecs(cfg, mesh, rules)
    p_shard = _ns(mesh, p_pspecs)
    params = abstract_params(cfg)
    dtype = jnp.dtype(cfg.dtype)

    # optimizer state: f32 moments sharded like params; scalar step replicated
    from repro.training.optimizer import OptState

    mu = jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct(s.shape, F32), params
    )
    opt_state = OptState(step=jax.ShapeDtypeStruct((), jnp.int32), mu=mu, nu=mu)
    opt_shard = OptState(step=_replicated(mesh), mu=_ns(mesh, p_pspecs),
                         nu=_ns(mesh, p_pspecs))

    batch: Dict[str, Any] = {
        "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
        "targets": jax.ShapeDtypeStruct((B, S), jnp.int32),
        "loss_mask": jax.ShapeDtypeStruct((B, S), F32),
    }
    bshard = {k: NamedSharding(mesh, bspec) for k in batch}
    if cfg.family == "encdec":
        batch["enc_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.encoder_seq_len, cfg.d_model), dtype
        )
        bshard["enc_embeds"] = NamedSharding(mesh, bspec)

    # microbatch so each microbatch row count matches the data axes (16/32):
    # bounds per-device logits to ~1 row x S x V while staying shardable
    num_mb = max(B // 16, 1)
    opt_cfg = AdamWConfig(total_steps=1000)
    step = make_train_step(cfg, opt_cfg, num_microbatches=num_mb, remat=True)

    metrics_shard = {
        k: _replicated(mesh)
        for k in ("loss", "aux_loss", "dropped_frac", "tokens", "grad_norm", "lr")
    }
    in_sh = (p_shard, opt_shard, bshard)
    out_sh = (p_shard, opt_shard, metrics_shard)
    return Case(arch, shape, cfg, step, (params, opt_state, batch), in_sh, out_sh)
