"""Pallas kernel lint: the universal-schedule rules, checked in source.

The batch-invariant kernel contract (paper §2.3, ``gemm_batch_invariant``):
reduction geometry must be pinned by *literals*, never derived from input
shapes.  For every ``pl.pallas_call`` in scope this pass checks:

* ``grid-reduction-extent`` — a grid axis whose index the ``out_specs``
  index_map ignores is a *reduction* axis (each step folds into the same
  output tile).  Its extent must be literal-derived: an int literal, a
  module-level constant, or ``X // literal`` chains (fixed chunk size ⇒
  the walk order and tree shape are pinned; only the trip count tracks the
  problem).  A function-parameter or shape-derived extent means the
  reduction tree can change with the workload.
* ``adaptive-block-size``     — ``min``/``max`` clamps mixing a block size
  with a shape component (``bm = min(bm, M)``).  Harmless when the axis is
  pure data parallelism, fatal when it feeds a reduction — so it is always
  reported and the harmless cases carry allowlist justifications.
* ``block-spec-shape-derived`` — a ``BlockSpec`` dimension that is neither
  literal-derived nor a whole input axis: partial shape-adaptive tiling.
* ``accum-dtype``             — a VMEM scratch accumulator or a
  ``preferred_element_type`` narrower than f32 inside a kernel body: the
  contract's combine dtype is f32.
* ``shape-branch-in-kernel``  — a Python ``if`` inside a kernel body: it
  branches at *trace time* on static arguments, so the compiled reduction
  structure depends on how the kernel was parameterized.  Runtime
  predication must use ``pl.when``.

Files or functions annotated ``# det: fastpath`` are exempt: they
implement the *licensed* nondeterministic fast path (split-K, kv-split
flash-decode) whose schedules the taint pass proves unreachable from the
commit side.
"""

from __future__ import annotations

import ast
import re
from pathlib import Path
from typing import Dict, List, Optional

from repro.analysis.report import Finding

FASTPATH_RE = re.compile(r"^\s*#\s*det:\s*fastpath\s*$")
_SAFE_ACC_TAILS = {"float32", "f32"}


def _tail(node: ast.expr) -> Optional[str]:
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        return node.attr
    return None


class _Module:
    """Per-file context: module constants, function defs, kernel bodies."""

    def __init__(self, path: Path, rel: str):
        self.rel = rel
        self.src = path.read_text()
        self.tree = ast.parse(self.src, filename=str(path))
        self.lines = self.src.splitlines()
        self.file_fastpath = any(FASTPATH_RE.match(ln) for ln in self.lines)
        self.module_assigns: Dict[str, ast.expr] = {}
        self.functions: Dict[str, ast.FunctionDef] = {}
        for node in self.tree.body:
            if isinstance(node, ast.Assign):
                for tgt in node.targets:
                    if isinstance(tgt, ast.Name):
                        self.module_assigns[tgt.id] = node.value
            elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self.functions[node.name] = node

    def fn_fastpath(self, fn: ast.FunctionDef) -> bool:
        start = min([fn.lineno] + [d.lineno for d in fn.decorator_list])
        prev = start - 2  # 0-indexed line above the def/decorators
        return 0 <= prev < len(self.lines) and bool(FASTPATH_RE.match(self.lines[prev]))


class _FnCtx:
    """Flow-insensitive view of one function containing pallas_call(s)."""

    def __init__(self, mod: _Module, fn: ast.FunctionDef):
        self.mod = mod
        self.fn = fn
        self.params = {
            a.arg for a in fn.args.posonlyargs + fn.args.args + fn.args.kwonlyargs
        }
        self.assigns: Dict[str, ast.expr] = {}
        self.shape_names: set = set()  # names bound to input-shape components
        self.adaptive_names: set = set()  # names already flagged adaptive
        for node in ast.walk(fn):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) and node is not fn:
                continue
            if isinstance(node, ast.Assign):
                val = node.value
                for tgt in node.targets:
                    if isinstance(tgt, ast.Name):
                        self.assigns[tgt.id] = val
                    elif isinstance(tgt, ast.Tuple) and self._is_shape_expr(val):
                        for el in tgt.elts:
                            if isinstance(el, ast.Name):
                                self.shape_names.add(el.id)
                    elif (
                        isinstance(tgt, ast.Tuple)
                        and isinstance(val, ast.Tuple)
                        and len(tgt.elts) == len(val.elts)
                    ):
                        for el, v in zip(tgt.elts, val.elts):
                            if isinstance(el, ast.Name):
                                self.assigns[el.id] = v
                # M = x.shape[0] style
                for tgt in node.targets:
                    if isinstance(tgt, ast.Name) and self._is_shape_expr(val):
                        self.shape_names.add(tgt.id)

    @staticmethod
    def _is_shape_expr(node: ast.expr) -> bool:
        # x.shape / x.shape[i] / x.shape[1], k.shape[2] ...
        if isinstance(node, ast.Attribute) and node.attr == "shape":
            return True
        if isinstance(node, ast.Subscript):
            return _FnCtx._is_shape_expr(node.value)
        if isinstance(node, ast.Tuple):
            return any(_FnCtx._is_shape_expr(e) for e in node.elts)
        return False

    def literal_derived(self, node: ast.expr, depth: int = 0) -> bool:
        """True if the reduction-relevant part of `node` is pinned by literals.

        ``X // bk`` with literal-derived ``bk`` counts: the chunk size (the
        reduction tree's shape) is fixed; only the trip count follows X.
        """
        if depth > 8:
            return False
        if isinstance(node, ast.Constant):
            return isinstance(node.value, int)
        if isinstance(node, ast.Name):
            if node.id in self.shape_names or node.id in self.adaptive_names:
                return False
            if node.id in self.assigns:
                return self.literal_derived(self.assigns[node.id], depth + 1)
            if node.id in self.mod.module_assigns:
                return self.literal_derived(self.mod.module_assigns[node.id], depth + 1)
            return False  # parameter or import: not provably literal
        if isinstance(node, ast.BinOp):
            if isinstance(node.op, ast.FloorDiv):
                return self.literal_derived(node.right, depth + 1)
            if isinstance(node.op, (ast.Mult, ast.Add, ast.Sub)):
                return self.literal_derived(node.left, depth + 1) and self.literal_derived(
                    node.right, depth + 1
                )
        if isinstance(node, ast.UnaryOp):
            return self.literal_derived(node.operand, depth + 1)
        return False

    def is_whole_axis(self, node: ast.expr) -> bool:
        return (
            isinstance(node, ast.Name) and node.id in self.shape_names
        ) or self._is_shape_expr(node)


def _index_map_used_params(spec_call: ast.Call) -> Optional[set]:
    """Grid-parameter indices an index_map lambda actually uses, or None."""
    lam = None
    if len(spec_call.args) >= 2 and isinstance(spec_call.args[1], ast.Lambda):
        lam = spec_call.args[1]
    for kw in spec_call.keywords:
        if kw.arg == "index_map" and isinstance(kw.value, ast.Lambda):
            lam = kw.value
    if lam is None:
        return None
    names = [a.arg for a in lam.args.args]
    used = {n.id for n in ast.walk(lam.body) if isinstance(n, ast.Name)}
    return {i for i, n in enumerate(names) if n in used}


def _resolve_kernel_fn(mod: _Module, entry: ast.expr) -> Optional[ast.FunctionDef]:
    """The kernel function behind pallas_call's first argument."""
    if isinstance(entry, ast.Call) and _tail(entry.func) == "partial" and entry.args:
        entry = entry.args[0]
    if isinstance(entry, ast.Name):
        return mod.functions.get(entry.id)
    return None


def _lint_file(path: Path, rel: str) -> list[Finding]:
    findings: list[Finding] = []
    try:
        mod = _Module(path, rel)
    except SyntaxError as e:
        return [
            Finding(
                pass_name="kernel_lint",
                rule="unparseable",
                where=rel,
                message=f"cannot parse: {e}",
            )
        ]
    if "pallas_call" not in mod.src:
        return []
    if mod.file_fastpath:
        return []

    linted_kernels: set = set()

    for fname, fn in mod.functions.items():
        calls = [
            n
            for n in ast.walk(fn)
            if isinstance(n, ast.Call) and _tail(n.func) == "pallas_call"
        ]
        if not calls:
            continue
        if mod.fn_fastpath(fn):
            continue
        ctx = _FnCtx(mod, fn)
        where = f"{rel}::{fname}"

        def emit(rule: str, lineno: int, message: str) -> None:
            findings.append(
                Finding(
                    pass_name="kernel_lint",
                    rule=rule,
                    where=where,
                    message=f"line {lineno}: {message}",
                )
            )

        # adaptive block sizes anywhere in the wrapper
        for name, val in ctx.assigns.items():
            if (
                isinstance(val, ast.Call)
                and _tail(val.func) in ("min", "max")
                and any(
                    isinstance(a, ast.Name) and a.id in ctx.shape_names
                    for a in val.args
                )
            ):
                ctx.adaptive_names.add(name)
                emit(
                    "adaptive-block-size",
                    val.lineno,
                    f"'{name} = {_tail(val.func)}(...)' clamps a block size "
                    "with an input-shape component: tile geometry adapts to "
                    "the workload (fatal if the axis feeds a reduction)",
                )

        for call in calls:
            kwargs = {kw.arg: kw.value for kw in call.keywords if kw.arg}
            grid = kwargs.get("grid")
            out_specs = kwargs.get("out_specs")
            in_specs = kwargs.get("in_specs")

            # reduction grid axes: ignored by the out_specs index_map
            if grid is not None and isinstance(out_specs, ast.Call):
                used = _index_map_used_params(out_specs)
                dims = (
                    list(grid.elts) if isinstance(grid, ast.Tuple) else [grid]
                )
                if used is not None:
                    for i, dim in enumerate(dims):
                        if i in used:
                            continue
                        if not ctx.literal_derived(dim):
                            emit(
                                "grid-reduction-extent",
                                dim.lineno,
                                f"grid axis {i} is a reduction axis (the "
                                "out_specs index_map ignores it) but its "
                                "extent is not literal-derived: the "
                                "reduction tree shape follows the workload",
                            )

            # BlockSpec block dims: literal-derived or whole-axis
            specs: List[ast.Call] = []
            for spec_src in (in_specs, out_specs):
                if isinstance(spec_src, ast.Call) and _tail(spec_src.func) == "BlockSpec":
                    specs.append(spec_src)
                elif isinstance(spec_src, (ast.List, ast.Tuple)):
                    specs.extend(
                        e
                        for e in spec_src.elts
                        if isinstance(e, ast.Call) and _tail(e.func) == "BlockSpec"
                    )
            for spec in specs:
                if not spec.args or not isinstance(spec.args[0], ast.Tuple):
                    continue
                for dim in spec.args[0].elts:
                    if isinstance(dim, ast.Name) and dim.id in ctx.adaptive_names:
                        continue  # already reported as adaptive-block-size
                    if ctx.literal_derived(dim) or ctx.is_whole_axis(dim):
                        continue
                    emit(
                        "block-spec-shape-derived",
                        dim.lineno,
                        "BlockSpec dimension is neither literal-derived nor "
                        "a whole input axis: shape-adaptive tiling",
                    )

            # f32 accumulators in VMEM scratch
            scratch = kwargs.get("scratch_shapes")
            entries = (
                list(scratch.elts)
                if isinstance(scratch, (ast.List, ast.Tuple))
                else ([scratch] if scratch is not None else [])
            )
            for entry in entries:
                if not (isinstance(entry, ast.Call) and _tail(entry.func) == "VMEM"):
                    continue
                if len(entry.args) < 2:
                    continue
                dt = entry.args[1]
                tail = _tail(dt)
                resolved = tail
                if isinstance(dt, ast.Name) and dt.id in mod.module_assigns:
                    resolved = _tail(mod.module_assigns[dt.id]) or tail
                if resolved is None or resolved.lower() not in _SAFE_ACC_TAILS:
                    emit(
                        "accum-dtype",
                        dt.lineno,
                        f"VMEM scratch accumulator dtype '{resolved or '?'}' "
                        "is not f32: the contract's combine dtype is f32",
                    )

            # the kernel body: trace-time branches + narrow dot accumulators
            kernel = _resolve_kernel_fn(mod, call.args[0] if call.args else None)
            if kernel is None or kernel.name in linted_kernels:
                continue
            linted_kernels.add(kernel.name)
            if mod.fn_fastpath(kernel):
                continue
            kwhere = f"{rel}::{kernel.name}"
            for node in ast.walk(kernel):
                if isinstance(node, ast.If):
                    findings.append(
                        Finding(
                            pass_name="kernel_lint",
                            rule="shape-branch-in-kernel",
                            where=kwhere,
                            message=(
                                f"line {node.lineno}: Python 'if' in a kernel "
                                "body branches at trace time on static "
                                "arguments — compiled reduction structure "
                                "depends on parameterization; use pl.when "
                                "for runtime predication"
                            ),
                        )
                    )
                elif isinstance(node, ast.Call):
                    for kw in node.keywords:
                        if kw.arg != "preferred_element_type":
                            continue
                        tail = _tail(kw.value)
                        resolved = tail
                        if (
                            isinstance(kw.value, ast.Name)
                            and kw.value.id in mod.module_assigns
                        ):
                            resolved = _tail(mod.module_assigns[kw.value.id]) or tail
                        if resolved is None or resolved.lower() not in _SAFE_ACC_TAILS:
                            findings.append(
                                Finding(
                                    pass_name="kernel_lint",
                                    rule="accum-dtype",
                                    where=kwhere,
                                    message=(
                                        f"line {kw.value.lineno}: dot "
                                        f"accumulates in '{resolved or '?'}', "
                                        "not f32: sub-f32 partials make the "
                                        "result depend on the fold order"
                                    ),
                                )
                            )
    return findings


def run_pass(repo_root: Path, files: Optional[List[Path]] = None) -> list[Finding]:
    if files is None:
        files = sorted((repo_root / "src/repro/kernels").glob("*.py"))
    findings: list[Finding] = []
    for path in files:
        rel = str(path.relative_to(repo_root)) if path.is_absolute() else str(path)
        findings.extend(_lint_file(path, rel))
    return findings
