"""Static determinism-contract checker (the trace-time analogue of the
bitwise-identity test suite).

LLM-42's correctness contract — everything that commits a token runs under
a fixed-shape reduction schedule (paper §2.2/§4) — is a *structural*
property of the traced computation: reduction geometry.  The dynamic tests
prove it for the workloads they happen to run; this package proves it from
the jaxprs themselves, so a refactor that silently re-schedules the commit
path fails CI before any stream drifts.

Four passes (run all via ``python -m repro.analysis.check``):

* ``invariance``   — trace the engine's actual jitted steps (verify,
  prefill-chunk, decode) at several batch compositions, canonicalize with
  the batch dim abstracted, and prove the commit-path jaxprs structurally
  identical modulo batch size, per arch class.
* ``hazards``      — walk those jaxprs flagging nondeterminism-hazard
  primitives on commit-feeding (live) paths: overlapping scatters,
  batch-extent reductions, dot_general precision/accumulator drift,
  data-dependent while loops.
* ``taint``        — AST dataflow over ``core/`` + ``serving/`` +
  ``models/``: no ``# det: commit-path`` function may reach a
  schedule-carrying op with a non-``VERIFY_SCHEDULE`` schedule.
* ``kernel_lint``  — structural checks over the Pallas kernels: grid dims
  on reduction axes literal-derived, f32 accumulators, no shape-adaptive
  tiling — fast-path kernels exempted via ``# det: fastpath``.

Findings are suppressed only through ``allowlist.toml``, where every entry
carries a justification string — the exemption set is itself reviewable.
"""

from repro.analysis.report import Finding, Report  # noqa: F401
