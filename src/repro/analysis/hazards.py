"""Hazard lint: nondeterminism-prone primitives on commit-feeding paths.

Walks the (DCE'd) jaxprs the invariance prover traced and flags primitives
that can break the f32 fixed-schedule combine contract:

* ``scatter-add-overlap``      — floating-point ``scatter-add`` without
  ``unique_indices``: duplicate indices combine in hardware-dependent
  order.  Integer scatter-adds are exact (associative) and not flagged.
* ``scatter-set-overlap``      — floating-point ``scatter`` (set) without
  ``unique_indices``: with duplicates, *which* value wins is
  implementation-defined.  The repo's cache writes are
  unique-by-construction but untagged, so these are allowlisted with the
  construction argument spelled out, not silently passed.
* ``batch-extent-reduction``   — a floating-point reduction whose axis
  extent is a multiple of the batch size: its combine tree grows with
  co-scheduled traffic, the exact shape drift the contract forbids.
  Integer reductions are exact at any extent and exempt.
* ``dot-accum-dtype``          — ``dot_general`` accumulating in an
  inexact dtype narrower than f32 (the contract's combine dtype).
* ``dot-default-precision``    — an f32 ``dot_general`` without
  ``Precision.HIGHEST``: on TPU, default precision may drop to bf16
  passes whose number is backend/shape dependent (low-order drift).
* ``data-dependent-while``     — a ``while`` on the commit path: its trip
  count is value-dependent, so the reduction structure is not fixed by
  shape alone.

Findings are attributed to source via each equation's traceback.
"""

from __future__ import annotations

from typing import Iterable

import jax.numpy as jnp

from repro.analysis.jaxpr_utils import eqn_source, walk_all
from repro.analysis.report import Finding

_REDUCE_PRIMS = {
    "reduce_sum",
    "reduce_prod",
    "cumsum",
    "cumprod",
    "cumlogsumexp",
    "reduce_precision",
}
# max/min/argmax select, not combine: exact under any order (ties are
# resolved by index rules, not accumulation), so they are not flagged.


def _is_inexact(dtype) -> bool:
    return jnp.issubdtype(dtype, jnp.inexact)


def _precision_is_highest(precision) -> bool:
    if precision is None:
        return False
    try:
        items = list(precision) if isinstance(precision, (tuple, list)) else [precision]
    except TypeError:
        items = [precision]
    return all("HIGHEST" in str(p) for p in items)


def scan_trace(
    closed, batch: int, *, arch: str, kind: str
) -> list[Finding]:
    """Lint one traced commit-path program (already DCE'd)."""
    findings: list[Finding] = []
    seen: set = set()

    def emit(rule: str, eqn, message: str) -> None:
        where, line = eqn_source(eqn)
        key = (rule, where, message)
        if key in seen:
            return
        seen.add(key)
        at = f" (line {line})" if line else ""
        findings.append(
            Finding(
                pass_name="hazards",
                rule=rule,
                where=where,
                arch=arch,
                message=f"[{arch}:{kind}]{at} {message}",
            )
        )

    def cb(eqn, path) -> None:
        name = eqn.primitive.name
        params = eqn.params
        if name in ("scatter-add", "scatter-mul"):
            out_dtype = eqn.outvars[0].aval.dtype
            if _is_inexact(out_dtype) and not params.get("unique_indices"):
                emit(
                    "scatter-add-overlap",
                    eqn,
                    f"{name} on {out_dtype} without unique_indices: "
                    "duplicate indices combine in hardware order, not the "
                    "fixed f32 schedule",
                )
        elif name == "scatter":
            out_dtype = eqn.outvars[0].aval.dtype
            if _is_inexact(out_dtype) and not params.get("unique_indices"):
                emit(
                    "scatter-set-overlap",
                    eqn,
                    f"scatter-set on {out_dtype} without unique_indices: "
                    "with duplicate indices the winning value is "
                    "implementation-defined",
                )
        elif name in _REDUCE_PRIMS:
            in_aval = eqn.invars[0].aval
            if not _is_inexact(getattr(in_aval, "dtype", jnp.int32)):
                return
            axes = params.get("axes", params.get("axis"))
            if axes is None:
                return
            axes = axes if isinstance(axes, Iterable) else (axes,)
            shape = getattr(in_aval, "shape", ())
            for ax in axes:
                try:
                    extent = int(shape[int(ax)])
                except (IndexError, TypeError, ValueError):
                    continue
                if extent >= batch and extent % batch == 0:
                    emit(
                        "batch-extent-reduction",
                        eqn,
                        f"{name} over axis {ax} of extent {extent} = "
                        f"{extent // batch} x batch({batch}) on "
                        f"{in_aval.dtype}: the combine tree grows with "
                        "co-scheduled traffic",
                    )
        elif name == "dot_general":
            lhs, rhs = (v.aval for v in eqn.invars[:2])
            out = eqn.outvars[0].aval
            if not (_is_inexact(lhs.dtype) or _is_inexact(rhs.dtype)):
                return  # integer dots are exact
            acc = params.get("preferred_element_type") or out.dtype
            if _is_inexact(acc) and jnp.finfo(acc).bits < 32:
                emit(
                    "dot-accum-dtype",
                    eqn,
                    f"dot_general accumulates in {jnp.dtype(acc).name} "
                    f"({lhs.dtype}x{rhs.dtype} operands): the contract "
                    "requires an f32 combine on the commit path",
                )
            if not _precision_is_highest(params.get("precision")):
                emit(
                    "dot-default-precision",
                    eqn,
                    f"dot_general ({lhs.dtype}x{rhs.dtype}) without "
                    "Precision.HIGHEST: default precision may split into "
                    "backend-dependent bf16 passes",
                )
        elif name == "while":
            emit(
                "data-dependent-while",
                eqn,
                "while loop on the commit path: trip count is "
                "value-dependent, so reduction structure is not fixed by "
                "shape alone",
            )

    walk_all(closed, cb)
    return findings


def run_pass(arch_traces) -> list[Finding]:
    """Lint every commit-path trace the invariance pass produced.

    Each program is scanned at its smallest traced batch; the invariance
    pass has already proven the structure identical at the others.
    """
    findings: list[Finding] = []
    merged: dict = {}
    for tr in arch_traces:
        for kind in ("verify", "prefill_chunk", "decode_invariant"):
            per = tr.traces[kind]
            b = min(per)
            for f in scan_trace(per[b], b, arch=tr.arch, kind=kind):
                # the same source line usually appears in several arch
                # traces; report it once with every context listed
                k = f.key() + (f.message.split("] ", 1)[-1],)
                if k in merged:
                    continue
                merged[k] = f
                findings.append(f)
    return findings
