"""Shape-invariance prover: the commit path is batch-invariant by trace.

The LLM-42 contract says the verify/prefill (commit) computations must not
change shape structure with the dynamic batch composition — that is what
makes committed tokens independent of co-scheduled traffic (paper §2.2/§4).
This pass proves it from the programs themselves:

1. Build the real ``serving.Engine`` for each arch class over *abstract*
   parameters (``ShapeDtypeStruct`` trees — nothing is allocated).
2. Trace its actual jitted steps — the grouped verify pass
   (``core.verifier.make_verify_fn``), the chunked-prefill step, and the
   batch-invariant decode step — at several batch compositions.
3. Canonicalize each jaxpr (``jaxpr_utils.canonicalize``) and require the
   canonical forms to be structurally identical across batch sizes, with
   integer pairs allowed to differ only as batch-affine dimensions
   ``k*B + c`` (``jaxpr_utils.compare_canonical``) — the form taken by
   every legitimate batch-derived extent (``G*W``, ``G*(W-1)``, mamba's
   conv-pad ``C + d_conv - 1``, jamba's MoE overflow bucket ``E*T + 1``).

Batch sizes are primes >= 13 (13/17/19): every model dimension in the
smoke configs is a power of two and every structural constant (axis
indices, window, block size) sits outside the affine window, so a
dimension that fits ``k*B + c`` consistently across traces really is
batch-derived and nothing else can fake it.

A negative control guards the prover itself against vacuity: the
fast-path decode step traced under ``FAST_PATH_POLICY`` *crosses a
split-count threshold* between 13 and 17 rows, so its canonical forms
must differ; if they do not, the canonicalizer has gone blind and the
pass fails itself.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict

import jax
import jax.numpy as jnp

from repro.analysis.jaxpr_utils import canonicalize, compare_canonical, dce
from repro.analysis.report import Finding
from repro.configs import get_smoke_config
from repro.core.determinism import FAST_PATH_POLICY, INVARIANT_SCHEDULE, Mode
from repro.core.verifier import make_verify_fn
from repro.models.base import ModelConfig, abstract_params
from repro.serving.engine import Engine

BATCHES = (13, 17, 19)
WINDOW = 8
CAPACITY = 128
BLOCK_SIZE = 16
MAX_BATCH = 20  # engine slots; >= max(BATCHES), never divisible by them
#: Mesh widths the TP-invariance pass parameterizes engines over.  Every
#: width divides CANONICAL_TP_SHARDS, so the pinned commit tree is
#: realizable on all of them (distributed.sharding.tp_matmul).
MESH_TPS = (1, 2, 4)
#: Fixed batch for the mesh pass: mesh traces vary TP at constant batch,
#: so batch-affine allowances must not fire — any divergence is a leak.
MESH_BATCH = 13
#: Arch classes the mesh pass sweeps: attention covers the pure-KV commit
#: path, jamba the recurrent+MoE hybrid — between them every commit-path
#: GEMM family is traced.  (The batch pass already sweeps all four; the
#: mesh pass keeps the blocking gate's trace budget bounded.)
MESH_ARCHES = ("attention", "jamba")


def _ssm_smoke() -> ModelConfig:
    """A mamba-carrying config without MoE: the 'ssm' contract class.

    The config zoo has no pure-mamba smoke entry (``family="ssm"`` maps to
    rwkv layers; hybrids always place attention at layer 0), so the ssm
    class is exercised through a 2-layer attn+mamba stack with the MoE
    stripped — the traced computation is dominated by the mamba
    conv/selective-scan leaves, which is what "ssm" means contract-wise.
    """
    base = get_smoke_config("jamba-1.5-large-398b")
    return dataclasses.replace(
        base,
        name="mamba-ssm-smoke",
        num_layers=2,
        num_experts=0,
        top_k=0,
        moe_d_ff=0,
    )


ARCH_CLASSES: Dict[str, Callable[[], ModelConfig]] = {
    "attention": lambda: get_smoke_config("llama3-8b"),
    "ssm": _ssm_smoke,
    "rwkv6": lambda: get_smoke_config("rwkv6-3b"),
    "jamba": lambda: get_smoke_config("jamba-1.5-large-398b"),
}


def build_engine(cfg: ModelConfig, tp: int = 1) -> Engine:
    """Engine over abstract params — real layout/metadata, no weights."""
    return Engine(
        cfg,
        abstract_params(cfg),
        mode=Mode.LLM42,
        window=WINDOW,
        group=4,  # replaced per-trace; Engine just needs a valid value
        max_batch=MAX_BATCH,
        capacity=CAPACITY,
        block_size=BLOCK_SIZE,
        prefill_chunk=BLOCK_SIZE,
        tp=tp,
    )


def _abstract_tree(tree):
    return jax.tree_util.tree_map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), tree
    )


def _num_table_blocks(engine: Engine) -> int:
    return engine.pool.table_array([[0]]).shape[1]


def trace_verify(engine: Engine, G: int):
    """Jaxpr of the grouped verify pass at group size G."""
    cfg = engine.cfg
    vfn = make_verify_fn(
        cfg, G, WINDOW, engine.pool.layout, paged=engine._paged_fwd
    )
    nblk = _num_table_blocks(engine)
    sds = jax.ShapeDtypeStruct
    W = WINDOW
    args = [
        sds((G,), jnp.int32),  # slots
        sds((G, nblk), jnp.int32),  # tables
        sds((G,), jnp.int32),  # start_pos
        sds((G, W), jnp.int32),  # inputs
        sds((G, W - 1), jnp.int32),  # cand
        sds((G,), jnp.int32),  # cand_len
        sds((G,), jnp.int32),  # seeds
        sds((G,), jnp.float32),  # temps
        sds((G,), jnp.int32),  # out_base
        sds((G,), jnp.int32),  # top_ks
    ]
    apool = _abstract_tree(engine.pool.data)
    if engine.has_recurrent_state:
        aanchor = _abstract_tree(engine.statepool.anchor)
        return jax.make_jaxpr(vfn)(engine.params, apool, aanchor, *args)
    return jax.make_jaxpr(vfn)(engine.params, apool, *args)


def trace_prefill_chunk(engine: Engine, C: int):
    """Jaxpr of the chunk-resumable prefill step at chunk width C."""
    step = engine._prefill_chunk_fn(C)
    nblk = _num_table_blocks(engine)
    sds = jax.ShapeDtypeStruct
    embed_dtype = engine.params["embed"].dtype
    apool = _abstract_tree(engine.pool.data)
    return jax.make_jaxpr(step)(
        engine.params,
        apool,
        sds((), jnp.int32),  # slot
        sds((nblk,), jnp.int32),  # table
        sds((1, C, engine.cfg.d_model), embed_dtype),  # embeds
        sds((), jnp.int32),  # start
        sds((), jnp.int32),  # last
    )


def trace_decode(engine: Engine, B: int, schedule):
    """Jaxpr of the decode step at batch B under a given schedule."""
    step = engine._decode_fn(B, schedule)
    nblk = _num_table_blocks(engine)
    sds = jax.ShapeDtypeStruct
    apool = _abstract_tree(engine.pool.data)
    i32 = jnp.int32
    return jax.make_jaxpr(step)(
        engine.params,
        apool,
        sds((B,), i32),  # slots
        sds((B, nblk), i32),  # tables
        sds((B,), i32),  # tokens
        sds((B,), i32),  # pos
        sds((B,), i32),  # seeds
        sds((B,), jnp.float32),  # temps
        sds((B,), i32),  # out_pos
        sds((B,), i32),  # top_ks
    )


@dataclasses.dataclass
class ArchTraces:
    arch: str
    cfg: ModelConfig
    # kind -> batch -> ClosedJaxpr (kinds: verify, prefill_chunk,
    # decode_invariant; plus decode_fast for the negative control)
    traces: Dict[str, Dict[int, object]]
    canon: Dict[str, Dict[int, str]]


def trace_arch(arch: str, batches=BATCHES) -> ArchTraces:
    cfg = ARCH_CLASSES[arch]()
    engine = build_engine(cfg)
    traces: Dict[str, Dict[int, object]] = {
        "verify": {},
        "prefill_chunk": {},
        "decode_invariant": {},
        "decode_fast": {},
    }
    for b in batches:
        # DCE first: equations that never feed an output (MoE aux stats in
        # the serving forward) are outside the commit contract
        traces["verify"][b] = dce(trace_verify(engine, b))
        traces["prefill_chunk"][b] = dce(trace_prefill_chunk(engine, b))
        traces["decode_invariant"][b] = dce(
            trace_decode(engine, b, INVARIANT_SCHEDULE)
        )
    # negative control: only two points needed, chosen to straddle a
    # FAST_PATH_POLICY split-count threshold (13 rows -> 4 splits,
    # 17 rows -> 2 splits)
    for b in batches[:2]:
        traces["decode_fast"][b] = dce(
            trace_decode(engine, b, FAST_PATH_POLICY.schedule_for(b))
        )
    canon = {
        kind: {b: canonicalize(jx, b) for b, jx in per.items()}
        for kind, per in traces.items()
    }
    return ArchTraces(arch=arch, cfg=cfg, traces=traces, canon=canon)


# commit-path kinds that must be invariant; decode_fast must NOT be
_INVARIANT_KINDS = ("verify", "prefill_chunk", "decode_invariant")


def prove(tr: ArchTraces) -> tuple[list[Finding], dict]:
    findings: list[Finding] = []
    cert: dict = {"arch": tr.arch, "config": tr.cfg.name, "kinds": {}}
    for kind in _INVARIANT_KINDS:
        per = tr.canon[kind]
        batches = sorted(per)
        ref_b = batches[0]
        ref = per[ref_b]
        invariant = True
        for b in batches[1:]:
            div = compare_canonical(ref, per[b], ref_b, b)
            if div is None:
                continue
            invariant = False
            line, a, bb = div
            findings.append(
                Finding(
                    pass_name="invariance",
                    rule="batch-variant-commit-path",
                    where=f"trace::{tr.arch}::{kind}",
                    arch=tr.arch,
                    message=(
                        f"{kind} jaxpr differs between batch {ref_b} and "
                        f"{b} at canonical line {line}:\n"
                        f"      B={ref_b}: {a}\n      B={b}: {bb}\n"
                        "    the commit path must run one batch-invariant "
                        "schedule (paper §2.2/§4)"
                    ),
                )
            )
        cert["kinds"][kind] = {
            "batches": batches,
            "invariant": invariant,
            "canonical_lines": len(ref.splitlines()),
        }
    # negative control: the prover must be able to SEE schedule changes
    fast = tr.canon["decode_fast"]
    b0, b1 = sorted(fast)[:2]
    control_ok = compare_canonical(fast[b0], fast[b1], b0, b1) is not None
    cert["negative_control"] = {
        "kind": "decode_fast",
        "batches": [b0, b1],
        "schedules_differ": control_ok,
    }
    if not control_ok:
        findings.append(
            Finding(
                pass_name="invariance",
                rule="prover-self-check",
                where=f"trace::{tr.arch}::decode_fast",
                arch=tr.arch,
                message=(
                    f"fast-path decode at B={b0} (schedule "
                    f"{tuple(FAST_PATH_POLICY.schedule_for(b0))}) and B={b1} "
                    f"(schedule {tuple(FAST_PATH_POLICY.schedule_for(b1))}) "
                    "canonicalized identically — the canonicalizer can no "
                    "longer distinguish reduction schedules, so the "
                    "invariance certificates above are vacuous"
                ),
            )
        )
    return findings, cert


def trace_arch_mesh(arch: str, tps=MESH_TPS, batch: int = MESH_BATCH) -> ArchTraces:
    """Trace the engine's steps from engines built at each TP width.

    The commit kinds (verify / prefill_chunk / decode_invariant) are traced
    through engines constructed with ``tp=t`` — if any mesh parameter
    leaked into a commit-path program, the jaxprs would differ across
    ``t``.  The fast-path decode is traced under each engine's OWN
    ``_decode_schedule`` (which threads ``tp`` un-pinned), giving the
    negative control: the canonicalizer demonstrably sees TP when it is
    present, so identical commit traces are a real proof, not blindness.

    Reuses :class:`ArchTraces` with the TP width in the batch-key slot;
    batch is held fixed so no batch-affine allowance can mask a leak.
    """
    cfg = ARCH_CLASSES[arch]()
    traces: Dict[str, Dict[int, object]] = {
        "verify": {},
        "prefill_chunk": {},
        "decode_invariant": {},
        "decode_fast": {},
    }
    for t in tps:
        engine = build_engine(cfg, tp=t)
        traces["verify"][t] = dce(trace_verify(engine, batch))
        traces["prefill_chunk"][t] = dce(trace_prefill_chunk(engine, batch))
        traces["decode_invariant"][t] = dce(
            trace_decode(engine, batch, INVARIANT_SCHEDULE)
        )
    # negative control: widest vs no mesh; the un-pinned tp_shards in the
    # fast schedule must change the traced reduction structure
    for t in (min(tps), max(tps)):
        engine = build_engine(cfg, tp=t)
        traces["decode_fast"][t] = dce(
            trace_decode(engine, batch, engine._decode_schedule(batch))
        )
    canon = {
        kind: {t: canonicalize(jx, batch) for t, jx in per.items()}
        for kind, per in traces.items()
    }
    return ArchTraces(arch=arch, cfg=cfg, traces=traces, canon=canon)


def prove_mesh(tr: ArchTraces, batch: int = MESH_BATCH) -> tuple[list[Finding], dict]:
    """Mesh-shape analogue of :func:`prove`: commit kinds must canonicalize
    identically across TP widths (batch is constant, so ``compare_canonical``
    runs with equal batch keys — every affine allowance degenerates to
    exact equality), and the un-pinned fast path must NOT."""
    findings: list[Finding] = []
    cert: dict = {"arch": tr.arch, "config": tr.cfg.name, "kinds": {}}
    for kind in _INVARIANT_KINDS:
        per = tr.canon[kind]
        tps = sorted(per)
        ref = per[tps[0]]
        invariant = True
        for t in tps[1:]:
            div = compare_canonical(ref, per[t], batch, batch)
            if div is None:
                continue
            invariant = False
            line, a, bb = div
            findings.append(
                Finding(
                    pass_name="invariance",
                    rule="mesh-variant-commit-path",
                    where=f"trace::{tr.arch}::{kind}",
                    arch=tr.arch,
                    message=(
                        f"{kind} jaxpr differs between TP {tps[0]} and "
                        f"{t} at canonical line {line}:\n"
                        f"      TP={tps[0]}: {a}\n      TP={t}: {bb}\n"
                        "    the commit path must replay under the "
                        "canonical mesh-reduction schedule regardless of "
                        "the fast path's mesh (TP-invariance)"
                    ),
                )
            )
        cert["kinds"][kind] = {
            "tps": tps,
            "invariant": invariant,
            "canonical_lines": len(ref.splitlines()),
        }
    fast = tr.canon["decode_fast"]
    t0, t1 = sorted(fast)[:2]
    control_ok = compare_canonical(fast[t0], fast[t1], batch, batch) is not None
    cert["negative_control"] = {
        "kind": "decode_fast",
        "tps": [t0, t1],
        "schedules_differ": control_ok,
    }
    if not control_ok:
        findings.append(
            Finding(
                pass_name="invariance",
                rule="prover-self-check",
                where=f"trace::{tr.arch}::decode_fast",
                arch=tr.arch,
                message=(
                    f"fast-path decode at TP={t0} and TP={t1} canonicalized "
                    "identically — the canonicalizer cannot see TP "
                    "reduction decomposition, so the mesh-invariance "
                    "certificates above are vacuous"
                ),
            )
        )
    return findings, cert


def run_mesh_pass(
    tps=MESH_TPS, arches=MESH_ARCHES, batch: int = MESH_BATCH
) -> tuple[list[Finding], dict]:
    """Trace + prove TP-invariance of the commit path (certs keyed
    ``mesh::<arch>``)."""
    findings: list[Finding] = []
    certs: dict = {}
    for arch in arches:
        tr = trace_arch_mesh(arch, tps, batch)
        f, cert = prove_mesh(tr, batch)
        findings.extend(f)
        certs[f"mesh::{arch}"] = cert
    return findings, certs


def run_pass(batches=BATCHES, arches=None) -> tuple[list[Finding], dict, list]:
    """Trace + prove all arch classes.

    Returns ``(findings, certificates, arch_traces)`` — the traces are
    reused by the hazard pass so each program is traced once.  Batch
    invariance here; mesh (TP) invariance in :func:`run_mesh_pass`.
    """
    findings: list[Finding] = []
    certs: dict = {}
    all_traces: list[ArchTraces] = []
    for arch in arches or ARCH_CLASSES:
        tr = trace_arch(arch, batches)
        all_traces.append(tr)
        f, cert = prove(tr)
        findings.extend(f)
        certs[arch] = cert
    return findings, certs, all_traces
