"""Schedule-taint pass: no fast-path schedule can reach the commit side.

An AST dataflow check over ``core/`` + ``serving/`` + ``models/``.  Commit
roots are marked in source with a ``# det: commit-path`` comment on the
line above the ``def`` (above its decorators, if any); the checker keeps a
built-in list of functions that are *expected* to be roots — the places
that bind schedules for verify/prefill — so deleting an annotation is
itself a finding, not a silent hole.

From the roots, reachability is computed over a name-matched call graph
(conservative: a call edge goes to every known function with that bare
name, nested functions included).  Within commit-reachable code:

* any expression classified FAST — ``FAST_PATH_POLICY``, a
  ``.schedule_for(...)`` call, or a ``Schedule(...)`` literal with
  ``splits/kv_splits != 1`` or a sub-f32 combine dtype — is a finding
  (``fast-schedule-on-commit-path``);
* any ``schedule=`` keyword argument whose value cannot be shown SAFE
  (``VERIFY_SCHEDULE``/``INVARIANT_SCHEDULE``, a safe ternary over them, a
  parameter threaded from an already-checked caller) is a finding
  (``unresolved-schedule``).

Under ``Mode.LLM42``/``Mode.BATCH_INVARIANT`` both ternary arms in the
engine's prefill builders resolve SAFE; the fast path (``_decode_step``)
is deliberately NOT commit-reachable — nondeterministic decode is the
contract's licensed speculation, repaired by verification.
"""

from __future__ import annotations

import ast
import dataclasses
import re
from pathlib import Path
from typing import Dict, List, Optional

from repro.analysis.report import Finding

# classification lattice (join = max)
SAFE, PARAM, UNKNOWN, FAST = 0, 1, 2, 3
_LEVEL_NAME = {SAFE: "SAFE", PARAM: "PARAM", UNKNOWN: "UNKNOWN", FAST: "FAST"}

SAFE_NAMES = {"VERIFY_SCHEDULE", "INVARIANT_SCHEDULE"}
FAST_NAMES = {"FAST_PATH_POLICY"}
FAST_CALLS = {"schedule_for"}
_SAFE_DTYPES = {"float32", "f32"}

ANNOTATION_RE = re.compile(r"^\s*#\s*det:\s*commit-path\s*$")

# Functions that must carry the `# det: commit-path` annotation: every
# place that binds a schedule on the verify/commit side.  A missing
# annotation (e.g. dropped in a refactor) fails the check.
EXPECTED_ROOTS = frozenset(
    {
        "src/repro/core/verifier.py::make_verify_fn",
        "src/repro/serving/engine.py::Engine._prefill_fn",
        "src/repro/serving/engine.py::Engine._prefill_chunk_fn",
        "src/repro/serving/engine.py::Engine._prefill",
        "src/repro/models/transformer.py::build_cross_cache",
    }
)

DEFAULT_SCOPE = ("src/repro/core", "src/repro/serving", "src/repro/models")


@dataclasses.dataclass
class FuncInfo:
    qualname: str  # "Class.method" / "outer.inner"
    file: str  # repo-relative path
    node: ast.AST
    parent: Optional["FuncInfo"]
    params: Dict[str, Optional[ast.expr]]  # name -> default expr (or None)
    assigns: Dict[str, List[ast.expr]]
    is_root: bool = False

    @property
    def where(self) -> str:
        return f"{self.file}::{self.qualname}"

    @property
    def bare(self) -> str:
        return self.qualname.rsplit(".", 1)[-1]


def _tail(func: ast.expr) -> Optional[str]:
    if isinstance(func, ast.Name):
        return func.id
    if isinstance(func, ast.Attribute):
        return func.attr
    return None


class _Collector(ast.NodeVisitor):
    def __init__(self, file: str, root_lines: set, registry: list):
        self.file = file
        self.root_lines = root_lines
        self.registry = registry
        self.stack: List[FuncInfo] = []
        self.class_stack: List[str] = []

    def _qual(self, name: str) -> str:
        parts = [f.bare for f in self.stack] or list(self.class_stack)
        if self.stack and self.class_stack:
            # methods: class prefix then function nesting
            parts = list(self.class_stack) + [f.bare.split(".")[-1] for f in self.stack]
        return ".".join(parts + [name]) if parts else name

    def visit_ClassDef(self, node: ast.ClassDef) -> None:
        self.class_stack.append(node.name)
        self.generic_visit(node)
        self.class_stack.pop()

    def _visit_func(self, node) -> None:
        start = min(
            [node.lineno] + [d.lineno for d in node.decorator_list]
        )
        qual = self._qual(node.name)
        args = node.args
        params: Dict[str, Optional[ast.expr]] = {}
        pos = list(args.posonlyargs) + list(args.args)
        defaults = list(args.defaults)
        for i, a in enumerate(pos):
            di = i - (len(pos) - len(defaults))
            params[a.arg] = defaults[di] if di >= 0 else None
        for a, d in zip(args.kwonlyargs, args.kw_defaults):
            params[a.arg] = d
        if args.vararg:
            params[args.vararg.arg] = None
        if args.kwarg:
            params[args.kwarg.arg] = None
        info = FuncInfo(
            qualname=qual,
            file=self.file,
            node=node,
            parent=self.stack[-1] if self.stack else None,
            params=params,
            assigns={},
            is_root=(start - 1) in self.root_lines,
        )
        self.registry.append(info)
        self.stack.append(info)
        for child in node.body:
            self._scan_assigns(child, info)
        self.generic_visit(node)
        self.stack.pop()

    visit_FunctionDef = _visit_func
    visit_AsyncFunctionDef = _visit_func

    def _scan_assigns(self, node: ast.AST, info: FuncInfo) -> None:
        # flow-insensitive: record every assignment to a bare name in this
        # function's immediate body (conditionals included, nested defs not)
        for sub in ast.walk(node):
            if isinstance(sub, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            if isinstance(sub, ast.Assign):
                for tgt in sub.targets:
                    if isinstance(tgt, ast.Name):
                        info.assigns.setdefault(tgt.id, []).append(sub.value)
            elif isinstance(sub, ast.AnnAssign) and sub.value is not None:
                if isinstance(sub.target, ast.Name):
                    info.assigns.setdefault(sub.target.id, []).append(sub.value)


def _collect(path: Path, rel: str) -> tuple[list[FuncInfo], list[int], set]:
    src = path.read_text()
    root_lines = {
        i for i, line in enumerate(src.splitlines(), start=1)
        if ANNOTATION_RE.match(line)
    }
    tree = ast.parse(src, filename=str(path))
    registry: list[FuncInfo] = []
    _Collector(rel, root_lines, registry).visit(tree)
    used = {
        min([f.node.lineno] + [d.lineno for d in f.node.decorator_list]) - 1
        for f in registry
        if f.is_root
    }
    dangling = sorted(root_lines - used)
    return registry, dangling, root_lines


class _Classifier:
    def __init__(self, by_bare: Dict[str, List[FuncInfo]]):
        self.by_bare = by_bare

    def classify(self, expr: ast.expr, scope: Optional[FuncInfo], seen=None) -> int:
        seen = seen or set()
        if isinstance(expr, (ast.Name, ast.Attribute)):
            tail = expr.id if isinstance(expr, ast.Name) else expr.attr
            if tail in SAFE_NAMES:
                return SAFE
            if tail in FAST_NAMES:
                return FAST
            if isinstance(expr, ast.Name):
                return self._resolve_name(expr.id, scope, seen)
            return UNKNOWN
        if isinstance(expr, ast.Call):
            tail = _tail(expr.func)
            if tail in FAST_CALLS:
                return FAST
            if tail == "Schedule":
                return self._classify_schedule_ctor(expr)
            return UNKNOWN
        if isinstance(expr, ast.IfExp):
            return max(
                self.classify(expr.body, scope, seen),
                self.classify(expr.orelse, scope, seen),
            )
        if isinstance(expr, (ast.Tuple, ast.List)) and expr.elts:
            return max(self.classify(e, scope, seen) for e in expr.elts)
        return UNKNOWN

    def _resolve_name(self, name: str, scope: Optional[FuncInfo], seen) -> int:
        s = scope
        while s is not None:
            key = (id(s), name)
            if key in seen:
                return UNKNOWN  # assignment cycle
            if name in s.assigns:
                seen = seen | {key}
                return max(
                    self.classify(v, s, seen) for v in s.assigns[name]
                )
            if name in s.params:
                default = s.params[name]
                if default is not None:
                    return max(PARAM, self.classify(default, s, seen))
                return PARAM
            s = s.parent
        return UNKNOWN

    def _classify_schedule_ctor(self, call: ast.Call) -> int:
        level = SAFE
        fields = ("splits", "kv_splits", "combine_dtype", "moe_no_drop")
        bound: Dict[str, ast.expr] = {}
        for i, a in enumerate(call.args):
            if i < len(fields):
                bound[fields[i]] = a
        for kw in call.keywords:
            if kw.arg:
                bound[kw.arg] = kw.value
        for field in ("splits", "kv_splits"):
            v = bound.get(field)
            if v is None:
                continue
            if isinstance(v, ast.Constant) and v.value == 1:
                continue
            return FAST
        v = bound.get("combine_dtype")
        if v is not None:
            tail = _tail(v) if isinstance(v, (ast.Name, ast.Attribute, ast.Call)) else None
            if isinstance(v, (ast.Name, ast.Attribute)):
                tail = v.id if isinstance(v, ast.Name) else v.attr
            if tail not in _SAFE_DTYPES:
                return FAST
        return level


def scan_files(
    files: List[Path], repo_root: Path, *, expected_roots=EXPECTED_ROOTS
) -> list[Finding]:
    findings: list[Finding] = []
    registry: list[FuncInfo] = []
    for path in files:
        rel = str(path.relative_to(repo_root)) if path.is_absolute() else str(path)
        try:
            file_funcs, dangling, _ = _collect(path, rel)
        except SyntaxError as e:
            findings.append(
                Finding(
                    pass_name="taint",
                    rule="unparseable",
                    where=rel,
                    message=f"cannot parse: {e}",
                )
            )
            continue
        registry.extend(file_funcs)
        for line in dangling:
            findings.append(
                Finding(
                    pass_name="taint",
                    rule="dangling-annotation",
                    where=f"{rel}::line{line}",
                    message=(
                        f"'# det: commit-path' at {rel}:{line} is not "
                        "attached to a function definition (it must sit on "
                        "the line above the def / its first decorator)"
                    ),
                )
            )

    by_where = {f.where: f for f in registry}
    for want in sorted(expected_roots):
        f = by_where.get(want)
        if f is None:
            continue  # function gone entirely: scope tests cover renames
        if not f.is_root:
            findings.append(
                Finding(
                    pass_name="taint",
                    rule="unannotated-commit-root",
                    where=want,
                    message=(
                        "this function binds schedules on the commit side "
                        "and must carry a '# det: commit-path' annotation "
                        "on the line above its definition"
                    ),
                )
            )

    by_bare: Dict[str, List[FuncInfo]] = {}
    for f in registry:
        by_bare.setdefault(f.bare, []).append(f)

    # commit-reachability over the name-matched call graph.  Nested
    # functions are visited as part of their enclosing body, so edges only
    # need to resolve outward calls.
    roots = [f for f in registry if f.is_root and f.parent is None]
    reachable: Dict[str, FuncInfo] = {}
    work = list(roots)
    while work:
        f = work.pop()
        if f.where in reachable:
            continue
        reachable[f.where] = f
        for sub in ast.walk(f.node):
            if not isinstance(sub, ast.Call):
                continue
            tail = _tail(sub.func)
            if not tail:
                continue
            for g in by_bare.get(tail, ()):
                if g.parent is None and g.where not in reachable:
                    work.append(g)

    classifier = _Classifier(by_bare)

    def innermost_scope(top: FuncInfo, node: ast.AST) -> FuncInfo:
        # find the innermost nested function containing `node`
        best = top
        lineno = getattr(node, "lineno", None)
        if lineno is None:
            return best
        for g in registry:
            if g.file != top.file:
                continue
            n = g.node
            if (
                g.where != top.where
                and g.qualname.startswith(top.qualname + ".")
                and n.lineno <= lineno <= (n.end_lineno or n.lineno)
                and n.lineno >= best.node.lineno
            ):
                best = g
        return best

    seen_lines: set = set()
    for f in reachable.values():
        for sub in ast.walk(f.node):
            if isinstance(sub, ast.Call):
                for kw in sub.keywords:
                    if kw.arg != "schedule":
                        continue
                    scope = innermost_scope(f, sub)
                    level = classifier.classify(kw.value, scope)
                    if level == FAST:
                        key = (f.file, kw.value.lineno, "fast")
                        if key in seen_lines:
                            continue
                        seen_lines.add(key)
                        findings.append(
                            Finding(
                                pass_name="taint",
                                rule="fast-schedule-on-commit-path",
                                where=f.where,
                                message=(
                                    f"line {kw.value.lineno}: schedule= "
                                    "argument classifies FAST on a "
                                    "commit-reachable path — the commit side "
                                    "must run VERIFY_SCHEDULE"
                                ),
                            )
                        )
                    elif level == UNKNOWN:
                        key = (f.file, kw.value.lineno, "unk")
                        if key in seen_lines:
                            continue
                        seen_lines.add(key)
                        findings.append(
                            Finding(
                                pass_name="taint",
                                rule="unresolved-schedule",
                                where=f.where,
                                message=(
                                    f"line {kw.value.lineno}: schedule= "
                                    "argument cannot be proven "
                                    "VERIFY/INVARIANT on a commit-reachable "
                                    "path — thread it from a checked "
                                    "binding or restructure"
                                ),
                            )
                        )
            elif isinstance(sub, (ast.Attribute, ast.Name)):
                tail = sub.id if isinstance(sub, ast.Name) else sub.attr
                if tail in FAST_NAMES or (
                    isinstance(sub, ast.Attribute) and sub.attr in FAST_CALLS
                ):
                    key = (f.file, sub.lineno, "fastref")
                    if key in seen_lines:
                        continue
                    seen_lines.add(key)
                    findings.append(
                        Finding(
                            pass_name="taint",
                            rule="fast-schedule-on-commit-path",
                            where=f.where,
                            message=(
                                f"line {sub.lineno}: reference to "
                                f"'{tail}' inside commit-reachable code — "
                                "fast-path reduction policies must not be "
                                "visible from the commit side"
                            ),
                        )
                    )
    return findings


def run_pass(repo_root: Path) -> list[Finding]:
    files: list[Path] = []
    for scope in DEFAULT_SCOPE:
        files.extend(sorted((repo_root / scope).glob("*.py")))
    return scan_files(files, repo_root)
