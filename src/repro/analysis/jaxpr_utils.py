"""Jaxpr plumbing shared by the invariance prover and the hazard lint.

Three pieces:

* ``canonicalize(closed_jaxpr, batch)`` — render a jaxpr to a canonical
  text form: variables alpha-renamed by first appearance, nested jaxprs
  (pjit bodies, scan bodies, cond branches) emitted as labelled blocks in
  deterministic order.  ``compare_canonical(a, b, b1, b2)`` then checks
  two canonical forms for structural equality *modulo batch size*: lines
  must be identical except for integers, and an integer pair ``(d1, d2)``
  may differ only as a batch-affine dimension ``d = k*B + c`` with integer
  ``k >= 1`` and ``|c| <= 8`` consistent across the pair.  The affine form
  covers the real batch-derived dims (``G*W``, ``G*(W-1)``, a conv-pad
  ``C + d_conv - 1``, the MoE overflow bucket ``E*T + 1``) while a genuine
  schedule change — e.g. split-K going 4 -> 2, making a 64 -> 128 chunk —
  cannot satisfy it (the offset would be -144).  Batch sizes are chosen
  prime and >= 13 by the caller so model dims (powers of two in the smoke
  configs) and small structural constants stay clear of the affine window.
* ``walk_live(closed_jaxpr, cb)`` — visit equations that feed the jaxpr's
  outputs (``cb(eqn, path)``), skipping dead code.  ``jax.make_jaxpr``
  keeps equations whose results are dropped (e.g. MoE aux statistics in the
  serving forward); hazard-linting those would produce false positives.
  Liveness propagates through pjit bodies, scan carries (to a fixpoint,
  since a carry dead at the scan's outputs may still feed a live output
  through the next iteration), and cond branches; anything unrecognized is
  treated conservatively as live.
* ``eqn_source(eqn)`` — best-effort ``path::function`` + line attribution
  from the equation's traceback, filtered to frames under ``src/repro``.
"""

from __future__ import annotations

import re

import numpy as np
from jax._src import core as jcore

# Largest |c| accepted in the batch-affine dimension model d = k*B + c.
# Real offsets are tiny: +1 (MoE overflow bucket), -1 (drop-last slice),
# +3 (mamba conv pad).  Kept well under the minimum batch size (13) so an
# unrelated integer pair can rarely fake an affine fit — and the negative
# control catches the canonicalizer if one ever could.
AFFINE_C_MAX = 8

# pjit params that carry sharding/compilation metadata, not computation
# structure; they differ spuriously across traces and are excluded from the
# canonical form.
_SKIP_PARAMS = frozenset(
    {
        "sharding",
        "in_shardings",
        "out_shardings",
        "in_layouts",
        "out_layouts",
        "resource_env",
        "donated_invars",
        "keep_unused",
        "inline",
        "compiler_options_kvs",
        "ctx_mesh",
        "mesh",
        "check_rep",
        "symbolic_zeros",
        "num_consts",  # rendered structurally via the sub-jaxpr split
        "jvp_jaxpr_fun",  # lu.WrappedFun, not a jaxpr
        "fwd_jaxpr_thunk",
        "bwd",
        "call_jaxpr_pe",  # remat bookkeeping
    }
)


def _batch_affine(d: int, batch: int) -> bool:
    """Could ``d`` be ``k*batch + c`` for some ``k >= 1``, ``|c| <= C_MAX``?"""
    if d < batch - AFFINE_C_MAX:
        return False
    k = max(1, round(d / batch))
    return abs(d - k * batch) <= AFFINE_C_MAX


def _aval_str(aval) -> str:
    shape = getattr(aval, "shape", None)
    if shape is None:
        return str(aval)
    dims = ",".join(str(int(d)) for d in shape)
    dtype = getattr(aval, "dtype", None)
    return f"{getattr(dtype, 'name', dtype)}[{dims}]"


class _Canon:
    def __init__(self, batch: int):
        self.batch = batch
        self.lines: list[str] = []
        self.queue: list[tuple[str, jcore.Jaxpr]] = []
        self.count = 0

    def run(self, top: jcore.Jaxpr) -> str:
        self._emit(top, "J0")
        while self.queue:
            label, jx = self.queue.pop(0)
            self._emit(jx, label)
        return "\n".join(self.lines)

    def _label(self, jx: jcore.Jaxpr) -> str:
        self.count += 1
        label = f"J{self.count}"
        self.queue.append((label, jx))
        return label

    def _emit(self, jaxpr: jcore.Jaxpr, label: str) -> None:
        names: dict[int, str] = {}

        def vname(v) -> str:
            if isinstance(v, jcore.Literal):
                return "lit:" + self._value(v.val)
            if type(v).__name__ == "DropVar":
                return "_"
            if id(v) not in names:
                names[id(v)] = f"v{len(names)}"
            return f"{names[id(v)]}:{_aval_str(v.aval)}"

        self.lines.append(f"{label}:")
        header = [vname(v) for v in list(jaxpr.constvars) + list(jaxpr.invars)]
        self.lines.append("  in " + " ".join(header))
        for eqn in jaxpr.eqns:
            outs = " ".join(vname(v) for v in eqn.outvars)
            ins = " ".join(vname(v) for v in eqn.invars)
            params = ",".join(
                f"{k}={self._value(v)}"
                for k, v in sorted(eqn.params.items())
                if k not in _SKIP_PARAMS
            )
            self.lines.append(f"  {outs} = {eqn.primitive.name}[{params}] {ins}")
        self.lines.append("  out " + " ".join(vname(v) for v in jaxpr.outvars))

    def _value(self, v) -> str:
        if isinstance(v, jcore.ClosedJaxpr):
            return self._label(v.jaxpr)
        if isinstance(v, jcore.Jaxpr):
            return self._label(v)
        if isinstance(v, bool):
            return str(v)
        if isinstance(v, (int, np.integer)):
            return str(int(v))
        if isinstance(v, (float, complex, np.floating)):
            return repr(v)
        if isinstance(v, str):
            return repr(v)
        if v is None:
            return "None"
        if isinstance(v, np.ndarray):
            if v.ndim == 0:
                return self._value(v.item())
            dims = ",".join(str(int(d)) for d in v.shape)
            if any(_batch_affine(int(d), self.batch) for d in v.shape):
                # possibly batch-shaped const (e.g. an arange over rows):
                # its values necessarily differ across batch sizes, so only
                # its structure enters the canonical form
                return f"const[{v.dtype}:{dims}]"
            return f"const[{v.dtype}:{dims}:{hash(v.tobytes())&0xFFFFFFFF:x}]"
        if isinstance(v, (tuple, list)):
            return "(" + ",".join(self._value(x) for x in v) + ")"
        if isinstance(v, dict):
            return (
                "{"
                + ",".join(f"{k}:{self._value(x)}" for k, x in sorted(v.items()))
                + "}"
            )
        try:
            s = str(v)
        except Exception:
            s = ""
        if "0x" in s or len(s) > 120 or not s:
            return f"<{type(v).__name__}>"
        return s


def dce(closed: jcore.ClosedJaxpr) -> jcore.ClosedJaxpr:
    """Dead-code-eliminate a traced jaxpr (all outputs kept).

    ``jax.make_jaxpr`` retains equations whose results never reach an
    output — e.g. the MoE aux statistics computed inside the serving
    forward — and those may legitimately be batch-*variant* (a ``1/T``
    load-balance scaling).  The contract covers computations that feed
    committed results, so both the prover and the hazard lint run on the
    DCE'd program.  Falls back to the original jaxpr if jax's internal
    DCE entry point moves (the pinned jax==0.4.37 has it).
    """
    try:
        from jax._src.interpreters import partial_eval as pe

        if closed.jaxpr.constvars:
            return closed
        new_jaxpr, used = pe.dce_jaxpr(
            closed.jaxpr,
            [True] * len(closed.jaxpr.outvars),
            instantiate=True,  # keep all binders: no arg renumbering
        )
        return jcore.ClosedJaxpr(new_jaxpr, closed.consts)
    except Exception:
        return closed


def canonicalize(closed: jcore.ClosedJaxpr, batch: int) -> str:
    return _Canon(batch).run(closed.jaxpr)


# numeric tokens in canonical lines: floats (kept verbatim) and ints
# (compared under the batch-affine model)
_NUM_RE = re.compile(r"-?\d+\.\d+(?:[eE][+-]?\d+)?|-?\d+")


def _skeleton(line: str) -> tuple[str, list]:
    nums: list = []

    def rep(m: re.Match) -> str:
        s = m.group(0)
        nums.append(float(s) if ("." in s or "e" in s or "E" in s) else int(s))
        return "§"

    return _NUM_RE.sub(rep, line), nums


def _lines_match(la: str, lb: str, b1: int, b2: int) -> bool:
    if la == lb:
        return True
    sa, na = _skeleton(la)
    sb, nb = _skeleton(lb)
    if sa != sb or len(na) != len(nb):
        return False
    for x, y in zip(na, nb):
        if x == y:
            continue
        if isinstance(x, float) or isinstance(y, float):
            return False
        # batch-affine: x = k*b1 + c, y = k*b2 + c, k >= 1, |c| <= C_MAX
        num, den = x - y, b1 - b2
        if den == 0 or num % den:
            return False
        k = num // den
        if k < 1:
            return False
        if abs(x - k * b1) > AFFINE_C_MAX:
            return False
    return True


def compare_canonical(
    a: str, b: str, b1: int, b2: int
) -> tuple[int, str, str] | None:
    """First structurally-divergent line between two canonical forms traced
    at batch sizes ``b1``/``b2``, or None when batch-invariant."""
    la, lb = a.splitlines(), b.splitlines()
    for i, (x, y) in enumerate(zip(la, lb)):
        if not _lines_match(x, y, b1, b2):
            return i, x, y
    if len(la) != len(lb):
        i = min(len(la), len(lb))
        longer = la if len(la) > len(lb) else lb
        extra = longer[i]
        return (i, extra, "<end>") if len(la) > len(lb) else (i, "<end>", extra)
    return None


# ---------------------------------------------------------------------------
# liveness-aware walking


def _invar_liveness(jaxpr: jcore.Jaxpr, out_mask: list[bool]) -> list[bool]:
    live: set[int] = {
        id(v)
        for v, keep in zip(jaxpr.outvars, out_mask)
        if keep and isinstance(v, jcore.Var)
    }
    for eqn in reversed(jaxpr.eqns):
        eqn_live = bool(getattr(eqn, "effects", None)) or any(
            isinstance(v, jcore.Var) and id(v) in live for v in eqn.outvars
        )
        if eqn_live:
            for v in eqn.invars:
                if isinstance(v, jcore.Var):
                    live.add(id(v))
    return [id(v) in live for v in jaxpr.invars]


def _scan_out_mask(
    body: jcore.Jaxpr, num_consts: int, num_carry: int, eqn_mask: list[bool]
) -> list[bool]:
    # A carry that is dead at the scan's outputs can still feed a live
    # output via the next iteration: iterate to a fixpoint.
    mask = list(eqn_mask)
    while True:
        inv = _invar_liveness(body, mask)
        changed = False
        for i in range(num_carry):
            if inv[num_consts + i] and not mask[i]:
                mask[i] = True
                changed = True
        if not changed:
            return mask


def _walk(jaxpr: jcore.Jaxpr, out_mask: list[bool], cb, path: tuple) -> None:
    live: set[int] = {
        id(v)
        for v, keep in zip(jaxpr.outvars, out_mask)
        if keep and isinstance(v, jcore.Var)
    }
    plan: list[tuple] = []
    for eqn in reversed(jaxpr.eqns):
        mask = [isinstance(v, jcore.Var) and id(v) in live for v in eqn.outvars]
        eqn_live = any(mask) or bool(getattr(eqn, "effects", None))
        plan.append((eqn, mask, eqn_live))
        if eqn_live:
            for v in eqn.invars:
                if isinstance(v, jcore.Var):
                    live.add(id(v))
    for eqn, mask, eqn_live in reversed(plan):
        if not eqn_live:
            continue
        cb(eqn, path)
        _recurse(eqn, mask, cb, path)


def _recurse(eqn, out_mask: list[bool], cb, path: tuple) -> None:
    name = eqn.primitive.name
    sub = path + (name,)
    params = eqn.params
    if name == "scan":
        body = params["jaxpr"].jaxpr
        mask = _scan_out_mask(
            body, params["num_consts"], params["num_carry"], out_mask
        )
        _walk(body, mask, cb, sub)
        return
    if name == "while":
        cond = params["cond_jaxpr"].jaxpr
        body = params["body_jaxpr"].jaxpr
        _walk(cond, [True] * len(cond.outvars), cb, sub)
        _walk(body, [True] * len(body.outvars), cb, sub)
        return
    if name == "cond":
        for br in params["branches"]:
            _walk(br.jaxpr, list(out_mask), cb, sub)
        return
    for v in params.values():
        jx = None
        if isinstance(v, jcore.ClosedJaxpr):
            jx = v.jaxpr
        elif isinstance(v, jcore.Jaxpr):
            jx = v
        elif (
            isinstance(v, (tuple, list))
            and v
            and all(isinstance(b, jcore.ClosedJaxpr) for b in v)
        ):
            for b in v:
                _walk(b.jaxpr, [True] * len(b.jaxpr.outvars), cb, sub)
            continue
        if jx is None:
            continue
        if len(jx.outvars) == len(out_mask):
            _walk(jx, list(out_mask), cb, sub)
        else:
            _walk(jx, [True] * len(jx.outvars), cb, sub)


def walk_live(closed: jcore.ClosedJaxpr, cb) -> None:
    """Call ``cb(eqn, path)`` for every equation feeding the outputs."""
    top = closed.jaxpr
    _walk(top, [True] * len(top.outvars), cb, ())


def walk_all(closed: jcore.ClosedJaxpr, cb) -> None:
    """Call ``cb(eqn, path)`` for every equation, live or dead."""

    def go(jaxpr: jcore.Jaxpr, path: tuple) -> None:
        for eqn in jaxpr.eqns:
            cb(eqn, path)
            sub = path + (eqn.primitive.name,)
            for v in eqn.params.values():
                if isinstance(v, jcore.ClosedJaxpr):
                    go(v.jaxpr, sub)
                elif isinstance(v, jcore.Jaxpr):
                    go(v, sub)
                elif (
                    isinstance(v, (tuple, list))
                    and v
                    and all(isinstance(b, jcore.ClosedJaxpr) for b in v)
                ):
                    for b in v:
                        go(b.jaxpr, sub)

    go(closed.jaxpr, ())


# ---------------------------------------------------------------------------
# source attribution


def eqn_source(eqn) -> tuple[str, int]:
    """Best-effort ``(path::function, line)`` for an equation."""
    frames = []
    try:
        from jax._src import source_info_util

        frames = list(source_info_util.user_frames(eqn.source_info))
    except Exception:
        pass
    chosen = None
    for fr in frames:
        fname = str(getattr(fr, "file_name", "")).replace("\\", "/")
        if "/repro/analysis/" in fname:
            continue  # the checker's own tracing machinery, never the cause
        if "/repro/" in fname:
            chosen = fr
            break
    if chosen is None:
        # fall back to the innermost non-checker frame (fixtures, tests)
        for fr in frames:
            fname = str(getattr(fr, "file_name", "")).replace("\\", "/")
            if "/repro/analysis/" not in fname:
                chosen = fr
                break
    if chosen is None:
        return "<untracked>", 0
    fname = str(getattr(chosen, "file_name", "?")).replace("\\", "/")
    for anchor in ("src/repro", "tests/"):
        idx = fname.find(anchor)
        if idx >= 0:
            fname = fname[idx:]
            break
    func = getattr(chosen, "function_name", "?")
    line = int(getattr(chosen, "start_line", 0) or 0)
    return f"{fname}::{func}", line
