"""Determinism-contract checker CLI: ``python -m repro.analysis.check``.

Runs all four passes and exits nonzero on any unexplained finding:

1. invariance  — traces verify/prefill/decode at several batch sizes per
   architecture class and proves the commit-path jaxprs batch-invariant;
2. hazards     — lints the traced programs for nondeterminism-prone
   primitives (overlapping scatters, batch-extent float reductions,
   narrow dot accumulators, data-dependent while);
3. taint       — AST dataflow proving no fast-path schedule reaches
   commit-annotated code;
4. kernel_lint — Pallas source rules (literal-derived reduction grids,
   f32 accumulators, no shape-adaptive tiling or trace-time branches).

Findings are suppressed only by a justified entry in ``allowlist.toml``;
stale entries are findings themselves.  The expensive trace passes (1+2)
are cached in ``.analysis_cache/`` keyed on a hash of ``src/repro`` — CI
restores that directory so unchanged source re-checks in seconds.

Fixture mode (``--paths f.py ...``) runs only the source passes on the
given files, plus the hazard pass on any module exposing
``analysis_trace() -> (closed_jaxpr, batch)`` — used by the seeded
violation fixtures in ``tests/analysis_fixtures/``.
"""

from __future__ import annotations

import argparse
import hashlib
import importlib.util
import json
import sys
from pathlib import Path
from typing import List, Optional

from repro.analysis import hazards, invariance, kernel_lint, taint
from repro.analysis.report import Finding, Report, load_allowlist

CACHE_VERSION = 4  # bump to invalidate cached trace-pass results


def repo_root() -> Path:
    return Path(__file__).resolve().parents[3]


def src_hash(root: Path) -> str:
    h = hashlib.sha256()
    for p in sorted((root / "src" / "repro").rglob("*.py")):
        h.update(str(p.relative_to(root)).encode())
        h.update(b"\0")
        h.update(p.read_bytes())
        h.update(b"\0")
    h.update(f"v{CACHE_VERSION}".encode())
    return h.hexdigest()


def _findings_to_json(findings: List[Finding]) -> list:
    return [f.to_dict() for f in findings]


def _findings_from_json(items: list) -> List[Finding]:
    return [
        Finding(
            pass_name=d["pass_name"],
            rule=d["rule"],
            where=d["where"],
            message=d["message"],
            arch=d.get("arch"),
        )
        for d in items
    ]


def run_trace_passes(
    root: Path, cache_dir: Optional[Path], *, use_cache: bool
) -> tuple[List[Finding], dict]:
    """Invariance + hazards, with results cached on the source hash."""
    key = src_hash(root)
    cache_file = (cache_dir or root / ".analysis_cache") / f"trace-{key[:16]}.json"
    if use_cache and cache_file.exists():
        try:
            data = json.loads(cache_file.read_text())
            if data.get("src_hash") == key:
                print(f"[check] trace cache hit ({cache_file.name})")
                return _findings_from_json(data["findings"]), data["certs"]
        except (json.JSONDecodeError, KeyError):
            pass  # corrupt cache: re-trace

    print("[check] tracing engine steps (no cache hit; this takes a few minutes)")
    inv_findings, certs, arch_traces = invariance.run_pass()
    hz_findings = hazards.run_pass(arch_traces)
    mesh_findings, mesh_certs = invariance.run_mesh_pass()
    certs.update(mesh_certs)
    findings = inv_findings + hz_findings + mesh_findings

    if use_cache:
        cache_file.parent.mkdir(parents=True, exist_ok=True)
        cache_file.write_text(
            json.dumps(
                {
                    "src_hash": key,
                    "findings": _findings_to_json(findings),
                    "certs": certs,
                },
                indent=1,
            )
        )
    return findings, certs


def _load_fixture_trace(path: Path):
    spec = importlib.util.spec_from_file_location(f"_fixture_{path.stem}", path)
    if spec is None or spec.loader is None:
        return None
    module = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(module)
    fn = getattr(module, "analysis_trace", None)
    return fn() if callable(fn) else None


def run_fixture_mode(paths: List[Path], root: Path) -> Report:
    report = Report(allowlist=[])
    report.extend(taint.scan_files(paths, root, expected_roots=frozenset()))
    report.extend(kernel_lint.run_pass(root, files=paths))
    for p in paths:
        if "analysis_trace" not in p.read_text():
            continue
        traced = _load_fixture_trace(p)
        if traced is None:
            continue
        closed, batch = traced
        from repro.analysis.jaxpr_utils import dce

        report.extend(
            hazards.scan_trace(dce(closed), batch, arch="fixture", kind=p.stem)
        )
    report.finish(check_stale=False)
    return report


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.check",
        description="LLM-42 determinism-contract static checker",
    )
    ap.add_argument("--json", type=Path, default=None, help="write JSON report here")
    ap.add_argument("--no-cache", action="store_true", help="always re-trace")
    ap.add_argument(
        "--cache-dir", type=Path, default=None, help="trace cache directory"
    )
    ap.add_argument(
        "--allowlist",
        type=Path,
        default=None,
        help="allowlist TOML (default: src/repro/analysis/allowlist.toml)",
    )
    ap.add_argument(
        "--skip-trace",
        action="store_true",
        help="source passes only (taint + kernel lint); no jaxpr tracing",
    )
    ap.add_argument(
        "--paths",
        type=Path,
        nargs="+",
        default=None,
        help="fixture mode: lint only these files (taint/kernel/hazard-trace)",
    )
    args = ap.parse_args(argv)
    root = repo_root()

    if args.paths:
        report = run_fixture_mode([p.resolve() for p in args.paths], root)
    else:
        allow_path = args.allowlist or root / "src/repro/analysis/allowlist.toml"
        report = Report(allowlist=load_allowlist(allow_path))
        report.extend(taint.run_pass(root))
        report.extend(kernel_lint.run_pass(root))
        if not args.skip_trace:
            trace_findings, certs = run_trace_passes(
                root, args.cache_dir, use_cache=not args.no_cache
            )
            report.extend(trace_findings)
            report.certificates = certs
            for arch, cert in sorted(certs.items()):
                print(f"[check] invariance {arch}: {cert}")
        # trace-pass allowlist entries look stale when tracing is skipped
        report.finish(check_stale=not args.skip_trace)

    out = report.format()
    if out:
        print(out)
    print(f"[check] {'OK' if report.ok else 'FAIL'}: {len(report.findings)} finding(s)")
    if args.json:
        report.write_json(args.json)
        print(f"[check] report written to {args.json}")
    return 0 if report.ok else 1


if __name__ == "__main__":
    sys.exit(main())
