"""Findings, reports, and the justified allowlist.

A finding is keyed ``(pass, rule, where)`` where ``where`` is a repo-relative
``path::function`` location.  The allowlist (``analysis/allowlist.toml``)
suppresses findings by exact key match; every entry must carry a non-empty
``justification`` string, and entries that no longer match anything are
themselves reported (stale-allowlist) so the exemption set cannot rot.

The TOML reader below is a deliberately tiny subset parser (array-of-tables
``[[allow]]`` with string values): the repo targets Python 3.10, which has
no ``tomllib``, and third-party parsers are out of bounds.
"""

from __future__ import annotations

import dataclasses
import json
from pathlib import Path


@dataclasses.dataclass
class Finding:
    """One contract violation (or prover failure) at a source location."""

    pass_name: str  # invariance | hazards | taint | kernel_lint | allowlist
    rule: str  # short rule id, e.g. "dot-default-precision"
    where: str  # repo-relative "path/to/file.py::function" (or module)
    message: str  # human diagnostic, includes line numbers where known
    arch: str = ""  # arch class for trace-derived findings ("" otherwise)

    def key(self) -> tuple[str, str, str]:
        return (self.pass_name, self.rule, self.where)

    def format(self) -> str:
        tag = f" [{self.arch}]" if self.arch else ""
        return f"{self.pass_name}/{self.rule}{tag} at {self.where}:\n    {self.message}"

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class AllowEntry:
    pass_name: str
    rule: str
    where: str
    justification: str
    used: bool = False

    def matches(self, f: Finding) -> bool:
        return (self.pass_name, self.rule, self.where) == f.key()


class AllowlistError(ValueError):
    pass


def _parse_toml_allow(text: str, source: str) -> list[AllowEntry]:
    """Parse the ``[[allow]]`` subset of TOML used by allowlist.toml."""
    entries: list[AllowEntry] = []
    current: dict[str, str] | None = None

    def flush() -> None:
        nonlocal current
        if current is None:
            return
        missing = {"pass", "rule", "where", "justification"} - set(current)
        if missing:
            raise AllowlistError(
                f"{source}: [[allow]] entry missing keys {sorted(missing)}: {current}"
            )
        if not current["justification"].strip():
            raise AllowlistError(
                f"{source}: empty justification for "
                f"{current['pass']}/{current['rule']} at {current['where']} — "
                "every allowlist entry must say why the finding is safe"
            )
        entries.append(
            AllowEntry(
                pass_name=current["pass"],
                rule=current["rule"],
                where=current["where"],
                justification=current["justification"],
            )
        )
        current = None

    for lineno, raw in enumerate(text.splitlines(), start=1):
        line = raw.strip()
        if not line or line.startswith("#"):
            continue
        if line == "[[allow]]":
            flush()
            current = {}
            continue
        if line.startswith("["):
            raise AllowlistError(
                f"{source}:{lineno}: only [[allow]] tables are supported, got {line!r}"
            )
        if "=" not in line:
            raise AllowlistError(f"{source}:{lineno}: expected key = \"value\"")
        if current is None:
            raise AllowlistError(
                f"{source}:{lineno}: key outside an [[allow]] table"
            )
        key, _, val = line.partition("=")
        key = key.strip()
        val = val.strip()
        if not (len(val) >= 2 and val[0] == '"' and val[-1] == '"'):
            raise AllowlistError(
                f"{source}:{lineno}: value for {key!r} must be a double-quoted string"
            )
        body = val[1:-1]
        if '"' in body.replace('\\"', ""):
            raise AllowlistError(f"{source}:{lineno}: unescaped quote in value")
        current[key] = body.replace('\\"', '"')
    flush()
    return entries


def load_allowlist(path: Path) -> list[AllowEntry]:
    if not path.exists():
        return []
    return _parse_toml_allow(path.read_text(), str(path))


class Report:
    """Accumulates findings across passes and applies the allowlist."""

    def __init__(self, allowlist: list[AllowEntry] | None = None):
        self.allowlist = allowlist or []
        self.findings: list[Finding] = []  # surviving (not allowlisted)
        self.suppressed: list[Finding] = []
        self.certificates: dict = {}  # invariance-prover output, by arch

    def add(self, finding: Finding) -> None:
        for entry in self.allowlist:
            if entry.matches(finding):
                entry.used = True
                self.suppressed.append(finding)
                return
        self.findings.append(finding)

    def extend(self, findings: list[Finding]) -> None:
        for f in findings:
            self.add(f)

    def finish(self, *, check_stale: bool = True) -> None:
        """Flag allowlist entries that matched nothing (stale exemptions)."""
        if not check_stale:
            return
        for entry in self.allowlist:
            if not entry.used:
                self.add(
                    Finding(
                        pass_name="allowlist",
                        rule="stale-entry",
                        where=entry.where,
                        message=(
                            f"allowlist entry {entry.pass_name}/{entry.rule} at "
                            f"{entry.where} no longer matches any finding — "
                            "remove it (justification was: "
                            f"{entry.justification!r})"
                        ),
                    )
                )

    @property
    def ok(self) -> bool:
        return not self.findings

    def to_dict(self) -> dict:
        return {
            "ok": self.ok,
            "findings": [f.to_dict() for f in self.findings],
            "suppressed": [f.to_dict() for f in self.suppressed],
            "certificates": self.certificates,
        }

    def write_json(self, path: Path) -> None:
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(self.to_dict(), indent=2, sort_keys=True) + "\n")

    def format(self) -> str:
        lines = []
        for f in self.findings:
            lines.append(f.format())
        if self.suppressed:
            lines.append(
                f"({len(self.suppressed)} finding(s) suppressed by allowlist)"
            )
        return "\n".join(lines)
