"""Deterministic multi-replica front end: router + cluster drive loop.

The missing layer between "one deterministic engine" and "millions of
users": N engine replicas behind a router whose request→replica assignment
is a pure function of (arrival order, replica states) — so the same
arrival trace produces the same assignment, the same per-replica
schedules, and therefore (by each engine's DVR contract) the same
committed streams, at ANY replica count.  Determinism composes: the
cluster adds no new nondeterminism source because the router consults
nothing outside the simulated state (no wall clock, no hashing of ids, no
randomness).

Routing rule (radix-prefix-affinity with a load guard):

1. Probe every replica's radix for the longest whole-block prefix of the
   prompt (``PrefixCache.peek`` — non-mutating).
2. Affinity: the replica with the longest match wins (ties → lowest
   index).  A request with no cached prefix anywhere goes to the
   least-loaded replica (ties → lowest index).
3. Load guard: when the affinity replica is overloaded — its load exceeds
   the least-loaded replica's by at least ``imbalance`` requests — the
   request lands on the least-loaded replica instead, and the prefix hit
   is on the *wrong* replica.  Policy ``transfer="copy"`` moves the cached
   blocks device-to-device (``replica.transfer_prefix``); ``"recompute"``
   moves nothing and lets the target replay the prefill — bitwise the
   same KV by the determinism contract, just different cost.

Each replica keeps its own ``DualClockRuntime``; the cluster admits an
arrival once the *fleet frontier* (min replica clock) reaches it, steps
every replica with work per iteration, and fast-forwards idle replicas to
the next arrival so the frontier never sticks.  Aggregate goodput comes
off the same cost model the single-engine benchmarks use.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.cluster.replica import Replica, transfer_prefix
from repro.models.base import ModelConfig
from repro.obs import MetricsRegistry, validate_chrome_trace
from repro.serving import costmodel
from repro.serving.engine import Engine
from repro.serving.request import Request


class Router:
    """Stable request→replica assignment with radix prefix affinity."""

    def __init__(
        self,
        replicas: List[Replica],
        *,
        transfer: str = "copy",  # "copy" | "recompute"
        imbalance: int = 2,  # load-guard threshold (requests)
    ):
        assert transfer in ("copy", "recompute")
        assert imbalance >= 1
        self.replicas = replicas
        self.transfer = transfer
        self.imbalance = imbalance
        # router telemetry (cluster.* metrics read these)
        self.assignments = 0
        self.affinity_hits = 0  # routed to the replica holding the prefix
        self.affinity_misses = 0  # no replica held any prefix
        self.diverted = 0  # prefix existed but load guard diverted
        self.transfers = 0
        self.transferred_tokens = 0

    def route(self, req: Request, now: int) -> Replica:
        """Pick the replica for ``req`` and perform any cross-replica
        prefix transfer the choice implies.  Deterministic: consults only
        replica states, breaks every tie by replica index."""
        scores = [(r.prefix_blocks(req.prompt), r) for r in self.replicas]
        best_blocks, affinity = max(scores, key=lambda s: (s[0], -s[1].idx))
        least = min(self.replicas, key=lambda r: (r.load, r.idx))
        self.assignments += 1

        if best_blocks == 0:
            self.affinity_misses += 1
            return least
        if affinity.load - least.load < self.imbalance or affinity is least:
            self.affinity_hits += 1
            return affinity
        # prefix lives on an overloaded replica: divert to the least-
        # loaded one, carrying (or deterministically recomputing) the KV
        self.diverted += 1
        if self.transfer == "copy":
            moved = transfer_prefix(affinity, least, req.prompt, now)
            if moved:
                self.transfers += 1
                self.transferred_tokens += moved
        return least

    @property
    def affinity_hit_rate(self) -> float:
        return self.affinity_hits / max(self.assignments, 1)


@dataclasses.dataclass
class ClusterResult:
    """Aggregate online-run result (mirrors ``serving.online.OnlineResult``
    plus fleet figures)."""

    latencies: Dict[int, float]  # rid -> end-to-end seconds (sim)
    ttfts: Dict[int, float]  # rid -> time-to-first-token seconds (sim)
    total_time: float  # fleet makespan: max over replica makespans
    out_tokens: int  # committed output tokens, all replicas
    assignment: Dict[int, int]  # rid -> replica idx (the routing record)
    metrics: Dict[str, Any] = dataclasses.field(default_factory=dict)
    replica_metrics: List[Dict[str, Any]] = dataclasses.field(
        default_factory=list
    )

    @property
    def throughput(self) -> float:
        """Aggregate committed tokens per simulated second."""
        return self.out_tokens / max(self.total_time, 1e-12)

    def goodput(self, slo_ttft_s: float) -> float:
        """Committed tokens/s from requests whose TTFT met the SLO — the
        fleet headline: adding replicas must grow *this*, not just raw
        throughput with queued-to-death stragglers."""
        good = sum(
            1 for rid, t in self.ttfts.items() if t <= slo_ttft_s
        )
        frac = good / max(len(self.ttfts), 1)
        return self.throughput * frac


class Cluster:
    """N engine replicas behind a deterministic router.

    ``make_engine(idx)`` must build identically configured engines — the
    replica index is for observability (per-replica trace pid), not for
    configuration divergence, which would break cross-replica-count
    determinism.
    """

    def __init__(
        self,
        make_engine: Callable[[int], Engine],
        n_replicas: int,
        *,
        transfer: str = "copy",
        imbalance: int = 2,
    ):
        assert n_replicas >= 1
        self.replicas = [
            Replica(i, make_engine(i)) for i in range(n_replicas)
        ]
        self.router = Router(
            self.replicas, transfer=transfer, imbalance=imbalance
        )
        self.metrics = MetricsRegistry()
        self._register_metrics()

    # -- observability ---------------------------------------------------

    def _register_metrics(self) -> None:
        m = self.metrics
        m.gauge_fn("cluster.replicas", lambda: len(self.replicas),
                   unit="replicas", help="engine replicas behind the router")
        m.gauge_fn("cluster.router.assignments",
                   lambda: self.router.assignments,
                   unit="requests", help="routing decisions made")
        m.gauge_fn("cluster.router.affinity_hits",
                   lambda: self.router.affinity_hits,
                   unit="requests",
                   help="requests routed to the replica holding their prefix")
        m.gauge_fn("cluster.router.affinity_misses",
                   lambda: self.router.affinity_misses,
                   unit="requests", help="requests with no cached prefix")
        m.gauge_fn("cluster.router.affinity_hit_rate",
                   lambda: self.router.affinity_hit_rate,
                   unit="fraction", help="affinity hits over assignments")
        m.gauge_fn("cluster.router.diverted",
                   lambda: self.router.diverted,
                   unit="requests",
                   help="prefix hits diverted by the load guard")
        m.gauge_fn("cluster.router.transfers",
                   lambda: self.router.transfers,
                   unit="transfers", help="cross-replica block transfers")
        m.gauge_fn("cluster.router.transferred_tokens",
                   lambda: self.router.transferred_tokens,
                   unit="tokens", help="KV tokens moved between replicas")
        for rep in self.replicas:
            # close over the loop variable via default arg
            m.gauge_fn(
                f"cluster.replica.{rep.idx}.occupancy",
                lambda r=rep: r.occupancy,
                unit="fraction", help="running requests over slot capacity")
            m.gauge_fn(
                f"cluster.replica.{rep.idx}.load",
                lambda r=rep: r.load,
                unit="requests", help="running + queued + preempted")
            m.gauge_fn(
                f"cluster.replica.{rep.idx}.transfers_in",
                lambda r=rep: r.transfers_in,
                unit="transfers", help="prefix transfers received")

    def chrome_trace(self) -> Dict[str, Any]:
        """One merged Chrome trace, each replica under its own pid —
        Perfetto renders the fleet as side-by-side processes."""
        events: List[Dict[str, Any]] = []
        for rep in self.replicas:
            sub = rep.engine.obs.tracer.to_chrome_trace(
                pid=rep.idx, process_name=f"llm42-replica-{rep.idx}"
            )
            events.extend(sub["traceEvents"])
        trace = {"traceEvents": events, "displayTimeUnit": "ms"}
        problems = validate_chrome_trace(trace)
        assert not problems, f"invalid merged cluster trace: {problems}"
        return trace

    # -- aggregate state -------------------------------------------------

    def drained(self) -> bool:
        return not any(r.has_work() for r in self.replicas)

    @property
    def finished(self) -> List[Request]:
        out: List[Request] = []
        for r in self.replicas:
            out.extend(r.engine.finished)
        return out


def run_online(
    cluster: Cluster,
    cost_cfg: ModelConfig,
    requests: List[Tuple[Request, float]],  # (request, arrival_time_s)
    *,
    hw: costmodel.Hardware = costmodel.V5E,
    invariant_mode: bool = False,
    max_iters: int = 200000,
    on_exhaust: str = "raise",  # "raise" | "warn"
) -> ClusterResult:
    """Cluster analogue of ``serving.online.run_online``: drive every
    replica's costed dual-clock runtime against one arrival trace.

    An arrival is admitted (routed + submitted) once the fleet frontier —
    the minimum replica clock — reaches it; replicas then step
    independently, verify streams and all, and idle replicas fast-forward
    to the next arrival so the frontier keeps moving.  ``total_time`` is
    the fleet makespan (max replica clock at drain).
    """
    assert on_exhaust in ("raise", "warn")
    reps = cluster.replicas
    for rep in reps:
        rep.engine.bind_cost_model(cost_cfg, hw, invariant=invariant_mode)
    pending = sorted(requests, key=lambda p: p[1])
    arrival: Dict[int, float] = {}
    ttft: Dict[int, float] = {}
    latency: Dict[int, float] = {}
    assignment: Dict[int, int] = {}
    home: Dict[int, Replica] = {}

    def frontier() -> float:
        return min(r.engine.runtime.now for r in reps)

    def admit() -> None:
        while pending and pending[0][1] <= frontier():
            req, t = pending.pop(0)
            arrival[req.rid] = t
            target = cluster.router.route(req, now=int(t * 1e6))
            assignment[req.rid] = target.idx
            home[req.rid] = target
            target.engine.submit(req)

    for _ in range(max_iters):
        admit()
        if not pending and cluster.drained():
            break
        next_arrival: Optional[float] = pending[0][1] if pending else None
        progressed = False
        for rep in reps:
            if not rep.has_work():
                continue
            rep.engine.runtime.skip_horizon = next_arrival
            stepped = rep.engine.step()
            progressed = progressed or stepped
            clock = rep.engine.runtime.now
            for r in rep.engine.running:
                if r.rid not in ttft and r.committed:
                    ttft[r.rid] = clock - arrival[r.rid]
            for r in rep.engine.finished:
                if r.rid not in latency:
                    latency[r.rid] = clock - arrival[r.rid]
                    ttft.setdefault(r.rid, clock - arrival[r.rid])
        if next_arrival is not None:
            # idle replicas wait for traffic; a fully stalled fleet
            # (verdict-gated everywhere) waits out the next arrival too
            for rep in reps:
                if not rep.has_work() or not progressed:
                    rep.engine.runtime.idle_until(next_arrival)

    if pending or not cluster.drained():
        busy = sum(r.load for r in reps)
        msg = (
            f"cluster run_online exhausted max_iters={max_iters} before "
            f"draining: {busy} requests in flight across "
            f"{len(reps)} replicas, {len(pending)} not yet arrived; "
            f"latency/TTFT dicts would be partial "
            f"({len(latency)}/{len(requests)} finished)"
        )
        if on_exhaust == "raise":
            raise RuntimeError(msg)
        warnings.warn(msg, RuntimeWarning, stacklevel=2)

    # drain bookkeeping against each request's OWN replica clock
    for rid, rep in home.items():
        clock = rep.engine.runtime.now
        for r in rep.engine.finished:
            if r.rid == rid:
                latency.setdefault(rid, clock - arrival[rid])
                ttft.setdefault(rid, clock - arrival[rid])

    out_tokens = sum(r.num_output for r in cluster.finished)
    makespan = max(r.engine.runtime.makespan for r in reps)
    return ClusterResult(
        latencies=latency,
        ttfts=ttft,
        total_time=makespan,
        out_tokens=out_tokens,
        assignment=assignment,
        metrics=cluster.metrics.snapshot(),
        replica_metrics=[
            r.engine.obs.metrics.snapshot() for r in reps
        ],
    )
