"""One engine replica behind the cluster router.

A :class:`Replica` wraps a ``serving.Engine`` with the identity and probes
the deterministic router needs: a stable index, a load figure, the
prefix-affinity probe (a *non-mutating* radix walk — probing must not
perturb the LRU state of replicas the router does not pick), and the
cross-replica prefix transfer.

Transfer semantics (``transfer_prefix``): when a request's cached prefix
lives on replica *i* but the router lands it on replica *j* (load guard),
the matched KV blocks are copied device-to-device into *j*'s pool
(``blockpool.copy_blocks``) and registered with *j*'s radix — arriving
resident-but-evictable, exactly like locally committed prefix blocks.  The
alternative ``"recompute"`` policy moves nothing: *j* replays the prefill
deterministically, and by the determinism contract the recomputed KV is
bitwise the KV the copy would have moved — the two policies differ only in
cost (ICI copy vs recompute FLOPs), never in committed streams.
"""

from __future__ import annotations

from typing import List, Optional

from repro.serving import blockpool
from repro.serving.engine import Engine


class Replica:
    """A routable engine: stable index + the router's probes."""

    def __init__(self, idx: int, engine: Engine):
        self.idx = idx
        self.engine = engine
        # cross-replica transfer telemetry (cluster.* metrics)
        self.transfers_in = 0
        self.transferred_tokens_in = 0

    # -- router probes ---------------------------------------------------

    @property
    def load(self) -> int:
        """Requests this replica is responsible for (running + queued +
        preempted-awaiting-restore) — the router's balance key."""
        e = self.engine
        return len(e.running) + len(e.queue) + len(e.preempted)

    @property
    def occupancy(self) -> float:
        """Running requests over slot capacity (per-replica gauge)."""
        return len(self.engine.running) / max(self.engine.max_batch, 1)

    def prefix_blocks(self, prompt: List[int]) -> int:
        """Whole blocks of ``prompt`` resident in this replica's radix —
        the affinity score.  Non-mutating (``PrefixCache.peek``)."""
        pc = self.engine.prefix_cache
        return pc.peek(prompt) if pc is not None else 0

    def has_work(self) -> bool:
        e = self.engine
        return bool(e.running or e.queue or e.preempted)


def transfer_prefix(
    src: Replica, dst: Replica, prompt: List[int], now: int
) -> int:
    """Copy ``src``'s cached prefix of ``prompt`` into ``dst``'s pool.

    Returns tokens actually moved (0 when either side has no prefix cache,
    ``dst`` already holds at least as long a prefix, or ``dst``'s pool is
    dry — a partial leading copy is still a valid radix prefix).  Blocks
    land in ``dst`` at refcount 0, ``cached`` — resident-but-evictable —
    so the next admission increfs them exactly like a local hit.
    """
    spc, dpc = src.engine.prefix_cache, dst.engine.prefix_cache
    if spc is None or dpc is None:
        return 0
    src_bids = spc.match(prompt, now)
    have = dpc.peek(prompt)
    if len(src_bids) <= have:
        return 0

    dst_bids: List[int] = list(dpc.match(prompt, now)[:have])
    fresh: List[int] = []
    for i in range(have, len(src_bids)):
        bid: Optional[int] = dst.engine._alloc_block()
        if bid is None:
            break
        fresh.append(bid)
        dst_bids.append(bid)
    if not fresh:
        return 0

    # device copy of the paged KV rows, then radix adoption on dst
    dst.engine.pool.data = blockpool.copy_blocks(
        src.engine.pool.data, dst.engine.pool.data, dst.engine.pool.layout,
        list(src_bids[have:have + len(fresh)]), fresh,
    )
    bs = dst.engine.pool.block_size
    dpc.insert(
        prompt[: len(dst_bids) * bs], dst_bids, now,
        dst.engine.pool.alloc_blocks,
    )
    # drop the alloc ref: resident-but-evictable, like committed prefixes
    for bid in fresh:
        dst.engine.pool.alloc_blocks.decref(bid)

    moved = len(fresh) * bs
    dst.transfers_in += 1
    dst.transferred_tokens_in += moved
    return moved
