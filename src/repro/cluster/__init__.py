"""Mesh-scale deterministic serving: N engine replicas behind a
deterministic router.

The single-engine DVR contract ("same committed stream regardless of
batching") composes to fleet scale only if the layer above the engine is
itself deterministic.  This package adds that layer: a router whose
request→replica assignment is a pure function of the arrival trace and
simulated replica states (radix-prefix affinity with index tie-breaks and
a load guard), replicas that can move committed-prefix KV blocks between
pools (or deterministically recompute them — bitwise the same by the
contract), and a cluster drive loop over per-replica dual-clock runtimes
reporting aggregate throughput/goodput off the shared cost model.
"""

from repro.cluster.replica import Replica, transfer_prefix
from repro.cluster.router import Cluster, ClusterResult, Router, run_online

__all__ = [
    "Cluster",
    "ClusterResult",
    "Replica",
    "Router",
    "run_online",
    "transfer_prefix",
]
