"""Logical-axis → PartitionSpec rules (MaxText-style, minimal).

``param_specs`` (models/base.py) annotates every tensor dim with a logical
axis name; this module maps those names onto mesh axes per execution mode:

  * TRAIN — FSDP: weight ``embed`` dims sharded over the data axes
    (ZeRO-3-style, all-gathered per layer by GSPMD), tensor-parallel
    ``heads/ffn/vocab`` over ``model``, MoE ``experts`` expert-parallel
    over the data axes.
  * SERVE — weights replicated over data (decode batches shard over data),
    tensor-parallel over ``model``; MoE experts expert-parallel over
    ``model`` (all-to-all dispatch inside a chip group).

Divisibility fallback: if a dim is not divisible by the mesh-axes product
(e.g. kv_heads=8 over model=16), axes are dropped right-to-left until it
divides — every (arch × shape × mesh) combination must lower, so the rules
degrade to replication rather than erroring (DESIGN.md §5).  A mesh axis is
never used twice in one PartitionSpec (GSPMD requirement); first dim wins.
"""

from __future__ import annotations

from typing import Any, Dict, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.base import ModelConfig, param_specs
from repro.models.transformer import cache_spec


Axes = Tuple[str, ...]


def _axis_sizes(mesh: Mesh) -> Dict[str, int]:
    return dict(mesh.shape)  # works for Mesh and AbstractMesh


def _data_axes(mesh: Mesh) -> Tuple[str, ...]:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def rules_train(mesh: Mesh, *, fsdp: bool = True) -> Dict[str, Any]:
    """fsdp=False replicates weights over the data axes (pure DP x TP) —
    trades memory for the per-layer all-gather traffic (§Perf lever)."""
    d = _data_axes(mesh)
    return {
        "embed": d if fsdp else None,
        "heads": "model", "kv": "model", "ffn": "model", "vocab": "model",
        "experts": d, "inner": "model", "state": None, "layers": None,
    }


def rules_serve(mesh: Mesh, *, moe_ep: str = "model") -> Dict[str, Any]:
    """moe_ep: which mesh axis carries the MoE expert dim at serving time.
    "model" (baseline): experts sharded 16-way, each expert's weights
    unsharded -> 1/16 of total expert params per device (129 GB for
    kimi-k2 — over HBM).  "data": 2-D expert sharding — experts over data,
    per-expert ffn over model -> 1/256 per device (§Perf P3 lever; the
    batch's token->expert dispatch becomes an all-to-all over data)."""
    return {
        "embed": None,
        "heads": "model", "kv": "model", "ffn": "model", "vocab": "model",
        "experts": moe_ep, "inner": "model", "state": None, "layers": None,
    }


def _normalize(entry) -> Tuple[str, ...]:
    if entry is None:
        return ()
    if isinstance(entry, str):
        return (entry,)
    return tuple(entry)


def spec_for(shape: Sequence[int], axes: Axes, rules: Dict[str, Any],
             mesh: Mesh) -> P:
    """PartitionSpec for one tensor, with divisibility + reuse fallback."""
    sizes = _axis_sizes(mesh)
    used: set = set()
    parts = []
    for dim, ax in zip(shape, axes):
        proposal = [a for a in _normalize(rules.get(ax)) if a not in used]
        # drop axes right-to-left until the dim divides
        while proposal:
            prod = int(np.prod([sizes[a] for a in proposal]))
            if dim % prod == 0:
                break
            proposal = proposal[:-1]
        if proposal:
            used.update(proposal)
            parts.append(tuple(proposal) if len(proposal) > 1 else proposal[0])
        else:
            parts.append(None)
    return P(*parts)


def param_pspecs(cfg: ModelConfig, mesh: Mesh, rules: Dict[str, Any]) -> Any:
    return jax.tree_util.tree_map(
        lambda s: spec_for(s.shape, s.axes, rules, mesh), param_specs(cfg)
    )


def param_shardings(cfg: ModelConfig, mesh: Mesh, rules: Dict[str, Any]) -> Any:
    return jax.tree_util.tree_map(
        lambda p: NamedSharding(mesh, p), param_pspecs(cfg, mesh, rules)
    )


def batch_pspec(mesh: Mesh) -> P:
    return P(_data_axes(mesh))


_SENTINEL_B, _SENTINEL_C = 1717, 1719


def cache_pspec_tree(
    cfg: ModelConfig, mesh: Mesh, batch: int, capacity: int,
    *, kv_policy: str = "feature_first",
) -> Any:
    """PartitionSpecs for the serving cache pytree.

    Batch dims go over the data axes.  The model-axis placement of KV
    leaves is the §Perf lever:

    * ``feature_first`` (the paper-faithful baseline we dry-ran): shard the
      first model-divisible non-batch dim — kv_heads when divisible, else
      head_dim.  head_dim sharding forces GSPMD resharding (involuntary
      full rematerialization) around the attention einsum.
    * ``seq_first``: shard the cache *sequence* dim over model (flash-
      decoding sequence parallelism): the attention contraction batches
      over the sharded axis, partial softmax stats combine with small
      collectives, no replication.  Found in hillclimb #1.

    Recurrent-state leaves shard their d_inner / head dim over model.
    Batch/seq axes are located via sentinel-sized template shapes.
    """
    sizes = _axis_sizes(mesh)
    model = sizes.get("model", 1)
    d = _data_axes(mesh)
    dprod = int(np.prod([sizes[a] for a in d]))

    template = cache_spec(cfg, _SENTINEL_B, _SENTINEL_C)
    real = cache_spec(cfg, batch, capacity)

    def leaf_spec(t: jax.ShapeDtypeStruct, r: jax.ShapeDtypeStruct) -> P:
        tshape, rshape = t.shape, r.shape
        parts: list = [None] * len(rshape)
        seq_axis = None
        for i, (td, rd) in enumerate(zip(tshape, rshape)):
            if td == _SENTINEL_B:  # batch axis
                if rd % dprod == 0:
                    parts[i] = tuple(d) if len(d) > 1 else d[0]
                elif len(d) > 1 and rd % sizes[d[-1]] == 0:
                    parts[i] = d[-1]
            elif td == _SENTINEL_C:
                seq_axis = i

        def try_seq() -> bool:
            if seq_axis is not None and rshape[seq_axis] % model == 0 \
                    and parts[seq_axis] is None:
                parts[seq_axis] = "model"
                return True
            return False

        def try_feature() -> bool:
            cand = [
                i for i, (td, rd) in enumerate(zip(tshape, rshape))
                if td not in (_SENTINEL_B, _SENTINEL_C) and parts[i] is None
                and rd % model == 0 and rd >= model and i >= 1
            ]
            if cand:
                parts[cand[0]] = "model"
                return True
            return False

        if kv_policy == "seq_first" and seq_axis is not None:
            try_seq() or try_feature()
        else:
            try_feature() or try_seq()
        return P(*parts)

    return jax.tree_util.tree_map(leaf_spec, template, real)
