"""Logical-axis → PartitionSpec rules (MaxText-style, minimal).

``param_specs`` (models/base.py) annotates every tensor dim with a logical
axis name; this module maps those names onto mesh axes per execution mode:

  * TRAIN — FSDP: weight ``embed`` dims sharded over the data axes
    (ZeRO-3-style, all-gathered per layer by GSPMD), tensor-parallel
    ``heads/ffn/vocab`` over ``model``, MoE ``experts`` expert-parallel
    over the data axes.
  * SERVE — weights replicated over data (decode batches shard over data),
    tensor-parallel over ``model``; MoE experts expert-parallel over
    ``model`` (all-to-all dispatch inside a chip group).

Divisibility fallback: if a dim is not divisible by the mesh-axes product
(e.g. kv_heads=8 over model=16), axes are dropped right-to-left until it
divides — every (arch × shape × mesh) combination must lower, so the rules
degrade to replication rather than erroring (DESIGN.md §5).  A mesh axis is
never used twice in one PartitionSpec (GSPMD requirement); first dim wins.
"""

from __future__ import annotations

from typing import Any, Dict, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.determinism import (
    Schedule, VERIFY_SCHEDULE, _split_sizes, matmul as sched_matmul, tree_combine,
)
from repro.models.base import ModelConfig, param_specs
from repro.models.transformer import cache_spec


Axes = Tuple[str, ...]


def tp_matmul(
    x: jax.Array,
    w: jax.Array,
    mesh: Mesh,
    *,
    axis: str = "model",
    schedule: Schedule = VERIFY_SCHEDULE,
) -> jax.Array:
    """Row-parallel commit-path GEMM under the canonical mesh-reduction schedule.

    The physical realization of ``core.determinism.matmul`` with a pinned
    schedule: ``w``'s K dim is sharded over the ``axis`` mesh axis (width d),
    each device reduces its ``tp_shards/d`` canonical K chunks to f32
    partials and sums them through its *local subtree* of the balanced tree,
    then a recursive-doubling butterfly (``ppermute`` XOR pairs, one add per
    level) completes the top log2(d) levels **in the same association** —
    ``((p0+p1)+(p2+p3))`` regardless of d.  IEEE addition is commutative
    bitwise, so each device adding (mine + received) lands on the identical
    sum.  Hence the result is bitwise equal to the single-device
    ``matmul(x, w, schedule)`` for every power-of-two d dividing
    ``schedule.tp_shards`` — a token committed on TP=1 is the token
    committed on TP=2/4.

    Falls back to the logical single-device path when the mesh axis is
    absent/1-wide, when d does not divide ``tp_shards``, or when K is not
    divisible by ``tp_shards`` (chunk boundaries would straddle shards).
    """
    from jax.experimental.shard_map import shard_map

    K = x.shape[-1]
    d = _axis_sizes(mesh).get(axis, 1)
    tp = schedule.tp_shards
    if (
        d <= 1 or tp <= 1 or tp > K
        or tp % d != 0 or K % tp != 0 or (d & (d - 1)) != 0
    ):
        return sched_matmul(x, w, schedule)

    chunk = K // tp
    per_dev = tp // d
    local = schedule._replace(tp_shards=1, tp_pinned=False)
    out_dtype = x.dtype

    def body(xb: jax.Array, wb: jax.Array) -> jax.Array:
        # xb: (..., K/d) local activation slice; wb: (K/d, N) weight shard.
        parts = []
        for c in range(per_dev):
            xc = jax.lax.slice_in_dim(
                xb, c * chunk, (c + 1) * chunk, axis=xb.ndim - 1
            )
            wc = jax.lax.slice_in_dim(wb, c * chunk, (c + 1) * chunk, axis=0)
            parts.append(
                sched_matmul(
                    xc.astype(jnp.float32), wc.astype(jnp.float32), local
                )
            )
        acc = tree_combine(parts)  # this device's local subtree, f32
        if schedule.tp_pinned:
            dist = 1
            while dist < d:  # top log2(d) tree levels, canonical association
                perm = [(i, i ^ dist) for i in range(d)]
                acc = acc + jax.lax.ppermute(acc, axis, perm=perm)
                dist *= 2
        else:
            # un-pinned: mesh-order ring reduce in combine_dtype — the
            # fast-path hazard; result depends on d.
            cd = jnp.dtype(schedule.combine_dtype)
            acc = jax.lax.psum(acc.astype(cd), axis)
        return acc.astype(out_dtype)

    x_spec = P(*([None] * (x.ndim - 1) + [axis]))
    w_spec = P(axis, None)
    fn = shard_map(
        body, mesh, in_specs=(x_spec, w_spec), out_specs=P(),
        check_rep=False,
    )
    return fn(x, w)


def _axis_sizes(mesh: Mesh) -> Dict[str, int]:
    return dict(mesh.shape)  # works for Mesh and AbstractMesh


def _data_axes(mesh: Mesh) -> Tuple[str, ...]:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def rules_train(mesh: Mesh, *, fsdp: bool = True) -> Dict[str, Any]:
    """fsdp=False replicates weights over the data axes (pure DP x TP) —
    trades memory for the per-layer all-gather traffic (§Perf lever)."""
    d = _data_axes(mesh)
    return {
        "embed": d if fsdp else None,
        "heads": "model", "kv": "model", "ffn": "model", "vocab": "model",
        "experts": d, "inner": "model", "state": None, "layers": None,
    }


def rules_serve(mesh: Mesh, *, moe_ep: str = "model") -> Dict[str, Any]:
    """moe_ep: which mesh axis carries the MoE expert dim at serving time.
    "model" (baseline): experts sharded 16-way, each expert's weights
    unsharded -> 1/16 of total expert params per device (129 GB for
    kimi-k2 — over HBM).  "data": 2-D expert sharding — experts over data,
    per-expert ffn over model -> 1/256 per device (§Perf P3 lever; the
    batch's token->expert dispatch becomes an all-to-all over data)."""
    return {
        "embed": None,
        "heads": "model", "kv": "model", "ffn": "model", "vocab": "model",
        "experts": moe_ep, "inner": "model", "state": None, "layers": None,
    }


def _normalize(entry) -> Tuple[str, ...]:
    if entry is None:
        return ()
    if isinstance(entry, str):
        return (entry,)
    return tuple(entry)


def spec_for(shape: Sequence[int], axes: Axes, rules: Dict[str, Any],
             mesh: Mesh) -> P:
    """PartitionSpec for one tensor, with divisibility + reuse fallback."""
    sizes = _axis_sizes(mesh)
    used: set = set()
    parts = []
    for dim, ax in zip(shape, axes):
        proposal = [a for a in _normalize(rules.get(ax)) if a not in used]
        # drop axes right-to-left until the dim divides
        while proposal:
            prod = int(np.prod([sizes[a] for a in proposal]))
            if dim % prod == 0:
                break
            proposal = proposal[:-1]
        if proposal:
            used.update(proposal)
            parts.append(tuple(proposal) if len(proposal) > 1 else proposal[0])
        else:
            parts.append(None)
    return P(*parts)


def param_pspecs(cfg: ModelConfig, mesh: Mesh, rules: Dict[str, Any]) -> Any:
    return jax.tree_util.tree_map(
        lambda s: spec_for(s.shape, s.axes, rules, mesh), param_specs(cfg)
    )


def param_shardings(cfg: ModelConfig, mesh: Mesh, rules: Dict[str, Any]) -> Any:
    return jax.tree_util.tree_map(
        lambda p: NamedSharding(mesh, p), param_pspecs(cfg, mesh, rules)
    )


def batch_pspec(mesh: Mesh) -> P:
    return P(_data_axes(mesh))


_SENTINEL_B, _SENTINEL_C = 1717, 1719


def cache_pspec_tree(
    cfg: ModelConfig, mesh: Mesh, batch: int, capacity: int,
    *, kv_policy: str = "feature_first",
) -> Any:
    """PartitionSpecs for the serving cache pytree.

    Batch dims go over the data axes.  The model-axis placement of KV
    leaves is the §Perf lever:

    * ``feature_first`` (the paper-faithful baseline we dry-ran): shard the
      first model-divisible non-batch dim — kv_heads when divisible, else
      head_dim.  head_dim sharding forces GSPMD resharding (involuntary
      full rematerialization) around the attention einsum.
    * ``seq_first``: shard the cache *sequence* dim over model (flash-
      decoding sequence parallelism): the attention contraction batches
      over the sharded axis, partial softmax stats combine with small
      collectives, no replication.  Found in hillclimb #1.

    Recurrent-state leaves shard their d_inner / head dim over model.
    Batch/seq axes are located via sentinel-sized template shapes.
    """
    sizes = _axis_sizes(mesh)
    model = sizes.get("model", 1)
    d = _data_axes(mesh)
    dprod = int(np.prod([sizes[a] for a in d]))

    template = cache_spec(cfg, _SENTINEL_B, _SENTINEL_C)
    real = cache_spec(cfg, batch, capacity)

    def leaf_spec(t: jax.ShapeDtypeStruct, r: jax.ShapeDtypeStruct) -> P:
        tshape, rshape = t.shape, r.shape
        parts: list = [None] * len(rshape)
        seq_axis = None
        for i, (td, rd) in enumerate(zip(tshape, rshape)):
            if td == _SENTINEL_B:  # batch axis
                if rd % dprod == 0:
                    parts[i] = tuple(d) if len(d) > 1 else d[0]
                elif len(d) > 1 and rd % sizes[d[-1]] == 0:
                    parts[i] = d[-1]
            elif td == _SENTINEL_C:
                seq_axis = i

        def try_seq() -> bool:
            if seq_axis is not None and rshape[seq_axis] % model == 0 \
                    and parts[seq_axis] is None:
                parts[seq_axis] = "model"
                return True
            return False

        def try_feature() -> bool:
            cand = [
                i for i, (td, rd) in enumerate(zip(tshape, rshape))
                if td not in (_SENTINEL_B, _SENTINEL_C) and parts[i] is None
                and rd % model == 0 and rd >= model and i >= 1
            ]
            if cand:
                parts[cand[0]] = "model"
                return True
            return False

        if kv_policy == "seq_first" and seq_axis is not None:
            try_seq() or try_feature()
        else:
            try_feature() or try_seq()
        return P(*parts)

    return jax.tree_util.tree_map(leaf_spec, template, real)
