"""Dual-stream request tracing with Chrome/Perfetto trace-event export.

Spans are recorded against the engine's :class:`DualClockRuntime` timeline
— the same clock that decides verdict deadlines — so a trace shows exactly
what the scheduler saw: decode/prefill passes on the **main** stream row,
deferred verification on the **verify** stream row (queueing, backlog and
all), protocol instants (window submit, commit, rollback, preempt,
restore) on a third row, and one async track per request spanning
submit → retire.

Two timing modes, matching the runtime's:

* **costed clock** — every pass has real ``(start, finish)`` stream times
  (``ExecStream.launch``); the runtime stashes the last span per stream
  (``last_main_span`` / ``last_verify_span``) and the engine hands it to
  :meth:`Tracer.pass_span` verbatim.
* **logical clock** — passes have no duration (the clock ticks once per
  iteration), so ``pass_span`` receives ``span=None`` and the tracer
  defers layout: at :meth:`end_iteration` the iteration's pending passes
  are laid out sequentially across the iteration window ``[t0, t1]``.
  Relative widths are synthetic; ordering, stream attribution and nesting
  are real.

A fused mixed-batch launch (``Engine._fused_step``) renders as ONE parent
``fused_step`` slice on the main row with its sub-passes nested inside:
the engine brackets the sub-pass bookkeeping with ``begin_group`` /
``end_group`` and the tracer emits a parent span covering the min/max
envelope of the group's children.

Export is the Chrome trace-event JSON format (the ``traceEvents`` array
form) — loadable in Perfetto (https://ui.perfetto.dev) or
``chrome://tracing``:

* ``"ph": "X"`` complete slices for passes (``ts``/``dur`` in µs),
* ``"ph": "i"`` instants for protocol events,
* ``"ph": "b"``/``"e"`` async begin/end per request lifecycle,
* ``"ph": "M"`` metadata naming the process and the stream rows.

:func:`validate_chrome_trace` is the schema gate CI runs on every exported
trace: required fields per phase type, non-negative µs clocks, per-row
monotonicity, and proper slice nesting (no partial overlap within a row).

The tracer is host-side bookkeeping only — it never changes what the
engine launches, so committed streams are bitwise identical with tracing
on or off (``tests/test_obs.py`` proves it property-style).  When tracing
is off the engine holds a :class:`NullTracer` whose methods are no-ops
behind a single ``enabled`` flag check.
"""

from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

#: thread-id (row) assignment: one process, three fixed rows + per-request
#: async tracks (async events carry their own ids, not tids)
TID_MAIN = 0
TID_VERIFY = 1
TID_PROTOCOL = 2

_TID_FOR_STREAM = {"main": TID_MAIN, "verify": TID_VERIFY,
                   "protocol": TID_PROTOCOL}
_THREAD_NAMES = {TID_MAIN: "main stream", TID_VERIFY: "verify stream",
                 TID_PROTOCOL: "protocol"}

_US = 1e6  # stream-clock seconds/ticks -> trace microseconds


class NullTracer:
    """No-op recorder: one attribute read per call site, zero allocation."""

    enabled = False

    def begin_iteration(self, it: int, t0: float) -> None:
        pass

    def end_iteration(self, t1: float) -> None:
        pass

    def pass_span(self, stream: str, name: str,
                  span: Optional[Tuple[float, float]],
                  args: Optional[Dict[str, Any]] = None) -> None:
        pass

    def instant(self, name: str, t: float, stream: str = "protocol",
                **args: Any) -> None:
        pass

    def request_begin(self, rid: int, t: float) -> None:
        pass

    def request_end(self, rid: int, t: float) -> None:
        pass

    def begin_group(self, name: str, **args: Any) -> None:
        pass

    def end_group(self) -> None:
        pass

    def to_chrome_trace(
        self, pid: int = 0, process_name: str = "llm42-engine"
    ) -> Dict[str, Any]:
        return {"traceEvents": [], "displayTimeUnit": "ms"}


class Tracer(NullTracer):
    enabled = True

    def __init__(self) -> None:
        #: finished slices: (name, tid, start, end, args)
        self._spans: List[Tuple[str, int, float, float, Dict[str, Any]]] = []
        #: instants: (name, tid, t, args)
        self._instants: List[Tuple[str, int, float, Dict[str, Any]]] = []
        #: async request events: (ph, rid, t)
        self._asyncs: List[Tuple[str, int, float]] = []
        #: passes awaiting layout: (stream, name, span|None, args, group_id)
        self._pending: List[Tuple[str, str, Optional[Tuple[float, float]],
                                  Dict[str, Any], int]] = []
        self._groups: Dict[int, Tuple[str, Dict[str, Any]]] = {}
        self._group_id = 0
        self._open_group: Optional[int] = None
        self._t0 = 0.0
        self._it = 0

    # -- iteration protocol --------------------------------------------

    def begin_iteration(self, it: int, t0: float) -> None:
        self._it = it
        self._t0 = float(t0)

    def end_iteration(self, t1: float) -> None:
        """Lay out the iteration's pending passes.  Spans that arrived
        with explicit stream times pass through; logical-clock spans
        (``span=None``) divide the iteration window ``[t0, t1]`` equally,
        in record order."""
        self._flush(float(t1))

    def _flush(self, t1: float) -> None:
        if not self._pending:
            return
        t0 = self._t0
        if t1 <= t0:
            t1 = t0 + 1.0  # degenerate window (drained-engine tail flush)
        n_synth = sum(1 for p in self._pending if p[2] is None)
        w = (t1 - t0) / max(n_synth, 1)
        cursor = t0
        placed: Dict[Tuple[int, int], List[Tuple[float, float]]] = {}
        for stream, name, span, args, gid in self._pending:
            if span is None:
                span = (cursor, cursor + w)
                cursor += w
            start, end = float(span[0]), float(span[1])
            end = max(end, start)  # zero-width passes still render
            tid = _TID_FOR_STREAM[stream]
            self._spans.append((name, tid, start, end, args))
            if gid >= 0:
                placed.setdefault((gid, tid), []).append((start, end))
        self._pending.clear()
        # fused groups: one parent slice nesting the group's sub-passes
        # (the "one launch with nested sub-pass slices" rendering).  The
        # parent lives on the main row and covers only main-row members —
        # verify sub-passes keep their stream-truthful verify-row slices
        # (they may drain past the iteration, and a cross-row envelope
        # would partially overlap the next iteration's main work).  A
        # verify-only fused launch parents on the verify row instead.
        for gid, (gname, gargs) in sorted(self._groups.items()):
            members = placed.get((gid, TID_MAIN))
            tid = TID_MAIN
            if not members:
                members = placed.get((gid, TID_VERIFY))
                tid = TID_VERIFY
            if not members:
                continue
            start = min(s for s, _ in members)
            end = max(e for _, e in members)
            self._spans.append((gname, tid, start, end, gargs))
        self._groups.clear()

    # -- recording ------------------------------------------------------

    def pass_span(self, stream: str, name: str,
                  span: Optional[Tuple[float, float]],
                  args: Optional[Dict[str, Any]] = None) -> None:
        """One device pass on ``stream`` ("main"/"verify").  ``span`` is
        the runtime's ``(start, finish)`` stream time, or None under the
        logical clock (laid out at ``end_iteration``)."""
        a = dict(args or {})
        a.setdefault("iter", self._it)
        gid = self._open_group if self._open_group is not None else -1
        self._pending.append((stream, name, span, a, gid))

    def instant(self, name: str, t: float, stream: str = "protocol",
                **args: Any) -> None:
        args.setdefault("iter", self._it)
        self._instants.append((name, _TID_FOR_STREAM[stream], float(t), args))

    def request_begin(self, rid: int, t: float) -> None:
        self._asyncs.append(("b", rid, float(t)))

    def request_end(self, rid: int, t: float) -> None:
        self._asyncs.append(("e", rid, float(t)))

    def begin_group(self, name: str, **args: Any) -> None:
        """Open a fused-launch group: subsequent ``pass_span`` calls nest
        under one parent slice until ``end_group``."""
        args.setdefault("iter", self._it)
        self._group_id += 1
        self._groups[self._group_id] = (name, args)
        self._open_group = self._group_id

    def end_group(self) -> None:
        self._open_group = None

    # -- export ---------------------------------------------------------

    def to_chrome_trace(
        self, pid: int = 0, process_name: str = "llm42-engine"
    ) -> Dict[str, Any]:
        """Chrome trace-event JSON (``traceEvents`` array form).

        ``pid``/``process_name`` namespace this tracer's rows: the cluster
        front end exports each replica under its own pid, so Perfetto
        shows the fleet side by side as separate processes
        (``Cluster.chrome_trace`` merges the per-replica arrays; the
        validator keys rows on (pid, tid), so a merged trace validates).
        """
        self._flush(self._t0 + 1.0)  # leftovers from a final partial iter
        events: List[Dict[str, Any]] = [
            {"ph": "M", "pid": pid, "tid": 0, "name": "process_name",
             "args": {"name": process_name}},
        ]
        for tid, tname in _THREAD_NAMES.items():
            events.append({"ph": "M", "pid": pid, "tid": tid,
                           "name": "thread_name", "args": {"name": tname}})
        # complete slices, per-row (ts, -dur) order => parents precede
        # children at equal boundaries, rows are monotone
        for name, tid, start, end, args in sorted(
            self._spans, key=lambda s: (s[1], s[2], -(s[3] - s[2]))
        ):
            # dur from the ROUNDED endpoints: adjacent slices then abut
            # exactly instead of drifting apart by float error
            ts = round(start * _US, 3)
            events.append({
                "ph": "X", "pid": pid, "tid": tid, "name": name,
                "cat": "pass", "ts": ts,
                "dur": round(round(end * _US, 3) - ts, 3),
                "args": args,
            })
        for name, tid, t, args in sorted(self._instants, key=lambda i: i[2]):
            events.append({
                "ph": "i", "pid": pid, "tid": tid, "name": name,
                "cat": "protocol", "s": "t", "ts": round(t * _US, 3),
                "args": args,
            })
        for ph, rid, t in sorted(self._asyncs, key=lambda a: (a[2], a[0])):
            events.append({
                "ph": ph, "pid": pid, "tid": TID_PROTOCOL,
                "name": f"request {rid}", "cat": "request", "id": str(rid),
                "ts": round(t * _US, 3),
            })
        return {"traceEvents": events, "displayTimeUnit": "ms"}


def validate_chrome_trace(trace: Any) -> List[str]:
    """Schema-check an exported trace; returns problems (empty = valid).

    Enforces what Perfetto's importer needs: the ``traceEvents`` container,
    required fields per phase, non-negative µs clocks, per-(pid, tid)
    monotone ``X`` starts, matched async begin/end per id, and proper
    nesting — two slices on one row either disjoint or contained, never
    partially overlapping."""
    errs: List[str] = []
    if not isinstance(trace, dict) or not isinstance(
        trace.get("traceEvents"), list
    ):
        return ["top level must be an object with a 'traceEvents' list"]
    by_row: Dict[Tuple[int, int], List[Tuple[float, float, str]]] = {}
    async_depth: Dict[str, int] = {}
    for i, ev in enumerate(trace["traceEvents"]):
        where = f"event[{i}]"
        if not isinstance(ev, dict):
            errs.append(f"{where}: not an object")
            continue
        ph = ev.get("ph")
        if ph not in ("X", "i", "b", "e", "M", "C"):
            errs.append(f"{where}: unknown phase {ph!r}")
            continue
        for field in ("pid", "name"):
            if field not in ev:
                errs.append(f"{where} (ph={ph}): missing {field!r}")
        if ph == "M":
            continue
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)) or ts < 0:
            errs.append(f"{where} (ph={ph}): ts must be a non-negative "
                        f"number of microseconds, got {ts!r}")
            continue
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                errs.append(f"{where}: X event needs dur >= 0, got {dur!r}")
                continue
            if "tid" not in ev:
                errs.append(f"{where}: X event missing tid")
                continue
            by_row.setdefault((ev["pid"], ev["tid"]), []).append(
                (float(ts), float(ts) + float(dur), ev.get("name", "?"))
            )
        elif ph == "i":
            if ev.get("s", "t") not in ("t", "p", "g"):
                errs.append(f"{where}: instant scope must be t/p/g")
        elif ph in ("b", "e"):
            if "id" not in ev or "cat" not in ev:
                errs.append(f"{where}: async {ph} event needs id and cat")
                continue
            d = async_depth.get(str(ev["id"]), 0) + (1 if ph == "b" else -1)
            async_depth[str(ev["id"])] = d
            if d < 0:
                errs.append(f"{where}: async end before begin for "
                            f"id {ev['id']!r}")
    for aid, d in async_depth.items():
        if d > 0:
            errs.append(f"async id {aid!r}: {d} begin(s) without end")
    eps = 1e-6  # sub-nanosecond slack for float error in ts + dur sums
    for (pid, tid), rows in by_row.items():
        last_start = -1.0
        for start, _, name in rows:
            if start < last_start - eps:
                errs.append(
                    f"row (pid={pid}, tid={tid}): X events not sorted by ts "
                    f"at slice {name!r}"
                )
                break
            last_start = start
        stack: List[Tuple[float, float, str]] = []
        for start, end, name in rows:
            while stack and start >= stack[-1][1] - eps:
                stack.pop()
            if stack and end > stack[-1][1] + eps:
                errs.append(
                    f"row (pid={pid}, tid={tid}): slice {name!r} "
                    f"[{start}, {end}) partially overlaps enclosing "
                    f"{stack[-1][2]!r} [{stack[-1][0]}, {stack[-1][1]})"
                )
                break
            stack.append((start, end, name))
    return errs
