"""Typed metrics registry: counters, gauges, histograms, one snapshot.

The serving stack used to scatter its telemetry: ``Engine.mem_stats()``
(block pool + preemption lane), raw attributes on the dual-clock runtime
(``peak_outstanding``, ``outstanding_verdicts``), per-request stat fields
summed ad hoc by every benchmark, and prefix-cache counters behind their
own ``stats()``.  This module is the one source of truth those callers now
share: the engine registers every series at construction, ``snapshot()``
returns a flat ``{name: value}`` dict, and ``describe()`` is the
machine-readable catalog (name, kind, unit, help) the README table is
generated from.

Design constraints (ISSUE 9):

* **Always on, observer-effect-free.**  The registry is pure host-side
  bookkeeping over values the engine already computes — it never touches
  device code, so committed streams are bitwise identical whether anyone
  ever calls ``snapshot()``.
* **Pull-based gauges.**  Occupancy-style series (blocks in use, stream
  backlog, queue depths) register a ``gauge_fn`` callback instead of being
  pushed every iteration: reading them costs nothing until a snapshot is
  taken, and they can never go stale.  Callbacks must close over ``self``
  lookups (e.g. ``lambda: self.runtime.peak_outstanding``), not over the
  objects themselves — ``Engine.bind_cost_model`` replaces the runtime
  wholesale.
* **Exact histograms.**  Histograms keep raw observations (these are
  discrete-event runs of bounded length, not an unbounded prod firehose),
  so snapshot percentiles are exact, not bucket-interpolated.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Any, Callable, Dict, List, Optional


def _num(v: float) -> Any:
    """ints stay ints in snapshots (JSON-friendly, test-friendly)."""
    f = float(v)
    return int(f) if f.is_integer() else f


@dataclasses.dataclass
class Counter:
    """Monotone non-negative accumulator."""

    name: str
    unit: str = ""
    help: str = ""
    value: float = 0.0

    def inc(self, n: float = 1.0) -> None:
        assert n >= 0, f"counter {self.name} cannot decrease"
        self.value += n


@dataclasses.dataclass
class Gauge:
    """Point-in-time value, set by the owner."""

    name: str
    unit: str = ""
    help: str = ""
    value: float = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def set_max(self, v: float) -> None:
        """High-watermark update (peak concurrency, peak depth)."""
        self.value = max(self.value, float(v))


@dataclasses.dataclass
class GaugeFn:
    """Pull-based gauge: ``fn()`` is evaluated at snapshot time."""

    name: str
    fn: Callable[[], float]
    unit: str = ""
    help: str = ""

    @property
    def value(self) -> float:
        return float(self.fn())


class Histogram:
    """Exact-value histogram; snapshot reports count/sum/min/max/mean and
    the p50/p90/p99 percentiles (nearest-rank, matching
    ``serving.online.percentile``)."""

    PERCENTILES = (50, 90, 99)

    def __init__(self, name: str, unit: str = "", help: str = "") -> None:
        self.name = name
        self.unit = unit
        self.help = help
        self.values: List[float] = []

    def observe(self, v: float) -> None:
        self.values.append(float(v))

    def summary(self) -> Dict[str, Any]:
        vs = self.values
        if not vs:
            return {"count": 0, "sum": 0, "min": 0, "max": 0, "mean": 0,
                    **{f"p{p}": 0 for p in self.PERCENTILES}}
        s = sorted(vs)
        out: Dict[str, Any] = {
            "count": len(vs),
            "sum": _num(sum(vs)),
            "min": _num(s[0]),
            "max": _num(s[-1]),
            "mean": sum(vs) / len(vs),
        }
        for p in self.PERCENTILES:
            idx = min(int(p / 100.0 * len(s)), len(s) - 1)
            out[f"p{p}"] = _num(s[idx])
        return out


class MetricsRegistry:
    """Get-or-create registry of named series.

    Names are dot-namespaced by subsystem (``blockpool.blocks_in_use``,
    ``verify.rollbacks``, ``latency.ttft``).  Re-registering a name returns
    the existing series (so idempotent wiring is safe) but re-registering
    it as a *different kind* is a bug and asserts.
    """

    def __init__(self) -> None:
        self._series: Dict[str, Any] = {}

    def _get_or_create(self, kind: type, name: str, make: Callable[[], Any]):
        existing = self._series.get(name)
        if existing is not None:
            assert isinstance(existing, kind), (
                f"metric {name!r} already registered as "
                f"{type(existing).__name__}, not {kind.__name__}"
            )
            return existing
        series = make()
        self._series[name] = series
        return series

    def counter(self, name: str, unit: str = "", help: str = "") -> Counter:
        return self._get_or_create(
            Counter, name, lambda: Counter(name, unit, help)
        )

    def gauge(self, name: str, unit: str = "", help: str = "") -> Gauge:
        return self._get_or_create(Gauge, name, lambda: Gauge(name, unit, help))

    def gauge_fn(
        self, name: str, fn: Callable[[], float], unit: str = "",
        help: str = "",
    ) -> GaugeFn:
        g = self._get_or_create(
            GaugeFn, name, lambda: GaugeFn(name, fn, unit, help)
        )
        g.fn = fn  # re-wiring replaces the callback (engine re-binds)
        return g

    def histogram(self, name: str, unit: str = "", help: str = "") -> Histogram:
        return self._get_or_create(
            Histogram, name, lambda: Histogram(name, unit, help)
        )

    # ------------------------------------------------------------------

    def snapshot(self) -> Dict[str, Any]:
        """Flat ``{name: value}`` view of every series.  Histograms expand
        to ``name.count`` / ``name.sum`` / ``name.mean`` / ``name.min`` /
        ``name.max`` / ``name.p50|p90|p99`` keys."""
        out: Dict[str, Any] = {}
        for name in sorted(self._series):
            s = self._series[name]
            if isinstance(s, Histogram):
                for k, v in s.summary().items():
                    out[f"{name}.{k}"] = v
            else:
                out[name] = _num(s.value)
        return out

    def describe(self) -> List[Dict[str, str]]:
        """Catalog rows: (name, kind, unit, help) per registered series."""
        kinds = {Counter: "counter", Gauge: "gauge", GaugeFn: "gauge",
                 Histogram: "histogram"}
        return [
            {
                "name": name,
                "kind": kinds[type(s)],
                "unit": s.unit,
                "help": s.help,
            }
            for name, s in sorted(self._series.items())
        ]

    def dump(self, path: str) -> None:
        """Write ``{"snapshot": ..., "catalog": ...}`` as JSON."""
        with open(path, "w") as f:
            json.dump(
                {"snapshot": self.snapshot(), "catalog": self.describe()},
                f, indent=1,
            )

    def __contains__(self, name: str) -> bool:
        return name in self._series

    def get(self, name: str) -> Optional[Any]:
        return self._series.get(name)
