"""Per-token determinism audit log: a provenance record per committed token.

"Beyond Reproducibility: Token Probabilities Expose LLM Nondeterminism"
(PAPERS.md) makes the case that determinism must be *observed* at token
granularity, not just asserted end-to-end.  This module is that
observation: every token the engine commits gets exactly one
:class:`TokenProvenance` record saying **why** it is deterministic — which
reduction schedule committed it, which verify window (and which occurrence
slot of the state-pool ring) it landed through, how much of its window
matched, whether it survived a rollback/cascade at its commit point, and
the verifier's top-1/top-2 logit margin at its position.

The margin field is the dataset the ROADMAP's margin-gated sparse
verification item calibrates against: a token committed with margin ``m``
is stable under any reduction reordering whose accumulated error is
``< m/2``, so the gate's threshold comes straight from this log's margin
distribution vs the kernel error bound.

Record semantics per origin:

* ``prefill`` — T0, sampled from the prompt's last logit under the fixed
  verify-grade schedule (deterministic by construction; window = -1).
* ``decode``  — a fast-path token committed *directly* (NONDET /
  BATCH_INVARIANT modes, and non-deterministic requests under LLM42);
  ``schedule`` is the fast-path schedule that produced it.  LLM42
  deterministic requests never commit from decode — their fast-path
  tokens are candidates, which only appear here once a verify pass
  commits them (origin ``verify``).
* ``verify``  — a token committed by a verify splice: the first
  ``n_match`` are accepted candidates, the last is the verifier's own
  commit token.  ``window``/``occurrence`` name the committing window;
  for pipelined windows ``window`` is the per-request submission sequence
  number, for synchronous (pause-style) passes the per-request verify-pass
  ordinal.  ``rollback``/``cascaded`` say what the committing splice did
  to the speculation behind it — the *victims* of that rollback get no
  record at all (they were never committed).

Rollback victims having no records is the invariant the unit tests pin:
the log covers the committed stream exactly — one record per committed
index, token values matching — and nothing else.

Like the tracer, the log is host-side bookkeeping over values the engine
already computed; margins are produced unconditionally inside the jitted
passes (identical device programs audit-on/off) and only *converted to
Python floats* when a real :class:`AuditLog` is attached.  Committed
streams are bitwise identical either way.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, Iterable, List, Optional, Sequence


@dataclasses.dataclass(frozen=True)
class TokenProvenance:
    """Why one committed token is what it is."""

    rid: int  #: request id
    index: int  #: output index in the committed stream (0 = T0)
    token: int  #: the committed token id
    origin: str  #: "prefill" | "decode" | "verify"
    #: reduction schedule of the committing pass.  Call sites pass the
    #: Schedule object itself (the taint pass proves those names resolve
    #: to VERIFY/INVARIANT on commit paths); it is normalized to
    #: ``str(tuple(schedule))`` here.
    schedule: str
    window: int = -1  #: committing verify window id (-1: not a verify commit)
    occurrence: int = -1  #: state-pool ring slot of that window
    n_match: int = -1  #: the committing window's matched-prefix length
    accepted: bool = False  #: True: matched candidate; False: verifier token
    rollback: bool = False  #: the committing splice rejected speculation
    cascaded: int = 0  #: later windows cascade-invalidated by that splice
    shifted: int = 0  #: candidates the window lost to front normalization
    margin: Optional[float] = None  #: top-1 minus top-2 logit margin

    def __post_init__(self) -> None:
        if not isinstance(self.schedule, str):
            object.__setattr__(
                self, "schedule", str(tuple(self.schedule))
            )

    def to_json(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


class NullAudit:
    """No-op recorder (auditing off): one flag check per call site."""

    enabled = False

    def record(self, rec: TokenProvenance) -> None:
        pass


class AuditLog(NullAudit):
    enabled = True

    def __init__(self) -> None:
        self.records: List[TokenProvenance] = []

    def record(self, rec: TokenProvenance) -> None:
        self.records.append(rec)

    def __len__(self) -> int:
        return len(self.records)

    def for_request(self, rid: int) -> List[TokenProvenance]:
        """One request's records, committed-stream order."""
        return sorted(
            (r for r in self.records if r.rid == rid), key=lambda r: r.index
        )

    def to_jsonl(self, path: str) -> None:
        with open(path, "w") as f:
            for r in self.records:
                f.write(json.dumps(r.to_json()) + "\n")

    def coverage_errors(self, requests: Iterable[Any]) -> List[str]:
        """Check the log covers each request's committed stream exactly:
        every committed index has exactly one record, every record's token
        matches the stream, and no record points outside it (rollback
        victims were never committed, so they must not appear).  Returns
        human-readable problems; empty = the log is a complete, consistent
        certificate."""
        errs: List[str] = []
        by_rid: Dict[int, List[TokenProvenance]] = {}
        for rec in self.records:
            by_rid.setdefault(rec.rid, []).append(rec)
        known = set()
        for req in requests:
            known.add(req.rid)
            committed: Sequence[int] = req.committed
            recs = by_rid.get(req.rid, [])
            seen: Dict[int, int] = {}
            for rec in recs:
                seen[rec.index] = seen.get(rec.index, 0) + 1
                if rec.index < 0 or rec.index >= len(committed):
                    errs.append(
                        f"rid {req.rid}: record index {rec.index} outside "
                        f"committed stream of length {len(committed)}"
                    )
                elif rec.token != committed[rec.index]:
                    errs.append(
                        f"rid {req.rid} index {rec.index}: record token "
                        f"{rec.token} != committed {committed[rec.index]}"
                    )
            for idx in range(len(committed)):
                n = seen.get(idx, 0)
                if n != 1:
                    errs.append(
                        f"rid {req.rid} index {idx}: {n} provenance "
                        f"records (want exactly 1)"
                    )
        for rid in sorted(set(by_rid) - known):
            errs.append(f"records for unknown rid {rid}")
        return errs
