"""Unified observability layer (ISSUE 9): tracing + metrics + audit.

One bundle, three concerns, one wiring point (``Engine(trace=..., audit=...)``):

* :mod:`repro.obs.metrics` — the typed metrics registry every subsystem
  registers into; always on (pure host bookkeeping), ``snapshot()`` is the
  single source of truth ``mem_stats()`` and the benchmarks now read.
* :mod:`repro.obs.trace` — dual-stream request tracing on the engine's
  stream clocks, exported as Chrome/Perfetto trace-event JSON
  (``serve.py --trace-out``).  Off by default (:class:`NullTracer`).
* :mod:`repro.obs.audit` — the per-committed-token determinism audit log
  (``serve.py --audit-out``).  Off by default (:class:`NullAudit`).

Everything here is observer-effect-free by construction: recorders are
host-side, device programs are identical with recording on or off, and
``tests/test_obs.py`` proves committed streams bitwise-identical across
the on/off matrix.
"""

from __future__ import annotations

from repro.obs.audit import AuditLog, NullAudit, TokenProvenance
from repro.obs.metrics import (
    Counter,
    Gauge,
    GaugeFn,
    Histogram,
    MetricsRegistry,
)
from repro.obs.trace import NullTracer, Tracer, validate_chrome_trace


class Observability:
    """The engine's observability bundle.

    ``metrics`` is always a live registry (snapshotting is free until
    called); ``tracer`` and ``audit`` are real recorders only when asked
    for — their Null twins cost one attribute check per call site.
    """

    def __init__(self, *, trace: bool = False, audit: bool = False,
                 registry: MetricsRegistry | None = None) -> None:
        self.metrics = registry if registry is not None else MetricsRegistry()
        self.tracer = Tracer() if trace else NullTracer()
        self.audit = AuditLog() if audit else NullAudit()


__all__ = [
    "AuditLog",
    "Counter",
    "Gauge",
    "GaugeFn",
    "Histogram",
    "MetricsRegistry",
    "NullAudit",
    "NullTracer",
    "Observability",
    "TokenProvenance",
    "Tracer",
    "validate_chrome_trace",
]
