"""Checkpointing: flat-key npz save/restore for params + optimizer state.

Sharding-aware in the trivially correct way for this repo: arrays are
device_get (fully gathered) before save and re-sharded by the caller's jit
in_shardings on restore.  Step metadata travels in the archive.
"""

from __future__ import annotations

import os
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np


_SEP = "::"


def _flatten(tree: Any) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_leaves_with_path(tree):
        key = _SEP.join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        flat[key] = np.asarray(jax.device_get(leaf))
    return flat


def _unflatten_into(template: Any, flat: Dict[str, np.ndarray]) -> Any:
    leaves_with_path = jax.tree_util.tree_leaves_with_path(template)
    treedef = jax.tree_util.tree_structure(template)
    new_leaves = []
    for path, leaf in leaves_with_path:
        key = _SEP.join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        arr = flat[key]
        assert arr.shape == tuple(leaf.shape), (key, arr.shape, leaf.shape)
        new_leaves.append(jnp.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, new_leaves)


def save(path: str, params: Any, opt_state: Any = None, step: int = 0) -> None:
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    payload = {f"params{_SEP}{k}": v for k, v in _flatten(params).items()}
    if opt_state is not None:
        payload.update(
            {f"opt{_SEP}{k}": v for k, v in _flatten(opt_state).items()}
        )
    payload["__step__"] = np.int64(step)
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        np.savez(f, **payload)
    os.replace(tmp, path)


def restore(
    path: str, params_template: Any, opt_template: Any = None
) -> Tuple[Any, Any, int]:
    with np.load(path, allow_pickle=False) as z:
        flat = {k: z[k] for k in z.files}
    step = int(flat.pop("__step__", 0))
    p_flat = {
        k[len("params") + len(_SEP):]: v
        for k, v in flat.items() if k.startswith("params" + _SEP)
    }
    params = _unflatten_into(params_template, p_flat)
    opt_state = None
    if opt_template is not None:
        o_flat = {
            k[len("opt") + len(_SEP):]: v
            for k, v in flat.items() if k.startswith("opt" + _SEP)
        }
        opt_state = _unflatten_into(opt_template, o_flat)
    return params, opt_state, step
