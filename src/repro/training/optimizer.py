"""AdamW optimizer on raw pytrees (no optax dependency), with global-norm
gradient clipping and a linear-warmup + cosine-decay LR schedule."""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

F32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    betas: Tuple[float, float] = (0.9, 0.95)
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1


class OptState(NamedTuple):
    step: jax.Array
    mu: Any  # first moment (pytree like params, f32)
    nu: Any  # second moment


def init_opt_state(params: Any) -> OptState:
    zeros = jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, F32), params)
    return OptState(step=jnp.zeros((), jnp.int32), mu=zeros,
                    nu=jax.tree_util.tree_map(jnp.copy, zeros))


def lr_at(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(F32)
    warm = cfg.lr * step / max(cfg.warmup_steps, 1)
    prog = jnp.clip(
        (step - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1),
        0.0, 1.0,
    )
    cos = cfg.lr * (cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def global_norm(tree: Any) -> jax.Array:
    sq = sum(jnp.sum(jnp.square(g.astype(F32))) for g in jax.tree_util.tree_leaves(tree))
    return jnp.sqrt(sq)


def apply_updates(
    cfg: AdamWConfig, params: Any, grads: Any, state: OptState
) -> Tuple[Any, OptState, Dict[str, jax.Array]]:
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))
    grads = jax.tree_util.tree_map(lambda g: g.astype(F32) * scale, grads)

    step = state.step + 1
    b1, b2 = cfg.betas
    mu = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g, state.mu, grads)
    nu = jax.tree_util.tree_map(lambda v, g: b2 * v + (1 - b2) * g * g, state.nu, grads)
    bc1 = 1 - b1 ** step.astype(F32)
    bc2 = 1 - b2 ** step.astype(F32)
    lr = lr_at(cfg, step)

    def upd(p, m, v):
        mh = m / bc1
        vh = v / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(F32)
        return (p.astype(F32) - lr * delta).astype(p.dtype)

    new_params = jax.tree_util.tree_map(upd, params, mu, nu)
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, OptState(step, mu, nu), metrics
