"""Training step: cross-entropy LM loss (+ MoE aux loss), microbatched
gradient accumulation, optional remat.

``make_train_step(cfg, opt_cfg, num_microbatches)`` returns a jittable
function mapping (params, opt_state, batch) -> (params, opt_state, metrics).
Microbatching scans over the leading batch split so full-scale configs
(global_batch=256 at 4k) never materialise (B, S, V) logits at once —
this is what production frameworks do, and it is what keeps the multi-pod
dry-run's memory analysis sane (DESIGN.md §5).
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.determinism import Schedule, VERIFY_SCHEDULE
from repro.models.base import ModelConfig
from repro.models.transformer import forward_train
from repro.training.optimizer import AdamWConfig, OptState, apply_updates

F32 = jnp.float32


def lm_loss(
    params: Any,
    cfg: ModelConfig,
    tokens: jax.Array,  # (b, S)
    targets: jax.Array,  # (b, S)
    loss_mask: jax.Array,  # (b, S)
    *,
    schedule: Schedule = VERIFY_SCHEDULE,
    enc_embeds: Optional[jax.Array] = None,
    remat: bool = True,
    aux_weight: float = 0.01,
    unroll: bool = False,
    denom: Optional[jax.Array] = None,  # global token count (microbatching)
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    kw = {}
    if cfg.family == "encdec":
        kw["enc_embeds"] = enc_embeds
    logits, aux = forward_train(
        params, cfg, tokens, schedule=schedule, remat=remat, unroll=unroll, **kw
    )
    logits = logits.astype(F32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    tgt = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    ce = (lse - tgt) * loss_mask
    if denom is None:
        denom = jnp.maximum(jnp.sum(loss_mask), 1.0)
    loss = jnp.sum(ce) / denom
    total = loss + aux_weight * aux["aux_loss"]
    metrics = {
        "loss": loss,
        "aux_loss": aux["aux_loss"],
        "dropped_frac": aux["dropped_frac"],
        "tokens": denom,
    }
    return total, metrics


def make_train_step(
    cfg: ModelConfig,
    opt_cfg: AdamWConfig,
    *,
    num_microbatches: int = 1,
    remat: bool = True,
    schedule: Schedule = VERIFY_SCHEDULE,
    unroll: bool = False,
):
    """Build train_step(params, opt_state, batch) -> (params, opt_state, metrics).

    batch: {"tokens": (B, S), "targets": (B, S), "loss_mask": (B, S)
            [, "enc_embeds": (B, Se, D)]}; B must divide by num_microbatches.
    """

    def grads_for(params, mb, denom=None):
        def loss_fn(p):
            return lm_loss(
                p, cfg, mb["tokens"], mb["targets"], mb["loss_mask"],
                schedule=schedule, enc_embeds=mb.get("enc_embeds"),
                remat=remat, unroll=unroll, denom=denom,
            )

        (total, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        return grads, metrics

    def train_step(params, opt_state: OptState, batch):
        B = batch["tokens"].shape[0]
        mb = num_microbatches
        assert B % mb == 0

        def split(x):
            return x.reshape(mb, B // mb, *x.shape[1:])

        mbs = {k: split(v) for k, v in batch.items()}

        if mb == 1:  # no accumulation loop (keeps probe cost analysis exact)
            sq = {k: v[0] for k, v in mbs.items()}
            grads, metrics = grads_for(params, sq)
            new_params, new_opt, opt_metrics = apply_updates(
                opt_cfg, params, grads, opt_state
            )
            return new_params, new_opt, {**metrics, **opt_metrics}

        global_denom = jnp.maximum(jnp.sum(batch["loss_mask"]), 1.0)

        def body(carry, mb_batch):
            acc, _ = carry
            # each microbatch loss is normalized by the GLOBAL token count,
            # so summing gradients reproduces the full-batch gradient exactly
            grads, metrics = grads_for(params, mb_batch, denom=global_denom)
            acc = jax.tree_util.tree_map(
                lambda a, g: a + g.astype(F32), acc, grads
            )
            return (acc, metrics), None

        zero = jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, F32), params)
        dummy_metrics = {
            "loss": jnp.float32(0), "aux_loss": jnp.float32(0),
            "dropped_frac": jnp.float32(0), "tokens": jnp.float32(0),
        }
        (grads, metrics), _ = jax.lax.scan(body, (zero, dummy_metrics), mbs,
                                           unroll=unroll)

        new_params, new_opt, opt_metrics = apply_updates(
            opt_cfg, params, grads, opt_state
        )
        metrics = {**metrics, **opt_metrics}
        return new_params, new_opt, metrics

    return train_step
