"""Data pipelines.

Training: an infinite synthetic token stream (deterministic per seed) with
document structure (BOS-delimited segments of varying length) so attention
masks and loss masking are exercised realistically.

Serving: ShareGPT- and ArXiv-like workload generators matching the paper's
Table 3 length statistics (lognormal fits to the reported mean/median/std),
used by the offline/online benchmark harnesses.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Iterator, List, Tuple

import numpy as np


# ---------------------------------------------------------------------------
# training stream
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class TrainBatch:
    tokens: np.ndarray  # (B, S) int32 inputs
    targets: np.ndarray  # (B, S) int32 next-token labels
    loss_mask: np.ndarray  # (B, S) f32


class SyntheticTextStream:
    """Deterministic document stream: Zipf-ish unigram draws per document
    with a document-specific bigram bias, BOS=0 delimited."""

    def __init__(self, vocab_size: int, seq_len: int, batch_size: int,
                 seed: int = 0, mean_doc_len: int = 512):
        self.vocab = vocab_size
        self.seq = seq_len
        self.batch = batch_size
        self.rng = np.random.default_rng(seed)
        self.mean_doc = mean_doc_len
        # Zipf-like unigram distribution
        ranks = np.arange(1, vocab_size + 1)
        p = 1.0 / ranks
        self.unigram = p / p.sum()

    def _document(self) -> np.ndarray:
        n = max(8, int(self.rng.exponential(self.mean_doc)))
        # markov structure: with p=0.7 continue a deterministic chain,
        # else draw fresh from the Zipf unigram — learnable in ~100 steps
        draws = self.rng.choice(self.vocab, size=n, p=self.unigram)
        cont = self.rng.random(n) < 0.7
        toks = np.empty(n, np.int64)
        toks[0] = 0  # BOS
        for i in range(1, n):
            toks[i] = (toks[i - 1] * 7 + 13) % self.vocab if cont[i] else draws[i]
        return toks.astype(np.int32)

    def __iter__(self) -> Iterator[TrainBatch]:
        buf = np.zeros(0, np.int32)
        while True:
            need = self.batch * (self.seq + 1)
            while buf.size < need:
                buf = np.concatenate([buf, self._document()])
            chunk, buf = buf[:need], buf[need:]
            chunk = chunk.reshape(self.batch, self.seq + 1)
            yield TrainBatch(
                tokens=chunk[:, :-1].copy(),
                targets=chunk[:, 1:].copy(),
                loss_mask=(chunk[:, 1:] != 0).astype(np.float32),
            )


# ---------------------------------------------------------------------------
# serving workloads (paper Table 3)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class WorkloadSpec:
    name: str
    in_mean: float
    in_median: float
    in_std: float
    out_mean: float
    out_median: float
    out_std: float


SHAREGPT = WorkloadSpec("sharegpt", 304, 136, 491, 192, 118, 212)
ARXIV = WorkloadSpec("arxiv", 7017, 6435, 3479, 198, 191, 74)


def _lognormal_params(mean: float, median: float) -> Tuple[float, float]:
    """mean = exp(mu + s^2/2), median = exp(mu)."""
    mu = math.log(max(median, 1.0))
    s2 = max(2.0 * (math.log(max(mean, 1.0)) - mu), 1e-4)
    return mu, math.sqrt(s2)


def sample_workload(
    spec: WorkloadSpec, n: int, seed: int = 0,
    max_in: int = 32768, max_out: int = 2048,
) -> List[Tuple[int, int]]:
    """Returns [(input_len, output_len)] drawn from lognormal fits."""
    rng = np.random.default_rng(seed)
    mu_i, s_i = _lognormal_params(spec.in_mean, spec.in_median)
    mu_o, s_o = _lognormal_params(spec.out_mean, spec.out_median)
    ins = np.clip(rng.lognormal(mu_i, s_i, n), 4, max_in).astype(int)
    outs = np.clip(rng.lognormal(mu_o, s_o, n), 4, max_out).astype(int)
    return list(zip(ins.tolist(), outs.tolist()))


def fixed_workload(n: int, in_len: int, out_len: int) -> List[Tuple[int, int]]:
    """The paper's synthetic in=X/out=Y configurations."""
    return [(in_len, out_len)] * n


def poisson_arrivals(n: int, qps: float, seed: int = 0) -> List[float]:
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / qps, n)
    return np.cumsum(gaps).tolist()
