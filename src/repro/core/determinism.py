"""Reduction schedules — the root cause of (non-)determinism (paper §2.2, O2/O3).

Non-determinism in LLM inference arises because high-performance kernels pick
*different reduction schedules for different input shapes* (split-K factor in
GEMMs, KV-split count in attention), and dynamic batching changes the shape a
given request's tokens are computed under across runs.  Floating point
addition is non-associative, so a different reduction tree produces different
low-order bits, which occasionally flip a sampled token (O1) and then diverge
catastrophically under autoregressive decoding.

This module makes the reduction schedule an explicit, first-class value:

* ``Schedule`` — (splits, kv_splits, combine_dtype).  Two executions of the
  same op with the same ``Schedule`` and the same input shape are bitwise
  identical (shape-consistency, O2).  Executions under different schedules
  are *both correct* but may differ in low-order bits.
* ``ReductionPolicy`` — maps batch size -> Schedule, mimicking the shape
  heuristics of cuBLAS/FlashAttention (split more at small batch to fill the
  machine).  This is what the *fast path* uses; it is why dynamic batching
  perturbs results.
* ``VERIFY_SCHEDULE`` — the fixed schedule used by the verifier
  (splits=1, kv_splits=1, f32 combine): position-consistent by construction.
* ``matmul(x, w, schedule)`` — a GEMM whose accumulation tree is determined
  by ``schedule``.  This routes *every* matrix multiply in the model zoo, so
  the whole forward pass inherits schedule-dependence exactly as on a GPU.

Determinism modes (paper §4.1 / §5):

* ``NONDET``          — fast path everywhere; no verification.
* ``BATCH_INVARIANT`` — the He-et-al. baseline: one universal schedule for
                        every op regardless of batch (deterministic, slow).
* ``LLM42``           — fast path + decode-verify-rollback for the requests
                        that ask for determinism (the paper's contribution).
"""

from __future__ import annotations

import enum
from typing import NamedTuple

import jax
import jax.numpy as jnp


class Mode(enum.Enum):
    NONDET = "nondet"
    BATCH_INVARIANT = "batch_invariant"
    LLM42 = "llm42"


class Schedule(NamedTuple):
    """A concrete reduction schedule.

    ``splits``        K-split count for GEMM reductions.
    ``kv_splits``     sequence-split count for decode attention.
    ``combine_dtype`` dtype in which split partials are combined.  Real GPU
                      split-K kernels accumulate partials in f32 but the
                      combine stage works on values that round-tripped
                      through the epilogue; we expose the dtype so tests and
                      experiments can dial the drift magnitude (f32 ==
                      reorder-only drift, bf16 == epilogue-rounded drift).
    ``moe_no_drop``   disable MoE capacity dropping.  Required for the
                      verifier: with dropping, whether a token overflows an
                      expert bucket depends on the *other* tokens in the
                      pass, so a dropped token's output would depend on its
                      co-grouped requests — breaking position-consistency
                      (O3).  With no dropping, expert GEMMs reduce each row
                      independently, so MoE is position-invariant and the
                      verifier's guarantee extends to MoE archs (a
                      beyond-paper consideration: the paper's Llama-8B has
                      no MoE).  The fast path keeps dropping — it is
                      speculative anyway, and DVR catches drop-induced
                      flips like any other inconsistency.
    ``tp_shards``     tensor-parallel decomposition of the K reduction: the
                      number of contiguous K chunks whose partials are
                      combined across the (logical or physical) ``model``
                      mesh axis.  TP width changes reduction geometry
                      exactly like batch size does ("Deterministic
                      Inference across Tensor Parallel Sizes", PAPERS.md):
                      each device reduces only its weight shard, then the
                      partials meet in a cross-device combine whose tree
                      follows the mesh.
    ``tp_pinned``     True pins the TP partial-sum tree to the *canonical*
                      form — f32 partials combined through a balanced
                      binary tree in f32 — which is realizable bitwise on
                      every mesh whose ``model`` axis width divides
                      ``tp_shards``: a width-d mesh computes each device's
                      local subtree locally and the top log2(d) levels via
                      deterministic manual collectives
                      (``distributed.sharding.tp_matmul``), reproducing the
                      same arithmetic DAG.  False models the un-pinned fast
                      path: partials combine *sequentially in
                      combine_dtype*, mesh (ring-reduce) order — so the
                      result varies with the actual TP width, which is the
                      hazard the commit path must not inherit.
    """

    splits: int = 1
    kv_splits: int = 1
    combine_dtype: str = "float32"
    moe_no_drop: bool = False
    tp_shards: int = 1
    tp_pinned: bool = False


#: The canonical mesh-reduction decomposition: the commit path always
#: reduces K in this many contiguous chunks, f32 partials, balanced-tree
#: f32 combine — independent of the mesh the fast path actually ran on.
#: Any power-of-two TP width d <= CANONICAL_TP_SHARDS realizes the same
#: tree (each device sums its local subtree, the cross-device combine is
#: the top of the same tree), so a token committed on TP=1 is bitwise the
#: token committed on TP=2/4.
CANONICAL_TP_SHARDS = 4

#: The verifier's schedule: no batch-dependent splits, f32 combine, and the
#: canonical (pinned) mesh-reduction decomposition.  Any op executed under
#: this schedule with a fixed input shape is bitwise reproducible (O2);
#: the verifier always pads its input to a fixed window shape, so every
#: verified token position sees this exact schedule on every run (O3); and
#: the pinned TP tree makes the guarantee hold across mesh shapes too.
VERIFY_SCHEDULE = Schedule(
    splits=1, kv_splits=1, combine_dtype="float32", moe_no_drop=True,
    tp_shards=CANONICAL_TP_SHARDS, tp_pinned=True,
)

#: Alias making the mesh story explicit at verifier call sites: the commit
#: path replays under the canonical mesh-reduction schedule.
CANONICAL_MESH_SCHEDULE = VERIFY_SCHEDULE

#: The universal schedule used by BATCH_INVARIANT mode for *all* traffic.
INVARIANT_SCHEDULE = VERIFY_SCHEDULE


class ReductionPolicy(NamedTuple):
    """Maps batch geometry -> Schedule, like a GPU kernel autotuner.

    Real libraries split the reduction dimension more aggressively at small
    batch to occupy more SMs (split-K) / more of the MXU (TPU grid).  The
    thresholds are deliberately explicit so experiments can vary them.
    """

    thresholds: tuple = ((4, 8), (16, 4), (64, 2))  # (batch_upper_bound, splits)
    default_splits: int = 1
    combine_dtype: str = "float32"

    def schedule_for(self, batch_size: int) -> Schedule:
        for bound, splits in self.thresholds:
            if batch_size < bound:
                return Schedule(
                    splits=splits, kv_splits=splits, combine_dtype=self.combine_dtype
                )
        return Schedule(
            splits=self.default_splits,
            kv_splits=self.default_splits,
            combine_dtype=self.combine_dtype,
        )


#: Default fast-path policy.  bfloat16 combine mirrors the magnitude of
#: drift seen on tensor-core split-K epilogues and makes the O1 phenomenon
#: observable at the reduced scales our CPU tests run at.
FAST_PATH_POLICY = ReductionPolicy(combine_dtype="bfloat16")

#: A conservative policy whose drift comes from reordering alone (f32
#: combine).  Flips are much rarer — closer to the paper's production rates.
REORDER_ONLY_POLICY = ReductionPolicy(combine_dtype="float32")


def _split_sizes(k: int, splits: int) -> list:
    """Partition the K dimension into ``splits`` contiguous chunks.

    Mirrors how split-K kernels divide the reduction dim: near-equal chunks,
    remainder spread over the leading chunks.  Chunk boundaries are a pure
    function of (k, splits) so the tree is shape-consistent (O2).
    """
    base, rem = divmod(k, splits)
    return [base + (1 if i < rem else 0) for i in range(splits)]


def _reduce_k_f32(x: jax.Array, w: jax.Array, schedule: Schedule) -> jax.Array:
    """Single-shard K reduction under the *local* split schedule; f32 result.

    This is the arithmetic one device performs on its weight shard: splits<=1
    is one f32 pass; otherwise the split-K chunk loop with sequential
    combine_dtype combine.  The caller owns the cross-shard combine.
    """
    k = x.shape[-1]
    if schedule.splits <= 1 or schedule.splits > k:
        return jnp.matmul(
            x.astype(jnp.float32), w.astype(jnp.float32),
            precision=jax.lax.Precision.HIGHEST,
        )
    combine_dtype = jnp.dtype(schedule.combine_dtype)
    sizes = _split_sizes(k, schedule.splits)
    acc = None
    start = 0
    for size in sizes:
        xc = jax.lax.slice_in_dim(x, start, start + size, axis=x.ndim - 1)
        wc = jax.lax.slice_in_dim(w, start, start + size, axis=0)
        partial = jnp.matmul(
            xc.astype(jnp.float32), wc.astype(jnp.float32),
            precision=jax.lax.Precision.HIGHEST,
        ).astype(combine_dtype)
        acc = partial if acc is None else (acc + partial)
        start += size
    return acc.astype(jnp.float32)


def _tp_partials(x: jax.Array, w: jax.Array, schedule: Schedule) -> list:
    """Per-shard f32 partials of the TP decomposition of the K reduction.

    K is cut into ``schedule.tp_shards`` contiguous chunks — the weight
    sharding a row-parallel matmul would have on a ``model``-axis mesh of
    that width.  Each chunk is reduced with the local split schedule; chunk
    boundaries are a pure function of (k, tp_shards), never of the mesh the
    fast path actually ran on.
    """
    sizes = _split_sizes(x.shape[-1], schedule.tp_shards)
    parts = []
    start = 0
    for size in sizes:
        xc = jax.lax.slice_in_dim(x, start, start + size, axis=x.ndim - 1)
        wc = jax.lax.slice_in_dim(w, start, start + size, axis=0)
        parts.append(_reduce_k_f32(xc, wc, schedule))
        start += size
    return parts


def tree_combine(parts: list) -> jax.Array:
    """Balanced binary tree sum — the canonical cross-shard combine.

    ``((p0+p1)+(p2+p3))`` for four partials.  A width-d mesh (d | len(parts),
    d a power of two) realizes this tree exactly: each device adds its local
    subtree, then the top log2(d) levels complete across devices in the same
    association (``distributed.sharding.tp_matmul``).  Sequential combine
    could NOT serve as the canonical form — ``((p0+p1)+p2)+p3`` on one
    device groups differently from ``(p0+p1) + (p2+p3)`` on two.
    """
    while len(parts) > 1:
        nxt = [parts[i] + parts[i + 1] for i in range(0, len(parts) - 1, 2)]
        if len(parts) % 2:
            nxt.append(parts[-1])
        parts = nxt
    return parts[0]


def matmul(x: jax.Array, w: jax.Array, schedule: Schedule) -> jax.Array:
    """GEMM with an explicit reduction tree: ``x @ w`` under ``schedule``.

    splits == 1: single accumulation pass over K in f32 (the verifier /
    batch-invariant schedule).

    splits == S: K is partitioned into S contiguous chunks; each chunk is
    reduced independently in f32 (a thread-block's partial in CUDA split-K;
    a K-minor grid step in our Pallas kernel), then the partials are combined
    *sequentially in combine_dtype*.  Different S => different accumulation
    tree => potentially different low-order bits.  This is the exact
    mechanism of paper Fig. 3.

    tp_shards == T additionally decomposes K into T mesh chunks *above* the
    local split schedule.  Pinned (commit path): f32 partials, balanced-tree
    f32 combine — the canonical mesh-reduction schedule, bitwise identical
    on every power-of-two TP width dividing T.  Un-pinned (fast path): the
    partials combine sequentially in combine_dtype, modelling a ring
    all-reduce whose tree follows the actual mesh — so the result depends
    on TP width, exactly the hazard O2 names for batch shape.

    Contraction is over the last dim of ``x`` and first dim of ``w``.
    Output dtype follows x.dtype.
    """
    out_dtype = x.dtype
    k = x.shape[-1]
    if schedule.tp_shards > 1 and schedule.tp_shards <= k:
        parts = _tp_partials(x, w, schedule)
        if schedule.tp_pinned:
            acc = tree_combine(parts)
        else:
            combine_dtype = jnp.dtype(schedule.combine_dtype)
            acc = None
            for p in parts:
                pc = p.astype(combine_dtype)
                acc = pc if acc is None else (acc + pc)
        return acc.astype(out_dtype)
    if schedule.splits <= 1 or schedule.splits > k:
        acc = jnp.matmul(
            x.astype(jnp.float32), w.astype(jnp.float32),
            precision=jax.lax.Precision.HIGHEST,
        )
        return acc.astype(out_dtype)

    combine_dtype = jnp.dtype(schedule.combine_dtype)
    sizes = _split_sizes(k, schedule.splits)
    acc = None
    start = 0
    for size in sizes:
        xc = jax.lax.slice_in_dim(x, start, start + size, axis=x.ndim - 1)
        wc = jax.lax.slice_in_dim(w, start, start + size, axis=0)
        partial = jnp.matmul(
            xc.astype(jnp.float32), wc.astype(jnp.float32),
            precision=jax.lax.Precision.HIGHEST,
        ).astype(combine_dtype)
        acc = partial if acc is None else (acc + partial)
        start += size
    return acc.astype(out_dtype)


def segment_reduce_sum(x: jax.Array, axis: int, schedule: Schedule) -> jax.Array:
    """Sum-reduction with a schedule-dependent tree (for norms etc.).

    splits==1 reduces in f32 in one pass; otherwise the axis is chunked and
    partials combine sequentially in combine_dtype.
    """
    if schedule.splits <= 1 or schedule.splits > x.shape[axis]:
        return jnp.sum(x.astype(jnp.float32), axis=axis)
    combine_dtype = jnp.dtype(schedule.combine_dtype)
    sizes = _split_sizes(x.shape[axis], schedule.splits)
    acc = None
    start = 0
    for size in sizes:
        xc = jax.lax.slice_in_dim(x, start, start + size, axis=axis)
        partial = jnp.sum(xc.astype(jnp.float32), axis=axis).astype(combine_dtype)
        acc = partial if acc is None else acc + partial
        start += size
    return acc.astype(jnp.float32)
