"""Consistent-span analysis (paper §3, O1 / Fig. 6).

Machinery to quantify how token-level divergence propagates: run a request
once at batch size one (ground truth), once under dynamic batching, and
measure the first/second consistent spans of the output.
"""

from __future__ import annotations

from typing import NamedTuple, Sequence


class SpanStats(NamedTuple):
    first_span: int  # leading tokens matching ground truth
    second_span: int  # matching tokens between 1st and 2nd divergence
    total: int
    match_frac: float


def consistent_spans(reference: Sequence[int], observed: Sequence[int]) -> SpanStats:
    n = min(len(reference), len(observed))
    matches = [reference[i] == observed[i] for i in range(n)]

    first = 0
    while first < n and matches[first]:
        first += 1

    second = 0
    i = first + 1  # skip the first divergent token
    while i < n and matches[i]:
        second += 1
        i += 1

    frac = sum(matches) / n if n else 1.0
    return SpanStats(first, second, n, frac)
