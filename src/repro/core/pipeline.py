"""Multi-window speculation pipeline (per-request in-flight verify FIFO).

PR 3's ``fig_pipeline`` sweep showed the binding constraint on deep
verify/decode pipelining is the *protocol*, not verify-stream bandwidth:
with one outstanding window per request, 50 ms of verdict latency drops
throughput to ~0.45x pause-decode while the verify stream idles.  The
paper's verify-rollback loop (§4.2) never requires a single outstanding
window — only that commits splice in submission order.  This module owns
that generalized protocol: ``Request.pipeline`` is a FIFO of
:class:`~repro.serving.request.InflightVerify` records (replacing the old
single ``req.inflight`` slot), and the functions here keep three
invariants:

* **in-order splicing** — only the FIFO's *front* verdict may land.  A
  verdict that arrives early (out-of-order landings across launch groups)
  waits until every earlier window of the same request has spliced, so the
  committed stream is extended strictly in submission order.
* **front normalization** — window *k+1* is submitted *chained*: its
  conditioning token is window *k*'s last candidate, and its first
  candidate occupies the same output position as window *k*'s commit
  token.  When window *k* fully matches and its commit token agrees with
  that first candidate, the successor's already-committed head is popped
  (and its ``n_match`` shifted) so the record reaching the FIFO front is
  always *anchored* on ``committed[-1]`` — the depth-1 splice rule then
  applies verbatim at every depth.
* **cascading invalidation** — a rollback in window *k* (partial match, or
  a full match whose commit token disagrees with the next speculated
  token) discards windows *k+1..n* and the fresh speculation tail: they
  all descend from a token the verifier rejected.  The engine restores the
  slot's device state from the window's state-pool checkpoint
  (``serving.statepool``) whenever :attr:`SpliceOutcome.restore_state` is
  set — on every rollback, and on a clean splice that leaves no surviving
  speculation (the live recurrent state then lags the committed stream by
  one token, exactly the gap the checkpoint closes).

Scheduling (when windows launch, how deep the pipeline runs) stays in
``serving.scheduler``; device passes stay in ``core.verifier``.  Nothing
here moves a committed token: the committed stream is the verifier's
reference sequence at every depth, which is what keeps streams bitwise
identical across ``--spec-depth``, policies, clock modes, and adversarial
verdict-landing schedules.
"""

from __future__ import annotations

import dataclasses
from typing import List

from repro.core import dvr
from repro.serving.request import InflightVerify, Request, State


def depth(req: Request) -> int:
    """Windows currently in flight for this request."""
    return len(req.pipeline)


def spec_len(req: Request) -> int:
    """Total candidates inside in-flight windows (sequence positions
    between ``committed`` and the fresh ``candidates`` buffer)."""
    return sum(len(fl.cands) for fl in req.pipeline)


def conditioning_token(req: Request) -> int:
    """The token the next submitted window's replay re-consumes first:
    the last in-flight candidate, or ``committed[-1]`` when the FIFO is
    empty (the anchored, depth-1 case)."""
    if req.pipeline:
        return int(req.pipeline[-1].cands[-1])
    return int(req.committed[-1])


@dataclasses.dataclass
class SpliceOutcome:
    """What one front splice did — the engine's cue for device-state work."""

    record: InflightVerify
    rolled_back: bool  #: any candidate (in-window or cascaded) was rejected
    cascaded: List[InflightVerify]  #: later windows discarded wholesale
    #: True => restore the slot's live state (and replay anchor) from the
    #: record's state-pool checkpoint: required on rollback, and on a clean
    #: splice with no surviving speculation (live recurrent state would
    #: otherwise lag ``committed`` by one consumed token)
    restore_state: bool
    #: True => the FIFO is empty after this splice: the NEXT window will
    #: launch *anchored* on ``committed[-1]``, so the replay anchor must
    #: move to this record's checkpoint (= state after its last candidate
    #: on a full match) even when the live state and a surviving
    #: speculation tail are untouched.  The anchor currently holds the
    #: chained start state (one token earlier), which is only right for a
    #: successor launched behind an in-flight window.
    reanchor: bool = False
    #: committed-stream extent of this splice AFTER the budget clamp:
    #: ``req.committed[committed_base : committed_base + committed_count]``
    #: are exactly the tokens this splice committed — the audit log's
    #: per-token provenance slice (the clamp may truncate the nominal
    #: matched-prefix + commit-token extension, so record counts must come
    #: from here, not from ``n_match``)
    committed_base: int = 0
    committed_count: int = 0
    #: speculated tokens this splice rejected (in-window rollback plus the
    #: cascaded windows' and fresh tail's candidates)
    rejected: int = 0


def submit_window(
    req: Request,
    window: int,
    submitted_at: float,
    ready_at: float,
    ring_idx: int = 0,
) -> InflightVerify:
    """Move the next window's candidates out of the speculation buffer and
    append them to the in-flight FIFO.  The request keeps decoding behind
    the window (fresh candidates queue after it); ``ring_idx`` names the
    state-pool checkpoint buffer the window's verify pass writes."""
    assert req.candidates, "no candidates to submit"
    k = dvr.candidates_per_window(window)
    fl = InflightVerify(
        cands=req.candidates[:k],
        submitted_at=submitted_at,
        ready_at=ready_at,
        cond_tok=conditioning_token(req),
        ring_idx=ring_idx,
        seq=req.window_seq,
    )
    req.candidates = req.candidates[k:]
    req.pipeline.append(fl)
    req.window_seq += 1
    # window is out: the request resumes speculating unless its budget is
    # already covered by outstanding speculation (then it awaits verdicts)
    if req.state is not State.FINISHED:
        req.state = (
            State.AWAITING_VERIFY if req.done_decoding() else State.RUNNING
        )
    return fl


def apply_ready(req: Request, window: int, now: float) -> List[SpliceOutcome]:
    """Splice every *due* verdict at the FIFO front (``ready_at <= now``),
    in submission order.  A ready verdict behind an unready front waits —
    in-order splicing is the protocol invariant that makes out-of-order
    cross-request landings harmless."""
    out: List[SpliceOutcome] = []
    while req.pipeline:
        fl = req.pipeline[0]
        if fl.n_match < 0 or fl.ready_at > now:
            break
        out.append(splice_front(req, window))
    return out


def splice_front(req: Request, window: int = 0) -> SpliceOutcome:
    """Apply the FIFO front's verdict (the depth-1 commit rule, thanks to
    front normalization) and cascade/normalize what rides behind it.

    Every record in the FIFO must already carry its device result
    (``n_match >= 0``): the discrete-event engine computes verdicts eagerly
    at launch and only their *visibility* is delayed, so a front splice may
    need to shift the successor's ``n_match`` during normalization."""
    fl = req.pipeline.pop(0)
    k = len(fl.cands)
    # acceptance telemetry over the window AS SUBMITTED: candidates popped
    # by front normalization were accepted (they got committed), so they
    # re-enter both numerator and denominator here
    dvr._update_acceptance(req, fl.n_match + fl.shifted, k + fl.shifted)
    n = min(fl.n_match, k)
    rejected = k - n

    committed_base = len(req.committed)
    req.committed.extend(fl.cands[:n])
    req.committed.append(int(fl.commit_tok))
    req.num_verify_passes += 1

    # Does the speculation behind this window survive?  Only a full match
    # whose commit token equals the next speculated token (it was
    # conditioned on exactly what got committed); the agreeing head is
    # popped — it is now committed as the commit token itself.
    chain = False
    cascaded: List[InflightVerify] = []
    if n == k:
        ct = int(fl.commit_tok)
        if req.pipeline:
            succ = req.pipeline[0]
            if succ.cands and int(succ.cands[0]) == ct:
                succ.cands.pop(0)
                if succ.margins:  # keep margins parallel to cands+commit
                    succ.margins.pop(0)
                # the successor's replay re-predicted this position from the
                # same context the commit token came from; the fixed-shape
                # fixed-schedule replay is batch-invariant, so it matched
                assert succ.n_match >= 1, (
                    "chained verdict disagrees with its own conditioning "
                    "context — verify replay is not batch-invariant"
                )
                succ.n_match -= 1
                succ.shifted += 1
                chain = True
        elif req.candidates:
            if int(req.candidates[0]) == ct:
                req.candidates.pop(0)
                chain = True
        else:
            chain = True  # nothing speculated past the window: clean splice

    if not chain:  # rollback: cascade-invalidate everything behind
        cascaded = req.pipeline
        req.pipeline = []
        rejected += sum(len(c.cands) for c in cascaded) + len(req.candidates)
        req.candidates = []
        req.num_cascaded_windows += len(cascaded)

    if rejected > 0:
        req.num_rollbacks += 1
        req.num_recomputed_tokens += rejected

    pre_clamp = req.pipeline
    dvr._clamp_budget(req)
    if pre_clamp and not req.pipeline:
        # the budget clamp mooted windows still in flight: no rollback
        # semantics (their tokens fell past the budget, not to a verdict),
        # but depth accounting and telemetry must see them discarded
        cascaded = cascaded + pre_clamp
        req.num_cascaded_windows += len(pre_clamp)
    if req.state is not State.FINISHED:
        req.state = State.RUNNING  # verdict landed: no longer verify-gated
        if window:  # unless the budget is covered by leftover speculation
            dvr.mark_window_state(req, window)
    return SpliceOutcome(
        record=fl,
        rolled_back=rejected > 0,
        cascaded=cascaded,
        restore_state=not chain or not (req.pipeline or req.candidates),
        reanchor=not req.pipeline,
        committed_base=committed_base,
        committed_count=len(req.committed) - committed_base,
        rejected=rejected,
    )
