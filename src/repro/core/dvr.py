"""Decode-verify-rollback bookkeeping (paper §4.2).

Host-side protocol logic for one request; the device-side fixed-shape pass
lives in ``core.verifier``.  The engine calls:

  * ``append_candidate``   after each fast-path decode of a det request
  * ``ready_for_verify``   to decide when a window is full
  * ``apply_verify_result`` to commit / roll back after a verify pass

Commit rule (paper Fig. 8): commit the leading run of matching candidates
plus the verifier token at the first mismatch (or the trailing verifier
token on a full match).  Every verify pass commits >= 1 token — guaranteed
forward progress.
"""

from __future__ import annotations

from typing import List, Tuple

from repro.serving.request import Request, State


def candidates_per_window(window: int) -> int:
    """A window of W inputs verifies W-1 candidates (input 0 is the last
    committed token) and emits one fresh verifier token."""
    return window - 1


def ready_for_verify(req: Request, window: int) -> bool:
    if not req.sampling.is_deterministic:
        return False
    if req.state == State.FINISHED or not req.candidates:
        return False
    return (
        len(req.candidates) >= candidates_per_window(window)
        or req.done_decoding()
    )


def build_verify_row(
    req: Request, window: int, pad_token: int = 0
) -> Tuple[List[int], List[int], int, int, int]:
    """Returns (inputs[W], cand[W-1], cand_len, start_pos, out_base)."""
    W = window
    cand = req.candidates[: W - 1]
    cand_len = len(cand)
    last_committed = req.committed[-1]
    inputs = [last_committed] + cand
    inputs = inputs + [pad_token] * (W - len(inputs))
    cand_padded = cand + [-1] * ((W - 1) - cand_len)
    # abs position of inputs[0]: prompt (+ any prefix embeds) + committed - 1
    prefix = getattr(req, "_prefix_len", 0)
    start_pos = req.prompt_len + prefix + len(req.committed) - 1
    out_base = len(req.committed)  # output index of v_0
    return inputs, cand_padded, cand_len, start_pos, out_base


def apply_verify_result(req: Request, n_match: int, commit_tok: int) -> None:
    """Commit matching prefix + the verifier token; roll back the rest."""
    cand_len = len(req.candidates)
    n_match = min(n_match, cand_len)
    accepted = req.candidates[:n_match]
    rejected = cand_len - n_match

    req.committed.extend(accepted)
    req.committed.append(int(commit_tok))
    req.candidates = []
    req.num_verify_passes += 1
    if rejected > 0:
        req.num_rollbacks += 1
        req.num_recomputed_tokens += rejected

    # clamp to the output budget (the verifier may add one token past it)
    budget = req.sampling.max_new_tokens
    if len(req.committed) > budget:
        req.committed = req.committed[:budget]
