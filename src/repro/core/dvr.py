"""Decode-verify-rollback bookkeeping (paper §4.2).

Host-side protocol logic for one request; the device-side fixed-shape pass
lives in ``core.verifier``.  The engine calls:

  * ``append_candidate``   after each fast-path decode of a det request
  * ``ready_for_verify``   to decide when a window is full
  * ``apply_verify_result`` to commit / roll back after a verify pass

Commit rule (paper Fig. 8): commit the leading run of matching candidates
plus the verifier token at the first mismatch (or the trailing verifier
token on a full match).  Every verify pass commits >= 1 token — guaranteed
forward progress.

In-flight verification (scheduler ``OverlapPolicy``, beyond §5.2
limitation (1)): windows can be *submitted* without pausing the request —
the candidates move to the request's in-flight FIFO (``req.pipeline``) and
the fast path keeps appending fresh candidates behind them, up to the
engine's ``spec_depth`` outstanding windows.  ``core.pipeline`` owns the
in-order splice / cascade-invalidation semantics; this module keeps the
synchronous commit rule, readiness/housekeeping helpers, and the verify-row
builder (which conditions each row on the speculation immediately preceding
it, so chained windows replay the right context).  Either way the committed
stream is the same deterministic reference sequence, which is why policies,
depths and verdict-landing orders are interchangeable bit-for-bit.
"""

from __future__ import annotations

from typing import List, Optional, Tuple

from repro.serving.request import Request, State


def candidates_per_window(window: int) -> int:
    """A window of W inputs verifies W-1 candidates (input 0 is the last
    committed token) and emits one fresh verifier token."""
    return window - 1


#: EMA step for per-request acceptance telemetry.  High on purpose: the
#: adaptive scheduler must react within a verdict or two of a request
#: entering (or leaving) a high-flip regime.
ACCEPT_EMA_ALPHA = 0.5


def _update_acceptance(req: Request, n_match: int, n_submitted: int) -> None:
    """Fold one verdict into the request's acceptance EMA.  The sample is
    the accepted fraction of the *submitted* candidates — a partial
    (eager) window counts the same as a full one, so the signal tracks
    flip probability, not window pacing."""
    if n_submitted <= 0:
        return
    frac = min(n_match, n_submitted) / n_submitted
    req.accept_ema += ACCEPT_EMA_ALPHA * (frac - req.accept_ema)


def ready_for_verify(
    req: Request,
    window: int,
    *,
    min_candidates: Optional[int] = None,
    depth: int = 1,
) -> bool:
    """A window is ready once full (W-1 candidates) or once the request is
    done decoding — and once the in-flight FIFO has room: ``depth`` is the
    pipelining bound (windows outstanding per request; the old protocol is
    ``depth=1``).  ``min_candidates`` lowers the fullness bar: the adaptive
    scheduler verifies high-flip requests *eagerly* with partial windows —
    the fixed-shape (G, W) verify pass pads short rows, and the committed
    stream is a prefix-stable reference sequence, so window pacing moves
    only throughput, never tokens."""
    if not req.sampling.is_deterministic:
        return False
    if req.state == State.FINISHED or not req.candidates:
        return False
    if len(req.pipeline) >= max(depth, 1):
        return False  # pipeline at configured depth: wait for a verdict
    threshold = candidates_per_window(window)
    if min_candidates is not None:
        threshold = max(1, min(min_candidates, threshold))
    return len(req.candidates) >= threshold or req.done_decoding()


def mark_window_state(req: Request, window: int) -> None:
    """Truthful ``State`` bookkeeping after a fast-path candidate lands: a
    deterministic request whose candidate window is full — or whose output
    budget is already covered by outstanding speculation — cannot take
    another fast-path token and is awaiting verification."""
    if req.state is State.FINISHED:
        return
    if len(req.candidates) >= candidates_per_window(window) or (
        req.candidates and req.done_decoding()
    ):
        req.state = State.AWAITING_VERIFY


def build_verify_row(
    req: Request, window: int, pad_token: int = 0
) -> Tuple[List[int], List[int], int, int, int]:
    """Returns (inputs[W], cand[W-1], cand_len, start_pos, out_base).

    The row conditions on the token immediately preceding its candidates in
    sequence order: ``committed[-1]`` with an empty in-flight FIFO (the
    anchored, depth-1 case) or the last in-flight candidate (a chained
    window at depth > 1).  Positions shift past the in-flight candidates —
    splices later move tokens from the FIFO into ``committed`` without
    changing ``committed + in-flight`` length, so the absolute positions
    fixed here stay valid however verdicts land."""
    W = window
    cand = req.candidates[: W - 1]
    cand_len = len(cand)
    spec = sum(len(fl.cands) for fl in req.pipeline)
    cond = req.pipeline[-1].cands[-1] if req.pipeline else req.committed[-1]
    inputs = [cond] + cand
    inputs = inputs + [pad_token] * (W - len(inputs))
    cand_padded = cand + [-1] * ((W - 1) - cand_len)
    # abs position of inputs[0]: prompt (+ any prefix embeds) + committed
    # + in-flight speculation - 1
    prefix = getattr(req, "_prefix_len", 0)
    start_pos = req.prompt_len + prefix + len(req.committed) + spec - 1
    out_base = len(req.committed) + spec  # output index of v_0
    return inputs, cand_padded, cand_len, start_pos, out_base


def apply_verify_result(
    req: Request, n_match: int, commit_tok: int, window: int = 0
) -> Tuple[int, int]:
    """Commit matching prefix + the verifier token; roll back the rest.

    The synchronous (pause-style) path: the row was conditioned on
    ``committed[-1]``, which requires an empty in-flight FIFO — a request
    with outstanding windows must drain them (``core.pipeline``) before it
    can be verified synchronously.

    Returns ``(n_committed, n_rejected)``: tokens actually appended to the
    committed stream (AFTER the budget clamp — what the audit log must
    cover) and candidates rolled back."""
    assert not req.pipeline, "sync verify requires an empty in-flight FIFO"
    cand_len = len(req.candidates)
    _update_acceptance(req, n_match, cand_len)
    n_match = min(n_match, cand_len)
    accepted = req.candidates[:n_match]
    rejected = cand_len - n_match

    base = len(req.committed)
    req.committed.extend(accepted)
    req.committed.append(int(commit_tok))
    req.candidates = []
    req.num_verify_passes += 1
    if rejected > 0:
        req.num_rollbacks += 1
        req.num_recomputed_tokens += rejected

    _clamp_budget(req)
    if req.state is not State.FINISHED:
        req.state = State.RUNNING  # verdict landed: no longer gated on verify
        if window:  # unless the budget is still covered by leftover cands
            mark_window_state(req, window)
    return len(req.committed) - base, rejected


def _clamp_budget(req: Request) -> None:
    # clamp to the output budget (the verifier may add one token past it)
    budget = req.sampling.max_new_tokens
    if len(req.committed) > budget:
        req.committed = req.committed[:budget]
    if len(req.committed) >= budget:
        # budget reached: any outstanding speculation — fresh candidates
        # AND windows still in flight — is moot
        req.candidates = []
        req.pipeline = []
