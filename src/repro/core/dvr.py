"""Decode-verify-rollback bookkeeping (paper §4.2).

Host-side protocol logic for one request; the device-side fixed-shape pass
lives in ``core.verifier``.  The engine calls:

  * ``append_candidate``   after each fast-path decode of a det request
  * ``ready_for_verify``   to decide when a window is full
  * ``apply_verify_result`` to commit / roll back after a verify pass

Commit rule (paper Fig. 8): commit the leading run of matching candidates
plus the verifier token at the first mismatch (or the trailing verifier
token on a full match).  Every verify pass commits >= 1 token — guaranteed
forward progress.

In-flight verification (scheduler ``OverlapPolicy``, beyond §5.2
limitation (1)): a window can be *submitted* (``begin_inflight``) without
pausing the request — the candidates move to ``req.inflight`` and the fast
path keeps appending fresh candidates behind it.  When the result lands,
``apply_inflight_result`` splices the commit underneath the outstanding
candidates: the committed stream is extended exactly as in the synchronous
path, and the speculated-past tokens survive only if the first of them
agrees with the verifier's commit token (they were conditioned on it);
otherwise they are invalidated and recomputed — a rollback that reaches
*past* the verified window.  Either way the committed stream is the same
deterministic reference sequence, which is why policies are interchangeable
bit-for-bit.
"""

from __future__ import annotations

from typing import List, Optional, Tuple

from repro.serving.request import InflightVerify, Request, State


def candidates_per_window(window: int) -> int:
    """A window of W inputs verifies W-1 candidates (input 0 is the last
    committed token) and emits one fresh verifier token."""
    return window - 1


#: EMA step for per-request acceptance telemetry.  High on purpose: the
#: adaptive scheduler must react within a verdict or two of a request
#: entering (or leaving) a high-flip regime.
ACCEPT_EMA_ALPHA = 0.5


def _update_acceptance(req: Request, n_match: int, n_submitted: int) -> None:
    """Fold one verdict into the request's acceptance EMA.  The sample is
    the accepted fraction of the *submitted* candidates — a partial
    (eager) window counts the same as a full one, so the signal tracks
    flip probability, not window pacing."""
    if n_submitted <= 0:
        return
    frac = min(n_match, n_submitted) / n_submitted
    req.accept_ema += ACCEPT_EMA_ALPHA * (frac - req.accept_ema)


def ready_for_verify(
    req: Request, window: int, *, min_candidates: Optional[int] = None
) -> bool:
    """A window is ready once full (W-1 candidates) or once the request is
    done decoding.  ``min_candidates`` lowers the bar: the adaptive
    scheduler verifies high-flip requests *eagerly* with partial windows —
    the fixed-shape (G, W) verify pass pads short rows, and the committed
    stream is a prefix-stable reference sequence, so window pacing moves
    only throughput, never tokens."""
    if not req.sampling.is_deterministic:
        return False
    if req.state == State.FINISHED or not req.candidates:
        return False
    if req.inflight is not None:
        return False  # one outstanding window per request
    threshold = candidates_per_window(window)
    if min_candidates is not None:
        threshold = max(1, min(min_candidates, threshold))
    return len(req.candidates) >= threshold or req.done_decoding()


def mark_window_state(req: Request, window: int) -> None:
    """Truthful ``State`` bookkeeping after a fast-path candidate lands: a
    deterministic request whose candidate window is full — or whose output
    budget is already covered by outstanding speculation — cannot take
    another fast-path token and is awaiting verification."""
    if req.state is State.FINISHED:
        return
    if len(req.candidates) >= candidates_per_window(window) or (
        req.candidates and req.done_decoding()
    ):
        req.state = State.AWAITING_VERIFY


def build_verify_row(
    req: Request, window: int, pad_token: int = 0
) -> Tuple[List[int], List[int], int, int, int]:
    """Returns (inputs[W], cand[W-1], cand_len, start_pos, out_base)."""
    W = window
    cand = req.candidates[: W - 1]
    cand_len = len(cand)
    last_committed = req.committed[-1]
    inputs = [last_committed] + cand
    inputs = inputs + [pad_token] * (W - len(inputs))
    cand_padded = cand + [-1] * ((W - 1) - cand_len)
    # abs position of inputs[0]: prompt (+ any prefix embeds) + committed - 1
    prefix = getattr(req, "_prefix_len", 0)
    start_pos = req.prompt_len + prefix + len(req.committed) - 1
    out_base = len(req.committed)  # output index of v_0
    return inputs, cand_padded, cand_len, start_pos, out_base


def apply_verify_result(
    req: Request, n_match: int, commit_tok: int, window: int = 0
) -> None:
    """Commit matching prefix + the verifier token; roll back the rest."""
    cand_len = len(req.candidates)
    _update_acceptance(req, n_match, cand_len)
    n_match = min(n_match, cand_len)
    accepted = req.candidates[:n_match]
    rejected = cand_len - n_match

    req.committed.extend(accepted)
    req.committed.append(int(commit_tok))
    req.candidates = []
    req.num_verify_passes += 1
    if rejected > 0:
        req.num_rollbacks += 1
        req.num_recomputed_tokens += rejected

    _clamp_budget(req)
    if req.state is not State.FINISHED:
        req.state = State.RUNNING  # verdict landed: no longer gated on verify
        if window:  # unless the budget is still covered by leftover cands
            mark_window_state(req, window)


def _clamp_budget(req: Request) -> None:
    # clamp to the output budget (the verifier may add one token past it)
    budget = req.sampling.max_new_tokens
    if len(req.committed) > budget:
        req.committed = req.committed[:budget]
    if len(req.committed) >= budget:
        # budget reached: any outstanding speculation is moot
        req.candidates = []


def begin_inflight(
    req: Request, window: int, submitted_at: float, ready_at: float
) -> InflightVerify:
    """Move the window's candidates out of the speculation buffer and mark
    them as submitted-for-verification.  The request may keep decoding —
    fresh candidates append to the (now shorter) ``req.candidates`` and are
    positioned *after* the in-flight window.

    ``submitted_at``/``ready_at`` are stream-clock times (see
    ``serving.streams``): the verdict lands at the first iteration whose
    main-stream clock reaches ``ready_at``."""
    assert req.inflight is None, "one outstanding verify window per request"
    k = candidates_per_window(window)
    submitted = req.candidates[:k]
    req.candidates = req.candidates[k:]
    req.inflight = InflightVerify(
        cands=submitted, submitted_at=submitted_at, ready_at=ready_at
    )
    # window is out: the request resumes speculating unless its budget is
    # already covered by outstanding speculation (then it awaits the verdict)
    if req.state is not State.FINISHED:
        req.state = (
            State.AWAITING_VERIFY if req.done_decoding() else State.RUNNING
        )
    return req.inflight


def apply_inflight_result(req: Request, window: int = 0) -> None:
    """Splice an in-flight window's verdict under the outstanding candidates.

    Commit rule is identical to ``apply_verify_result`` applied to the
    *submitted* candidates.  The speculated-past candidates (decoded while
    the window was in flight) survive only on a full match whose commit
    token equals the first speculated token — i.e. the continuation was
    conditioned on exactly the tokens that ended up committed.  Any other
    outcome invalidates them: they descend from a token the verifier rolled
    back (or from a candidate beyond the budget), so they are discarded and
    counted as recomputed.
    """
    fl = req.inflight
    assert fl is not None and fl.n_match >= 0, "no completed in-flight verify"
    k = len(fl.cands)
    _update_acceptance(req, fl.n_match, k)
    n_match = min(fl.n_match, k)
    rejected = k - n_match

    req.committed.extend(fl.cands[:n_match])
    req.committed.append(int(fl.commit_tok))
    req.num_verify_passes += 1

    full_match = n_match == k
    keep_tail = (
        full_match
        and bool(req.candidates)
        and req.candidates[0] == int(fl.commit_tok)
    )
    if keep_tail:
        # commit_tok subsumes the first speculated-past token; the rest
        # remain valid candidates for the next window
        req.candidates = req.candidates[1:]
    else:
        rejected += len(req.candidates)
        req.candidates = []
    if rejected > 0:
        req.num_rollbacks += 1
        req.num_recomputed_tokens += rejected

    req.inflight = None
    _clamp_budget(req)
    if req.state is not State.FINISHED:
        req.state = State.RUNNING  # verdict landed: no longer gated on verify
        if window:  # unless the budget is still covered by leftover cands
            mark_window_state(req, window)
