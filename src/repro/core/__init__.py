from repro.core.determinism import (  # noqa: F401
    FAST_PATH_POLICY,
    INVARIANT_SCHEDULE,
    Mode,
    REORDER_ONLY_POLICY,
    ReductionPolicy,
    Schedule,
    VERIFY_SCHEDULE,
    matmul,
)
