"""Persist benchmark headline numbers as ``BENCH_*.json`` at the repo root.

Runs the headline benchmarks in ``--smoke --json`` mode and leaves
their row payloads (the format ``common.emit`` writes) at the repo root,
where they are *committed*: the perf trajectory then lives in git history
next to the code that produced it, and CI uploads the regenerated files as
artifacts for side-by-side comparison.

    python benchmarks/persist.py            # writes BENCH_{overlap,pipeline,cache,prefill}.json
    python benchmarks/persist.py --check    # regenerate to temp, compare per metric

``--check`` regenerates each benchmark and compares it against the
committed file **per metric**, with a tolerance class picked from the
metric's name: CPU wall-time columns get a very loose relative tolerance
(CI machines differ wildly), ratios/rates/occupancies a small absolute
tolerance, simulated timings/throughputs a moderate relative tolerance,
and discrete counts a moderate relative + small absolute slack.  It
prints a pass/fail table (also appended to ``$GITHUB_STEP_SUMMARY`` as
markdown when set) and exits non-zero on any out-of-tolerance metric or
schema drift; the CI job marks the step non-blocking, so the table is a
trajectory signal, not a gate.
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import tempfile
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]

BENCHES = {
    "overlap": "benchmarks/fig_overlap.py",
    "pipeline": "benchmarks/fig_pipeline.py",
    "cache": "benchmarks/fig_cache.py",
    "prefill": "benchmarks/fig_prefill.py",
    "cluster": "benchmarks/fig_cluster.py",
}


def run_bench(script: str, out_path: Path) -> None:
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src") + os.pathsep + env.get("PYTHONPATH", "")
    subprocess.run(
        [sys.executable, str(REPO / script), "--smoke", "--json", str(out_path)],
        check=True,
        env=env,
        cwd=REPO,
    )


def _schema(path: Path) -> dict:
    rows = json.loads(path.read_text())
    return {
        "n_rows": len(rows),
        "columns": sorted(rows[0]) if rows else [],
        "names": sorted({str(r.get("name", r.get("mode", "?"))) for r in rows}),
    }


def _rows(path: Path) -> dict:
    """Row dicts keyed on the metric name column."""
    return {
        str(r.get("name", r.get("mode", "?"))): r
        for r in json.loads(path.read_text())
    }


def tolerance(metric: str, column: str):
    """(kind, bound) for one metric cell — the comparison contract.

    * ``us_per_call`` (and any ``wall`` column/metric) is measured CPU
      wall time: rel tol 2.0 (within 3x) — it exists to catch order-of-
      magnitude regressions, not jitter.
    * ratios / rates / occupancies are dimensionless and O(1): abs 0.15.
    * simulated timings (``_ms``) and throughputs (``tput``): rel 0.5 —
      the cost model is deterministic, but schedule changes move these
      legitimately between commits.
    * everything else (verify passes, peak depth/concurrency, preemption
      and restore counts, hit tokens): rel 0.5 with +/-2 absolute slack
      so tiny counts don't trip the relative bound.
    """
    name = metric.lower()
    if column == "us_per_call" or "wall" in name or "wall" in column:
        return ("rel", 2.0)
    if any(k in name for k in ("ratio", "rate", "occupancy", "vs_")):
        return ("abs", 0.15)
    if any(k in name for k in ("_ms", "tput", "hbm", "_s")):
        return ("rel", 0.5)
    return ("relabs", (0.5, 2.0))


def _within(kind, bound, committed: float, fresh: float) -> bool:
    diff = abs(fresh - committed)
    if kind == "abs":
        return diff <= bound
    if kind == "rel":
        return diff <= bound * max(abs(committed), 1e-9)
    rel, slack = bound  # "relabs"
    return diff <= max(rel * abs(committed), slack)


def compare_rows(committed: dict, fresh: dict, bench: str) -> list:
    """Per-metric comparison table rows:
    ``(bench, metric, column, committed, fresh, bound, ok)``."""
    table = []
    for metric in sorted(set(committed) | set(fresh)):
        c_row, f_row = committed.get(metric), fresh.get(metric)
        if c_row is None or f_row is None:
            which = "committed" if c_row is None else "fresh"
            table.append((bench, metric, "-", "-", "-",
                          f"missing from {which}", False))
            continue
        for col in sorted(set(c_row) | set(f_row)):
            if col in ("name", "mode"):
                continue
            cv, fv = c_row.get(col, ""), f_row.get(col, "")
            if not isinstance(cv, (int, float)) or isinstance(cv, bool) or (
                not isinstance(fv, (int, float)) or isinstance(fv, bool)
            ):
                if cv != fv:  # non-numeric cells must match exactly
                    table.append((bench, metric, col, cv, fv, "exact", False))
                continue
            kind, bound = tolerance(metric, col)
            ok = _within(kind, bound, float(cv), float(fv))
            table.append((bench, metric, col, cv, fv,
                          f"{kind} {bound}", ok))
    return table


def print_table(table: list) -> None:
    header = ("bench", "metric", "col", "committed", "fresh", "tol", "ok")
    lines = [header] + [
        (b, m, c, str(cv), str(fv), tol, "PASS" if ok else "FAIL")
        for b, m, c, cv, fv, tol, ok in table
    ]
    widths = [max(len(str(row[i])) for row in lines) for i in range(7)]
    for row in lines:
        print("  ".join(str(row[i]).ljust(widths[i]) for i in range(7)))

    summary = os.environ.get("GITHUB_STEP_SUMMARY")
    if summary:
        n_fail = sum(1 for r in table if not r[6])
        with open(summary, "a") as f:
            f.write("\n### Benchmark trajectory vs committed BENCH_*.json\n\n")
            f.write(f"{len(table) - n_fail}/{len(table)} metrics within "
                    f"tolerance\n\n")
            f.write("| bench | metric | col | committed | fresh | tol | ok |\n")
            f.write("|---|---|---|---|---|---|---|\n")
            for b, m, c, cv, fv, tol, ok in table:
                f.write(f"| {b} | {m} | {c} | {cv} | {fv} | {tol} | "
                        f"{'✅' if ok else '❌'} |\n")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--check",
        action="store_true",
        help="regenerate to temp and compare per metric against committed "
             "files (tolerance classes by metric name)",
    )
    ap.add_argument(
        "--only", choices=sorted(BENCHES), nargs="+", default=None,
        help="subset of benchmarks to run",
    )
    args = ap.parse_args(argv)
    names = args.only or sorted(BENCHES)

    failures = []
    table = []
    for name in names:
        committed = REPO / f"BENCH_{name}.json"
        if args.check:
            with tempfile.TemporaryDirectory() as td:
                fresh = Path(td) / f"BENCH_{name}.json"
                run_bench(BENCHES[name], fresh)
                if not committed.exists():
                    failures.append(f"{committed.name} missing — run persist.py")
                    continue
                rows = compare_rows(_rows(committed), _rows(fresh), name)
                table.extend(rows)
                failures.extend(
                    f"{committed.name}: {m} [{c}] committed={cv} fresh={fv} "
                    f"(tol {tol}) — rerun persist.py if intentional"
                    for _, m, c, cv, fv, tol, ok in rows if not ok
                )
        else:
            run_bench(BENCHES[name], committed)
            print(f"[persist] wrote {committed.name}: {_schema(committed)}")

    if table:
        print_table(table)
    for f in failures:
        print(f"[persist] FAIL: {f}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
