"""Persist benchmark headline numbers as ``BENCH_*.json`` at the repo root.

Runs the headline benchmarks in ``--smoke --json`` mode and leaves
their row payloads (the format ``common.emit`` writes) at the repo root,
where they are *committed*: the perf trajectory then lives in git history
next to the code that produced it, and CI uploads the regenerated files as
artifacts for side-by-side comparison.

    python benchmarks/persist.py            # writes BENCH_{overlap,pipeline,cache,prefill}.json
    python benchmarks/persist.py --check    # regenerate to temp, diff row keys only

``--check`` verifies the committed files are structurally current (same
benchmark names and row schema) without failing on timing jitter.
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import tempfile
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]

BENCHES = {
    "overlap": "benchmarks/fig_overlap.py",
    "pipeline": "benchmarks/fig_pipeline.py",
    "cache": "benchmarks/fig_cache.py",
    "prefill": "benchmarks/fig_prefill.py",
}


def run_bench(script: str, out_path: Path) -> None:
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src") + os.pathsep + env.get("PYTHONPATH", "")
    subprocess.run(
        [sys.executable, str(REPO / script), "--smoke", "--json", str(out_path)],
        check=True,
        env=env,
        cwd=REPO,
    )


def _schema(path: Path) -> dict:
    rows = json.loads(path.read_text())
    return {
        "n_rows": len(rows),
        "columns": sorted(rows[0]) if rows else [],
        "names": sorted({str(r.get("name", r.get("mode", "?"))) for r in rows}),
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--check",
        action="store_true",
        help="regenerate to temp and compare row schema against committed files",
    )
    ap.add_argument(
        "--only", choices=sorted(BENCHES), nargs="+", default=None,
        help="subset of benchmarks to run",
    )
    args = ap.parse_args(argv)
    names = args.only or sorted(BENCHES)

    failures = []
    for name in names:
        committed = REPO / f"BENCH_{name}.json"
        if args.check:
            with tempfile.TemporaryDirectory() as td:
                fresh = Path(td) / f"BENCH_{name}.json"
                run_bench(BENCHES[name], fresh)
                if not committed.exists():
                    failures.append(f"{committed.name} missing — run persist.py")
                    continue
                want, got = _schema(fresh), _schema(committed)
                if want != got:
                    failures.append(
                        f"{committed.name} schema drift: committed {got} "
                        f"vs fresh {want} — rerun persist.py"
                    )
        else:
            run_bench(BENCHES[name], committed)
            print(f"[persist] wrote {committed.name}: {_schema(committed)}")

    for f in failures:
        print(f"[persist] FAIL: {f}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
