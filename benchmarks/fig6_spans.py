"""Paper Fig. 6 — first/second consistent spans under dynamic batching (O1).

Ground truth: each request decoded alone (batch size one, stable schedule).
Comparison: the same requests under dynamic batching (NONDET mode, mixed
arrivals).  Reports per-request first/second consistent spans — the O1
claim is first spans are long, second spans collapse to ~0 (autoregressive
amplification after the first flip).
"""

from __future__ import annotations

from repro.core.determinism import Mode, REORDER_ONLY_POLICY
from repro.core.spans import consistent_spans
from benchmarks.common import BENCH_POLICY, bench_model, make_requests, run_scenario


def _spans_under(cfg, params, policy, tag, n_requests, max_new):
    truth = {}
    for i in range(n_requests):
        reqs = make_requests(cfg, n_requests, 0.0, max_new)
        r = run_scenario(cfg, params, [reqs[i]], mode=Mode.NONDET, policy=policy)
        truth[i] = r["done"][0].committed

    reqs = make_requests(cfg, n_requests, 0.0, max_new)
    batched = run_scenario(cfg, params, reqs, mode=Mode.NONDET, policy=policy)
    out = {r.rid: r.committed for r in batched["done"]}

    rows = []
    n_perfect = 0
    second_spans = []
    for i in range(n_requests):
        s = consistent_spans(truth[i], out[i])
        n_perfect += s.first_span == s.total
        second_spans.append(s.second_span)
        rows.append((f"fig6_{tag}_req{i}_first_span", "", s.first_span))
    rows.append((f"fig6_{tag}_max_second_span", "", max(second_spans)))
    rows.append((f"fig6_{tag}_frac_fully_consistent", "",
                 round(n_perfect / n_requests, 3)))
    return rows


def run(n_requests: int = 8, max_new: int = 48):
    """Two drift regimes: 'aggressive' (bf16 split-K combine — flips are
    frequent, makes the amplification structure visible at toy scale) and
    'reorder' (pure f32 reorder drift — flips rare, the paper's production
    regime where most requests match ground truth in full)."""
    cfg, params = bench_model()
    rows = _spans_under(cfg, params, BENCH_POLICY, "aggressive",
                        n_requests, max_new)
    rows += _spans_under(cfg, params, REORDER_ONLY_POLICY, "reorder",
                         n_requests, max_new)
    return rows
