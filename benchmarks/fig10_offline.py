"""Paper Fig. 10 + Table 4 — offline throughput & rollback statistics
across deterministic-traffic ratios.

For each det ratio in {0%, 10%, 50%, 100%}:
  * LLM42 simulated v5e throughput
  * SGLang-Deterministic (batch-invariant, global) and
    SGLang-Non-Deterministic reference points
  * rollback count + recomputed-token fraction (Table 4)
"""

from __future__ import annotations

from repro.core.determinism import Mode
from benchmarks.common import (
    bench_model, full_config, make_requests, run_scenario,
    simulated_throughput,
)


def run(n_requests: int = 12, max_new: int = 32):
    cfg, params = bench_model()
    fcfg = full_config()
    rows = []

    nd = run_scenario(cfg, params, make_requests(cfg, n_requests, 0.0, max_new),
                      mode=Mode.NONDET)
    t_nd = simulated_throughput(fcfg, nd)
    rows.append(("fig10_sglang_nondet_tok_s", round(nd["wall_s"], 1), round(t_nd, 1)))

    bi = run_scenario(cfg, params, make_requests(cfg, n_requests, 0.0, max_new),
                      mode=Mode.BATCH_INVARIANT)
    t_bi = simulated_throughput(fcfg, bi, invariant=True)
    rows.append(("fig10_sglang_det_tok_s", round(bi["wall_s"], 1), round(t_bi, 1)))

    for ratio in (0.0, 0.1, 0.5, 1.0):
        reqs = make_requests(cfg, n_requests, ratio, max_new, seed=7)
        r = run_scenario(cfg, params, reqs, mode=Mode.LLM42, window=8, group=4)
        t = simulated_throughput(fcfg, r)
        pct = int(ratio * 100)
        rows.append((f"fig10_llm42_{pct}pct_tok_s", round(r["wall_s"], 1), round(t, 1)))
        rows.append((f"table4_rollbacks_{pct}pct", "", r["rollbacks"]))
        rows.append((f"table4_recompute_frac_{pct}pct", "",
                     round(r["recomputed"] / max(r["out_tokens"], 1), 4)))

    rows.append(("fig10_llm42_100pct_vs_sglang_det", "",
                 round(rows[-3][2] / max(t_bi, 1e-9), 3)))
    return rows
