"""Paper Fig. 5 — selective determinism vs all-or-nothing.

Scenarios (scaled to CPU: 5/6 requests instead of 10/11):
  (1) B nondet requests, NONDET mode            — baseline throughput
  (2) B+1 nondet requests, NONDET mode          — batching helps (+~10%)
  (3) B+1 requests, ONE deterministic:
        a. BATCH_INVARIANT mode (SGLang-Deterministic): everyone pays
        b. LLM42: only the det request pays (the paper's point)

Reported: simulated TPU-v5e decode throughput (tokens/s) per scenario.
"""

from __future__ import annotations

from repro.core.determinism import Mode
from repro.serving.scheduler import OverlapPolicy, PauseDecodePolicy
from benchmarks.common import (
    bench_model, full_config, make_requests, run_scenario,
    simulated_throughput,
)


def run():
    cfg, params = bench_model()
    fcfg = full_config()
    B, max_new = 5, 32

    rows = []

    r1 = run_scenario(cfg, params, make_requests(cfg, B, 0.0, max_new),
                      mode=Mode.NONDET)
    tput1 = simulated_throughput(fcfg, r1)
    rows.append(("fig5_nondet_B", round(r1["wall_s"] * 1e6 / max(r1["out_tokens"], 1), 1),
                 round(tput1, 1)))

    r2 = run_scenario(cfg, params, make_requests(cfg, B + 1, 0.0, max_new),
                      mode=Mode.NONDET)
    tput2 = simulated_throughput(fcfg, r2)
    rows.append(("fig5_nondet_B+1", round(r2["wall_s"] * 1e6 / max(r2["out_tokens"], 1), 1),
                 round(tput2, 1)))

    reqs = make_requests(cfg, B + 1, 0.0, max_new)
    reqs[0].sampling.is_deterministic = True
    r3 = run_scenario(cfg, params, reqs, mode=Mode.BATCH_INVARIANT)
    tput3 = simulated_throughput(fcfg, r3, invariant=True)
    rows.append(("fig5_batchinv_B+1_1det",
                 round(r3["wall_s"] * 1e6 / max(r3["out_tokens"], 1), 1),
                 round(tput3, 1)))

    reqs = make_requests(cfg, B + 1, 0.0, max_new)
    reqs[0].sampling.is_deterministic = True
    r4 = run_scenario(cfg, params, reqs, mode=Mode.LLM42, window=8, group=1,
                      scheduler=PauseDecodePolicy())
    tput4 = simulated_throughput(fcfg, r4)
    rows.append(("fig5_llm42_pause_B+1_1det",
                 round(r4["wall_s"] * 1e6 / max(r4["out_tokens"], 1), 1),
                 round(tput4, 1)))

    # the overlapped scheduler (default): verify runs beside the decode batch
    reqs = make_requests(cfg, B + 1, 0.0, max_new)
    reqs[0].sampling.is_deterministic = True
    r5 = run_scenario(cfg, params, reqs, mode=Mode.LLM42, window=8, group=1,
                      scheduler=OverlapPolicy())
    tput5 = simulated_throughput(fcfg, r5)
    rows.append(("fig5_llm42_overlap_B+1_1det",
                 round(r5["wall_s"] * 1e6 / max(r5["out_tokens"], 1), 1),
                 round(tput5, 1)))

    # headline ratios (paper: LLM-42 2.2x over SGLang-Det, within 3% of
    # best) — computed from the PAUSE run, the paper prototype's scheduler,
    # so these rows stay comparable to the paper and to earlier revisions;
    # the overlap scheduler's variants are reported separately
    rows.append(("fig5_llm42_over_batchinv", "", round(tput4 / max(tput3, 1e-9), 3)))
    rows.append(("fig5_llm42_vs_nondet_frac", "", round(tput4 / max(tput2, 1e-9), 3)))
    rows.append(("fig5_llm42_overlap_over_batchinv", "",
                 round(tput5 / max(tput3, 1e-9), 3)))
    rows.append(("fig5_overlap_over_pause", "", round(tput5 / max(tput4, 1e-9), 3)))
    return rows
