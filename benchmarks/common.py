"""Shared benchmark utilities: engine scenario runner + CSV emission.

All benchmarks execute REAL engine schedules (real rollbacks, real token
divergence) on reduced models on CPU, then replay the event log through the
TPU-v5e cost model at the full model's scale (serving/costmodel.py).  Two
numbers are therefore reported per scenario: measured CPU wall time (noisy,
interpretive) and simulated v5e time (the paper-comparable figure).
"""

from __future__ import annotations

import json
import os
import time
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro import configs as config_registry
from repro.core.determinism import Mode, ReductionPolicy
from repro.models import init_params
from repro.serving import costmodel
from repro.serving.engine import Engine
from repro.serving.request import Request, SamplingParams

#: benchmark model: the paper evaluates Llama-3.1-8B; we schedule on its
#: reduced variant and cost on the full config.
BENCH_ARCH = "llama3-8b"

#: aggressive fast-path policy so divergence is observable at toy scale
BENCH_POLICY = ReductionPolicy(
    thresholds=((2, 16), (4, 8), (16, 4)), combine_dtype="bfloat16"
)

_PARAM_CACHE: Dict[str, tuple] = {}


def bench_model(arch: str = BENCH_ARCH):
    if arch not in _PARAM_CACHE:
        cfg = config_registry.get_smoke_config(arch)
        params = init_params(cfg, jax.random.key(0))
        _PARAM_CACHE[arch] = (cfg, params)
    return _PARAM_CACHE[arch]


def full_config(arch: str = BENCH_ARCH):
    return config_registry.get_config(arch)


def make_requests(
    cfg, n: int, det_ratio: float, max_new: int, in_len: int = 12,
    seed: int = 0, out_lens: Optional[Sequence[int]] = None,
    in_lens: Optional[Sequence[int]] = None,
) -> List[Request]:
    rng = np.random.default_rng(seed)
    det_flags = rng.random(n) < det_ratio
    reqs = []
    for i in range(n):
        il = in_lens[i] if in_lens is not None else in_len
        prompt = rng.integers(0, cfg.vocab_size, il).tolist()
        ol = out_lens[i] if out_lens is not None else max_new
        reqs.append(Request(
            rid=i, prompt=prompt,
            sampling=SamplingParams(
                max_new_tokens=int(ol), is_deterministic=bool(det_flags[i]),
                seed=1000 + i,
            ),
        ))
    return reqs


def run_scenario(
    cfg, params, requests: List[Request], *, mode: Mode = Mode.LLM42,
    window: int = 8, group: int = 4, max_batch: int = 8, capacity: int = 256,
    policy: ReductionPolicy = BENCH_POLICY, scheduler=None,
    prefill_chunk: int = 0, **eng_kw,
) -> Dict:
    """Extra ``eng_kw`` pass straight to ``Engine`` (e.g. ``trace=True`` to
    capture a Chrome-trace of the scenario via ``engine.obs.tracer``)."""
    eng = Engine(cfg, params, mode=mode, policy=policy, window=window,
                 group=group, max_batch=max_batch, capacity=capacity,
                 scheduler=scheduler, prefill_chunk=prefill_chunk, **eng_kw)
    for r in requests:
        eng.submit(r)
    t0 = time.time()
    done = eng.run()
    wall = time.time() - t0
    out_tokens = sum(r.num_output for r in done)
    return {
        "engine": eng,
        "done": done,
        "events": eng.events,
        "wall_s": wall,
        "out_tokens": out_tokens,
        "rollbacks": sum(r.num_rollbacks for r in done),
        "recomputed": sum(r.num_recomputed_tokens for r in done),
        "metrics": eng.obs.metrics.snapshot(),
    }


def simulated_throughput(full_cfg, result: Dict, *, invariant=False) -> float:
    return costmodel.throughput_tokens_per_s(
        full_cfg, result["events"], result["out_tokens"],
        invariant_mode=invariant,
    )


def emit(rows: List[Tuple], header: str, json_path: Optional[str] = None
         ) -> None:
    """Print the CSV rows; when ``json_path`` is given, also persist them
    as JSON (``[{<header-col>: value, ...}]``) — CI uploads these as
    workflow artifacts so the perf trajectory is recorded per commit."""
    print(header)
    for row in rows:
        print(",".join(str(x) for x in row))
    if json_path:
        cols = header.split(",")
        payload = [
            {cols[i]: row[i] for i in range(min(len(cols), len(row)))}
            for row in rows
        ]
        d = os.path.dirname(json_path)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=1)
        print(f"# wrote {json_path}")
