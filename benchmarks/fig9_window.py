"""Paper Fig. 9 — verification vs recomputation cost across window sizes.

(a) per-token verification cost: falls with window size as the fixed-shape
    verify pass moves from memory-bound to compute-bound (derived from the
    v5e roofline — the paper measures 0.75 ms -> 0.05 ms/token on H100).
(b-d) rollback ratio and recomputed tokens: measured by running the real
    engine at each window size (100% deterministic traffic).
"""

from __future__ import annotations

from repro.serving.costmodel import V5E, attn_flops, flops_per_token, kv_bytes_per_token
from benchmarks.common import bench_model, full_config, make_requests, run_scenario


def verify_cost_per_token_us(fcfg, window: int, ctx: int = 512) -> float:
    flops = flops_per_token(fcfg) * window + attn_flops(fcfg, window, ctx)
    pbytes = fcfg.active_param_count() * V5E.dtype_bytes
    bytes_ = pbytes + kv_bytes_per_token(fcfg) * (ctx + window)
    util = min(1.0, window / V5E.sat_rows)
    t = max(flops / (V5E.peak_flops * max(util, 1e-3)), bytes_ / V5E.hbm_bw)
    return t / window * 1e6


def run(max_new: int = 48, n_requests: int = 8):
    cfg, params = bench_model()
    fcfg = full_config()
    rows = []
    for w in (16, 32, 64, 128, 256, 512):
        rows.append((f"fig9a_verify_us_per_tok_W{w}", "",
                     round(verify_cost_per_token_us(fcfg, w), 2)))

    for w in (4, 8, 16):
        reqs = make_requests(cfg, n_requests, 1.0, max_new)
        r = run_scenario(cfg, params, reqs, window=w, group=4)
        total_out = r["out_tokens"]
        rows.append((f"fig9bc_rollbacks_W{w}", round(r["wall_s"], 1), r["rollbacks"]))
        rows.append((f"fig9d_recompute_frac_W{w}", "",
                     round(r["recomputed"] / max(total_out, 1), 4)))
    return rows
