"""Benchmark harness entry point — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  ``us_per_call`` is measured
CPU wall time (reduced models, interpretive — trends only); ``derived`` is
the paper-comparable quantity (simulated TPU-v5e throughput/latency from
the roofline cost model, span lengths, rollback counts, ...).

The roofline analysis (deliverable (g)) runs as a separate process because
it needs the 512-device XLA host-platform simulation:
    PYTHONPATH=src python benchmarks/roofline.py
"""

from __future__ import annotations

import time
import traceback


def main() -> None:
    from benchmarks import (
        fig4_kernels,
        fig5_selective,
        fig6_spans,
        fig9_window,
        fig10_offline,
        fig11_online,
        fig12_grouped,
        fig_overlap,
        fig_pipeline,
        fig_prefill,
    )

    suites = [
        ("fig4", fig4_kernels.run),
        ("fig5", fig5_selective.run),
        ("fig6", fig6_spans.run),
        ("fig9", fig9_window.run),
        ("fig10+table4", fig10_offline.run),
        ("fig11+table5", fig11_online.run),
        ("fig12", fig12_grouped.run),
        ("fig_overlap", fig_overlap.run),
        ("fig_pipeline", fig_pipeline.run),
        ("fig_prefill", fig_prefill.run),
    ]
    print("name,us_per_call,derived")
    for name, fn in suites:
        t0 = time.time()
        try:
            rows = fn()
            for row in rows:
                print(",".join(str(x) for x in row), flush=True)
            print(f"# {name} done in {time.time() - t0:.0f}s", flush=True)
        except Exception as e:
            print(f"# {name} FAILED: {type(e).__name__}: {e}", flush=True)
            traceback.print_exc()


if __name__ == "__main__":
    main()
