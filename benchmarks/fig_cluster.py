"""Mesh-scale serving sweep — replicas x TP on the cost model.

Drives the deterministic cluster front end (``repro.cluster``) on
shared-prefix traffic: N engine replicas behind the radix-prefix-affinity
router, each replica a REAL engine on its own costed dual-clock runtime,
TP width threaded into the cost model's per-event scaling (FLOPs/bytes
divided across shards plus the un-overlapped all-reduce ICI term).

Reported per configuration:

  * aggregate throughput (committed tokens per simulated second across
    the fleet) and goodput (throughput from requests meeting the TTFT
    SLO) — the replica sweep is weak-scaled (arrival rate and request
    count grow with the fleet), so near-linear aggregate scaling is the
    acceptance bar;
  * router telemetry: affinity hit rate, load-guard diverts,
    cross-replica block transfers;
  * the TP sweep at fixed workload: per-token latency drops sub-linearly
    (the ICI term), committed streams bitwise unchanged.

Two determinism assertions ride along, mirroring the test suite: the
deterministic requests' committed streams are bitwise identical across
replica counts (same arrival trace, fixed workload) and across TP widths
(the pinned canonical mesh-reduction schedule).
"""

from __future__ import annotations

import argparse
import time

from repro.cluster import Cluster, run_online
from repro.core.determinism import Mode
from repro.serving.engine import Engine
from repro.serving.online import percentile
from repro.training.data import poisson_arrivals
from benchmarks.common import (
    BENCH_POLICY, bench_model, emit, full_config, make_requests,
)

BLOCK = 16
CAPACITY = 256
SLO_TTFT_S = 1.0


def _requests(cfg, n: int, sys_len: int, tail_len: int, max_new: int,
              seed: int):
    reqs = make_requests(
        cfg, n, det_ratio=0.5, max_new=max_new, seed=seed,
        in_lens=[sys_len + tail_len] * n,
    )
    sys_prompt = [(7 * j + 3) % cfg.vocab_size for j in range(sys_len)]
    for r in reqs:  # shared system prompt, unique tail
        r.prompt = sys_prompt + r.prompt[sys_len:]
    return reqs


def _run(cfg, params, fcfg, *, replicas, tp, n, qps, sys_len, tail_len,
         max_new, max_batch, seed=0):
    def make_engine(idx):
        return Engine(
            cfg, params, mode=Mode.LLM42, policy=BENCH_POLICY, window=8,
            group=4, max_batch=max_batch, capacity=CAPACITY,
            prefill_chunk=BLOCK, block_size=BLOCK, tp=tp,
        )

    cluster = Cluster(make_engine, replicas)
    reqs = _requests(cfg, n, sys_len, tail_len, max_new, seed)
    arrivals = poisson_arrivals(n, qps, seed=seed)
    t0 = time.time()
    res = run_online(cluster, fcfg, list(zip(reqs, arrivals)))
    wall = time.time() - t0
    tt = list(res.ttfts.values())
    return {
        "tput": res.throughput,
        "goodput": res.goodput(SLO_TTFT_S),
        "ttft_p50": percentile(tt, 50),
        "ttft_p99": percentile(tt, 99),
        "hit_rate": cluster.router.affinity_hit_rate,
        "diverted": cluster.router.diverted,
        "transfers": cluster.router.transfers,
        "wall_s": wall,
        "streams": {
            r.rid: list(r.committed)
            for r in cluster.finished if r.sampling.is_deterministic
        },
    }


def run(base_n: int = 16, base_qps: float = 80.0, sys_len: int = 64,
        tail_len: int = 6, max_new: int = 16, max_batch: int = 8):
    cfg, params = bench_model()
    fcfg = full_config()
    rows = []
    common = dict(sys_len=sys_len, tail_len=tail_len, max_new=max_new,
                  max_batch=max_batch)

    # -- replica sweep: weak scaling (workload grows with the fleet) -----
    tput_by_r = {}
    for r_count in (1, 2, 4):
        r = _run(cfg, params, fcfg, replicas=r_count, tp=1,
                 n=base_n * r_count, qps=base_qps * r_count, **common)
        tput_by_r[r_count] = r["tput"]
        rows.append((f"fig_cluster_r{r_count}_tput", "",
                     round(r["tput"], 1)))
        rows.append((f"fig_cluster_r{r_count}_goodput", "",
                     round(r["goodput"], 1)))
        rows.append((f"fig_cluster_r{r_count}_ttft_p99_ms", "",
                     round(r["ttft_p99"] * 1e3, 2)))
        rows.append((f"fig_cluster_r{r_count}_hit_rate", "",
                     round(r["hit_rate"], 3)))
        rows.append((f"fig_cluster_r{r_count}_transfers", "",
                     r["transfers"]))
    for r_count in (2, 4):
        ratio = tput_by_r[r_count] / max(tput_by_r[1], 1e-12)
        rows.append((f"fig_cluster_scaling_x{r_count}_ratio", "",
                     round(ratio, 3)))
        # near-linear aggregate scaling under weak scaling: each replica
        # carries the single-replica load, the router only adds
        # deterministic bookkeeping
        assert ratio >= 0.7 * r_count, (
            f"{r_count} replicas scaled {ratio:.2f}x (< {0.7 * r_count:.1f})"
        )

    # -- determinism across replica counts: FIXED workload ---------------
    fixed = {
        r_count: _run(cfg, params, fcfg, replicas=r_count, tp=1,
                      n=base_n, qps=base_qps, **common)
        for r_count in (1, 2, 4)
    }
    assert (fixed[1]["streams"] == fixed[2]["streams"]
            == fixed[4]["streams"]), (
        "replica count moved a deterministic committed stream"
    )
    rows.append(("fig_cluster_det_streams_replica_invariant", "", 1))

    # -- TP sweep at fixed workload: cost scaling + stream invariance ----
    tp_streams = {}
    for tp in (1, 2, 4):
        r = _run(cfg, params, fcfg, replicas=1, tp=tp,
                 n=base_n, qps=base_qps, **common)
        tp_streams[tp] = r["streams"]
        rows.append((f"fig_cluster_tp{tp}_tput", "", round(r["tput"], 1)))
        rows.append((f"fig_cluster_tp{tp}_ttft_p50_ms", "",
                     round(r["ttft_p50"] * 1e3, 2)))
    assert tp_streams[1] == tp_streams[2] == tp_streams[4], (
        "TP width moved a deterministic committed stream"
    )
    rows.append(("fig_cluster_det_streams_tp_invariant", "", 1))
    # sharding cuts per-shard work: wider TP must not be slower
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced workload for CI (fewer, shorter requests)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write the rows as JSON (CI artifact)")
    args = ap.parse_args()
    if args.smoke:
        rows = run(base_n=6, base_qps=60.0, sys_len=48, tail_len=4,
                   max_new=10, max_batch=4)
    else:
        rows = run()
    emit(rows, "name,us_per_call,derived", json_path=args.json)


if __name__ == "__main__":
    main()
