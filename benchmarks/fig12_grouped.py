"""Paper Fig. 12 — grouped verification ablation.

Grid over (per-request window W x verify-group size G), 100% deterministic
traffic: simulated v5e total completion time (offline analogue of their P99
latency) and recomputation overhead.  The paper's finding: grouped small
windows dominate single-request large windows (e.g. 8x32 beats 1x256).
"""

from __future__ import annotations

from benchmarks.common import (
    bench_model, full_config, make_requests, run_scenario,
)
from repro.serving import costmodel


def run(n_requests: int = 8, max_new: int = 48):
    cfg, params = bench_model()
    fcfg = full_config()
    rows = []
    for w in (4, 8, 16):
        for g in (1, 4, 8):
            reqs = make_requests(cfg, n_requests, 1.0, max_new, seed=3)
            r = run_scenario(cfg, params, reqs, window=w, group=g)
            sim = costmodel.simulate(fcfg, r["events"])
            rows.append((
                f"fig12_W{w}_G{g}_sim_ms",
                round(r["wall_s"], 1),
                round(sim["total_s"] * 1e3, 2),
            ))
            rows.append((
                f"fig12_W{w}_G{g}_recompute_frac", "",
                round(r["recomputed"] / max(r["out_tokens"], 1), 4),
            ))
    return rows
