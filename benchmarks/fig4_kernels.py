"""Paper Fig. 4 — batch-invariant vs shape-adaptive kernel performance.

(a) GEMM: split-K (shape-adaptive) vs batch-invariant (universal schedule)
    at Llama-8B FFN down-projection shapes, across batch sizes.
(b) RMSNorm: fused kernel vs unfused (python-composed) reference.

Two result columns per row:
  us_cpu      measured wall μs on this CPU (jnp semantics; interpretive —
              relative trends only)
  derived     modeled TPU-v5e μs from the roofline cost model with the
              paper-calibrated batch-invariance penalties (Fig. 4: 194 vs
              527 TFLOPS ⇒ 0.368x compute; RMSNorm ⇒ 0.7x bandwidth)
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.serving.costmodel import V5E


def _time(fn, *args, reps=5) -> float:
    fn(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    out.block_until_ready()
    return (time.perf_counter() - t0) / reps * 1e6


def gemm_rows():
    # Llama-3.1-8B FFN down-proj: K=14336, N=4096 (paper Fig. 4a), scaled
    # K,N /8 for CPU tractability; flops model uses the full shape.
    K_full, N_full = 14336, 4096
    K, N = K_full // 8, N_full // 8
    w = jax.random.normal(jax.random.key(0), (K, N))
    rows = []
    for M in (1, 8, 64, 512):
        x = jax.random.normal(jax.random.key(M), (M, K))
        splits = {1: 16, 8: 8, 64: 4, 512: 1}[M]
        t_fast = _time(jax.jit(lambda a, b: ref.gemm_splitk(a, b, splits, "bfloat16")), x, w)
        t_bi = _time(jax.jit(ref.gemm_batch_invariant), x, w)

        # derived TPU time: utilisation-limited roofline
        flops = 2.0 * M * K_full * N_full
        bytes_ = 2 * (M * K_full + K_full * N_full + M * N_full)
        util_fast = min(1.0, (M * splits) / V5E.sat_rows)
        util_bi = min(1.0, M / V5E.sat_rows)
        tpu_fast = max(flops / (V5E.peak_flops * max(util_fast, 1e-3)),
                       bytes_ / V5E.hbm_bw) * 1e6
        tpu_bi = max(flops / (V5E.peak_flops * V5E.bi_compute_frac
                              * max(util_bi, 1e-3)),
                     bytes_ / V5E.hbm_bw) * 1e6
        rows.append((f"fig4a_gemm_M{M}_splitk", round(t_fast, 1), round(tpu_fast, 2)))
        rows.append((f"fig4a_gemm_M{M}_batchinv", round(t_bi, 1), round(tpu_bi, 2)))
    return rows


def _unfused_rmsnorm(x, scale):
    # the "python/unfused" baseline the paper measures in Fig. 4b
    xf = x.astype(jnp.float32)
    sq = jnp.square(xf)
    mean = jnp.mean(sq, axis=-1, keepdims=True)
    r = jnp.sqrt(mean + 1e-5)
    return (xf / r * scale).astype(x.dtype)


def rmsnorm_rows():
    D = 4096
    scale = jax.random.normal(jax.random.key(0), (D,))
    rows = []
    for M in (64, 1024, 8192):
        x = jax.random.normal(jax.random.key(M), (M, D))
        t_fused = _time(jax.jit(lambda a, s: ref.rmsnorm(a, s)), x, scale)
        t_unfused = _time(jax.jit(_unfused_rmsnorm), x, scale)
        bytes_ = 4 * (2 * M * D + D)
        tpu_fused = bytes_ / V5E.hbm_bw * 1e6
        tpu_unfused = bytes_ * 3 / (V5E.hbm_bw * V5E.bi_mem_frac) * 1e6
        rows.append((f"fig4b_rmsnorm_M{M}_fused", round(t_fused, 1), round(tpu_fused, 2)))
        rows.append((f"fig4b_rmsnorm_M{M}_unfused", round(t_unfused, 1),
                     round(tpu_unfused, 2)))
    return rows


def run():
    return gemm_rows() + rmsnorm_rows()
