"""Paper Fig. 11 + Table 5 — online inference: end-to-end latency and TTFT
CDFs under Poisson load, across modes and deterministic-traffic ratios.

The engine runs for real (reduced model, real rollbacks); the clock is the
v5e cost model (discrete-event simulation, serving/online.py).  Load is
scaled to the simulated throughput of the reduced-cost Llama-8B (the paper
drives 4xH100 at 12–18 QPS; our single-chip sim saturates lower).
"""

from __future__ import annotations


from repro.core.determinism import Mode
from repro.serving.online import percentile, run_online
from repro.serving.engine import Engine
from repro.serving.scheduler import OverlapPolicy, PauseDecodePolicy
from benchmarks.common import (
    BENCH_POLICY, bench_model, full_config, make_requests,
)
from repro.training.data import poisson_arrivals


def _run(cfg, params, fcfg, n, qps, det_ratio, mode, seed=0, scheduler=None,
         prefill_chunk=0, in_lens=None, capacity=256):
    engine = Engine(cfg, params, mode=mode, policy=BENCH_POLICY,
                    window=8, group=4, max_batch=8, capacity=capacity,
                    scheduler=scheduler, prefill_chunk=prefill_chunk)
    reqs = make_requests(cfg, n, det_ratio, max_new=24, seed=seed,
                         in_lens=in_lens)
    arrivals = poisson_arrivals(n, qps, seed=seed)
    res = run_online(engine, fcfg, list(zip(reqs, arrivals)),
                     invariant_mode=(mode == Mode.BATCH_INVARIANT))
    lat = list(res.latencies.values())
    tt = list(res.ttfts.values())
    return {
        "p50": percentile(lat, 50), "p99": percentile(lat, 99),
        "ttft_p50": percentile(tt, 50), "ttft_p90": percentile(tt, 90),
    }


def run(n: int = 24, qps: float = 40.0):
    cfg, params = bench_model()
    fcfg = full_config()
    rows = []

    nd = _run(cfg, params, fcfg, n, qps, 0.0, Mode.NONDET)
    rows.append((f"fig11_nondet_p50_ms", "", round(nd["p50"] * 1e3, 1)))
    rows.append((f"fig11_nondet_p99_ms", "", round(nd["p99"] * 1e3, 1)))
    rows.append((f"table5_nondet_ttft_p50_ms", "", round(nd["ttft_p50"] * 1e3, 2)))

    bi = _run(cfg, params, fcfg, n, qps, 0.0, Mode.BATCH_INVARIANT)
    rows.append((f"fig11_batchinv_p50_ms", "", round(bi["p50"] * 1e3, 1)))
    rows.append((f"fig11_batchinv_p99_ms", "", round(bi["p99"] * 1e3, 1)))
    rows.append((f"table5_batchinv_ttft_p50_ms", "", round(bi["ttft_p50"] * 1e3, 2)))

    for ratio in (0.02, 0.1, 0.5, 1.0):
        r = _run(cfg, params, fcfg, n, qps, ratio, Mode.LLM42)
        pct = int(ratio * 100)
        rows.append((f"fig11_llm42_{pct}pct_p50_ms", "", round(r["p50"] * 1e3, 1)))
        rows.append((f"fig11_llm42_{pct}pct_p99_ms", "", round(r["p99"] * 1e3, 1)))
        rows.append((f"table5_llm42_{pct}pct_ttft_p50_ms", "",
                     round(r["ttft_p50"] * 1e3, 2)))

    # scheduler ablation at the 50% mix: pause-decode (paper prototype,
    # §5.2 limitation (1)) vs the default overlapped scheduler
    pa = _run(cfg, params, fcfg, n, qps, 0.5, Mode.LLM42,
              scheduler=PauseDecodePolicy())
    ov = _run(cfg, params, fcfg, n, qps, 0.5, Mode.LLM42,
              scheduler=OverlapPolicy())
    rows.append(("fig11_llm42_50pct_pause_p99_ms", "", round(pa["p99"] * 1e3, 1)))
    rows.append(("fig11_llm42_50pct_overlap_p99_ms", "", round(ov["p99"] * 1e3, 1)))

    # chunked-prefill ablation (§5.2 limitation (2)): every 4th prompt is
    # long; exclusive prefill stalls co-resident decode traffic for the
    # whole prompt, the chunked lane amortizes it chunk by chunk.  TTFT p50
    # is the short-prompt traffic (the stall victims) and improves; TTFT
    # p90 is the long prompts themselves, which pay for their chunking —
    # the cost lands on the traffic that causes it (see
    # benchmarks/fig_prefill.py for the dedicated TTFT study)
    long_lens = [512 if i % 4 == 0 else 12 for i in range(n)]
    for chunk, tag in ((0, "exclusive"), (128, "chunked128")):
        r = _run(cfg, params, fcfg, n, qps, 0.5, Mode.LLM42,
                 prefill_chunk=chunk, in_lens=long_lens, capacity=1024)
        rows.append((f"fig11_llm42_longprompt_{tag}_ttft_p50_ms", "",
                     round(r["ttft_p50"] * 1e3, 2)))
        rows.append((f"fig11_llm42_longprompt_{tag}_ttft_p90_ms", "",
                     round(r["ttft_p90"] * 1e3, 2)))
        rows.append((f"fig11_llm42_longprompt_{tag}_p99_ms", "",
                     round(r["p99"] * 1e3, 1)))
    return rows
