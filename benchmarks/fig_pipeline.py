"""Verify-pipelining sweep — verdict latency x per-request speculation depth.

The dual-clock runtime (``serving.streams``) prices verification on its
own execution stream with continuous verdict deadlines
(``Engine(verify_latency_ms=...)``); the multi-window speculation pipeline
(``core.pipeline`` + ``serving.statepool``) lets a single request keep
``--spec-depth`` verify windows in flight.  Together they answer the
question the old integer ``verify_latency`` could not express: how much
verdict latency can the scheduler hide, and how deep must the per-request
pipeline run to hide it?

The sweep runs the REAL engine (reduced model, real rollbacks, real
cascade invalidations) with the stream clocks costed at the full model's
scale, over:

  * ``verify_latency_ms`` — extra delay between a verify pass completing
    on its stream and the verdict becoming visible (interconnect /
    host-sync / remote-verifier time);
  * ``spec_depth`` — verify windows in flight per request (1 = the
    paper's protocol, the old hard cap).

Reported per point: simulated throughput (tokens/s over the two-stream
makespan), verify-stream occupancy, peak in-flight depth actually reached,
and the ratio vs pause-decode.  PR 3 showed the one-window protocol was
the binding constraint at 50 ms (0.45x pause with the verify stream ~18%
occupied); the depth axis is the fix.  A second table runs the ssm
(rwkv6) — and, in full mode, hybrid (jamba) — configs through the same
sweep: the double-buffered state pool is what lets them sustain depth >= 2
at all (they were hard-capped at one window).  Every configuration also
asserts the tentpole invariant: committed streams are bitwise identical to
the pause-decode baseline at every (latency, depth) point.
"""

from __future__ import annotations

import argparse

from repro.core.determinism import Mode, REORDER_ONLY_POLICY
from repro.serving.engine import Engine
from repro.serving.scheduler import OverlapPolicy, PauseDecodePolicy
from benchmarks.common import (
    bench_model, emit, full_config, make_requests,
)

#: paper-regime drift (flips rare, spans long) — the pipelining question
#: is about latency hiding, not rollback recovery
DRIFT = REORDER_ONLY_POLICY


def _requests(cfg, n, max_new):
    reqs = make_requests(cfg, n, 0.0, max_new, seed=7)
    for i, r in enumerate(reqs):
        r.sampling.is_deterministic = i % 2 == 0  # exact 50/50 mix
    return reqs


def _run(cfg, params, fcfg, n, max_new, *, scheduler, depth=1,
         latency_ms=None):
    # group=2 on a 50% det mix => several verify groups can be in flight
    # concurrently even at depth 1; spec_depth then multiplies the windows
    # a single request contributes
    eng = Engine(
        cfg, params, mode=Mode.LLM42, policy=DRIFT, window=8, group=2,
        max_batch=8, capacity=256, scheduler=scheduler, spec_depth=depth,
        verify_latency_ms=latency_ms, cost_cfg=fcfg,
    )
    for r in _requests(cfg, n, max_new):
        eng.submit(r)
    done = eng.run()
    out_tokens = sum(r.num_output for r in done)
    rt = eng.runtime
    return {
        "streams": {
            r.rid: list(r.committed)
            for r in done if r.sampling.is_deterministic
        },
        "tput": out_tokens / max(rt.makespan, 1e-12),
        "occupancy": rt.verify.occupancy(max(rt.makespan, 1e-12)),
        "peak_depth": eng.statepool.peak_depth,
        "cascades": sum(r.num_cascaded_windows for r in done),
    }


def _sweep(arch, rows, n, max_new, latencies_ms, depths, tag=""):
    cfg, params = bench_model(arch)
    fcfg = full_config(arch)
    base = _run(cfg, params, fcfg, n, max_new,
                scheduler=PauseDecodePolicy(), latency_ms=0.0)
    rows.append((f"fig_pipeline{tag}_pause_tput", "", round(base["tput"], 1)))

    for lat in latencies_ms:
        for depth in depths:
            r = _run(cfg, params, fcfg, n, max_new,
                     scheduler=OverlapPolicy(), depth=depth, latency_ms=lat)
            assert r["streams"] == base["streams"], (
                f"{arch}: latency {lat} ms / spec_depth {depth} moved a "
                f"committed stream"
            )
            point = f"{tag}_lat{lat:g}ms_depth{depth}"
            rows.append((f"fig_pipeline{point}_tput", "",
                         round(r["tput"], 1)))
            rows.append((f"fig_pipeline{point}_occupancy", "",
                         round(r["occupancy"], 3)))
            rows.append((f"fig_pipeline{point}_peak_depth", "",
                         r["peak_depth"]))
            rows.append((f"fig_pipeline{point}_vs_pause", "",
                         round(r["tput"] / max(base["tput"], 1e-9), 3)))
    return rows


def run(n: int = 8, max_new: int = 32,
        latencies_ms=(0.0, 25.0, 50.0, 150.0, 300.0), depths=(1, 2, 4, 8),
        recurrent_rows=(("rwkv6-3b", 50.0), ("jamba-1.5-large-398b", 2000.0)),
        recurrent_depths=(1, 2, 4)):
    """Per-request depth bites once verdict latency exceeds the window
    FILL time ((W-1) x decode-iteration seconds at the costed scale) —
    below that, a request's next window isn't full before its verdict
    lands and cross-request interleaving already hides the round trip.
    The recurrent rows pick latencies scaled to each arch's iteration
    cost for the same reason (llama-8B fills a W=8 window in ~140 ms;
    jamba-398B in ~1.7 s)."""
    rows = []
    _sweep("llama3-8b", rows, n, max_new, latencies_ms, depths)
    # the state-pool rows: recurrent/hybrid archs, previously hard-capped
    # at one in-flight window, running the same latency-hiding sweep
    for arch, lat in recurrent_rows:
        _sweep(arch, rows, n, max_new, (lat,),
               recurrent_depths, tag=f"_{arch.split('-')[0]}")
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced sweep for CI (fewer points, shorter runs)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write the rows as JSON (CI artifact)")
    args = ap.parse_args()
    if args.smoke:
        rows = run(n=8, max_new=32, latencies_ms=(50.0, 150.0),
                   depths=(1, 4), recurrent_rows=(("rwkv6-3b", 50.0),),
                   recurrent_depths=(1, 2))
    else:
        rows = run()
    emit(rows, "name,us_per_call,derived", json_path=args.json)


if __name__ == "__main__":
    main()
