"""Verify-pipelining-depth sweep — latency ms x in-flight depth.

The dual-clock runtime (``serving.streams``) is what makes this figure
possible: verification runs on its own execution stream with continuous
verdict deadlines (``Engine(verify_latency_ms=...)``), so we can ask the
question the old integer ``verify_latency`` could not express — how much
verdict latency can the scheduler hide, and how many verify windows must
be in flight to hide it?

The sweep runs the REAL engine (reduced model, real rollbacks) with the
stream clocks costed at the full Llama-8B scale, over:

  * ``verify_latency_ms`` — extra delay between a verify pass completing
    on its stream and the verdict becoming visible (interconnect /
    host-sync / remote-verifier time);
  * ``max_inflight`` — OverlapPolicy's cap on concurrently outstanding
    verify windows, counted in requests (0 = unbounded): the pipelining
    depth.  The workload verifies in groups of 2 so several groups can be
    airborne at once.

Reported per point: simulated throughput (tokens/s over the two-stream
makespan), verify-stream occupancy, and the ratio vs pause-decode.
Expected shape: at depth 1 throughput decays with latency (each window
waits for the previous verdict); deeper pipelining recovers it until the
verify stream saturates.  Every configuration also asserts the tentpole
invariant: committed streams are bitwise identical to the pause-decode
baseline at every (latency, depth) point.
"""

from __future__ import annotations

import argparse

from repro.core.determinism import Mode, REORDER_ONLY_POLICY
from repro.serving.engine import Engine
from repro.serving.scheduler import OverlapPolicy, PauseDecodePolicy
from benchmarks.common import bench_model, emit, full_config, make_requests

#: paper-regime drift (flips rare, spans long) — the pipelining question
#: is about latency hiding, not rollback recovery
DRIFT = REORDER_ONLY_POLICY


def _requests(cfg, n, max_new):
    reqs = make_requests(cfg, n, 0.0, max_new, seed=7)
    for i, r in enumerate(reqs):
        r.sampling.is_deterministic = i % 2 == 0  # exact 50/50 mix
    return reqs


def _run(cfg, params, fcfg, n, max_new, *, scheduler, latency_ms=None):
    # group=2 on a 50% det mix => several verify groups can be in flight
    # concurrently, so the depth cap actually bites (one group of G=4
    # would make every depth >= 1 equivalent)
    eng = Engine(
        cfg, params, mode=Mode.LLM42, policy=DRIFT, window=8, group=2,
        max_batch=8, capacity=256, scheduler=scheduler,
        verify_latency_ms=latency_ms, cost_cfg=fcfg,
    )
    for r in _requests(cfg, n, max_new):
        eng.submit(r)
    done = eng.run()
    out_tokens = sum(r.num_output for r in done)
    rt = eng.runtime
    return {
        "streams": {
            r.rid: list(r.committed)
            for r in done if r.sampling.is_deterministic
        },
        "tput": out_tokens / max(rt.makespan, 1e-12),
        "occupancy": rt.verify.occupancy(max(rt.makespan, 1e-12)),
    }


def run(n: int = 8, max_new: int = 32,
        latencies_ms=(0.0, 10.0, 25.0, 50.0), depths=(1, 2, 4, 0)):
    cfg, params = bench_model()
    fcfg = full_config()
    rows = []

    base = _run(cfg, params, fcfg, n, max_new,
                scheduler=PauseDecodePolicy(), latency_ms=0.0)
    rows.append(("fig_pipeline_pause_tput", "", round(base["tput"], 1)))

    for lat in latencies_ms:
        for depth in depths:
            r = _run(cfg, params, fcfg, n, max_new,
                     scheduler=OverlapPolicy(max_inflight=depth),
                     latency_ms=lat)
            assert r["streams"] == base["streams"], (
                f"latency {lat} ms / depth {depth} moved a committed stream"
            )
            tag = f"lat{lat:g}ms_depth{depth or 'inf'}"
            rows.append((f"fig_pipeline_{tag}_tput", "",
                         round(r["tput"], 1)))
            rows.append((f"fig_pipeline_{tag}_occupancy", "",
                         round(r["occupancy"], 3)))
            rows.append((f"fig_pipeline_{tag}_vs_pause", "",
                         round(r["tput"] / max(base["tput"], 1e-9), 3)))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced sweep for CI (fewer points, shorter runs)")
    args = ap.parse_args()
    if args.smoke:
        rows = run(n=8, max_new=16, latencies_ms=(50.0,), depths=(2, 0))
    else:
        rows = run()
    emit(rows, "name,us_per_call,derived")


if __name__ == "__main__":
    main()
