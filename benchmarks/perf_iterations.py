import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""§Perf hillclimbs (deliverable (g) iteration log) — run standalone:

  PYTHONPATH=src python benchmarks/perf_iterations.py

Three pairs, per the assignment's selection rule:

  P1  command-r-35b × decode_32k   — most representative of the paper's
      technique (serving decode is where DVR lives) AND worst useful-flops
      ratio in the baseline table (~0.1: per-device FLOPs ~10× the model
      ideal, caused by GSPMD "involuntary full rematerialization" around
      the attention einsum when the KV cache is sharded on head_dim).
  P2  seamless-m4t-medium × train_4k — most collective-bound baseline
      (collective term > memory > 30× compute): FSDP all-gathers of a 1B-
      param model dominate; FSDP buys nothing at this scale.
  P3  kimi-k2-1t-a32b × decode_32k  — the paper-table trillion-param MoE;
      worst absolute decode step time, same replication pathology plus
      expert-weight streaming.

Each iteration records hypothesis → change → before/after terms → verdict.
The paper-faithful BASELINE rows are kept separately from the optimized
variants (assignment: both must stay visible).
"""

import json
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(__file__))

from repro.launch.mesh import make_production_mesh  # noqa: E402
import roofline as R  # noqa: E402


PAIRS = [
    {
        "id": "P1",
        "arch": "command_r_35b",
        "shape": "decode_32k",
        "why": "paper-technique-representative + worst useful ratio",
        "iterations": [
            {
                "name": "kv-seq-sharding",
                "variant": {"kv_policy": "seq_first"},
                "hypothesis": (
                    "Baseline shards KV head_dim over model=16 (kv_heads=8 "
                    "not divisible); GSPMD cannot propagate that layout "
                    "through the attention einsum and falls back to "
                    "involuntary full rematerialization — replicating the "
                    "(B,32k,8,128) cache per device per layer.  Napkin: "
                    "replication costs ~cache_bytes×model ≈ 16× extra "
                    "traffic and compute; seq-first sharding (FlashDecoding "
                    "sequence parallelism) makes the contraction batch over "
                    "the sharded axis, needing only O(B·H·D) LSE-combine "
                    "collectives.  Expect memory term to drop ≥5×, compute "
                    "term toward the 2ND ideal (useful → ~1)."
                ),
            },
        ],
    },
    {
        "id": "P2",
        "arch": "seamless_m4t_medium",
        "shape": "train_4k",
        "why": "most collective-bound baseline",
        "iterations": [
            {
                "name": "drop-fsdp",
                "variant": {"fsdp": False},
                "hypothesis": (
                    "FSDP all-gathers every weight once per microbatch "
                    "(16 microbatches × ~1B params × 2B ≈ 32 GB/step of "
                    "all-gather per device-column) while the model needs "
                    "only ~2.6 GB/device replicated — at 1B params FSDP "
                    "buys nothing (fits easily) and costs the dominant "
                    "term.  Expect collective term to drop to the gradient "
                    "all-reduce floor (~2×params×4B/step) — roughly "
                    "16×num_mb → 2, i.e. ≥5× down; memory/compute ~flat."
                ),
            },
        ],
    },
    {
        "id": "P2b",
        "arch": "seamless_m4t_medium",
        "shape": "train_4k",
        "why": "alternative branch: keep FSDP, quarter the all-gather count",
        "iterations": [
            {
                "name": "mb-rows-64",
                "variant": {"fsdp": True, "mb_rows": 64},
                "hypothesis": (
                    "FSDP all-gathers run once per microbatch; at 1B params "
                    "the activations of a 64-row microbatch (64x4096x1024x2B "
                    "x 24 layers ~ 13 GB global, 0.8 GB/device after remat) "
                    "still fit, so quartering the microbatch count (16 -> 4) "
                    "should cut all-gather traffic ~4x while keeping the "
                    "FSDP memory benefit (unlike P2's drop-fsdp).  Expect "
                    "collective term ~4x down vs the FSDP baseline; compute "
                    "and memory ~flat."
                ),
            },
        ],
    },
    {
        "id": "P3",
        "arch": "kimi_k2_1t_a32b",
        "shape": "decode_32k",
        "why": "paper-table MoE giant; worst absolute decode step",
        "iterations": [
            {
                "name": "kv-seq-sharding",
                "variant": {"kv_policy": "seq_first"},
                "hypothesis": (
                    "Same replication pathology as P1 (kv=8 < model=16 ⇒ "
                    "head_dim sharding ⇒ involuntary remat), on a 61-layer "
                    "cache.  Baseline per-device memory term (~3 s) is "
                    "~300× the 8 GB/device weight-streaming floor (~10 ms), "
                    "so replication dominates; expect ≥10× memory-term "
                    "drop.  Expert weights (1T params/256 chips ≈ 8 GB bf16 "
                    "per device) then become the floor — irreducible "
                    "without quantization, which we note but do not apply."
                ),
            },
            {
                "name": "expert-2d-sharding",
                "variant": {"kv_policy": "seq_first", "moe_ep": "data"},
                "hypothesis": (
                    "Baseline serve rules put experts on the model axis "
                    "only: 384/16 = 24 FULL experts per device = 129 GB — "
                    "over v5e HBM and 13x the streaming floor.  2-D expert "
                    "sharding (experts over data=16, per-expert ffn over "
                    "model=16) cuts resident expert weights to ~8 GB/device "
                    "at the cost of an all-to-all token dispatch across "
                    "data.  Napkin: memory term floor 129 GB -> 8 GB "
                    "streaming => up to 16x down on the weight component; "
                    "all-to-all adds ~B*top_k*d_model*2B/(16 links) ~ "
                    "2 MB/device — negligible.  Expect memory term >=3x "
                    "down and per-device HBM residency to become feasible."
                ),
            },
        ],
    },
]


def run_pair(pair, mesh, dryrun_dir):
    arch, shape = pair["arch"], pair["shape"]
    print(f"\n=== {pair['id']} {arch} × {shape} ({pair['why']}) ===", flush=True)
    baseline = R.analyze(arch, shape, mesh, dryrun_dir, variant=None)
    rec = {"pair": pair["id"], "arch": arch, "shape": shape,
           "why": pair["why"], "baseline": baseline, "iterations": []}
    print(f"  baseline: compute={baseline['compute_s']*1e3:.3f}ms "
          f"memory={baseline['memory_s']*1e3:.3f}ms "
          f"coll={baseline['collective_s']*1e3:.3f}ms "
          f"dom={baseline['dominant']} useful={baseline['useful_ratio']:.3f}",
          flush=True)
    prev = baseline
    for it in pair["iterations"]:
        result = R.analyze(arch, shape, mesh, dryrun_dir, variant=it["variant"])
        dom = prev["dominant"] + "_s"
        before, after = prev[dom], result[dom]
        delta = (before - after) / max(before, 1e-12)
        verdict = "CONFIRMED" if delta > 0.05 else (
            "REFUTED" if delta < -0.05 else "NEUTRAL")
        entry = {
            "name": it["name"], "variant": it["variant"],
            "hypothesis": it["hypothesis"],
            "before": {k: prev[k] for k in
                       ("compute_s", "memory_s", "collective_s", "dominant",
                        "useful_ratio", "step_time_s")},
            "after": {k: result[k] for k in
                      ("compute_s", "memory_s", "collective_s", "dominant",
                       "useful_ratio", "step_time_s")},
            "dominant_term_delta": delta,
            "step_time_speedup": prev["step_time_s"] / max(result["step_time_s"], 1e-12),
            "verdict": verdict,
        }
        rec["iterations"].append(entry)
        print(f"  [{it['name']}] {verdict}: dominant({prev['dominant']}) "
              f"{before*1e3:.3f}ms -> {after*1e3:.3f}ms "
              f"({delta*100:+.1f}%), step {entry['step_time_speedup']:.2f}x; "
              f"now compute={result['compute_s']*1e3:.3f} "
              f"memory={result['memory_s']*1e3:.3f} "
              f"coll={result['collective_s']*1e3:.3f} "
              f"useful={result['useful_ratio']:.3f}", flush=True)
        prev = result
    return rec


def main():
    mesh = make_production_mesh(multi_pod=False)
    out = []
    for pair in PAIRS:
        out.append(run_pair(pair, mesh, "experiments/dryrun"))
    with open("experiments/perf_iterations.json", "w") as f:
        json.dump(out, f, indent=1, default=str)
    print("\nwrote experiments/perf_iterations.json")


if __name__ == "__main__":
    main()
