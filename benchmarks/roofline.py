import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Roofline analysis (deliverable (g)) — run standalone:

  PYTHONPATH=src python benchmarks/roofline.py [--arch A --shape S] \
      [--dryrun-dir experiments/dryrun] [--out experiments/roofline.json]

Per (arch × input-shape) on the single-pod 16x16 mesh, derives the three
roofline terms:

  compute    = HLO_FLOPs/device        / 197e12 FLOP/s
  memory     = HLO_bytes/device        / 819e9 B/s
  collective = collective_bytes/device / 50e9 B/s (ICI link)

NOTE: ``cost_analysis()`` of a GSPMD-partitioned module reports PER-DEVICE
costs (verified empirically: a 16-way TP matmul reports 1/16 of the global
flops), and HLO-text shapes are per-device shards — so all three terms are
already per-chip; the division by chip count happens inside XLA, not here.
This also means the analysis *sees* partitioner pathologies: an
"involuntary full rematerialization" (replicated resharding) shows up as
inflated per-device flops/bytes — exactly what hillclimb #2 attacks.

METHODOLOGY (scan-correction): XLA's ``compiled.cost_analysis()`` counts a
while-loop body ONCE, and our models scan over layer blocks — so raw
numbers undercount by ~n_blocks.  We therefore compile two PROBES per case
(2 and 4 layer-blocks, scans fully unrolled, microbatch loop removed) and
solve cost(n) = a + b·n exactly for the per-block cost b, extrapolating to
the full depth.  Costs that sit inside *inner* loops the probes keep
(q-chunked attention at long seq, mamba/rwkv time scans) are added back
analytically — formulas in ``analytic_*`` below.  Raw, probed, and analytic
numbers are all recorded.

MODEL_FLOPS: 6·N·D for training (N = active params, D = tokens), 2·N·D for
inference shapes (forward only).  The ratio MODEL_FLOPS / HLO_FLOPs exposes
remat/routing/attention overhead beyond the ideal-params roofline.
"""

import argparse
import dataclasses
import json
import sys
from typing import Any, Dict, Optional

import jax

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro import configs as config_registry  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch import specs as S  # noqa: E402
from repro.launch.dryrun import collective_bytes  # noqa: E402

CHIPS = 256
PEAK = 197e12
HBM = 819e9
ICI = 50e9


# ---------------------------------------------------------------------------
# analytic terms
# ---------------------------------------------------------------------------


def _matmul_params(cfg) -> int:
    """Active params participating in matmuls per token (embed lookup is
    free; tied unembed counts once)."""
    n = cfg.active_param_count()
    if not cfg.tie_embeddings:
        n -= cfg.vocab_size * cfg.d_model  # the lookup-only embed table
    return n


def analytic_attn_flops(cfg, tokens: int, ctx: float) -> float:
    n_attn = sum(1 for i in range(cfg.num_layers) if cfg.layer_kind(i) == "attn")
    if cfg.attn_kind == "sliding":
        ctx = min(ctx, cfg.window)
    return 4.0 * n_attn * tokens * ctx * cfg.num_heads * cfg.hd


def analytic_recurrent_flops(cfg, tokens: int) -> float:
    """Per-token flops inside mamba/rwkv time scans (undercounted by probes)."""
    total = 0.0
    for i in range(cfg.num_layers):
        kind = cfg.layer_kind(i)
        if kind == "mamba":
            total += tokens * (4.0 * cfg.d_inner * cfg.d_state)
        elif kind == "rwkv":
            h = cfg.d_model // cfg.rwkv_head_dim
            total += tokens * (5.0 * h * cfg.rwkv_head_dim**2)
    return total


def analytic_flops(cfg, shape_meta: Dict[str, Any], train: bool) -> float:
    B, seq = shape_meta["batch"], shape_meta["seq"]
    if train:
        tokens, ctx = B * seq, seq / 2
        mult = 3.0  # fwd + bwd
    elif shape_meta["kind"] == "prefill":
        tokens, ctx = B * seq, seq / 2
        mult = 1.0
    elif shape_meta["kind"] == "verify":
        tokens, ctx = B * shape_meta["window"], seq
        mult = 1.0
    else:  # decode: one token against ctx
        tokens, ctx = B, seq
        mult = 1.0
    core = 2.0 * _matmul_params(cfg) * tokens
    attn = analytic_attn_flops(cfg, tokens, ctx)
    rec = analytic_recurrent_flops(cfg, tokens)
    return mult * (core + attn + rec)


def model_flops(cfg, shape_meta: Dict[str, Any], train: bool) -> float:
    B, seq = shape_meta["batch"], shape_meta["seq"]
    n = cfg.active_param_count()
    if train:
        return 6.0 * n * B * seq
    if shape_meta["kind"] == "prefill":
        return 2.0 * n * B * seq
    if shape_meta["kind"] == "verify":
        return 2.0 * n * B * shape_meta["window"]
    return 2.0 * n * B  # decode: one token per sequence


# ---------------------------------------------------------------------------
# probes
# ---------------------------------------------------------------------------


def _probe_cfg(cfg, n_blocks: int):
    period = cfg.block_period()
    if (cfg.num_layers - cfg.first_k_dense) % period != 0:
        period = 1
    return dataclasses.replace(
        cfg, num_layers=cfg.first_k_dense + n_blocks * period
    ), period


def _compile_probe(arch: str, shape: str, mesh, n_blocks: int,
                   train_mb: int = 1,
                   variant: Optional[Dict[str, Any]] = None
                   ) -> Optional[Dict[str, float]]:
    """Lower+compile a reduced-depth unrolled probe; return cost numbers.
    ``variant`` overrides sharding policy: {"kv_policy": ..., "fsdp": ...}
    (the §Perf hillclimb levers)."""
    variant = variant or {}
    cfg_full, skip = S.resolve_config(arch, shape)
    if skip:
        return None
    cfg, period = _probe_cfg(cfg_full, n_blocks)
    meta = S.INPUT_SHAPES[shape]
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.core.determinism import VERIFY_SCHEDULE
    from repro.distributed import sharding
    from repro.models.base import abstract_params
    from repro.models.transformer import cache_spec, forward

    if meta["kind"] == "train":
        from repro.training.optimizer import AdamWConfig, OptState
        from repro.training.train import make_train_step

        mb_rows = variant.get("mb_rows", 16)
        B = mb_rows * train_mb  # rows per microbatch x probe microbatches
        rules = sharding.rules_train(mesh, fsdp=variant.get("fsdp", True))
        p_ps = sharding.param_pspecs(cfg, mesh, rules)
        p_sh = jax.tree_util.tree_map(lambda p: NamedSharding(mesh, p), p_ps)
        params = abstract_params(cfg)
        F32 = jnp.float32
        mu = jax.tree_util.tree_map(lambda s: jax.ShapeDtypeStruct(s.shape, F32), params)
        opt = OptState(step=jax.ShapeDtypeStruct((), jnp.int32), mu=mu, nu=mu)
        opt_sh = OptState(step=NamedSharding(mesh, P()), mu=p_sh, nu=p_sh)
        batch = {
            "tokens": jax.ShapeDtypeStruct((B, meta["seq"]), jnp.int32),
            "targets": jax.ShapeDtypeStruct((B, meta["seq"]), jnp.int32),
            "loss_mask": jax.ShapeDtypeStruct((B, meta["seq"]), F32),
        }
        bsh = {k: NamedSharding(mesh, P("data")) for k in batch}
        if cfg.family == "encdec":
            batch["enc_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.encoder_seq_len, cfg.d_model), jnp.dtype(cfg.dtype))
            bsh["enc_embeds"] = NamedSharding(mesh, P("data"))
        fn = make_train_step(cfg, AdamWConfig(total_steps=100),
                             num_microbatches=train_mb, remat=True, unroll=True)
        m_sh = {k: NamedSharding(mesh, P()) for k in
                ("loss", "aux_loss", "dropped_frac", "tokens", "grad_norm", "lr")}
        args, in_sh, out_sh = (params, opt, batch), (p_sh, opt_sh, bsh), (p_sh, opt_sh, m_sh)
    else:
        rules = sharding.rules_serve(mesh, moe_ep=variant.get("moe_ep", "model"))
        p_sh = sharding.param_shardings(cfg, mesh, rules)
        params = abstract_params(cfg)
        B = meta["batch"]
        cap = S.decode_capacity(cfg, meta["seq"])
        cache = cache_spec(cfg, B, cap)
        c_sh = jax.tree_util.tree_map(
            lambda p: NamedSharding(mesh, p),
            sharding.cache_pspec_tree(
                cfg, mesh, B, cap,
                kv_policy=variant.get("kv_policy", "feature_first")))
        bspec = S._maybe_batch_spec(B, mesh)
        bsh = NamedSharding(mesh, bspec)
        if meta["kind"] == "verify":
            G, W = meta["batch"], meta["window"]
            from repro.serving.sampler import sample_window

            def fn(params, cache, inputs, cand, cand_len, start_pos,
                   seeds, temps, out_base):
                logits, new_cache, _ = forward(
                    params, cfg, inputs, cache=cache, start_pos=start_pos,
                    schedule=VERIFY_SCHEDULE, unroll=True,
                )
                v = sample_window(logits, seeds, out_base, temps)
                cmp = (v[:, : W - 1] == cand).astype(jnp.int32)
                valid = (jnp.arange(W - 1)[None] < cand_len[:, None]).astype(jnp.int32)
                n_match = jnp.sum(jnp.cumprod(cmp * valid, axis=1), axis=1)
                commit = jnp.take_along_axis(v, n_match[:, None], axis=1)[:, 0]
                return n_match, commit, new_cache

            i32 = jnp.int32
            args = (params, cache,
                    jax.ShapeDtypeStruct((G, W), i32),
                    jax.ShapeDtypeStruct((G, W - 1), i32),
                    jax.ShapeDtypeStruct((G,), i32),
                    jax.ShapeDtypeStruct((G,), i32),
                    jax.ShapeDtypeStruct((G,), i32),
                    jax.ShapeDtypeStruct((G,), jnp.float32),
                    jax.ShapeDtypeStruct((G,), i32))
            in_sh = (p_sh, c_sh) + (bsh,) * 7
            out_sh = (bsh, bsh, c_sh)
        elif meta["kind"] == "prefill":
            n_prefix = cfg.num_prefix_embeds
            S_tok = meta["seq"] - n_prefix

            def fn(params, cache, tokens, prefix, start_pos):
                if n_prefix:
                    te = jnp.take(params["embed"], tokens, axis=0)
                    embeds = jnp.concatenate([prefix, te], axis=1)
                    logits, nc, _ = forward(params, cfg, inputs_embeds=embeds,
                                            cache=cache, start_pos=start_pos,
                                            schedule=VERIFY_SCHEDULE, unroll=True)
                else:
                    logits, nc, _ = forward(params, cfg, tokens, cache=cache,
                                            start_pos=start_pos,
                                            schedule=VERIFY_SCHEDULE, unroll=True)
                return jnp.argmax(logits[:, -1], -1).astype(jnp.int32), nc

            args = (params, cache,
                    jax.ShapeDtypeStruct((B, S_tok), jnp.int32),
                    jax.ShapeDtypeStruct((B, n_prefix, cfg.d_model), jnp.dtype(cfg.dtype)),
                    jax.ShapeDtypeStruct((B,), jnp.int32))
            in_sh = (p_sh, c_sh, bsh, bsh, bsh)
            out_sh = (bsh, c_sh)
        elif meta["kind"] == "decode":
            def fn(params, cache, tokens, start_pos):
                logits, nc, _ = forward(params, cfg, tokens, cache=cache,
                                        start_pos=start_pos,
                                        schedule=VERIFY_SCHEDULE, unroll=True)
                return jnp.argmax(logits[:, -1], -1).astype(jnp.int32), nc

            args = (params, cache,
                    jax.ShapeDtypeStruct((B, 1), jnp.int32),
                    jax.ShapeDtypeStruct((B,), jnp.int32))
            in_sh = (p_sh, c_sh, bsh, bsh)
            out_sh = (bsh, c_sh)
        else:
            raise ValueError(meta["kind"])

    with mesh:
        compiled = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh).lower(*args).compile()
    cost = compiled.cost_analysis()
    coll = collective_bytes(compiled.as_text())
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll": float(coll["total"]),
    }


def probe_costs(arch: str, shape: str, mesh,
                variant: Optional[Dict[str, Any]] = None
                ) -> Optional[Dict[str, float]]:
    """Linear-solve per-block costs from 2- and 4-block unrolled probes,
    extrapolate to full depth (and to the full microbatch count for train)."""
    cfg_full, skip = S.resolve_config(arch, shape)
    if skip:
        return None
    meta = S.INPUT_SHAPES[shape]
    period = cfg_full.block_period()
    if (cfg_full.num_layers - cfg_full.first_k_dense) % period != 0:
        period = 1
    nb_full = (cfg_full.num_layers - cfg_full.first_k_dense) // period

    out = {}
    if meta["kind"] == "train":
        # Train steps have two cost components with different scaling:
        # per-microbatch fwd/bwd work (x num_microbatches) and per-step
        # optimizer/update work (x 1 — dominant in BYTES for big params).
        # Solve cost(nb, mb) = opt(nb) + mb*fwd(nb) from a 2x2 probe grid.
        c21 = _compile_probe(arch, shape, mesh, 2, train_mb=1, variant=variant)
        c41 = _compile_probe(arch, shape, mesh, 4, train_mb=1, variant=variant)
        c22 = _compile_probe(arch, shape, mesh, 2, train_mb=2, variant=variant)
        c42 = _compile_probe(arch, shape, mesh, 4, train_mb=2, variant=variant)
        num_mb = meta["batch"] / (variant or {}).get("mb_rows", 16)
        for key in ("flops", "bytes", "coll"):
            fwd2 = c22[key] - c21[key]
            fwd4 = c42[key] - c41[key]
            opt2 = 2 * c21[key] - c22[key]
            opt4 = 2 * c41[key] - c42[key]
            fwd_b = (fwd4 - fwd2) / 2.0
            fwd_a = fwd2 - 2.0 * fwd_b
            opt_b = (opt4 - opt2) / 2.0
            opt_a = opt2 - 2.0 * opt_b
            total = (opt_a + opt_b * nb_full) + num_mb * (
                fwd_a + fwd_b * nb_full)
            # linear extrapolation can go slightly negative on noisy small
            # probe terms; clamp (and the per-probe raw numbers are kept in
            # the record for audit)
            out[key] = max(total, 0.0)
        return out
    c2 = _compile_probe(arch, shape, mesh, 2, variant=variant)
    c4 = _compile_probe(arch, shape, mesh, 4, variant=variant)
    for key in ("flops", "bytes", "coll"):
        b = (c4[key] - c2[key]) / 2.0
        a = c2[key] - 2.0 * b
        out[key] = max(a + b * nb_full, 0.0)
    return out


# ---------------------------------------------------------------------------
# assembly
# ---------------------------------------------------------------------------


def analyze(arch: str, shape: str, mesh, dryrun_dir: str,
            variant: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
    cfg, skip = S.resolve_config(arch, shape)
    meta = S.INPUT_SHAPES[shape]
    rec: Dict[str, Any] = {"arch": arch, "shape": shape}
    if skip:
        rec["status"] = "skipped"
        rec["reason"] = skip
        return rec

    raw_path = os.path.join(dryrun_dir, f"{arch}_{shape}_pod_16x16.json")
    raw = None
    if os.path.exists(raw_path):
        with open(raw_path) as f:
            raw = json.load(f)

    train = meta["kind"] == "train"
    probed = probe_costs(arch, shape, mesh, variant)
    a_flops = analytic_flops(cfg, meta, train)
    mf = model_flops(cfg, meta, train)

    # attention q-chunk loops + recurrent scans sit inside probe bodies;
    # add the analytically-known undercounted remainder
    if meta["kind"] == "decode":
        tokens, ctx = meta["batch"], meta["seq"]
    elif meta["kind"] == "verify":
        tokens, ctx = meta["batch"] * meta["window"], meta["seq"]
    else:
        tokens, ctx = meta["batch"] * meta["seq"], meta["seq"] / 2
    mult = 3.0 if train else 1.0
    attn_total = mult * analytic_attn_flops(cfg, tokens, ctx)
    rec_total = mult * analytic_recurrent_flops(cfg, tokens)
    n_qchunks = max(tokens // meta["batch"] // 512, 1) if meta["kind"] != "decode" else 1
    seq_steps = meta["seq"] if meta["kind"] != "decode" else 1
    corr = attn_total * (1 - 1.0 / n_qchunks) + rec_total * (1 - 1.0 / seq_steps)
    corr /= CHIPS  # per-device share (assumes the loop body was well-sharded)
    hlo_flops = probed["flops"] + corr

    compute_s = hlo_flops / PEAK
    memory_s = probed["bytes"] / HBM
    collective_s = probed["coll"] / ICI
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dominant = max(terms, key=terms.get)

    suggestions = {
        "compute_s": "more chips or lower-precision matmuls; compute-bound is the healthy regime",
        "memory_s": "raise arithmetic intensity: bigger per-chip batch, fuse KV reads, quantize weights/KV to 8-bit",
        "collective_s": "reshard to cut resharding collectives (co-locate attention heads and KV), overlap collectives with compute, or move FSDP gathers off the critical path",
    }
    rec.update({
        "status": "ok",
        "chips": CHIPS,
        "hlo_flops": hlo_flops,
        "hlo_flops_raw": raw["cost"]["flops"] if raw else None,
        "hlo_bytes": probed["bytes"],
        "collective_bytes": probed["coll"],
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "dominant": dominant.replace("_s", ""),
        "model_flops": mf,
        "model_flops_per_device": mf / CHIPS,
        "useful_ratio": (mf / CHIPS) / max(hlo_flops, 1.0),
        "step_time_s": max(terms.values()),
        "memory_per_device": raw["memory"] if raw else None,
        "note": suggestions[dominant],
    })
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--dryrun-dir", default="experiments/dryrun")
    ap.add_argument("--out", default="experiments/roofline.json")
    args = ap.parse_args()

    mesh = make_production_mesh(multi_pod=False)
    archs = config_registry.list_archs() if args.arch == "all" else [args.arch]
    shapes = ([k for k, v in S.INPUT_SHAPES.items() if not v.get("extra")]
              if args.shape == "all" else [args.shape])

    results = []
    for arch in archs:
        for shape in shapes:
            r = analyze(arch, shape, mesh, args.dryrun_dir)
            results.append(r)
            if r["status"] == "ok":
                print(f"{arch:26s} {shape:12s} compute={r['compute_s']*1e3:9.3f}ms "
                      f"memory={r['memory_s']*1e3:9.3f}ms "
                      f"coll={r['collective_s']*1e3:9.3f}ms "
                      f"dom={r['dominant']:10s} useful={r['useful_ratio']:.2f}",
                      flush=True)
            else:
                print(f"{arch:26s} {shape:12s} SKIP ({r['reason'][:60]})", flush=True)
    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(results, f, indent=1, default=str)
    print(f"\nwrote {args.out}")


if __name__ == "__main__":
    main()
