"""Paged-KV memory subsystem ablation — shared-prefix traffic.

The dense cache manager binds one ``max_seq_len`` KV ring per slot:
concurrency is capped by the worst-case footprint, and an identical system
prompt is recomputed for every request.  The paged subsystem
(``serving.blockpool`` + ``serving.prefixcache``) allocates fixed-size
blocks on demand, shares committed-prefix blocks read-only across
requests, and preempts/restores LRU victims when an undersized pool runs
dry — restore is bitwise-identical by construction (it replays only
committed tokens).

This benchmark drives the REAL engine on a Poisson stream of requests that
share an S-token system prompt (distinct tails), advancing a simulated
TPU-v5e clock per event (``serving.online``), and reports:

  * TTFT p50/p99 and throughput for the dense-equivalent baseline
    (prefix cache off, dense-parity pool, dense-slot concurrency) vs the
    paged pool with the cache on at the SAME KV HBM budget but a larger
    admission window — the "production-shaped" configuration;
  * the cache's isolated TTFT cut (paged cache-on vs cache-off at equal
    config) and its hit rate;
  * max sustained concurrency (peak co-resident requests) at equal HBM —
    paged must be strictly higher than the dense pool;
  * a pool-size sweep (1x / 0.5x dense parity) showing the preemption
    lane absorbing pressure: undersized pools preempt + restore instead
    of rejecting, and committed streams stay bitwise identical.

Every configuration asserts the tentpole invariant: deterministic
requests commit bitwise-identical streams under cache on/off, pool sizes,
and forced preemption/restore traffic.
"""

from __future__ import annotations

import argparse

from repro.core.determinism import Mode
from repro.serving import costmodel
from repro.serving.engine import Engine
from repro.serving.online import percentile, run_online
from repro.training.data import poisson_arrivals
from benchmarks.common import (
    BENCH_POLICY, bench_model, emit, full_config, make_requests,
)

BLOCK = 16
CAPACITY = 256
DENSE_SLOTS = 4


def _requests(cfg, n: int, sys_len: int, tail_len: int, max_new: int,
              seed: int):
    reqs = make_requests(
        cfg, n, det_ratio=0.5, max_new=max_new, seed=seed,
        in_lens=[sys_len + tail_len] * n,
    )
    sys_prompt = [(7 * j + 3) % cfg.vocab_size for j in range(sys_len)]
    for r in reqs:  # shared system prompt, unique tail
        r.prompt = sys_prompt + r.prompt[sys_len:]
    return reqs


def _run(cfg, params, fcfg, n, qps, *, sys_len, tail_len, max_new,
         max_batch, num_blocks, prefix_cache, seed=0):
    engine = Engine(
        cfg, params, mode=Mode.LLM42, policy=BENCH_POLICY, window=8, group=4,
        max_batch=max_batch, capacity=CAPACITY, prefill_chunk=BLOCK,
        block_size=BLOCK, num_blocks=num_blocks, prefix_cache=prefix_cache,
    )
    reqs = _requests(cfg, n, sys_len, tail_len, max_new, seed)
    arrivals = poisson_arrivals(n, qps, seed=seed)
    res = run_online(engine, fcfg, list(zip(reqs, arrivals)))
    tt = list(res.ttfts.values())
    snap = res.metrics  # registry snapshot (mem_stats is a shim over it)
    return {
        "ttft_p50": percentile(tt, 50),
        "ttft_p99": percentile(tt, 99),
        "tput": res.out_tokens / max(res.total_time, 1e-12),
        "peak_running": snap["engine.peak_running"],
        "hit_tokens": snap.get("prefixcache.hit_tokens", 0),
        "preemptions": snap["mem.preemptions"],
        "restores": snap["mem.restores"],
        "streams": {
            r.rid: list(r.committed)
            for r in engine.finished if r.sampling.is_deterministic
        },
    }


def run(n: int = 24, qps: float = 60.0, sys_len: int = 96, tail_len: int = 8,
        max_new: int = 24):
    cfg, params = bench_model()
    fcfg = full_config()
    rows = []
    parity_blocks = DENSE_SLOTS * (CAPACITY // BLOCK)  # dense-pool HBM
    hbm_gb = costmodel.pool_hbm_bytes(
        fcfg, parity_blocks, DENSE_SLOTS, BLOCK) / 1e9
    rows.append(("fig_cache_hbm_budget_gb", "", round(hbm_gb, 3)))

    common = dict(sys_len=sys_len, tail_len=tail_len, max_new=max_new)

    # dense-equivalent baseline: per-slot reservation semantics — slot
    # count bounded by worst-case footprint, no sharing
    dense = _run(cfg, params, fcfg, n, qps, max_batch=DENSE_SLOTS,
                 num_blocks=parity_blocks, prefix_cache=False, **common)
    rows.append(("fig_cache_dense_ttft_p50_ms", "",
                 round(dense["ttft_p50"] * 1e3, 2)))
    rows.append(("fig_cache_dense_ttft_p99_ms", "",
                 round(dense["ttft_p99"] * 1e3, 2)))
    rows.append(("fig_cache_dense_tput", "", round(dense["tput"], 1)))
    rows.append(("fig_cache_dense_peak_concurrency", "",
                 dense["peak_running"]))

    # paged pool at the SAME HBM budget: blocks allocated on demand, the
    # admission window opens up to 4x the dense slot count
    for label, prefix_cache in (("nocache", False), ("cache", True)):
        r = _run(cfg, params, fcfg, n, qps, max_batch=4 * DENSE_SLOTS,
                 num_blocks=parity_blocks, prefix_cache=prefix_cache,
                 **common)
        assert r["streams"] == dense["streams"], (
            f"paged pool ({label}) moved a deterministic stream"
        )
        rows.append((f"fig_cache_paged_{label}_ttft_p50_ms", "",
                     round(r["ttft_p50"] * 1e3, 2)))
        rows.append((f"fig_cache_paged_{label}_ttft_p99_ms", "",
                     round(r["ttft_p99"] * 1e3, 2)))
        rows.append((f"fig_cache_paged_{label}_tput", "",
                     round(r["tput"], 1)))
        rows.append((f"fig_cache_paged_{label}_peak_concurrency", "",
                     r["peak_running"]))
        if prefix_cache:
            rows.append(("fig_cache_hit_tokens", "", r["hit_tokens"]))
            rows.append(("fig_cache_ttft_p50_vs_dense", "",
                         round(r["ttft_p50"] / max(dense["ttft_p50"], 1e-12),
                               3)))
            # acceptance criteria: TTFT cut on shared-prefix traffic +
            # strictly higher sustained concurrency at equal HBM
            assert r["hit_tokens"] > 0, "shared prefixes never hit the cache"
            assert r["ttft_p50"] < dense["ttft_p50"], (
                "paged+cache did not cut TTFT on shared-prefix traffic"
            )
            assert r["peak_running"] > dense["peak_running"], (
                "paged pool did not sustain more concurrency at equal HBM"
            )

    # pool-size sweep: an undersized pool absorbs pressure through the
    # preemption lane instead of rejecting — and never moves a token
    for frac_name, blocks in (("half", parity_blocks // 2),):
        r = _run(cfg, params, fcfg, n, qps, max_batch=4 * DENSE_SLOTS,
                 num_blocks=blocks, prefix_cache=True, **common)
        assert r["streams"] == dense["streams"], (
            "memory pressure moved a deterministic stream"
        )
        rows.append((f"fig_cache_pool_{frac_name}_ttft_p99_ms", "",
                     round(r["ttft_p99"] * 1e3, 2)))
        rows.append((f"fig_cache_pool_{frac_name}_tput", "",
                     round(r["tput"], 1)))
        rows.append((f"fig_cache_pool_{frac_name}_preemptions", "",
                     r["preemptions"]))
        rows.append((f"fig_cache_pool_{frac_name}_restores", "",
                     r["restores"]))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced workload for CI (fewer, shorter requests)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write the rows as JSON (CI artifact)")
    args = ap.parse_args()
    if args.smoke:
        rows = run(n=10, qps=60.0, sys_len=64, tail_len=6, max_new=12)
    else:
        rows = run()
    emit(rows, "name,us_per_call,derived", json_path=args.json)


if __name__ == "__main__":
    main()
