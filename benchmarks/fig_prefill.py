"""Chunked-prefill ablation — TTFT under long-prompt co-residency.

Paper §5.2 limitation (2): prefill is a per-request exclusive pass, so one
long prompt stalls the entire decode batch (and every in-flight verify
group) for its whole prefill.  The chunked-prefill lane
(``Engine(prefill_chunk=C)``) slices a prompt into fixed-shape C-token
chunks that ``OverlapPolicy`` co-schedules with each iteration's decode
batch and verify launch — the cost scales with the long-prompt traffic that
needs it, not with the worst case.

This benchmark drives the REAL engine (real schedules, real rollbacks) on a
Poisson arrival stream mixing short-prompt decode traffic with long
(>= 256-token) prompts, advancing a simulated TPU-v5e clock per event
(``serving.online``).  Reported per configuration:

  * TTFT p50/p99 of the *short-prompt* (decode) traffic — the requests an
    exclusive prefill stalls;
  * total simulated throughput — chunking is not free (each chunk streams
    the weights, and overlapped iterations pay the modeled contention
    term), so the ablation reports what the TTFT win costs.

Every chunked run also asserts the tentpole invariant: deterministic
requests commit bitwise-identical streams under every chunk size, including
the exclusive (chunk = 0) baseline.
"""

from __future__ import annotations

import argparse

from repro.core.determinism import Mode
from repro.serving.engine import Engine
from repro.serving.online import percentile, run_online
from repro.serving.request import Request
from repro.training.data import poisson_arrivals
from benchmarks.common import (
    BENCH_POLICY, bench_model, emit, full_config, make_requests,
)

#: every LONG_EVERY-th arrival is a long prompt
LONG_EVERY = 4
SHORT_LEN = 12


def _requests(cfg, n: int, long_len: int, max_new: int, seed: int) -> list:
    in_lens = [
        long_len if i % LONG_EVERY == 0 else SHORT_LEN for i in range(n)
    ]
    return make_requests(
        cfg, n, det_ratio=0.25, max_new=max_new, seed=seed, in_lens=in_lens
    )


def _run(cfg, params, fcfg, n, qps, *, prefill_chunk, long_len, max_new=24,
         seed=0):
    engine = Engine(
        cfg, params, mode=Mode.LLM42, policy=BENCH_POLICY, window=8, group=4,
        max_batch=8, capacity=2 * long_len + 2 * max_new + 64,
        prefill_chunk=prefill_chunk,
    )
    reqs = _requests(cfg, n, long_len, max_new, seed)
    arrivals = poisson_arrivals(n, qps, seed=seed)
    res = run_online(engine, fcfg, list(zip(reqs, arrivals)))
    short: list[Request] = [
        r for r in engine.finished if r.prompt_len <= SHORT_LEN
    ]
    tt = [res.ttfts[r.rid] for r in short]
    return {
        "ttft_p50": percentile(tt, 50),
        "ttft_p99": percentile(tt, 99),
        "tput": res.out_tokens / max(res.total_time, 1e-12),
        "streams": {
            r.rid: list(r.committed)
            for r in engine.finished if r.sampling.is_deterministic
        },
    }


def run(n: int = 16, qps: float = 30.0, long_len: int = 1024):
    cfg, params = bench_model()
    fcfg = full_config()
    rows = []

    base = _run(cfg, params, fcfg, n, qps, prefill_chunk=0, long_len=long_len)
    rows.append(("fig_prefill_exclusive_ttft_p50_ms", "",
                 round(base["ttft_p50"] * 1e3, 2)))
    rows.append(("fig_prefill_exclusive_ttft_p99_ms", "",
                 round(base["ttft_p99"] * 1e3, 2)))
    rows.append(("fig_prefill_exclusive_tput", "", round(base["tput"], 1)))

    for chunk in (long_len // 8, long_len // 4):
        r = _run(cfg, params, fcfg, n, qps, prefill_chunk=chunk,
                 long_len=long_len)
        # tentpole invariant: chunking never moves a committed token
        assert r["streams"] == base["streams"], (
            f"chunked prefill (C={chunk}) changed a deterministic stream"
        )
        rows.append((f"fig_prefill_chunk{chunk}_ttft_p50_ms", "",
                     round(r["ttft_p50"] * 1e3, 2)))
        rows.append((f"fig_prefill_chunk{chunk}_ttft_p99_ms", "",
                     round(r["ttft_p99"] * 1e3, 2)))
        rows.append((f"fig_prefill_chunk{chunk}_tput", "",
                     round(r["tput"], 1)))
        rows.append((f"fig_prefill_chunk{chunk}_ttft_p99_ratio", "",
                     round(r["ttft_p99"] / max(base["ttft_p99"], 1e-12), 3)))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced workload for CI (shorter prompts, fewer"
                         " requests)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write the rows as JSON (CI artifact)")
    args = ap.parse_args()
    if args.smoke:
        rows = run(n=8, qps=30.0, long_len=256)
    else:
        rows = run()
    emit(rows, "name,us_per_call,derived", json_path=args.json)


if __name__ == "__main__":
    main()
