"""Scheduler ablation — pause-decode vs overlapped vs adaptive verification.

The paper's prototype pauses ALL decoding during a verification pass (§5.2
limitation (1)); the scheduler subsystem's ``OverlapPolicy`` co-schedules
the verify group with the same iteration's decode batch instead, and lets
submitted requests keep speculating past their in-flight window.  This
benchmark runs the SAME mixed deterministic/non-deterministic workloads
under both policies (real engine schedules, real rollbacks) and replays the
event logs through the TPU-v5e cost model, which charges an overlapped
iteration max(decode, verify) plus a contention term rather than their sum.

Scenarios (all 50/50 det/non-det request mixes):
  * ``50pct``          — equal output lengths, reorder-only drift (the
                         paper's production regime: flips are rare, spans
                         long).  Overlap wins on two fronts: verify passes
                         stop costing exclusive iterations, and surviving
                         past-window speculation shortens det window cycles.
  * ``50pct_longtail`` — deterministic requests short (eval-style traffic),
                         non-deterministic bulk long (chat-style): every
                         pause now stalls the critical path, widening the
                         gap.
  * ``50pct_stress``   — the aggressive bf16-combine drift policy used by
                         the other figures to make rollbacks visible at toy
                         scale.  Near-constant rollback kills speculation,
                         so overlap's win shrinks toward (and can dip
                         below) parity — the contention term with nothing
                         hidden behind it.  This is the regime
                         ``AdaptivePolicy`` exists for: it watches each
                         request's acceptance EMA, demotes high-flip
                         requests to pause-style sync verification with
                         acceptance-scaled eager windows, and promotes
                         them back when the traffic recovers — closing the
                         stress gap (ratio >= 1.0 vs pause) while running
                         OverlapPolicy verbatim (100% of its win) on the
                         low-rollback scenarios.

Every scenario also asserts the tentpole invariant: all three policies
commit bitwise-identical streams.
"""

from __future__ import annotations

import argparse
import json
import os

from repro.core.determinism import Mode, REORDER_ONLY_POLICY
from repro.serving.costmodel import flatten_events
from repro.serving.scheduler import (
    AdaptivePolicy, OverlapPolicy, PauseDecodePolicy,
)
from benchmarks.common import (
    BENCH_POLICY, bench_model, emit, full_config, make_requests,
    run_scenario, simulated_throughput,
)


def _count(events, kind):
    return sum(1 for e in flatten_events(events) if e["kind"] == kind)


def _mixed_requests(cfg, n, max_new, out_lens=None):
    reqs = make_requests(cfg, n, 0.0, max_new, seed=3, out_lens=out_lens)
    for i, r in enumerate(reqs):
        r.sampling.is_deterministic = i % 2 == 0  # exact 50/50 mix
    return reqs


def write_trace(path: str, n: int = 6) -> None:
    """Run one traced overlap scenario and export its Chrome/Perfetto
    trace-event JSON (schema-validated) — the CI bench artifact that lets
    anyone load a real mixed-batch schedule into ui.perfetto.dev."""
    from repro.obs import validate_chrome_trace

    cfg, params = bench_model()
    reqs = _mixed_requests(cfg, n, 24)
    r = run_scenario(cfg, params, reqs, mode=Mode.LLM42, window=8, group=4,
                     scheduler=OverlapPolicy(), policy=REORDER_ONLY_POLICY,
                     trace=True)
    trace = r["engine"].obs.tracer.to_chrome_trace()
    errors = validate_chrome_trace(trace)
    assert not errors, f"trace failed schema validation: {errors[:5]}"
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    with open(path, "w") as f:
        json.dump(trace, f)
    print(f"# wrote {path} ({len(trace['traceEvents'])} trace events)")


def run(n: int = 8):
    cfg, params = bench_model()
    fcfg = full_config()
    rows = []

    long_tail = [24 if i % 2 == 0 else 48 for i in range(n)]
    scenarios = [
        ("50pct", REORDER_ONLY_POLICY, 32, None),
        ("50pct_longtail", REORDER_ONLY_POLICY, 48, long_tail),
        ("50pct_stress", BENCH_POLICY, 32, None),
    ]
    for tag, drift, max_new, out_lens in scenarios:
        results = {}
        for policy in (PauseDecodePolicy(), OverlapPolicy(), AdaptivePolicy()):
            reqs = _mixed_requests(cfg, n, max_new, out_lens)
            r = run_scenario(cfg, params, reqs, mode=Mode.LLM42, window=8,
                             group=4, scheduler=policy, policy=drift)
            results[policy.name] = r
            tput = simulated_throughput(fcfg, r)
            rows.append((
                f"fig_overlap_{tag}_{policy.name}_tput",
                round(r["wall_s"] * 1e6 / max(r["out_tokens"], 1), 1),
                round(tput, 1),
            ))
            rows.append((f"fig_overlap_{tag}_{policy.name}_verify_passes", "",
                         _count(r["events"], "verify")))

        # determinism invariant: policies must agree bitwise on every
        # DETERMINISTIC request (non-deterministic fast-path outputs are
        # allowed to drift with batch composition — that is the paper's
        # selective-determinism contract, not a bug)
        pause_out = {
            q.rid: q.committed for q in results["pause_decode"]["done"]
            if q.sampling.is_deterministic
        }
        for name in ("overlap", "adaptive"):
            out = {
                q.rid: q.committed for q in results[name]["done"]
                if q.sampling.is_deterministic
            }
            assert pause_out == out, (
                f"{name} disagrees with pause_decode on committed streams"
            )

        t_pause = simulated_throughput(fcfg, results["pause_decode"])
        t_over = simulated_throughput(fcfg, results["overlap"])
        t_adapt = simulated_throughput(fcfg, results["adaptive"])
        rows.append((f"fig_overlap_{tag}_ratio", "",
                     round(t_over / max(t_pause, 1e-9), 3)))
        rows.append((f"fig_overlap_{tag}_adaptive_ratio", "",
                     round(t_adapt / max(t_pause, 1e-9), 3)))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced workload for CI")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write the rows as JSON (CI artifact)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="also export a Chrome/Perfetto trace of one traced"
                         " overlap scenario (CI artifact)")
    args = ap.parse_args()
    rows = run(n=6) if args.smoke else run()
    emit(rows, "name,us_per_call,derived", json_path=args.json)
    if args.trace_out:
        write_trace(args.trace_out, n=6 if args.smoke else 8)


if __name__ == "__main__":
    main()
