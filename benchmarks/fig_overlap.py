"""Scheduler ablation — pause-decode vs overlapped verification.

The paper's prototype pauses ALL decoding during a verification pass (§5.2
limitation (1)); the scheduler subsystem's ``OverlapPolicy`` co-schedules
the verify group with the same iteration's decode batch instead, and lets
submitted requests keep speculating past their in-flight window.  This
benchmark runs the SAME mixed deterministic/non-deterministic workloads
under both policies (real engine schedules, real rollbacks) and replays the
event logs through the TPU-v5e cost model, which charges an overlapped
iteration max(decode, verify) plus a contention term rather than their sum.

Scenarios (all 50/50 det/non-det request mixes):
  * ``50pct``          — equal output lengths, reorder-only drift (the
                         paper's production regime: flips are rare, spans
                         long).  Overlap wins on two fronts: verify passes
                         stop costing exclusive iterations, and surviving
                         past-window speculation shortens det window cycles.
  * ``50pct_longtail`` — deterministic requests short (eval-style traffic),
                         non-deterministic bulk long (chat-style): every
                         pause now stalls the critical path, widening the
                         gap.
  * ``50pct_stress``   — the aggressive bf16-combine drift policy used by
                         the other figures to make rollbacks visible at toy
                         scale.  Near-constant rollback kills speculation,
                         so overlap's win shrinks toward (and can dip
                         slightly below) parity — the contention term with
                         nothing hidden behind it.  Reported for honesty;
                         the paper's measured flip rates are the first
                         regime, not this one.

Every scenario also asserts the tentpole invariant: both policies commit
bitwise-identical streams.
"""

from __future__ import annotations

from repro.core.determinism import Mode, REORDER_ONLY_POLICY
from repro.serving.costmodel import flatten_events
from repro.serving.scheduler import OverlapPolicy, PauseDecodePolicy
from benchmarks.common import (
    BENCH_POLICY, bench_model, full_config, make_requests, run_scenario,
    simulated_throughput,
)


def _count(events, kind):
    return sum(1 for e in flatten_events(events) if e["kind"] == kind)


def _mixed_requests(cfg, n, max_new, out_lens=None):
    reqs = make_requests(cfg, n, 0.0, max_new, seed=3, out_lens=out_lens)
    for i, r in enumerate(reqs):
        r.sampling.is_deterministic = i % 2 == 0  # exact 50/50 mix
    return reqs


def run(n: int = 8):
    cfg, params = bench_model()
    fcfg = full_config()
    rows = []

    long_tail = [24 if i % 2 == 0 else 48 for i in range(n)]
    scenarios = [
        ("50pct", REORDER_ONLY_POLICY, 32, None),
        ("50pct_longtail", REORDER_ONLY_POLICY, 48, long_tail),
        ("50pct_stress", BENCH_POLICY, 32, None),
    ]
    for tag, drift, max_new, out_lens in scenarios:
        results = {}
        for policy in (PauseDecodePolicy(), OverlapPolicy()):
            reqs = _mixed_requests(cfg, n, max_new, out_lens)
            r = run_scenario(cfg, params, reqs, mode=Mode.LLM42, window=8,
                             group=4, scheduler=policy, policy=drift)
            results[policy.name] = r
            tput = simulated_throughput(fcfg, r)
            rows.append((
                f"fig_overlap_{tag}_{policy.name}_tput",
                round(r["wall_s"] * 1e6 / max(r["out_tokens"], 1), 1),
                round(tput, 1),
            ))
            rows.append((f"fig_overlap_{tag}_{policy.name}_verify_passes", "",
                         _count(r["events"], "verify")))

        # determinism invariant: the policies must agree bitwise per request
        pause_out = {q.rid: q.committed for q in results["pause_decode"]["done"]}
        over_out = {q.rid: q.committed for q in results["overlap"]["done"]}
        assert pause_out == over_out, "policies disagree on committed streams"

        t_pause = simulated_throughput(fcfg, results["pause_decode"])
        t_over = simulated_throughput(fcfg, results["overlap"])
        rows.append((f"fig_overlap_{tag}_ratio", "",
                     round(t_over / max(t_pause, 1e-9), 3)))
    return rows
