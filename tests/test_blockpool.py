"""Paged-KV unit tests: block allocator, layout classification, device
gather/scatter through block tables, block wipe semantics, and the
commit-aware radix prefix cache (match / insert / leaf-first LRU
eviction)."""

import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_smoke_config
from repro.serving import blockpool
from repro.serving.blockpool import BlockAllocator
from repro.serving.prefixcache import PrefixCache


# ----------------------------------------------------------------------
# allocator
# ----------------------------------------------------------------------


class TestBlockAllocator:
    def test_alloc_free_roundtrip(self):
        a = BlockAllocator(4)
        bids = [a.alloc() for _ in range(4)]
        assert sorted(bids) == [0, 1, 2, 3]
        assert a.alloc() is None and a.num_free() == 0
        for b in bids:
            assert a.decref(b) == 0
            a.release(b)
        assert a.num_free() == 4

    def test_refcount_shares(self):
        a = BlockAllocator(2)
        b = a.alloc()
        a.incref(b)  # second request maps the same block
        assert a.decref(b) == 1  # first releases: still referenced
        assert a.decref(b) == 0
        with pytest.raises(AssertionError):
            a.decref(b)  # double free

    def test_cached_blocks_are_not_free_but_evictable(self):
        a = BlockAllocator(2)
        b = a.alloc()
        a.cached.add(b)
        a.decref(b)
        assert a.num_free() == 1  # the OTHER block
        assert a.num_evictable() == 1
        assert a.available() == 2

    def test_peak_accounting(self):
        a = BlockAllocator(8)
        got = [a.alloc() for _ in range(5)]
        for b in got[:3]:
            a.decref(b)
            a.release(b)
        assert a.peak_in_use == 5
        assert a.in_use() == 2


# ----------------------------------------------------------------------
# layout classification + device ops
# ----------------------------------------------------------------------


class TestLayout:
    def test_full_attention_is_paged(self):
        cfg = get_smoke_config("llama3-8b")
        lay = blockpool.build_layout(cfg, 128, 16, 32)
        kinds = {d.kind for d in jax.tree_util.tree_leaves(lay.axes)}
        assert kinds == {"paged"}  # pure full attention: everything paged
        assert lay.has_paged and lay.blocks_per_table == 8
        assert lay.null_bid == 32 and lay.scratch_bid == 33

    def test_recurrent_leaves_stay_slot(self):
        cfg = get_smoke_config("rwkv6-3b")
        lay = blockpool.build_layout(cfg, 128, 16, 32)
        kinds = {d.kind for d in jax.tree_util.tree_leaves(lay.axes)}
        assert kinds == {"slot"}  # O(1) state: nothing to page
        assert not lay.has_paged

    def test_hybrid_splits_by_leaf(self):
        cfg = get_smoke_config("jamba-1.5-large-398b")
        lay = blockpool.build_layout(cfg, 128, 16, 32)
        kinds = {d.kind for d in jax.tree_util.tree_leaves(lay.axes)}
        assert kinds == {"slot", "paged"}  # attn KV paged, mamba state slot

    def test_sliding_rings_stay_slot(self):
        cfg = dataclasses.replace(
            get_smoke_config("phi3-mini-3.8b"), attn_kind="sliding", window=8
        )
        lay = blockpool.build_layout(cfg, 10_000, 16, 32)
        kinds = {d.kind for d in jax.tree_util.tree_leaves(lay.axes)}
        assert kinds == {"slot"}  # bounded ring buffers: paging buys nothing

    def test_gather_scatter_roundtrip_and_null_isolation(self):
        cfg = get_smoke_config("llama3-8b")
        lay = blockpool.build_layout(cfg, 64, 16, 8)
        pool = blockpool.init_cache(cfg, lay, num_slots=2)
        slots = jnp.array([0], jnp.int32)
        tables = jnp.array([[3, 5, -1, -1]], jnp.int32)
        view = blockpool.gather(pool, lay, slots, tables)
        # a pos leaf view: allocated region gathers the (wiped) blocks,
        # the -1 tail gathers the frozen null block — everything masked
        pos_leaves = [
            leaf for leaf, d in zip(
                jax.tree_util.tree_leaves(view),
                jax.tree_util.tree_leaves(lay.axes),
            ) if d.kind == "paged" and leaf.dtype == jnp.int32
        ]
        assert pos_leaves and all(bool((p == -1).all()) for p in pos_leaves)
        # writes into the view land in the right blocks; pad-region writes
        # are absorbed by the scratch block, never the null block
        view2 = jax.tree_util.tree_map(
            lambda a: a.at[...].set(7) if a.dtype == jnp.int32 else a, view
        )
        pool2 = blockpool.scatter(pool, lay, slots, tables, view2)

        def check(leaf, desc):
            if desc.kind != "paged" or leaf.dtype != jnp.int32:
                return
            ax = desc.axis
            take = lambda b: jnp.take(leaf, jnp.array([b]), axis=ax)  # noqa: E731
            assert bool((take(3) == 7).all()) and bool((take(5) == 7).all())
            assert bool((take(lay.null_bid) == -1).all()), "null block written!"
            assert bool((take(lay.scratch_bid) == 7).all())  # absorbed pads
            assert bool((take(0) == -1).all())  # unrelated block untouched

        jax.tree_util.tree_map(check, pool2, lay.axes)

    def test_wipe_blocks_resets_pos_only(self):
        cfg = get_smoke_config("llama3-8b")
        lay = blockpool.build_layout(cfg, 64, 16, 8)
        pool = blockpool.init_cache(cfg, lay, num_slots=1)
        slots = jnp.array([0], jnp.int32)
        tables = jnp.array([[2, -1, -1, -1]], jnp.int32)
        view = blockpool.gather(pool, lay, slots, tables)
        view = jax.tree_util.tree_map(
            lambda a: a.at[...].set(9) if a.dtype == jnp.int32 else a, view
        )
        pool = blockpool.scatter(pool, lay, slots, tables, view)
        pool = blockpool.wipe_blocks(pool, lay, [2])

        def check(leaf, desc):
            if desc.kind == "paged" and leaf.dtype == jnp.int32:
                sub = jnp.take(leaf, jnp.array([2]), axis=desc.axis)
                assert bool((sub == -1).all())

        jax.tree_util.tree_map(check, pool, lay.axes)


# ----------------------------------------------------------------------
# radix prefix cache
# ----------------------------------------------------------------------


def _toks(n, off=0):
    return [(off + i) % 97 for i in range(n)]


class TestPrefixCache:
    def test_match_whole_blocks_only(self):
        a = BlockAllocator(8)
        c = PrefixCache(block_size=4)
        bids = [a.alloc() for _ in range(3)]
        c.insert(_toks(12), bids, now=1, allocator=a)
        assert c.match(_toks(12), now=2) == bids
        assert c.match(_toks(10), now=2) == bids[:2]  # partial tail block
        assert c.match(_toks(3), now=2) == []  # shorter than one block
        assert c.match(_toks(12, off=1), now=2) == []  # different stream

    def test_insert_is_idempotent_and_keeps_first_owner(self):
        a = BlockAllocator(8)
        c = PrefixCache(block_size=4)
        first = [a.alloc() for _ in range(2)]
        c.insert(_toks(8), first, now=1, allocator=a)
        dup = [a.alloc() for _ in range(2)]
        adopted = c.insert(_toks(8), dup, now=2, allocator=a)
        assert adopted == 0  # the duplicate stays request-owned
        assert c.match(_toks(8), now=3) == first
        assert set(first) <= a.cached and not (set(dup) & a.cached)

    def test_eviction_is_leaf_first_lru(self):
        a = BlockAllocator(8)
        c = PrefixCache(block_size=4)
        bids = [a.alloc() for _ in range(3)]
        c.insert(_toks(12), bids, now=1, allocator=a)
        for b in bids:
            a.decref(b)  # owner retired: zero-ref, cache-resident
        # deepest (least-recently *inserted*) leaf goes first, and an
        # interior node is never evicted before its children
        assert c.evict_lru(a) == bids[2]
        assert c.evict_lru(a) == bids[1]
        assert c.evict_lru(a) == bids[0]
        assert c.evict_lru(a) is None
        assert c.size == 0 and c.evictions == 3

    def test_eviction_skips_referenced_blocks(self):
        a = BlockAllocator(8)
        c = PrefixCache(block_size=4)
        bids = [a.alloc() for _ in range(2)]
        c.insert(_toks(8), bids, now=1, allocator=a)
        a.decref(bids[0])
        a.decref(bids[1])
        a.incref(bids[1])  # a running request maps the deep block
        assert c.evict_lru(a) is None  # leaf busy, parent not a leaf
        a.decref(bids[1])
        assert c.evict_lru(a) == bids[1]

    def test_lru_order_follows_use(self):
        a = BlockAllocator(8)
        c = PrefixCache(block_size=2)
        x = [a.alloc()]
        y = [a.alloc()]
        c.insert([1, 2], x, now=1, allocator=a)
        c.insert([3, 4], y, now=2, allocator=a)
        c.match([1, 2], now=5)  # bump x
        a.decref(x[0])
        a.decref(y[0])
        assert c.evict_lru(a) == y[0]  # y is now least recently used
