"""Paged-attention kernel + fused mixed-batch engine step.

Two layers of contract:

* kernel — ``kernels.paged_attention`` must match the pure-jnp oracle
  (``kernels/ref.py``) bitwise in interpret mode on randomized block
  tables, including ``-1`` (null-block) entries, and must be bitwise
  repeatable across invocations; the ``# det: fastpath`` split variant
  must match the oracle at the same split/combine configuration.
* engine — with ``paged_attention=True`` the engine runs the in-place
  paged forward and ONE fused mixed-batch launch per iteration; committed
  streams of deterministic requests must be bitwise identical to the
  legacy gather/scatter path across block sizes, schedulers and
  speculation depths, and the fused composite events must carry the
  structure the cost model prices (lead pays the weight stream, followers
  are marked ``fused``).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.determinism import Mode, ReductionPolicy
from repro.kernels import paged_attention as pk
from repro.kernels import ref
from repro.models import init_params
from repro.serving.engine import Engine
from repro.serving.request import Request, SamplingParams
from repro.serving.scheduler import (
    AdaptivePolicy,
    OverlapPolicy,
    PauseDecodePolicy,
)

DRIFTY = ReductionPolicy(
    thresholds=((2, 16), (4, 8), (16, 4)), combine_dtype="bfloat16"
)

_MODELS = {}


def _model(arch="llama3-8b"):
    if arch not in _MODELS:
        cfg = get_smoke_config(arch)
        _MODELS[arch] = (cfg, init_params(cfg, jax.random.key(0)))
    return _MODELS[arch]


# ----------------------------------------------------------------------
# kernel vs oracle
# ----------------------------------------------------------------------


def _rand_problem(seed, *, B=3, H=4, KV=2, D=8, NB=20, bs=4, nblk=5,
                  dtype=jnp.float32):
    """Random pool + tables; the last two pool blocks are null/scratch."""
    rng = np.random.default_rng(seed)
    null_bid, scratch_bid = NB - 2, NB - 1
    q = jnp.asarray(rng.standard_normal((B, H, D)), dtype)
    k = jnp.asarray(rng.standard_normal((NB, bs, KV, D)), dtype)
    v = jnp.asarray(rng.standard_normal((NB, bs, KV, D)), dtype)
    # null block: positions -1 (always masked), zero K/V
    k = k.at[null_bid].set(0.0)
    v = v.at[null_bid].set(0.0)

    pos = np.full((NB, bs), -1, np.int32)
    tables = np.full((B, nblk), -1, np.int32)
    real = list(rng.permutation(null_bid))  # distinct real block ids
    q_pos = np.zeros((B,), np.int32)
    for b in range(B):
        n_alloc = int(rng.integers(1, nblk + 1))  # rest stay -1 (null reads)
        length = int(rng.integers((n_alloc - 1) * bs + 1, n_alloc * bs + 1))
        for j in range(n_alloc):
            bid = real.pop()
            tables[b, j] = bid
            fill = min(bs, length - j * bs)
            pos[bid, :fill] = np.arange(j * bs, j * bs + fill)
        q_pos[b] = length - 1
    return (q, k, v, jnp.asarray(pos), jnp.asarray(tables),
            jnp.asarray(q_pos), null_bid)


class TestPagedKernel:
    @pytest.mark.parametrize("seed", [0, 1, 2])
    def test_commit_kernel_matches_oracle_bitwise(self, seed):
        q, k, v, pos, tab, qp, null_bid = _rand_problem(seed)
        got = pk.paged_attention(q, k, v, pos, tab, qp, null_bid=null_bid)
        want = ref.paged_attention(q, k, v, pos, tab, qp, null_bid=null_bid)
        assert jnp.array_equal(got, want), f"seed={seed}"

    def test_null_block_reads_are_masked(self):
        """Rows whose tables are mostly -1 read the null block; those
        positions are -1 and must contribute exactly nothing."""
        q, k, v, pos, tab, qp, null_bid = _rand_problem(7, nblk=6)
        got = pk.paged_attention(q, k, v, pos, tab, qp, null_bid=null_bid)
        # poison the null block's K/V: masked reads must not see it
        k2 = k.at[null_bid].set(1e4)
        v2 = v.at[null_bid].set(1e4)
        got2 = pk.paged_attention(q, k2, v2, pos, tab, qp, null_bid=null_bid)
        assert jnp.array_equal(got, got2)
        assert bool(jnp.all(jnp.isfinite(got)))

    def test_commit_kernel_bitwise_repeatable(self):
        q, k, v, pos, tab, qp, null_bid = _rand_problem(3)
        a = pk.paged_attention(q, k, v, pos, tab, qp, null_bid=null_bid)
        b = pk.paged_attention(q, k, v, pos, tab, qp, null_bid=null_bid)
        assert jnp.array_equal(a, b)

    @pytest.mark.parametrize("splits,cd,tol", [
        # f32 combine: kernel and oracle run the same tree tightly; bf16
        # combine rounds at different points (scratch stays f32 on-chip),
        # so agreement is only to bf16 precision
        (2, "float32", 1e-5),
        (4, "bfloat16", 2e-2),
    ])
    def test_fastpath_matches_split_oracle(self, splits, cd, tol):
        q, k, v, pos, tab, qp, null_bid = _rand_problem(11, nblk=4)
        got = pk.paged_attention_fast(
            q, k, v, pos, tab, qp, kv_splits=splits, combine_dtype=cd,
            null_bid=null_bid,
        )
        want = ref.paged_attention(
            q, k, v, pos, tab, qp, kv_splits=splits, combine_dtype=cd,
            null_bid=null_bid,
        )
        assert jnp.allclose(got, want, atol=tol, rtol=tol)

    def test_fastpath_split_count_changes_result(self):
        """Sanity that the fast path really is schedule-dependent — the
        reason it carries ``# det: fastpath`` instead of a proof."""
        q, k, v, pos, tab, qp, null_bid = _rand_problem(5, nblk=4)
        a = pk.paged_attention_fast(
            q, k, v, pos, tab, qp, kv_splits=1, combine_dtype="bfloat16",
            null_bid=null_bid,
        )
        b = pk.paged_attention_fast(
            q, k, v, pos, tab, qp, kv_splits=4, combine_dtype="bfloat16",
            null_bid=null_bid,
        )
        assert not jnp.array_equal(a, b)


# ----------------------------------------------------------------------
# engine: paged/fused vs legacy gather — bitwise identity sweep
# ----------------------------------------------------------------------

SCHEDULERS = {
    "pause": PauseDecodePolicy,
    "overlap": OverlapPolicy,
    "adaptive": AdaptivePolicy,
}


def _reqs(cfg, det, max_new=12):
    out = []
    for i in range(4):
        tail = [(5 * i + j) % cfg.vocab_size for j in range(9)]
        out.append(Request(
            rid=i, prompt=tail,
            sampling=SamplingParams(
                max_new_tokens=max_new, is_deterministic=(i in det),
                seed=70 + i,
            ),
        ))
    return out


def _run(cfg, params, *, paged, scheduler="overlap", block_size=16,
         spec_depth=1):
    eng = Engine(
        cfg, params, mode=Mode.LLM42, policy=DRIFTY, window=5, group=2,
        max_batch=8, capacity=128, scheduler=SCHEDULERS[scheduler](),
        block_size=block_size, spec_depth=spec_depth, paged_attention=paged,
    )
    det = {0, 2}
    for r in _reqs(cfg, det):
        eng.submit(r)
    it = 0
    while eng.step():
        it += 1
        assert it < 5000, "engine did not drain"
    done = {r.rid: r for r in eng.finished}
    return {rid: done[rid].committed for rid in det}, eng


class TestFusedStepBitwiseIdentity:
    @pytest.mark.parametrize("scheduler", sorted(SCHEDULERS))
    @pytest.mark.parametrize("spec_depth", [1, 4])
    def test_scheduler_depth_sweep(self, scheduler, spec_depth):
        cfg, params = _model()
        base, _ = _run(cfg, params, paged=False, scheduler=scheduler,
                       spec_depth=spec_depth)
        got, eng = _run(cfg, params, paged=True, scheduler=scheduler,
                        spec_depth=spec_depth)
        assert got == base, (scheduler, spec_depth)
        assert eng._paged_fwd

    @pytest.mark.parametrize("block_size", [8, 64])
    def test_block_size_sweep(self, block_size):
        cfg, params = _model()
        base, _ = _run(cfg, params, paged=False, block_size=block_size)
        got, _ = _run(cfg, params, paged=True, block_size=block_size)
        assert got == base, block_size

    def test_recurrent_arch_identity(self):
        """Hybrid (attn + mamba + MoE) engine: the fused step threads the
        state-pool anchor through the same launch."""
        cfg, params = _model("jamba-1.5-large-398b")
        base, _ = _run(cfg, params, paged=False)
        got, _ = _run(cfg, params, paged=True)
        assert got == base


class TestFusedStepStructure:
    def test_one_fused_launch_per_mixed_iteration(self):
        """Overlap iterations on the paged engine are ONE launch: exactly
        one sub-pass (the lead) pays the weight stream, every other
        sub-pass is marked ``fused``."""
        cfg, params = _model()
        _, eng = _run(cfg, params, paged=True, scheduler="overlap")
        ov = [e for e in eng.events if e.get("kind") == "overlap"]
        assert ov, "no overlapped iterations at all"
        saw_fused = False
        for e in ov:
            subs = [e[k] for k in ("prefill", "decode", "verify") if k in e]
            subs += list(e.get("verifies", ()))
            leads = [s for s in subs if not s.get("fused")]
            assert len(leads) == 1, e
            saw_fused |= len(subs) > 1
        assert saw_fused

    def test_legacy_engine_never_marks_fused(self):
        cfg, params = _model()
        _, eng = _run(cfg, params, paged=False, scheduler="overlap")
        from repro.serving.costmodel import flatten_events
        assert not any(e.get("fused") for e in flatten_events(eng.events))

    def test_multi_group_iteration_emits_verifies(self):
        """With spec_depth > 1 the scheduler may drain several due windows
        in one iteration; extra groups ride the composite event's
        ``verifies`` list and the cost model prices them."""
        cfg, params = _model()
        _, eng = _run(cfg, params, paged=True, scheduler="overlap",
                      spec_depth=4)
        from repro.serving import costmodel
        ov = [e for e in eng.events if e.get("kind") == "overlap"]
        assert ov
        multi = [e for e in ov if e.get("verifies")]
        for e in multi:
            for v in e["verifies"]:
                assert v["kind"] == "verify"
            # extra groups serialize on the verify stream: pricing the
            # composite must strictly exceed pricing it without them
            bare = {k: v for k, v in e.items() if k != "verifies"}
            t_with = costmodel.step_time(cfg, e)
            t_without = costmodel.step_time(cfg, bare)
            assert t_with > t_without
        # and the flattened view exposes them as leaf verify events
        flat = costmodel.flatten_events(eng.events)
        n_groups = sum(1 for e in flat if e.get("kind") == "verify")
        n_inline = sum(1 for e in eng.events if e.get("kind") == "verify")
        n_in_comp = sum(
            (1 if "verify" in e else 0) + len(e.get("verifies", ()))
            for e in ov
        )
        assert n_groups == n_inline + n_in_comp
