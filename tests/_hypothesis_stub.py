"""Minimal deterministic fallback for ``hypothesis`` (used when the real
package is not installed, e.g. in the hermetic CPU container).

Only the subset this suite uses is provided: ``given``, ``settings`` and the
``strategies`` namespace with ``integers``, ``sampled_from`` and
``booleans``.  Examples are drawn from a fixed-seed RNG, so a run is fully
reproducible — this trades hypothesis' shrinking/coverage machinery for a
plain deterministic parameter sweep.  CI installs the real package via the
``[test]`` extra and never touches this module.
"""

from __future__ import annotations

import functools
import inspect
import random
from types import SimpleNamespace
from typing import Any, Callable, Sequence


class _Strategy:
    def __init__(self, draw: Callable[[random.Random], Any]):
        self._draw = draw

    def example_from(self, rng: random.Random) -> Any:
        return self._draw(rng)


def integers(min_value: int, max_value: int) -> _Strategy:
    return _Strategy(lambda rng: rng.randint(min_value, max_value))


def sampled_from(elements: Sequence[Any]) -> _Strategy:
    items = list(elements)
    return _Strategy(lambda rng: items[rng.randrange(len(items))])


def booleans() -> _Strategy:
    return _Strategy(lambda rng: bool(rng.randrange(2)))


def lists(elements: _Strategy, min_size: int = 0, max_size: int = 10) -> _Strategy:
    def draw(rng: random.Random) -> list:
        n = rng.randint(min_size, max_size)
        return [elements.example_from(rng) for _ in range(n)]

    return _Strategy(draw)


strategies = SimpleNamespace(
    integers=integers, sampled_from=sampled_from, booleans=booleans,
    lists=lists,
)

_DEFAULT_MAX_EXAMPLES = 10


def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES, **_ignored) -> Callable:
    """Decorator recording ``max_examples``; other knobs are ignored."""

    def deco(fn: Callable) -> Callable:
        fn._stub_max_examples = max_examples
        return fn

    return deco


def given(**named_strategies: _Strategy) -> Callable:
    """Run the test once per drawn example (fixed seed => reproducible)."""

    def deco(fn: Callable) -> Callable:
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_stub_max_examples", _DEFAULT_MAX_EXAMPLES)
            rng = random.Random(0)
            for _ in range(n):
                drawn = {
                    name: s.example_from(rng)
                    for name, s in named_strategies.items()
                }
                fn(*args, **kwargs, **drawn)

        # hide strategy-supplied params from pytest's fixture resolution
        sig = inspect.signature(fn)
        kept = [p for n, p in sig.parameters.items() if n not in named_strategies]
        wrapper.__signature__ = sig.replace(parameters=kept)
        del wrapper.__wrapped__  # signature() must not follow back to fn
        return wrapper

    return deco
