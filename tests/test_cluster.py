"""Cluster front-end tests (mesh-scale deterministic serving).

Contracts under test:

* **Router determinism** — assignment is a pure function of the arrival
  trace and replica states: affinity by longest cached prefix (index
  tie-break), least-loaded fallback, load-guard divert.
* **Prefix transfer** — diverted prefix hits move KV blocks bitwise into
  the destination pool and register them with its radix; the
  ``"recompute"`` policy moves nothing yet commits the same streams.
* **Probe purity** — the router's radix probe (``PrefixCache.peek``)
  must not perturb LRU state on replicas it does not pick.
* **Aggregate accounting** — ClusterResult throughput/goodput and the
  ``cluster.*`` metrics series; the merged multi-pid Chrome trace
  validates.
"""

import jax
import jax.numpy as jnp
import jax.tree_util as jtu
import pytest

from repro.cluster import Cluster, Router, run_online, transfer_prefix
from repro.configs import get_smoke_config
from repro.core.determinism import Mode
from repro.models import init_params
from repro.obs import validate_chrome_trace
from repro.serving.engine import Engine
from repro.serving.request import Request, SamplingParams


@pytest.fixture(scope="module")
def model():
    cfg = get_smoke_config("llama3-8b")
    return cfg, init_params(cfg, jax.random.key(0))


SHARED = list(range(100, 132))  # two full 16-token blocks


def _req(i, prompt, max_new=8, det=True):
    return Request(
        rid=i, prompt=prompt,
        sampling=SamplingParams(
            max_new_tokens=max_new, is_deterministic=det, seed=50 + i,
        ),
    )


def _maker(cfg, params, **kw):
    def make_engine(idx):
        return Engine(cfg, params, mode=Mode.LLM42, window=5, group=2,
                      max_batch=2, capacity=128, **kw)
    return make_engine


class TestRouter:
    def test_least_loaded_spread_and_index_tiebreak(self, model):
        cfg, params = model
        cluster = Cluster(_maker(cfg, params), 3)
        # no prefixes anywhere: misses go least-loaded, ties to lowest idx
        tgts = []
        for i in range(5):
            t = cluster.router.route(_req(i, [900 + i] * 20), now=0)
            t.engine.submit(_req(i, [900 + i] * 20))
            tgts.append(t.idx)
        assert tgts == [0, 1, 2, 0, 1]
        assert cluster.router.affinity_misses == 5

    def test_affinity_beats_load_below_guard(self, model):
        cfg, params = model
        cluster = Cluster(_maker(cfg, params), 2, imbalance=2)
        r0 = cluster.replicas[0]
        r0.engine.submit(_req(0, SHARED + [200]))
        r0.engine.run()  # warms replica 0's radix with the shared prefix
        assert r0.prefix_blocks(SHARED + [201]) == 2
        # load difference 1 < imbalance 2: affinity wins despite the load
        r0.engine.submit(_req(1, [700] * 20))
        t = cluster.router.route(_req(2, SHARED + [202]), now=10)
        assert t.idx == 0
        assert cluster.router.affinity_hits == 1
        assert cluster.router.diverted == 0

    def test_load_guard_diverts_and_transfers(self, model):
        cfg, params = model
        cluster = Cluster(_maker(cfg, params), 2, transfer="copy",
                          imbalance=2)
        r0, r1 = cluster.replicas
        r0.engine.submit(_req(0, SHARED + [200]))
        r0.engine.run()
        for i in range(1, 4):  # pile load on the prefix holder
            r0.engine.submit(_req(i, [300 + i] * 40))
        t = cluster.router.route(_req(9, SHARED + [202]), now=1000)
        assert t.idx == 1
        assert cluster.router.diverted == 1
        assert cluster.router.transfers == 1
        assert cluster.router.transferred_tokens == 2 * 16
        assert r1.prefix_blocks(SHARED + [203]) == 2

    def test_transferred_blocks_bitwise_equal(self, model):
        cfg, params = model
        cluster = Cluster(_maker(cfg, params), 2)
        r0, r1 = cluster.replicas
        r0.engine.submit(_req(0, SHARED + [200]))
        r0.engine.run()
        moved = transfer_prefix(r0, r1, SHARED, now=50)
        assert moved == 2 * 16
        sb = r0.engine.prefix_cache.match(SHARED, 0)
        db = r1.engine.prefix_cache.match(SHARED, 0)
        checked = 0
        for s_leaf, d_leaf, desc in zip(
            jtu.tree_leaves(r0.engine.pool.data),
            jtu.tree_leaves(r1.engine.pool.data),
            jtu.tree_leaves(
                r0.engine.pool.layout.axes,
                is_leaf=lambda x: x is None or hasattr(x, "axis"),
            ),
        ):
            if desc is None:
                continue
            srows = jnp.take(s_leaf, jnp.array(sb), axis=desc.axis)
            drows = jnp.take(d_leaf, jnp.array(db), axis=desc.axis)
            assert bool(jnp.array_equal(srows, drows))
            checked += 1
        assert checked > 0
        # blocks landed resident-but-evictable: refcount 0, cached
        alloc = r1.engine.pool.alloc_blocks
        for bid in db:
            assert alloc.refs[bid] == 0
            assert bid in alloc.cached

    def test_transfer_noop_when_dst_has_longer_prefix(self, model):
        cfg, params = model
        cluster = Cluster(_maker(cfg, params), 2)
        r0, r1 = cluster.replicas
        r1.engine.submit(_req(0, SHARED + [200]))
        r1.engine.run()
        assert transfer_prefix(r0, r1, SHARED, now=0) == 0

    def test_peek_probe_does_not_perturb_lru(self, model):
        cfg, params = model
        eng = _maker(cfg, params)(0)
        eng.submit(_req(0, SHARED + [200]))
        eng.run()
        pc = eng.prefix_cache
        before = [
            (n.bid, n.last_use, n.seq)
            for n in _walk(pc.root)
        ]
        stats_before = dict(pc.stats())
        assert pc.peek(SHARED + [999]) == 2
        after = [
            (n.bid, n.last_use, n.seq)
            for n in _walk(pc.root)
        ]
        assert before == after
        assert dict(pc.stats()) == stats_before

    def test_recompute_policy_commits_same_streams(self, model):
        cfg, params = model

        def once(transfer):
            cluster = Cluster(_maker(cfg, params), 2, transfer=transfer,
                              imbalance=1)
            reqs = [_req(i, SHARED + [200 + i]) for i in range(6)]
            run_online(cluster, cfg, [(r, 0.0) for r in reqs])
            return {r.rid: tuple(r.committed) for r in cluster.finished}

        assert once("copy") == once("recompute")


def _walk(node):
    out = []
    stack = [node]
    while stack:
        n = stack.pop()
        stack.extend(n.children.values())
        if n.bid >= 0:
            out.append(n)
    out.sort(key=lambda n: n.seq)
    return out


class TestClusterRun:
    def test_aggregate_result_and_metrics(self, model):
        cfg, params = model
        cluster = Cluster(_maker(cfg, params), 2)
        reqs = [_req(i, SHARED + [200 + i], det=(i % 2 == 0))
                for i in range(4)]
        res = run_online(cluster, cfg, [(r, 0.05 * i)
                                        for i, r in enumerate(reqs)])
        assert len(res.latencies) == 4
        assert len(res.ttfts) == 4
        assert all(res.ttfts[r] <= res.latencies[r] for r in res.ttfts)
        assert res.out_tokens == sum(
            r.num_output for r in cluster.finished)
        assert res.throughput > 0
        # goodput with an infinite SLO is plain throughput; with a zero
        # SLO nothing qualifies
        assert res.goodput(float("inf")) == pytest.approx(res.throughput)
        assert res.goodput(0.0) == pytest.approx(0.0)
        m = res.metrics
        assert m["cluster.replicas"] == 2
        assert m["cluster.router.assignments"] == 4
        assert "cluster.replica.0.occupancy" in m
        assert "cluster.replica.1.load" in m
        assert len(res.replica_metrics) == 2

    def test_makespan_covers_late_arrivals(self, model):
        cfg, params = model
        cluster = Cluster(_maker(cfg, params), 2)
        reqs = [_req(i, [600 + i] * 12) for i in range(3)]
        res = run_online(cluster, cfg,
                         [(reqs[0], 0.0), (reqs[1], 0.0), (reqs[2], 5.0)])
        assert res.total_time >= 5.0
        assert len(res.latencies) == 3

    def test_merged_trace_has_one_pid_per_replica(self, model):
        cfg, params = model
        cluster = Cluster(_maker(cfg, params, trace=True), 2)
        reqs = [_req(i, SHARED + [200 + i]) for i in range(4)]
        run_online(cluster, cfg, [(r, 0.0) for r in reqs])
        trace = cluster.chrome_trace()
        assert not validate_chrome_trace(trace)
        pids = {e["pid"] for e in trace["traceEvents"]}
        assert pids == {0, 1}
        names = {
            (e["pid"], e["args"]["name"])
            for e in trace["traceEvents"]
            if e.get("ph") == "M" and e.get("name") == "process_name"
        }
        assert names == {(0, "llm42-replica-0"), (1, "llm42-replica-1")}

    def test_exhausting_max_iters_raises(self, model):
        cfg, params = model
        cluster = Cluster(_maker(cfg, params), 2)
        reqs = [_req(i, [500 + i] * 12, max_new=12) for i in range(4)]
        with pytest.raises(RuntimeError, match="partial"):
            run_online(cluster, cfg, [(r, 0.0) for r in reqs], max_iters=2)

    def test_single_replica_matches_plain_online_runner(self, model):
        """A 1-replica cluster is the single-engine online runner: same
        committed streams, same clock."""
        from repro.serving.online import run_online as single_online

        cfg, params = model
        reqs = [_req(i, SHARED + [200 + i]) for i in range(3)]
        arrivals = [0.0, 0.1, 0.2]

        eng = _maker(cfg, params)(0)
        single = single_online(eng, cfg, list(zip(reqs, arrivals)))
        s_streams = {r.rid: tuple(r.committed) for r in eng.finished}

        cluster = Cluster(_maker(cfg, params), 1)
        reqs2 = [_req(i, SHARED + [200 + i]) for i in range(3)]
        res = run_online(cluster, cfg, list(zip(reqs2, arrivals)))
        c_streams = {r.rid: tuple(r.committed) for r in cluster.finished}

        assert s_streams == c_streams
        assert res.total_time == pytest.approx(single.total_time)


class TestRouterUnit:
    def test_rejects_bad_policy(self, model):
        cfg, params = model
        replicas = Cluster(_maker(cfg, params), 1).replicas
        with pytest.raises(AssertionError):
            Router(replicas, transfer="teleport")
        with pytest.raises(AssertionError):
            Router(replicas, imbalance=0)
