"""End-to-end behaviour tests for LLM-42 (the paper's determinism claims).

The headline property (paper abstract): a request with
``is_deterministic=True`` produces bitwise-identical output across runs,
*whatever* the co-batched traffic — while fast-path decoding stays
dynamically batched.
"""

import jax
import pytest

from repro.configs import get_smoke_config
from repro.core.determinism import (
    FAST_PATH_POLICY,
    Mode,
    ReductionPolicy,
)
from repro.models import init_params
from repro.serving.costmodel import flatten_events
from repro.serving.engine import Engine
from repro.serving.request import Request, SamplingParams

pytestmark = pytest.mark.slow


def _prompt(i, n=10, vocab=512):
    import random

    r = random.Random(i)
    return [r.randrange(vocab) for _ in range(n)]


def _run(cfg, params, rids, det_rids, *, mode=Mode.LLM42, window=5, group=2,
         max_new=20, temperature=0.0, policy=FAST_PATH_POLICY, arrivals=None):
    eng = Engine(cfg, params, mode=mode, policy=policy, window=window,
                 group=group, max_batch=8, capacity=256)
    for j, i in enumerate(rids):
        eng.submit(Request(
            rid=i, prompt=_prompt(i, vocab=cfg.vocab_size),
            sampling=SamplingParams(
                max_new_tokens=max_new, is_deterministic=(i in det_rids),
                seed=100 + i, temperature=temperature,
            ),
        ))
    done = {r.rid: r for r in eng.run()}
    return done, eng


@pytest.fixture(scope="module")
def dense():
    cfg = get_smoke_config("llama3-8b")
    return cfg, init_params(cfg, jax.random.key(0))


@pytest.fixture(scope="module")
def moe():
    cfg = get_smoke_config("kimi-k2-1t-a32b")
    return cfg, init_params(cfg, jax.random.key(0))


@pytest.fixture(scope="module")
def ssm():
    cfg = get_smoke_config("rwkv6-3b")
    return cfg, init_params(cfg, jax.random.key(0))


@pytest.fixture(scope="module")
def hybrid():
    cfg = get_smoke_config("jamba-1.5-large-398b")
    return cfg, init_params(cfg, jax.random.key(0))


class TestDeterminismProperty:
    """Same det request, three different traffic mixes -> identical output."""

    def test_dense_greedy(self, dense):
        cfg, params = dense
        a, _ = _run(cfg, params, [0], {0})
        b, _ = _run(cfg, params, [0, 1, 2, 3, 4], {0})
        c, _ = _run(cfg, params, [0, 1, 2], {0, 2})
        assert a[0].committed == b[0].committed == c[0].committed

    def test_dense_stochastic_sampling(self, dense):
        cfg, params = dense
        a, _ = _run(cfg, params, [0], {0}, temperature=0.8)
        b, _ = _run(cfg, params, [0, 1, 2, 3], {0}, temperature=0.8)
        assert a[0].committed == b[0].committed

    def test_dense_top_k_sampling(self, dense):
        """Fixed (temperature, top_k, seed) hyper-params => deterministic
        output (paper footnote 2's intended semantics)."""
        cfg, params = dense

        def run_tk(rids):
            eng = Engine(cfg, params, mode=Mode.LLM42, policy=FAST_PATH_POLICY,
                         window=5, group=2, max_batch=8, capacity=256)
            for i in rids:
                eng.submit(Request(
                    rid=i, prompt=_prompt(i, vocab=cfg.vocab_size),
                    sampling=SamplingParams(
                        max_new_tokens=16, is_deterministic=(i == 0),
                        seed=100 + i, temperature=0.9, top_k=10,
                    ),
                ))
            return {r.rid: r for r in eng.run()}

        a = run_tk([0])
        b = run_tk([0, 1, 2, 3])
        assert a[0].committed == b[0].committed

    def test_moe(self, moe):
        cfg, params = moe
        a, _ = _run(cfg, params, [0], {0}, max_new=16)
        b, _ = _run(cfg, params, [0, 1, 2, 3], {0}, max_new=16)
        assert a[0].committed == b[0].committed

    def test_ssm_state_checkpointing(self, ssm):
        """SSM rollback uses state checkpoints, not KV truncation
        (beyond-paper extension, DESIGN.md §4)."""
        cfg, params = ssm
        a, _ = _run(cfg, params, [0], {0}, max_new=16)
        b, _ = _run(cfg, params, [0, 1, 2, 3], {0}, max_new=16)
        assert a[0].committed == b[0].committed

    def test_hybrid_mixed_state_repair(self, hybrid):
        cfg, params = hybrid
        a, _ = _run(cfg, params, [0], {0}, max_new=12)
        b, _ = _run(cfg, params, [0, 1, 2], {0}, max_new=12)
        assert a[0].committed == b[0].committed

    def test_multiple_det_requests_all_consistent(self, dense):
        cfg, params = dense
        a, _ = _run(cfg, params, [0, 1, 2, 3], {0, 1, 2, 3})
        b, _ = _run(cfg, params, [0, 1, 2, 3, 4, 5], {0, 1, 2, 3})
        for rid in (0, 1, 2, 3):
            assert a[rid].committed == b[rid].committed, rid


class TestFastPathNondeterminism:
    """The problem being solved must actually exist in our system: nondet
    requests may diverge across batch mixes (floating-point + schedules)."""

    def test_nondet_can_diverge(self, dense):
        cfg, params = dense
        # aggressive policy to make flips likely at toy scale
        policy = ReductionPolicy(
            thresholds=((2, 16), (4, 8), (8, 4)), combine_dtype="bfloat16"
        )
        diverged = False
        for seed_set in range(6):
            rids = [0] + list(range(10 * seed_set + 1, 10 * seed_set + 4))
            a, _ = _run(cfg, params, [0], set(), policy=policy, max_new=32)
            b, _ = _run(cfg, params, rids, set(), policy=policy, max_new=32)
            if a[0].committed != b[0].committed:
                diverged = True
                break
        assert diverged, (
            "fast path never diverged — the determinism problem would be "
            "vacuous in this setup"
        )


class TestModes:
    def test_batch_invariant_mode_deterministic(self, dense):
        """The He-et-al. baseline: global determinism without verification."""
        cfg, params = dense
        a, ea = _run(cfg, params, [0], set(), mode=Mode.BATCH_INVARIANT)
        b, eb = _run(cfg, params, [0, 1, 2, 3, 4], set(), mode=Mode.BATCH_INVARIANT)
        assert a[0].committed == b[0].committed
        assert not any(e["kind"] == "verify" for e in flatten_events(eb.events))

    def test_nondet_mode_has_no_verification(self, dense):
        cfg, params = dense
        _, eng = _run(cfg, params, [0, 1], {0}, mode=Mode.NONDET)
        assert not any(e["kind"] == "verify" for e in flatten_events(eng.events))

    def test_llm42_verifies_only_det_traffic(self, dense):
        cfg, params = dense
        _, eng = _run(cfg, params, [0, 1, 2, 3], set())
        assert not any(e["kind"] == "verify" for e in flatten_events(eng.events))
        _, eng2 = _run(cfg, params, [0, 1, 2, 3], {0})
        assert any(e["kind"] == "verify" for e in flatten_events(eng2.events))


class TestDVRMechanics:
    def test_forward_progress_and_budget(self, dense):
        cfg, params = dense
        done, _ = _run(cfg, params, list(range(6)), set(range(6)), max_new=17)
        for r in done.values():
            assert len(r.committed) == 17

    def test_rollback_accounting(self, dense):
        cfg, params = dense
        policy = ReductionPolicy(
            thresholds=((2, 16), (4, 8), (8, 4)), combine_dtype="bfloat16"
        )
        done, _ = _run(cfg, params, list(range(6)), {0, 1, 2}, policy=policy,
                       max_new=24)
        for r in done.values():
            assert r.num_recomputed_tokens >= r.num_rollbacks * 0
            if r.num_rollbacks:
                assert r.num_recomputed_tokens > 0
            assert len(r.committed) == 24

    def test_verify_touches_only_det_rows(self, dense):
        """Grouped verification with padding must not corrupt live nondet
        requests: nondet outputs identical with/without a det neighbour."""
        cfg, params = dense
        a, _ = _run(cfg, params, [1, 2], set())
        b, _ = _run(cfg, params, [1, 2, 0], {0})
        # co-batching CAN change nondet bits (schedule changes), so compare
        # against a same-traffic-shape run instead: determinism of the
        # engine itself given identical inputs.
        c, _ = _run(cfg, params, [1, 2, 0], {0})
        assert b[1].committed == c[1].committed
        assert b[2].committed == c[2].committed

    def test_grouped_verification_group_independence(self, dense):
        """O3 for groups: a det request's output must not depend on WHICH
        requests share its verification group."""
        cfg, params = dense
        a, _ = _run(cfg, params, [0, 7, 8], {0, 7, 8}, group=3)
        b, _ = _run(cfg, params, [0, 11, 12], {0, 11, 12}, group=3)
        assert a[0].committed == b[0].committed

    def test_window_size_does_not_change_output(self, dense):
        """Window alignment must be invisible (position-consistency O3):
        different W => different verify boundaries, same committed tokens."""
        cfg, params = dense
        outs = []
        for w in (3, 5, 9):
            d, _ = _run(cfg, params, [0, 1], {0}, window=w, max_new=20)
            outs.append(d[0].committed)
        assert outs[0] == outs[1] == outs[2]
