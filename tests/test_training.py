"""Training substrate tests: optimizer semantics, data pipeline determinism,
microbatch-accumulation equivalence, checkpoint roundtrip."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import init_params
from repro.training import checkpoint
from repro.training.data import (
    ARXIV,
    SHAREGPT,
    SyntheticTextStream,
    poisson_arrivals,
    sample_workload,
)
from repro.training.optimizer import (
    AdamWConfig,
    apply_updates,
    init_opt_state,
    lr_at,
)
from repro.training.train import make_train_step


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("tinyllama-1.1b")
    params = init_params(cfg, jax.random.key(0))
    stream = iter(SyntheticTextStream(cfg.vocab_size, 32, 4, seed=1))
    b = next(stream)
    batch = {
        "tokens": jnp.asarray(b.tokens),
        "targets": jnp.asarray(b.targets),
        "loss_mask": jnp.asarray(b.loss_mask),
    }
    return cfg, params, batch


class TestOptimizer:
    def test_lr_schedule(self):
        cfg = AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=100)
        assert float(lr_at(cfg, jnp.int32(5))) == pytest.approx(5e-4)
        assert float(lr_at(cfg, jnp.int32(10))) == pytest.approx(1e-3, rel=1e-2)
        assert float(lr_at(cfg, jnp.int32(100))) == pytest.approx(
            cfg.lr * cfg.min_lr_frac, rel=1e-2)

    def test_grad_clip(self):
        p = {"w": jnp.ones(4)}
        g = {"w": jnp.full(4, 100.0)}
        cfg = AdamWConfig(clip_norm=1.0, weight_decay=0.0)
        _, state, m = apply_updates(cfg, p, g, init_opt_state(p))
        assert float(m["grad_norm"]) == pytest.approx(200.0)

    def test_descends_quadratic(self):
        cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0,
                          total_steps=1000, clip_norm=1e9)
        p = {"w": jnp.float32(5.0)}
        s = init_opt_state(p)
        for _ in range(50):
            g = {"w": 2 * p["w"]}
            p, s, _ = apply_updates(cfg, p, g, s)
        assert abs(float(p["w"])) < 1.0


class TestTrainStep:
    def test_microbatch_equivalence(self, setup):
        """grad accumulation over 2 microbatches == single batch (f32)."""
        cfg, params, batch = setup
        opt = AdamWConfig(lr=1e-3, total_steps=100)
        s1 = jax.jit(make_train_step(cfg, opt, num_microbatches=1))
        s2 = jax.jit(make_train_step(cfg, opt, num_microbatches=2))
        p1, _, m1 = s1(params, init_opt_state(params), batch)
        p2, _, m2 = s2(params, init_opt_state(params), batch)
        for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
            assert jnp.allclose(a, b, atol=1e-5)

    def test_remat_matches_no_remat(self, setup):
        cfg, params, batch = setup
        opt = AdamWConfig(lr=1e-3, total_steps=100)
        pa, _, _ = jax.jit(make_train_step(cfg, opt, remat=True))(
            params, init_opt_state(params), batch)
        pb, _, _ = jax.jit(make_train_step(cfg, opt, remat=False))(
            params, init_opt_state(params), batch)
        for a, b in zip(jax.tree.leaves(pa), jax.tree.leaves(pb)):
            assert jnp.allclose(a, b, atol=1e-5)

    def test_loss_mask_respected(self, setup):
        cfg, params, batch = setup
        from repro.training.train import lm_loss

        masked = dict(batch)
        masked["loss_mask"] = batch["loss_mask"].at[:, 16:].set(0.0)
        # changing tokens under a zeroed mask must not change the loss
        poked = dict(masked)
        poked["targets"] = masked["targets"].at[:, 20].set(3)
        l1, _ = lm_loss(params, cfg, masked["tokens"], masked["targets"],
                        masked["loss_mask"])
        l2, _ = lm_loss(params, cfg, poked["tokens"], poked["targets"],
                        poked["loss_mask"])
        assert float(l1) == float(l2)


class TestData:
    def test_stream_deterministic(self):
        a = next(iter(SyntheticTextStream(256, 16, 2, seed=5)))
        b = next(iter(SyntheticTextStream(256, 16, 2, seed=5)))
        assert (a.tokens == b.tokens).all()

    def test_workload_stats_roughly_match_table3(self):
        lens = sample_workload(SHAREGPT, 4000, seed=0)
        ins = np.array([i for i, _ in lens])
        assert 80 < np.median(ins) < 260  # Table 3: median 136, mean 304
        lens_a = sample_workload(ARXIV, 2000, seed=0)
        ins_a = np.array([i for i, _ in lens_a])
        assert np.median(ins_a) > 3000  # ArXiv is long-context

    def test_poisson_arrivals_monotone(self):
        t = poisson_arrivals(100, qps=10, seed=0)
        assert all(b > a for a, b in zip(t, t[1:]))
        assert 5 < t[-1] < 25  # ~10s at 10 qps


class TestCheckpoint:
    def test_roundtrip(self, setup):
        cfg, params, _ = setup
        opt_state = init_opt_state(params)
        with tempfile.TemporaryDirectory() as d:
            path = os.path.join(d, "c.npz")
            checkpoint.save(path, params, opt_state, step=7)
            p2, o2, step = checkpoint.restore(path, params, opt_state)
            assert step == 7
            for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
                assert (a == b).all()
            for a, b in zip(jax.tree.leaves(opt_state), jax.tree.leaves(o2)):
                assert (jnp.asarray(a) == jnp.asarray(b)).all()
