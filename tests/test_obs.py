"""Observability-layer tests (ISSUE 9).

Three contracts:

* **Schema** — engine-exported Chrome/Perfetto traces pass
  :func:`repro.obs.validate_chrome_trace` (and the validator itself
  rejects each class of malformed trace).
* **Observer-effect freedom** — committed streams are bitwise identical
  with tracing+auditing on vs off, across scheduler policies and
  speculation depths (hypothesis-driven).
* **Audit coverage** — every committed token of an audited run has
  exactly one provenance record (schedule + window + margin for
  verify-committed tokens); rollback victims have none.

Plus unit tests for the metrics registry, the ``mem_stats`` compat shim,
and the ``persist.py --check`` tolerance comparator.
"""

import json

import jax
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_smoke_config
from repro.core.determinism import Mode, ReductionPolicy
from repro.models import init_params
from repro.obs import (
    AuditLog,
    MetricsRegistry,
    TokenProvenance,
    Tracer,
    validate_chrome_trace,
)
from repro.obs.trace import TID_MAIN, TID_PROTOCOL, TID_VERIFY
from repro.serving.engine import Engine
from repro.serving.request import Request, SamplingParams
from repro.serving.scheduler import (
    AdaptivePolicy,
    OverlapPolicy,
    PauseDecodePolicy,
)

#: aggressive drift so rollbacks actually happen at toy scale
DRIFTY = ReductionPolicy(
    thresholds=((2, 16), (4, 8), (16, 4)), combine_dtype="bfloat16"
)

SCHEDULERS = {
    "pause": PauseDecodePolicy,
    "overlap": OverlapPolicy,
    "adaptive": AdaptivePolicy,
}


@pytest.fixture(scope="module")
def model():
    cfg = get_smoke_config("llama3-8b")
    return cfg, init_params(cfg, jax.random.key(0))


def _reqs(cfg, n=4, max_new=14):
    return [
        Request(
            rid=i, prompt=[(5 * i + j) % cfg.vocab_size for j in range(9)],
            sampling=SamplingParams(
                max_new_tokens=max_new, is_deterministic=(i % 2 == 0),
                seed=70 + i,
            ),
        )
        for i in range(n)
    ]


def _run(cfg, params, *, scheduler="overlap", spec_depth=1, trace=False,
         audit=False, n=4, max_new=14):
    eng = Engine(cfg, params, mode=Mode.LLM42, policy=DRIFTY, window=5,
                 group=2, max_batch=8, capacity=256,
                 scheduler=SCHEDULERS[scheduler](), spec_depth=spec_depth,
                 trace=trace, audit=audit)
    for r in _reqs(cfg, n, max_new):
        eng.submit(r)
    done = eng.run()
    return eng, done


#: run cache — hypothesis revisits configurations, engine runs are the
#: expensive part, and every run is deterministic by construction
_RUNS = {}


def _cached_run(cfg, params, scheduler, spec_depth, obs_on):
    key = (scheduler, spec_depth, obs_on)
    if key not in _RUNS:
        _RUNS[key] = _run(cfg, params, scheduler=scheduler,
                          spec_depth=spec_depth, trace=obs_on, audit=obs_on)
    return _RUNS[key]


# ----------------------------------------------------------------------
# metrics registry
# ----------------------------------------------------------------------


def test_counter_gauge_histogram_snapshot():
    reg = MetricsRegistry()
    c = reg.counter("a.count", unit="1", help="things")
    c.inc()
    c.inc(3)
    g = reg.gauge("a.level")
    g.set(2.5)
    g.set_max(1.0)  # lower: no-op
    reg.gauge_fn("a.pull", lambda: 7)
    h = reg.histogram("a.lat", unit="s")
    for v in (1.0, 2.0, 3.0, 4.0):
        h.observe(v)
    snap = reg.snapshot()
    assert snap["a.count"] == 4 and isinstance(snap["a.count"], int)
    assert snap["a.level"] == 2.5
    assert snap["a.pull"] == 7
    assert snap["a.lat.count"] == 4
    assert snap["a.lat.sum"] == 10
    assert snap["a.lat.min"] == 1 and snap["a.lat.max"] == 4
    assert snap["a.lat.p50"] == 3  # nearest-rank
    assert snap["a.lat.p99"] == 4


def test_registry_get_or_create_and_kind_mismatch():
    reg = MetricsRegistry()
    c1 = reg.counter("x")
    c2 = reg.counter("x")
    assert c1 is c2
    with pytest.raises(AssertionError):
        reg.gauge("x")
    with pytest.raises(AssertionError):
        c1.inc(-1)
    # gauge_fn re-registration replaces the callback (engine re-binds the
    # runtime under bind_cost_model)
    g = reg.gauge_fn("y", lambda: 1)
    reg.gauge_fn("y", lambda: 2)
    assert g.value == 2
    assert "y" in reg and reg.get("zzz") is None


def test_histogram_empty_and_describe():
    reg = MetricsRegistry()
    reg.histogram("h", unit="s", help="empty")
    snap = reg.snapshot()
    assert snap["h.count"] == 0 and snap["h.p99"] == 0
    cat = reg.describe()
    assert cat == [{"name": "h", "kind": "histogram", "unit": "s",
                    "help": "empty"}]


def test_registry_dump(tmp_path):
    reg = MetricsRegistry()
    reg.counter("n").inc(2)
    p = tmp_path / "m.json"
    reg.dump(str(p))
    d = json.loads(p.read_text())
    assert d["snapshot"] == {"n": 2}
    assert d["catalog"][0]["name"] == "n"


# ----------------------------------------------------------------------
# trace validator (negative cases — no engine needed)
# ----------------------------------------------------------------------


def _ev(**kw):
    base = {"ph": "X", "pid": 0, "tid": 0, "name": "p", "ts": 0, "dur": 1}
    base.update(kw)
    return base


def test_validator_rejects_malformed_traces():
    assert validate_chrome_trace([]) != []
    assert validate_chrome_trace({"traceEvents": [_ev(ph="Q")]}) != []
    assert validate_chrome_trace({"traceEvents": [_ev(ts=-1)]}) != []
    assert validate_chrome_trace({"traceEvents": [_ev(dur=None)]}) != []
    # unmatched async begin
    assert validate_chrome_trace({"traceEvents": [
        {"ph": "b", "pid": 0, "tid": 2, "name": "r", "cat": "request",
         "id": "0", "ts": 0},
    ]}) != []
    # async end before begin
    assert validate_chrome_trace({"traceEvents": [
        {"ph": "e", "pid": 0, "tid": 2, "name": "r", "cat": "request",
         "id": "0", "ts": 0},
    ]}) != []
    # partial overlap on one row
    assert validate_chrome_trace({"traceEvents": [
        _ev(ts=0, dur=10), _ev(ts=5, dur=10),
    ]}) != []
    # out-of-order starts
    assert validate_chrome_trace({"traceEvents": [
        _ev(ts=10), _ev(ts=0),
    ]}) != []


def test_validator_accepts_nested_and_adjacent():
    assert validate_chrome_trace({"traceEvents": [
        _ev(ts=0, dur=10, name="parent"),
        _ev(ts=0, dur=4, name="child1"),
        _ev(ts=4, dur=6, name="child2"),
        _ev(ts=10, dur=5, name="next"),
    ]}) == []


def test_tracer_logical_layout_and_groups():
    tr = Tracer()
    tr.begin_iteration(0, 0.0)
    tr.request_begin(7, 0.0)
    tr.begin_group("fused_step", subs=2)
    tr.pass_span("main", "decode", None)
    tr.pass_span("main", "verify", None)
    tr.end_group()
    tr.instant("commit", 0.5, rid=7)
    tr.end_iteration(1.0)
    tr.request_end(7, 1.0)
    trace = tr.to_chrome_trace()
    assert validate_chrome_trace(trace) == []
    names = [e["name"] for e in trace["traceEvents"] if e["ph"] == "X"]
    assert "fused_step" in names and "decode" in names
    # the fused parent covers its children
    xs = {e["name"]: e for e in trace["traceEvents"] if e["ph"] == "X"}
    par, d, v = xs["fused_step"], xs["decode"], xs["verify"]
    assert par["ts"] <= d["ts"]
    assert par["ts"] + par["dur"] >= v["ts"] + v["dur"]


# ----------------------------------------------------------------------
# engine-exported traces (golden schema)
# ----------------------------------------------------------------------


def test_engine_trace_schema_and_attribution(model):
    cfg, params = model
    eng, done = _cached_run(cfg, params, "overlap", 1, True)
    trace = eng.obs.tracer.to_chrome_trace()
    assert validate_chrome_trace(trace) == []
    evs = trace["traceEvents"]

    # stream rows are named via metadata
    names = {e["args"]["name"] for e in evs if e["ph"] == "M"
             and e["name"] == "thread_name"}
    assert {"main stream", "verify stream", "protocol"} <= names

    # pass slices land on their stream's row
    rows = {e["tid"] for e in evs if e["ph"] == "X"}
    assert TID_MAIN in rows
    verify_slices = [e for e in evs if e["ph"] == "X"
                     and e["name"] == "verify"]
    assert verify_slices, "no verify passes traced"
    assert all(e["tid"] in (TID_MAIN, TID_VERIFY) for e in verify_slices)

    # per-request lifecycle: one async begin + one end per request
    begins = [e for e in evs if e["ph"] == "b"]
    ends = [e for e in evs if e["ph"] == "e"]
    assert len(begins) == len(done) and len(ends) == len(done)
    assert {e["id"] for e in begins} == {str(r.rid) for r in done}

    # protocol instants cover the lifecycle events this run had
    instants = {e["name"] for e in evs if e["ph"] == "i"}
    assert {"submit", "admit", "verify_submit", "retire"} <= instants
    assert instants & {"commit", "rollback"}
    assert all(e["tid"] == TID_PROTOCOL for e in evs if e["ph"] == "i")


def test_engine_trace_costed_clock(model):
    cfg, params = model
    from repro.configs import get_config

    eng = Engine(cfg, params, mode=Mode.LLM42, policy=DRIFTY, window=5,
                 group=2, max_batch=8, capacity=256,
                 scheduler=OverlapPolicy(), trace=True,
                 verify_latency_ms=5.0, cost_cfg=get_config("llama3-8b"))
    for r in _reqs(cfg):
        eng.submit(r)
    eng.run()
    trace = eng.obs.tracer.to_chrome_trace()
    assert validate_chrome_trace(trace) == []
    # costed spans carry real durations on the verify row
    vs = [e for e in trace["traceEvents"]
          if e["ph"] == "X" and e["tid"] == TID_VERIFY]
    assert vs and all(e["dur"] > 0 for e in vs)


# ----------------------------------------------------------------------
# observer-effect freedom (the tentpole invariant)
# ----------------------------------------------------------------------


@settings(max_examples=6, deadline=None)
@given(
    scheduler=st.sampled_from(sorted(SCHEDULERS)),
    spec_depth=st.sampled_from([1, 4]),
)
def test_observability_is_observer_effect_free(model, scheduler, spec_depth):
    """Tracing + auditing on vs off: committed streams bitwise identical
    for EVERY request (deterministic and fast-path alike — the engine
    launches identical device programs either way)."""
    cfg, params = model
    _, done_on = _cached_run(cfg, params, scheduler, spec_depth, True)
    _, done_off = _cached_run(cfg, params, scheduler, spec_depth, False)
    on = {r.rid: list(r.committed) for r in done_on}
    off = {r.rid: list(r.committed) for r in done_off}
    assert on == off


def test_policies_agree_with_observability_on(model):
    """The scheduler-interchangeability invariant holds for the
    deterministic subset while traced+audited."""
    cfg, params = model
    ref = None
    for scheduler in sorted(SCHEDULERS):
        _, done = _cached_run(cfg, params, scheduler, 1, True)
        streams = {r.rid: list(r.committed) for r in done
                   if r.sampling.is_deterministic}
        if ref is None:
            ref = streams
        assert streams == ref, f"{scheduler} moved a deterministic stream"


def _cluster_run(cfg, params, obs_on):
    from repro.cluster import Cluster, run_online

    def make_engine(idx):
        return Engine(cfg, params, mode=Mode.LLM42, policy=DRIFTY, window=5,
                      group=2, max_batch=2, capacity=128,
                      trace=obs_on, audit=obs_on)

    cluster = Cluster(make_engine, 2)
    shared = [(7 * j + 3) % cfg.vocab_size for j in range(32)]
    reqs = [
        Request(
            rid=i, prompt=shared + [(5 * i + j) % cfg.vocab_size
                                    for j in range(3)],
            sampling=SamplingParams(
                max_new_tokens=10, is_deterministic=(i % 2 == 0),
                seed=70 + i,
            ),
        )
        for i in range(5)
    ]
    res = run_online(cluster, cfg, [(r, 0.0) for r in reqs])
    return cluster, res


def test_cluster_router_path_is_observer_effect_free(model):
    """The routed multi-replica path keeps the tentpole invariant: with
    per-replica tracing + auditing on vs off, the router makes the same
    assignments and every replica commits bitwise-identical streams."""
    cfg, params = model
    cl_on, res_on = _cluster_run(cfg, params, True)
    cl_off, res_off = _cluster_run(cfg, params, False)
    assert res_on.assignment == res_off.assignment
    on = {r.rid: list(r.committed) for r in cl_on.finished}
    off = {r.rid: list(r.committed) for r in cl_off.finished}
    assert on == off


def test_cluster_merged_trace_validates_per_pid(model):
    """The merged fleet trace keys rows on (pid, tid): each replica's
    spans nest within its own process namespace and the whole trace
    passes the schema validator."""
    cfg, params = model
    cluster, _ = _cluster_run(cfg, params, True)
    trace = cluster.chrome_trace()
    assert validate_chrome_trace(trace) == []
    pids = {e["pid"] for e in trace["traceEvents"]}
    assert pids == {0, 1}
    # per-pid process_name metadata is present for both replicas
    meta = {
        (e["pid"], e["args"]["name"]) for e in trace["traceEvents"]
        if e.get("ph") == "M" and e.get("name") == "process_name"
    }
    assert meta == {(0, "llm42-replica-0"), (1, "llm42-replica-1")}


# ----------------------------------------------------------------------
# determinism audit log
# ----------------------------------------------------------------------


@pytest.mark.parametrize("scheduler,spec_depth",
                         [("pause", 1), ("overlap", 1), ("overlap", 4),
                          ("adaptive", 1)])
def test_audit_covers_committed_stream_exactly(model, scheduler, spec_depth):
    cfg, params = model
    eng, done = _cached_run(cfg, params, scheduler, spec_depth, True)
    audit = eng.obs.audit
    assert audit.coverage_errors(done) == []
    total = sum(len(r.committed) for r in done)
    assert len(audit.records) == total


def test_audit_verify_records_carry_provenance(model):
    cfg, params = model
    eng, done = _cached_run(cfg, params, "overlap", 1, True)
    recs = eng.obs.audit.records
    vrecs = [r for r in recs if r.origin == "verify"]
    assert vrecs, "no verify-committed tokens in an LLM42 run with det reqs"
    for r in vrecs:
        assert r.window >= 0 and r.occurrence >= 0
        assert r.n_match >= 0
        assert r.schedule.startswith("(")  # str(tuple(schedule))
        assert r.margin is not None and r.margin >= 0.0
    # within one window, the accepted candidates precede the verifier token
    assert any(not r.accepted for r in vrecs), "every window ends in a " \
        "verifier-token record (accepted=False)"
    # det requests under LLM42 never commit from the fast path
    det = {r.rid for r in done if r.sampling.is_deterministic}
    assert all(r.origin != "decode" for r in recs if r.rid in det)
    # the committing schedule for verify commits is the verify-grade one
    from repro.core.determinism import VERIFY_SCHEDULE
    assert all(r.schedule == str(tuple(VERIFY_SCHEDULE)) for r in vrecs)


def test_audit_rollback_semantics(model):
    cfg, params = model
    eng, done = _cached_run(cfg, params, "overlap", 1, True)
    recs = eng.obs.audit.records
    # DRIFTY forces flips: some splice rolled back, and its record says so
    assert any(r.rollback for r in recs), "DRIFTY run had no rollback"
    total_rollbacks = sum(r.num_rollbacks for r in done)
    assert total_rollbacks > 0
    # rollback victims were never committed => coverage is exact (checked
    # above) AND indices are dense per request
    for r in done:
        idxs = [rec.index for rec in eng.obs.audit.for_request(r.rid)]
        assert idxs == list(range(len(r.committed)))


def test_audit_coverage_errors_detects_problems():
    audit = AuditLog()
    req = type("R", (), {"rid": 1, "committed": [5, 6]})()
    audit.record(TokenProvenance(rid=1, index=0, token=5, origin="prefill",
                                 schedule="s"))
    errs = audit.coverage_errors([req])  # index 1 uncovered
    assert any("index 1" in e for e in errs)
    audit.record(TokenProvenance(rid=1, index=1, token=99, origin="decode",
                                 schedule="s"))
    errs = audit.coverage_errors([req])  # wrong token
    assert any("99" in e for e in errs)
    audit.record(TokenProvenance(rid=2, index=0, token=1, origin="decode",
                                 schedule="s"))
    errs = audit.coverage_errors([req])  # unknown rid
    assert any("unknown rid 2" in e for e in errs)


# ----------------------------------------------------------------------
# engine metrics + mem_stats shim
# ----------------------------------------------------------------------


def test_engine_metrics_snapshot(model):
    cfg, params = model
    eng, done = _cached_run(cfg, params, "overlap", 1, True)
    snap = eng.obs.metrics.snapshot()
    assert snap["engine.requests_finished"] == len(done)
    assert snap["tokens.committed"] == sum(len(r.committed) for r in done)
    assert snap["verify.rollbacks"] == sum(r.num_rollbacks for r in done)
    assert snap["tokens.recomputed"] == sum(
        r.num_recomputed_tokens for r in done
    )
    assert snap["verify.rollback_depth.count"] == snap["verify.rollbacks"]
    assert snap["latency.ttft.count"] == len(done)
    assert snap["latency.e2e.count"] == len(done)
    assert snap["engine.running"] == 0  # drained
    assert snap["engine.peak_running"] >= 1
    assert snap["blockpool.peak_blocks_in_use"] >= 1
    assert snap["verify.acceptance_ema.count"] == sum(
        1 for r in done if r.sampling.is_deterministic
    )
    # the catalog describes every snapshot series (histograms expand)
    catalog = {c["name"] for c in eng.obs.metrics.describe()}
    for key in snap:
        base = key.rsplit(".", 1)[0] if key.split(".")[-1] in (
            "count", "sum", "min", "max", "mean", "p50", "p90", "p99"
        ) else key
        assert base in catalog or key in catalog


def test_mem_stats_is_a_snapshot_shim(model):
    cfg, params = model
    eng, _ = _cached_run(cfg, params, "overlap", 1, True)
    ms = eng.mem_stats()
    snap = eng.obs.metrics.snapshot()
    assert ms["block_size"] == snap["blockpool.block_size"]
    assert ms["num_blocks"] == snap["blockpool.num_blocks"]
    assert ms["peak_blocks_in_use"] == snap["blockpool.peak_blocks_in_use"]
    assert ms["num_preemptions"] == snap["mem.preemptions"]
    assert ms["num_restores"] == snap["mem.restores"]
    assert ms["peak_running"] == snap["engine.peak_running"]
    assert ms["paged"] == bool(snap["blockpool.paged"])
    if eng.prefix_cache is not None:
        assert ms["prefix_hits"] == snap["prefixcache.hits"]
        assert ms["prefix_hit_tokens"] == snap["prefixcache.hit_tokens"]


def test_disabled_observability_is_null(model):
    cfg, params = model
    eng, _ = _cached_run(cfg, params, "overlap", 1, False)
    assert not eng.obs.tracer.enabled and not eng.obs.audit.enabled
    assert eng.obs.tracer.to_chrome_trace()["traceEvents"] == []
    # metrics stay live even with trace/audit off (mem_stats shim needs it)
    assert eng.obs.metrics.snapshot()["engine.requests_finished"] >= 1


# ----------------------------------------------------------------------
# persist.py tolerance comparator
# ----------------------------------------------------------------------


def test_persist_tolerance_classes():
    import importlib.util
    import pathlib

    spec = importlib.util.spec_from_file_location(
        "persist", pathlib.Path(__file__).parents[1] / "benchmarks"
        / "persist.py"
    )
    persist = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(persist)

    assert persist.tolerance("fig_x_tput", "us_per_call") == ("rel", 2.0)
    assert persist.tolerance("fig_x_ratio", "derived") == ("abs", 0.15)
    assert persist.tolerance("fig_x_ttft_p50_ms", "derived") == ("rel", 0.5)
    kind, _ = persist.tolerance("fig_x_verify_passes", "derived")
    assert kind == "relabs"

    committed = {
        "a_tput": {"name": "a_tput", "us_per_call": "", "derived": 100.0},
        "a_ratio": {"name": "a_ratio", "us_per_call": "", "derived": 1.0},
        "a_passes": {"name": "a_passes", "us_per_call": "", "derived": 4},
        "gone": {"name": "gone", "us_per_call": "", "derived": 1},
    }
    fresh = {
        "a_tput": {"name": "a_tput", "us_per_call": "", "derived": 120.0},
        "a_ratio": {"name": "a_ratio", "us_per_call": "", "derived": 1.5},
        "a_passes": {"name": "a_passes", "us_per_call": "", "derived": 5},
        "new": {"name": "new", "us_per_call": "", "derived": 1},
    }
    table = persist.compare_rows(committed, fresh, "t")
    verdict = {(m, c): ok for _, m, c, _, _, _, ok in table}
    assert verdict[("a_tput", "derived")] is True  # 20% < rel 0.5
    assert verdict[("a_ratio", "derived")] is False  # 0.5 > abs 0.15
    assert verdict[("a_passes", "derived")] is True  # +/-2 slack
    assert verdict[("gone", "-")] is False  # missing from fresh
    assert verdict[("new", "-")] is False  # missing from committed
