import sys

import jax
import numpy as np
import pytest

# Tests run on the single real CPU device; only launch/dryrun.py (run as a
# separate process) uses the 512-device simulation.  Keep f32 exactness.
jax.config.update("jax_enable_x64", False)

# Property tests import hypothesis; the hermetic container doesn't ship it.
# Install the deterministic fallback before test modules are collected (CI
# installs the real package via the [test] extra, so this is a no-op there).
try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    import importlib.util
    import os

    _spec = importlib.util.spec_from_file_location(
        "hypothesis",
        os.path.join(os.path.dirname(__file__), "_hypothesis_stub.py"),
    )
    _stub = importlib.util.module_from_spec(_spec)
    _spec.loader.exec_module(_stub)
    sys.modules["hypothesis"] = _stub
    sys.modules["hypothesis.strategies"] = _stub.strategies


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
