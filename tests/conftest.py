import jax
import numpy as np
import pytest

# Tests run on the single real CPU device; only launch/dryrun.py (run as a
# separate process) uses the 512-device simulation.  Keep f32 exactness.
jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
