"""Sharding-rule tests (pure logic on an AbstractMesh — no devices), plus
the mesh-scale determinism contract: committed streams bitwise-identical
across logical TP widths and replica counts, the pinned canonical tree
realized identically on real shard_map meshes (subprocess, faked host
devices), and the un-pinned fast path as negative control."""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.configs import get_config, get_smoke_config, list_archs
from repro.core.determinism import Mode, Schedule, matmul
from repro.distributed import sharding
from repro.launch.specs import INPUT_SHAPES, resolve_config
from repro.models import init_params
from repro.serving.engine import Engine
from repro.serving.request import Request, SamplingParams
from repro.serving.scheduler import (
    AdaptivePolicy,
    OverlapPolicy,
    PauseDecodePolicy,
)


def _mesh(multi=False):
    # jax 0.4.37 AbstractMesh signature: a tuple of (axis_name, size) pairs
    if multi:
        return AbstractMesh((("pod", 2), ("data", 16), ("model", 16)))
    return AbstractMesh((("data", 16), ("model", 16)))


class TestSpecFor:
    def test_basic_tp(self):
        m = _mesh()
        s = sharding.spec_for((4096, 14336), ("embed", "ffn"),
                              sharding.rules_serve(m), m)
        assert s == P(None, "model")

    def test_fsdp_train(self):
        m = _mesh()
        s = sharding.spec_for((4096, 14336), ("embed", "ffn"),
                              sharding.rules_train(m), m)
        assert s == P("data", "model")

    def test_divisibility_fallback(self):
        m = _mesh()
        # kv dim 1024 divides 16; 8 does not -> dropped to replicated
        s = sharding.spec_for((8,), ("kv",), sharding.rules_serve(m), m)
        assert s == P(None)

    def test_no_axis_reuse(self):
        m = _mesh()
        # experts->model and ffn->model would reuse 'model'; first dim wins
        s = sharding.spec_for((16, 4096, 8192), ("experts", "embed", "ffn"),
                              sharding.rules_serve(m), m)
        assert s == P("model", None, None)

    def test_multipod_fsdp_uses_both_data_axes(self):
        m = _mesh(multi=True)
        s = sharding.spec_for((8192, 1024), ("embed", "ffn"),
                              sharding.rules_train(m), m)
        assert s == P(("pod", "data"), "model")

    def test_multipod_nondivisible_drops_right(self):
        m = _mesh(multi=True)
        # 16 % (2*16) != 0 -> drop 'data' from the right, keep 'pod'? No:
        # the rule drops right-to-left until divisible: ('pod','data')->('pod',)
        s = sharding.spec_for((16,), ("embed",), sharding.rules_train(m), m)
        assert s == P("pod")


class TestParamPspecs:
    @pytest.mark.parametrize("arch", list_archs())
    def test_every_param_gets_a_valid_spec(self, arch):
        cfg = get_config(arch)
        m = _mesh(multi=True)
        specs = sharding.param_pspecs(cfg, m, sharding.rules_train(m))
        sizes = dict(zip(m.axis_names, m.axis_sizes))
        from repro.models.base import param_specs

        for (path, ps), (_, spec) in zip(
            jax.tree_util.tree_leaves_with_path(specs, is_leaf=lambda x: isinstance(x, P)),
            jax.tree_util.tree_leaves_with_path(param_specs(cfg)),
        ):
            used = set()
            for dim, part in zip(spec.shape, tuple(ps) + (None,) * 10):
                if part is None:
                    continue
                axes = (part,) if isinstance(part, str) else part
                prod = int(np.prod([sizes[a] for a in axes]))
                assert dim % prod == 0, (arch, path, spec.shape, ps)
                for a in axes:
                    assert a not in used, (arch, path, ps)
                    used.add(a)


class TestHostMesh:
    def test_non_divisible_model_axis_raises_readable(self):
        from repro.launch.mesh import make_host_mesh

        n = len(jax.devices())
        with pytest.raises(ValueError) as ei:
            make_host_mesh(model=n + 3)  # never divides
        msg = str(ei.value)
        # the message must name the actual device count and the remedy
        assert str(n) in msg
        assert "xla_force_host_platform_device_count" in msg

    def test_zero_model_axis_raises(self):
        from repro.launch.mesh import make_host_mesh

        with pytest.raises(ValueError):
            make_host_mesh(model=0)

    def test_divisible_model_axis_ok(self):
        from repro.launch.mesh import make_host_mesh

        m = make_host_mesh(model=1)
        assert m.axis_names == ("data", "model")


@pytest.fixture(scope="module")
def smoke_model():
    cfg = get_smoke_config("llama3-8b")
    return cfg, init_params(cfg, jax.random.key(0))


def _det_reqs(cfg, n=3, max_new=8):
    return [
        Request(
            rid=i, prompt=[(5 * i + j) % cfg.vocab_size for j in range(9)],
            sampling=SamplingParams(
                max_new_tokens=max_new, is_deterministic=True, seed=70 + i,
            ),
        )
        for i in range(n)
    ]


_SCHEDULERS = {
    "pause": PauseDecodePolicy,
    "overlap": OverlapPolicy,
    "adaptive": AdaptivePolicy,
}


class TestTPInvariantCommit:
    """The tentpole theorem at engine level: the fast path may run at any
    logical TP width, but commits replay under the canonical mesh schedule,
    so committed streams are bitwise TP-invariant."""

    @pytest.mark.parametrize("scheduler", sorted(_SCHEDULERS))
    def test_committed_streams_bitwise_across_tp(self, smoke_model,
                                                 scheduler):
        cfg, params = smoke_model
        streams = {}
        for tp in (1, 2, 4):
            eng = Engine(cfg, params, mode=Mode.LLM42, window=4, group=2,
                         max_batch=4, capacity=128,
                         scheduler=_SCHEDULERS[scheduler](), tp=tp)
            for r in _det_reqs(cfg):
                eng.submit(r)
            streams[tp] = {
                r.rid: tuple(r.committed) for r in eng.run()
            }
        assert streams[1] == streams[2] == streams[4]

    def test_fast_path_tp_variant_negative_control(self):
        """The un-pinned fast path MUST vary across TP widths — if it did
        not, the pinned commit tree would be vacuous (nothing to defend
        against) and the prover's negative control would be meaningless."""
        x = jax.random.normal(jax.random.key(3), (4, 64), jnp.bfloat16)
        w = jax.random.normal(jax.random.key(4), (64, 32), jnp.bfloat16)
        fast1 = Schedule(splits=2, combine_dtype="bfloat16",
                         tp_shards=1, tp_pinned=False)
        fast4 = Schedule(splits=2, combine_dtype="bfloat16",
                         tp_shards=4, tp_pinned=False)
        assert not bool(jnp.array_equal(matmul(x, w, fast1),
                                        matmul(x, w, fast4)))

    def test_pinned_tree_is_tp_invariant_logically(self):
        """The canonical pinned decomposition is a fixed logical program:
        the same schedule evaluates to the same bits no matter what width
        the caller models (it never reads a mesh)."""
        from repro.core.determinism import VERIFY_SCHEDULE

        x = jax.random.normal(jax.random.key(5), (4, 64), jnp.bfloat16)
        w = jax.random.normal(jax.random.key(6), (64, 32), jnp.bfloat16)
        a = matmul(x, w, VERIFY_SCHEDULE)
        b = matmul(x, w, VERIFY_SCHEDULE._replace())  # fresh equal schedule
        assert bool(jnp.array_equal(a, b))

    def test_tp_matmul_mesh_widths_bitwise(self):
        """Real shard_map execution: the pinned canonical tree commits the
        same bits on host meshes of width 1, 2 and 4, and equals the
        logical (unsharded) canonical matmul; the un-pinned fast schedule
        diverges between widths (negative control).  Runs in a subprocess
        because the faked 8-device host platform must be configured before
        jax initializes."""
        script = textwrap.dedent("""
            import jax, jax.numpy as jnp
            from repro.core.determinism import (
                Schedule, VERIFY_SCHEDULE, matmul)
            from repro.distributed.sharding import tp_matmul
            from repro.launch.mesh import make_host_mesh

            x = jax.random.normal(jax.random.key(0), (4, 64), jnp.bfloat16)
            w = jax.random.normal(jax.random.key(1), (64, 32), jnp.bfloat16)
            ref = matmul(x, w, VERIFY_SCHEDULE)
            for d in (1, 2, 4):
                mesh = make_host_mesh(model=d)
                got = tp_matmul(x, w, mesh, schedule=VERIFY_SCHEDULE)
                assert jnp.array_equal(ref, got), f"width {d} diverged"
            fast = Schedule(splits=1, combine_dtype="bfloat16",
                            tp_shards=4, tp_pinned=False)
            a = tp_matmul(x, w, make_host_mesh(model=1), schedule=fast)
            b = tp_matmul(x, w, make_host_mesh(model=4), schedule=fast)
            assert not jnp.array_equal(a, b), (
                "un-pinned fast path failed to diverge across widths")
            print("ALL-OK")
        """)
        env = dict(os.environ)
        env["XLA_FLAGS"] = (
            env.get("XLA_FLAGS", "")
            + " --xla_force_host_platform_device_count=8"
        ).strip()
        env["PYTHONPATH"] = (
            os.path.join(os.path.dirname(__file__), "..", "src")
            + os.pathsep + env.get("PYTHONPATH", "")
        )
        proc = subprocess.run(
            [sys.executable, "-c", script], env=env,
            capture_output=True, text=True, timeout=600,
        )
        assert proc.returncode == 0, proc.stderr
        assert "ALL-OK" in proc.stdout


class TestRouterDeterminism:
    """Cluster layer of the contract: same arrival trace => same
    request->replica assignment => same committed streams, bitwise, at any
    replica count."""

    def _once(self, smoke_model, n_replicas):
        from repro.cluster import Cluster, run_online

        cfg, params = smoke_model

        def make_engine(idx):
            return Engine(cfg, params, mode=Mode.LLM42, window=4, group=2,
                          max_batch=2, capacity=128)

        cluster = Cluster(make_engine, n_replicas)
        reqs = _det_reqs(cfg, n=6)
        arrivals = [0.0] * 6
        res = run_online(cluster, cfg, list(zip(reqs, arrivals)))
        streams = {r.rid: tuple(r.committed) for r in cluster.finished}
        return res.assignment, streams

    def test_streams_bitwise_across_replica_counts(self, smoke_model):
        a1, s1 = self._once(smoke_model, 1)
        a2, s2 = self._once(smoke_model, 2)
        a4, s4 = self._once(smoke_model, 4)
        assert len(s1) == 6
        assert s1 == s2 == s4
        # more replicas actually get used when load warrants it
        assert set(a2.values()) == {0, 1}
        assert set(a4.values()) == {0, 1, 2, 3}

    def test_assignment_is_reproducible(self, smoke_model):
        a, s = self._once(smoke_model, 2)
        b, t = self._once(smoke_model, 2)
        assert a == b
        assert s == t


class TestCacheSpecs:
    @pytest.mark.parametrize("arch", list_archs())
    @pytest.mark.parametrize("shape", list(INPUT_SHAPES))
    def test_cache_specs_divisible(self, arch, shape):
        cfg, skip = resolve_config(arch, shape)
        if skip or INPUT_SHAPES[shape]["kind"] == "train":
            pytest.skip("n/a")
        from repro.launch.specs import decode_capacity
        from repro.models.transformer import cache_spec

        m = _mesh()
        meta = INPUT_SHAPES[shape]
        cap = decode_capacity(cfg, meta["seq"])
        tree = sharding.cache_pspec_tree(cfg, m, meta["batch"], cap)
        spec = cache_spec(cfg, meta["batch"], cap)
        sizes = dict(zip(m.axis_names, m.axis_sizes))
        for ps, s in zip(jax.tree_util.tree_leaves(
                tree, is_leaf=lambda x: isinstance(x, P)),
                jax.tree_util.tree_leaves(spec)):
            for dim, part in zip(s.shape, tuple(ps) + (None,) * 10):
                if part is None:
                    continue
                axes = (part,) if isinstance(part, str) else part
                prod = int(np.prod([sizes[a] for a in axes]))
                assert dim % prod == 0, (arch, shape, s.shape, ps)
