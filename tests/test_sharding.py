"""Sharding-rule tests (pure logic on an AbstractMesh — no devices)."""

import jax
import numpy as np
import pytest
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.configs import get_config, list_archs
from repro.distributed import sharding
from repro.launch.specs import INPUT_SHAPES, resolve_config


def _mesh(multi=False):
    # jax 0.4.37 AbstractMesh signature: a tuple of (axis_name, size) pairs
    if multi:
        return AbstractMesh((("pod", 2), ("data", 16), ("model", 16)))
    return AbstractMesh((("data", 16), ("model", 16)))


class TestSpecFor:
    def test_basic_tp(self):
        m = _mesh()
        s = sharding.spec_for((4096, 14336), ("embed", "ffn"),
                              sharding.rules_serve(m), m)
        assert s == P(None, "model")

    def test_fsdp_train(self):
        m = _mesh()
        s = sharding.spec_for((4096, 14336), ("embed", "ffn"),
                              sharding.rules_train(m), m)
        assert s == P("data", "model")

    def test_divisibility_fallback(self):
        m = _mesh()
        # kv dim 1024 divides 16; 8 does not -> dropped to replicated
        s = sharding.spec_for((8,), ("kv",), sharding.rules_serve(m), m)
        assert s == P(None)

    def test_no_axis_reuse(self):
        m = _mesh()
        # experts->model and ffn->model would reuse 'model'; first dim wins
        s = sharding.spec_for((16, 4096, 8192), ("experts", "embed", "ffn"),
                              sharding.rules_serve(m), m)
        assert s == P("model", None, None)

    def test_multipod_fsdp_uses_both_data_axes(self):
        m = _mesh(multi=True)
        s = sharding.spec_for((8192, 1024), ("embed", "ffn"),
                              sharding.rules_train(m), m)
        assert s == P(("pod", "data"), "model")

    def test_multipod_nondivisible_drops_right(self):
        m = _mesh(multi=True)
        # 16 % (2*16) != 0 -> drop 'data' from the right, keep 'pod'? No:
        # the rule drops right-to-left until divisible: ('pod','data')->('pod',)
        s = sharding.spec_for((16,), ("embed",), sharding.rules_train(m), m)
        assert s == P("pod")


class TestParamPspecs:
    @pytest.mark.parametrize("arch", list_archs())
    def test_every_param_gets_a_valid_spec(self, arch):
        cfg = get_config(arch)
        m = _mesh(multi=True)
        specs = sharding.param_pspecs(cfg, m, sharding.rules_train(m))
        sizes = dict(zip(m.axis_names, m.axis_sizes))
        from repro.models.base import param_specs

        for (path, ps), (_, spec) in zip(
            jax.tree_util.tree_leaves_with_path(specs, is_leaf=lambda x: isinstance(x, P)),
            jax.tree_util.tree_leaves_with_path(param_specs(cfg)),
        ):
            used = set()
            for dim, part in zip(spec.shape, tuple(ps) + (None,) * 10):
                if part is None:
                    continue
                axes = (part,) if isinstance(part, str) else part
                prod = int(np.prod([sizes[a] for a in axes]))
                assert dim % prod == 0, (arch, path, spec.shape, ps)
                for a in axes:
                    assert a not in used, (arch, path, ps)
                    used.add(a)


class TestCacheSpecs:
    @pytest.mark.parametrize("arch", list_archs())
    @pytest.mark.parametrize("shape", list(INPUT_SHAPES))
    def test_cache_specs_divisible(self, arch, shape):
        cfg, skip = resolve_config(arch, shape)
        if skip or INPUT_SHAPES[shape]["kind"] == "train":
            pytest.skip("n/a")
        from repro.launch.specs import decode_capacity
        from repro.models.transformer import cache_spec

        m = _mesh()
        meta = INPUT_SHAPES[shape]
        cap = decode_capacity(cfg, meta["seq"])
        tree = sharding.cache_pspec_tree(cfg, m, meta["batch"], cap)
        spec = cache_spec(cfg, meta["batch"], cap)
        sizes = dict(zip(m.axis_names, m.axis_sizes))
        for ps, s in zip(jax.tree_util.tree_leaves(
                tree, is_leaf=lambda x: isinstance(x, P)),
                jax.tree_util.tree_leaves(spec)):
            for dim, part in zip(s.shape, tuple(ps) + (None,) * 10):
                if part is None:
                    continue
                axes = (part,) if isinstance(part, str) else part
                prod = int(np.prod([sizes[a] for a in axes]))
                assert dim % prod == 0, (arch, shape, s.shape, ps)
