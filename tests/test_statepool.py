"""Double-buffered state pool: tree plumbing, depth accounting, and the
engine-level acceptance criterion — recurrent/hybrid archs sustain
speculation depth >= 2 (previously hard-capped at one in-flight window)
with committed streams bitwise identical to pause-decode."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_smoke_config
from repro.core.determinism import Mode, ReductionPolicy
from repro.models import init_params
from repro.serving import statepool
from repro.serving.engine import Engine
from repro.serving.request import Request, SamplingParams
from repro.serving.scheduler import OverlapPolicy, PauseDecodePolicy

DRIFTY = ReductionPolicy(
    thresholds=((2, 16), (4, 8), (16, 4)), combine_dtype="bfloat16"
)


class TestStateTrees:
    def test_state_spec_keeps_only_recurrent_leaves(self):
        cfg = get_smoke_config("jamba-1.5-large-398b")  # attn + mamba mix
        spec = statepool.state_spec(cfg, batch=3)
        leaves = jax.tree_util.tree_leaves(spec)
        assert leaves, "hybrid arch must carry recurrent state"
        kinds = {cfg.layer_kind(i) for i in range(cfg.num_layers)}
        assert "attn" in kinds and "mamba" in kinds
        # attention periods collapse to None (empty nodes), so every leaf
        # that remains is recurrent state
        flat, _ = jax.tree_util.tree_flatten_with_path(spec)
        for path, leaf in flat:
            assert path[-1].key in statepool.RECURRENT_KEYS

    def test_attention_arch_pool_is_inert(self):
        cfg = get_smoke_config("llama3-8b")
        pool = statepool.StatePool(cfg, num_slots=4, depth=2)
        assert not pool.active
        assert pool.anchor is None and pool.ring == []
        pool.set_commit_point({}, 0)  # device methods are no-ops
        assert pool.restore({"x": 1}, 0, 0) == {"x": 1}

    def test_gather_scatter_roundtrip(self):
        cfg = get_smoke_config("rwkv6-3b")
        state = statepool.init_state(cfg, batch=4)
        slots = jnp.array([1, 3], jnp.int32)
        rows = statepool.gather_rows(state, slots)
        bumped = jax.tree_util.tree_map(lambda a: a + 1.0, rows)
        state2 = statepool.scatter_rows(state, slots, bumped)
        back = statepool.gather_rows(state2, slots)
        for a, b in zip(jax.tree_util.tree_leaves(back),
                        jax.tree_util.tree_leaves(bumped)):
            assert jnp.allclose(a, b.astype(a.dtype))
        # untouched slots stay zero
        rest = statepool.gather_rows(state2, jnp.array([0, 2], jnp.int32))
        assert all(
            jnp.all(leaf == 0) for leaf in jax.tree_util.tree_leaves(rest)
        )

    def test_select_index_picks_per_row_positions(self):
        """per_pos[j] = state after window input j; selection is per-row.
        Attention placeholders — scalar or scan-stacked — drop to None."""
        L, B, W = 2, 3, 4
        pp = {
            "blocks": {
                "0": jnp.zeros((L,)),  # scan-stacked attention placeholder
                "1": {"ssm": jnp.arange(L * B * W * 5, dtype=jnp.float32)
                      .reshape(L, B, W, 5)},
            },
            "head_layers": {
                "0": 0.0,  # scalar attention placeholder
                "1": {"wkv": jnp.arange(B * W * 3, dtype=jnp.float32)
                      .reshape(B, W, 3)},
            },
        }
        idx = jnp.array([0, 2, 3], jnp.int32)
        rows = statepool.select_index(pp, idx)
        assert rows["blocks"]["0"] is None
        assert rows["head_layers"]["0"] is None
        picked = rows["blocks"]["1"]["ssm"]  # (L, B, 5)
        for b in range(B):
            assert jnp.array_equal(
                picked[:, b], pp["blocks"]["1"]["ssm"][:, b, int(idx[b])]
            )
        head = rows["head_layers"]["1"]["wkv"]  # (B, 3)
        for b in range(B):
            assert jnp.array_equal(
                head[b], pp["head_layers"]["1"]["wkv"][b, int(idx[b])]
            )

    def test_checkpoint_and_restore_roundtrip(self):
        """A window checkpoint scattered to the ring comes back through
        restore() into both the live cache and the anchor."""
        cfg = get_smoke_config("rwkv6-3b")
        pool = statepool.StatePool(cfg, num_slots=2, depth=2)
        assert pool.active
        from repro.models.transformer import init_cache

        cache = init_cache(cfg, 3, 16)  # 2 slots + scratch
        rows = statepool.rows_from_cache(cache, jnp.array([1], jnp.int32))
        marked = jax.tree_util.tree_map(lambda a: a + 7.0, rows)
        pool.checkpoint([1], [1], marked)
        cache2 = pool.restore(cache, slot=1, ring_idx=1)
        live = statepool.rows_from_cache(cache2, jnp.array([1], jnp.int32))
        anchored = statepool.gather_rows(
            pool.anchor, jnp.array([1], jnp.int32)
        )
        for got, want in zip(jax.tree_util.tree_leaves(live),
                             jax.tree_util.tree_leaves(marked)):
            assert jnp.allclose(got.astype(jnp.float32),
                                want.astype(jnp.float32))
        for got, want in zip(jax.tree_util.tree_leaves(anchored),
                             jax.tree_util.tree_leaves(marked)):
            assert jnp.allclose(got.astype(jnp.float32),
                                want.astype(jnp.float32))

    def test_depth_accounting(self):
        cfg = get_smoke_config("llama3-8b")
        pool = statepool.StatePool(cfg, num_slots=4, depth=4)
        assert pool.note_submit(0, extent=10) == 1
        assert pool.note_submit(0, extent=20) == 2
        assert pool.note_submit(1, extent=5) == 1
        assert pool.peak_depth == 2
        assert pool.peak_extent == 20
        pool.note_splice(0)
        assert pool.depth_of(0) == 1
        pool.note_splice(0, flushed=0)
        assert pool.depth_of(0) == 0
        pool.note_submit(1, extent=5)
        pool.note_splice(1, flushed=1)  # rollback cascade drops both
        assert pool.depth_of(1) == 0
        pool.note_submit(2, extent=1)
        pool.note_release(2)
        assert pool.depth_of(2) == 0


def _reqs(cfg, rids, det, max_new=14):
    return [
        Request(
            rid=i, prompt=[(5 * i + j) % cfg.vocab_size for j in range(9)],
            sampling=SamplingParams(
                max_new_tokens=max_new, is_deterministic=(i in det),
                seed=70 + i,
            ),
        )
        for i in rids
    ]


@pytest.mark.parametrize("arch", ["rwkv6-3b", "jamba-1.5-large-398b"])
class TestRecurrentDepth:
    """Acceptance criterion: ssm (rwkv6) and hybrid (jamba, with mamba
    layers) configs sustain speculation depth >= 2 bitwise-identically."""

    _models = {}

    def _model(self, arch):
        if arch not in self._models:
            cfg = get_smoke_config(arch)
            self._models[arch] = (cfg, init_params(cfg, jax.random.key(0)))
        return self._models[arch]

    def _run(self, arch, scheduler, depth=1, **kw):
        cfg, params = self._model(arch)
        eng = Engine(cfg, params, mode=Mode.LLM42, policy=DRIFTY, window=5,
                     group=2, max_batch=8, capacity=256, scheduler=scheduler,
                     spec_depth=depth, **kw)
        for r in _reqs(cfg, [0, 1, 2, 3], {0, 2}):
            eng.submit(r)
        return {r.rid: r for r in eng.run()}, eng

    def test_depth_two_bitwise_and_exercised(self, arch):
        base, _ = self._run(arch, PauseDecodePolicy())
        got, eng = self._run(arch, OverlapPolicy(), depth=2,
                             verify_latency_ms=20.0)
        for rid in (0, 2):
            assert got[rid].committed == base[rid].committed, (arch, rid)
        # previously hard-capped at 1: the pool must prove depth 2 happened
        assert eng.statepool.peak_depth >= 2, arch
        # the drifty policy flips: cascade rollbacks must actually have
        # exercised the restore path, not just the happy chain
        assert sum(r.num_rollbacks for r in got.values()) > 0, arch

    def test_deep_pipeline_with_cascades(self, arch):
        base, _ = self._run(arch, PauseDecodePolicy())
        got, eng = self._run(arch, OverlapPolicy(), depth=4,
                             verify_latency_ms=50.0)
        for rid in (0, 2):
            assert got[rid].committed == base[rid].committed, (arch, rid)
        assert eng.statepool.peak_depth >= 3, arch
