"""Unit tests for the reduction-schedule machinery (paper §2.2/O2/O3)."""

import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.core.determinism import (
    FAST_PATH_POLICY,
    Schedule,
    VERIFY_SCHEDULE,
    matmul,
    segment_reduce_sum,
)


class TestSchedulePolicy:
    def test_small_batch_splits_more(self):
        p = FAST_PATH_POLICY
        assert p.schedule_for(1).splits > p.schedule_for(100).splits

    def test_schedule_is_shape_function(self):
        # O2: same batch size -> same schedule, always
        for b in (1, 3, 17, 64, 500):
            assert FAST_PATH_POLICY.schedule_for(b) == FAST_PATH_POLICY.schedule_for(b)

    def test_verify_schedule_is_unsplit(self):
        assert VERIFY_SCHEDULE.splits == 1
        assert VERIFY_SCHEDULE.kv_splits == 1
        assert VERIFY_SCHEDULE.moe_no_drop


class TestScheduledMatmul:
    @settings(max_examples=20, deadline=None)
    @given(
        m=st.integers(1, 9),
        k=st.sampled_from([16, 48, 128]),
        n=st.integers(1, 17),
        splits=st.sampled_from([1, 2, 4]),
    )
    def test_same_schedule_bitwise(self, m, k, n, splits):
        """O2: one schedule, one result — bitwise."""
        kx, kw = jax.random.split(jax.random.key(m * 1000 + k + n))
        x = jax.random.normal(kx, (m, k))
        w = jax.random.normal(kw, (k, n))
        s = Schedule(splits=splits, combine_dtype="bfloat16")
        a = matmul(x, w, s)
        b = matmul(x, w, s)
        assert (a == b).all()

    def test_different_splits_drift(self):
        kx, kw = jax.random.split(jax.random.key(7))
        x = jax.random.normal(kx, (8, 1024))
        w = jax.random.normal(kw, (1024, 64))
        a = matmul(x, w, Schedule(splits=1, combine_dtype="bfloat16"))
        b = matmul(x, w, Schedule(splits=8, combine_dtype="bfloat16"))
        # different reduction trees must not agree bitwise at this size
        assert not (a == b).all()
        # but they are numerically close (it is *rounding*, not error)
        assert jnp.allclose(a, b, atol=0.5, rtol=0.1)

    def test_position_invariance(self):
        """O2/O3: a row's result is independent of the other rows, given a
        fixed schedule — the property the verifier's guarantee rests on."""
        kx, kw = jax.random.split(jax.random.key(3))
        x = jax.random.normal(kx, (16, 256))
        w = jax.random.normal(kw, (256, 32))
        s = Schedule(splits=4, combine_dtype="bfloat16")
        full = matmul(x, w, s)
        perm = jnp.array([5, 3, 11, 0, 15, 8, 2, 9, 1, 14, 7, 4, 10, 6, 13, 12])
        permuted = matmul(x[perm], w, s)
        assert (full[perm] == permuted).all()

    def test_split1_matches_f32_reference(self):
        kx, kw = jax.random.split(jax.random.key(5))
        x = jax.random.normal(kx, (4, 64))
        w = jax.random.normal(kw, (64, 8))
        got = matmul(x, w, VERIFY_SCHEDULE)
        want = jnp.matmul(x, w, precision=jax.lax.Precision.HIGHEST)
        assert jnp.allclose(got, want, atol=1e-6)

    @settings(max_examples=15, deadline=None)
    @given(
        k=st.sampled_from([32, 100, 256]),
        splits=st.sampled_from([2, 3, 5]),
    )
    def test_uneven_split_still_deterministic(self, k, splits):
        kx, kw = jax.random.split(jax.random.key(k))
        x = jax.random.normal(kx, (4, k))
        w = jax.random.normal(kw, (k, 8))
        s = Schedule(splits=splits, combine_dtype="bfloat16")
        assert (matmul(x, w, s) == matmul(x, w, s)).all()


class TestSegmentReduce:
    def test_schedule_dependent_norm_reduction(self):
        x = jax.random.normal(jax.random.key(0), (4, 1024)) * 100
        a = segment_reduce_sum(x, -1, Schedule(splits=1))
        b = segment_reduce_sum(x, -1, Schedule(splits=8, combine_dtype="bfloat16"))
        assert not (a == b).all()
        assert jnp.allclose(a, b, rtol=0.05, atol=10.0)
