"""Paged KV memory subsystem — engine integration tests.

The determinism contract under memory management: committed streams of
deterministic requests are bitwise identical with the prefix cache on vs
off, across block sizes, and under adversarial preemption / restore
schedules — on every scheduler and spec depth, for attention and
recurrent/hybrid archs.  Plus the block-accounting admission guard and the
preemption lane's liveness under genuine pool pressure.
"""

import jax
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_smoke_config
from repro.core.determinism import Mode, ReductionPolicy
from repro.models import init_params
from repro.serving.engine import Engine
from repro.serving.request import Request, SamplingParams, State
from repro.serving.scheduler import (
    AdaptivePolicy,
    BlockMemoryPolicy,
    OverlapPolicy,
    PauseDecodePolicy,
)

#: aggressive drift so rollbacks actually happen at toy scale
DRIFTY = ReductionPolicy(
    thresholds=((2, 16), (4, 8), (16, 4)), combine_dtype="bfloat16"
)

_MODELS = {}


def _model(arch="llama3-8b"):
    if arch not in _MODELS:
        cfg = get_smoke_config(arch)
        _MODELS[arch] = (cfg, init_params(cfg, jax.random.key(0)))
    return _MODELS[arch]


SYS_LEN = 40  # shared system prompt (2.5 blocks at the default size)


def _reqs(cfg, rids, det, max_new=12, shared_sys=False):
    sys_prompt = [(3 * j + 1) % cfg.vocab_size for j in range(SYS_LEN)]
    out = []
    for i in rids:
        tail = [(5 * i + j) % cfg.vocab_size for j in range(9)]
        out.append(Request(
            rid=i, prompt=(sys_prompt + tail[:5]) if shared_sys else tail,
            sampling=SamplingParams(
                max_new_tokens=max_new, is_deterministic=(i in det),
                seed=70 + i,
            ),
        ))
    return out


def _run(cfg, params, requests, *, preempt_at=(), preempt_rid=0, window=5,
         group=2, scheduler=None, **kw):
    eng = Engine(cfg, params, mode=Mode.LLM42, policy=DRIFTY, window=window,
                 group=group, max_batch=8, capacity=128,
                 scheduler=scheduler, **kw)
    for r in requests:
        eng.submit(r)
    it = 0
    while eng.step():
        it += 1
        if it in preempt_at:
            for r in list(eng.running):
                if r.rid == preempt_rid and r.state is not State.PREFILLING:
                    eng.preempt(r)
                    break
        assert it < 5000, "engine did not drain"
    return {r.rid: r for r in eng.finished}, eng


def _det_streams(done, det):
    return {rid: done[rid].committed for rid in det}


# ----------------------------------------------------------------------
# prefix cache: sharing is commit-aware and bitwise-invisible
# ----------------------------------------------------------------------


class TestPrefixCacheDeterminism:
    def _staggered(self, cfg, params, prefix_cache, block_size=16):
        eng = Engine(cfg, params, mode=Mode.LLM42, policy=DRIFTY, window=5,
                     group=2, max_batch=4, capacity=128,
                     prefix_cache=prefix_cache, block_size=block_size,
                     prefill_chunk=8)
        det = {0, 2}
        reqs = _reqs(cfg, [0, 1, 2, 3], det, shared_sys=True)
        eng.submit(reqs[0])
        it, submitted = 0, 1
        while True:
            alive = eng.step()
            it += 1
            if it in (8, 16, 24) and submitted < 4:
                eng.submit(reqs[submitted])
                submitted += 1
            if not alive and submitted >= 4:
                break
            assert it < 5000
        return _det_streams({r.rid: r for r in eng.finished}, det), eng

    def test_cache_on_off_bitwise_identical_and_hits(self):
        cfg, params = _model()
        base, _ = self._staggered(cfg, params, False)
        got, eng = self._staggered(cfg, params, True)
        assert got == base
        # late arrivals really shared the system prompt's blocks
        assert eng.prefix_cache.hits >= 2
        assert eng.prefix_cache.hit_tokens >= 2 * 32

    @pytest.mark.parametrize("block_size", [8, 64])
    def test_block_sizes_bitwise_identical(self, block_size):
        cfg, params = _model()
        base, _ = self._staggered(cfg, params, False)
        got, eng = self._staggered(cfg, params, True, block_size=block_size)
        assert got == base, block_size

    def test_cache_hit_skips_prefill_work(self):
        cfg, params = _model()
        _, eng = self._staggered(cfg, params, True)
        hits = [e for e in eng.events if e.get("kind") == "cache_hit"]
        assert hits and all(e["tokens"] >= 32 for e in hits)
        # hit requests chunk-prefill only the tail: their first chunk
        # event starts past the cached prefix
        hit_rids = {e["rid"] for e in hits}
        from repro.serving.costmodel import flatten_events
        for rid in hit_rids:
            chunks = [e for e in flatten_events(eng.events)
                      if e.get("kind") == "prefill_chunk"
                      and e.get("rid") == rid]
            assert chunks and min(c["start"] for c in chunks) >= 32

    def test_nondet_output_is_never_cached(self):
        """Commit-aware rule: generated tokens enter the radix tree only
        when their KV is deterministic — a NONDET-mode engine may cache
        prompts (fixed-schedule prefill) but never fast-path output."""
        cfg, params = _model()
        eng = Engine(cfg, params, mode=Mode.NONDET, max_batch=4,
                     capacity=128, prefill_chunk=8)
        for r in _reqs(cfg, [0, 1], set(), shared_sys=True):
            eng.submit(r)
        eng.run()
        bs = eng.pool.block_size
        max_prompt_blocks = (SYS_LEN + 5) // bs
        # every cached chain is a prompt prefix: no node deeper than the
        # prompt's whole-block count
        assert eng.prefix_cache.size <= 2 * max_prompt_blocks

        def depth(node, d=0):
            return max([d] + [depth(c, d + 1)
                              for c in node.children.values()])

        assert depth(eng.prefix_cache.root) <= max_prompt_blocks

    def test_det_output_extends_the_cache_at_retirement(self):
        cfg, params = _model()
        done, eng = _run(cfg, params, _reqs(cfg, [0], {0}, max_new=30),
                         scheduler=OverlapPolicy(), prefix_cache=True,
                         block_size=8)
        r = done[0]
        # prompt (9) + committed[:-1] (29) = 38 tokens -> 4 full 8-blocks,
        # deeper than the 1-block prompt prefix alone
        assert eng.prefix_cache.size > r.prompt_len // 8


# ----------------------------------------------------------------------
# admission guard: block accounting
# ----------------------------------------------------------------------


class TestBlockCapacityGuard:
    def test_pool_block_supply_bounds_admission(self):
        """ISSUE 5 satellite: the submit guard derives from block-pool
        accounting.  A pool of 4 x 16-token blocks holds 64 positions:
        prompt 21 + max_new 43 (need 64 = 4 blocks) fits exactly; one more
        token needs a 5th block and is rejected — even though the
        per-request capacity (128) would allow it."""
        cfg, params = _model()
        eng = Engine(cfg, params, mode=Mode.NONDET, max_batch=2,
                     capacity=128, num_blocks=4)
        eng.submit(Request(rid=0, prompt=[1] * 21,
                           sampling=SamplingParams(max_new_tokens=43)))
        with pytest.raises(ValueError, match="cannot fit"):
            eng.submit(Request(rid=1, prompt=[1] * 21,
                               sampling=SamplingParams(max_new_tokens=44)))

    def test_det_requests_reserve_verify_rows_in_blocks(self):
        """The spec_depth x (W-1) + 1 verify-row reservation rides the
        block accounting: depth 3, W 8 => 22 extra rows."""
        cfg, params = _model()
        eng = Engine(cfg, params, mode=Mode.LLM42, window=8, max_batch=2,
                     capacity=128, spec_depth=3, num_blocks=4)
        # 21 + 21 + 22 = 64 == 4 blocks exactly
        eng.submit(Request(rid=0, prompt=[1] * 21, sampling=SamplingParams(
            max_new_tokens=21, is_deterministic=True)))
        with pytest.raises(ValueError, match="cannot fit"):
            eng.submit(Request(rid=1, prompt=[1] * 21,
                               sampling=SamplingParams(
                                   max_new_tokens=22, is_deterministic=True)))

    def test_queued_requests_wait_for_free_blocks(self):
        """Transient pressure queues instead of rejecting: both requests
        fit the pool alone but not together; the engine serializes them
        through free-block admission and both finish."""
        cfg, params = _model()
        eng = Engine(cfg, params, mode=Mode.NONDET, max_batch=4,
                     capacity=128, num_blocks=5)
        for i in range(2):
            eng.submit(Request(rid=i, prompt=[1 + i] * 30,
                               sampling=SamplingParams(max_new_tokens=30)))
        done = eng.run()
        assert len(done) == 2
        assert all(len(r.committed) == 30 for r in done)


# ----------------------------------------------------------------------
# preemption / restore
# ----------------------------------------------------------------------


SCHEDULERS = {
    "pause": PauseDecodePolicy,
    "overlap": OverlapPolicy,
    "adaptive": AdaptivePolicy,
}


class TestPreemptionDeterminism:
    def test_all_schedulers_and_depths_bitwise_identical(self):
        """Acceptance criterion: forced preemption/restore schedules on
        all schedulers and spec depths {1, 4} never move a committed
        token."""
        cfg, params = _model()
        det = {0, 2}
        reqs = lambda: _reqs(cfg, [0, 1, 2, 3], det)  # noqa: E731
        base, _ = _run(cfg, params, reqs(), scheduler=PauseDecodePolicy())
        base = _det_streams(base, det)
        for name, mk in SCHEDULERS.items():
            for depth in (1, 4):
                done, eng = _run(cfg, params, reqs(), scheduler=mk(),
                                 spec_depth=depth, preempt_at=(5, 11))
                assert _det_streams(done, det) == base, (name, depth)
                assert eng.num_preemptions >= 1, (name, depth)
                assert eng.num_restores >= 1, (name, depth)

    @pytest.mark.parametrize("arch", ["rwkv6-3b", "jamba-1.5-large-398b"])
    def test_recurrent_archs_restore_bitwise(self, arch):
        """Eviction/restore replays committed tokens through the chunked
        prefill lane — the replay starts from a pristine state row, so
        ssm/hybrid state is rebuilt bitwise (the live state at preemption
        is post-speculation and must NOT leak into the replay)."""
        cfg, params = _model(arch)
        det = {0, 2}
        reqs = lambda: _reqs(cfg, [0, 1, 2, 3], det)  # noqa: E731
        base, _ = _run(cfg, params, reqs(), scheduler=PauseDecodePolicy())
        base = _det_streams(base, det)
        for depth, pre in ((1, (6,)), (4, (5, 12))):
            done, eng = _run(cfg, params, reqs(), scheduler=OverlapPolicy(),
                             spec_depth=depth, preempt_at=pre)
            assert _det_streams(done, det) == base, (arch, depth)
            assert eng.num_restores >= 1

    def test_memory_pressure_preempts_and_drains(self):
        """An undersized pool triggers REAL (policy-driven) preemption:
        the run still drains, streams match, victims restore."""
        cfg, params = _model()
        det = {0, 2}
        base, _ = _run(cfg, params,
                       _reqs(cfg, [0, 1, 2, 3], det, shared_sys=True),
                       scheduler=PauseDecodePolicy())
        base = _det_streams(base, det)
        done, eng = _run(
            cfg, params, _reqs(cfg, [0, 1, 2, 3], det, shared_sys=True),
            scheduler=OverlapPolicy(), num_blocks=14, prefill_chunk=8,
            mem_policy=BlockMemoryPolicy(restore_cooldown=2),
        )
        assert _det_streams(done, det) == base
        assert eng.num_preemptions >= 1 and eng.num_restores >= 1

    def test_preempted_request_keeps_slot_and_stats(self):
        cfg, params = _model()
        done, eng = _run(cfg, params, _reqs(cfg, [0, 1], {0}),
                         scheduler=OverlapPolicy(), preempt_at=(5,))
        r = done[0]
        assert r.num_preemptions == 1
        assert r.finished() and len(r.committed) == 12
        assert eng.restored_tokens > 0

    def test_preempting_a_finished_flush_retires(self):
        """A victim whose flushed verdicts complete its budget retires on
        the spot instead of entering the restore lane."""
        cfg, params = _model()
        done, eng = _run(cfg, params, _reqs(cfg, [0, 1], {0}, max_new=4),
                         scheduler=OverlapPolicy(), preempt_at=(4, 5, 6, 7))
        assert done[0].finished()
        assert not eng.preempted

    _base = {}

    @settings(max_examples=5, deadline=None)
    @given(
        pre1=st.integers(4, 9), pre2=st.integers(10, 16),
        rid=st.integers(0, 3), block_size=st.sampled_from([8, 16, 64]),
        cache=st.booleans(),
        latency=st.lists(st.integers(1, 7), min_size=2, max_size=6),
    )
    def test_adversarial_eviction_and_landing_schedules(
            self, pre1, pre2, rid, block_size, cache, latency):
        """Hypothesis sweep (ISSUE 5 satellite): random eviction/restore
        schedules combined with adversarial verdict-landing schedules,
        across block sizes and cache on/off — committed streams must stay
        bitwise identical to a no-preemption run.  (Falls back to the
        deterministic stub sweep without hypothesis.)"""
        cfg, params = _model()
        det = {0, 2}
        if "b" not in self._base:
            done, _ = _run(cfg, params,
                           _reqs(cfg, [0, 1, 2, 3], det, shared_sys=True),
                           scheduler=PauseDecodePolicy())
            self._base["b"] = _det_streams(done, det)
        eng = Engine(cfg, params, mode=Mode.LLM42, policy=DRIFTY, window=5,
                     group=2, max_batch=8, capacity=128,
                     scheduler=OverlapPolicy(), spec_depth=2,
                     block_size=block_size, prefix_cache=cache,
                     prefill_chunk=8,
                     mem_policy=BlockMemoryPolicy(restore_cooldown=3))
        eng.runtime.latency_schedule = [float(x) for x in latency]
        for r in _reqs(cfg, [0, 1, 2, 3], det, shared_sys=True):
            eng.submit(r)
        it = 0
        while eng.step():
            it += 1
            if it in (pre1, pre2):
                for r in list(eng.running):
                    if r.rid == rid and r.state is not State.PREFILLING:
                        eng.preempt(r)
                        break
            assert it < 5000
        done = {r.rid: r for r in eng.finished}
        assert _det_streams(done, det) == self._base["b"], (
            pre1, pre2, rid, block_size, cache, latency
        )


class TestOnlineRunnerDrainsPreempted:
    def test_run_online_waits_for_the_restore_lane(self):
        """Regression (review): run_online's drain check must include
        engine.preempted — a victim preempted just before the rest of the
        workload finishes (inside the restore cooldown) used to be
        silently dropped from the results with a truncated stream."""
        from repro.serving.online import run_online
        cfg, params = _model()
        eng = Engine(cfg, params, mode=Mode.LLM42, policy=DRIFTY, window=5,
                     group=2, max_batch=4, capacity=128,
                     mem_policy=BlockMemoryPolicy(restore_cooldown=8))
        reqs = _reqs(cfg, [0, 1], {0}, max_new=30)
        orig_step = eng.step

        def step_and_preempt():
            alive = orig_step()
            r1 = next((r for r in eng.running if r.rid == 1), None)
            if r1 is not None and len(r1.committed) >= 25:
                # victim 0 evicted while 1 is about to finish
                r0 = next((r for r in eng.running if r.rid == 0), None)
                if r0 is not None and r0.state is not State.PREFILLING:
                    eng.preempt(r0)
            return alive

        eng.step = step_and_preempt
        res = run_online(eng, cfg, [(r, 0.0) for r in reqs])
        assert not eng.preempted
        assert sorted(res.latencies) == [0, 1]
        done = {r.rid: r for r in eng.finished}
        assert len(done[0].committed) == 30


class TestMemoryPolicy:
    def test_lru_victim_choice_is_deterministic(self):
        pol = BlockMemoryPolicy(restore_cooldown=4)
        a = Request(rid=1, prompt=[1])
        b = Request(rid=2, prompt=[1])
        a.last_sched, b.last_sched = 3, 5
        assert pol.pick_victim([a, b], now=10) is a
        a.last_sched = 5
        assert pol.pick_victim([b, a], now=10) is a  # tie -> lowest rid

    def test_restore_shield_is_advisory(self):
        pol = BlockMemoryPolicy(restore_cooldown=4)
        fresh = Request(rid=1, prompt=[1])
        fresh.restore_iter = 9
        old = Request(rid=2, prompt=[1])
        old.last_sched = 99
        # the freshly restored request is passed over while another
        # candidate exists...
        assert pol.pick_victim([fresh, old], now=10) is old
        # ...but forward progress beats the shield when it is alone
        assert pol.pick_victim([fresh], now=10) is fresh

    def test_restore_hysteresis_gates_readmission(self):
        pol = BlockMemoryPolicy(watermark_blocks=2, restore_cooldown=4)
        r = Request(rid=1, prompt=[1])
        r.preempt_iter = 10
        assert not pol.may_restore(r, free_blocks=99, need_blocks=1, now=12)
        assert pol.may_restore(r, free_blocks=99, need_blocks=1, now=14)
        assert not pol.may_restore(r, free_blocks=2, need_blocks=1, now=14)
