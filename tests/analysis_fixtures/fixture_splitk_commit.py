"""SEEDED VIOLATION (do not fix): split-K fast path on the verify path.

A commit-annotated verify step that picks its matmul schedule from
``FAST_PATH_POLICY.schedule_for(batch)`` — the batch-adaptive split-K
schedule leaking onto the commit side, which is the single invariant the
whole contract exists to prevent.  The checker must flag:
  * taint/fast-schedule-on-commit-path  (schedule_for reference in the root)
  * taint/unresolved-schedule           (helper's schedule= from an opaque
    attribute lookup)
"""

from __future__ import annotations

from repro.core.determinism import FAST_PATH_POLICY, matmul


def _project(x, w, sched):
    # schedule threaded from a parameter: resolved at the caller, not here
    return matmul(x, w, schedule=sched)


def _mystery_project(x, w, cfg):
    # VIOLATION: schedule from an opaque attribute — cannot be proven safe
    return matmul(x, w, schedule=cfg.decode_schedule)


# det: commit-path
def verify_step_fast(params, x, batch: int):
    # VIOLATION: batch-adaptive split-K schedule on the commit side
    sched = FAST_PATH_POLICY.schedule_for(batch)
    h = _project(x, params["w1"], sched)
    return _mystery_project(h, params["w2"], params["cfg"])
