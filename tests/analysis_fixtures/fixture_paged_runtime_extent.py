"""SEEDED VIOLATION (do not fix): reduction extent from a runtime table.

A paged-attention variant that walks the block table with a GRID axis
instead of an in-kernel loop: grid axis 2 merges per-block softmax
partials through scratch, so it is a reduction axis (the ``out_specs``
index_map ignores it) — and its extent is ``tables.shape[1]``, the
caller's block-table reach.  Two requests whose tables were allocated at
different lengths run DIFFERENT reduction trees over identical masked
content, which is exactly the workload-dependent schedule the
determinism contract forbids on the commit path.  The checker must flag
  * kernel_lint/grid-reduction-extent   (axis 2 extent is shape-derived)
The repo's real commit kernel (``kernels/paged_attention.py``) avoids
this by keeping both grid axes output-indexed and walking the table in a
``fori_loop`` of literal ``block_size`` chunks.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

F32 = jnp.float32


def _kernel(q_ref, kp_ref, vp_ref, pp_ref, tab_ref, o_ref, m_ref, d_ref,
            acc_ref, *, n_blocks: int, block_size: int, scale: float):
    s_idx = pl.program_id(2)
    q = q_ref[0, 0].astype(F32) * scale  # (G, D)
    bid = tab_ref[0, s_idx]
    kb = pl.load(
        kp_ref, (pl.dslice(bid, 1), slice(None), slice(None), slice(None))
    ).reshape(block_size, q.shape[-1]).astype(F32)
    vb = pl.load(
        vp_ref, (pl.dslice(bid, 1), slice(None), slice(None), slice(None))
    ).reshape(block_size, q.shape[-1]).astype(F32)
    pv = pl.load(pp_ref, (pl.dslice(bid, 1), slice(None))).reshape(block_size)

    s = jnp.dot(q, kb.T, preferred_element_type=F32)
    s = jnp.where((pv >= 0)[None, :], s, -jnp.inf)
    m_c = jnp.maximum(jnp.max(s, axis=-1), -1e30)
    e = jnp.exp(s - m_c[:, None])
    d_c = jnp.sum(e, axis=-1)
    o_c = jnp.dot(e, vb, preferred_element_type=F32)

    @pl.when(s_idx == 0)
    def _init():
        m_ref[...] = m_c
        d_ref[...] = d_c
        acc_ref[...] = o_c

    @pl.when(s_idx > 0)
    def _merge():
        m_new = jnp.maximum(m_ref[...], m_c)
        a_prev = jnp.exp(m_ref[...] - m_new)
        a_c = jnp.exp(m_c - m_new)
        m_ref[...] = m_new
        d_ref[...] = d_ref[...] * a_prev + d_c * a_c
        acc_ref[...] = acc_ref[...] * a_prev[:, None] + o_c * a_c[:, None]

    @pl.when(s_idx == n_blocks - 1)
    def _emit():
        denom = jnp.maximum(d_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / denom[:, None]).astype(o_ref.dtype)


def paged_attention_table_grid(
    q: jax.Array,         # (B, H, D)
    k_pool: jax.Array,    # (NB, bs, KV, D)
    v_pool: jax.Array,    # (NB, bs, KV, D)
    pos_pool: jax.Array,  # (NB, bs)
    tables: jax.Array,    # (B, nblk)
    *,
    interpret: bool = True,
) -> jax.Array:
    B, H, D = q.shape
    NB, bs, KVH, _ = k_pool.shape
    # VIOLATION: the reduction trip count is the runtime table length —
    # reallocate the table and the merge tree over the SAME tokens changes
    nblk = tables.shape[1]
    qg = q.reshape(B, KVH, H // KVH, D)
    B, KV, G, D = qg.shape
    tab = jnp.where(tables < 0, NB - 2, tables).astype(jnp.int32)
    out = pl.pallas_call(
        functools.partial(
            _kernel, n_blocks=nblk, block_size=bs, scale=D ** -0.5
        ),
        grid=(B, KV, nblk),
        in_specs=[
            pl.BlockSpec((1, 1, G, D), lambda b, h, s: (b, h, 0, 0)),
            pl.BlockSpec((NB, bs, 1, D), lambda b, h, s: (0, 0, h, 0)),
            pl.BlockSpec((NB, bs, 1, D), lambda b, h, s: (0, 0, h, 0)),
            pl.BlockSpec((NB, bs), lambda b, h, s: (0, 0)),
            pl.BlockSpec((1, nblk), lambda b, h, s: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, h, s: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KV, G, D), F32),
        scratch_shapes=[
            pltpu.VMEM((G,), F32),
            pltpu.VMEM((G,), F32),
            pltpu.VMEM((G, D), F32),
        ],
        interpret=interpret,
    )(qg, k_pool, v_pool, pos_pool, tab)
    return out.reshape(B, H, D)
