"""SEEDED VIOLATION (do not fix): bf16 accumulator in a reduction kernel.

A split-accumulation GEMM whose VMEM scratch and dot accumulation are
bfloat16.  Sub-f32 partials round between folds, so the result depends on
the fold order — the contract requires f32 combines on the commit path.
The checker must flag:
  * kernel_lint/accum-dtype  (VMEM scratch is bf16)
  * kernel_lint/accum-dtype  (preferred_element_type is bf16)
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BF16 = jnp.bfloat16
BK = 512
BM = 128
BN = 128


def _kernel(x_ref, w_ref, o_ref, acc_ref, *, k_steps: int):
    s = pl.program_id(2)
    # VIOLATION: bf16 accumulation — every partial rounds to 8 mantissa bits
    partial = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=BF16)

    @pl.when(s == 0)
    def _init():
        acc_ref[...] = partial

    @pl.when(s > 0)
    def _fold():
        acc_ref[...] = acc_ref[...] + partial

    @pl.when(s == k_steps - 1)
    def _emit():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def gemm_bf16_accum(x: jax.Array, w: jax.Array, *, interpret: bool = True) -> jax.Array:
    M, K = x.shape
    _, N = w.shape
    k_steps = K // BK
    return pl.pallas_call(
        functools.partial(_kernel, k_steps=k_steps),
        grid=(M // BM, N // BN, k_steps),
        in_specs=[
            pl.BlockSpec((BM, BK), lambda i, j, s: (i, s)),
            pl.BlockSpec((BK, BN), lambda i, j, s: (s, j)),
        ],
        out_specs=pl.BlockSpec((BM, BN), lambda i, j, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        scratch_shapes=[pltpu.VMEM((BM, BN), BF16)],  # VIOLATION: bf16 scratch
        interpret=interpret,
    )(x, w)
