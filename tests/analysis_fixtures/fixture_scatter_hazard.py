"""SEEDED VIOLATION (do not fix): overlapping float scatter-add.

A KV-style writeback that scatter-adds f32 rows at *data-dependent* slot
indices with no uniqueness guarantee: duplicate slots combine in hardware
order, not the fixed f32 schedule.  Exposes ``analysis_trace()`` so the
checker's fixture mode (and the hazard pass in tests) can lint the traced
jaxpr.  The checker must flag:
  * hazards/scatter-add-overlap
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

BATCH = 13
CAPACITY = 64
DIM = 8


def overlapping_writeback(cache, updates, slots):
    """cache: (CAP, D) f32; updates: (B, D) f32; slots: (B,) i32."""
    # VIOLATION: slots are data-dependent and may collide; float adds at
    # duplicate indices fold in implementation order
    return cache.at[slots].add(updates)


def analysis_trace():
    closed = jax.make_jaxpr(overlapping_writeback)(
        jax.ShapeDtypeStruct((CAPACITY, DIM), jnp.float32),
        jax.ShapeDtypeStruct((BATCH, DIM), jnp.float32),
        jax.ShapeDtypeStruct((BATCH,), jnp.int32),
    )
    return closed, BATCH
