"""SEEDED VIOLATION (do not fix): batch-adaptive reduction block size.

A GEMM whose K-block size is derived from the batch dimension M — the
reduction tree's chunking changes with how many requests are co-scheduled,
which is exactly the batch-variance the universal-schedule rule forbids.
The checker must flag:
  * kernel_lint/adaptive-block-size    (bk = min(...) over a shape name)
  * kernel_lint/grid-reduction-extent  (k_steps inherits the adaptive bk)
(The BlockSpec uses of bk are folded into the adaptive-block-size report.)
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

F32 = jnp.float32


def _kernel(x_ref, w_ref, o_ref, acc_ref, *, k_steps: int):
    s = pl.program_id(2)
    partial = jnp.dot(
        x_ref[...].astype(F32), w_ref[...].astype(F32), preferred_element_type=F32
    )

    @pl.when(s == 0)
    def _init():
        acc_ref[...] = partial

    @pl.when(s > 0)
    def _fold():
        acc_ref[...] = acc_ref[...] + partial

    @pl.when(s == k_steps - 1)
    def _emit():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def gemm_adaptive(x: jax.Array, w: jax.Array, *, interpret: bool = True) -> jax.Array:
    M, K = x.shape
    _, N = w.shape
    bm, bn = 128, 128
    # VIOLATION: K-chunk size adapts to batch size — small batches get a
    # finer split (more parallelism), changing the reduction tree with M.
    bk = min(K, 4096 // M * 128)
    k_steps = K // bk
    return pl.pallas_call(
        functools.partial(_kernel, k_steps=k_steps),
        grid=(M // bm, N // bn, k_steps),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, s: (i, s)),
            pl.BlockSpec((bk, bn), lambda i, j, s: (s, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), F32)],
        interpret=interpret,
    )(x, w)
