"""Per-kernel validation: Pallas (interpret=True) vs pure-jnp oracle,
with hypothesis shape/dtype sweeps."""

import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.core.determinism import Schedule
from repro.kernels import ops, ref
from repro.kernels.decode_attention import decode_attention
from repro.kernels.gemm_batch_invariant import gemm_batch_invariant
from repro.kernels.gemm_splitk import gemm_splitk
from repro.kernels.rmsnorm import rmsnorm


def _arrays(key, *shapes, dtype=jnp.float32):
    keys = jax.random.split(key, len(shapes))
    return [jax.random.normal(k, s, dtype) for k, s in zip(keys, shapes)]


class TestGemmSplitK:
    @settings(max_examples=12, deadline=None)
    @given(
        m=st.sampled_from([8, 128, 256]),
        k=st.sampled_from([128, 512]),
        n=st.sampled_from([128, 256]),
        splits=st.sampled_from([1, 2, 4]),
        cd=st.sampled_from(["float32", "bfloat16"]),
        dtype=st.sampled_from(["float32", "bfloat16"]),
    )
    def test_matches_oracle(self, m, k, n, splits, cd, dtype):
        """Tree-level semantics match the oracle.  NOTE: interpret mode
        delegates each block dot to the CPU backend, whose *within-dot*
        accumulation order varies with block geometry (ironically, the
        paper's own phenomenon) — so the contract here is allclose plus
        bitwise self-determinism and position-invariance below; on real
        TPU the MXU order is fixed per block shape."""
        dt = jnp.dtype(dtype)
        x, w = _arrays(jax.random.key(m + k + n + splits), (m, k), (k, n))
        x, w = x.astype(dt), w.astype(dt)
        got = gemm_splitk(x, w, splits=splits, combine_dtype=cd, bm=min(m, 128))
        want = ref.gemm_splitk(x, w, splits, cd)
        assert got.dtype == want.dtype
        # tolerance keyed to the COMBINE dtype: bf16 combine rounds partials
        # at ~0.4% relative of |values| (~sqrt(k) here), independent of the
        # input dtype
        if cd == "float32" and dt == jnp.float32:
            tol, rtol = 1e-3, 1e-3
        else:
            tol, rtol = 0.25, 2e-2
        assert jnp.allclose(
            got.astype(jnp.float32), want.astype(jnp.float32),
            atol=tol, rtol=rtol)
        # bitwise run-to-run determinism of the kernel itself (O2)
        again = gemm_splitk(x, w, splits=splits, combine_dtype=cd, bm=min(m, 128))
        assert (got == again).all()

    def test_split_count_changes_bits(self):
        x, w = _arrays(jax.random.key(0), (128, 1024), (1024, 128))
        a = gemm_splitk(x, w, splits=1, combine_dtype="bfloat16")
        b = gemm_splitk(x, w, splits=8, combine_dtype="bfloat16")
        assert not (a == b).all()  # the paper's Fig. 3 mechanism


class TestGemmBatchInvariant:
    @settings(max_examples=8, deadline=None)
    @given(
        m1=st.sampled_from([128, 256]),
        m2=st.sampled_from([8, 64]),
        k=st.sampled_from([256, 1024]),
        n=st.sampled_from([128]),
    )
    def test_batch_invariance(self, m1, m2, k, n):
        """The defining property: a row's bits don't depend on batch size.
        Holds because the kernel's block schedule is FIXED (inputs padded
        to the universal grid) — a shape-adaptive block size would break
        this, which is the whole point of the universal schedule."""
        x, w = _arrays(jax.random.key(m1 + k), (m1, k), (k, n))
        full = gemm_batch_invariant(x, w)
        sub = gemm_batch_invariant(x[:m2], w)
        assert (full[:m2] == sub).all()

    def test_close_to_oracle(self):
        x, w = _arrays(jax.random.key(1), (64, 2048), (2048, 128))
        got = gemm_batch_invariant(x, w)
        want = ref.gemm_batch_invariant(x, w)
        assert jnp.allclose(got, want, atol=1e-4, rtol=1e-5)


class TestDecodeAttention:
    @settings(max_examples=10, deadline=None)
    @given(
        b=st.sampled_from([1, 4]),
        kv=st.sampled_from([1, 2]),
        g=st.sampled_from([1, 4]),
        s=st.sampled_from([64, 256]),
        splits=st.sampled_from([1, 4]),
        cd=st.sampled_from(["float32", "bfloat16"]),
    )
    def test_matches_oracle(self, b, kv, g, s, splits, cd):
        h, d = kv * g, 64
        key = jax.random.key(b * 100 + s + splits)
        q, k, v = _arrays(key, (b, h, d), (b, s, kv, d), (b, s, kv, d))
        lengths = jax.random.randint(jax.random.key(9), (b,), 1, s + 1)
        got = decode_attention(q, k, v, lengths, kv_splits=splits, combine_dtype=cd)
        want = ref.decode_attention(q, k, v, lengths, splits, cd)
        assert jnp.allclose(got, want, atol=1e-6, rtol=1e-6)

    def test_kv_splits_change_bits(self):
        q, k, v = _arrays(jax.random.key(2), (2, 8, 64), (2, 512, 2, 64), (2, 512, 2, 64))
        lengths = jnp.array([512, 300])
        a = decode_attention(q, k, v, lengths, kv_splits=1, combine_dtype="bfloat16")
        b = decode_attention(q, k, v, lengths, kv_splits=8, combine_dtype="bfloat16")
        assert not (a == b).all()

    def test_masked_positions_have_no_effect(self):
        """Garbage beyond `lengths` must not leak — DVR's stale-KV argument."""
        q, k, v = _arrays(jax.random.key(3), (1, 4, 64), (1, 128, 1, 64), (1, 128, 1, 64))
        lengths = jnp.array([60])
        base = decode_attention(q, k, v, lengths, kv_splits=4)
        k2 = k.at[:, 60:].set(1e9)
        v2 = v.at[:, 60:].set(-1e9)
        poisoned = decode_attention(q, k2, v2, lengths, kv_splits=4)
        assert (base == poisoned).all()


class TestRMSNorm:
    @settings(max_examples=10, deadline=None)
    @given(
        m=st.sampled_from([1, 8, 128]),
        d=st.sampled_from([128, 512]),
        with_res=st.booleans(),
        dtype=st.sampled_from(["float32", "bfloat16"]),
    )
    def test_matches_oracle_bitwise(self, m, d, with_res, dtype):
        dt = jnp.dtype(dtype)
        x, sc, res = _arrays(jax.random.key(m + d), (m, d), (d,), (m, d))
        x, res = x.astype(dt), res.astype(dt)
        r = res if with_res else None
        got = rmsnorm(x, sc, r, bm=min(m, 128))
        want = ref.rmsnorm(x, sc, 1e-5, r)
        assert (got == want).all()

    def test_batch_invariant(self):
        x, sc = _arrays(jax.random.key(4), (128, 256), (256,))
        full = rmsnorm(x, sc)
        sub = rmsnorm(x[:16], sc, bm=16)
        assert (full[:16] == sub).all()


class TestOpsDispatch:
    def test_matmul_pallas_vs_jnp(self):
        x = jax.random.normal(jax.random.key(0), (3, 7, 384))
        w = jax.random.normal(jax.random.key(1), (384, 200))
        s = Schedule(splits=4, combine_dtype="bfloat16")
        a = ops.matmul(x, w, s, impl="pallas")
        b = ops.matmul(x, w, s, impl="jnp")
        assert jnp.allclose(a, b, atol=1e-2, rtol=1e-2)

    def test_decode_attention_dispatch(self):
        q = jax.random.normal(jax.random.key(0), (2, 4, 64))
        k = jax.random.normal(jax.random.key(1), (2, 128, 2, 64))
        v = jax.random.normal(jax.random.key(2), (2, 128, 2, 64))
        lengths = jnp.array([128, 64])
        s = Schedule(kv_splits=4, combine_dtype="bfloat16")
        a = ops.decode_attention(q, k, v, lengths, s, impl="pallas")
        b = ops.decode_attention(q, k, v, lengths, s, impl="jnp")
        assert jnp.allclose(a, b, atol=1e-6)

    def test_rmsnorm_dispatch(self):
        x = jax.random.normal(jax.random.key(0), (5, 300))
        sc = jax.random.normal(jax.random.key(1), (300,))
        a = ops.rmsnorm(x, sc, impl="pallas")
        b = ops.rmsnorm(x, sc, impl="jnp")
        assert jnp.allclose(a, b, atol=1e-5)
