"""Chunked-prefill lane: chunk-resume unit behavior, family coverage
(dense / sliding-window / prefix-embeds / encdec), the admission capacity
guard, and the cost model's prefill terms (causal KV reads, 3-way overlap).

The headline determinism property — committed streams bitwise identical
across chunk sizes, policies and arrival orders under mixed det/non-det
traffic — lives in ``tests/test_scheduler.py``.
"""

import dataclasses

import jax
import pytest

from repro.configs import get_smoke_config
from repro.core.determinism import Mode
from repro.models import init_params
from repro.models.multimodal import audio_frames, vision_embeds
from repro.serving import costmodel
from repro.serving.engine import Engine
from repro.serving.request import Request, SamplingParams, State
from repro.serving.scheduler import (
    OverlapPolicy,
    PauseDecodePolicy,
    SchedulerView,
)


def _cfg(family: str):
    if family == "dense":
        return get_smoke_config("llama3-8b")
    if family == "sliding":
        return dataclasses.replace(
            get_smoke_config("phi3-mini-3.8b"), attn_kind="sliding", window=8
        )
    if family == "prefix":
        return get_smoke_config("llava-next-mistral-7b")
    if family == "encdec":
        return get_smoke_config("seamless-m4t-medium")
    raise ValueError(family)


_MODELS = {}


def _model(family: str):
    if family not in _MODELS:
        cfg = _cfg(family)
        _MODELS[family] = (cfg, init_params(cfg, jax.random.key(0)))
    return _MODELS[family]


def _req(cfg, plen=21, max_new=6, seed=7, det=False, rid=0):
    r = Request(
        rid=rid, prompt=[(3 + 5 * j) % cfg.vocab_size for j in range(plen)],
        sampling=SamplingParams(max_new_tokens=max_new,
                                is_deterministic=det, seed=seed),
    )
    if cfg.family == "encdec":
        r.enc_embeds = audio_frames(
            jax.random.PRNGKey(0), 1, cfg.encoder_seq_len, cfg.d_model
        )
    if cfg.num_prefix_embeds:
        r.prefix_embeds = vision_embeds(
            jax.random.PRNGKey(0), 1, cfg.d_model, num_tiles=0
        )[:, : cfg.num_prefix_embeds]
    return r


class TestChunkResume:
    def test_prefill_pos_advances_chunk_by_chunk(self):
        cfg, params = _model("dense")
        eng = Engine(cfg, params, mode=Mode.NONDET, max_batch=4,
                     capacity=256, prefill_chunk=8)
        req = _req(cfg, plen=21, max_new=4)
        eng.submit(req)
        total = 21  # no prefix embeds
        seen_pos = []
        while req.state is not State.RUNNING:
            eng.step()
            seen_pos.append(req.prefill_pos)
        assert req.prefill_total == total
        # 21 tokens at C=8: three chunks of 8/8/5 real tokens
        assert seen_pos == [8, 16, 21]
        assert req.committed  # T0 sampled on the final chunk
        assert req.prefill_remaining == 0
        chunk_evs = [e for e in eng.events if e["kind"] == "prefill_chunk"]
        assert [e["start"] for e in chunk_evs] == [0, 8, 16]
        assert [e["tokens"] for e in chunk_evs] == [8, 8, 5]
        assert all(e["padded"] == 8 for e in chunk_evs)
        assert [e["done"] for e in chunk_evs] == [False, False, True]

    def test_prefilling_requests_never_decode_or_verify(self):
        """A PREFILLING request has no committed token: the scheduler must
        not hand it to the decode batch or a verify group.  The prefilling
        state is snapshotted BEFORE each step so events emitted while the
        request was mid-prefill are checked against that, not against its
        state after the step."""
        cfg, params = _model("dense")
        eng = Engine(cfg, params, mode=Mode.LLM42, window=5, group=2,
                     max_batch=4, capacity=256, prefill_chunk=4)
        short = _req(cfg, plen=5, max_new=8, det=True, rid=0)
        long = _req(cfg, plen=40, max_new=8, rid=1)
        eng.submit(short)
        eng.submit(long)
        n_ev = 0
        saw_prefilling_iter = False
        for _ in range(100):
            was_prefilling = long.state is State.PREFILLING
            if not eng.step():
                break
            new = costmodel.flatten_events(eng.events[n_ev:])
            n_ev = len(eng.events)
            if was_prefilling and long.slot >= 0:
                saw_prefilling_iter = True
                for ev in new:
                    if ev["kind"] in ("decode", "verify"):
                        assert long.rid not in ev["rids"], ev
        assert saw_prefilling_iter  # the guard actually exercised something
        done = {r.rid: r for r in eng.finished}
        assert len(done) == 2
        assert all(len(r.committed) == 8 for r in done.values())

    @pytest.mark.parametrize("family", ["dense", "sliding", "prefix", "encdec"])
    def test_families_bitwise_identical_to_exclusive(self, family):
        """Chunk-resumable prefill commits the same stream as the legacy
        exclusive pass for every attention family, at every chunk size."""
        cfg, params = _model(family)

        def run(chunk):
            eng = Engine(cfg, params, mode=Mode.NONDET, max_batch=4,
                         capacity=256, prefill_chunk=chunk)
            eng.submit(_req(cfg))
            return eng.run()[0].committed

        base = run(0)
        for chunk in (4, 8):
            assert run(chunk) == base, (family, chunk)

    @pytest.mark.parametrize("arch", ["rwkv6-3b", "jamba-1.5-large-398b"])
    def test_recurrent_families_join_the_chunked_lane(self, arch):
        """ssm/hybrid archs now prefill chunk-resumably: the per-chunk
        state checkpoint selects the state at each chunk's last REAL
        position, so final-chunk (and exclusive-path bucket) padding never
        advances the recurrent state — streams are bitwise identical
        across chunk sizes INCLUDING the exclusive path."""
        cfg = get_smoke_config(arch)
        params = init_params(cfg, jax.random.key(0))

        def run(chunk):
            eng = Engine(cfg, params, mode=Mode.NONDET, max_batch=2,
                         capacity=128, prefill_chunk=chunk)
            assert eng.chunked_prefill == (chunk > 0)
            eng.submit(_req(cfg, plen=21, max_new=4))
            done = eng.run()
            if chunk:
                assert any(
                    e["kind"] == "prefill_chunk"
                    for e in costmodel.flatten_events(eng.events)
                ), "chunked lane never ran"
            return done[0].committed

        base = run(0)
        assert len(base) == 4
        for chunk in (4, 8, 16):
            assert run(chunk) == base, (arch, chunk)


class TestCapacityGuard:
    def test_boundary(self):
        """Peak usage is max(prefill extent, prompt + budget), not the sum —
        decode writes overwrite the prefill pad tail."""
        cfg, params = _model("dense")
        eng = Engine(cfg, params, mode=Mode.NONDET, max_batch=2, capacity=64)
        # 21 + max_new must fit capacity 64 exactly (bucket(21) = 32 < 64)
        eng.submit(_req(cfg, plen=21, max_new=43, rid=0))
        with pytest.raises(ValueError, match="cannot fit"):
            eng.submit(_req(cfg, plen=21, max_new=44, rid=1))
        # a padded prompt that fits exactly is accepted (sum would reject)
        eng.submit(_req(cfg, plen=60, max_new=4, rid=2))  # bucket(60) = 64
        with pytest.raises(ValueError, match="cannot fit"):
            eng.submit(_req(cfg, plen=65, max_new=4, rid=3))  # bucket 128

    def test_det_requests_reserve_the_verify_window(self):
        cfg, params = _model("dense")
        eng = Engine(cfg, params, mode=Mode.LLM42, window=8, max_batch=2,
                     capacity=64)
        eng.submit(_req(cfg, plen=21, max_new=35, det=True, rid=0))
        with pytest.raises(ValueError, match="cannot fit"):
            eng.submit(_req(cfg, plen=21, max_new=36, det=True, rid=1))

    def test_det_requests_reserve_depth_times_window(self):
        """ISSUE 4 satellite: with spec_depth windows in flight, a det
        request reserves depth x (W-1) + 1 verify rows past its budget —
        boundary-exact at capacity."""
        cfg, params = _model("dense")
        # depth 3, W 8: spec = 3*7 + 1 = 22; prompt 21 + max_new 21 + 22
        # = 64 == capacity fits exactly, one more token does not
        eng = Engine(cfg, params, mode=Mode.LLM42, window=8, max_batch=2,
                     capacity=64, spec_depth=3)
        eng.submit(_req(cfg, plen=21, max_new=21, det=True, rid=0))
        with pytest.raises(ValueError, match="cannot fit"):
            eng.submit(_req(cfg, plen=21, max_new=22, det=True, rid=1))
        # non-deterministic traffic reserves nothing extra at any depth
        eng.submit(_req(cfg, plen=21, max_new=43, det=False, rid=2))
        with pytest.raises(ValueError, match="cannot fit"):
            eng.submit(_req(cfg, plen=21, max_new=44, det=False, rid=3))

    def test_chunked_extent_uses_chunk_padding(self):
        cfg, params = _model("dense")
        eng = Engine(cfg, params, mode=Mode.NONDET, max_batch=2, capacity=48,
                     prefill_chunk=32)
        eng.submit(_req(cfg, plen=32, max_new=8, rid=0))  # extent 32
        with pytest.raises(ValueError, match="cannot fit"):
            # 33 tokens pad to two 32-chunks: extent 64 > 48
            eng.submit(_req(cfg, plen=33, max_new=8, rid=1))

    def test_sliding_ring_buffer_never_rejects(self):
        cfg, params = _model("sliding")
        eng = Engine(cfg, params, mode=Mode.NONDET, max_batch=2, capacity=64)
        eng.submit(_req(cfg, plen=120, max_new=8))  # wraps, by design
        assert len(eng.queue) == 1


class TestPrefillCostModel:
    def _prefill_ev(self, padded, start=0, kind="prefill"):
        ev = {"kind": kind, "tokens": padded, "padded": padded, "wall": 0.0,
              "iter": 1}
        if kind == "prefill_chunk":
            ev["start"] = start
        return ev

    def test_prefill_kv_read_is_nonzero(self):
        """Regression: the seed priced causal KV reads during prefill at
        zero bytes (a dead ``* 0`` expression), underestimating prefill
        memory time."""
        cfg = get_smoke_config("llama3-8b")
        # memory-only hardware: infinite FLOPs isolate the bytes term
        hw = dataclasses.replace(costmodel.V5E, peak_flops=1e30)
        t = costmodel.step_time(cfg, self._prefill_ev(256), hw)
        pbytes = cfg.active_param_count() * hw.dtype_bytes
        kvb = costmodel.kv_bytes_per_token(cfg, hw.dtype_bytes)
        weights_and_writes = (pbytes + kvb * 256) / hw.hbm_bw
        assert t > weights_and_writes  # reads contribute, not just writes
        expected = (
            (pbytes + kvb * 256 + kvb * 128) / hw.hbm_bw
            + hw.launch_overhead_s
        )
        assert t == pytest.approx(expected)

    def test_chunk_cost_grows_with_context_depth(self):
        """A later chunk reads a deeper cache: same shape, higher cost."""
        cfg = get_smoke_config("llama3-8b")
        hw = dataclasses.replace(costmodel.V5E, peak_flops=1e30)
        early = costmodel.step_time(
            cfg, self._prefill_ev(64, start=0, kind="prefill_chunk"), hw)
        late = costmodel.step_time(
            cfg, self._prefill_ev(64, start=512, kind="prefill_chunk"), hw)
        assert late > early

    def test_three_way_overlap_uses_per_stream_rule(self):
        """Composite iteration cost is per-stream: decode + prefill
        serialize on the main stream (two launches, one queue), the verify
        pass rides the second stream derated by the cross-stream
        contention coefficient."""
        cfg = get_smoke_config("llama3-8b")
        hw = costmodel.V5E
        dev = {"kind": "decode", "batch": 4, "ctx_sum": 200,
               "schedule": (1, 1, "float32", False), "wall": 0.0, "iter": 1}
        vev = {"kind": "verify", "group": 4, "window": 8, "ctx_sum": 400,
               "wall": 0.0, "iter": 1}
        pev = self._prefill_ev(64, start=128, kind="prefill_chunk")
        t_main = sum(costmodel.step_time(cfg, e, hw) for e in (dev, pev))
        t_v = costmodel.step_time(cfg, vev, hw)
        got = costmodel.step_time(
            cfg, {"kind": "overlap", "decode": dev, "verify": vev,
                  "prefill": pev, "wall": 0.0, "iter": 1}, hw)
        assert got == pytest.approx(
            max(t_main, t_v) + hw.stream_contention * min(t_main, t_v)
        )
        assert max(t_main, t_v) < got < t_main + t_v

    def test_flatten_expands_prefill_sub_event(self):
        pev = self._prefill_ev(8, kind="prefill_chunk")
        dev = {"kind": "decode", "batch": 1, "wall": 0.0, "iter": 1}
        flat = costmodel.flatten_events(
            [{"kind": "overlap", "decode": dev, "prefill": pev,
              "wall": 0.0, "iter": 1}]
        )
        assert [e["kind"] for e in flat] == ["decode", "prefill_chunk"]


class TestPrefillPlans:
    def _prefilling(self, rid, remaining, total=100):
        r = Request(rid=rid, prompt=[1, 2, 3],
                    sampling=SamplingParams(max_new_tokens=10))
        r.state = State.PREFILLING
        r.prefill_total = total
        r.prefill_pos = total - remaining
        return r

    def _decodable(self, rid):
        r = Request(rid=rid, prompt=[1, 2, 3],
                    sampling=SamplingParams(max_new_tokens=10))
        r.committed = [5]
        r.state = State.RUNNING
        return r

    def _view(self, running, now=1):
        return SchedulerView(
            running=tuple(running), mode=Mode.LLM42, window=5, group=2,
            speculate_past_inflight=True, now=now,
            prefilling=tuple(
                r for r in running if r.state is State.PREFILLING
            ),
        )

    def test_pause_runs_prefill_exclusively(self):
        pre = self._prefilling(0, remaining=50)
        dec = self._decodable(1)
        plan = PauseDecodePolicy().plan(self._view([pre, dec]))
        assert plan.prefill is pre
        assert not plan.decode and not plan.verify

    def test_overlap_coschedules_prefill_with_decode(self):
        pre = self._prefilling(0, remaining=50)
        dec = self._decodable(1)
        plan = OverlapPolicy().plan(self._view([pre, dec]))
        assert plan.prefill is pre
        assert [r.rid for r in plan.decode] == [1]
        assert plan.overlapped

    def test_overlap_picks_shortest_remaining_prefill(self):
        """A short prompt's single chunk must not queue behind a long
        prefill (head-of-line blocking)."""
        long = self._prefilling(0, remaining=900, total=1000)
        short = self._prefilling(1, remaining=12, total=12)
        plan = OverlapPolicy().plan(self._view([long, short], now=1))
        assert plan.prefill is short
        # ties break by admission order
        a = self._prefilling(2, remaining=30)
        b = self._prefilling(3, remaining=30)
        plan2 = OverlapPolicy().plan(self._view([a, b], now=1))
        assert plan2.prefill is a

    def test_overlap_never_starves_a_long_prefill(self):
        """Every fourth iteration serves the admission-order head, so a
        stream of short arrivals cannot starve a long prefill forever."""
        long = self._prefilling(0, remaining=900, total=1000)
        short = self._prefilling(1, remaining=12, total=12)
        picks = [
            OverlapPolicy().plan(self._view([long, short], now=t)).prefill
            for t in range(1, 9)
        ]
        assert picks[3] is long and picks[7] is long  # now = 4, 8
        assert all(p is short for i, p in enumerate(picks) if (i + 1) % 4)
