"""Online runner + engine edge cases: EOS, arrival ordering, determinism of
the discrete-event clock."""

import jax
import pytest

from repro.configs import get_smoke_config
from repro.core.determinism import Mode
from repro.models import init_params
from repro.serving.engine import Engine
from repro.serving.online import percentile, run_online
from repro.serving.request import Request, SamplingParams


@pytest.fixture(scope="module")
def model():
    cfg = get_smoke_config("llama3-8b")
    return cfg, init_params(cfg, jax.random.key(0))


def _reqs(cfg, n, det_rids=(), max_new=12, eos=None):
    out = []
    for i in range(n):
        out.append(Request(
            rid=i, prompt=[(3 * i + j) % cfg.vocab_size for j in range(8)],
            sampling=SamplingParams(
                max_new_tokens=max_new, is_deterministic=(i in det_rids),
                seed=50 + i, eos_id=eos,
            ),
        ))
    return out


class TestOnlineRunner:
    def test_latency_accounting(self, model):
        cfg, params = model
        eng = Engine(cfg, params, mode=Mode.NONDET, max_batch=4, capacity=128)
        reqs = _reqs(cfg, 4)
        arrivals = [0.0, 0.0, 5.0, 5.0]
        res = run_online(eng, cfg, list(zip(reqs, arrivals)))
        assert len(res.latencies) == 4
        assert all(v > 0 for v in res.latencies.values())
        assert all(res.ttfts[r] <= res.latencies[r] for r in res.ttfts)
        # the late arrivals cannot have been served before t=5
        assert res.total_time >= 5.0

    def test_clock_is_deterministic(self, model):
        cfg, params = model

        def once():
            eng = Engine(cfg, params, mode=Mode.LLM42, window=5, group=2,
                         max_batch=4, capacity=128)
            reqs = _reqs(cfg, 4, det_rids={0})
            res = run_online(eng, cfg, list(zip(reqs, [0.0, 0.1, 0.2, 0.3])))
            return res.total_time, sorted(res.latencies.items())

        assert once() == once()

    def test_percentile(self):
        assert percentile([1.0, 2.0, 3.0, 4.0], 50) == 3.0
        assert percentile([5.0], 99) == 5.0

    def test_exhausting_max_iters_raises(self, model):
        """Regression (ISSUE 3 satellite): falling out of the loop at
        max_iters used to silently return truncated latency/TTFT dicts —
        quietly partial benchmark numbers.  Now it raises (default) or
        warns with the unfinished counts."""
        cfg, params = model
        eng = Engine(cfg, params, mode=Mode.NONDET, max_batch=4, capacity=128)
        reqs = _reqs(cfg, 2, max_new=12)
        with pytest.raises(RuntimeError, match="partial"):
            run_online(eng, cfg, list(zip(reqs, [0.0, 0.0])), max_iters=3)

    def test_exhausting_max_iters_warn_mode(self, model):
        cfg, params = model
        eng = Engine(cfg, params, mode=Mode.NONDET, max_batch=4, capacity=128)
        reqs = _reqs(cfg, 2, max_new=12)
        with pytest.warns(RuntimeWarning, match="run_online exhausted"):
            res = run_online(eng, cfg, list(zip(reqs, [0.0, 0.0])),
                             max_iters=3, on_exhaust="warn")
        assert len(res.latencies) < 2  # partial, and flagged as such

    def test_clock_rides_engine_streams(self, model):
        """The discrete-event clock IS the engine's main-stream clock."""
        cfg, params = model
        eng = Engine(cfg, params, mode=Mode.LLM42, window=5, group=2,
                     max_batch=4, capacity=128)
        reqs = _reqs(cfg, 3, det_rids={0})
        res = run_online(eng, cfg, list(zip(reqs, [0.0, 0.1, 0.2])))
        assert res.total_time == pytest.approx(eng.runtime.now)
        assert eng.runtime.main.busy > 0.0


class TestEngineEdges:
    def test_eos_stops_generation(self, model):
        cfg, params = model
        # find an eos token that the model actually emits: run once, grab
        # the 3rd output token, then re-run with it as eos
        eng = Engine(cfg, params, mode=Mode.NONDET, max_batch=2, capacity=128)
        eng.submit(_reqs(cfg, 1, max_new=12)[0])
        probe = eng.run()[0].committed
        eos = probe[3]

        eng2 = Engine(cfg, params, mode=Mode.NONDET, max_batch=2, capacity=128)
        eng2.submit(_reqs(cfg, 1, max_new=12, eos=eos)[0])
        out = eng2.run()[0].committed
        assert eos in out
        assert len(out) <= 4 + 1

    def test_eos_deterministic_request(self, model):
        """EOS inside a verification window: the committed output must stop
        at EOS identically across traffic mixes."""
        cfg, params = model
        eng = Engine(cfg, params, mode=Mode.NONDET, max_batch=2, capacity=128)
        eng.submit(_reqs(cfg, 1, max_new=16)[0])
        eos = eng.run()[0].committed[5]

        def run_det(n_extra):
            e = Engine(cfg, params, mode=Mode.LLM42, window=4, group=2,
                       max_batch=4, capacity=128)
            rs = _reqs(cfg, 1 + n_extra, det_rids={0}, max_new=16, eos=None)
            rs[0].sampling.eos_id = eos
            for r in rs:
                e.submit(r)
            return {r.rid: r.committed for r in e.run()}[0]

        a, b = run_det(0), run_det(3)
        assert a == b

    def test_slot_reuse_after_retirement(self, model):
        """More requests than slots: slots must recycle without cross-request
        state leakage (pool wipe on free)."""
        cfg, params = model
        eng = Engine(cfg, params, mode=Mode.LLM42, window=4, group=2,
                     max_batch=2, capacity=128)
        for r in _reqs(cfg, 6, det_rids={0, 3}, max_new=8):
            eng.submit(r)
        done = eng.run()
        assert len(done) == 6
        assert all(len(r.committed) == 8 for r in done)
        # det request 0 unaffected by slot churn: same as solo run
        solo = Engine(cfg, params, mode=Mode.LLM42, window=4, group=2,
                      max_batch=2, capacity=128)
        solo.submit(_reqs(cfg, 1, det_rids={0}, max_new=8)[0])
        assert solo.run()[0].committed == [
            r for r in done if r.rid == 0][0].committed
