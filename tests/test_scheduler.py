"""Scheduler subsystem tests: policy plumbing, the no-idle guarantee, and
the headline invariant — committed token streams for deterministic requests
are bitwise identical across scheduler policies and arrival interleavings.
"""

import jax
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_smoke_config
from repro.core.determinism import Mode, ReductionPolicy
from repro.models import init_params
from repro.serving import scheduler as sched
from repro.serving.costmodel import flatten_events
from repro.serving.engine import Engine
from repro.serving.request import Request, SamplingParams
from repro.serving.scheduler import (
    AdaptivePolicy,
    OverlapPolicy,
    PauseDecodePolicy,
    Plan,
    SchedulerView,
    default_policy,
)

#: aggressive drift so rollbacks actually happen at toy scale
DRIFTY = ReductionPolicy(
    thresholds=((2, 16), (4, 8), (16, 4)), combine_dtype="bfloat16"
)


@pytest.fixture(scope="module")
def model():
    cfg = get_smoke_config("llama3-8b")
    return cfg, init_params(cfg, jax.random.key(0))


def _reqs(cfg, rids, det_rids, max_new=18):
    return [
        Request(
            rid=i, prompt=[(5 * i + j) % cfg.vocab_size for j in range(9)],
            sampling=SamplingParams(
                max_new_tokens=max_new, is_deterministic=(i in det_rids),
                seed=70 + i,
            ),
        )
        for i in rids
    ]


def _run(cfg, params, requests, *, scheduler, window=5, group=2, **kw):
    eng = Engine(cfg, params, mode=Mode.LLM42, policy=DRIFTY, window=window,
                 group=group, max_batch=8, capacity=256, scheduler=scheduler,
                 **kw)
    for r in requests:
        eng.submit(r)
    done = {r.rid: r for r in eng.run()}
    return done, eng


def _long_reqs(cfg, rids, det_rids, max_new=14, plen=21):
    """Prompts long enough to span several prefill chunks."""
    return [
        Request(
            rid=i, prompt=[(5 * i + j) % cfg.vocab_size for j in range(plen)],
            sampling=SamplingParams(
                max_new_tokens=max_new, is_deterministic=(i in det_rids),
                seed=70 + i,
            ),
        )
        for i in rids
    ]


# ----------------------------------------------------------------------
# pure policy logic (no model)
# ----------------------------------------------------------------------


def _fake_req(rid, *, det=True, committed=1, cands=0, max_new=100,
              inflight=0):
    r = Request(rid=rid, prompt=[1, 2, 3],
                sampling=SamplingParams(max_new_tokens=max_new,
                                        is_deterministic=det))
    r.committed = list(range(100, 100 + committed))
    r.candidates = list(range(200, 200 + cands))
    from repro.serving.request import InflightVerify

    for i in range(inflight):
        r.pipeline.append(InflightVerify(
            cands=[7 + 2 * i, 8 + 2 * i], submitted_at=i, ready_at=i + 2,
        ))
    return r


def _view(running, *, window=5, group=2, speculate=True, now=1,
          verify_inflight=0, acceptance=None, spec_depth=1):
    if acceptance is None:
        acceptance = {r.rid: r.accept_ema for r in running}
    return SchedulerView(
        running=tuple(running), mode=Mode.LLM42, window=window, group=group,
        speculate_past_inflight=speculate, now=now,
        verify_inflight=verify_inflight, acceptance=acceptance,
        spec_depth=spec_depth,
    )


class TestPolicyPlans:
    def test_pause_is_exclusive(self):
        """PauseDecodePolicy never co-schedules: one pass per iteration."""
        ready = _fake_req(0, cands=4)  # full window for W=5
        nondet = _fake_req(1, det=False)
        plan = PauseDecodePolicy().plan(_view([ready, nondet]))
        # group not full, decoding possible -> decode only
        assert plan.decode and not plan.verify
        ready2 = _fake_req(2, cands=4)
        plan2 = PauseDecodePolicy().plan(_view([ready, ready2, nondet]))
        # full group -> verify only; the nondet request idles (limitation (1))
        assert plan2.verify and not plan2.decode

    def test_overlap_coschedules(self):
        """OverlapPolicy: the verify group rides alongside the decode batch —
        decodable requests are NEVER dropped to make room for verification."""
        ready = [_fake_req(0, cands=4), _fake_req(1, cands=4)]
        nondet = [_fake_req(2, det=False), _fake_req(3, det=False)]
        plan = OverlapPolicy().plan(_view(ready + nondet))
        assert plan.overlapped
        # nondets ride the batch; the submitted rows join it too (their
        # first past-window token shares the launch quantum)
        assert set(r.rid for r in plan.decode) == {0, 1, 2, 3}
        assert set(r.rid for r in plan.verify) == {0, 1}
        # on recurrent archs the submitted rows must NOT speculate
        plan2 = OverlapPolicy().plan(_view(ready + nondet, speculate=False))
        assert set(r.rid for r in plan2.decode) == {2, 3}

    def test_overlap_launches_partial_groups(self):
        plan = OverlapPolicy().plan(
            _view([_fake_req(0, cands=4), _fake_req(1, det=False)])
        )
        assert plan.verify and plan.decode

    def test_inflight_request_keeps_decoding(self):
        r = _fake_req(0, cands=1, inflight=1)
        assert r in sched.decodable(_view([r]))
        # …but not when the engine reports no state pool to restore from
        assert r not in sched.decodable(_view([r], speculate=False))
        # and it cannot be submitted again at the depth-1 default
        assert r not in sched.verify_ready(_view([r]))

    def test_spec_depth_opens_multi_window_launches(self):
        """With spec_depth > 1 a request with a full window AND windows in
        flight may launch again — until its FIFO reaches the bound."""
        r = _fake_req(0, cands=4, inflight=1)
        assert r not in sched.verify_ready(_view([r], spec_depth=1))
        assert r in sched.verify_ready(_view([r], spec_depth=2))
        deep = _fake_req(1, cands=4, inflight=3)
        assert deep not in sched.verify_ready(_view([deep], spec_depth=3))
        assert deep in sched.verify_ready(_view([deep], spec_depth=4))
        plan = OverlapPolicy().plan(_view([r], spec_depth=2))
        assert [q.rid for q in plan.verify] == [0]

    def test_default_policy_per_mode(self):
        assert isinstance(default_policy(Mode.LLM42), OverlapPolicy)
        assert isinstance(default_policy(Mode.NONDET), PauseDecodePolicy)
        assert isinstance(default_policy(Mode.BATCH_INVARIANT),
                          PauseDecodePolicy)

    def test_plan_flags(self):
        assert Plan().empty
        assert not Plan(decode=[_fake_req(0)]).overlapped
        assert Plan(decode=[_fake_req(0)], verify=[_fake_req(1)]).overlapped

    def test_overlap_depth_cap_holds_launches(self):
        """max_inflight gates new deferred launches while the verify
        stream is saturated — the pipelining-depth knob."""
        ready = _fake_req(0, cands=4)
        nondet = _fake_req(1, det=False)
        capped = OverlapPolicy(max_inflight=2)
        held = capped.plan(_view([ready, nondet], verify_inflight=2))
        assert not held.verify and held.decode  # launch held, decode rides
        freed = capped.plan(_view([ready, nondet], verify_inflight=1))
        assert freed.verify

    def test_overlap_depth_cap_never_overshoots(self):
        """A launch fills only the remaining room: in-flight depth stays
        <= max_inflight even when a whole group is ready (a pre-launch
        gate alone would overshoot by up to group-1)."""
        ready = [_fake_req(0, cands=4), _fake_req(1, cands=4)]
        capped = OverlapPolicy(max_inflight=2)
        plan = capped.plan(_view(ready, verify_inflight=1))
        assert [r.rid for r in plan.verify] == [0]  # room for one, not two
        full = capped.plan(_view(ready, verify_inflight=0))
        assert [r.rid for r in full.verify] == [0, 1]


class TestAdaptivePolicy:
    """Acceptance-adaptive demotion/promotion (pure plan logic)."""

    def test_identical_to_overlap_while_acceptance_high(self):
        reqs = [_fake_req(0, cands=4), _fake_req(1, det=False)]
        a = AdaptivePolicy().plan(_view(reqs))
        o = OverlapPolicy().plan(_view(reqs))
        assert ([r.rid for r in a.decode], [r.rid for r in a.verify]) == (
            [r.rid for r in o.decode], [r.rid for r in o.verify]
        )
        assert not a.sync_verify

    def test_low_acceptance_demotes_to_sync_exclusive(self):
        r = _fake_req(0, cands=1)  # one candidate: eager depth is enough
        r.accept_ema = 0.1
        nondet = _fake_req(1, det=False)
        plan = AdaptivePolicy().plan(_view([r, nondet]))
        # pause-style: sync verdict, exclusive iteration
        assert plan.sync_verify
        assert [q.rid for q in plan.verify] == [0]
        assert not plan.decode

    def test_eager_depth_scales_with_acceptance(self):
        # ema 0.5 at window 5 -> depth 2: one candidate is NOT ready yet
        r = _fake_req(0, cands=1)
        r.accept_ema = 0.5
        pol = AdaptivePolicy()
        plan = pol.plan(_view([r]))
        assert not plan.verify and [q.rid for q in plan.decode] == [0]
        r.candidates.append(201)  # second candidate reaches the depth
        plan2 = pol.plan(_view([r]))
        assert plan2.sync_verify and [q.rid for q in plan2.verify] == [0]

    def test_hysteresis_promotes_back(self):
        r = _fake_req(0, cands=4)
        r.accept_ema = 0.1
        pol = AdaptivePolicy(demote_below=0.6, promote_above=0.8)
        assert pol.plan(_view([r])).sync_verify
        r.accept_ema = 0.7  # between the thresholds: stays demoted
        assert pol.plan(_view([r])).sync_verify
        r.accept_ema = 0.9  # recovered: promoted to overlapped verification
        plan = pol.plan(_view([r]))
        assert not plan.sync_verify and plan.verify

    def test_demoted_request_cannot_hold_a_group_open(self):
        """A partial deferred group must not wait for a demoted request —
        it will never join a deferred launch."""
        ready = _fake_req(0, cands=4)
        demoted = _fake_req(1, cands=0)
        demoted.accept_ema = 0.1
        plan = AdaptivePolicy().plan(_view([ready, demoted], group=3))
        # ready launches deferred (group not held); demoted decodes along
        assert [r.rid for r in plan.verify] == [0]
        assert not plan.sync_verify
        assert 1 in [r.rid for r in plan.decode]

    def test_demoted_request_drains_its_pipeline_before_sync(self):
        """Sync verification replays from committed[-1]: a freshly demoted
        request with windows still in flight must wait them out."""
        r = _fake_req(0, cands=1, inflight=1)
        r.accept_ema = 0.1
        pol = AdaptivePolicy()
        plan = pol.plan(_view([r], spec_depth=2))
        assert not plan.verify  # in-flight window pending: no sync launch
        r.pipeline.clear()
        plan2 = pol.plan(_view([r], spec_depth=2))
        assert plan2.sync_verify and [q.rid for q in plan2.verify] == [0]

    def test_pipeline_depth_scales_with_acceptance(self):
        """Acceptance-scaled pipelining: a promoted request's in-flight
        depth shrinks with its EMA — full spec_depth at 1.0, depth 1 near
        the demotion threshold."""
        r = _fake_req(0, cands=4, inflight=2)
        pol = AdaptivePolicy()
        # ema 1.0 at spec_depth 4 -> depth 4: two in flight, may launch
        plan = pol.plan(_view([r], spec_depth=4))
        assert [q.rid for q in plan.verify] == [0]
        # ema 0.62 (not demoted) -> round(0.62 * 4) = 2: FIFO already full
        r.accept_ema = 0.62
        plan2 = pol.plan(_view([r], spec_depth=4))
        assert not plan2.verify
        assert not plan2.sync_verify  # not demoted, just depth-throttled


# ----------------------------------------------------------------------
# engine integration: determinism across policies / arrival orders
# ----------------------------------------------------------------------


class TestCrossPolicyDeterminism:
    def test_policies_and_interleavings_agree_bitwise(self, model):
        """The repo's whole point: committed streams of deterministic
        requests are bitwise identical under PauseDecodePolicy,
        OverlapPolicy, and different arrival interleavings."""
        cfg, params = model
        det = {0, 2}
        runs = []
        for scheduler, order in [
            (PauseDecodePolicy(), [0, 1, 2, 3]),
            (OverlapPolicy(), [0, 1, 2, 3]),
            (PauseDecodePolicy(), [3, 2, 1, 0]),
            (OverlapPolicy(), [2, 0, 3, 1]),
        ]:
            done, _ = _run(cfg, params, _reqs(cfg, order, det),
                           scheduler=scheduler)
            runs.append({rid: done[rid].committed for rid in det})
        for other in runs[1:]:
            assert other == runs[0]

    def test_overlap_with_larger_verify_latency(self, model):
        """A slower (more async) verifier means deeper speculation past the
        window — the committed stream must not move.  Routed through the
        costed clock (verify_latency_ms); the integer shim is deprecated."""
        cfg, params = model
        det = {0}
        base, _ = _run(cfg, params, _reqs(cfg, [0, 1, 2], det),
                       scheduler=PauseDecodePolicy())
        for latency_ms in (5.0, 20.0, 60.0):
            got, _ = _run(cfg, params, _reqs(cfg, [0, 1, 2], det),
                          scheduler=OverlapPolicy(),
                          verify_latency_ms=latency_ms)
            assert got[0].committed == base[0].committed, latency_ms

    def test_integer_verify_latency_shim_is_removed(self, model):
        """ISSUE 5 satellite: the integer ``verify_latency`` shim
        (deprecated since the multi-window PR) is gone — the continuous
        ``verify_latency_ms`` clock is the only latency knob."""
        cfg, params = model
        with pytest.raises(TypeError, match="verify_latency"):
            Engine(cfg, params, mode=Mode.LLM42, verify_latency=2)
        eng = Engine(cfg, params, mode=Mode.LLM42)
        assert not hasattr(eng, "verify_latency")

    def test_spec_depth_sweep_agrees_bitwise(self, model):
        """Acceptance criterion: committed streams bitwise identical
        across --spec-depth {1, 2, 4, 8} under both clock modes."""
        cfg, params = model
        det = {0, 2}
        base, _ = _run(cfg, params, _reqs(cfg, [0, 1, 2, 3], det),
                       scheduler=PauseDecodePolicy())
        for depth in (1, 2, 4, 8):
            for kw in ({}, dict(verify_latency_ms=25.0)):
                got, eng = _run(cfg, params, _reqs(cfg, [0, 1, 2, 3], det),
                                scheduler=OverlapPolicy(), spec_depth=depth,
                                **kw)
                for rid in det:
                    assert got[rid].committed == base[rid].committed, (
                        depth, kw, rid
                    )
                if kw and depth > 1:
                    # the costed clock keeps windows airborne long enough
                    # for the depth to actually be exercised
                    assert eng.statepool.peak_depth > 1, (depth, kw)

    def test_adaptive_policy_agrees_bitwise(self, model):
        """AdaptivePolicy reschedules (demotions, eager partial windows,
        sync verdicts) but never moves a committed token — under the
        drifty policy it WILL demote, so this exercises the demoted path."""
        cfg, params = model
        det = {0, 2}
        base, _ = _run(cfg, params, _reqs(cfg, [0, 1, 2, 3], det),
                       scheduler=PauseDecodePolicy())
        got, eng = _run(cfg, params, _reqs(cfg, [0, 1, 2, 3], det),
                        scheduler=AdaptivePolicy())
        for rid in det:
            assert got[rid].committed == base[rid].committed
        # the drifty bench policy flips constantly: demotion must trigger
        assert eng.scheduler._demoted or all(
            r.accept_ema > 0.6 for r in got.values()
        )

    def test_costed_clock_agrees_bitwise(self, model):
        """The continuous (costed) stream clock changes when verdicts land,
        not what they say: committed streams match the logical-shim runs
        across verify latencies and depth caps."""
        cfg, params = model
        det = {0, 2}
        base, _ = _run(cfg, params, _reqs(cfg, [0, 1, 2, 3], det),
                       scheduler=PauseDecodePolicy())
        for kw in (
            dict(scheduler=OverlapPolicy(), verify_latency_ms=0.0),
            dict(scheduler=OverlapPolicy(), verify_latency_ms=20.0),
            dict(scheduler=OverlapPolicy(max_inflight=1),
                 verify_latency_ms=20.0),
            dict(scheduler=AdaptivePolicy(), verify_latency_ms=20.0),
        ):
            got, eng = _run(cfg, params, _reqs(cfg, [0, 1, 2, 3], det), **kw)
            for rid in det:
                assert got[rid].committed == base[rid].committed, kw
            assert eng.runtime.makespan > 0.0

    def test_stochastic_sampling_unaffected_by_policy(self, model):
        cfg, params = model
        reqs = _reqs(cfg, [0, 1, 2, 3], {0, 1}, max_new=14)
        for r in reqs:
            r.sampling.temperature = 0.8
        a, _ = _run(cfg, params, reqs, scheduler=PauseDecodePolicy())
        reqs2 = _reqs(cfg, [0, 1, 2, 3], {0, 1}, max_new=14)
        for r in reqs2:
            r.sampling.temperature = 0.8
        b, _ = _run(cfg, params, reqs2, scheduler=OverlapPolicy())
        assert a[0].committed == b[0].committed
        assert a[1].committed == b[1].committed


class TestChunkedPrefillDeterminism:
    def test_streams_identical_across_chunk_sizes(self, model):
        """Acceptance criterion: committed streams bitwise identical across
        prefill_chunk in {0, 4, 8, W}, both policies, and shuffled arrival
        orders — a per-request fixed chunk schedule is shape-consistent by
        construction."""
        cfg, params = model
        det = {0, 2}
        base, _ = _run(cfg, params, _long_reqs(cfg, [0, 1, 2, 3], det),
                       scheduler=PauseDecodePolicy())
        for chunk, scheduler, order in [
            (4, PauseDecodePolicy(), [0, 1, 2, 3]),
            (4, OverlapPolicy(), [0, 1, 2, 3]),
            (8, OverlapPolicy(), [3, 2, 1, 0]),
            (16, OverlapPolicy(), [2, 0, 3, 1]),
        ]:
            got, eng = _run(cfg, params, _long_reqs(cfg, order, det),
                            scheduler=scheduler, prefill_chunk=chunk)
            for rid in det:
                assert got[rid].committed == base[rid].committed, (
                    chunk, scheduler.name, order, rid
                )
            assert any(
                e["kind"] == "prefill_chunk"
                for e in flatten_events(eng.events)
            ), "chunked lane never ran"

    def test_overlap_coschedules_prefill_chunks(self, model):
        """Under OverlapPolicy a prefill chunk rides composite iterations
        instead of stalling the decode batch."""
        cfg, params = model
        _, eng = _run(cfg, params, _long_reqs(cfg, [0, 1, 2, 3], {0}),
                      scheduler=OverlapPolicy(), prefill_chunk=4)
        assert any(
            ev["kind"] == "overlap" and "prefill" in ev for ev in eng.events
        )


class TestVerdictOrdering:
    def test_final_verdict_retires_same_iteration(self, model):
        """Regression (engine.step ordering): due verdicts must land BEFORE
        retirement, so a request whose last in-flight verdict lands this
        iteration retires this iteration — finish_time was off by one and
        drain took an extra step."""
        cfg, params = model
        done, eng = _run(cfg, params, _reqs(cfg, [0], {0}),
                         scheduler=OverlapPolicy())
        r = done[0]
        last_ev_iter = max(e["iter"] for e in eng.events)
        # the verdict lands (one logical tick) the iteration after the last
        # device pass and the request retires in that same iteration
        assert r.finish_time == last_ev_iter + 1
        assert eng._now == last_ev_iter + 1  # no dead drain iterations

    def test_out_of_order_verdict_landing_is_bitwise_identical(self, model):
        """Property (ISSUE 3): verify groups launched at different times
        whose verdicts land in the same iteration — or in INVERTED launch
        order — must commit identical streams.  A per-launch latency
        schedule forces the inversions deterministically."""
        cfg, params = model
        det = {0, 1, 2}
        base, _ = _run(cfg, params, _reqs(cfg, [0, 1, 2, 3], det),
                       scheduler=PauseDecodePolicy())
        # group=1 => every request launches its own verify group, so the
        # schedule staggers landings ACROSS concurrently-running requests
        for schedule in ([5, 1, 4, 1], [7, 1, 1, 5, 1], [2, 2, 2],
                         [9, 1, 8, 1, 7, 1]):
            eng = Engine(cfg, params, mode=Mode.LLM42, policy=DRIFTY,
                         window=5, group=1, max_batch=8, capacity=256,
                         scheduler=OverlapPolicy())
            eng.runtime.latency_schedule = [float(x) for x in schedule]
            for r in _reqs(cfg, [0, 1, 2, 3], det):
                eng.submit(r)
            got = {r.rid: r for r in eng.run()}
            for rid in det:
                assert got[rid].committed == base[rid].committed, schedule

    def test_multiwindow_out_of_order_landings_are_bitwise_identical(
            self, model):
        """Tentpole acceptance: several windows PER REQUEST airborne while
        verdicts land in inverted launch order across requests — in-order
        splicing within each request keeps every committed stream on the
        reference sequence."""
        cfg, params = model
        det = {0, 1, 2}
        base, _ = _run(cfg, params, _reqs(cfg, [0, 1, 2, 3], det),
                       scheduler=PauseDecodePolicy())
        for schedule in ([9, 1, 8, 1, 7, 1], [2, 9, 2, 9, 2],
                         [13, 1, 1, 11, 1, 1, 9]):
            eng = Engine(cfg, params, mode=Mode.LLM42, policy=DRIFTY,
                         window=5, group=1, max_batch=8, capacity=256,
                         scheduler=OverlapPolicy(), spec_depth=3)
            eng.runtime.latency_schedule = [float(x) for x in schedule]
            for r in _reqs(cfg, [0, 1, 2, 3], det):
                eng.submit(r)
            got = {r.rid: r for r in eng.run()}
            for rid in det:
                assert got[rid].committed == base[rid].committed, schedule
            assert eng.statepool.peak_depth > 1, schedule  # depth exercised

    _base_cache = {}

    @settings(max_examples=4, deadline=None)
    @given(
        schedule=st.lists(st.integers(1, 9), min_size=2, max_size=10),
        depth=st.integers(1, 4),
    )
    def test_random_latency_schedules_never_move_tokens(self, model,
                                                        schedule, depth):
        """Hypothesis sweep (ISSUE 4 satellite): random per-launch latency
        schedules drive inverted verdict landings ACROSS requests while
        multi-window pipelines are airborne; in-order splicing WITHIN each
        request must keep committed streams bitwise identical.  (Falls
        back to the deterministic example sweep without hypothesis.)"""
        cfg, params = model
        if "base" not in self._base_cache:  # one baseline run per session
            self._base_cache["base"], _ = _run(
                cfg, params, _reqs(cfg, [0, 1], {0, 1}, max_new=10),
                scheduler=PauseDecodePolicy())
        base = self._base_cache["base"]
        eng = Engine(cfg, params, mode=Mode.LLM42, policy=DRIFTY, window=5,
                     group=1, max_batch=8, capacity=256,
                     scheduler=OverlapPolicy(), spec_depth=depth)
        eng.runtime.latency_schedule = [float(x) for x in schedule]
        for r in _reqs(cfg, [0, 1], {0, 1}, max_new=10):
            eng.submit(r)
        got = {r.rid: r for r in eng.run()}
        assert got[0].committed == base[0].committed, (schedule, depth)
        assert got[1].committed == base[1].committed, (schedule, depth)


class TestNoIdleGuarantee:
    def test_verify_never_idles_decodable_requests(self, model):
        """Acceptance criterion: under OverlapPolicy, every verify pass that
        launches while anything is decodable is co-scheduled with that
        decode batch (event log shows no standalone verify with co-decodable
        requests), and overlapped iterations actually occur."""
        cfg, params = model
        done, eng = _run(cfg, params, _reqs(cfg, range(6), {0, 1, 2}),
                         scheduler=OverlapPolicy(), group=3)
        assert any(e["kind"] == "overlap" for e in eng.events)
        for ev in eng.events:
            if ev["kind"] == "verify":  # standalone verify iteration
                assert ev["n_decodable"] == 0, (
                    "verify pass idled a decodable request"
                )
            if ev["kind"] == "overlap":
                # every decodable request rode the batch; submitted rows may
                # join on top (they resume speculating in the launch quantum)
                assert ev["decode"]["batch"] >= ev["verify"]["n_decodable"]

    def test_pause_policy_does_idle(self, model):
        """Sanity check of the ablation: the seed policy DOES stall the fast
        path (otherwise the tentpole is vacuous)."""
        cfg, params = model
        done, eng = _run(cfg, params, _reqs(cfg, range(6), {0, 1, 2}),
                         scheduler=PauseDecodePolicy(), group=3)
        assert not any(e["kind"] == "overlap" for e in eng.events)
        stalled = [
            ev for ev in eng.events
            if ev["kind"] == "verify" and ev["n_decodable"] > 0
        ]
        assert stalled, "pause policy never stalled a decodable request"

    def test_event_log_flattening(self, model):
        cfg, params = model
        _, eng = _run(cfg, params, _reqs(cfg, [0, 1], {0}),
                      scheduler=OverlapPolicy())
        flat = flatten_events(eng.events)
        assert not any(e["kind"] == "overlap" for e in flat)
        n_leaf = sum(
            2 if e["kind"] == "overlap" else 1 for e in eng.events
        )
        assert len(flat) == n_leaf
