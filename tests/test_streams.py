"""Unit tests for the dual-clock execution-stream runtime (host logic).

The stream abstraction decides only *when* things happen — the committed-
stream invariance across clock modes is asserted in test_scheduler.py; here
we pin the time semantics themselves: in-order launch, queueing, the
contention rule, the logical shim's tick-equivalence, and event-driven
skipping.
"""

import pytest

from repro.serving.streams import DualClockRuntime, EventQueue, ExecStream


class TestExecStream:
    def test_launch_is_in_order(self):
        s = ExecStream("main")
        a = s.launch(2.0)
        b = s.launch(3.0)
        assert a == (0.0, 2.0)
        assert b == (2.0, 5.0)  # queues behind the first launch
        assert s.now == 5.0
        assert s.busy == 5.0

    def test_not_before_delays_start(self):
        s = ExecStream("verify")
        start, finish = s.launch(1.0, not_before=4.0)
        assert (start, finish) == (4.0, 5.0)

    def test_wait_idles_without_busy(self):
        s = ExecStream("main")
        s.launch(1.0)
        s.wait(10.0)
        assert s.now == 10.0
        assert s.busy == 1.0
        s.wait(3.0)  # no-op: frontier already past
        assert s.now == 10.0

    def test_occupancy(self):
        s = ExecStream("verify")
        s.launch(2.0)
        s.wait(8.0)
        assert s.occupancy(8.0) == pytest.approx(0.25)
        assert s.occupancy(0.0) == 0.0


class TestEventQueue:
    def test_pop_due_orders_by_time_then_push_order(self):
        q = EventQueue()
        q.push(5.0, "verdict", "late")
        q.push(2.0, "verdict", "a")
        q.push(2.0, "verdict", "b")
        due = q.pop_due(3.0)
        assert [e.payload for e in due] == ["a", "b"]  # same-time: push order
        assert len(q) == 1
        assert q.peek_time() == 5.0

    def test_empty_peek(self):
        assert EventQueue().peek_time() is None


class TestLogicalShim:
    """cost_fn=None reproduces the old integer verify_latency semantics."""

    def test_one_tick_per_iteration(self):
        rt = DualClockRuntime(latency=2.0)
        assert rt.logical
        assert rt.begin_iteration() == 1.0
        rt.charge({"kind": "decode"})  # charges are free ticks-wise
        assert rt.begin_iteration() == 2.0

    def test_verdict_ready_latency_ticks_after_launch(self):
        rt = DualClockRuntime(latency=2.0)
        rt.begin_iteration()  # now = 1
        ready = rt.launch_verify({"kind": "verify"})
        assert ready == 3.0  # lands at the start of iteration 3

    def test_latency_schedule_overrides_per_launch(self):
        rt = DualClockRuntime(latency=1.0)
        rt.latency_schedule = [3.0, 1.0]
        rt.begin_iteration()
        first = rt.launch_verify({"kind": "verify"})
        rt.begin_iteration()
        second = rt.launch_verify({"kind": "verify"})
        rt.begin_iteration()
        third = rt.launch_verify({"kind": "verify"})  # past schedule: default
        # second lands BEFORE first despite launching later — out of order
        assert (first, second, third) == (4.0, 3.0, 4.0)


class TestCostedClock:
    def _rt(self, costs, latency=0.0, contention=0.5):
        return DualClockRuntime(
            lambda ev: costs[ev["kind"]], latency=latency,
            contention=contention,
        )

    def test_main_passes_serialize(self):
        rt = self._rt({"decode": 2.0, "prefill_chunk": 1.0})
        rt.begin_iteration()
        rt.charge({"kind": "decode"})
        rt.charge({"kind": "prefill_chunk"})
        assert rt.now == 3.0  # one stream, two launches: serial
        assert rt.main.busy == 3.0

    def test_deferred_verify_rides_second_stream_with_contention(self):
        rt = self._rt({"decode": 2.0, "verify": 1.0})
        rt.begin_iteration()
        rt.charge({"kind": "decode"})
        ready = rt.launch_verify({"kind": "verify"})
        # verify [0, 1] fully overlaps decode [0, 2]: main slips by c*1
        assert rt.now == pytest.approx(2.5)
        assert ready == pytest.approx(1.0)  # completion + 0 extra latency
        assert rt.verify.busy == 1.0

    def test_verify_tail_spills_into_backlog_not_main(self):
        rt = self._rt({"decode": 1.0, "verify": 5.0})
        rt.begin_iteration()
        rt.charge({"kind": "decode"})
        ready = rt.launch_verify({"kind": "verify"})
        # only the overlapped first second slows main; the 4s tail rides
        # the verify stream (old composite model would block ~5s here)
        assert rt.now == pytest.approx(1.5)
        assert ready == pytest.approx(5.0)
        assert rt.verify_backlog == pytest.approx(3.5)
        assert rt.makespan == pytest.approx(5.0)

    def test_verify_passes_queue_on_their_stream(self):
        rt = self._rt({"verify": 3.0, "decode": 1.0}, contention=0.0)
        rt.begin_iteration()
        rt.charge({"kind": "decode"})
        first = rt.launch_verify({"kind": "verify"})
        rt.begin_iteration()
        rt.charge({"kind": "decode"})
        second = rt.launch_verify({"kind": "verify"})
        # second launch cannot start before the first completes: genuine
        # stream occupancy, verdicts 3s apart however fast main runs
        assert first == pytest.approx(3.0)
        assert second == pytest.approx(6.0)

    def test_sync_verify_blocks_main(self):
        rt = self._rt({"verify": 3.0})
        rt.begin_iteration()
        rt.launch_verify({"kind": "verify"}, sync=True)
        assert rt.now == pytest.approx(3.0)  # exclusive: main waited
        assert rt.verify.busy == 3.0  # occupancy sees sync passes too

    def test_extra_latency_delays_verdict_only(self):
        rt = self._rt({"decode": 1.0, "verify": 1.0}, latency=10.0,
                      contention=0.0)
        rt.begin_iteration()
        rt.charge({"kind": "decode"})
        ready = rt.launch_verify({"kind": "verify"})
        assert ready == pytest.approx(11.0)
        assert rt.now == pytest.approx(1.0)  # latency is not stream work

    def test_idle_iteration_skips_to_earliest_deadline(self):
        rt = self._rt({"verify": 1.0, "decode": 1.0}, latency=7.0,
                      contention=0.0)
        rt.begin_iteration()
        rt.charge({"kind": "decode"})
        ready = rt.launch_verify({"kind": "verify"})
        rt.begin_iteration()  # nothing decodable: no main work
        rt.end_iteration()
        assert rt.now == pytest.approx(ready)  # event-driven skip

    def test_skip_never_jumps_past_the_horizon(self):
        """An arrival during a verdict-gated idle window must be admitted
        at its arrival time, not at the verdict deadline."""
        rt = self._rt({"verify": 1.0, "decode": 1.0}, latency=7.0,
                      contention=0.0)
        rt.begin_iteration()
        rt.charge({"kind": "decode"})
        ready = rt.launch_verify({"kind": "verify"})
        rt.skip_horizon = 3.0  # next request arrives at t=3
        rt.begin_iteration()
        rt.end_iteration()
        assert rt.now == pytest.approx(3.0)  # stopped at the arrival
        rt.skip_horizon = None
        rt.begin_iteration()
        rt.end_iteration()
        assert rt.now == pytest.approx(ready)  # then on to the deadline

    def test_stale_horizon_does_not_pin_the_clock(self):
        rt = self._rt({"verify": 1.0}, latency=5.0, contention=0.0)
        rt.begin_iteration()
        ready = rt.launch_verify({"kind": "verify"})
        rt.main.wait(2.0)
        rt.skip_horizon = 1.0  # already in the past: must be ignored
        rt.begin_iteration()
        rt.end_iteration()
        assert rt.now == pytest.approx(ready)

    def test_idle_until(self):
        rt = self._rt({"decode": 1.0})
        rt.idle_until(4.0)
        assert rt.now == 4.0
        assert rt.main.busy == 0.0

    def test_outstanding_verdict_telemetry(self):
        """Multi-window pipelining keeps several verdicts airborne; the
        runtime tracks the live count and the peak (benchmark telemetry)."""
        rt = self._rt({"verify": 1.0, "decode": 1.0}, latency=50.0,
                      contention=0.0)
        for _ in range(3):
            rt.begin_iteration()
            rt.charge({"kind": "decode"})
            rt.launch_verify({"kind": "verify"})
        assert rt.outstanding_verdicts == 3
        assert rt.peak_outstanding == 3
        rt.main.wait(200.0)
        rt.begin_iteration()  # drains all due deadlines
        assert rt.outstanding_verdicts == 0
        assert rt.peak_outstanding == 3  # peak is sticky
